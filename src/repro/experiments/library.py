"""Named experiment library: the paper-figure parameter studies
(fig8/9/10/11/12/14a/15) as `Experiment` definitions, plus reusable
multi-axis grids.  The `benchmarks/fig*.py` scripts pull their sweeps
from here — the hand-rolled loops those scripts used to carry are now
grid axes, so every figure run is cacheable and resumable.

Derive hooks are module-level (process pools pickle them) and read only
what the backend provides: `mean_goodput`/`completion_slot` exist on
both backends, full `goodput`/`rtt` timelines only on NumPy results —
hooks needing those degrade gracefully so the same experiment still
runs under a `sim.backend` axis.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, Tuple

import numpy as np

from repro.scenarios.registry import fig11_partial_uplink
from repro.scenarios.spec import (FaultSpec, ScenarioSpec, SimSpec,
                                  TenantSpec, TopologySpec, WorkloadSpec)

from .axes import Axis, product, zip_axes
from .experiment import Experiment, register_experiment

# the paper's paired stacks: SPX NIC + adaptive routing vs commodity
# Ethernet (DCQCN + ECMP); fig11 pairs SPX with weighted-AR instead
ETH_SPX = zip_axes(Axis("sim.nic", ("dcqcn", "spx")),
                   Axis("sim.routing", ("ecmp", "ar")))
ETH_SPX_WAR = zip_axes(Axis("sim.nic", ("dcqcn", "spx")),
                       Axis("sim.routing", ("ecmp", "war")))

STACK_NAMES = {"dcqcn": "eth", "spx": "spx", "swlb": "sw_lb",
               "global": "globalcc", "esr": "esr"}


# ---------------------------------------------------------------------------
# derive hooks
# ---------------------------------------------------------------------------

def fig8_metrics(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    gp = res.mean_goodput
    out = {"p01_bw": float(np.quantile(gp, 0.01)),
           "median_bw": float(np.median(gp))}
    rtt = getattr(res, "rtt", None)          # NumPy backend only
    if rtt is not None:
        lat = rtt[rtt.shape[0] // 2:]
        out["p99_lat_us"] = float(np.quantile(lat, 0.99))
    return out


def fig9_metrics(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    """Collective bw is gated by the slowest flow (stragglers, §2.1)."""
    if "victim" in res.groups:
        vi = res.groups.index("victim")
        vflows = res.mean_goodput[res.group_of == vi]
        v = vflows.reshape(16, 15).sum(1)
        return {"victim_bw_frac": float(v.mean()),
                "cct_gated_bw": float(vflows.min() * 15)}
    per_rank = res.mean_goodput.reshape(32, 31).sum(1)
    return {"rank_bw_frac": float(per_rank.mean()),
            "cct_gated_bw": float(res.mean_goodput.min() * 31)}


def fig10_metrics(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    vi = res.groups.index("victim")
    vflows = res.mean_goodput[res.group_of == vi]
    return {"victim_gated_bw": max(float(vflows.min() * 15), 1e-3)}


def fig11_metrics(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    n_ranks = len(c.tenants["main"])
    per_rank = res.mean_goodput.reshape(n_ranks, -1).sum(1)
    # the degraded leaf's ranks gate the collective (§2.1)
    return {"bw_frac": float(per_rank.mean()),
            "cct_gated_bw": float(res.mean_goodput.min() * (n_ranks - 1))}


def fig12_metrics(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    goodput = getattr(res, "goodput", None)  # NumPy backend only
    if goodput is None:
        return {}
    g = goodput[:, 0]
    fail_slot = spec.faults[0].start_slot
    # recovery = first slot after failure with goodput >= 0.9 x the
    # 3-plane steady state (0.75 of original line rate)
    post = np.flatnonzero((np.arange(len(g)) > fail_slot)
                          & (g >= 0.9 * 0.75))
    rec_ms = ((post[0] - fail_slot) * spec.sim.slot_us / 1000.0
              if len(post) else float("inf"))
    return {"recovery_ms": float(rec_ms),
            "steady": float(g[-10:].mean()),
            "pre_fail": float(g[fail_slot - 5])}


def train_comms_metrics(spec: ScenarioSpec, c, res) -> Dict:
    """Per-step completion times from the compiled training schedule:
    each step's time is its last closed collective (DP sync / EP a2a)
    completion minus the scheduled step start (`comms.TrainSchedule`).
    Works on both backends — only `completion_slot` is read.  The
    in-run baseline is the fastest step, so a single faulted run yields
    its own inflation and recovery ratios."""
    scheds = getattr(c, "schedules", ())
    comp = getattr(res, "completion_slot", None)
    if not scheds or comp is None:
        return {}
    sched = scheds[0]
    st = sched.step_times(np.asarray(comp), spec.sim.slots)
    ref = max(float(np.nanmin(st)), 1e-9)
    return {"step_time_slots": [float(x) for x in st],
            "step_period": int(sched.step_period),
            "step_inflation": float(np.nanmax(st) / ref),
            "last_step_ratio": float(st[-1] / ref)}


def fig14a_metrics(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    gp = np.maximum(res.mean_goodput, 1e-3)
    return {"p99_cct": float(1.0 / np.quantile(gp, 0.01))}


def fig15_per_nic(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    mi = res.groups.index("main")
    gp = res.mean_goodput[res.group_of == mi]
    n_nics = 8 if spec.workloads[0].kind == "one2many" else 24
    per_nic = gp.reshape(n_nics, -1).sum(1)
    return {"per_nic_bw": float(per_nic.mean())}


def fig15_convergence(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    mi = res.groups.index("main")
    sel = res.group_of == mi
    comp = res.completion_slot[sel].astype(float)
    comp[comp < 0] = spec.sim.slots // spec.sim.record_every
    warm = spec.workloads[0].start_slot
    comp -= warm
    # per-flow rate 1/16 -> msg duration in slots = 16 x bytes_total
    msg_slots = spec.workloads[0].bytes_total * 16
    ratio = msg_slots / max(float(np.mean(comp)), 1e-9)
    return {"normalized_bw": float(min(ratio, 1.0))}


def fig15_oscillation(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    goodput = getattr(res, "goodput", None)  # NumPy backend only
    if goodput is None:
        return {}
    mi = res.groups.index("main")
    series = goodput[:, res.group_of == mi].sum(1)
    tail = series[len(series) // 2:]
    return {"bw_cv": float(tail.std() / max(tail.mean(), 1e-9)),
            "mean_bw": float(tail.mean())}


# ---------------------------------------------------------------------------
# spec factories for the non-registry testbeds
# ---------------------------------------------------------------------------

def fig14a_spec() -> ScenarioSpec:
    """Fig 14a proxy fabric: 64-rank random ring on a 128-host
    single-plane 16x16 fabric (SPX/WAR stack).  The k concurrent failed
    links arrive as a `faults` axis."""
    return ScenarioSpec(
        name="fig14a_fabric_flaps",
        description="Fig 14a: P99 CCT of a random 64-rank ring vs k "
                    "concurrent fabric link failures.",
        topo=TopologySpec(n_leaves=16, n_spines=16, hosts_per_leaf=8,
                          n_planes=1),
        tenants=(TenantSpec("main", placement="random", n_hosts=64),
                 TenantSpec("rest", placement="remainder")),
        workloads=(WorkloadSpec("permutation", tenant="main"),),
        sim=SimSpec(slots=300, nic="spx", routing="war", seed=11),
        workload_seed=11)


def fig14a_faults(k: int) -> Tuple[FaultSpec, ...]:
    """Exactly k uniformly-drawn uplink kills at slot 0."""
    if k == 0:
        return ()
    return (FaultSpec("random_fail", start_slot=0, count=k, frac=1.0),)


def fig15_testbed(kind: str, asym: bool, seed: int,
                  slots: int = 500) -> ScenarioSpec:
    """The Fig 15/16 testbed: 3 leaves x 16 NICs, 4 planes of 200G ports
    (access 0.25 x line), leaf uplinks 2 spines x 8 parallel x 0.25;
    planes 2/3 trimmed to 25% uplinks when `asym`.  'main' is the first
    8 NICs of every leaf, 'noise' the second 8."""
    mains = tuple(h for leaf in range(3)
                  for h in range(leaf * 16, leaf * 16 + 8))
    noises = tuple(h for leaf in range(3)
                   for h in range(leaf * 16 + 8, leaf * 16 + 16))
    faults = ((FaultSpec("leaf_trim", start_slot=0, plane=2, leaf=1,
                         frac=0.25),
               FaultSpec("leaf_trim", start_slot=0, plane=3, leaf=2,
                         frac=0.25)) if asym else ())
    main_wl = (WorkloadSpec("one2many", tenant="main", srcs=8)
               if kind == "one2many"
               else WorkloadSpec("all2all", tenant="main"))
    return ScenarioSpec(
        name=f"fig15_{kind}_{'asym' if asym else 'base'}",
        description="Fig 15 testbed: main+noise bursts under "
                    "noise-induced plane asymmetry.",
        topo=TopologySpec(n_leaves=3, n_spines=2, hosts_per_leaf=16,
                          n_planes=4, parallel_links=8, link_cap=0.25,
                          access_cap=0.25),
        tenants=(TenantSpec("main", placement="explicit", hosts=mains),
                 TenantSpec("noise", placement="explicit", hosts=noises)),
        workloads=(main_wl, WorkloadSpec("all2all", tenant="noise")),
        faults=faults,
        sim=SimSpec(slots=slots, seed=seed))


# ---------------------------------------------------------------------------
# registered experiments
# ---------------------------------------------------------------------------

@register_experiment
def fig8_bisection_stacks() -> Experiment:
    return Experiment(
        name="fig8_bisection_stacks",
        base="fig8_bisection", axes=ETH_SPX, derive=fig8_metrics,
        description="Fig 8: RDMA bisection per stack — p01/median bw "
                    "and p99 latency.")


@register_experiment
def fig9_isolation() -> Experiment:
    return Experiment(
        name="fig9_isolation",
        axes=product(Axis("scenario", ("fig9_single_all2all",
                                       "fig9_victim_noise")),
                     ETH_SPX),
        derive=fig9_metrics,
        description="Fig 9: single All2All capacity ceiling + "
                    "victim/noise isolation per stack.")


@register_experiment
def fig10_step_time() -> Experiment:
    return Experiment(
        name="fig10_step_time",
        axes=product(Axis("scenario", ("fig10_victim_alone",
                                       "fig10_victim_noise")),
                     ETH_SPX),
        derive=fig10_metrics,
        description="Fig 10: victim training-collective bandwidth with "
                    "and without bisection noise (step-time input).")


@register_experiment
def fig11_static_resiliency() -> Experiment:
    keeps = (1.0, 0.75, 0.5, 0.25)
    base = replace(fig11_partial_uplink(1.0), name="fig11_partial_uplink")
    return Experiment(
        name="fig11_static_resiliency",
        base=base,
        axes=product(
            Axis("faults",
                 tuple(fig11_partial_uplink(k).faults for k in keeps),
                 labels=tuple(int(k * 100) for k in keeps)),
            ETH_SPX_WAR),
        derive=fig11_metrics,
        description="Fig 11 / §6.4: All2All bw vs surviving leaf-uplink "
                    "fraction, SPX (weighted-AR) vs ETH.")


@register_experiment
def fig12_flap_recovery() -> Experiment:
    return Experiment(
        name="fig12_flap_recovery",
        base="fig12_plane_flap",
        axes=zip_axes(Axis("sim.nic", ("spx", "swlb")),
                      Axis("sim.slots", (600, 12000)),
                      Axis("sim.sw_lb_delay_ms", (0.0, 1000.0))),
        derive=fig12_metrics,
        description="Fig 12: hardware PLB vs software LB plane-flap "
                    "recovery time.")


@register_experiment
def fig14a_fabric_flaps() -> Experiment:
    ks = tuple(range(11))
    return Experiment(
        name="fig14a_fabric_flaps",
        base=fig14a_spec(),
        axes=product(Axis("faults", tuple(fig14a_faults(k) for k in ks),
                          labels=ks),
                     Axis("seed", (0, 1))),
        derive=fig14a_metrics,
        description="Fig 14a: P99 ring CCT vs k concurrent fabric link "
                    "failures (expectation-weighted by the caller).")


@register_experiment
def fig15_lb_asymmetry() -> Experiment:
    specs = tuple(fig15_testbed(kind, asym, seed=8)
                  for kind in ("one2many", "all2all")
                  for asym in (False, True))
    return Experiment(
        name="fig15_lb_asymmetry",
        axes=product(Axis("scenario", specs),
                     Axis("sim.nic", ("spx", "global"))),
        derive=fig15_per_nic,
        description="Fig 15: per-plane CC (SPX PLB) vs a single global "
                    "CC context under plane asymmetry.")


@register_experiment
def fig15_msg_convergence() -> Experiment:
    sizes = (5, 20, 80, 320)
    warm = 150          # noise saturates the degraded planes first
    base = fig15_testbed("one2many", True, seed=9)
    base = replace(
        base,
        workloads=(replace(base.workloads[0], start_slot=warm),
                   base.workloads[1]),
        sim=replace(base.sim, warmup_frac=0.0))
    return Experiment(
        name="fig15_msg_convergence",
        base=base,
        axes=zip_axes(
            # ideal per-flow rate = NIC line / 16 destinations
            Axis("workloads[0].bytes_total",
                 tuple(ms / 16 for ms in sizes), labels=sizes),
            Axis("sim.slots", tuple(8 * ms + 2 * warm for ms in sizes))),
        derive=fig15_convergence,
        description="Fig 15c: message-size convergence — short bursts "
                    "end before the PLB accumulates per-plane state.")


@register_experiment
def fig15_esr_oscillation() -> Experiment:
    return Experiment(
        name="fig15_esr_oscillation",
        base=fig15_testbed("all2all", True, seed=10, slots=600),
        axes=Axis("sim.nic", ("spx", "esr")),
        derive=fig15_oscillation,
        description="Fig 15d: entangled CC+LB loops (ESR) oscillate; "
                    "SPX stays stable.")


def topo_kind_metrics(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    """Post-failure bisection throughput per endpoint (the §3.1
    multiplane-vs-hierarchy comparison metric: the scenario's warmup
    window ends after the fault, so `mean_goodput` is already the
    post-failure steady state) plus the straggler tail that gates
    collectives."""
    gp = res.mean_goodput
    return {"post_failure_bw": float(gp.mean()),
            "post_failure_p01": float(np.quantile(gp, 0.01))}


@register_experiment
def topo_kind_resiliency() -> Experiment:
    """The paper's headline architecture argument as ONE sweep: topology
    kind x routing x failure fraction on the equal-bisection pair.  On
    the JAX backend the whole grid rides the megabatch path (one fused
    launch per topology-kind shape bucket)."""
    return Experiment(
        name="topo_kind_resiliency",
        axes=(Axis("scenario", ("bisection_multiplane",
                                "bisection_fat_tree")),
              Axis("sim.routing", ("war", "ecmp")),
              Axis("faults[0].frac", (0.05, 0.15, 0.25))),
        derive=topo_kind_metrics,
        description="§3.1/§6.4: flat multiplane vs 3-tier fat-tree "
                    "post-failure bisection throughput, kind x routing "
                    "x fault-frac.")


@register_experiment
def train_comms_resiliency() -> Experiment:
    """Training co-simulation: collective schedules compiled from real
    `ModelConfig`s (dense llama3-8b and MoE phi3.5, reduced) run through
    the fabric, with a plane flap pinned to step 1's gradient-sync
    window.  Expected signature (both backends, exact): the flapped
    step's time inflates >= 1.2x the in-run baseline step and the final
    step recovers to <= 1.1x after the heal."""
    return Experiment(
        name="train_comms_resiliency",
        axes=Axis("scenario", ("train_step_baseline", "train_step_flap",
                               "train_step_flap_moe")),
        derive=train_comms_metrics,
        description="Collective-schedule co-simulation: plane flap "
                    "during DP sync -> step-time inflation -> recovery "
                    "(dense + MoE schedules, both backends).")


def reroute_metrics(spec: ScenarioSpec, c, res) -> Dict[str, float]:
    """Reaction-policy comparison columns: the p50 completion slot (for
    the §6.4 '7% at 10% failures' inflation check against the frac=0.0
    rows) — blackholed bytes and worst reaction window are standard
    `ScenarioMetrics` columns already."""
    comp = res.completion_slot[res.completion_slot >= 0]
    return {"p50_completion": (float(np.median(comp)) if comp.size
                               else float("nan"))}


@register_experiment
def reroute_reaction() -> Experiment:
    """The failure-reaction policy sweep: precomputed backup failover
    (hardware PLB-style) vs post-detection ECMP re-randomization
    (software LB-style) across topology kind, failure fraction, and
    detection latency.  Expected signatures: backup's blackhole window
    closes within detect_slots of the fault while rehash stays dark for
    detect+converge (>= 10x longer at the registry defaults), and
    backup's p50 completion at 10% failures inflates <= 1.10x over the
    frac=0 rows."""
    return Experiment(
        name="reroute_reaction",
        axes=(Axis("scenario", ("reroute_random_failures",
                                "reroute_random_failures_ft")),
              Axis("reaction.mode", ("backup", "rehash")),
              Axis("faults[0].frac", (0.0, 0.10)),
              Axis("reaction.detect_slots", (1, 4))),
        derive=reroute_metrics,
        description="§6.4: reroute-policy grid — mode x topology kind x "
                    "fault-frac x detection latency; blackhole windows "
                    "and completion inflation per policy.")


@register_experiment
def resiliency_fault_planes() -> Experiment:
    return Experiment(
        name="resiliency_fault_planes",
        base="allreduce_under_random_failures",
        axes=product(Axis("faults[0].frac", (0.05, 0.1, 0.2)),
                     Axis("topo.n_planes", (1, 2))),
        description="Showcase multi-axis grid: random-failure fraction "
                    "x plane count on the ring-allreduce scenario "
                    "(README's worked example).")
