"""Unified experiment API: arbitrary-axis sweeps over `ScenarioSpec`
override paths, columnar `ResultSet` results, and a content-hashed run
cache with resume (see README "Experiments")."""
from .axes import Axis, Chain, Product, Zip, chain, product, zip_axes
from .cache import RunCache, canonicalize, spec_key
from .execute import (compile_cache_entries, enable_compile_cache,
                      execute_points)
from .experiment import (EXPERIMENTS, Experiment, ExperimentPoint,
                         get_experiment, list_experiments,
                         register_experiment, run_experiment)
from .overrides import OverridePathError, apply_override, get_path
from .resultset import ResultSet, axis_column
from . import library  # noqa: F401  (populates the experiment registry)
