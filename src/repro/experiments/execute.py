"""Grid-point executor shared by `Experiment.run` and the deprecated
`sweep`/`sweep_many` shims.

'numpy' fans points out over a process pool; 'jax' dispatches the whole
grid through the megabatch path by default — every structurally
compatible point (any mix of routing / nic / fault / seed axes) stacks
into ONE fused `jit(vmap)` launch (mesh-sharded over multiple devices)
that compiles once (`repro.netsim.jx.megabatch`), with host prep of
bucket k+1 pipelined against device execution of bucket k — or, with
`jx_dispatch="group"`, through the legacy per-(scenario, routing, nic)
grouped-vmap path.  Either way
completed rows stream back through `on_result(index, metrics)` as they
finish — per future on the pool path, per finalized batch/group on the
JAX paths — which is what lets `run_experiment` write the cache and
fill the `ResultSet` incrementally instead of all-or-nothing at the
end.

`enable_compile_cache` points JAX's persistent compilation cache at a
directory, so the megabatch program (one compile per grid *structure*)
survives process restarts; `scenario_sweep --compile-cache-dir` wires
it up and reports entry counts next to the run-cache stats.
"""
from __future__ import annotations

import multiprocessing
import os
import sys
import time
from concurrent.futures import (FIRST_COMPLETED, ProcessPoolExecutor,
                                ThreadPoolExecutor, wait)
from dataclasses import replace
from functools import partial
from typing import Callable, Dict, List, Optional

from repro.scenarios.compile import compile_scenario
from repro.scenarios.runner import ScenarioMetrics, distill_metrics, run_point
from repro.scenarios.spec import ScenarioSpec

OnResult = Callable[[int, ScenarioMetrics], None]

JX_DISPATCH_MODES = ("megabatch", "group")


def enable_compile_cache(cache_dir: str) -> None:
    """Enable JAX's persistent compilation cache at `cache_dir` (created
    if missing) with thresholds dropped to zero so every simulator
    program is cached — a re-run of a sweep in a fresh process then pays
    deserialization instead of XLA compilation."""
    import jax

    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


def compile_cache_entries(cache_dir: str) -> int:
    """Number of compiled-program entries currently in a persistent
    compilation cache directory."""
    try:
        return sum(1 for n in os.listdir(cache_dir)
                   if n.endswith("-cache"))
    except OSError:
        return 0


def _timed_point(p: ScenarioSpec, derive: Optional[Callable] = None):
    """`run_point` plus its wall clock — module-level so process pools
    can pickle it (workers time themselves; the parent only sees
    completion order)."""
    t0 = time.perf_counter()
    m = run_point(p, derive=derive)
    return m, time.perf_counter() - t0


def execute_points(points: List[ScenarioSpec],
                   processes: Optional[int] = None,
                   backend: Optional[str] = None,
                   derive: Optional[Callable] = None,
                   on_result: Optional[OnResult] = None,
                   jx_dispatch: Optional[str] = None,
                   compile_cache_dir: Optional[str] = None,
                   flight: Optional[Dict] = None
                   ) -> List[ScenarioMetrics]:
    """Run every point; returns metrics in point order.  `backend=None`
    inherits the specs' `sim.backend` (which must agree — mixed grids
    are partitioned by the caller).  `on_result` fires once per point as
    it completes, *before* the call returns.  `jx_dispatch` picks the
    JAX dispatch path ('megabatch' default, 'group' = the legacy
    per-structure batching; `REPRO_JX_DISPATCH` overrides the default);
    `compile_cache_dir` enables the persistent XLA compilation cache.

    `flight`, when a dict, is filled with the executor flight-recorder
    summary: backend/mode, total wall clock, per-point wall times (JAX
    points share one launch, so their cost is the finalized group's wall
    amortized over its points), and — on the JAX paths — this sweep's
    own dispatch/compile counts (`collect_dispatch`) plus any float32
    bytes_total overflow conditions hit while preparing it."""
    emit = on_result or (lambda i, m: None)
    t_start = time.perf_counter()
    point_walls: List[Dict] = []

    def _done(mode: str, **kw) -> None:
        if flight is not None:
            flight.update(
                {"backend": backend, "mode": mode, "n_points": len(points),
                 "wall_s": round(time.perf_counter() - t_start, 6),
                 "points": point_walls, **kw})
    if backend is None:
        inherited = {p.sim.backend for p in points}
        if len(inherited) > 1:
            raise ValueError(
                f"sweep mixes spec backends {sorted(inherited)}; pass "
                "backend= explicitly")
        backend = inherited.pop() if inherited else "numpy"
    if backend == "jax":
        if compile_cache_dir:
            enable_compile_cache(compile_cache_dir)
        mode = (jx_dispatch or
                os.environ.get("REPRO_JX_DISPATCH", "megabatch"))
        if mode not in JX_DISPATCH_MODES:
            raise ValueError(
                f"unknown jx_dispatch {mode!r}; expected one of "
                f"{JX_DISPATCH_MODES}")
        out, stats, overflows, pipeline = _execute_jax(
            points, derive, emit, mode, point_walls)
        _done(mode, dispatch_stats=stats, f32_overflows=overflows,
              pipeline=pipeline)
        return out
    if backend != "numpy":
        raise ValueError(
            f"unknown backend {backend!r}; expected 'numpy' or 'jax'")
    # make the override symmetric: run_point honors each spec's own
    # sim.backend, so pin it to numpy or a backend="numpy" sweep of
    # jax-backend specs would silently still run on JAX
    points = [replace(p, sim=replace(p.sim, backend="numpy"))
              if p.sim.backend != "numpy" else p for p in points]
    if processes is None:
        processes = min(len(points), os.cpu_count() or 1)
    runner = partial(_timed_point, derive=derive)

    def _serial(results=None):
        results = []
        for i, p in enumerate(points):
            m, w = runner(p)
            point_walls.append({"index": i, "wall_s": round(w, 6)})
            emit(i, m)
            results.append(m)
        return results

    if processes <= 1 or len(points) <= 1:
        results = _serial()
        _done("serial")
        return results
    # forking a parent whose XLA backend is live (multithreaded) can
    # deadlock the workers, so after a backend="jax" sweep ran in this
    # process switch to the spawn family.  Merely having jax *imported*
    # is fine — repro.core pulls it in transitively, and penalizing
    # every NumPy sweep with spawn start-up costs would be wrong.
    # Spawn/forkserver re-import __main__, which is impossible for
    # stdin/heredoc programs — fall back to serial there rather than
    # crash or risk the fork.
    if _xla_backend_live():
        main_file = getattr(sys.modules.get("__main__"), "__file__", None)
        if main_file is not None and not os.path.exists(main_file):
            results = _serial()
            _done("serial")
            return results
        methods = multiprocessing.get_all_start_methods()
        ctx = multiprocessing.get_context(
            "forkserver" if "forkserver" in methods else "spawn")
    else:
        ctx = multiprocessing.get_context()
    out: List[Optional[ScenarioMetrics]] = [None] * len(points)
    with ProcessPoolExecutor(max_workers=processes, mp_context=ctx) as ex:
        futures = {ex.submit(runner, p): i for i, p in enumerate(points)}
        pending = set(futures)
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for fut in done:
                i = futures[fut]
                m, w = fut.result()   # re-raises worker exceptions
                point_walls.append({"index": i, "wall_s": round(w, 6)})
                out[i] = m
                emit(i, m)
    _done("pool", processes=processes)
    return out


def _xla_backend_live() -> bool:
    """True iff an XLA backend (and its thread pools) was plausibly
    created in this process — not merely `import jax`.  First line: our
    own jax engine's dispatch flag (set on actual use, not import).
    Second line: jax's backend cache (private, so probed defensively —
    if jax renames it we degrade to the first check)."""
    if getattr(sys.modules.get("repro.netsim.jx.engine"),
               "_BACKEND_USED", False):
        return True
    xb = sys.modules.get("jax._src.xla_bridge")
    return bool(getattr(xb, "_backends", None))


def _execute_jax(points: List[ScenarioSpec], derive: Optional[Callable],
                 emit: OnResult, mode: str = "megabatch",
                 point_walls: Optional[List[Dict]] = None):
    """Batched single-process sweep.

    'megabatch' (default): every structurally compatible point — any
    mix of routing, nic, fault, and seed axes — stacks into ONE fused
    `jit(vmap)` launch that compiles once; heterogeneous flow counts
    and fault timelines share programs via shape buckets
    (`repro.netsim.jx.megabatch`).  Dispatch is pipelined: a single
    prep worker runs the memoized host prep + launch of shape bucket
    k+1 while the device executes bucket k, and the main thread
    finalizes each bucket's rows as it retires.

    'group' (the PR 3 path, kept for A/B benchmarking and parity
    pinning): group grid points that share structure (same scenario
    modulo the seeds) and run each group as its own `vmap` batch — one
    compile and one launch per (scenario, routing, nic, fault)
    structure.

    Either way everything is dispatched before anything is awaited (JAX
    CPU execution is async), with
    `XLA_FLAGS=--xla_force_host_platform_device_count=N` sharding batch
    axes over the N host devices, and completed rows stream out per
    finalized batch."""
    from repro.netsim.jx.engine import collect_dispatch, f32_overflow_log

    results: List[Optional[ScenarioMetrics]] = [None] * len(points)
    n_overflows0 = len(f32_overflow_log())

    def deliver(i, c, r):
        m = distill_metrics(points[i], c, r)
        if derive is not None:
            m.extra.update(derive(points[i], c, r))
        results[i] = m
        emit(i, m)

    def record_group(idxs: List[int], wall_s: float) -> None:
        # one fused launch per group: its wall clock amortizes evenly
        if point_walls is not None:
            each = round(wall_s / max(len(idxs), 1), 6)
            point_walls.extend({"index": i, "wall_s": each}
                               for i in idxs)

    # collect_dispatch attributes launches to THIS sweep: the
    # before/after global-counter delta it replaces misattributed any
    # launches concurrent executors made on other threads
    pipeline: Dict = {}
    with collect_dispatch() as counter:
        if mode == "megabatch":
            from repro.netsim.jx.engine import (adopt_dispatch,
                                                current_collectors)
            from repro.netsim.jx.megabatch import (dispatch_planned,
                                                   finalize_group,
                                                   plan_megabatch)

            import jax
            from jax.experimental import disable_x64, enable_x64

            compiled = [compile_scenario(p) for p in points]
            caches, planned = plan_megabatch(compiled)
            collectors = current_collectors()
            x64 = bool(jax.config.jax_enable_x64)

            def prep(group):
                # the worker thread runs outside the main thread's
                # collect_dispatch scope AND its thread-local jax
                # config overrides (`enable_x64()` contexts): adopt the
                # counters and re-assert the caller's x64 state so the
                # launch traces with the caller's dtypes
                with adopt_dispatch(collectors), \
                        (enable_x64() if x64 else disable_x64()):
                    return dispatch_planned(group, caches)

            launches = 0
            # single prep worker: host prep (memoized flow arrays,
            # fault timelines, ECMP replays) of bucket k+1 overlaps
            # device execution of bucket k (JAX dispatch is async);
            # the main thread finalizes rows as buckets retire
            with ThreadPoolExecutor(max_workers=1) as pool:
                futs = [pool.submit(prep, g) for g in planned]
                for fut in futs:
                    for idxs, handle in fut.result():
                        launches += 1
                        tg = time.perf_counter()
                        for i, r in zip(idxs, finalize_group(handle)):
                            deliver(i, compiled[i], r)
                        record_group(idxs, time.perf_counter() - tg)
            # >1 launch means prep/execute/finalize actually overlapped
            # (launch k+1's host prep runs while the device executes k)
            pipeline = {"groups": len(planned), "launches": launches,
                        "pipelined": launches > 1}
        else:
            from repro.netsim.jx.engine import (dispatch_compiled_batch,
                                                finalize_batch)

            order: List = []
            groups: Dict = {}
            for i, p in enumerate(points):
                key = replace(p,
                              sim=replace(p.sim, seed=0,
                                          backend="numpy"),
                              workload_seed=0)
                if key not in groups:
                    groups[key] = []
                    order.append(key)
                groups[key].append(i)
            dispatched = []
            for key in order:
                idxs = groups[key]
                compiled = [compile_scenario(points[i]) for i in idxs]
                dispatched.append((idxs, compiled,
                                   dispatch_compiled_batch(compiled)))
            for idxs, compiled, handle in dispatched:
                tg = time.perf_counter()
                for i, c, r in zip(idxs, compiled,
                                   finalize_batch(handle)):
                    deliver(i, c, r)
                record_group(idxs, time.perf_counter() - tg)
    overflows = list(f32_overflow_log()[n_overflows0:])
    return results, counter.snapshot(), overflows, pipeline
