"""Override paths: address any field of a (nested, frozen) `ScenarioSpec`
by a dotted string with optional sequence indices —

    "sim.routing"          -> spec.sim.routing
    "faults[0].frac"       -> spec.faults[0].frac
    "topo.n_planes"        -> spec.topo.n_planes
    "workloads[1].demand"  -> spec.workloads[1].demand
    "faults"               -> the whole fault tuple

`apply_override` returns a *new* spec (dataclass `replace` all the way
down — specs stay frozen and hashable), validating each step: unknown
field names, out-of-range indices, indexing a non-sequence, and leaf
type mismatches all raise `OverridePathError` with the full path in the
message.  This is the substrate `Experiment` axes lower through.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, List, Union

PathStep = Union[str, int]

_STEP_RE = re.compile(r"^([A-Za-z_][A-Za-z0-9_]*)((?:\[\d+\])*)$")
_INDEX_RE = re.compile(r"\[(\d+)\]")


class OverridePathError(ValueError):
    """An override path failed to parse, resolve, or type-check."""


def parse_path(path: str) -> List[PathStep]:
    """'faults[0].frac' -> ['faults', 0, 'frac']."""
    if not isinstance(path, str) or not path.strip():
        raise OverridePathError(f"empty override path {path!r}")
    steps: List[PathStep] = []
    for part in path.split("."):
        m = _STEP_RE.match(part)
        if not m:
            raise OverridePathError(
                f"malformed override path {path!r}: cannot parse "
                f"segment {part!r} (expected name or name[index])")
        steps.append(m.group(1))
        steps.extend(int(i) for i in _INDEX_RE.findall(m.group(2)))
    return steps


def _type_name(v: Any) -> str:
    return type(v).__name__


def _check_leaf_type(path: str, old: Any, new: Any) -> Any:
    """Value compatibility against the current leaf value.  Returns the
    (possibly coerced) value: int -> float promotion and list -> tuple
    are allowed; everything else must match the existing kind."""
    if old is None:                      # Optional field — can't infer
        return new
    if isinstance(old, bool):
        if not isinstance(new, bool):
            raise OverridePathError(
                f"override {path!r}: expected bool, got "
                f"{_type_name(new)} ({new!r})")
        return new
    if isinstance(old, int) and not isinstance(old, bool):
        if not isinstance(new, int) or isinstance(new, bool):
            raise OverridePathError(
                f"override {path!r}: expected int, got "
                f"{_type_name(new)} ({new!r})")
        return new
    if isinstance(old, float):
        if not isinstance(new, (int, float)) or isinstance(new, bool):
            raise OverridePathError(
                f"override {path!r}: expected float, got "
                f"{_type_name(new)} ({new!r})")
        return float(new)
    if isinstance(old, str):
        if not isinstance(new, str):
            raise OverridePathError(
                f"override {path!r}: expected str, got "
                f"{_type_name(new)} ({new!r})")
        return new
    if isinstance(old, tuple):
        if not isinstance(new, (tuple, list)):
            raise OverridePathError(
                f"override {path!r}: expected tuple, got "
                f"{_type_name(new)} ({new!r})")
        return tuple(new)
    if dataclasses.is_dataclass(old):
        if type(new) is not type(old):
            raise OverridePathError(
                f"override {path!r}: expected {_type_name(old)}, got "
                f"{_type_name(new)} ({new!r})")
        return new
    return new                            # pragma: no cover — no such leaf


def _set(obj: Any, steps: List[PathStep], value: Any, path: str) -> Any:
    if not steps:
        return _check_leaf_type(path, obj, value)
    step, rest = steps[0], steps[1:]
    if isinstance(step, int):
        if not isinstance(obj, (tuple, list)):
            raise OverridePathError(
                f"override {path!r}: index [{step}] into a "
                f"{_type_name(obj)} (not a sequence)")
        if not 0 <= step < len(obj):
            raise OverridePathError(
                f"override {path!r}: index [{step}] out of range for "
                f"length {len(obj)}")
        items = list(obj)
        items[step] = _set(items[step], rest, value, path)
        return tuple(items)
    if not dataclasses.is_dataclass(obj):
        raise OverridePathError(
            f"override {path!r}: field {step!r} on a "
            f"{_type_name(obj)} (not a spec dataclass)")
    names = [f.name for f in dataclasses.fields(obj)]
    if step not in names:
        raise OverridePathError(
            f"override {path!r}: {_type_name(obj)} has no field "
            f"{step!r}; known fields: {names}")
    return dataclasses.replace(
        obj, **{step: _set(getattr(obj, step), rest, value, path)})


def apply_override(spec: Any, path: str, value: Any) -> Any:
    """Return a copy of `spec` with the field at `path` set to `value`."""
    return _set(spec, parse_path(path), value, path)


def get_path(spec: Any, path: str) -> Any:
    """Read the current value at `path` (same grammar as overrides)."""
    obj = spec
    for step in parse_path(path):
        if isinstance(step, int):
            if not isinstance(obj, (tuple, list)):
                raise OverridePathError(
                    f"path {path!r}: index [{step}] into a "
                    f"{_type_name(obj)}")
            if not 0 <= step < len(obj):
                raise OverridePathError(
                    f"path {path!r}: index [{step}] out of range for "
                    f"length {len(obj)}")
            obj = obj[step]
        else:
            if not dataclasses.is_dataclass(obj) or not hasattr(obj, step):
                raise OverridePathError(
                    f"path {path!r}: no field {step!r} on "
                    f"{_type_name(obj)}")
            obj = getattr(obj, step)
    return obj
