"""Columnar experiment results.

A `ResultSet` replaces `List[ScenarioMetrics]` + hand-rolled CSV: one
typed column per metric field (names and kinds come from the single
`runner.METRIC_FIELDS` table), one `axis.<path>` column per grid axis
(holding that point's coordinate label), and per-run `extra` metrics as
a JSON column.  Rows stream in while an experiment runs; queries
(`filter` / `group_by` / `pivot` / `summary`) and lossless JSON / CSV
serialization (schema-versioned) operate on the finished set.

Column kinds: "str" | "int" | "float" | "bool" for scalars, "json" for
structured values (tenant dicts, tuple-valued recovery columns, extra).
Coordinate columns are "json"-kinded so CSV cells round-trip exact types
(NaN floats survive both formats).
"""
from __future__ import annotations

import csv
import io
import json
import math
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.scenarios.runner import (METRIC_FIELDS, METRIC_KINDS,
                                    TRACE_METRIC_DEFAULTS, ScenarioMetrics,
                                    metric_value)

SCHEMA_VERSION = 1

METRIC_COLUMNS: Tuple[str, ...] = tuple(n for n, _, _ in METRIC_FIELDS)

# Columns that may be absent from serializations written before they
# existed — deserialization backfills the default instead of raising.
_BACKFILL_COLUMNS: Dict[str, Any] = dict(TRACE_METRIC_DEFAULTS)

def _std(xs: List[float]) -> float:
    mu = sum(xs) / len(xs)
    return math.sqrt(sum((x - mu) ** 2 for x in xs) / len(xs))


_AGGS: Dict[str, Callable[[List[float]], float]] = {
    "mean": lambda xs: sum(xs) / len(xs),
    "min": min,
    "max": max,
    "sum": sum,
    "std": _std,
    "count": len,
}


def axis_column(path: str) -> str:
    """ResultSet column name of a grid axis (`faults[0].frac` ->
    `axis.faults[0].frac`) — prefixed so axis paths can never collide
    with metric columns like `seed` or `nic`."""
    return f"axis.{path}"


def _jsonify(v: Any) -> Any:
    """Tuples -> lists (JSON has no tuples); dicts copied."""
    if isinstance(v, dict):
        return {str(k): _jsonify(x) for k, x in v.items()}
    if isinstance(v, (tuple, list)):
        return [_jsonify(x) for x in v]
    return v


class ResultSet:
    """Columnar store: `self._cols[name]` is the column list; all
    columns share length.  `coord_names` are axis paths (unprefixed)."""

    def __init__(self, coord_names: Sequence[str] = ()):
        self.coord_names: List[str] = list(coord_names)
        self._cols: Dict[str, List] = {n: [] for n in self.column_names}
        self._order: List[int] = []          # grid ordinal per row
        self.cache_hits = 0
        self.cache_misses = 0
        # executor flight-recorder summary (per-point wall clock,
        # dispatch/compile counts); attached by `run_experiment`
        self.flight: Optional[Dict[str, Any]] = None

    # ---- shape ----------------------------------------------------------
    @property
    def column_names(self) -> List[str]:
        return ([axis_column(p) for p in self.coord_names]
                + list(METRIC_COLUMNS))

    def column_kind(self, name: str) -> str:
        if name in METRIC_KINDS:
            return METRIC_KINDS[name]
        if name.startswith("axis.") and name[5:] in self.coord_names:
            return "json"
        raise KeyError(f"unknown column {name!r}; "
                       f"known: {self.column_names}")

    def __len__(self) -> int:
        return len(self._cols[METRIC_COLUMNS[0]])

    def column(self, name: str) -> List:
        if name not in self._cols:
            raise KeyError(f"unknown column {name!r}; "
                           f"known: {self.column_names}")
        return list(self._cols[name])

    def rows(self) -> List[Dict[str, Any]]:
        names = self.column_names
        return [{n: self._cols[n][i] for n in names}
                for i in range(len(self))]

    # ---- building -------------------------------------------------------
    def append(self, m: ScenarioMetrics,
               coords: Optional[Dict[str, Any]] = None,
               order: Optional[int] = None) -> None:
        coords = coords or {}
        unknown = sorted(set(coords) - set(self.coord_names))
        if unknown:
            raise KeyError(
                f"coords {unknown} are not declared axes "
                f"{self.coord_names}")
        for p in self.coord_names:
            self._cols[axis_column(p)].append(coords.get(p))
        for name in METRIC_COLUMNS:
            v = metric_value(m, name)
            if METRIC_KINDS[name] == "json":
                v = _jsonify(v)
            self._cols[name].append(v)
        self._order.append(len(self._order) if order is None else order)

    def extend(self, other: "ResultSet") -> None:
        """Append another set's rows (coordinate columns are unioned;
        rows missing an axis get None there)."""
        for p in other.coord_names:
            if p not in self.coord_names:
                self.coord_names.append(p)
                self._cols[axis_column(p)] = [None] * len(self)
        base = (max(self._order) + 1) if self._order else 0
        for i in range(len(other)):
            for p in self.coord_names:
                col = axis_column(p)
                v = other._cols[col][i] if col in other._cols else None
                self._cols[col].append(v)
            for n in METRIC_COLUMNS:
                self._cols[n].append(other._cols[n][i])
            self._order.append(base + other._order[i])

    def sort_to_grid_order(self) -> None:
        """Re-order rows by grid ordinal — streaming appends rows in
        completion order; this restores the declared grid order."""
        perm = sorted(range(len(self)), key=self._order.__getitem__)
        for n in self._cols:
            col = self._cols[n]
            self._cols[n] = [col[i] for i in perm]
        self._order = [self._order[i] for i in perm]

    def to_metrics(self) -> List[ScenarioMetrics]:
        """Reconstruct the `ScenarioMetrics` records (row order)."""
        derived = ("worst_recovery_slots",)      # recomputed, not stored
        keys = [n for n in METRIC_COLUMNS if n not in derived]
        return [ScenarioMetrics.from_dict({k: r[k] for k in keys})
                for r in self.rows()]

    # ---- queries --------------------------------------------------------
    def _subset(self, idxs: Iterable[int]) -> "ResultSet":
        rs = ResultSet(self.coord_names)
        for i in idxs:
            for n in self._cols:
                rs._cols[n].append(self._cols[n][i])
            rs._order.append(self._order[i])
        return rs

    def filter(self, pred: Optional[Callable[[Dict], bool]] = None,
               **eq) -> "ResultSet":
        """Rows where `pred(row_dict)` holds and/or column == value for
        every `column=value` kwarg (axis columns via their full
        `axis.<path>` name, passed through a dict if not an identifier)."""
        for k in eq:
            if k not in self._cols:
                raise KeyError(f"unknown column {k!r}; "
                               f"known: {self.column_names}")
        names = self.column_names
        keep = []
        for i in range(len(self)):
            row = {n: self._cols[n][i] for n in names}
            if any(row[k] != v for k, v in eq.items()):
                continue
            if pred is not None and not pred(row):
                continue
            keep.append(i)
        return self._subset(keep)

    def group_by(self, *names: str) -> Dict[Tuple, "ResultSet"]:
        for n in names:
            if n not in self._cols:
                raise KeyError(f"unknown column {n!r}; "
                               f"known: {self.column_names}")
        groups: Dict[Tuple, List[int]] = {}
        for i in range(len(self)):
            key = tuple(self._cols[n][i] for n in names)
            groups.setdefault(key, []).append(i)
        return {k: self._subset(v) for k, v in groups.items()}

    def pivot(self, index: str, columns: str, values: str,
              agg: str = "mean") -> Dict[Any, Dict[Any, float]]:
        """{index_label: {column_label: agg(values)}} — e.g.
        `pivot("axis.faults[0].frac", "nic", "mean_goodput")`."""
        if agg not in _AGGS:
            raise ValueError(f"unknown agg {agg!r}; known: "
                             f"{sorted(_AGGS)}")
        cells: Dict[Any, Dict[Any, List[float]]] = {}
        for i in range(len(self)):
            r = cells.setdefault(self._cols[index][i], {})
            r.setdefault(self._cols[columns][i], []).append(
                self._cols[values][i])
        return {ri: {ci: _AGGS[agg](vs) for ci, vs in row.items()}
                for ri, row in cells.items()}

    def summary(self, values: Sequence[str] = ("mean_goodput",),
                by: Sequence[str] = ()) -> Dict:
        """Per-group mean/std/min/max/count of the value columns.
        Without `by`, one group keyed by ()."""
        groups = self.group_by(*by) if by else {(): self}
        out: Dict = {}
        for key, rs in groups.items():
            stats = {}
            for v in values:
                xs = [x for x in rs.column(v)
                      if isinstance(x, (int, float))
                      and not (isinstance(x, float) and math.isnan(x))]
                stats[v] = ({"mean": _AGGS["mean"](xs),
                             "std": _AGGS["std"](xs),
                             "min": min(xs), "max": max(xs),
                             "count": len(xs)} if xs
                            else {"mean": float("nan"),
                                  "std": float("nan"),
                                  "min": float("nan"),
                                  "max": float("nan"), "count": 0})
            out[key] = stats
        return out

    # ---- serialization --------------------------------------------------
    def to_json(self) -> str:
        doc = {"schema_version": SCHEMA_VERSION,
               "coord_names": self.coord_names,
               "n_rows": len(self),
               "columns": {n: self._cols[n] for n in self.column_names}}
        if self.flight is not None:
            doc["flight"] = self.flight
        return json.dumps(doc, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ResultSet":
        d = json.loads(text)
        ver = d.get("schema_version")
        if ver != SCHEMA_VERSION:
            raise ValueError(
                f"ResultSet schema version {ver!r} != supported "
                f"{SCHEMA_VERSION}")
        rs = cls(d["coord_names"])
        n_rows = int(d.get("n_rows", 0))
        for n in rs.column_names:
            if n not in d["columns"]:
                if n in _BACKFILL_COLUMNS:
                    rs._cols[n] = [_jsonify(_BACKFILL_COLUMNS[n])
                                   for _ in range(n_rows)]
                    continue
                raise ValueError(f"ResultSet JSON missing column {n!r}")
            rs._cols[n] = list(d["columns"][n])
        lens = {len(c) for c in rs._cols.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged ResultSet columns: lengths {lens}")
        rs._order = list(range(len(rs)))
        rs.flight = d.get("flight")
        return rs

    def to_csv(self) -> str:
        """Lossless CSV: scalar columns as plain text, json-kinded
        columns (and axis coordinates) as JSON-encoded cells."""
        buf = io.StringIO()
        w = csv.writer(buf, lineterminator="\n")
        names = self.column_names
        w.writerow(names)
        for i in range(len(self)):
            row = []
            for n in names:
                v = self._cols[n][i]
                if self.column_kind(n) == "json":
                    row.append(json.dumps(v, sort_keys=True))
                elif isinstance(v, float) and math.isnan(v):
                    row.append("nan")
                else:
                    row.append(str(v))
            w.writerow(row)
        return buf.getvalue()

    @classmethod
    def from_csv(cls, text: str) -> "ResultSet":
        rows = list(csv.reader(io.StringIO(text)))
        if not rows:
            raise ValueError("empty ResultSet CSV")
        header = rows[0]
        coord_names = [n[5:] for n in header if n.startswith("axis.")]
        missing = [n for n in METRIC_COLUMNS if n not in header
                   and n not in _BACKFILL_COLUMNS]
        if missing:
            raise ValueError(f"ResultSet CSV missing columns {missing}")
        rs = cls(coord_names)
        parsers = {"str": str, "int": int, "float": float,
                   "bool": lambda s: s == "True", "json": json.loads}
        backfill = [n for n in METRIC_COLUMNS if n not in header]
        for cells in rows[1:]:
            for n, cell in zip(header, cells):
                if n in rs._cols:
                    rs._cols[n].append(parsers[rs.column_kind(n)](cell))
            for n in backfill:
                rs._cols[n].append(_jsonify(_BACKFILL_COLUMNS[n]))
            rs._order.append(len(rs._order))
        lens = {len(c) for c in rs._cols.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged ResultSet CSV: column lengths "
                             f"{lens}")
        return rs
