"""The `Experiment` spec: a named grid over arbitrary `ScenarioSpec`
override paths, executed into a columnar `ResultSet` through an on-disk
run cache.

    exp = Experiment(
        name="fault_fraction_x_planes",
        base="allreduce_under_random_failures",
        axes=product(Axis("faults[0].frac", (0.05, 0.1, 0.2)),
                     Axis("topo.n_planes", (1, 2, 4))),
    )
    rs = run_experiment(exp, cache=".expcache")
    rs.pivot("axis.faults[0].frac", "axis.topo.n_planes",
             "mean_goodput")

Each grid point is the base spec with that point's coordinate values
applied in axis order ("scenario" replaces the base, "seed" perturbs
both `sim.seed` and `workload_seed`, everything else is an override
path), then validated.  Re-running with the same cache directory skips
every point whose fully-resolved spec hashes to a cached entry, so an
interrupted sweep resumes where it died.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.scenarios.registry import get_scenario
from repro.scenarios.spec import ScenarioSpec

from .axes import Axis, Chain, Product, Zip, product
from .cache import RunCache, spec_key
from .execute import execute_points
from .overrides import apply_override
from .resultset import ResultSet

GridExpr = Union[Axis, Product, Zip, Chain]


@dataclass(frozen=True)
class ExperimentPoint:
    """One fully-resolved grid point: its ordinal, its coordinate labels
    (axis path -> label), and the spec to run."""
    index: int
    coords: Dict[str, Any]
    spec: ScenarioSpec


@dataclass(frozen=True)
class Experiment:
    """A named parameter study.  `base` is a registry scenario name or an
    inline `ScenarioSpec` (optional when a "scenario" axis supplies it).
    `axes` is a grid expression — a single `Axis`, a combinator
    (`product`/`zip_axes`/`chain`), or a plain sequence of those, which
    is treated as an implicit product.  `derive(spec, compiled, result)
    -> dict` adds per-run `extra` metrics; it must be a module-level
    function (process pools pickle it) and is folded into the cache key
    by qualified name."""
    name: str
    axes: Union[GridExpr, Sequence[GridExpr]]
    base: Union[str, ScenarioSpec, None] = None
    derive: Optional[Callable] = None
    description: str = ""

    def grid(self) -> GridExpr:
        if isinstance(self.axes, (Axis, Product, Zip, Chain)):
            return self.axes
        return product(*self.axes)

    def coord_names(self) -> List[str]:
        return list(self.grid().paths())

    def _base_spec(self) -> Optional[ScenarioSpec]:
        if self.base is None:
            return None
        if isinstance(self.base, str):
            return get_scenario(self.base)
        return self.base

    def points(self) -> List[ExperimentPoint]:
        base = self._base_spec()
        out: List[ExperimentPoint] = []
        for i, pt in enumerate(self.grid().points()):
            spec = base
            coords: Dict[str, Any] = {}
            overridden = False
            for path, value, label in pt:
                coords[path] = label
                if path == "scenario":
                    if overridden:
                        # replacing the spec now would silently discard
                        # the overrides already applied (while their
                        # coordinates still label the row) — refuse
                        raise ValueError(
                            f"experiment {self.name!r}: 'scenario' axis "
                            "must come before override axes — it "
                            "replaces the spec and would drop "
                            f"{[p for p, _, _ in pt if p != 'scenario']}")
                    spec = (get_scenario(value) if isinstance(value, str)
                            else value)
                    continue
                overridden = True
                if spec is None:
                    raise ValueError(
                        f"experiment {self.name!r}: no base scenario — "
                        "pass base= or lead with a 'scenario' axis")
                if path == "seed":
                    spec = spec.with_sim(
                        seed=spec.sim.seed + value).with_workload_seed(
                        spec.workload_seed + value)
                else:
                    spec = apply_override(spec, path, value)
            if spec is None:
                raise ValueError(
                    f"experiment {self.name!r}: no base scenario — "
                    "pass base= or lead with a 'scenario' axis")
            spec.validate()
            out.append(ExperimentPoint(index=i, coords=coords, spec=spec))
        return out

    def cache_salt(self) -> str:
        """Folds the derive hook's identity into cache keys: different
        extra-metric logic must not alias plain runs.  `functools.partial`
        of a module-level function is accepted (its bound arguments join
        the salt — e.g. a trace export directory)."""
        if self.derive is None:
            return ""
        d = self.derive
        if isinstance(d, functools.partial):
            inner = f"{d.func.__module__}.{d.func.__qualname__}"
            return f"{inner}{d.args!r}{sorted(d.keywords.items())!r}"
        return f"{d.__module__}.{d.__qualname__}"


def run_experiment(exp: Experiment,
                   processes: Optional[int] = None,
                   backend: Optional[str] = None,
                   cache: Union[RunCache, str, None] = None,
                   jx_dispatch: Optional[str] = None,
                   compile_cache_dir: Optional[str] = None
                   ) -> ResultSet:
    """Execute the experiment grid into a `ResultSet`.

    `cache` is a `RunCache` or a directory path; cached points are
    served without running, fresh points stream into both the cache and
    the `ResultSet` as they complete (so an interrupt loses at most the
    in-flight points, and the next call resumes from the survivors).
    `backend` pins every point ('numpy' | 'jax'); None runs each point
    on its spec's own `sim.backend`, so a `sim.backend` axis sweeps
    both.  On the JAX backend `jx_dispatch` selects 'megabatch' (whole
    grid fused into one launch per structure — the default) or 'group'
    (legacy per-structure batching), and `compile_cache_dir` turns on
    JAX's persistent compilation cache so the fused program survives
    process restarts.  Rows come back in grid order; `rs.cache_hits` /
    `rs.cache_misses` report how the run was served."""
    if isinstance(cache, str):
        cache = RunCache(cache)
    if backend is not None and backend not in ("numpy", "jax"):
        raise ValueError(
            f"unknown backend {backend!r}; expected 'numpy' or 'jax'")
    pts = exp.points()
    if backend is not None:
        pts = [replace(p, spec=p.spec.with_sim(backend=backend))
               for p in pts]
    salt = exp.cache_salt()
    rs = ResultSet(exp.coord_names())
    pending: List[ExperimentPoint] = []
    for p in pts:
        hit = cache.get(spec_key(p.spec, salt)) if cache else None
        if hit is not None:
            rs.cache_hits += 1
            rs.append(hit, p.coords, order=p.index)
        else:
            pending.append(p)
    rs.cache_misses = len(pending)

    def on_result(group: List[ExperimentPoint], j: int,
                  m) -> None:
        p = group[j]
        if cache is not None:
            cache.put(spec_key(p.spec, salt), p.spec, m)
        rs.append(m, p.coords, order=p.index)

    # mixed-backend grids (e.g. a sim.backend axis) partition into one
    # executor call per backend, each batched as usual
    executions: List[Dict] = []
    for bk in ("numpy", "jax"):
        group = [p for p in pending if p.spec.sim.backend == bk]
        if group:
            fl: Dict = {}
            execute_points(
                [p.spec for p in group], processes=processes, backend=bk,
                derive=exp.derive, jx_dispatch=jx_dispatch,
                compile_cache_dir=compile_cache_dir,
                on_result=lambda j, m, g=group: on_result(g, j, m),
                flight=fl)
            # executor point indices are group-local; lift to grid order
            for pw in fl.get("points", ()):
                pw["index"] = group[pw["index"]].index
            executions.append(fl)
    rs.flight = {"experiment": exp.name,
                 "cache_hits": rs.cache_hits,
                 "cache_misses": rs.cache_misses,
                 "executions": executions}
    rs.sort_to_grid_order()
    return rs


# ---------------------------------------------------------------------------
# experiment registry (mirrors the scenario registry)
# ---------------------------------------------------------------------------

EXPERIMENTS: Dict[str, Callable[[], Experiment]] = {}


def register_experiment(fn: Callable[[], Experiment]
                        ) -> Callable[[], Experiment]:
    exp = fn()
    exp.points()                      # fail at import, not first run
    EXPERIMENTS[exp.name] = fn
    return fn


def get_experiment(name: str) -> Experiment:
    try:
        return EXPERIMENTS[name]()
    except KeyError:
        raise KeyError(
            f"unknown experiment {name!r}; known: {sorted(EXPERIMENTS)}"
        ) from None


def list_experiments() -> List[str]:
    return sorted(EXPERIMENTS)
