"""On-disk run cache keyed by a content hash of the fully-resolved
per-point `ScenarioSpec`.

The key is a SHA-256 over a *canonical* form of the spec — dataclasses
lowered field-by-field (type name included, so a `FaultSpec` never
collides with a `WorkloadSpec` of equal fields), tuples as lists, dicts
key-sorted — serialized with `json.dumps(sort_keys=True)`.  No `repr`
anywhere: formatting changes can't invalidate or alias entries.  A salt
(derive-hook tag, schema version) folds in anything that changes the
*metrics* without changing the spec.

Entries are one JSON file per key under `root/<k[:2]>/<k>.json`, written
atomically (tmp + rename) so an interrupted sweep never leaves a
half-written entry.  `get` treats unreadable, corrupt, version-skewed,
or key-mismatched files as misses — a poisoned entry costs one re-run,
never a crash or a wrong row.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Optional

import numpy as np

from repro.scenarios.runner import ScenarioMetrics
from repro.scenarios.spec import ScenarioSpec

from .resultset import SCHEMA_VERSION

CACHE_VERSION = 1


def canonicalize(obj: Any) -> Any:
    """Lower specs to a deterministic JSON-ready structure.

    Fields named in a dataclass's `HASH_ELIDE_DEFAULTS` class attribute
    are omitted while they hold their declared default — the additive-
    schema-evolution contract: extending a spec with new defaulted
    fields (e.g. `TopologySpec.kind`) must not re-key every pre-existing
    cache entry."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        elide = getattr(type(obj), "HASH_ELIDE_DEFAULTS", ())
        return {"__dataclass__": type(obj).__name__,
                "fields": {f.name: canonicalize(getattr(obj, f.name))
                           for f in dataclasses.fields(obj)
                           if not (f.name in elide
                                   and f.default is not dataclasses.MISSING
                                   and getattr(obj, f.name) == f.default)}}
    if isinstance(obj, (tuple, list)):
        return [canonicalize(v) for v in obj]
    if isinstance(obj, dict):
        return {str(k): canonicalize(obj[k]) for k in sorted(obj)}
    if isinstance(obj, np.generic):
        return obj.item()
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(
        f"cannot canonicalize {type(obj).__name__} for cache hashing: "
        f"{obj!r}")


def spec_key(spec: ScenarioSpec, salt: str = "") -> str:
    """Content hash of a fully-resolved grid point."""
    payload = json.dumps(
        {"cache_version": CACHE_VERSION,
         "metrics_schema": SCHEMA_VERSION,
         "salt": salt,
         "spec": canonicalize(spec)},
        sort_keys=True, allow_nan=True)
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()


class RunCache:
    """Directory-backed metrics cache; safe to share across sweeps."""

    def __init__(self, root: str):
        self.root = str(root)

    def path_for(self, key: str) -> str:
        return os.path.join(self.root, key[:2], f"{key}.json")

    def get(self, key: str) -> Optional[ScenarioMetrics]:
        try:
            with open(self.path_for(key), "r", encoding="utf-8") as f:
                entry = json.load(f)
            if entry.get("cache_version") != CACHE_VERSION:
                return None
            if entry.get("key") != key:
                return None
            return ScenarioMetrics.from_dict(entry["metrics"])
        except (OSError, ValueError, KeyError, TypeError):
            return None

    def put(self, key: str, spec: ScenarioSpec,
            metrics: ScenarioMetrics) -> None:
        path = self.path_for(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        entry = {"cache_version": CACHE_VERSION, "key": key,
                 "spec": canonicalize(spec),
                 "metrics": metrics.to_dict()}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(entry, f, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        n = 0
        for _, _, files in os.walk(self.root):
            n += sum(f.endswith(".json") for f in files)
        return n
