"""Declarative grid axes.

An `Axis` names one override path and the values it takes; combinators
compose axes into a grid expression:

    product(a, b)   — cartesian product, last axis fastest (C order);
    zip_axes(a, b)  — lockstep iteration (equal lengths required);
    chain(g1, g2)   — run grid g1's points, then g2's.

Every grid lowers to an ordered list of coordinate assignments
`((path, value, label), ...)`; `Experiment` applies the values to the
base spec in order and records the labels as the point's grid
coordinates.  Labels default to the value when it is a plain scalar —
pass `labels=` for unwieldy values (whole fault tuples, inline specs).

Two virtual paths exist on top of real spec fields:

    "scenario" — value is a registry name or a `ScenarioSpec`; replaces
                 the base spec (put this axis first);
    "seed"     — perturbs `sim.seed` *and* `workload_seed` by the value
                 (the same semantics as `SweepGrid.seeds`).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple, Union

Coord = Tuple[str, Any, Any]               # (path, value, label)
Point = Tuple[Coord, ...]

SPECIAL_PATHS = ("scenario", "seed")


def _default_label(value: Any) -> Any:
    if isinstance(value, (str, int, float, bool)) or value is None:
        return value
    name = getattr(value, "name", None)    # ScenarioSpec and friends
    if isinstance(name, str):
        return name
    return repr(value)


@dataclass(frozen=True)
class Axis:
    """One swept dimension: `path` (override path or virtual path) and
    the `values` it takes.  `labels` (same length) are what lands in the
    ResultSet coordinate column; they must be JSON scalars."""
    path: str
    values: Tuple[Any, ...]
    labels: Optional[Tuple[Any, ...]] = None

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))
        if self.labels is not None:
            object.__setattr__(self, "labels", tuple(self.labels))
        if not self.values:
            raise ValueError(f"axis {self.path!r} has no values")
        if self.labels is not None and len(self.labels) != len(self.values):
            raise ValueError(
                f"axis {self.path!r}: {len(self.labels)} labels for "
                f"{len(self.values)} values")
        for lab in self.labels or ():
            if not (isinstance(lab, (str, int, float, bool)) or lab is None):
                raise ValueError(
                    f"axis {self.path!r}: label {lab!r} is not a JSON "
                    "scalar")

    def points(self) -> List[Point]:
        labels = (self.labels if self.labels is not None
                  else tuple(_default_label(v) for v in self.values))
        return [((self.path, v, l),) for v, l in zip(self.values, labels)]

    def paths(self) -> Tuple[str, ...]:
        return (self.path,)


GridLike = Union[Axis, "Product", "Zip", "Chain"]


def _as_grid(g) -> GridLike:
    if isinstance(g, (Axis, Product, Zip, Chain)):
        return g
    raise TypeError(
        f"expected an Axis or grid combinator, got {type(g).__name__}: "
        f"{g!r}")


@dataclass(frozen=True)
class Product:
    grids: Tuple[GridLike, ...]

    def points(self) -> List[Point]:
        out = []
        for combo in itertools.product(*(g.points() for g in self.grids)):
            pt: Point = tuple(c for part in combo for c in part)
            seen = [p for p, _, _ in pt]
            dupes = sorted({p for p in seen if seen.count(p) > 1})
            if dupes:
                raise ValueError(
                    f"grid point assigns paths {dupes} more than once")
            out.append(pt)
        return out

    def paths(self) -> Tuple[str, ...]:
        return tuple(p for g in self.grids for p in g.paths())


@dataclass(frozen=True)
class Zip:
    grids: Tuple[GridLike, ...]

    def points(self) -> List[Point]:
        lengths = {len(g.points()) for g in self.grids}
        if len(lengths) > 1:
            raise ValueError(
                f"zip_axes requires equal-length axes; got lengths "
                f"{sorted(len(g.points()) for g in self.grids)}")
        return [tuple(c for part in combo for c in part)
                for combo in zip(*(g.points() for g in self.grids))]

    def paths(self) -> Tuple[str, ...]:
        return tuple(p for g in self.grids for p in g.paths())


@dataclass(frozen=True)
class Chain:
    grids: Tuple[GridLike, ...]

    def points(self) -> List[Point]:
        return [pt for g in self.grids for pt in g.points()]

    def paths(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for g in self.grids:
            for p in g.paths():
                if p not in seen:
                    seen.append(p)
        return tuple(seen)


def product(*grids) -> Product:
    return Product(tuple(_as_grid(g) for g in grids))


def zip_axes(*grids) -> Zip:
    return Zip(tuple(_as_grid(g) for g in grids))


def chain(*grids) -> Chain:
    return Chain(tuple(_as_grid(g) for g in grids))
