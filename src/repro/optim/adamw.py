"""AdamW with decoupled weight decay and global-norm clipping.

State mirrors the parameter tree (sharded identically by GSPMD), fp32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state: Dict, params, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0,
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    count = opt_state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p2 = p32 - lr * (step + cfg.weight_decay * p32)
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        p2, m2, v2 = upd(g, m, v, p)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "count": count},
            {"grad_norm": gnorm})


def cosine_schedule(step: jax.Array, warmup: int, total: int,
                    floor: float = 0.1) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
