"""Transport / NIC models for the simulator.

Four stacks (§6 reference solutions and ablations):
  * 'spx'    — per-(flow, plane) CC contexts + PLB two-stage plane split +
               probe-timeout plane exclusion (the full SPX NIC).
  * 'dcqcn'  — single CC context, ECMP routing (the ETH baseline).
  * 'global' — one shared CC context across planes, oblivious equal split
               (Fig 15 'Global CC' ablation).
  * 'esr'    — entropy-based source routing: one CC loop whose signal
               aggregates planes AND paths (UET-style spraying; Fig 15d) —
               plane selection cannot be steered independently.
  * 'swlb'   — software plane LB: per-plane awareness but O(1 s) reaction
               time (Fig 12 comparison).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

SPX_MD = 0.7
SPX_AI = 0.08
SPX_RTT_GAIN = 0.15
DCQCN_ALPHA_G = 0.0625
DCQCN_AI = 0.01
MIN_RATE = 0.01
TARGET_RTT_US = 12.0
PROBE_TIMEOUT = 3


@dataclass
class NicState:
    mode: str
    n_flows: int
    n_planes: int
    target_rtt_us: float = TARGET_RTT_US
    probe_timeout: int = PROBE_TIMEOUT
    sw_lb_delay_slots: int = 0       # 'swlb': reaction delay in slots

    rate: np.ndarray = field(init=False)        # (F, P) allowances
    alpha: np.ndarray = field(init=False)       # (F, P) dcqcn alpha
    probe_miss: np.ndarray = field(init=False)  # (F, P)
    eligible: np.ndarray = field(init=False)    # (F, P) bool
    pending_fail: np.ndarray = field(init=False)  # swlb delayed reaction

    def __post_init__(self):
        F, P = self.n_flows, self.n_planes
        self.rate = np.ones((F, P))
        self.alpha = np.zeros((F, P))
        self.probe_miss = np.zeros((F, P), np.int64)
        self.eligible = np.ones((F, P), bool)
        self.pending_fail = np.zeros((F, P), np.int64)

    # ------------------------------------------------------------------
    def plane_split(self, demand: np.ndarray) -> np.ndarray:
        """(F,) demand -> (F, P) offered per plane (the PLB, Fig 4)."""
        F, P = self.rate.shape
        if self.mode in ("dcqcn",):
            # single plane topologies use P=1; otherwise equal split
            w = np.ones((F, P)) / P
            return np.minimum(demand[:, None] * w, self.rate)
        if self.mode == "swlb":
            # software LB: oblivious equal split over planes it BELIEVES
            # are up; belief updates only at software timescales (_probe).
            elig = self.eligible
            n_up = np.maximum(elig.sum(1, keepdims=True), 1)
            return np.where(elig, demand[:, None] / n_up, 0.0)
        if self.mode in ("global", "esr"):
            # oblivious equal split over planes believed up; one shared
            # rate context (min over planes' contexts = stored identical)
            elig = self.eligible
            n_up = np.maximum(elig.sum(1, keepdims=True), 1)
            shared = self.rate.min(1, keepdims=True)
            return np.where(elig, demand[:, None] * shared / n_up, 0.0)
        # spx / swlb: rate-filter then weight by allowance
        elig = self.eligible & (self.rate > MIN_RATE + 1e-9)
        any_ok = elig.any(1, keepdims=True)
        elig = np.where(any_ok, elig, self.eligible)
        w = np.where(elig, self.rate, 0.0)
        s = w.sum(1, keepdims=True)
        w = np.where(s > 0, w / np.maximum(s, 1e-12), 1.0 / P)
        return np.minimum(demand[:, None] * w, np.where(elig, self.rate,
                                                        0.0))

    # ------------------------------------------------------------------
    def update(self, offered: np.ndarray, delivered: np.ndarray,
               rtt: np.ndarray, ecn: np.ndarray, slot: int,
               probe_ok: Optional[np.ndarray] = None) -> None:
        """Per-slot control update. offered/delivered: (F, P).
        probe_ok: (F, P) RTT-probe success (plane reachability) — probes
        run independently of data traffic (§4.4.1)."""
        if probe_ok is None:
            probe_ok = ~((offered > 1e-9) & (delivered <= 1e-9))
        self._probe_ok = probe_ok
        F, P = self.rate.shape
        if self.mode == "dcqcn":
            ecn_any = ecn.max(1, keepdims=True)
            self.alpha = ((1 - DCQCN_ALPHA_G) * self.alpha +
                          DCQCN_ALPHA_G * (ecn_any > 0))
            cut = self.rate * (1 - self.alpha / 2)
            grow = np.minimum(self.rate + DCQCN_AI, 1.0)
            self.rate = np.clip(np.where(ecn_any > 0, cut, grow),
                                MIN_RATE, 1.0)
            return

        if self.mode in ("global", "esr"):
            # one context: aggregate signal over planes (and paths for esr)
            agg_ecn = ecn.max(1, keepdims=True)
            agg_rtt = rtt.max(1, keepdims=True)
            cut = self.rate * SPX_MD
            rtt_err = (agg_rtt - self.target_rtt_us) / self.target_rtt_us
            trim = self.rate * (1 - SPX_RTT_GAIN * np.clip(rtt_err, 0, 2))
            grow = np.minimum(self.rate + SPX_AI, 1.0)
            new = np.where(agg_ecn > 0, cut,
                           np.where(rtt_err > 0.25, trim, grow))
            if self.mode == "esr":
                # entangled loops overreact: extra MD when signal flips
                new = np.where(agg_ecn > 0, new * 0.85, new)
            self.rate = np.clip(new, MIN_RATE, 1.0)
            self._probe(offered, delivered, slot)
            return

        # --- spx / swlb: per-plane contexts ---
        rtt_err = (rtt - self.target_rtt_us) / self.target_rtt_us
        cut = self.rate * (SPX_MD + (1 - SPX_MD) * np.clip(1 - ecn, 0, 1))
        trim = self.rate * (1 - SPX_RTT_GAIN * np.clip(rtt_err, 0, 2))
        grow = np.minimum(self.rate + SPX_AI, 1.0)
        self.rate = np.clip(
            np.where(ecn > 0, cut, np.where(rtt_err > 0.25, trim, grow)),
            MIN_RATE, 1.0)
        self._probe(offered, delivered, slot)

    def _probe(self, offered, delivered, slot) -> None:
        """RTT-probe timeouts -> plane exclusion (§4.4.1).  'swlb' flips
        eligibility only sw_lb_delay_slots after detection (software
        timescale); hardware PLB reacts within probe_timeout slots."""
        miss = ~self._probe_ok
        self.probe_miss = np.where(miss, self.probe_miss + 1, 0)
        dead = self.probe_miss >= self.probe_timeout
        if self.mode == "swlb" and self.sw_lb_delay_slots > 0:
            newly = dead & self.eligible & (self.pending_fail == 0)
            self.pending_fail = np.where(
                newly, slot + self.sw_lb_delay_slots, self.pending_fail)
            fire = (self.pending_fail > 0) & (slot >= self.pending_fail)
            self.eligible = np.where(fire & dead, False, self.eligible)
            healed = ~dead & ~self.eligible
            self.eligible = np.where(healed, True, self.eligible)
            self.pending_fail = np.where(~dead, 0, self.pending_fail)
        else:
            was = self.eligible
            self.eligible = ~dead
            just_back = self.eligible & ~was
            self.rate = np.where(just_back, 0.5, self.rate)
        self.rate = np.where(~self.eligible, MIN_RATE, self.rate)
