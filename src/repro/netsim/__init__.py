from .topology import (Fabric, FatTree, LeafSpine, leaf_pair_maxflow,
                       maxflow_matrix)
from .fabric import Flow, FluidFabric, FlowArrays
from .cc import NicState
from .sim import SimConfig, SimResult, run_sim
from .workloads import (bisection_pairs, all2all, one_to_many,
                        ring_neighbors, all2all_cct_us,
                        ring_collective_cct_us, bus_bandwidth_gbps)
from .queuesim import jsq_delay_sim
