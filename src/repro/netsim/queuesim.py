"""Slot-accurate single-switch queue microsimulation (Fig 1b): per-packet
JSQ over N egress ports with a *stale* queue view (load-balancing decision
delay).  100 ns slots.

At delay -> 0 JSQ keeps queues near-empty; at ~1 µs queues grow ~5x; by
~2.5 µs decisions are effectively random and queues saturate.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class QueueSimResult:
    mean_queue: float
    p99_queue: float
    mean_delay_us: float


def jsq_delay_sim(n_ports: int = 256, load: float = 0.9,
                  decision_delay_ns: float = 100.0,
                  slot_ns: float = 100.0, slots: int = 200_000,
                  seed: int = 0, nbins: int = 16,
                  qmax_pkts: float = 64.0) -> QueueSimResult:
    """Each slot: Poisson(load*n_ports) packet arrivals are routed to the
    min-quantized-queue port as seen `decision_delay` ago; each port
    drains one packet per slot."""
    rng = np.random.default_rng(seed)
    delay_slots = max(0, int(round(decision_delay_ns / slot_ns)))
    q = np.zeros(n_ports)
    hist = [q.copy() for _ in range(delay_slots + 1)]
    samples = []
    lam = load * n_ports
    for t in range(slots):
        stale = hist[0]
        n_arr = rng.poisson(lam)
        if n_arr:
            qb = np.floor(np.clip(stale / qmax_pkts, 0, 1 - 1e-9) * nbins)
            # JSQ among min-bin ports, random tie-break — vectorized by
            # assigning arrivals proportionally to min-bin ports
            min_ports = np.flatnonzero(qb == qb.min())
            picks = rng.integers(0, len(min_ports), n_arr)
            np.add.at(q, min_ports[picks], 1.0)
        q = np.maximum(q - 1.0, 0.0)
        hist.append(q.copy())
        hist.pop(0)
        if t > slots // 4:
            samples.append(q.mean())
    samples = np.asarray(samples)
    mean_q = float(samples.mean())
    p99 = float(np.quantile(samples, 0.99))
    return QueueSimResult(mean_queue=mean_q, p99_queue=p99,
                          mean_delay_us=mean_q * slot_ns / 1000.0)
