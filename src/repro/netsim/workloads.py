"""Workload builders (§6.1): RDMA bisection, All2All, one-to-many bursts,
ring collectives — plus CCT calculators.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .fabric import Flow
from .topology import LeafSpine


def bisection_pairs(t: LeafSpine, hosts: Sequence[int],
                    rng: np.random.Generator,
                    group: str = "main") -> List[Flow]:
    """Worst-case pairing: every pair crosses the spine (src and dst on
    different leaves), full line-rate demand."""
    hosts = list(hosts)
    by_leaf = {}
    for h in hosts:
        by_leaf.setdefault(t.leaf_of(h), []).append(h)
    leaves = sorted(by_leaf)
    flows = []
    half = len(leaves) // 2
    left = [h for l in leaves[:half] for h in by_leaf[l]]
    right = [h for l in leaves[half:] for h in by_leaf[l]]
    n = min(len(left), len(right))
    lperm = rng.permutation(left)[:n]
    rperm = rng.permutation(right)[:n]
    for a, b in zip(lperm, rperm):
        flows.append(Flow(int(a), int(b), 1.0, group=group))
        flows.append(Flow(int(b), int(a), 1.0, group=group))
    return flows


def all2all(t: LeafSpine, hosts: Sequence[int], group: str = "main",
            bytes_per_pair: float = np.inf) -> List[Flow]:
    hosts = list(hosts)
    n = len(hosts)
    flows = []
    # ordered pairs; per-flow demand = line_rate / (n-1)
    d = 1.0 / max(n - 1, 1)
    for a in hosts:
        for b in hosts:
            if a != b:
                flows.append(Flow(int(a), int(b), d, bytes_per_pair,
                                  group=group))
    return flows


def one_to_many(t: LeafSpine, srcs: Sequence[int], dsts: Sequence[int],
                group: str = "main",
                bytes_per_flow: float = np.inf) -> List[Flow]:
    d = 1.0 / max(len(dsts), 1)
    return [Flow(int(a), int(b), d, bytes_per_flow, group=group)
            for a in srcs for b in dsts]


def ring_neighbors(hosts: Sequence[int], group: str = "main",
                   bytes_per_hop: float = np.inf) -> List[Flow]:
    """Ring AllGather/ReduceScatter traffic: each rank streams to its
    successor."""
    hosts = list(hosts)
    return [Flow(int(hosts[i]), int(hosts[(i + 1) % len(hosts)]), 1.0,
                 bytes_per_hop, group=group)
            for i in range(len(hosts))]


# ---------------------------------------------------------------------------
# analytic CCT helpers
# ---------------------------------------------------------------------------

def all2all_cct_us(message_bytes: float, n_ranks: int, bw_gbps: float,
                   latency_us: float, chunk_bytes: float = 4 << 20
                   ) -> float:
    """All2All completion time: each rank sends (n-1)/n of the message,
    split into dependent chunk rounds — latency is paid per round (Fig 1a's
    sensitivity)."""
    payload = message_bytes * (n_ranks - 1) / n_ranks
    wire_us = payload * 8.0 / (bw_gbps * 1e3)
    rounds = max(1, int(np.ceil(payload / max(chunk_bytes, 1))))
    return wire_us + rounds * latency_us


def ring_collective_cct_us(message_bytes: float, n_ranks: int,
                           bw_gbps: float, latency_us: float) -> float:
    """Ring AllGather: (n-1) dependent steps of message/n each."""
    step_bytes = message_bytes / n_ranks
    step_us = step_bytes * 8.0 / (bw_gbps * 1e3) + latency_us
    return (n_ranks - 1) * step_us


def bus_bandwidth_gbps(message_bytes: float, cct_us: float,
                       n_ranks: int, kind: str = "all2all") -> float:
    """NCCL bus-bandwidth normalization [22]."""
    factor = (n_ranks - 1) / n_ranks
    return message_bytes * 8.0 * factor / max(cct_us * 1e3, 1e-9)
