"""Multi-plane leaf–spine topologies (NSX-style, fluid granularity).

Link capacities are normalized to 1.0 = one port at line rate.  Parallel
links between switches (sub-max-scale consolidation, §6.1) appear as
capacity > 1 on a (leaf, spine) edge.  Every plane is an independent copy
(§3.1: planes are disconnected, joined only at the host NIC).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class LeafSpine:
    n_leaves: int
    n_spines: int
    hosts_per_leaf: int
    n_planes: int = 1
    parallel_links: int = 1
    link_cap: float = 1.0
    access_cap: float = 1.0

    # capacity arrays (set in __post_init__)
    up: np.ndarray = field(init=False)      # (P, L, S) leaf->spine
    down: np.ndarray = field(init=False)    # (P, S, L) spine->leaf
    access: np.ndarray = field(init=False)  # (P, H) host<->leaf (full dup)

    def __post_init__(self):
        P, L, S = self.n_planes, self.n_leaves, self.n_spines
        cap = self.link_cap * self.parallel_links
        self.up = np.full((P, L, S), cap, np.float64)
        self.down = np.full((P, S, L), cap, np.float64)
        self.access = np.full((P, self.n_hosts), self.access_cap,
                              np.float64)

    @property
    def n_hosts(self) -> int:
        return self.n_leaves * self.hosts_per_leaf

    def leaf_of(self, host: int) -> int:
        return host // self.hosts_per_leaf

    # ---- fault injection -------------------------------------------------
    def fail_uplink(self, plane: int, leaf: int, spine: int,
                    frac: float = 1.0) -> None:
        self.up[plane, leaf, spine] *= (1.0 - frac)
        self.down[plane, spine, leaf] *= (1.0 - frac)

    def trim_leaf_uplinks(self, plane: int, leaf: int,
                          keep_frac: float) -> None:
        """§6.4 / Fig 16: reduce a leaf's uplink capacity to keep_frac."""
        self.up[plane, leaf, :] *= keep_frac
        self.down[plane, :, leaf] *= keep_frac

    def fail_access(self, plane: int, host: int) -> None:
        self.access[plane, host] = 0.0

    def restore_access(self, plane: int, host: int) -> None:
        self.access[plane, host] = self.access_cap

    def random_link_failures(self, rng: np.random.Generator,
                             frac: float) -> None:
        """Uniform random fabric link failures (Fig 1c / §6.4)."""
        for p in range(self.n_planes):
            mask = rng.random((self.n_leaves, self.n_spines)) < frac
            unit = self.link_cap
            self.up[p] = np.maximum(self.up[p] - mask * unit, 0.0)
            self.down[p] = np.maximum(self.down[p] - mask.T * unit, 0.0)

    def copy(self) -> "LeafSpine":
        t = LeafSpine(self.n_leaves, self.n_spines, self.hosts_per_leaf,
                      self.n_planes, self.parallel_links, self.link_cap,
                      self.access_cap)
        t.up = self.up.copy()
        t.down = self.down.copy()
        t.access = self.access.copy()
        return t


def leaf_pair_maxflow(t: LeafSpine, plane: int, l1: int, l2: int) -> float:
    """Max flow leaf->leaf through the spine tier (2-tier: sum over spines
    of min(up, down))."""
    return float(np.sum(np.minimum(t.up[plane, l1, :],
                                   t.down[plane, :, l2])))


def maxflow_matrix(t: LeafSpine, plane: int = 0) -> np.ndarray:
    """(L, L) leaf-pair max-flow (Fig 1c)."""
    up = t.up[plane]                     # (L, S)
    down = t.down[plane]                 # (S, L)
    return np.minimum(up[:, None, :], down.T[None, :, :]).sum(-1)
