"""Tier-generic fabrics: multi-plane leaf–spine and 3-tier fat-tree.

Link capacities are normalized to 1.0 = one port at line rate.  Parallel
links between switches (sub-max-scale consolidation, §6.1) appear as
capacity > 1 on a (leaf, spine) edge.  Every plane is an independent copy
(§3.1: planes are disconnected, joined only at the host NIC).

Two fabric kinds share one protocol (`Fabric`):

* `LeafSpine` — the paper's flat multiplane design: one switching stage,
  path axis = spine index.
* `FatTree` — the hierarchical 3-tier baseline (leaf–agg–core with pods)
  the multiplane argument is made against.  Canonical wiring: core `j`
  attaches to agg `j // (n_cores // n_aggs)` in *every* pod, so an
  inter-pod path is fully determined by the core index and the path axis
  is simply `j ∈ [0, n_cores)`; intra-pod paths alias onto aggs via
  `agg_of_path[j]`.  Two link stages result:

    stage A  leaf↔agg   `up`/`down`, shapes (P, L, A) / (P, A, L)
             (aggs are pod-local: leaf `l` reaches only its pod's aggs,
             so the local agg index `a` is unambiguous given `l`)
    stage B  pod↔core   `up2`/`down2`, shapes (P, pods, C)
             (each core has exactly one agg link per pod)

  Oversubscription is the ratio of a leaf's host-facing capacity to its
  stage-A uplink capacity, tuned via `link_cap`/`parallel_links` and
  `core_link_cap` (stage B).

Both kinds expose `n_paths`, `path_capacity` (the per-(src_leaf,
dst_leaf, path) min-capacity the ECMP re-hash and max-flow build on),
and tier-aware fault injection; `maxflow_matrix` computes the exact
min-cut across stages (the layered graphs are series-parallel) and sums
across planes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple, Union

import numpy as np


@dataclass
class LeafSpine:
    n_leaves: int
    n_spines: int
    hosts_per_leaf: int
    n_planes: int = 1
    parallel_links: int = 1
    link_cap: float = 1.0
    access_cap: float = 1.0

    kind = "leaf_spine"

    # capacity arrays (set in __post_init__)
    up: np.ndarray = field(init=False)      # (P, L, S) leaf->spine
    down: np.ndarray = field(init=False)    # (P, S, L) spine->leaf
    access: np.ndarray = field(init=False)  # (P, H) host<->leaf (full dup)

    def __post_init__(self):
        P, L, S = self.n_planes, self.n_leaves, self.n_spines
        cap = self.link_cap * self.parallel_links
        self.up = np.full((P, L, S), cap, np.float64)
        self.down = np.full((P, S, L), cap, np.float64)
        self.access = np.full((P, self.n_hosts), self.access_cap,
                              np.float64)

    @property
    def n_hosts(self) -> int:
        return self.n_leaves * self.hosts_per_leaf

    @property
    def n_paths(self) -> int:
        """Size of the per-(leaf pair) routing-choice axis."""
        return self.n_spines

    def leaf_of(self, host: int) -> int:
        return host // self.hosts_per_leaf

    def path_capacity(self, src_leaf: np.ndarray, dst_leaf: np.ndarray
                      ) -> np.ndarray:
        """(F, P, J) min capacity along each path for each leaf pair."""
        cap = np.minimum(self.up[:, src_leaf, :],
                         np.swapaxes(self.down, 1, 2)[:, dst_leaf, :])
        return cap.transpose(1, 0, 2)

    # ---- fault injection -------------------------------------------------
    def fail_uplink(self, plane: int, leaf: int, spine: int,
                    frac: float = 1.0) -> None:
        self.up[plane, leaf, spine] *= (1.0 - frac)
        self.down[plane, spine, leaf] *= (1.0 - frac)

    def trim_leaf_uplinks(self, plane: int, leaf: int,
                          keep_frac: float) -> None:
        """§6.4 / Fig 16: reduce a leaf's uplink capacity to keep_frac."""
        self.up[plane, leaf, :] *= keep_frac
        self.down[plane, :, leaf] *= keep_frac

    def fail_access(self, plane: int, host: int) -> None:
        self.access[plane, host] = 0.0

    def restore_access(self, plane: int, host: int) -> None:
        self.access[plane, host] = self.access_cap

    def random_link_failures(self, rng: np.random.Generator,
                             frac: float) -> None:
        """Uniform random fabric link failures (Fig 1c / §6.4)."""
        for p in range(self.n_planes):
            mask = rng.random((self.n_leaves, self.n_spines)) < frac
            unit = self.link_cap
            self.up[p] = np.maximum(self.up[p] - mask * unit, 0.0)
            self.down[p] = np.maximum(self.down[p] - mask.T * unit, 0.0)

    def copy(self) -> "LeafSpine":
        t = LeafSpine(self.n_leaves, self.n_spines, self.hosts_per_leaf,
                      self.n_planes, self.parallel_links, self.link_cap,
                      self.access_cap)
        t.up = self.up.copy()
        t.down = self.down.copy()
        t.access = self.access.copy()
        return t


@dataclass
class FatTree:
    """3-tier leaf–agg–core fat-tree (see module docstring for the
    path-axis reduction).  `n_cores` must be a multiple of `n_aggs`;
    `core_link_cap` <= 0 inherits the stage-A uplink capacity."""
    n_pods: int
    leaves_per_pod: int
    n_aggs: int                  # agg switches per pod
    n_cores: int                 # core switches, total
    hosts_per_leaf: int
    n_planes: int = 1
    parallel_links: int = 1
    link_cap: float = 1.0        # leaf<->agg discrete link
    core_link_cap: float = 0.0   # pod<->core link; <= 0 -> uplink_cap
    access_cap: float = 1.0

    kind = "fat_tree"

    up: np.ndarray = field(init=False)      # (P, L, A) leaf->agg (local a)
    down: np.ndarray = field(init=False)    # (P, A, L) agg->leaf
    up2: np.ndarray = field(init=False)     # (P, pods, C) agg->core
    down2: np.ndarray = field(init=False)   # (P, pods, C) core->agg
    access: np.ndarray = field(init=False)  # (P, H)

    def __post_init__(self):
        if self.n_pods < 2:
            raise ValueError("FatTree requires n_pods >= 2 "
                             "(use LeafSpine for a single-stage fabric)")
        if self.n_cores % self.n_aggs != 0 or self.n_cores < self.n_aggs:
            raise ValueError(
                f"n_cores ({self.n_cores}) must be a positive multiple "
                f"of n_aggs ({self.n_aggs})")
        P, L, A = self.n_planes, self.n_leaves, self.n_aggs
        cap = self.link_cap * self.parallel_links
        self.up = np.full((P, L, A), cap, np.float64)
        self.down = np.full((P, A, L), cap, np.float64)
        ccap = self.core_cap
        self.up2 = np.full((P, self.n_pods, self.n_cores), ccap,
                           np.float64)
        self.down2 = np.full((P, self.n_pods, self.n_cores), ccap,
                             np.float64)
        self.access = np.full((P, self.n_hosts), self.access_cap,
                              np.float64)

    # ---- shape helpers ---------------------------------------------------
    @property
    def n_leaves(self) -> int:
        return self.n_pods * self.leaves_per_pod

    @property
    def n_hosts(self) -> int:
        return self.n_leaves * self.hosts_per_leaf

    @property
    def n_paths(self) -> int:
        return self.n_cores

    @property
    def cores_per_agg(self) -> int:
        return self.n_cores // self.n_aggs

    @property
    def core_cap(self) -> float:
        return (self.core_link_cap if self.core_link_cap > 0
                else self.link_cap * self.parallel_links)

    @property
    def agg_of_path(self) -> np.ndarray:
        """(C,) local agg index serving path (= core) j, in every pod."""
        return np.arange(self.n_cores) // self.cores_per_agg

    @property
    def pod_of_leaf(self) -> np.ndarray:
        return np.arange(self.n_leaves) // self.leaves_per_pod

    def leaf_of(self, host: int) -> int:
        return host // self.hosts_per_leaf

    def path_capacity(self, src_leaf: np.ndarray, dst_leaf: np.ndarray
                      ) -> np.ndarray:
        """(F, P, J) min capacity along each path: stage-A on both ends,
        plus the pod<->core hops when the pair crosses pods."""
        src_leaf = np.asarray(src_leaf)
        dst_leaf = np.asarray(dst_leaf)
        aj = self.agg_of_path
        capA = np.minimum(self.up[:, src_leaf, :][:, :, aj],
                          self.down[:, aj, :][:, :, dst_leaf]
                          .transpose(0, 2, 1))            # (P, F, J)
        pod_s = self.pod_of_leaf[src_leaf]
        pod_d = self.pod_of_leaf[dst_leaf]
        capB = np.minimum(self.up2[:, pod_s, :],
                          self.down2[:, pod_d, :])        # (P, F, J)
        cross = (pod_s != pod_d)[None, :, None]
        return np.where(cross, np.minimum(capA, capB),
                        capA).transpose(1, 0, 2)

    # ---- fault injection -------------------------------------------------
    def fail_uplink(self, plane: int, leaf: int, agg: int,
                    frac: float = 1.0) -> None:
        """Kill `frac` of a stage-A (leaf, local agg) link."""
        self.up[plane, leaf, agg] *= (1.0 - frac)
        self.down[plane, agg, leaf] *= (1.0 - frac)

    def fail_core_link(self, plane: int, pod: int, core: int,
                       frac: float = 1.0) -> None:
        """Kill `frac` of a stage-B (pod, core) link pair."""
        self.up2[plane, pod, core] *= (1.0 - frac)
        self.down2[plane, pod, core] *= (1.0 - frac)

    def fail_agg(self, plane: int, pod: int, agg: int) -> None:
        """Whole-switch loss: the agg's leaf links and core links die."""
        lo, hi = pod * self.leaves_per_pod, (pod + 1) * self.leaves_per_pod
        self.up[plane, lo:hi, agg] = 0.0
        self.down[plane, agg, lo:hi] = 0.0
        cores = np.flatnonzero(self.agg_of_path == agg)
        self.up2[plane, pod, cores] = 0.0
        self.down2[plane, pod, cores] = 0.0

    def trim_leaf_uplinks(self, plane: int, leaf: int,
                          keep_frac: float) -> None:
        self.up[plane, leaf, :] *= keep_frac
        self.down[plane, :, leaf] *= keep_frac

    def fail_access(self, plane: int, host: int) -> None:
        self.access[plane, host] = 0.0

    def restore_access(self, plane: int, host: int) -> None:
        self.access[plane, host] = self.access_cap

    def random_link_failures(self, rng: np.random.Generator,
                             frac: float) -> None:
        """Uniform random failures over BOTH stages: every leaf–agg and
        every pod–core link fails independently with probability `frac`
        (one discrete link subtracted, floor 0)."""
        for p in range(self.n_planes):
            mask = rng.random((self.n_leaves, self.n_aggs)) < frac
            unit = self.link_cap
            self.up[p] = np.maximum(self.up[p] - mask * unit, 0.0)
            self.down[p] = np.maximum(self.down[p] - mask.T * unit, 0.0)
            mask2 = rng.random((self.n_pods, self.n_cores)) < frac
            unit2 = self.core_cap
            self.up2[p] = np.maximum(self.up2[p] - mask2 * unit2, 0.0)
            self.down2[p] = np.maximum(self.down2[p] - mask2 * unit2, 0.0)

    def copy(self) -> "FatTree":
        t = FatTree(self.n_pods, self.leaves_per_pod, self.n_aggs,
                    self.n_cores, self.hosts_per_leaf, self.n_planes,
                    self.parallel_links, self.link_cap,
                    self.core_link_cap, self.access_cap)
        t.up = self.up.copy()
        t.down = self.down.copy()
        t.up2 = self.up2.copy()
        t.down2 = self.down2.copy()
        t.access = self.access.copy()
        return t


Fabric = Union[LeafSpine, FatTree]


def backup_path_table(kind: str, n_paths: int,
                      cores_per_agg: int = 1) -> np.ndarray:
    """(J,) precomputed fast-reroute successor per path index — the
    MRC/SRv6-style backup table derived from the topology shape alone
    (no runtime state), so it compiles once per `Fabric` kind.

    The successor chain must be a single cycle over all J paths:
    `backup_reassign` walks it until the first alive path, so a chain
    that partitions into sub-cycles could starve even when alive paths
    exist elsewhere.

    leaf_spine: next spine, `(j + 1) % S` — any failed (leaf, spine)
    uplink falls over to the neighboring plane-local spine.

    fat_tree: next agg first.  Core j is served by agg `j // cpa`; a
    stage-A (leaf, agg) failure takes out that agg's whole core bundle
    at once, so the useful fallback is a core under the *next* agg
    (`j + cpa`), preserving the within-agg offset.  The last agg wraps
    to agg 0 while stepping the offset (`(j % cpa + 1) % cpa`), which
    stitches the A sub-chains into one full J-cycle."""
    if kind == "leaf_spine":
        return ((np.arange(n_paths) + 1) % n_paths).astype(np.int32)
    j = np.arange(n_paths)
    cpa = cores_per_agg
    wrap = j >= n_paths - cpa                 # cores under the last agg
    return np.where(wrap, (j % cpa + 1) % cpa, j + cpa).astype(np.int32)


# ---------------------------------------------------------------------------
# max-flow as min-cut across stages
# ---------------------------------------------------------------------------

def _planes(t: Fabric, plane: Optional[int]) -> List[int]:
    return list(range(t.n_planes)) if plane is None else [plane]


def leaf_pair_maxflow(t: Fabric, l1: int, l2: int,
                      plane: Optional[int] = None) -> float:
    """Max flow leaf->leaf through the fabric.  `plane=None` (default)
    sums every plane — planes are disconnected copies joined at the NIC,
    so fabric-level max-flow is additive across them; pass an int to
    restrict to one plane.

    leaf_spine: sum over spines of min(up, down).
    fat_tree:   exact min-cut of the series-parallel layered graph —
    per agg, the leaf-facing bottleneck caps the parallel core bundle:
    sum_a min(min(up1, down1), sum_{j in a} min(up2, down2)) for
    cross-pod pairs; intra-pod pairs never leave stage A.
    """
    total = 0.0
    for p in _planes(t, plane):
        if t.kind == "leaf_spine":
            total += float(np.sum(np.minimum(t.up[p, l1, :],
                                             t.down[p, :, l2])))
            continue
        capA = np.minimum(t.up[p, l1, :], t.down[p, :, l2])   # (A,)
        pod1 = int(t.pod_of_leaf[l1])
        pod2 = int(t.pod_of_leaf[l2])
        if pod1 == pod2:
            total += float(capA.sum())
            continue
        capB = np.minimum(t.up2[p, pod1, :], t.down2[p, pod2, :])  # (C,)
        bundle = capB.reshape(t.n_aggs, t.cores_per_agg).sum(1)
        total += float(np.minimum(capA, bundle).sum())
    return total


def maxflow_matrix(t: Fabric, plane: Optional[int] = None) -> np.ndarray:
    """(L, L) leaf-pair max-flow (Fig 1c).  `plane=None` sums across
    planes (the whole-fabric figure the multiplane claims are about);
    an int restricts to one plane."""
    L = t.n_leaves
    out = np.zeros((L, L))
    for p in _planes(t, plane):
        if t.kind == "leaf_spine":
            up = t.up[p]                     # (L, S)
            down = t.down[p]                 # (S, L)
            out += np.minimum(up[:, None, :],
                              down.T[None, :, :]).sum(-1)
            continue
        capA = np.minimum(t.up[p][:, None, :],
                          t.down[p].T[None, :, :])        # (L, L, A)
        pods = t.pod_of_leaf
        # stage B only varies per (pod, pod): bundle at pod granularity
        # first, then gather per leaf pair — (pods, pods, A), not (L, L, C)
        capB_pod = np.minimum(t.up2[p][:, None, :],
                              t.down2[p][None, :, :])     # (pods, pods, C)
        bundle_pod = capB_pod.reshape(t.n_pods, t.n_pods, t.n_aggs,
                                      t.cores_per_agg).sum(-1)
        bundle = bundle_pod[pods[:, None], pods[None, :]]  # (L, L, A)
        cross = pods[:, None] != pods[None, :]
        out += np.where(cross[:, :, None],
                        np.minimum(capA, bundle), capA).sum(-1)
    return out
