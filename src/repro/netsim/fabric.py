"""Discrete-time fluid fabric: flows, routing fractions, queues, ECN.

Each slot (default 10 µs):
  1. NIC PLB splits each flow's offered rate across planes (per-packet in
     hardware -> fractional in the fluid model).
  2. In-plane routing splits a flow's plane-rate across spines: ECMP = a
     fixed hash assignment; AR = quantized-JSQ fractions re-balanced every
     slot; weighted-AR folds in remote capacity weights (§4.4.2).
  3. Link loads -> bottleneck scaling (lossless: excess becomes queue/PFC
     backpressure, modeled as achieved-rate scaling + queue growth).
  4. Queues update; ECN marks where queueing persists beyond what AR can
     re-balance; per-(flow, plane) RTT proxy = base + queue delays.

Fully vectorized over flows (all2all workloads reach 1e5 flows).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .topology import LeafSpine

# fabric constants — the JAX backend (netsim/jx) imports these so the two
# engines cannot drift when one is tuned
ECN_QUEUE_THRESH = 3.0
AR_TEMPERATURE = 0.25
JSQ_BINS = 16
Q_CAP = 64.0


@dataclass
class Flow:
    src: int
    dst: int
    demand: float = 1.0          # offered rate cap (line rate = 1.0)
    bytes_total: float = np.inf  # in rate*slot units (CCT workloads)
    group: str = "main"
    start_slot: int = 0


@dataclass
class FlowArrays:
    src: np.ndarray
    dst: np.ndarray
    src_leaf: np.ndarray
    dst_leaf: np.ndarray
    demand: np.ndarray
    bytes_total: np.ndarray
    group: np.ndarray            # int-coded
    groups: List[str]
    start_slot: np.ndarray = None

    @classmethod
    def build(cls, flows: List[Flow], t: LeafSpine) -> "FlowArrays":
        src = np.array([f.src for f in flows], np.int64)
        dst = np.array([f.dst for f in flows], np.int64)
        names = sorted({f.group for f in flows})
        gmap = {g: i for i, g in enumerate(names)}
        return cls(
            src=src, dst=dst,
            src_leaf=src // t.hosts_per_leaf,
            dst_leaf=dst // t.hosts_per_leaf,
            demand=np.array([f.demand for f in flows]),
            bytes_total=np.array([f.bytes_total for f in flows]),
            group=np.array([gmap[f.group] for f in flows], np.int64),
            groups=names,
            start_slot=np.array([f.start_slot for f in flows], np.int64))

    def __len__(self) -> int:
        return self.src.shape[0]


@dataclass
class FabricState:
    q_up: np.ndarray             # (P, L, S) in slot*cap units
    q_down: np.ndarray           # (P, S, L)

    @classmethod
    def zeros(cls, t: LeafSpine) -> "FabricState":
        return cls(np.zeros_like(t.up), np.zeros_like(t.down))


@dataclass
class SlotResult:
    achieved: np.ndarray         # (F,) total goodput this slot
    plane_rates: np.ndarray      # (F, P) achieved per plane
    rtt: np.ndarray              # (F, P) µs proxy
    ecn: np.ndarray              # (F, P) marked fraction
    util_up: np.ndarray          # (P, L, S)


class FluidFabric:
    def __init__(self, topo: LeafSpine, base_rtt_us: float = 4.0,
                 slot_us: float = 10.0,
                 ecn_queue_thresh: float = ECN_QUEUE_THRESH,
                 ar_temperature: float = AR_TEMPERATURE,
                 jsq_bins: int = JSQ_BINS, q_cap: float = Q_CAP):
        self.t = topo
        self.state = FabricState.zeros(topo)
        self.base_rtt = base_rtt_us
        self.slot_us = slot_us
        self.ecn_thresh = ecn_queue_thresh
        self.ar_temp = ar_temperature
        self.jsq_bins = jsq_bins
        self.q_cap = q_cap

    # ------------------------------------------------------------------
    def pair_fractions(self, mode: str,
                       remote_weights: Optional[np.ndarray] = None
                       ) -> np.ndarray:
        """(P, L, L, S) spine split per (plane, src leaf, dst leaf).
        mode: 'ar' | 'war'.  (ECMP is per-flow — see ecmp_fractions.)"""
        t = self.t
        P, L, S = t.n_planes, t.n_leaves, t.n_spines
        cap = np.minimum(t.up[:, :, None, :],                 # (P,L,1,S)
                         np.swapaxes(t.down, 1, 2)[:, None, :, :])
        up_mask = cap > 1e-9
        q = (self.state.q_up[:, :, None, :] +
             np.swapaxes(self.state.q_down, 1, 2)[:, None, :, :])
        qbin = np.floor(np.clip(q / 8.0, 0, 1 - 1e-9) * self.jsq_bins) + 1.0
        w = cap.copy()
        if mode == "war" and remote_weights is not None:
            # remote_weights: (P, S, L) healthy-capacity weight to dst leaf
            w = w * np.swapaxes(remote_weights, 1, 2)[:, None, :, :]
        score = qbin / np.maximum(w, 1e-9)
        logit = np.where(up_mask, -score / self.ar_temp, -1e30)
        logit -= logit.max(-1, keepdims=True)
        e = np.exp(logit)
        sums = e.sum(-1, keepdims=True)
        return np.where(sums > 0, e / np.maximum(sums, 1e-30), 0.0)

    def ecmp_fractions(self, fa: FlowArrays,
                       assign: np.ndarray) -> np.ndarray:
        """assign: (F, P) spine index per flow per plane -> (F, P, S)."""
        F, P, S = len(fa), self.t.n_planes, self.t.n_spines
        out = np.zeros((F, P, S))
        fi = np.repeat(np.arange(F), P)
        pi = np.tile(np.arange(P), F)
        out[fi, pi, assign.reshape(-1)] = 1.0
        return out

    # ------------------------------------------------------------------
    def step(self, fa: FlowArrays, plane_rates: np.ndarray,
             frac: np.ndarray) -> SlotResult:
        """plane_rates: (F, P) offered; frac: (F, P, S). Vectorized."""
        t = self.t
        F, P, S, L = len(fa), t.n_planes, t.n_spines, t.n_leaves
        eps = 1e-12
        same_leaf = fa.src_leaf == fa.dst_leaf
        fabric_rate = np.where(same_leaf[:, None], 0.0, plane_rates)
        contrib = fabric_rate[:, :, None] * frac              # (F, P, S)

        # ---- offered load per link ----
        load_up = np.zeros((L, P, S))
        np.add.at(load_up, fa.src_leaf, contrib.transpose(0, 1, 2))
        load_up = load_up.transpose(1, 0, 2)                  # (P, L, S)
        load_down = np.zeros((L, P, S))
        np.add.at(load_down, fa.dst_leaf, contrib)
        load_down = load_down.transpose(1, 2, 0)              # (P, S, L)
        load_acc_tx = np.zeros((t.n_hosts, P))
        np.add.at(load_acc_tx, fa.src, plane_rates)
        load_acc_rx = np.zeros((t.n_hosts, P))
        np.add.at(load_acc_rx, fa.dst, plane_rates)

        # ---- bottleneck scaling ----
        f_up = np.minimum(1.0, t.up / np.maximum(load_up, eps))
        f_down = np.minimum(1.0, t.down / np.maximum(load_down, eps))
        acc = t.access.T                                      # (H, P)
        f_acc_tx = np.minimum(1.0, acc / np.maximum(load_acc_tx, eps))
        f_acc_rx = np.minimum(1.0, acc / np.maximum(load_acc_rx, eps))
        up_alive_tx = acc[fa.src] > eps                       # (F, P)
        up_alive_rx = acc[fa.dst] > eps

        # ---- achieved per (flow, plane) ----
        fup_g = f_up[:, fa.src_leaf, :].transpose(1, 0, 2)    # (F, P, S)
        fdn_g = f_down.transpose(0, 2, 1)[:, fa.dst_leaf, :]
        fdn_g = fdn_g.transpose(1, 0, 2)                      # (F, P, S)
        scale = np.minimum(fup_g, fdn_g)
        through = (contrib * scale).sum(-1)                   # (F, P)
        local = np.where(same_leaf[:, None], plane_rates, 0.0)
        acc_scale = np.minimum(f_acc_tx[fa.src], f_acc_rx[fa.dst])
        achieved_pp = (through + local) * acc_scale
        achieved_pp = np.where(up_alive_tx & up_alive_rx, achieved_pp, 0.0)

        # ---- rtt / ecn per (flow, plane) ----
        q_path = (self.state.q_up[:, fa.src_leaf, :].transpose(1, 0, 2) +
                  self.state.q_down.transpose(0, 2, 1)[:, fa.dst_leaf, :]
                  .transpose(1, 0, 2))                        # (F, P, S)
        qmean = (frac * q_path).sum(-1)                       # (F, P)
        qmean = np.where(same_leaf[:, None], 0.0, qmean)
        rtt = self.base_rtt + qmean * self.slot_us * 0.5
        ecn = np.where(qmean > self.ecn_thresh,
                       np.minimum(1.0, qmean / (4 * self.ecn_thresh)), 0.0)

        # ---- queue evolution ----
        self.state.q_up = np.clip(
            self.state.q_up + (load_up - t.up) / np.maximum(t.up, eps),
            0.0, self.q_cap)
        self.state.q_down = np.clip(
            self.state.q_down + (load_down - t.down) /
            np.maximum(t.down, eps), 0.0, self.q_cap)
        self.state.q_up[t.up <= eps] = 0.0
        self.state.q_down[t.down <= eps] = 0.0

        util = load_up / np.maximum(t.up, eps)
        return SlotResult(achieved=achieved_pp.sum(1),
                          plane_rates=achieved_pp, rtt=rtt, ecn=ecn,
                          util_up=util)
