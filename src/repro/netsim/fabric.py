"""Discrete-time fluid fabric: flows, routing fractions, queues, ECN.

Each slot (default 10 µs):
  1. NIC PLB splits each flow's offered rate across planes (per-packet in
     hardware -> fractional in the fluid model).
  2. In-plane routing splits a flow's plane-rate across the fabric's
     path axis (spines on leaf_spine, cores on fat_tree): ECMP = a
     fixed hash assignment; AR = quantized-JSQ fractions re-balanced every
     slot; weighted-AR folds in remote capacity weights (§4.4.2).
  3. Link loads -> bottleneck scaling (lossless: excess becomes queue/PFC
     backpressure, modeled as achieved-rate scaling + queue growth).
     On fat_tree, loads and bottlenecks are computed per *stage*: path
     contributions fold onto the serving leaf–agg link (stage A) and —
     for cross-pod traffic only — the pod–core link (stage B).
  4. Queues update; ECN marks where queueing persists beyond what AR can
     re-balance; per-(flow, plane) RTT proxy = base + queue delays.

Fully vectorized over flows (all2all workloads reach 1e5 flows).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .topology import Fabric, FatTree, LeafSpine

# fabric constants — the JAX backend (netsim/jx) imports these so the two
# engines cannot drift when one is tuned
ECN_QUEUE_THRESH = 3.0
AR_TEMPERATURE = 0.25
JSQ_BINS = 16
Q_CAP = 64.0


@dataclass
class Flow:
    src: int
    dst: int
    demand: float = 1.0          # offered rate cap (line rate = 1.0)
    bytes_total: float = np.inf  # in rate*slot units (CCT workloads)
    group: str = "main"
    start_slot: int = 0
    phase: int = 0               # demand-timeline lane (0 = always-on)


@dataclass
class FlowArrays:
    src: np.ndarray
    dst: np.ndarray
    src_leaf: np.ndarray
    dst_leaf: np.ndarray
    demand: np.ndarray
    bytes_total: np.ndarray
    group: np.ndarray            # int-coded
    groups: List[str]
    start_slot: np.ndarray = None
    phase: np.ndarray = None     # demand-timeline lane per flow

    @classmethod
    def build(cls, flows: List[Flow], t) -> "FlowArrays":
        """`t` is any fabric/spec exposing `hosts_per_leaf`."""
        src = np.array([f.src for f in flows], np.int64)
        dst = np.array([f.dst for f in flows], np.int64)
        names = sorted({f.group for f in flows})
        gmap = {g: i for i, g in enumerate(names)}
        return cls(
            src=src, dst=dst,
            src_leaf=src // t.hosts_per_leaf,
            dst_leaf=dst // t.hosts_per_leaf,
            demand=np.array([f.demand for f in flows]),
            bytes_total=np.array([f.bytes_total for f in flows]),
            group=np.array([gmap[f.group] for f in flows], np.int64),
            groups=names,
            start_slot=np.array([f.start_slot for f in flows], np.int64),
            phase=np.array([f.phase for f in flows], np.int64))

    def __len__(self) -> int:
        return self.src.shape[0]


@dataclass
class FabricState:
    """Per-link queues in slot*cap units.  Stage A (`q_up`/`q_down`) is
    leaf↔spine on leaf_spine and leaf↔agg on fat_tree; stage B
    (`q2_up`/`q2_down`, fat_tree only) is the pod↔core tier."""
    q_up: np.ndarray             # (P, L, S|A)
    q_down: np.ndarray           # (P, S|A, L)
    q2_up: Optional[np.ndarray] = None    # (P, pods, C)
    q2_down: Optional[np.ndarray] = None  # (P, pods, C)

    @classmethod
    def zeros(cls, t: Fabric) -> "FabricState":
        if t.kind == "fat_tree":
            return cls(np.zeros_like(t.up), np.zeros_like(t.down),
                       np.zeros_like(t.up2), np.zeros_like(t.down2))
        return cls(np.zeros_like(t.up), np.zeros_like(t.down))


@dataclass
class SlotResult:
    achieved: np.ndarray         # (F,) total goodput this slot
    plane_rates: np.ndarray      # (F, P) achieved per plane
    rtt: np.ndarray              # (F, P) µs proxy
    ecn: np.ndarray              # (F, P) marked fraction
    util_up: np.ndarray          # (P, L, S)


class FluidFabric:
    def __init__(self, topo: Fabric, base_rtt_us: float = 4.0,
                 slot_us: float = 10.0,
                 ecn_queue_thresh: float = ECN_QUEUE_THRESH,
                 ar_temperature: float = AR_TEMPERATURE,
                 jsq_bins: int = JSQ_BINS, q_cap: float = Q_CAP,
                 route_topo: Optional[Fabric] = None):
        """`route_topo` is the *routing-visible* fabric (failure-reaction
        lowering): fractions and remote weights read its capacities while
        delivery, queues, and bottlenecks stay on the physical `topo`.
        `None` routes against the physical fabric (instant detection)."""
        self.t = topo
        self.rt = topo if route_topo is None else route_topo
        self.state = FabricState.zeros(topo)
        self.base_rtt = base_rtt_us
        self.slot_us = slot_us
        self.ecn_thresh = ecn_queue_thresh
        self.ar_temp = ar_temperature
        self.jsq_bins = jsq_bins
        self.q_cap = q_cap

    # ------------------------------------------------------------------
    def _jsq_softmax(self, q: np.ndarray, cap: np.ndarray,
                     w: np.ndarray) -> np.ndarray:
        """Quantized-JSQ scoring + softmax over the path axis — the one
        fraction formula both topology kinds share (and the jnp/Pallas
        kernel `kernels.jsq_route.pair_fractions` mirrors)."""
        qbin = np.floor(np.clip(q / 8.0, 0, 1 - 1e-9) * self.jsq_bins) + 1.0
        score = qbin / np.maximum(w, 1e-9)
        logit = np.where(cap > 1e-9, -score / self.ar_temp, -1e30)
        logit -= logit.max(-1, keepdims=True)
        e = np.exp(logit)
        sums = e.sum(-1, keepdims=True)
        return np.where(sums > 0, e / np.maximum(sums, 1e-30), 0.0)

    def pair_fractions(self, mode: str,
                       remote_weights: Optional[np.ndarray] = None
                       ) -> np.ndarray:
        """(P, L, L, J) path split per (plane, src leaf, dst leaf) —
        J = spines (leaf_spine) or cores (fat_tree).  mode: 'ar' | 'war'.
        (ECMP is per-flow — see ecmp_fractions.)  `remote_weights` is
        (P, J, L): healthy-capacity weight of path j toward dst leaf."""
        t = self.rt
        if t.kind == "fat_tree":
            return self._pair_fractions_fat_tree(mode, remote_weights)
        cap = np.minimum(t.up[:, :, None, :],                 # (P,L,1,S)
                         np.swapaxes(t.down, 1, 2)[:, None, :, :])
        q = (self.state.q_up[:, :, None, :] +
             np.swapaxes(self.state.q_down, 1, 2)[:, None, :, :])
        w = cap.copy()
        if mode == "war" and remote_weights is not None:
            w = w * remote_weights.transpose(0, 2, 1)[:, None, :, :]
        return self._jsq_softmax(q, cap, w)

    def _pair_fractions_fat_tree(self, mode: str,
                                 remote_weights: Optional[np.ndarray]
                                 ) -> np.ndarray:
        """Fat-tree pair split: per-path capacity/queue compose stage A
        (leaf↔agg, via the path→agg map) with stage B (pod↔core) for
        cross-pod pairs; intra-pod pairs see stage A only."""
        t, st = self.rt, self.state
        aj = t.agg_of_path                                   # (J,)
        pol = t.pod_of_leaf                                  # (L,)
        cross = (pol[:, None] != pol[None, :])[None, :, :, None]
        upJ = t.up[:, :, aj]                                 # (P, L, J)
        dnJ = t.down[:, aj, :]                               # (P, J, L)
        capA = np.minimum(upJ[:, :, None, :],
                          dnJ.transpose(0, 2, 1)[:, None, :, :])
        capB = np.minimum(t.up2[:, pol, :][:, :, None, :],
                          t.down2[:, pol, :][:, None, :, :])
        cap = np.where(cross, np.minimum(capA, capB), capA)
        qA = (st.q_up[:, :, aj][:, :, None, :] +
              st.q_down[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
        qB = (st.q2_up[:, pol, :][:, :, None, :] +
              st.q2_down[:, pol, :][:, None, :, :])
        q = qA + np.where(cross, qB, 0.0)
        w = cap.copy()
        if mode == "war" and remote_weights is not None:
            w = w * remote_weights.transpose(0, 2, 1)[:, None, :, :]
        return self._jsq_softmax(q, cap, w)

    def remote_weights(self) -> np.ndarray:
        """(P, J, L) weighted-AR remote weight: healthy downstream
        capacity of path j toward dst leaf, normalized per leaf.  On
        fat_tree the weight composes the agg→leaf link with the
        core→agg hop serving the leaf's pod."""
        t = self.rt
        if t.kind == "fat_tree":
            aj, pol = t.agg_of_path, t.pod_of_leaf
            eff = np.minimum(t.down[:, aj, :],
                             t.down2[:, pol, :].transpose(0, 2, 1))
        else:
            eff = t.down
        return eff / np.maximum(eff.max(axis=1, keepdims=True), 1e-9)

    def ecmp_fractions(self, fa: FlowArrays,
                       assign: np.ndarray) -> np.ndarray:
        """assign: (F, P) path index per flow per plane -> (F, P, J)."""
        F, P, J = len(fa), self.t.n_planes, self.t.n_paths
        out = np.zeros((F, P, J))
        fi = np.repeat(np.arange(F), P)
        pi = np.tile(np.arange(P), F)
        out[fi, pi, assign.reshape(-1)] = 1.0
        return out

    # ------------------------------------------------------------------
    def step(self, fa: FlowArrays, plane_rates: np.ndarray,
             frac: np.ndarray,
             pair: Optional[np.ndarray] = None) -> SlotResult:
        """plane_rates: (F, P) offered; frac: (F, P, J) path fractions.
        Vectorized; dispatches on the fabric's stage structure.

        `pair` is the (P, L, L, J) fraction table `frac` was gathered
        from (AR/WAR only).  On fat_tree the dense-fraction load math
        then runs pair-aggregated — the exact op sequence the JAX engine
        uses — so the two backends' queue trajectories stay bit-aligned
        through the quantized-JSQ floor and the ECN threshold (AR's
        symmetric fractions park queues exactly on those knife edges;
        see `tests/test_jx_parity.py`'s fat-tree suite)."""
        if self.t.kind == "fat_tree":
            return self._step_fat_tree(fa, plane_rates, frac, pair)
        return self._step_leaf_spine(fa, plane_rates, frac)

    def _access_scale(self, fa: FlowArrays, plane_rates: np.ndarray,
                      eps: float) -> Tuple[np.ndarray, np.ndarray]:
        """Host-port bottleneck scaling + liveness, shared by both
        topology kinds: (F, P) scale, (F, P) alive mask."""
        t = self.t
        P = t.n_planes
        load_acc_tx = np.zeros((t.n_hosts, P))
        np.add.at(load_acc_tx, fa.src, plane_rates)
        load_acc_rx = np.zeros((t.n_hosts, P))
        np.add.at(load_acc_rx, fa.dst, plane_rates)
        acc = t.access.T                                      # (H, P)
        f_acc_tx = np.minimum(1.0, acc / np.maximum(load_acc_tx, eps))
        f_acc_rx = np.minimum(1.0, acc / np.maximum(load_acc_rx, eps))
        scale = np.minimum(f_acc_tx[fa.src], f_acc_rx[fa.dst])
        alive = (acc[fa.src] > eps) & (acc[fa.dst] > eps)
        return scale, alive

    def _step_fat_tree(self, fa: FlowArrays, plane_rates: np.ndarray,
                       frac: np.ndarray,
                       pair: Optional[np.ndarray] = None) -> SlotResult:
        """Fat-tree slot step: path contributions fold onto stage-A
        (leaf–agg) links via the path→agg map; cross-pod contributions
        additionally load stage-B (pod–core) links.  Queue/ECN/RTT
        formulas are byte-identical to the 2-tier step, applied per
        stage.  With `pair` (AR/WAR) the loads/throughput run
        pair-aggregated, mirroring `jx.engine._route_pair_ft`; without
        it (ECMP's one-hot fractions) they run per-flow in flow order,
        mirroring the jx plan gathers."""
        t, st = self.t, self.state
        F, P, J = len(fa), t.n_planes, t.n_paths
        L, A, pods = t.n_leaves, t.n_aggs, t.n_pods
        cpa, lpp = t.cores_per_agg, t.leaves_per_pod
        aj, pol = t.agg_of_path, t.pod_of_leaf
        eps = 1e-12
        same_leaf = fa.src_leaf == fa.dst_leaf
        fabric_rate = np.where(same_leaf[:, None], 0.0, plane_rates)
        cross_f = pol[fa.src_leaf] != pol[fa.dst_leaf]        # (F,)

        # ---- offered load per link, per stage ----
        if pair is not None:
            pair_idx = fa.src_leaf * L + fa.dst_leaf
            rate_pair = np.zeros((L * L, P))
            np.add.at(rate_pair, pair_idx, fabric_rate)       # flow order
            rate_pair = rate_pair.T.reshape(P, L, L)
            loadJ_up = np.einsum("plm,plmj->plj", rate_pair, pair)
            loadJ_dn = np.einsum("plm,plmj->pmj", rate_pair, pair)
            loadA_up = loadJ_up.reshape(P, L, A, cpa).sum(-1)
            loadA_dn = loadJ_dn.reshape(P, L, A, cpa).sum(-1) \
                .transpose(0, 2, 1)                           # (P, A, L)
            xpod = pol[:, None] != pol[None, :]
            ratex = rate_pair * xpod[None]
            loadB_up = np.einsum("plm,plmj->plj", ratex, pair) \
                .reshape(P, pods, lpp, J).sum(2)              # (P, pods, J)
            loadB_dn = np.einsum("plm,plmj->pmj", ratex, pair) \
                .reshape(P, pods, lpp, J).sum(2)
        else:
            contrib = fabric_rate[:, :, None] * frac          # (F, P, J)
            contribB = contrib * cross_f[:, None, None]
            contribA = contrib.reshape(F, P, A, cpa).sum(-1)  # (F, P, A)
            loadA_up = np.zeros((L, P, A))
            np.add.at(loadA_up, fa.src_leaf, contribA)
            loadA_up = loadA_up.transpose(1, 0, 2)            # (P, L, A)
            loadA_dn = np.zeros((L, P, A))
            np.add.at(loadA_dn, fa.dst_leaf, contribA)
            loadA_dn = loadA_dn.transpose(1, 2, 0)            # (P, A, L)
            loadB_up = np.zeros((pods, P, J))
            np.add.at(loadB_up, pol[fa.src_leaf], contribB)
            loadB_up = loadB_up.transpose(1, 0, 2)            # (P, pods, J)
            loadB_dn = np.zeros((pods, P, J))
            np.add.at(loadB_dn, pol[fa.dst_leaf], contribB)
            loadB_dn = loadB_dn.transpose(1, 0, 2)

        # ---- bottleneck scaling per stage ----
        fA_up = np.minimum(1.0, t.up / np.maximum(loadA_up, eps))
        fA_dn = np.minimum(1.0, t.down / np.maximum(loadA_dn, eps))
        fB_up = np.minimum(1.0, t.up2 / np.maximum(loadB_up, eps))
        fB_dn = np.minimum(1.0, t.down2 / np.maximum(loadB_dn, eps))

        # ---- achieved per (flow, plane): min stage scale per path ----
        if pair is not None:
            cross = (pol[:, None] != pol[None, :])[None, :, :, None]
            sA = np.minimum(
                fA_up[:, :, aj][:, :, None, :],
                fA_dn[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
            sB = np.minimum(fB_up[:, pol, :][:, :, None, :],
                            fB_dn[:, pol, :][:, None, :, :])
            scale_pair = np.where(cross, np.minimum(sA, sB), sA)
            path_scale = (pair * scale_pair).sum(-1).reshape(P, L * L)
            through = fabric_rate * path_scale[:, pair_idx].T
        else:
            gA_up = fA_up[:, fa.src_leaf, :][:, :, aj] \
                .transpose(1, 0, 2)                           # (F, P, J)
            gA_dn = fA_dn[:, aj, :][:, :, fa.dst_leaf] \
                .transpose(2, 0, 1)                           # (F, P, J)
            gB_up = fB_up[:, pol[fa.src_leaf], :].transpose(1, 0, 2)
            gB_dn = fB_dn[:, pol[fa.dst_leaf], :].transpose(1, 0, 2)
            scale = np.minimum(gA_up, gA_dn)
            scaleB = np.minimum(gB_up, gB_dn)
            scale = np.where(cross_f[:, None, None],
                             np.minimum(scale, scaleB), scale)
            through = (contrib * scale).sum(-1)               # (F, P)
        local = np.where(same_leaf[:, None], plane_rates, 0.0)
        acc_scale, acc_alive = self._access_scale(fa, plane_rates, eps)
        achieved_pp = (through + local) * acc_scale
        achieved_pp = np.where(acc_alive, achieved_pp, 0.0)

        # ---- rtt / ecn per (flow, plane): queues along the path ----
        if pair is not None:
            qA_p = (st.q_up[:, :, aj][:, :, None, :] +
                    st.q_down[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
            qB_p = (st.q2_up[:, pol, :][:, :, None, :] +
                    st.q2_down[:, pol, :][:, None, :, :])
            q_pair = qA_p + np.where(cross, qB_p, 0.0)
            qmean = (pair * q_pair).sum(-1) \
                .reshape(P, L * L)[:, pair_idx].T             # (F, P)
        else:
            qA = (st.q_up[:, fa.src_leaf, :][:, :, aj]
                  .transpose(1, 0, 2) +
                  st.q_down[:, aj, :][:, :, fa.dst_leaf]
                  .transpose(2, 0, 1))
            qB = (st.q2_up[:, pol[fa.src_leaf], :].transpose(1, 0, 2) +
                  st.q2_down[:, pol[fa.dst_leaf], :].transpose(1, 0, 2))
            q_path = qA + np.where(cross_f[:, None, None], qB, 0.0)
            qmean = (frac * q_path).sum(-1)                   # (F, P)
        qmean = np.where(same_leaf[:, None], 0.0, qmean)
        rtt = self.base_rtt + qmean * self.slot_us * 0.5
        ecn = np.where(qmean > self.ecn_thresh,
                       np.minimum(1.0, qmean / (4 * self.ecn_thresh)), 0.0)

        # ---- queue evolution, both stages ----
        def integrate(q, load, cap):
            q = np.clip(q + (load - cap) / np.maximum(cap, eps),
                        0.0, self.q_cap)
            q[cap <= eps] = 0.0
            return q

        st.q_up = integrate(st.q_up, loadA_up, t.up)
        st.q_down = integrate(st.q_down, loadA_dn, t.down)
        st.q2_up = integrate(st.q2_up, loadB_up, t.up2)
        st.q2_down = integrate(st.q2_down, loadB_dn, t.down2)

        util = loadA_up / np.maximum(t.up, eps)
        return SlotResult(achieved=achieved_pp.sum(1),
                          plane_rates=achieved_pp, rtt=rtt, ecn=ecn,
                          util_up=util)

    def _step_leaf_spine(self, fa: FlowArrays, plane_rates: np.ndarray,
                         frac: np.ndarray) -> SlotResult:
        """plane_rates: (F, P) offered; frac: (F, P, S). Vectorized."""
        t = self.t
        F, P, S, L = len(fa), t.n_planes, t.n_spines, t.n_leaves
        eps = 1e-12
        same_leaf = fa.src_leaf == fa.dst_leaf
        fabric_rate = np.where(same_leaf[:, None], 0.0, plane_rates)
        contrib = fabric_rate[:, :, None] * frac              # (F, P, S)

        # ---- offered load per link ----
        load_up = np.zeros((L, P, S))
        np.add.at(load_up, fa.src_leaf, contrib.transpose(0, 1, 2))
        load_up = load_up.transpose(1, 0, 2)                  # (P, L, S)
        load_down = np.zeros((L, P, S))
        np.add.at(load_down, fa.dst_leaf, contrib)
        load_down = load_down.transpose(1, 2, 0)              # (P, S, L)

        # ---- bottleneck scaling ----
        f_up = np.minimum(1.0, t.up / np.maximum(load_up, eps))
        f_down = np.minimum(1.0, t.down / np.maximum(load_down, eps))

        # ---- achieved per (flow, plane) ----
        fup_g = f_up[:, fa.src_leaf, :].transpose(1, 0, 2)    # (F, P, S)
        fdn_g = f_down.transpose(0, 2, 1)[:, fa.dst_leaf, :]
        fdn_g = fdn_g.transpose(1, 0, 2)                      # (F, P, S)
        scale = np.minimum(fup_g, fdn_g)
        through = (contrib * scale).sum(-1)                   # (F, P)
        local = np.where(same_leaf[:, None], plane_rates, 0.0)
        acc_scale, acc_alive = self._access_scale(fa, plane_rates, eps)
        achieved_pp = (through + local) * acc_scale
        achieved_pp = np.where(acc_alive, achieved_pp, 0.0)

        # ---- rtt / ecn per (flow, plane) ----
        q_path = (self.state.q_up[:, fa.src_leaf, :].transpose(1, 0, 2) +
                  self.state.q_down.transpose(0, 2, 1)[:, fa.dst_leaf, :]
                  .transpose(1, 0, 2))                        # (F, P, S)
        qmean = (frac * q_path).sum(-1)                       # (F, P)
        qmean = np.where(same_leaf[:, None], 0.0, qmean)
        rtt = self.base_rtt + qmean * self.slot_us * 0.5
        ecn = np.where(qmean > self.ecn_thresh,
                       np.minimum(1.0, qmean / (4 * self.ecn_thresh)), 0.0)

        # ---- queue evolution ----
        self.state.q_up = np.clip(
            self.state.q_up + (load_up - t.up) / np.maximum(t.up, eps),
            0.0, self.q_cap)
        self.state.q_down = np.clip(
            self.state.q_down + (load_down - t.down) /
            np.maximum(t.down, eps), 0.0, self.q_cap)
        self.state.q_up[t.up <= eps] = 0.0
        self.state.q_down[t.down <= eps] = 0.0

        util = load_up / np.maximum(t.up, eps)
        return SlotResult(achieved=achieved_pp.sum(1),
                          plane_rates=achieved_pp, rtt=rtt, ecn=ecn,
                          util_up=util)
