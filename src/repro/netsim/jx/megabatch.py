"""Megabatch dispatch: one XLA launch per experiment sweep.

The per-group path (`engine.dispatch_compiled_batch`) batches only the
seed axis: every distinct (scenario, routing, nic, fault) structure is
its own compiled program and its own launch, so a routing × nic × fault
grid pays tens of compiles and serialized dispatches.  This module
instead stacks *every* point of a grid into one `jit(vmap)` launch —
sharded over the lane axis with a `jax.sharding` Mesh/NamedSharding
when multiple devices are visible:

  * `routing` / `nic` become per-element `StackIdx` branch selectors,
    resolved by `lax.switch` inside the traced program (the engine's
    "traced" dispatch form, `JxConfig.routing == nic == "*"`);
  * flow counts and fault-timeline segment counts are padded up to
    power-of-two buckets so heterogeneous points share static shapes —
    pad flows are inert (zero demand, infinite bytes, never started)
    and pad segments replicate the final capacity snapshot, which the
    per-slot segment-id gather never selects;
  * host-side prep is content-memoized: fault timelines, flow arrays,
    ECMP assignment replays, and aggregation plans are built once per
    distinct (faults, slots, workload-seed, …) key instead of once per
    grid point — a fault × seed grid shares almost everything;
  * the big ECMP permutation plans are deduplicated into one
    batch-constant table (`ecmp_table`) indexed by a per-element `uid`,
    instead of being replicated across the batch (for a 120-point grid
    this shrinks the transfer from O(B) plans to O(#distinct) plans);
  * the initial scan carry is built host-side and donated, so XLA
    reuses its buffers for the carry that the scan rewrites.

Points that cannot share a program (different topology shape, slot
count, record cadence, … or a different shape bucket) split into
multiple launches — still one per *structure*, never one per point.
Row-identity with the per-group path (1e-5, x64) is pinned by
`tests/test_megabatch.py`.

Multi-device runs hand the batch to `engine._jitted_mb` as flat
`(B, ...)` arrays with lane-axis `NamedSharding`s; the jitted program
reshapes to `(shards, B//shards, ...)` internally so each mesh device
sees the same static per-shard lane layout the old `pmap` path used.
The mesh is 1-D over `jax.devices()`, so the same code path extends to
multi-process `jax.distributed` meshes later.

`plan_megabatch` / `dispatch_planned` split the grouping (cheap,
structural) from the host prep + launch (expensive, memoized) so
`experiments/execute.py` can pipeline: prep bucket k+1 on a worker
thread while the device executes bucket k.  `dispatch_megabatch` is the
sequential composition of the two.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.netsim.fabric import FlowArrays
from repro.trace import FLOW_AXIS_FIELDS

from repro.scenarios.spec import reaction_lag

from . import engine
from .engine import JxConfig, JxSimResult, StackIdx, stack_idx_for
from .events import compile_fault_timeline, lagged_timeline


def _bucket(n: int, lo: int = 1) -> int:
    """Smallest power of two >= n (>= lo) — the static-shape buckets
    that let heterogeneous grid points share one compiled program."""
    return max(lo, 1 << max(0, int(n - 1).bit_length()))


# flow-count buckets start here: tiny scenarios all land in one shape
FLOW_BUCKET_MIN = 8


@dataclass
class _Point:
    """Host-side prep for one grid point.  The `*_key` fields are the
    content keys under which shared artifacts were memoized."""
    index: int
    cfg: JxConfig               # struct cfg (routing = nic = "*")
    routing: str
    nic: str
    fa_key: Tuple
    tl_key: Tuple
    assign_key: Optional[Tuple]
    fa: FlowArrays
    boundaries: Tuple[int, ...]
    caps: Tuple[np.ndarray, np.ndarray, np.ndarray]  # (n_seg, ...) each
    assign: Optional[np.ndarray]  # (n_seg, F, P), ECMP points only
    widths: Tuple[int, ...]
    dem: np.ndarray = None        # (n_seg, K) phase-demand snapshots
    # routing-visible capacity snapshots (4 arrays; inert ones-dummies
    # when the point's reaction is off)
    vcaps: Tuple[np.ndarray, ...] = ()


def _struct_cfg(compiled) -> JxConfig:
    """`JxConfig` with routing/nic lifted out of the static key.  The
    swlb reaction delay is resolved unconditionally (SimConfig returns 0
    for non-swlb NICs, but here swlb is one traced branch of every
    program and only swlb elements ever read it).  Schedule points set
    `n_phases` to the pow2 bucket of their lane count, so schedule and
    non-schedule points split into separate structural groups (each
    still one compile per bucket)."""
    sim = compiled.cfg
    base = JxConfig.from_sim(sim, compiled.spec.topo)
    delay = int(sim.sw_lb_delay_ms * 1000 / sim.slot_us)
    pm = getattr(compiled, "phase_mult", None)
    n_phases = _bucket(pm.shape[1]) if pm is not None else 0
    r = compiled.spec.reaction
    react = r is not None and r.enabled
    cfg = replace(base, routing="*", nic="*", sw_lb_delay_slots=delay,
                  n_phases=n_phases, react=react)
    # chunked flow streaming: size the chunk off the point's flow
    # *bucket* (not the raw count) so every point of a shape bucket
    # lands in the same structural group with the same chunk length
    chunk = engine.flow_chunk_default(
        _bucket(len(compiled.flows), FLOW_BUCKET_MIN), cfg.n_planes,
        cfg.agg_mode)
    if chunk and not cfg.trace.enabled:
        cfg = replace(cfg, agg_mode="sparse", flow_chunk=chunk)
    return cfg


def _prepare(index: int, compiled, caches: Dict) -> _Point:
    cfg = _struct_cfg(compiled)
    spec = compiled.spec
    fa_key = (spec.topo, spec.tenants, spec.workloads, spec.workload_seed)
    fa = caches.get(("fa", fa_key))
    if fa is None:
        fa = FlowArrays.build(compiled.flows, compiled.topo)
        engine._warn_f32_bytes(spec.name, fa, stacklevel=5)
        caches[("fa", fa_key)] = fa
    pm = getattr(compiled, "phase_mult", None)
    # phase-change slots join the segment boundaries, so the timeline
    # memo key folds them in ((0,) for every non-schedule point —
    # existing sharing is untouched)
    pb = tuple(engine.phase_boundaries(pm))
    r = spec.reaction
    react = cfg.react
    lag = reaction_lag(r, spec.sim.routing) if react else None
    # the reaction lag shapes both the visible snapshots and the
    # boundary set, so it joins the timeline memo key (None when the
    # reaction is off — existing sharing untouched)
    tl_key = (spec.faults, spec.sim.slots, spec.topo, spec.workload_seed,
              pb, lag)
    cached = caches.get(("tl", tl_key))
    if cached is None:
        tl = compile_fault_timeline(spec)
        vtl = None
        if react:
            vtl = lagged_timeline(tl, lag) if lag > 0 else tl
        boundaries = set(tl.change_slots()) | set(pb)
        if vtl is not None:
            boundaries |= set(vtl.change_slots())
        boundaries = tuple(sorted(boundaries))
        cached = (tl, boundaries, engine._seg_caps(tl, boundaries),
                  engine._vis_seg_caps(vtl, boundaries, cfg.n_planes),
                  vtl)
        caches[("tl", tl_key)] = cached
    tl, boundaries, caps, vcaps, vtl = cached
    routing, nic = spec.sim.routing, spec.sim.nic
    mode = r.mode if react else "instant"
    assign_key = assign = None
    if routing == "ecmp":
        assign_key = (fa_key, tl_key, compiled.cfg.seed, mode)
        assign = caches.get(("assign", assign_key))
        if assign is None:
            assign = engine._assign_for(
                replace(cfg, routing="ecmp"), fa, tl, compiled.cfg.seed,
                boundaries, vtl=vtl, mode=mode,
                backup=getattr(compiled, "backup", None))
            caches[("assign", assign_key)] = assign
    wkey = ("widths", fa_key, assign_key)
    widths = caches.get(wkey)
    if widths is None:
        widths = engine._agg_widths(
            replace(cfg, routing=routing), fa,
            assign if assign is not None
            else np.zeros((1, len(fa), cfg.n_planes), np.int32))
        caches[wkey] = widths
    return _Point(index=index, cfg=cfg, routing=routing, nic=nic,
                  fa_key=fa_key, tl_key=tl_key, assign_key=assign_key,
                  fa=fa, boundaries=boundaries, caps=caps, assign=assign,
                  widths=widths, dem=engine._seg_dem(pm, boundaries),
                  vcaps=vcaps)


def _pad_segs(a: np.ndarray, seg_b: int) -> np.ndarray:
    """Pad the leading segment axis to `seg_b` by replicating the last
    snapshot (never selected by `_seg_id`, which maps real slots only
    onto real segments)."""
    n = a.shape[0]
    if n == seg_b:
        return a
    return np.concatenate([a, np.repeat(a[-1:], seg_b - n, 0)])


def _padded_flow_cols(fa: FlowArrays, F_b: int, slots: int
                      ) -> Dict[str, np.ndarray]:
    """FlowBatch columns padded to the flow bucket.  Pad flows are
    inert: zero demand, infinite remaining bytes, start beyond the
    horizon, and `same_leaf` so they never touch the fabric."""
    F = len(fa)
    pad = F_b - F

    def p(a, fill):
        return np.concatenate([a, np.full(pad, fill, a.dtype)]) \
            if pad else a

    return {
        "src": p(fa.src, 0), "dst": p(fa.dst, 0),
        "src_leaf": p(fa.src_leaf, 0), "dst_leaf": p(fa.dst_leaf, 0),
        "demand": p(fa.demand, 0.0),
        "bytes_total": p(fa.bytes_total, np.inf),
        "start_slot": p(fa.start_slot, slots),
        "same_leaf": p(fa.src_leaf == fa.dst_leaf, True),
        "phase": p(fa.phase, 0),
    }


def _ecmp_plan(cfg: JxConfig, fa: FlowArrays, assign: np.ndarray,
               wu: int, F_b: int, seg_b: int) -> np.ndarray:
    """(seg_b, P, L*S + S*L, wu) ECMP load-aggregation plan — one table
    row, flow-padded to `F_b` (built by the same
    `engine._ecmp_load_plan` the per-group path uses) and
    segment-padded to the bucket."""
    return _pad_segs(engine._ecmp_load_plan(cfg, fa, assign, wu, F_b),
                     seg_b)


def _carry0(B: int, F_b: int, cfg: JxConfig,
            remaining: np.ndarray) -> engine.SimCarry:
    """Batched initial scan carry (the donated argument), mirroring
    `state.init_carry`'s dtypes under the active x64 setting."""
    from .state import NicCarry, SimCarry, probe_miss_dtype, stage_shapes
    x64 = bool(jax.config.jax_enable_x64)
    fdt = np.float64 if x64 else np.float32
    idt = np.int64 if x64 else np.int32
    (P, L, U), b_shape = stage_shapes(cfg)
    nic = NicCarry(
        rate=np.ones((B, F_b, P), fdt),
        alpha=np.zeros((B, F_b, P), fdt),
        probe_miss=np.zeros((B, F_b, P),
                            np.dtype(probe_miss_dtype(cfg, fdt))),
        eligible=np.ones((B, F_b, P), bool),
        pending_fail=np.zeros((B, F_b, P), idt))
    return SimCarry(
        q_up=np.zeros((B, P, L, U), fdt),
        q_down=np.zeros((B, P, U, L), fdt),
        q2_up=np.zeros((B,) + b_shape, fdt),
        q2_down=np.zeros((B,) + b_shape, fdt),
        nic=nic,
        remaining=remaining.astype(fdt),
        done=np.zeros((B, F_b), bool),
        completion=np.full((B, F_b), -1, idt),
        goodput_sum=np.zeros((B, F_b), fdt),
        util_up=np.zeros((B, P, L, U), fdt))


def _dispatch_group(cfg: JxConfig, pts: List[_Point], caches: Dict):
    """Assemble one structural group into a single launch.

    Elements are **lane-sorted** by routing branch: within a lane the
    `StackIdx.route` index is a concrete constant, so the engine traces
    only that routing branch for the lane instead of evaluating every
    branch batch-wide and selecting (`lax.switch`'s behavior under
    `vmap`).  NIC branches — cheap elementwise math — stay per-element
    traced switches, so a lane freely mixes all five NIC stacks (and
    ar/war, which share the pair lane via the traced `is_war` flag).
    Each lane is padded to a multiple of the device count with inert
    replicas of its last element; `finalize_group` drops them."""
    from .state import FlowBatch
    F_b = _bucket(max(len(p.fa) for p in pts), FLOW_BUCKET_MIN)
    if cfg.flow_chunk:
        # chunked runs reshape the flow axis to (chunks, chunk): round
        # the bucket up to a chunk multiple so the streamed scan needs
        # no extra tail pad (the rounding pad is the usual inert kind)
        F_b = -(-F_b // cfg.flow_chunk) * cfg.flow_chunk
    seg_b = _bucket(max(len(p.boundaries) for p in pts))
    widths = tuple(_bucket(m) for m in
                   map(max, zip(*(p.widths for p in pts))))
    wu = widths[3]
    P = cfg.n_planes
    sparse = cfg.agg_mode == "sparse"

    # deduplicated ECMP plan table; uid 0 = the inert all-pad plan that
    # pair-routed elements point at (its gathers read the zero row).
    # Sparse groups never gather a plan, so the table shrinks to one
    # inert cell.
    rows: List[np.ndarray] = [
        np.zeros((1, P, 1, 1), np.int32) if sparse else
        np.full((seg_b, P, engine._plan_rows(cfg), wu), F_b, np.int32)]
    row_uid: Dict[Tuple, int] = {}
    zero_assign = np.zeros((seg_b, F_b, P), np.int32)

    def elem(p: _Point) -> Dict:
        ckey = ("cols", p.fa_key, F_b, cfg.slots)
        cols = caches.get(ckey)
        if cols is None:
            cols = caches[ckey] = _padded_flow_cols(p.fa, F_b, cfg.slots)
        pkey = ("perms", p.fa_key, widths[:3], F_b)
        perms = caches.get(pkey)
        if perms is None:
            a = engine._aggs_for(replace(cfg, routing="ar"), p.fa,
                                 zero_assign, widths, pad=F_b)
            perms = caches[pkey] = (a.src, a.dst, a.pair)
        uid = 0
        assign = zero_assign
        if p.routing == "ecmp":
            if not sparse:
                tkey = (p.assign_key, seg_b, wu, F_b)
                uid = row_uid.get(tkey)
                if uid is None:
                    uid = row_uid[tkey] = len(rows)
                    rows.append(_ecmp_plan(cfg, p.fa, p.assign, wu, F_b,
                                           seg_b))
            assign = _pad_segs(p.assign, seg_b)
            if len(p.fa) < F_b:
                assign = np.concatenate(
                    [assign, np.zeros((seg_b, F_b - len(p.fa), P),
                                      assign.dtype)], axis=1)
        skey = ("segcaps", p.tl_key, seg_b)
        padded = caches.get(skey)
        if padded is None:
            u, d, ac, u2, d2 = p.caps
            padded = caches[skey] = (
                _pad_segs(u, seg_b), _pad_segs(d, seg_b),
                _pad_segs(ac, seg_b), _pad_segs(u2, seg_b),
                _pad_segs(d2, seg_b),
                tuple(_pad_segs(v, seg_b) for v in p.vcaps),
                engine._seg_id(p.boundaries, cfg.slots))
        # phase-demand snapshots: segment-padded like the capacity
        # snapshots, lane-padded with 1.0 to the group's phase bucket
        # (no flow carries a padded phase id)
        K_b = max(cfg.n_phases, 1)
        dem = _pad_segs(p.dem, seg_b)
        if dem.shape[1] < K_b:
            dem = np.concatenate(
                [dem, np.ones((seg_b, K_b - dem.shape[1]), dem.dtype)],
                axis=1)
        return {"index": p.index, "fa": p.fa, "cols": cols,
                "perms": perms, "uid": uid, "assign": assign,
                "caps": padded, "dem": dem,
                "stack": stack_idx_for(p.routing, p.nic)}

    n_dev = len(jax.devices())
    shards = min(len(pts), n_dev) if n_dev > 1 and len(pts) > 1 else 1

    # lane-sort: per route, pad the lane to a multiple of the shard
    # count, then deal each lane's chunks out device-major so every
    # device sees the same static (route, count) layout
    lane_elems: Dict[int, List[Dict]] = {}
    for p in pts:
        lane_elems.setdefault(stack_idx_for(p.routing, p.nic)[0],
                              []).append(elem(p))
    lanes = []
    for route in sorted(lane_elems):
        es = lane_elems[route]
        pad = -len(es) % shards
        es += [dict(es[-1], index=-1)] * pad      # inert replicas
        lanes.append((route, len(es) // shards))
    seq: List[Dict] = []
    for d in range(shards):
        for route, n in lanes:
            seq += lane_elems[route][d * n:(d + 1) * n]
    lanes_static = tuple(lanes)

    B = len(seq)
    fb = FlowBatch(**{k: np.stack([e["cols"][k] for e in seq])
                      for k in seq[0]["cols"]})
    aggs = engine._AggPerms(
        src=np.stack([e["perms"][0] for e in seq]),
        dst=np.stack([e["perms"][1] for e in seq]),
        pair=np.stack([e["perms"][2] for e in seq]),
        ecmp_load=np.zeros((B, 1, 1, 1, 1), np.int32))  # table instead
    table = np.stack(rows)
    stack = StackIdx(
        route=np.array([e["stack"][0] for e in seq], np.int32),
        is_war=np.array([e["stack"][1] for e in seq], bool),
        nic=np.array([e["stack"][2] for e in seq], np.int32),
        is_esr=np.array([e["stack"][3] for e in seq], bool))
    carry0 = _carry0(B, F_b, cfg, fb.bytes_total)
    mapped = (stack, carry0, fb,
              np.stack([e["caps"][0] for e in seq]),
              np.stack([e["caps"][1] for e in seq]),
              np.stack([e["caps"][2] for e in seq]),
              np.stack([e["caps"][3] for e in seq]),
              np.stack([e["caps"][4] for e in seq]),
              np.stack([e["dem"] for e in seq]),
              np.stack([e["caps"][5][0] for e in seq]),
              np.stack([e["caps"][5][1] for e in seq]),
              np.stack([e["caps"][5][2] for e in seq]),
              np.stack([e["caps"][5][3] for e in seq]),
              np.stack([e["assign"] for e in seq]), aggs,
              np.array([e["uid"] for e in seq], np.int32),
              np.stack([e["caps"][6] for e in seq]))
    # multi-shard groups stay flat (B, ...): the mesh-sharded program
    # reshapes to (shards, B//shards, ...) internally, and `seq` is
    # already dealt device-major so the flat order is shard-major
    engine._record_launch("mega", (cfg, shards, lanes_static),
                          mapped + (table,))
    with warnings.catch_warnings():
        # the scan rewrites the whole donated carry, but only 4 of its
        # leaves alias a program output — jax warns about the rest on
        # every first compile, which is expected here, not actionable
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        out = engine._jitted_mb(cfg, shards, lanes_static)(*mapped,
                                                           table)
    metas = [(e["index"], e["fa"]) for e in seq]
    return cfg, metas, [p.index for p in pts], shards, out


def plan_megabatch(points: List) -> Tuple[Dict, List[List[Tuple]]]:
    """Cheap structural pre-grouping of `CompiledScenario`s: bucket by
    `(struct cfg, flow bucket)` *without* building flow arrays or fault
    timelines.  Returns `(caches, planned)` where each planned group is
    `[(point_index, compiled), ...]` ready for `dispatch_planned` —
    this is the unit the executor pipelines (host prep of group k+1
    overlapping device execution of group k)."""
    engine._BACKEND_USED = True
    caches: Dict = {}
    groups: Dict[Tuple, List[Tuple]] = {}
    order: List[Tuple] = []
    for i, c in enumerate(points):
        key = (_struct_cfg(c), _bucket(len(c.flows), FLOW_BUCKET_MIN))
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append((i, c))
    return caches, [groups[k] for k in order]


def dispatch_planned(group: List[Tuple], caches: Dict) -> List:
    """Full host prep + launch for one planned group.  Runs the
    memoized `_prepare` for each member, sub-splits by the complete
    structural key (fault-timeline segment counts only become known
    here), and launches each sub-group.  Returns `[(point_indices,
    handle)]` entries for `finalize_group`."""
    prepared = [_prepare(i, c, caches) for i, c in group]
    sub: Dict[Tuple, List[_Point]] = {}
    order: List[Tuple] = []
    for p in prepared:
        key = (p.cfg, _bucket(len(p.fa), FLOW_BUCKET_MIN),
               _bucket(len(p.boundaries)))
        if key not in sub:
            sub[key] = []
            order.append(key)
        sub[key].append(p)
    out = []
    for key in order:
        pts = sub[key]
        handle = _dispatch_group(key[0], pts, caches)
        out.append(([p.index for p in pts], handle))
    return out


def dispatch_megabatch(points: List) -> List:
    """Group `CompiledScenario`s by structural key and launch each group
    as ONE fused program (all groups dispatched before any is awaited —
    JAX CPU execution is async).  Returns `[(point_indices, handle)]`
    for `finalize_group`.  A homogeneous-topology grid — however many
    routing/nic/fault/seed axes it sweeps — is a single group.  This is
    the sequential composition of `plan_megabatch` + `dispatch_planned`;
    the executor's pipelined path calls the two halves itself."""
    caches, planned = plan_megabatch(points)
    out: List = []
    for group in planned:
        out.extend(dispatch_planned(group, caches))
    return out


def finalize_group(handle) -> List[JxSimResult]:
    """Block on one `_dispatch_group` handle and unpack per-point
    results, dropping lane padding and flow-bucket padding and undoing
    the lane sort (results come back in the group's point order)."""
    cfg, metas, order, shards, out = handle
    outs = [np.asarray(o) for o in out]
    by_index = {}
    for b, (index, fa) in enumerate(metas):
        if index < 0 or index in by_index:      # lane pad replica
            continue
        F = len(fa)
        row = [o[b] for o in outs]
        mean_goodput, completion, totals, util = row[:4]
        point_out = [mean_goodput[:F], completion[:F], totals, util]
        tail = 4
        if cfg.react:
            point_out.append(row[tail])       # blackhole timeline (T,)
            tail += 1
        # trace tail: flow-axis fields carry the bucket padding on axis 1
        # (after time); pad flows are inert, so slicing recovers the
        # unpadded capture exactly
        for name, arr in zip(cfg.trace.active_fields(), row[tail:]):
            point_out.append(arr[:, :F] if name in FLOW_AXIS_FIELDS
                             else arr)
        by_index[index] = engine._wrap(cfg, fa, point_out)
    return [by_index[i] for i in order]


def run_megabatch(points: List) -> List[JxSimResult]:
    """Simulate arbitrary `CompiledScenario` grid points with the fewest
    possible launches (one per structural group), returning results in
    point order."""
    results: List = [None] * len(points)
    for idxs, handle in dispatch_megabatch(points):
        for i, r in zip(idxs, finalize_group(handle)):
            results[i] = r
    return results
