"""Chunked flow streaming: `_slot_step` with the flow axis split into
fixed-size chunks (`JxConfig.flow_chunk`), so populations whose per-flow
working set exceeds one device's memory budget still run.

The slot step factors into three stages:

  1. **Accumulate** (inner `lax.scan` over chunks): per-chunk offered
     rates scatter-add into flat per-link / per-host load accumulators
     via `kernels.link_load.segment_load_chunk`.  Folding chunks
     left-to-right reproduces the monolithic `segment_load` call's
     per-bucket addition chain exactly (both lower to the XLA CPU
     scatter expander, which applies duplicate updates in index = flow
     order), so x64 results are **bit-identical** to the unchunked
     engine — including non-divisible tails, whose pad flows are inert
     (+0.0 contributions onto sums of non-negative rates).
  2. **Link-level mid-slot**: bottleneck fractions, pair-fraction
     tables, queue/utilization integration — O(fabric), no flow axis.
  3. **Emit** (second inner scan over chunks): recompute each chunk's
     offered rate (bit-identical elementwise replay of stage 1 — XLA
     CSEs the duplicate when it keeps both live anyway), gather its
     fabric scale/queue view, and run the per-flow NIC / completion /
     goodput updates, stacking the new per-flow carry as scan outputs.

Both inner scans read only the *old* carry (the monolithic step has no
intra-slot feedback into the per-flow state), so chunk order cannot
create sequencing hazards.  The chunk axis being a `lax.scan` is also
what buys the double-buffered transfer structure: under JAX's async
dispatch XLA overlaps fetching chunk k+1's slice with chunk k's
scatter, without the engine managing buffers by hand.

Not supported here: dense aggregation (chunking exists to avoid its
monolithic gather plans), `TraceSpec` captures (per-slot stacked trace
ys would defeat the memory bound), and the megabatch `lax.switch` route
fallback (lanes give a concrete per-lane route index; evaluating both
route branches per chunk would double the streaming cost).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.jsq_route import pair_fractions as _k_pair_fractions
from repro.kernels.link_load import (bottleneck as _k_bottleneck,
                                     segment_load_chunk)
from repro.kernels.queue_ecn import queue_update as _k_queue_update

from . import engine
from .state import FlowBatch, NicCarry, SimCarry, init_carry

_EPS = engine._EPS


def _pad_flows(fb: FlowBatch, F_pad: int, slots: int) -> FlowBatch:
    """Pad the flow axis to a chunk multiple with the megabatch's inert
    pads: zero demand, infinite bytes, start beyond the horizon, and
    `same_leaf` so they never touch the fabric."""
    pad = F_pad - fb.src.shape[0]
    if not pad:
        return fb

    def p(a, fill):
        return jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)])

    return FlowBatch(
        src=p(fb.src, 0), dst=p(fb.dst, 0),
        src_leaf=p(fb.src_leaf, 0), dst_leaf=p(fb.dst_leaf, 0),
        demand=p(fb.demand, 0.0),
        bytes_total=p(fb.bytes_total, jnp.inf),
        start_slot=p(fb.start_slot, slots),
        same_leaf=p(fb.same_leaf, True),
        phase=p(fb.phase, 0))


def _pad_carry(carry: SimCarry, pad: int) -> SimCarry:
    """Pad a caller-built carry's per-flow leaves to the chunk multiple
    (no-op on the megabatch path, whose flow bucket is pre-rounded so
    the donated buffers stay structurally usable)."""
    if not pad:
        return carry

    def p(a, fill):
        return jnp.concatenate(
            [a, jnp.full((pad,) + a.shape[1:], fill, a.dtype)])

    nic = NicCarry(
        rate=p(carry.nic.rate, 1.0), alpha=p(carry.nic.alpha, 0.0),
        probe_miss=p(carry.nic.probe_miss, 0),
        eligible=p(carry.nic.eligible, True),
        pending_fail=p(carry.nic.pending_fail, 0))
    return carry._replace(
        nic=nic, remaining=p(carry.remaining, jnp.inf),
        done=p(carry.done, False), completion=p(carry.completion, -1),
        goodput_sum=p(carry.goodput_sum, 0.0))


def _slot_step_chunked(cfg, route, use_war, stack, fbc, assign_c, F_in,
                       seg_up, seg_down, seg_acc, seg_up2, seg_down2,
                       seg_dem, seg_vup, seg_vdown, seg_vup2, seg_vdown2,
                       carry, xs):
    t, seg = xs
    up = seg_up[seg] * cfg.uplink_cap
    down = seg_down[seg] * cfg.uplink_cap
    acc = (seg_acc[seg] * cfg.access_cap).T
    up2 = seg_up2[seg] * cfg.core_cap
    down2 = seg_down2[seg] * cfg.core_cap
    if cfg.react:
        upv = seg_vup[seg] * cfg.uplink_cap
        downv = seg_vdown[seg] * cfg.uplink_cap
        up2v = seg_vup2[seg] * cfg.core_cap
        down2v = seg_vdown2[seg] * cfg.core_cap
    else:
        upv, downv, up2v, down2v = up, down, up2, down2
    dem_now = seg_dem[seg]

    nc, ch = fbc.src.shape[:2]
    fdt = fbc.demand.dtype
    tm = jax.tree_util.tree_map
    P, L, H = cfg.n_planes, cfg.n_leaves, cfg.n_hosts
    S, A = cfg.n_spines, cfg.n_aggs
    J, cpa = cfg.n_paths, cfg.cores_per_agg
    pods, lpp = cfg.n_pods, cfg.leaves_per_pod
    fat = cfg.kind == "fat_tree"
    pair_route = route == engine.ROUTE_PAIR
    pk = jnp.arange(P)[None, :]

    def chunk_view(a):
        return jnp.reshape(a, (nc, ch) + tuple(a.shape[1:]))

    xs_chunks = (fbc, tm(chunk_view, carry.nic), chunk_view(carry.done),
                 assign_c)

    def offered_of(fb_c, nic_k, done_k):
        """One chunk's plane-split offered rate — evaluated identically
        by both inner scans (all inputs come from the old carry)."""
        demand = jnp.where(done_k | (t < fb_c.start_slot), 0.0,
                           fb_c.demand)
        if cfg.n_phases:
            demand = demand * dem_now[fb_c.phase]
        offered = engine._plane_split(cfg, nic_k, demand, stack)
        return offered, jnp.where(fb_c.same_leaf[:, None], 0.0, offered)

    # ---- pass 1: stream chunks through the scatter-add accumulators --
    if pair_route:
        accs0 = {"pair": jnp.zeros(P * L * L, fdt)}
    elif not fat:
        accs0 = {"up": jnp.zeros(P * L * S, fdt),
                 "dn": jnp.zeros(P * S * L, fdt)}
    else:
        accs0 = {"Au": jnp.zeros(P * L * A, fdt),
                 "Ad": jnp.zeros(P * A * L, fdt),
                 "Bu": jnp.zeros(P * pods * J, fdt),
                 "Bd": jnp.zeros(P * pods * J, fdt)}
    accs0["tx"] = jnp.zeros(H * P, fdt)
    accs0["rx"] = jnp.zeros(H * P, fdt)

    def accumulate(accs, xs_k):
        fb_c, nic_k, done_k, asg_k = xs_k[:4]
        offered, fr = offered_of(fb_c, nic_k, done_k)
        accs = dict(accs)
        accs["tx"] = segment_load_chunk(
            accs["tx"], offered, fb_c.src[:, None] * P + pk)
        accs["rx"] = segment_load_chunk(
            accs["rx"], offered, fb_c.dst[:, None] * P + pk)
        if pair_route:
            pair_idx = fb_c.src_leaf * L + fb_c.dst_leaf
            accs["pair"] = segment_load_chunk(
                accs["pair"], fr, pk * (L * L) + pair_idx[:, None])
        elif not fat:
            assign = asg_k[seg]
            k_up = pk * (L * S) + fb_c.src_leaf[:, None] * S + assign
            k_dn = pk * (S * L) + assign * L + fb_c.dst_leaf[:, None]
            accs["up"] = segment_load_chunk(accs["up"], fr, k_up)
            accs["dn"] = segment_load_chunk(accs["dn"], fr, k_dn)
        else:
            assign = asg_k[seg]
            a_of = assign // cpa
            pod_s = fb_c.src_leaf // lpp
            pod_d = fb_c.dst_leaf // lpp
            # intra-pod flows add exact 0.0 to the stage-B buckets —
            # same contract as the monolithic sparse path
            vB = jnp.where((pod_s != pod_d)[:, None], fr, 0.0)
            kAu = pk * (L * A) + fb_c.src_leaf[:, None] * A + a_of
            kAd = pk * (A * L) + a_of * L + fb_c.dst_leaf[:, None]
            kBu = pk * (pods * J) + pod_s[:, None] * J + assign
            kBd = pk * (pods * J) + pod_d[:, None] * J + assign
            accs["Au"] = segment_load_chunk(accs["Au"], fr, kAu)
            accs["Ad"] = segment_load_chunk(accs["Ad"], fr, kAd)
            accs["Bu"] = segment_load_chunk(accs["Bu"], vB, kBu)
            accs["Bd"] = segment_load_chunk(accs["Bd"], vB, kBd)
        return accs, None

    accs, _ = jax.lax.scan(accumulate, accs0, xs_chunks)

    # ---- mid-slot: link-level math, transcribed from the monolithic
    # route branches (`_route_pair[_ft]` / `_route_ecmp[_ft]`) ----
    bh_mid = None
    if pair_route and not fat:
        rate_pair = accs["pair"].reshape(P, L, L)
        rw_arr = downv / jnp.maximum(
            downv.max(axis=1, keepdims=True), 1e-9)
        if isinstance(use_war, bool):
            rw = rw_arr if use_war else None
        else:
            rw = jnp.where(use_war, rw_arr, jnp.ones_like(downv))
        pair = engine._pair_fractions(cfg, carry.q_up, carry.q_down,
                                      upv, downv, rw)
        load_up = jnp.einsum("plm,plms->pls", rate_pair, pair)
        load_down = jnp.einsum("plm,plms->psm", rate_pair, pair)
        f_up, f_down = engine._bottleneck(cfg, up, down, load_up,
                                          load_down)
        scale_pair = jnp.minimum(
            f_up[:, :, None, :],
            f_down.transpose(0, 2, 1)[:, None, :, :])
        path_scale = (pair * scale_pair).sum(-1).reshape(P, L * L)
        q_pair = (carry.q_up[:, :, None, :] +
                  carry.q_down.transpose(0, 2, 1)[:, None, :, :])
        q_tab = (pair * q_pair).sum(-1).reshape(P, L * L)
        if cfg.react:
            cap = jnp.minimum(up[:, :, None, :],
                              jnp.swapaxes(down, 1, 2)[:, None, :, :])
            bh_mid = (rate_pair[..., None] * pair * (cap <= _EPS)).sum()
    elif pair_route:
        rate_pair = accs["pair"].reshape(P, L, L)
        aj, pol = engine._ft_maps(cfg)
        cross_t = (pol[:, None] != pol[None, :])[None, :, :, None]
        upJ = upv[:, :, aj]
        dnJ = downv[:, aj, :]
        capA = jnp.minimum(upJ[:, :, None, :],
                           dnJ.transpose(0, 2, 1)[:, None, :, :])
        up2L = up2v[:, pol, :]
        dn2L = down2v[:, pol, :]
        capB = jnp.minimum(up2L[:, :, None, :], dn2L[:, None, :, :])
        cap = jnp.where(cross_t, jnp.minimum(capA, capB), capA)
        qA = (carry.q_up[:, :, aj][:, :, None, :] +
              carry.q_down[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
        qB = (carry.q2_up[:, pol, :][:, :, None, :] +
              carry.q2_down[:, pol, :][:, None, :, :])
        q = qA + jnp.where(cross_t, qB, 0.0)
        eff = jnp.minimum(dnJ, dn2L.transpose(0, 2, 1))
        rw_arr = eff / jnp.maximum(eff.max(axis=1, keepdims=True), 1e-9)
        if isinstance(use_war, bool):
            rw = rw_arr if use_war else None
        else:
            rw = jnp.where(use_war, rw_arr, jnp.ones_like(rw_arr))
        w = cap if rw is None \
            else cap * rw.transpose(0, 2, 1)[:, None, :, :]
        pair = _k_pair_fractions(q, cap, w, nbins=cfg.jsq_bins,
                                 temperature=cfg.ar_temperature,
                                 qmax=8.0, use_pallas=cfg.use_pallas)
        loadJ_up = jnp.einsum("plm,plmj->plj", rate_pair, pair)
        loadJ_dn = jnp.einsum("plm,plmj->pmj", rate_pair, pair)
        load_up = loadJ_up.reshape(P, L, A, cpa).sum(-1)
        load_down = loadJ_dn.reshape(P, L, A, cpa).sum(-1) \
            .transpose(0, 2, 1)
        ratex = rate_pair * (pol[:, None] != pol[None, :])[None]
        loadB_up = jnp.einsum("plm,plmj->plj", ratex, pair) \
            .reshape(P, pods, lpp, J).sum(2)
        loadB_dn = jnp.einsum("plm,plmj->pmj", ratex, pair) \
            .reshape(P, pods, lpp, J).sum(2)
        fA_up, fA_dn = engine._bottleneck(cfg, up, down, load_up,
                                          load_down)
        fB_up, fB_dn = engine._bottleneck(cfg, up2, down2, loadB_up,
                                          loadB_dn)
        sA = jnp.minimum(
            fA_up[:, :, aj][:, :, None, :],
            fA_dn[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
        sB = jnp.minimum(fB_up[:, pol, :][:, :, None, :],
                         fB_dn[:, pol, :][:, None, :, :])
        scale_pair = jnp.where(cross_t, jnp.minimum(sA, sB), sA)
        path_scale = (pair * scale_pair).sum(-1).reshape(P, L * L)
        q_tab = (pair * q).sum(-1).reshape(P, L * L)
        if cfg.react:
            capA_p = jnp.minimum(
                up[:, :, aj][:, :, None, :],
                down[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
            capB_p = jnp.minimum(up2[:, pol, :][:, :, None, :],
                                 down2[:, pol, :][:, None, :, :])
            cap_p = jnp.where(cross_t, jnp.minimum(capA_p, capB_p),
                              capA_p)
            bh_mid = (rate_pair[..., None] * pair
                      * (cap_p <= _EPS)).sum()
    elif not fat:
        load_up = accs["up"].reshape(P, L, S)
        load_down = accs["dn"].reshape(P, S, L)
        f_up, f_down = engine._bottleneck(cfg, up, down, load_up,
                                          load_down)
    else:
        load_up = accs["Au"].reshape(P, L, A)
        load_down = accs["Ad"].reshape(P, A, L)
        loadB_up = accs["Bu"].reshape(P, pods, J)
        loadB_dn = accs["Bd"].reshape(P, pods, J)
        fA_up, fA_dn = engine._bottleneck(cfg, up, down, load_up,
                                          load_down)
        fB_up, fB_dn = engine._bottleneck(cfg, up2, down2, loadB_up,
                                          loadB_dn)

    load_acc_tx = accs["tx"].reshape(H, P)
    load_acc_rx = accs["rx"].reshape(H, P)
    f_acc_tx = _k_bottleneck(acc, load_acc_tx, eps=_EPS,
                             use_pallas=cfg.use_pallas)
    f_acc_rx = _k_bottleneck(acc, load_acc_rx, eps=_EPS,
                             use_pallas=cfg.use_pallas)

    # queue evolution reads the OLD carry + the accumulated loads, so it
    # can run before the per-flow pass (the monolithic step has no
    # intra-slot queue feedback either)
    q_up, util = _k_queue_update(carry.q_up, load_up, up,
                                 q_cap=cfg.q_cap, eps=_EPS,
                                 use_pallas=cfg.use_pallas)
    q_down, _ = _k_queue_update(carry.q_down, load_down, down,
                                q_cap=cfg.q_cap, eps=_EPS,
                                use_pallas=cfg.use_pallas)
    if fat:
        q2_up, _ = _k_queue_update(carry.q2_up, loadB_up, up2,
                                   q_cap=cfg.q_cap, eps=_EPS,
                                   use_pallas=cfg.use_pallas)
        q2_down, _ = _k_queue_update(carry.q2_down, loadB_dn, down2,
                                     q_cap=cfg.q_cap, eps=_EPS,
                                     use_pallas=cfg.use_pallas)
    else:
        q2_up, q2_down = carry.q2_up, carry.q2_down

    # ---- pass 2: stream chunks through the per-flow fabric gathers.
    # Only the gather-heavy delivery math stays inside the chunk scan;
    # the NIC / completion / goodput updates run once on the flat
    # (F_pad, ...) results below, in the monolithic step's exact op
    # order — keeping those mul-add chains out of the scan body, where
    # XLA's small-shape codegen (scalar FMA contraction at chunk sizes
    # like 1) would cost the last ulp of x64 parity. ----
    p_io = jnp.arange(P)[None, :].repeat(ch, 0)

    def emit(_, xs_k):
        fb_c, nic_k, done_k, asg_k = xs_k
        offered, fr = offered_of(fb_c, nic_k, done_k)
        emit_bh = ()
        if pair_route:
            pair_idx = fb_c.src_leaf * L + fb_c.dst_leaf
            through = fr * path_scale[:, pair_idx].T
            qmean = q_tab[:, pair_idx].T
        elif not fat:
            assign = asg_k[seg]
            scale_f = jnp.minimum(
                f_up[p_io, fb_c.src_leaf[:, None], assign],
                f_down[p_io, assign, fb_c.dst_leaf[:, None]])
            through = fr * scale_f
            qmean = (carry.q_up[p_io, fb_c.src_leaf[:, None], assign] +
                     carry.q_down[p_io, assign, fb_c.dst_leaf[:, None]])
            if cfg.react:
                capF = jnp.minimum(
                    up[p_io, fb_c.src_leaf[:, None], assign],
                    down[p_io, assign, fb_c.dst_leaf[:, None]])
                emit_bh = (fr * (capF <= _EPS),)
        else:
            assign = asg_k[seg]
            a_of = assign // cpa
            pod_s = fb_c.src_leaf // lpp
            pod_d = fb_c.dst_leaf // lpp
            cross = (pod_s != pod_d)[:, None]
            sAf = jnp.minimum(
                fA_up[p_io, fb_c.src_leaf[:, None], a_of],
                fA_dn[p_io, a_of, fb_c.dst_leaf[:, None]])
            sBf = jnp.minimum(fB_up[p_io, pod_s[:, None], assign],
                              fB_dn[p_io, pod_d[:, None], assign])
            through = fr * jnp.where(cross, jnp.minimum(sAf, sBf), sAf)
            qAf = (carry.q_up[p_io, fb_c.src_leaf[:, None], a_of] +
                   carry.q_down[p_io, a_of, fb_c.dst_leaf[:, None]])
            qBf = (carry.q2_up[p_io, pod_s[:, None], assign] +
                   carry.q2_down[p_io, pod_d[:, None], assign])
            qmean = qAf + jnp.where(cross, qBf, 0.0)
            if cfg.react:
                capAf = jnp.minimum(
                    up[p_io, fb_c.src_leaf[:, None], a_of],
                    down[p_io, a_of, fb_c.dst_leaf[:, None]])
                capBf = jnp.minimum(
                    up2[p_io, pod_s[:, None], assign],
                    down2[p_io, pod_d[:, None], assign])
                capF = jnp.where(cross, jnp.minimum(capAf, capBf),
                                 capAf)
                emit_bh = (fr * (capF <= _EPS),)
        up_alive_tx = acc[fb_c.src] > _EPS
        up_alive_rx = acc[fb_c.dst] > _EPS
        local = jnp.where(fb_c.same_leaf[:, None], offered, 0.0)
        acc_scale = jnp.minimum(f_acc_tx[fb_c.src], f_acc_rx[fb_c.dst])
        achieved_pp = (through + local) * acc_scale
        achieved_pp = jnp.where(up_alive_tx & up_alive_rx, achieved_pp,
                                0.0)
        qmean = jnp.where(fb_c.same_leaf[:, None], 0.0, qmean)
        probe_ok = (acc[fb_c.src] > _EPS) & (acc[fb_c.dst] > _EPS)
        stalled = ((offered > 1e-9) & (achieved_pp <= 1e-9)).any(1)
        achieved = jnp.where(stalled, 0.0, achieved_pp.sum(1))
        w = jnp.maximum(offered, _EPS)
        return None, (achieved, w, qmean, probe_ok) + emit_bh

    _, ys2 = jax.lax.scan(emit, None, xs_chunks)

    def flat(a):
        return jnp.reshape(a, (nc * ch,) + tuple(a.shape[2:]))

    achieved = flat(ys2[0])
    w = flat(ys2[1])
    qmean = flat(ys2[2])
    probe_ok = flat(ys2[3])

    # ---- per-flow control/accounting updates, verbatim monolithic ----
    nic_new, rtt, ecn = engine._nic_update(cfg, carry.nic, qmean,
                                           probe_ok, t, stack)
    remaining = carry.remaining - achieved
    newly = (~carry.done) & (remaining <= 0)
    qdelay = (((rtt * w).sum(1) / w.sum(1)) - cfg.base_rtt_us) \
        / cfg.slot_us
    completion = jnp.where(
        newly, t + jnp.ceil(qdelay).astype(carry.completion.dtype),
        carry.completion)
    done = carry.done | newly
    r = cfg.record_every
    n_rec = (cfg.slots + r - 1) // r
    w0 = int(n_rec * cfg.warmup_frac)
    rec = (t % r) == 0
    counted = rec & ((t // r) >= w0) if n_rec > w0 else rec
    goodput_sum = carry.goodput_sum + jnp.where(counted, achieved, 0.0)

    new_carry = SimCarry(
        q_up=q_up, q_down=q_down, q2_up=q2_up, q2_down=q2_down,
        nic=nic_new, remaining=remaining, done=done,
        completion=completion, goodput_sum=goodput_sum, util_up=util)
    # totals reduce over the *incoming* flow count: the (F_in,) slice
    # has the monolithic sum's exact shape, so the reduction tree — and
    # with it x64 bit-parity — matches (pads would only append +0.0
    # terms, but a wider shape alone can change the tree)
    total = achieved[:F_in].sum()
    if not cfg.react:
        return new_carry, total
    bh = bh_mid if pair_route else flat(ys2[4])[:F_in].sum()
    return new_carry, (total, bh)


def simulate_chunked(cfg, fb: FlowBatch, seg_up, seg_down, seg_acc,
                     seg_up2, seg_down2, seg_dem, seg_vup, seg_vdown,
                     seg_vup2, seg_vdown2, assign_segments, seg_id,
                     stack=None, carry0: Optional[SimCarry] = None):
    """`engine._simulate`'s streaming twin (`cfg.flow_chunk > 0`).
    Same operands, same return contract (minus trace tails); dispatched
    from inside `_simulate`, so every caller — per-group, grouped vmap,
    megabatch lanes — streams transparently."""
    ch = int(cfg.flow_chunk)
    if cfg.agg_mode != "sparse":
        raise ValueError(
            "flow_chunk requires agg_mode='sparse' (the dense gather "
            "plans are exactly the monolithic layout chunking avoids)")
    if cfg.trace.enabled:
        raise NotImplementedError(
            "flow_chunk does not compose with TraceSpec captures")
    if stack is not None and not isinstance(stack.route, int):
        raise NotImplementedError(
            "chunked streaming needs a concrete per-lane route index "
            "(megabatch lane-sorts elements); the per-element "
            "lax.switch fallback is unsupported")
    route = (stack.route if stack is not None else
             (engine.ROUTE_ECMP if cfg.routing == "ecmp"
              else engine.ROUTE_PAIR))
    use_war = cfg.routing == "war" if stack is None else stack.is_war
    F_in = int(fb.src.shape[0])
    F_pad = -(-F_in // ch) * ch
    nc = F_pad // ch
    fb = _pad_flows(fb, F_pad, cfg.slots)
    if carry0 is None:
        carry0 = init_carry(fb, cfg)
    else:
        carry0 = _pad_carry(carry0, F_pad - F_in)
    assign = jnp.asarray(assign_segments)
    if assign.shape[1] < F_pad:
        assign = jnp.concatenate(
            [assign, jnp.zeros((assign.shape[0], F_pad - assign.shape[1],
                                assign.shape[2]), assign.dtype)], axis=1)
    # chunk-major views, built once outside the scan: flow columns as
    # (nc, ch, ...), the assignment segments as (nc, n_seg, ch, P)
    fbc = FlowBatch(*[jnp.reshape(jnp.asarray(a),
                                  (nc, ch) + tuple(a.shape[1:]))
                      for a in fb])
    assign_c = jnp.moveaxis(
        assign.reshape(assign.shape[0], nc, ch, assign.shape[2]), 1, 0)
    step = partial(_slot_step_chunked, cfg, route, use_war, stack, fbc,
                   assign_c, F_in,
                   jnp.asarray(seg_up), jnp.asarray(seg_down),
                   jnp.asarray(seg_acc), jnp.asarray(seg_up2),
                   jnp.asarray(seg_down2), jnp.asarray(seg_dem),
                   jnp.asarray(seg_vup), jnp.asarray(seg_vdown),
                   jnp.asarray(seg_vup2), jnp.asarray(seg_vdown2))
    xs = (jnp.arange(cfg.slots), seg_id)
    carry, ys = jax.lax.scan(step, carry0, xs)
    bh = ()
    if cfg.react:
        totals, bh = ys[0], (ys[1],)
    else:
        totals = ys
    r = cfg.record_every
    n_rec = (cfg.slots + r - 1) // r
    w0 = int(n_rec * cfg.warmup_frac)
    frames = (n_rec - w0) if n_rec > w0 else n_rec
    return (carry.goodput_sum[:F_in] / frames, carry.completion[:F_in],
            totals, carry.util_up) + bh
