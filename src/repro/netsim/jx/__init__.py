"""JAX backend for the fluid network simulator.

A pure-functional twin of `netsim.sim.run_sim`: the per-slot dynamics run
as a jitted `lax.scan`, and whole sweep grids run as one `jax.vmap` batch
instead of a process pool — the megabatch path (`megabatch.py`) fuses an
entire routing x nic x fault x seed grid into a single launch that
compiles once, with per-element `StackIdx` branch selection inside the
traced program.  Fault schedules are compiled to dense per-slot capacity
timelines (`events.py`) because Python event callbacks cannot execute
inside `scan` — only `FaultSpec`-declared schedules are supported, not
arbitrary event closures.

Parity: with x64 enabled (`JAX_ENABLE_X64=1` or
`jax.experimental.enable_x64()`), results match the NumPy backend within
1e-5 on every registry scenario (see `tests/test_jx_parity.py`).
"""
from .events import FaultTimeline, compile_fault_timeline, has_static_timeline
from .engine import (JxConfig, JxSimResult, StackIdx, dispatch_stats,
                     reset_dispatch_stats, run_compiled,
                     run_compiled_batch)
from .megabatch import (dispatch_megabatch, dispatch_planned,
                        finalize_group, plan_megabatch, run_megabatch)
from .state import FlowBatch, NicCarry, SimCarry

__all__ = [
    "FaultTimeline", "compile_fault_timeline", "has_static_timeline",
    "JxConfig", "JxSimResult", "StackIdx", "run_compiled",
    "run_compiled_batch", "run_megabatch", "dispatch_megabatch",
    "plan_megabatch", "dispatch_planned", "finalize_group",
    "dispatch_stats", "reset_dispatch_stats",
    "FlowBatch", "NicCarry", "SimCarry",
]
