"""Compile `FaultSpec` schedules to dense per-slot capacity timelines.

`lax.scan` cannot call the Python closures `scenarios.compile.make_events`
builds, so the JAX engine consumes faults as data: for every slot `t` the
timeline holds the capacity *multiplier* (relative to the pristine
capacity) of every uplink `(T, P, L, S)`, downlink `(T, P, S, L)`, and
access port `(T, P, H)` — exactly the state the callback-driven path
would have left on a `LeafSpine` after `events(t)` ran (the property
suite checks this slot-by-slot on random `FaultSpec`s).

This is an independent interpretation of the `FaultSpec` semantics, not a
replay of `make_events`; multipliers compose the same way the in-place
topology mutations do (kills multiply, restores reset to 1).  Dynamic
Python event callbacks remain a NumPy-backend-only feature.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.scenarios.spec import (FAULT_KINDS, FaultSpec, ScenarioSpec,
                                  fault_planes, flap_phase)


@dataclass(frozen=True)
class FaultTimeline:
    """Per-slot capacity multipliers, 1.0 = pristine.  All arrays are
    float64 and non-negative.  Stage A (`up`/`down`) is leaf↔spine on
    leaf_spine and leaf↔agg on fat_tree; `up2`/`down2` carry the
    fat-tree pod↔core tier and are None on leaf_spine."""
    up: np.ndarray         # (T, P, L, S|A)
    down: np.ndarray       # (T, P, S|A, L)
    access: np.ndarray     # (T, P, H)
    up2: Optional[np.ndarray] = None     # (T, P, pods, C)
    down2: Optional[np.ndarray] = None   # (T, P, pods, C)

    @property
    def slots(self) -> int:
        return self.up.shape[0]

    def change_slots(self) -> List[int]:
        """Slots (always including 0) at which any fabric multiplier —
        either stage, or access — differs from the previous slot: the
        only instants the ECMP re-hash or routing weights can see a
        different fabric."""
        stages = [self.up, self.down, self.access]
        if self.up2 is not None:
            stages += [self.up2, self.down2]
        out = [0]
        for t in range(1, self.slots):
            if any(not np.array_equal(s[t], s[t - 1]) for s in stages):
                out.append(t)
        return out


def has_static_timeline(spec: ScenarioSpec) -> bool:
    """True iff every fault is a `FaultSpec` of a known kind — i.e. the
    schedule compiles to a dense timeline the JAX backend can consume."""
    return all(isinstance(f, FaultSpec) and f.kind in FAULT_KINDS
               for f in spec.faults)


# ---------------------------------------------------------------------------
# compiler
# ---------------------------------------------------------------------------

def _apply_fault(t: int, i: int, f: FaultSpec, up: np.ndarray,
                 down: np.ndarray, access: np.ndarray,
                 unit_rel: float, workload_seed: int,
                 up2: Optional[np.ndarray] = None,
                 down2: Optional[np.ndarray] = None,
                 sched: Sequence = ()) -> None:
    """Mutate multiplier arrays in place with fault `f`'s slot-`t` effect.
    `unit_rel` is one discrete stage-A link as a multiplier
    (link_cap/uplink_cap); stage-B core links are whole (unit 1.0).
    `up2`/`down2` are the fat-tree pod↔core multipliers (None on
    leaf_spine), and `spine` indices address pod-local aggs there —
    mirroring `scenarios.compile.make_events` mutation for mutation."""
    P = up.shape[0]
    if f.kind == "link_kill":
        if t == f.start_slot:
            for p in fault_planes(f, P):
                up[p, f.leaf, f.spine] *= (1.0 - f.frac)
                down[p, f.spine, f.leaf] *= (1.0 - f.frac)
        elif f.stop_slot is not None and t == f.stop_slot:
            for p in fault_planes(f, P):
                up[p, f.leaf, f.spine] = 1.0
                down[p, f.spine, f.leaf] = 1.0
    elif f.kind == "link_flap":
        ph = flap_phase(t, f)
        for p in fault_planes(f, P):
            if ph == "fail":
                up[p, f.leaf, f.spine] *= (1.0 - f.frac)
                down[p, f.spine, f.leaf] *= (1.0 - f.frac)
            elif ph == "restore":
                up[p, f.leaf, f.spine] = 1.0
                down[p, f.spine, f.leaf] = 1.0
    elif f.kind == "access_kill":
        if t == f.start_slot:
            for p in fault_planes(f, P):
                access[p, f.host] = 0.0
        elif f.stop_slot is not None and t == f.stop_slot:
            for p in fault_planes(f, P):
                access[p, f.host] = 1.0
    elif f.kind == "access_flap":
        ph = flap_phase(t, f)
        for p in fault_planes(f, P):
            if ph == "fail":
                access[p, f.host] = 0.0
            elif ph == "restore":
                access[p, f.host] = 1.0
    elif f.kind == "cascade":
        for j, s in enumerate(f.spines):
            if t == f.start_slot + j * f.period:
                for p in fault_planes(f, P):
                    if up2 is not None:
                        # fat_tree: whole agg-switch loss in pod f.pod —
                        # its leaf links AND its core links die
                        lpp = up.shape[1] // up2.shape[1]
                        lo, hi = f.pod * lpp, (f.pod + 1) * lpp
                        up[p, lo:hi, s] = 0.0
                        down[p, s, lo:hi] = 0.0
                        cpa = up2.shape[2] // up.shape[2]
                        up2[p, f.pod, s * cpa:(s + 1) * cpa] = 0.0
                        down2[p, f.pod, s * cpa:(s + 1) * cpa] = 0.0
                    else:
                        up[p, :, s] = 0.0
                        down[p, s, :] = 0.0
    elif f.kind == "straggler":
        if t == f.start_slot:
            for p in fault_planes(f, P):
                access[p, f.host] = f.frac
        elif f.stop_slot is not None and t == f.stop_slot:
            for p in fault_planes(f, P):
                access[p, f.host] = 1.0
    elif f.kind == "leaf_trim":
        if t == f.start_slot:
            for p in fault_planes(f, P):
                up[p, f.leaf, :] *= f.frac
                down[p, :, f.leaf] *= f.frac
    elif f.kind == "random_fail":
        if t == f.start_slot:
            # same derived stream as make_events: independent of other
            # faults' existence and firing order
            rng = np.random.default_rng((workload_seed, 7919, i))
            L, S = up.shape[1], up.shape[2]
            if f.count:
                # exact-k mode mirrors fail_uplink's multiplicative
                # degradation, draw for draw (fat_tree draws one index
                # over stage-A then stage-B links, like
                # `scenarios.compile._fail_random_link`)
                pods, C = ((up2.shape[1], up2.shape[2])
                           if up2 is not None else (0, 0))
                for p in fault_planes(f, P):
                    for _ in range(f.count):
                        if up2 is None:
                            leaf = int(rng.integers(L))
                            spine = int(rng.integers(S))
                            up[p, leaf, spine] *= (1.0 - f.frac)
                            down[p, spine, leaf] *= (1.0 - f.frac)
                            continue
                        idx = int(rng.integers(L * S + pods * C))
                        if idx < L * S:
                            up[p, idx // S, idx % S] *= (1.0 - f.frac)
                            down[p, idx % S, idx // S] *= (1.0 - f.frac)
                        else:
                            rem = idx - L * S
                            up2[p, rem // C, rem % C] *= (1.0 - f.frac)
                            down2[p, rem // C, rem % C] *= (1.0 - f.frac)
            else:
                for p in range(P):
                    mask = rng.random((L, S)) < f.frac
                    up[p] = np.maximum(up[p] - mask * unit_rel, 0.0)
                    down[p] = np.maximum(down[p] - mask.T * unit_rel, 0.0)
                    if up2 is not None:
                        mask2 = rng.random(up2.shape[1:]) < f.frac
                        up2[p] = np.maximum(up2[p] - mask2 * 1.0, 0.0)
                        down2[p] = np.maximum(down2[p] - mask2 * 1.0, 0.0)
    elif f.kind == "core_kill":
        if t == f.start_slot:
            for p in fault_planes(f, P):
                up2[p, f.pod, f.core] *= (1.0 - f.frac)
                down2[p, f.pod, f.core] *= (1.0 - f.frac)
        elif f.stop_slot is not None and t == f.stop_slot:
            for p in fault_planes(f, P):
                up2[p, f.pod, f.core] = 1.0
                down2[p, f.pod, f.core] = 1.0
    elif f.kind == "poisson_flap":
        # `sched` is the precomputed (down, up, plane, link) table from
        # `scenarios.compile.poisson_flap_schedule` — mutation for
        # mutation with `apply_poisson_flap`: restores first (full-cap
        # reset), then kills multiply
        L, A = up.shape[1], up.shape[2]
        n_stage_a = L * A
        C = up2.shape[2] if up2 is not None else 0

        def place(link):
            if up2 is None or link < n_stage_a:
                return "a", link // A, link % A
            rem = link - n_stage_a
            return "b", rem // C, rem % C

        for dn, upslot, p, link in sched:
            if t != upslot:
                continue
            stage, x, y = place(link)
            if stage == "a":
                up[p, x, y] = 1.0
                down[p, y, x] = 1.0
            else:
                up2[p, x, y] = 1.0
                down2[p, x, y] = 1.0
        for dn, upslot, p, link in sched:
            if t != dn:
                continue
            stage, x, y = place(link)
            if stage == "a":
                up[p, x, y] *= (1.0 - f.frac)
                down[p, y, x] *= (1.0 - f.frac)
            else:
                up2[p, x, y] *= (1.0 - f.frac)
                down2[p, x, y] *= (1.0 - f.frac)
    else:                                            # pragma: no cover
        raise ValueError(f"unknown fault kind {f.kind!r}")


def compile_fault_timeline(spec: ScenarioSpec) -> FaultTimeline:
    """Lower `spec.faults` to dense multiplier timelines over
    `spec.sim.slots` slots.  Timeline[t] equals the fabric state *after*
    the slot-`t` events fired (mirroring `run_sim`, which applies events
    at the top of each slot)."""
    if not has_static_timeline(spec):
        raise ValueError(
            f"{spec.name}: faults are not all static FaultSpecs; the JAX "
            "backend cannot compile dynamic event callbacks")
    topo, T = spec.topo, spec.sim.slots
    fat = topo.kind == "fat_tree"
    P, L = topo.n_planes, topo.n_leaves
    S = topo.n_aggs if fat else topo.n_spines
    H = topo.n_hosts
    up = np.ones((P, L, S))
    down = np.ones((P, S, L))
    access = np.ones((P, H))
    up2 = np.ones((P, topo.n_pods, topo.n_cores)) if fat else None
    down2 = np.ones((P, topo.n_pods, topo.n_cores)) if fat else None
    unit_rel = topo.link_cap / topo.uplink_cap    # one discrete link
    # deterministic rebuild of each poisson_flap schedule (same derived
    # seed as the events-closure path); lazy import keeps the module
    # free of a scenarios.compile dependency at import time
    scheds = {}
    if any(f.kind == "poisson_flap" for f in spec.faults):
        from repro.scenarios.compile import poisson_flap_schedule
        scheds = {i: poisson_flap_schedule(spec, i)
                  for i, f in enumerate(spec.faults)
                  if f.kind == "poisson_flap"}
    out_up = np.empty((T, P, L, S))
    out_down = np.empty((T, P, S, L))
    out_access = np.empty((T, P, H))
    out_up2 = np.empty((T,) + up2.shape) if fat else None
    out_down2 = np.empty((T,) + down2.shape) if fat else None
    for t in range(T):
        for i, f in enumerate(spec.faults):
            _apply_fault(t, i, f, up, down, access, unit_rel,
                         spec.workload_seed, up2=up2, down2=down2,
                         sched=scheds.get(i, ()))
        out_up[t] = up
        out_down[t] = down
        out_access[t] = access
        if fat:
            out_up2[t] = up2
            out_down2[t] = down2
    return FaultTimeline(up=out_up, down=out_down, access=out_access,
                         up2=out_up2, down2=out_down2)


def lagged_timeline(tl: FaultTimeline, lag: int) -> FaultTimeline:
    """The routing-*visible* twin of a physical timeline under a failure
    reaction with `lag` slots of detection (+convergence) delay: fabric
    stages shift right by `lag` (pristine 1.0 for t < lag); access stays
    all-ones because NIC probes observe host access directly — reaction
    lag applies to fabric reroute only, and an all-ones access lane keeps
    `change_slots()` boundaries purely fabric-driven."""

    def shift(a):
        if a is None:
            return None
        out = np.ones_like(a)
        out[lag:] = a[:a.shape[0] - lag]
        return out

    return FaultTimeline(up=shift(tl.up), down=shift(tl.down),
                         access=np.ones_like(tl.access),
                         up2=shift(tl.up2), down2=shift(tl.down2))


# ---------------------------------------------------------------------------
# ECMP assignment replay
# ---------------------------------------------------------------------------

def timeline_path_capacity(timeline: FaultTimeline, b: int,
                           src_leaf: np.ndarray, dst_leaf: np.ndarray,
                           uplink_cap: float = 1.0,
                           core_cap: float = 1.0,
                           cores_per_agg: int = 1,
                           leaves_per_pod: int = 0) -> np.ndarray:
    """(F, P, J) per-path capacity at boundary slot `b` — the timeline
    twin of `topology.{LeafSpine,FatTree}.path_capacity`.  A fat-tree
    timeline (up2 present) composes stage A via the path→agg map with
    the pod↔core hops for cross-pod pairs."""
    if timeline.up2 is None:
        cap = np.minimum(
            timeline.up[b][:, src_leaf, :],
            np.swapaxes(timeline.down[b], 1, 2)[:, dst_leaf, :])  # (P, F, S)
        return cap.transpose(1, 0, 2) * uplink_cap                # (F, P, S)
    C = timeline.up2.shape[3]
    aj = np.arange(C) // cores_per_agg
    capA = np.minimum(
        timeline.up[b][:, src_leaf, :][:, :, aj],
        timeline.down[b][:, aj, :][:, :, dst_leaf].transpose(0, 2, 1))
    pod_s = src_leaf // leaves_per_pod
    pod_d = dst_leaf // leaves_per_pod
    capB = np.minimum(timeline.up2[b][:, pod_s, :],
                      timeline.down2[b][:, pod_d, :])             # (P, F, C)
    cross = (pod_s != pod_d)[None, :, None]
    cap = np.where(cross,
                   np.minimum(capA * uplink_cap, capB * core_cap),
                   capA * uplink_cap)
    return cap.transpose(1, 0, 2)                                 # (F, P, C)


def ecmp_assign_segments(src_leaf: np.ndarray, dst_leaf: np.ndarray,
                         timeline: FaultTimeline, seed: int,
                         n_paths: int, boundaries: Sequence[int],
                         uplink_cap: float = 1.0,
                         core_cap: float = 1.0,
                         cores_per_agg: int = 1,
                         leaves_per_pod: int = 0,
                         vis_timeline: Optional[FaultTimeline] = None,
                         mode: str = "instant",
                         backup: Optional[np.ndarray] = None
                         ) -> np.ndarray:
    """Replay `run_sim`'s ECMP path assignment (initial hash + dead-path
    re-hash) against the static capacity timeline.

    The NumPy path re-checks assignments every slot but only *draws* from
    its RNG on slots where a currently-assigned path died with an alive
    alternative — which can only happen when fabric capacity changed.
    Replaying the check at each capacity-change boundary therefore
    consumes the RNG identically and yields the exact per-slot assignment
    as a step function over the boundary segments: (n_seg, F, P) int.

    Failure reaction: `vis_timeline` (the `lagged_timeline` view) makes
    the dead-path check steer against what the control plane has
    *detected* rather than physical truth — boundaries where only the
    physical fabric changed leave the visible caps (and hence the RNG)
    untouched, so the per-boundary replay still matches the per-slot
    check exactly.  `mode='backup'` swaps the re-randomizing rehash for
    the RNG-free precomputed `backup` successor walk (the initial hash
    draw is still consumed, matching `run_sim`)."""
    from repro.netsim.sim import backup_reassign, rehash_dead_assign

    check_tl = timeline if vis_timeline is None else vis_timeline
    F = src_leaf.shape[0]
    P = timeline.up.shape[1]
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, n_paths, size=(F, P))
    segments = []
    for b in boundaries:
        cap = timeline_path_capacity(
            check_tl, b, src_leaf, dst_leaf, uplink_cap=uplink_cap,
            core_cap=core_cap, cores_per_agg=cores_per_agg,
            leaves_per_pod=leaves_per_pod)
        if mode == "backup":
            assign = backup_reassign(cap > 1e-12, assign, backup)
        else:
            assign = rehash_dead_assign(cap > 1e-12, assign, rng, n_paths)
        segments.append(np.asarray(assign).copy())
    return np.stack(segments).astype(np.int32)
