"""Jitted slot loop: the JAX twin of `netsim.sim.run_sim`.

One slot is a pure function `(SimCarry, slot inputs) -> SimCarry` that
reproduces, operation for operation, the NumPy pipeline:

  PLB plane split -> routing fractions (AR / weighted-AR from the queue
  carry, ECMP from precompiled assignment segments) -> per-link bottleneck
  scaling -> queue/ECN/RTT evolution -> NIC control update
  (`spx|dcqcn|global|esr|swlb`) -> loss-stall masking -> transfer
  completion.

The loop runs under `lax.scan`; whole sweep axes (seeds, each with its own
flow population and fault timeline) run as one `jax.vmap` batch.  Fault
schedules are compiled to capacity-multiplier timelines by `events.py` and
enter the scan compressed to their piecewise-constant segment snapshots
(per-slot segment-id gathers re-expand them); ECMP spine assignments
arrive as step-function segments precomputed by
`events.ecmp_assign_segments` (the dead-path re-hash depends only on the
static timeline, so its RNG stream is replayed exactly on the host).

Routing and NIC control exist in two dispatch forms sharing one set of
branch functions:

  * **static** — `cfg.routing`/`cfg.nic` are concrete strings and the
    branch is chosen at trace time (the historical per-group path: one
    compiled program per (scenario, routing, nic) structure);
  * **traced** — `cfg.routing == cfg.nic == "*"` and a per-batch-element
    `StackIdx` selects the branch via `lax.switch` inside the traced
    program, so a whole routing × nic × fault × seed grid runs as ONE
    compiled program (`megabatch.py` builds those batches).

The per-slot hot paths dispatch through the `repro.kernels` package —
NIC plane split (`plb_select.plane_split`), quantized-JSQ spine scoring
(`jsq_route.pair_fractions`), fused load-accumulate + bottleneck
(`link_load.bucket_load_bottleneck` / `link_load.bottleneck`), and the
fused queue/ECN/NIC control update (`queue_ecn.queue_update` /
`queue_ecn.nic_update`): a Pallas kernel on TPU (or under
`REPRO_NETSIM_PALLAS=1`, interpret mode off-TPU), and otherwise a jnp
fallback (`kernels/ref.py`) that is bit-identical to the historical
engine math.

Flow aggregation has two modes (`JxConfig.agg_mode`): **dense** gathers
flows into padded per-link bucket matrices (fast at registry shapes,
but memory is bounded by `leaves² · planes`-sized plans), **sparse**
accumulates with `segment_sum` keyed by (plane, link) so flow count
bounds memory — the giga-scale path, selected automatically for large
fabrics or forced with `REPRO_JX_AGG=dense|sparse`.  On XLA CPU f64 the
sparse scatter applies updates in flow order, matching the NumPy
engine's sequential `np.add.at` bit for bit.

With x64 enabled the trajectory matches the NumPy backend within 1e-5
(registry-wide parity is enforced by `tests/test_jx_parity.py`); without
x64 it runs float32 — faster, looser tolerance (and
`REPRO_JX_COMPACT=1` additionally shrinks the scan carry: int8 probe
counters).
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from dataclasses import dataclass, replace
from functools import partial
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import pallas_enabled
from repro.kernels.jsq_route import pair_fractions as _k_pair_fractions
from repro.kernels.link_load import (bottleneck as _k_bottleneck,
                                     bucket_load_bottleneck,
                                     segment_load)
from repro.kernels.plb_select import plane_split as _k_plane_split
from repro.kernels.queue_ecn import (nic_update as _k_nic_update,
                                     queue_update as _k_queue_update)
from repro.netsim.cc import (DCQCN_AI, DCQCN_ALPHA_G, MIN_RATE,
                             PROBE_TIMEOUT, SPX_AI, SPX_MD, SPX_RTT_GAIN,
                             TARGET_RTT_US)
from repro.netsim.fabric import (AR_TEMPERATURE, ECN_QUEUE_THRESH,
                                 JSQ_BINS, Q_CAP, FlowArrays)
from repro.netsim.sim import SimConfig
from repro.trace import TraceSpec

from .events import (FaultTimeline, compile_fault_timeline,
                     ecmp_assign_segments, lagged_timeline)
from .state import FlowBatch, NicCarry, SimCarry, init_carry

_EPS = 1e-12

# flipped on first dispatch; scenarios.runner consults it to decide
# whether forking a process pool is still safe in this process
_BACKEND_USED = False


def _env_flag(name: str) -> Optional[bool]:
    env = os.environ.get(name)
    if env is None:
        return None
    return env.lower() in ("1", "true", "t", "yes", "y", "on")


def agg_mode_default(n_hosts: int, n_leaves: int, n_paths: int,
                     n_planes: int) -> str:
    """Pick the flow-aggregation mode for a fabric shape.  Dense
    gather-plan bucket sums win at registry shapes (XLA CPU gathers beat
    scatters by ~10x), but their ECMP plans are `2·L²·paths·planes`
    int32 rows per capacity segment — at giga-scale that term, not the
    flow population, dominates memory.  `REPRO_JX_AGG=dense|sparse`
    overrides."""
    env = os.environ.get("REPRO_JX_AGG")
    if env in ("dense", "sparse"):
        return env
    big = (n_hosts >= 4096 or
           n_leaves * n_leaves * n_paths * n_planes > (1 << 22))
    return "sparse" if big else "dense"


def compact_carry_default() -> bool:
    """`REPRO_JX_COMPACT=1` opts float32 runs into the shrunken scan
    carry (int8 probe counters; x64 parity runs always keep wide
    state)."""
    return bool(_env_flag("REPRO_JX_COMPACT"))


# per-flow working-set arrays live per (flow, plane) cell in the chunked
# estimate: 5 NicCarry leaves + offered/fabric_rate/through/qmean/
# achieved_pp/rtt/ecn intermediates
_FLOW_WORKING_ARRAYS = 12


def flow_chunk_default(n_flows: int, n_planes: int,
                       agg_mode: str) -> int:
    """Chunk length for streaming the flow axis through `_slot_step`'s
    sparse path, or 0 to keep the monolithic layout.  Auto-enables when
    the per-flow working set (roughly `_FLOW_WORKING_ARRAYS` live
    (F, P) arrays) exceeds `REPRO_JX_FLOW_BUDGET_MB` (default 8192 —
    one device's comfortable share); `REPRO_JX_FLOW_CHUNK=<n>` forces a
    chunk length (0 disables) regardless of the budget.  Chunking is a
    sparse-aggregation feature: callers that enable it coerce
    `agg_mode="sparse"` (the dense gather plans are exactly the
    monolithic layout chunking exists to avoid)."""
    env = os.environ.get("REPRO_JX_FLOW_CHUNK")
    if env is not None:
        return max(0, int(env))
    if agg_mode != "sparse" or n_flows <= 0:
        return 0
    itemsize = 8 if jax.config.jax_enable_x64 else 4
    per_flow = max(1, n_planes) * itemsize * _FLOW_WORKING_ARRAYS
    budget = float(os.environ.get("REPRO_JX_FLOW_BUDGET_MB", 8192))
    if n_flows * per_flow <= budget * 2**20:
        return 0
    chunk = int(budget * 2**20 // per_flow)
    # pow2 floor (shape-bucket friendly), never below 1024 — tiny
    # chunks would make the inner scan longer than the flow axis wins
    chunk = max(1024, 1 << max(0, chunk.bit_length() - 1))
    return min(chunk, n_flows)


@dataclass(frozen=True)
class JxConfig:
    """Static (hashable) simulation parameters: everything `lax.scan`
    needs resolved at trace time — sim knobs, topology shape, and the
    `FluidFabric` constants.  `routing`/`nic` of `"*"` mean "traced":
    the slot step expects a per-element `StackIdx` and selects the
    branch with `lax.switch` (megabatch mode)."""
    slots: int
    slot_us: float
    routing: str
    nic: str
    base_rtt_us: float
    warmup_frac: float
    record_every: int
    sw_lb_delay_slots: int
    n_planes: int
    n_leaves: int
    n_spines: int
    n_hosts: int
    uplink_cap: float
    access_cap: float
    kind: str = "leaf_spine"
    n_pods: int = 1
    n_aggs: int = 1
    n_cores: int = 1
    core_cap: float = 1.0
    target_rtt_us: float = TARGET_RTT_US
    probe_timeout: int = PROBE_TIMEOUT
    ecn_queue_thresh: float = ECN_QUEUE_THRESH
    ar_temperature: float = AR_TEMPERATURE
    jsq_bins: int = JSQ_BINS
    q_cap: float = Q_CAP
    use_pallas: bool = False
    # "dense": padded gather-plan bucket sums (registry shapes);
    # "sparse": segment_sum keyed by (plane, link), so flow count — not
    # leaves²·paths·planes — bounds memory (giga-scale shapes).
    agg_mode: str = "dense"
    # float32 runs only: int8 probe counters in the scan carry
    compact_carry: bool = False
    # Sparse mode only: >0 streams the flow axis through the slot step
    # in chunks of this length (an inner `lax.scan` accumulates the
    # per-chunk scatter-adds in flow order, so x64 results stay
    # bit-identical to the monolithic layout) — populations larger than
    # one device's memory budget still run.  0 = monolithic (see
    # `flow_chunk_default`).
    flow_chunk: int = 0
    # Schedule workloads: number of demand-multiplier lanes in the
    # per-segment phase timeline (0 = no timeline; the multiply is
    # compiled out and program identity matches pre-schedule HLO).
    n_phases: int = 0
    # Failure reaction (spec.reaction enabled): routing steers against
    # the four extra *visible*-capacity operands (the lagged timeline)
    # and every slot additionally emits the blackholed-byte total.
    # False leaves those operands dead ones-dummies and the scan ys a
    # raw scalar — the traced program is the pre-reaction one.  The
    # detect/converge depths and the reroute mode stay host-side (they
    # only shape the operand *values*), so a mode × detect sweep shares
    # one compiled program per bucket.
    react: bool = False
    # Participates in every jit-cache key / launch fingerprint, so the
    # default (disabled) spec leaves program identity — and the HLO —
    # exactly as if tracing did not exist.
    trace: TraceSpec = TraceSpec()

    @property
    def n_paths(self) -> int:
        """Per-(leaf pair, plane) routing-choice axis: spines on
        leaf_spine, cores on fat_tree."""
        return self.n_spines if self.kind == "leaf_spine" else self.n_cores

    @property
    def n_up(self) -> int:
        """Stage-A link axis per leaf: spines or pod-local aggs."""
        return self.n_spines if self.kind == "leaf_spine" else self.n_aggs

    @property
    def cores_per_agg(self) -> int:
        return self.n_cores // self.n_aggs

    @property
    def leaves_per_pod(self) -> int:
        return self.n_leaves // self.n_pods

    @classmethod
    def from_sim(cls, cfg: SimConfig, topo) -> "JxConfig":
        """`topo` is a `TopologySpec` (or anything with the same shape
        attributes and a uniform base capacity)."""
        kind = getattr(topo, "kind", "leaf_spine")
        fat = kind == "fat_tree"
        return cls(
            slots=cfg.slots, slot_us=cfg.slot_us, routing=cfg.routing,
            nic=cfg.nic, base_rtt_us=cfg.base_rtt_us,
            warmup_frac=cfg.warmup_frac, record_every=cfg.record_every,
            sw_lb_delay_slots=cfg.sw_lb_delay_slots(),
            n_planes=topo.n_planes, n_leaves=topo.n_leaves,
            n_spines=topo.n_spines, n_hosts=topo.n_hosts,
            uplink_cap=topo.link_cap * topo.parallel_links,
            access_cap=topo.access_cap,
            kind=kind,
            n_pods=topo.n_pods if fat else 1,
            n_aggs=topo.n_aggs if fat else 1,
            n_cores=topo.n_cores if fat else 1,
            core_cap=topo.core_cap if fat else 1.0,
            use_pallas=pallas_enabled(),
            agg_mode=agg_mode_default(
                topo.n_hosts, topo.n_leaves,
                topo.n_cores if fat else topo.n_spines, topo.n_planes),
            compact_carry=compact_carry_default(),
            trace=getattr(cfg, "trace", TraceSpec()))


@dataclass
class JxSimResult:
    """Distilled run output — the fields `scenarios.runner` consumes.
    Unlike the NumPy `SimResult` there is no dense `(T, F)` goodput
    record; the per-flow mean and the per-slot total are accumulated
    inside the scan instead."""
    mean_goodput: np.ndarray     # (F,) post-warmup average
    completion_slot: np.ndarray  # (F,) -1 = unfinished
    total_goodput: np.ndarray    # (T_rec,) summed over flows per frame
    util_up_last: np.ndarray     # (P, L, S)
    groups: List[str]
    group_of: np.ndarray
    slot_us: float
    trace: Optional[Dict[str, np.ndarray]] = None
    # failure reaction only: full-rate (T,) per-slot bytes offered onto
    # physically dead paths (None when spec.reaction is off)
    blackhole_timeline: Optional[np.ndarray] = None

    def group_mean(self, group: str) -> float:
        gi = self.groups.index(group)
        return float(self.mean_goodput[self.group_of == gi].mean())


# ---------------------------------------------------------------------------
# traced branch selection (megabatch mode)
# ---------------------------------------------------------------------------

ROUTE_PAIR, ROUTE_ECMP = 0, 1
_SPLIT_MODE = {"spx": "spx", "dcqcn": "dcqcn", "global": "agg",
               "esr": "agg", "swlb": "swlb"}
_BRANCH_ORDER = ("spx", "dcqcn", "agg", "swlb")
_BRANCH_IDX = {m: i for i, m in enumerate(_BRANCH_ORDER)}


class StackIdx(NamedTuple):
    """Per-batch-element (routing, nic) branch selectors for the traced
    dispatch form — scalars under `vmap`, arrays `(B,)` host-side.  The
    one `nic` index selects both the plane-split and the control-update
    branch (their branch lists share `_BRANCH_ORDER`)."""
    route: jnp.ndarray    # 0 = pair (ar/war), 1 = ecmp
    is_war: jnp.ndarray   # bool: fold remote weights into pair scores
    nic: jnp.ndarray      # _BRANCH_ORDER index (split + update)
    is_esr: jnp.ndarray   # bool: ESR's extra multiplicative cut


def stack_idx_for(routing: str, nic: str) -> Tuple[int, bool, int, bool]:
    """Host-side `StackIdx` row for one grid point."""
    return (ROUTE_ECMP if routing == "ecmp" else ROUTE_PAIR,
            routing == "war", _BRANCH_IDX[_SPLIT_MODE[nic]],
            nic == "esr")


# ---------------------------------------------------------------------------
# dispatch bookkeeping: launches + (program-level) compiles
# ---------------------------------------------------------------------------

_STATS_LOCK = threading.RLock()
_STATS = {"dispatches": 0, "compiles": 0}
_SEEN_PROGRAMS: set = set()
_JIT_CACHE: Dict[Tuple, Callable] = {}
_COLLECTORS = threading.local()


class DispatchCounter:
    """Per-scope launch/compile counters (see `collect_dispatch`).
    Incremented only under `_STATS_LOCK`; `snapshot()` returns a plain
    dict in the `dispatch_stats` shape."""

    __slots__ = ("dispatches", "compiles")

    def __init__(self) -> None:
        self.dispatches = 0
        self.compiles = 0

    def snapshot(self) -> Dict[str, int]:
        with _STATS_LOCK:
            return {"dispatches": self.dispatches,
                    "compiles": self.compiles}


@contextmanager
def collect_dispatch():
    """Attribute launches made by *this thread* inside the block to a
    fresh `DispatchCounter`.  Unlike sampling the module-global
    `dispatch_stats` before/after (which misattributes launches from
    concurrent executors), a collector only sees its own thread's
    dispatches.  Collectors nest: every active one on the thread counts
    each launch."""
    stack = getattr(_COLLECTORS, "stack", None)
    if stack is None:
        stack = _COLLECTORS.stack = []
    counter = DispatchCounter()
    stack.append(counter)
    try:
        yield counter
    finally:
        stack.remove(counter)


def current_collectors() -> Tuple["DispatchCounter", ...]:
    """Snapshot of the collectors active on *this* thread — capture it
    before handing work to a helper thread, then `adopt_dispatch` the
    snapshot there so `collect_dispatch` scopes survive the hop."""
    return tuple(getattr(_COLLECTORS, "stack", None) or ())


@contextmanager
def adopt_dispatch(collectors: Tuple["DispatchCounter", ...]):
    """Attribute this thread's launches to collectors captured on
    another thread (via `current_collectors`).  The pipelined megabatch
    executor dispatches from a worker thread while the caller's
    `collect_dispatch` scope lives on the main thread — without
    adoption those launches would vanish from the sweep's own counter.
    Collectors already active on this thread are not double-counted."""
    stack = getattr(_COLLECTORS, "stack", None)
    if stack is None:
        stack = _COLLECTORS.stack = []
    adopted = [c for c in collectors if c not in stack]
    stack.extend(adopted)
    try:
        yield
    finally:
        for c in adopted:
            stack.remove(c)


def _device_fingerprint() -> Tuple:
    """Identity of the visible device set — part of every jit-cache and
    program key, so a `pmap` built for N host devices is never reused
    after the device set changes."""
    return tuple((d.platform, d.id) for d in jax.devices())


def _record_launch(tag: str, key, args) -> None:
    shapes = tuple(
        (np.shape(leaf), str(getattr(leaf, "dtype", type(leaf))))
        for leaf in jax.tree_util.tree_leaves(args))
    fp = (tag, key, shapes, bool(jax.config.jax_enable_x64),
          _device_fingerprint())
    with _STATS_LOCK:
        _STATS["dispatches"] += 1
        fresh = fp not in _SEEN_PROGRAMS
        if fresh:
            _SEEN_PROGRAMS.add(fp)
            _STATS["compiles"] += 1
        for counter in getattr(_COLLECTORS, "stack", ()):
            counter.dispatches += 1
            if fresh:
                counter.compiles += 1


def dispatch_stats() -> Dict[str, int]:
    """Process-wide counters since the last reset: `dispatches` =
    device-program launches, `compiles` = launches whose (program,
    shapes, devices) fingerprint had not been seen before in this
    process.  For attributing launches to one executor, prefer
    `collect_dispatch` — these globals count every thread."""
    with _STATS_LOCK:
        return dict(_STATS)


def reset_dispatch_stats() -> None:
    """Zero the counters.  The seen-program set is *not* cleared — it
    mirrors the lifetime of jax's own executable caches, so a warm
    re-run correctly reports 0 compiles."""
    with _STATS_LOCK:
        _STATS["dispatches"] = 0
        _STATS["compiles"] = 0


# ---------------------------------------------------------------------------
# NIC: plane split + control update (port of netsim.cc.NicState)
# ---------------------------------------------------------------------------

def _split_mode(cfg: JxConfig, mode: str, nic: NicCarry,
                demand: jnp.ndarray) -> jnp.ndarray:
    """One plane-split branch — the select stage of the paper's NIC PLB
    (Fig. 4), dispatched through the kernels layer."""
    return _k_plane_split(nic.rate, nic.eligible, demand, mode=mode,
                          min_rate=MIN_RATE, use_pallas=cfg.use_pallas)


def _plane_split(cfg: JxConfig, nic: NicCarry, demand: jnp.ndarray,
                 stack: Optional[StackIdx] = None) -> jnp.ndarray:
    if stack is None:
        return _split_mode(cfg, _SPLIT_MODE[cfg.nic], nic, demand)
    return jax.lax.switch(
        stack.nic,
        [partial(_split_mode, cfg, m, nic, demand)
         for m in _BRANCH_ORDER])


def _probe_common(cfg: JxConfig, nic: NicCarry, probe_ok: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    miss = ~probe_ok
    # saturate at the timeout: `dead` is unchanged (>= comparison) and
    # the counter stays in int8 range under the compact carry
    bump = jnp.minimum(nic.probe_miss + 1, cfg.probe_timeout)
    probe_miss = jnp.where(miss, bump, 0).astype(nic.probe_miss.dtype)
    dead = probe_miss >= cfg.probe_timeout
    return probe_miss, dead


def _probe_basic(cfg: JxConfig, nic: NicCarry, rate: jnp.ndarray,
                 probe_ok: jnp.ndarray, slot: jnp.ndarray) -> NicCarry:
    probe_miss, dead = _probe_common(cfg, nic, probe_ok)
    was = nic.eligible
    eligible = ~dead
    just_back = eligible & ~was
    rate = jnp.where(just_back, 0.5, rate)
    rate = jnp.where(~eligible, MIN_RATE, rate)
    return NicCarry(rate=rate, alpha=nic.alpha, probe_miss=probe_miss,
                    eligible=eligible, pending_fail=nic.pending_fail)


def _probe_swlb(cfg: JxConfig, nic: NicCarry, rate: jnp.ndarray,
                probe_ok: jnp.ndarray, slot: jnp.ndarray) -> NicCarry:
    if cfg.sw_lb_delay_slots <= 0:
        return _probe_basic(cfg, nic, rate, probe_ok, slot)
    probe_miss, dead = _probe_common(cfg, nic, probe_ok)
    eligible, pending = nic.eligible, nic.pending_fail
    newly = dead & eligible & (pending == 0)
    pending = jnp.where(newly, slot + cfg.sw_lb_delay_slots, pending)
    fire = (pending > 0) & (slot >= pending)
    eligible = jnp.where(fire & dead, False, eligible)
    healed = ~dead & ~eligible
    eligible = jnp.where(healed, True, eligible)
    pending = jnp.where(~dead, 0, pending)
    rate = jnp.where(~eligible, MIN_RATE, rate)
    return NicCarry(rate=rate, alpha=nic.alpha, probe_miss=probe_miss,
                    eligible=eligible, pending_fail=pending)


def _upd_rate(cfg: JxConfig, mode: str, nic: NicCarry, qmean, esr):
    """RTT/ECN derivation + one fused CC rate branch, dispatched through
    `kernels.queue_ecn.nic_update` (Pallas on TPU, bit-exact jnp ref
    otherwise).  Returns `(rtt, ecn, rate, alpha)`."""
    return _k_nic_update(
        qmean, nic.rate, nic.alpha, esr, mode=mode,
        base_rtt_us=cfg.base_rtt_us, slot_us=cfg.slot_us,
        ecn_thresh=cfg.ecn_queue_thresh,
        target_rtt_us=cfg.target_rtt_us, min_rate=MIN_RATE, md=SPX_MD,
        ai=SPX_AI, rtt_gain=SPX_RTT_GAIN, dcqcn_ai=DCQCN_AI,
        alpha_g=DCQCN_ALPHA_G, use_pallas=cfg.use_pallas)


def _upd_dcqcn(cfg, nic, qmean, probe_ok, slot, esr):
    rtt, ecn, rate, alpha = _upd_rate(cfg, "dcqcn", nic, qmean, esr)
    return nic._replace(rate=rate, alpha=alpha), rtt, ecn


def _upd_agg(cfg, nic, qmean, probe_ok, slot, esr):
    """'global'/'esr': one aggregate CC context across planes.  ESR's
    extra multiplicative cut rides the kernel's `esr` operand — a ×1.0
    multiply for non-ESR flows, which is bit-exact."""
    rtt, ecn, rate, _ = _upd_rate(cfg, "agg", nic, qmean, esr)
    return _probe_basic(cfg, nic, rate, probe_ok, slot), rtt, ecn


def _upd_spx(cfg, nic, qmean, probe_ok, slot, esr):
    rtt, ecn, rate, _ = _upd_rate(cfg, "spx", nic, qmean, esr)
    return _probe_basic(cfg, nic, rate, probe_ok, slot), rtt, ecn


def _upd_swlb(cfg, nic, qmean, probe_ok, slot, esr):
    # swlb shares spx's per-plane AIMD law; only the probe path differs
    rtt, ecn, rate, _ = _upd_rate(cfg, "spx", nic, qmean, esr)
    return _probe_swlb(cfg, nic, rate, probe_ok, slot), rtt, ecn


def _nic_update(cfg: JxConfig, nic: NicCarry, qmean: jnp.ndarray,
                probe_ok: jnp.ndarray, slot: jnp.ndarray,
                stack: Optional[StackIdx] = None
                ) -> Tuple[NicCarry, jnp.ndarray, jnp.ndarray]:
    """NIC control update (pre-stall rates, as in `run_sim`), fused with
    the rtt/ecn derivation from the per-flow mean queue.  Returns the
    new carry plus rtt/ecn (for the queue-delay estimate and trace)."""
    F = qmean.shape[0]
    if stack is None:
        esr = jnp.full((F, 1), cfg.nic == "esr")
        if cfg.nic == "dcqcn":
            return _upd_dcqcn(cfg, nic, qmean, probe_ok, slot, esr)
        if cfg.nic in ("global", "esr"):
            return _upd_agg(cfg, nic, qmean, probe_ok, slot, esr)
        if cfg.nic == "swlb":
            return _upd_swlb(cfg, nic, qmean, probe_ok, slot, esr)
        return _upd_spx(cfg, nic, qmean, probe_ok, slot, esr)
    esr = jnp.broadcast_to(jnp.reshape(stack.is_esr, (1, 1)), (F, 1))
    return jax.lax.switch(stack.nic, [
        partial(_upd_spx, cfg, nic, qmean, probe_ok, slot, esr),
        partial(_upd_dcqcn, cfg, nic, qmean, probe_ok, slot, esr),
        partial(_upd_agg, cfg, nic, qmean, probe_ok, slot, esr),
        partial(_upd_swlb, cfg, nic, qmean, probe_ok, slot, esr),
    ])


# ---------------------------------------------------------------------------
# routing fractions (port of FluidFabric.pair_fractions / ecmp_fractions)
# ---------------------------------------------------------------------------

def _pair_fractions(cfg: JxConfig, q_up: jnp.ndarray, q_down: jnp.ndarray,
                    up: jnp.ndarray, down: jnp.ndarray,
                    remote_weights: Optional[jnp.ndarray]) -> jnp.ndarray:
    """(P, L_src, L_dst, S) spine split; 'war' folds in remote weights.
    Scoring + softmax run through `kernels.jsq_route.pair_fractions`."""
    cap = jnp.minimum(up[:, :, None, :],
                      jnp.swapaxes(down, 1, 2)[:, None, :, :])
    q = (q_up[:, :, None, :] +
         jnp.swapaxes(q_down, 1, 2)[:, None, :, :])
    w = cap
    if remote_weights is not None:
        w = w * jnp.swapaxes(remote_weights, 1, 2)[:, None, :, :]
    return _k_pair_fractions(q, cap, w, nbins=cfg.jsq_bins,
                             temperature=cfg.ar_temperature, qmax=8.0,
                             use_pallas=cfg.use_pallas)


def _bottleneck(cfg: JxConfig, up, down, load_up, load_down):
    f_up = _k_bottleneck(up, load_up, eps=_EPS,
                         use_pallas=cfg.use_pallas)
    f_down = _k_bottleneck(down, load_down, eps=_EPS,
                           use_pallas=cfg.use_pallas)
    return f_up, f_down


# ---------------------------------------------------------------------------
# one slot
# ---------------------------------------------------------------------------

class _AggPerms(NamedTuple):
    """Flow -> bucket aggregation plans.  XLA CPU scatters (and one-hot
    matmuls) are an order of magnitude slower than gathers, so every
    per-slot "sum flows into buckets" becomes: gather flows into a
    `(n_buckets, width)` layout (rows padded with an index that reads a
    zero row) and sum the width axis.  The permutations are static per
    run — ECMP's spine assignment is piecewise-constant, so it gets one
    plan per capacity segment.

    The ECMP plan (`ecmp_load`) stacks uplink and downlink buckets into
    one `(n_seg, P, _plan_rows(cfg), C)` matrix — stage-A up/down
    buckets, plus the two stage-B (pod–core) bucket families on
    fat_tree.  In float64 (parity mode) its
    width axis is summed strictly left-to-right (flow order): those sums
    feed the queue integrators, where a last-ulp tree-reduction
    difference vs NumPy's sequential `np.add.at` can walk a queue across
    an ECN threshold and fork the trajectory.  Float32 runs take the
    fast tree reduction instead — they drift from the f64 reference at
    ulp level regardless.  AR/WAR fractions are smooth in the loads, so
    their aggregations tolerate tree reduction at either precision."""
    src: jnp.ndarray        # (H, Cs)  flows by src host
    dst: jnp.ndarray        # (H, Cd)  flows by dst host
    pair: jnp.ndarray       # (L*L, Cp) flows by (src_leaf, dst_leaf)
    ecmp_load: jnp.ndarray  # (n_seg, P, L*S + S*L, Cu)


def _perm_matrix(keys: np.ndarray, n_buckets: int, width: int,
                 pad: int) -> np.ndarray:
    """(n_buckets, width) flow indices grouped by key, flow order
    preserved within a bucket, padded with `pad`."""
    perm = np.full((n_buckets, width), pad, np.int32)
    keys = np.asarray(keys)
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    counts = np.bincount(sk, minlength=n_buckets)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    ranks = np.arange(len(sk)) - starts[sk]
    perm[sk, ranks] = order
    return perm


def _seg_sum(vals: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """vals (F, P), perm (K, C) -> (K, P) bucket sums."""
    pad = jnp.concatenate(
        [vals, jnp.zeros((1, vals.shape[1]), vals.dtype)], 0)
    return pad[perm].sum(1)


def _host_sum(cfg: JxConfig, vals: jnp.ndarray, idx: jnp.ndarray,
              perm: jnp.ndarray) -> jnp.ndarray:
    """(F, P) per-flow values summed into (H, P) per-host buckets:
    gather-plan sum (dense) or a (host, plane)-keyed `segment_load`
    (sparse — the row-major flatten scatters in flow order, so XLA CPU
    f64 stays bit-equal to the NumPy engine's `np.add.at`)."""
    if cfg.agg_mode != "sparse":
        return _seg_sum(vals, perm)
    P = vals.shape[1]
    keys = idx[:, None] * P + jnp.arange(P)[None, :]
    return segment_load(vals, keys, cfg.n_hosts * P).reshape(
        cfg.n_hosts, P)


def _pair_rate_sum(cfg: JxConfig, fabric_rate: jnp.ndarray,
                   pair_idx: jnp.ndarray,
                   aggs: "_AggPerms") -> jnp.ndarray:
    """(P, L, L) offered rate summed by (src-leaf, dst-leaf) pair."""
    P, L = cfg.n_planes, cfg.n_leaves
    if cfg.agg_mode != "sparse":
        return _seg_sum(fabric_rate, aggs.pair).T.reshape(P, L, L)
    keys = jnp.arange(P)[None, :] * (L * L) + pair_idx[:, None]
    return segment_load(fabric_rate, keys, P * L * L).reshape(P, L, L)


def _route_pair(cfg: JxConfig, carry: SimCarry, fabric_rate: jnp.ndarray,
                up: jnp.ndarray, down: jnp.ndarray, upv: jnp.ndarray,
                downv: jnp.ndarray, aggs: _AggPerms,
                pair_idx: jnp.ndarray, use_war):
    """AR / weighted-AR: leaf-pair spine fractions.  `use_war` is a
    Python bool on the static path or a traced bool under switch — the
    traced form multiplies weights by exactly 1.0 for plain AR, which is
    bit-identical to not multiplying.  `upv`/`downv` are the routing-
    *visible* capacities (the reaction-lagged view; the physical arrays
    themselves when reaction is off): fractions and remote weights steer
    against them, while loads/bottlenecks/queues stay physical — exactly
    `FluidFabric`'s `route_topo` split."""
    P, L = cfg.n_planes, cfg.n_leaves
    rw_arr = downv / jnp.maximum(downv.max(axis=1, keepdims=True), 1e-9)
    if isinstance(use_war, bool):
        rw = rw_arr if use_war else None
    else:
        rw = jnp.where(use_war, rw_arr, jnp.ones_like(downv))
    pair = _pair_fractions(cfg, carry.q_up, carry.q_down, upv, downv, rw)
    rate_pair = _pair_rate_sum(cfg, fabric_rate, pair_idx, aggs)
    load_up = jnp.einsum("plm,plms->pls", rate_pair, pair)
    load_down = jnp.einsum("plm,plms->psm", rate_pair, pair)
    f_up, f_down = _bottleneck(cfg, up, down, load_up, load_down)
    scale_pair = jnp.minimum(
        f_up[:, :, None, :],
        f_down.transpose(0, 2, 1)[:, None, :, :])         # (P, L, L, S)
    path_scale = (pair * scale_pair).sum(-1).reshape(P, L * L)
    through = fabric_rate * path_scale[:, pair_idx].T
    q_pair = (carry.q_up[:, :, None, :] +
              carry.q_down.transpose(0, 2, 1)[:, None, :, :])
    qmean = (pair * q_pair).sum(-1).reshape(P, L * L)[:, pair_idx].T
    if not cfg.react:
        return load_up, load_down, through, qmean
    # blackholed bytes: offered rate steered (by the lagged view) onto
    # physically dead paths — pair-aggregated, so no (F, P, J) tensor
    cap = jnp.minimum(up[:, :, None, :],
                      jnp.swapaxes(down, 1, 2)[:, None, :, :])
    bh = (rate_pair[..., None] * pair * (cap <= _EPS)).sum()
    return load_up, load_down, through, qmean, bh


def _route_ecmp(cfg: JxConfig, carry: SimCarry, fabric_rate: jnp.ndarray,
                up: jnp.ndarray, down: jnp.ndarray, fb: FlowBatch,
                assign_segments: jnp.ndarray, load_fn: Callable,
                seg: jnp.ndarray):
    """ECMP: one-hot spine choice from the precomputed assignment
    segment, loads via padded bucket sums.  `load_fn(seg)` yields the
    (P, LS+SL, C) permutation plan for the current capacity segment —
    a slice of this element's `_AggPerms.ecmp_load` on the per-group
    path, a row of the batch-deduplicated plan table on the megabatch
    path."""
    P, L, S = cfg.n_planes, cfg.n_leaves, cfg.n_spines
    assign = assign_segments[seg]                         # (F, P)
    p_iota = jnp.arange(P)[None, :].repeat(fabric_rate.shape[0], 0)
    if cfg.agg_mode == "sparse":
        pk = jnp.arange(P)[None, :]
        k_up = pk * (L * S) + fb.src_leaf[:, None] * S + assign
        k_dn = pk * (S * L) + assign * L + fb.dst_leaf[:, None]
        load_up = segment_load(fabric_rate, k_up,
                               P * L * S).reshape(P, L, S)
        load_down = segment_load(fabric_rate, k_dn,
                                 P * S * L).reshape(P, S, L)
        f_up, f_down = _bottleneck(cfg, up, down, load_up, load_down)
    else:
        padT = jnp.concatenate(
            [fabric_rate, jnp.zeros((1, P), fabric_rate.dtype)], 0).T
        pidx = jnp.arange(P)[:, None, None]
        g = padT[pidx, load_fn(seg)]                      # (P, LS+SL, C)
        cap = jnp.concatenate(
            [up.reshape(P, L * S), down.reshape(P, S * L)], 1)
        loads, fracs = bucket_load_bottleneck(
            g, cap, eps=_EPS, use_pallas=cfg.use_pallas)
        load_up = loads[:, :L * S].reshape(P, L, S)
        load_down = loads[:, L * S:].reshape(P, S, L)
        f_up = fracs[:, :L * S].reshape(P, L, S)
        f_down = fracs[:, L * S:].reshape(P, S, L)
    scale_f = jnp.minimum(
        f_up[p_iota, fb.src_leaf[:, None], assign],
        f_down[p_iota, assign, fb.dst_leaf[:, None]])
    through = fabric_rate * scale_f
    qmean = (carry.q_up[p_iota, fb.src_leaf[:, None], assign] +
             carry.q_down[p_iota, assign, fb.dst_leaf[:, None]])
    if not cfg.react:
        return load_up, load_down, through, qmean
    # blackholed bytes: the one-hot assignment (already steered by the
    # lagged view on the host) landing on a physically dead path
    capF = jnp.minimum(up[p_iota, fb.src_leaf[:, None], assign],
                       down[p_iota, assign, fb.dst_leaf[:, None]])
    bh = (fabric_rate * (capF <= _EPS)).sum()
    return load_up, load_down, through, qmean, bh


def _ft_maps(cfg: JxConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Static fat-tree index maps: path→serving-agg and leaf→pod."""
    aj = jnp.arange(cfg.n_paths) // cfg.cores_per_agg
    pol = jnp.arange(cfg.n_leaves) // cfg.leaves_per_pod
    return aj, pol


def _route_pair_ft(cfg: JxConfig, carry: SimCarry,
                   fabric_rate: jnp.ndarray, up: jnp.ndarray,
                   down: jnp.ndarray, up2: jnp.ndarray,
                   down2: jnp.ndarray, upv: jnp.ndarray,
                   downv: jnp.ndarray, up2v: jnp.ndarray,
                   down2v: jnp.ndarray, aggs: _AggPerms,
                   pair_idx: jnp.ndarray, use_war):
    """Fat-tree AR / weighted-AR: the pair split runs over the path
    (= core) axis; capacity/queue per path compose stage A (leaf↔agg,
    via the path→agg map) with stage B (pod↔core) for cross-pod pairs.
    Mirrors `FluidFabric._pair_fractions_fat_tree` + `_step_fat_tree`
    operation for operation; the `*v` operands are the routing-visible
    (reaction-lagged) capacities — JSQ scores, weights, and remote
    weights come from them while delivery stays physical."""
    P, L, A = cfg.n_planes, cfg.n_leaves, cfg.n_aggs
    J, cpa = cfg.n_paths, cfg.cores_per_agg
    pods, lpp = cfg.n_pods, cfg.leaves_per_pod
    aj, pol = _ft_maps(cfg)
    cross = (pol[:, None] != pol[None, :])[None, :, :, None]
    upJ = upv[:, :, aj]                                   # (P, L, J)
    dnJ = downv[:, aj, :]                                 # (P, J, L)
    capA = jnp.minimum(upJ[:, :, None, :],
                       dnJ.transpose(0, 2, 1)[:, None, :, :])
    up2L = up2v[:, pol, :]                                # (P, L, J)
    dn2L = down2v[:, pol, :]
    capB = jnp.minimum(up2L[:, :, None, :], dn2L[:, None, :, :])
    cap = jnp.where(cross, jnp.minimum(capA, capB), capA)
    qA = (carry.q_up[:, :, aj][:, :, None, :] +
          carry.q_down[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
    qB = (carry.q2_up[:, pol, :][:, :, None, :] +
          carry.q2_down[:, pol, :][:, None, :, :])
    q = qA + jnp.where(cross, qB, 0.0)
    eff = jnp.minimum(dnJ, dn2L.transpose(0, 2, 1))       # (P, J, L)
    rw_arr = eff / jnp.maximum(eff.max(axis=1, keepdims=True), 1e-9)
    if isinstance(use_war, bool):
        rw = rw_arr if use_war else None
    else:
        rw = jnp.where(use_war, rw_arr, jnp.ones_like(rw_arr))
    w = cap if rw is None \
        else cap * rw.transpose(0, 2, 1)[:, None, :, :]
    pair = _k_pair_fractions(q, cap, w, nbins=cfg.jsq_bins,
                             temperature=cfg.ar_temperature, qmax=8.0,
                             use_pallas=cfg.use_pallas)
    rate_pair = _pair_rate_sum(cfg, fabric_rate, pair_idx, aggs)
    loadJ_up = jnp.einsum("plm,plmj->plj", rate_pair, pair)
    loadJ_dn = jnp.einsum("plm,plmj->pmj", rate_pair, pair)
    loadA_up = loadJ_up.reshape(P, L, A, cpa).sum(-1)     # (P, L, A)
    loadA_dn = loadJ_dn.reshape(P, L, A, cpa).sum(-1) \
        .transpose(0, 2, 1)                               # (P, A, L)
    ratex = rate_pair * (pol[:, None] != pol[None, :])[None]
    loadB_up = jnp.einsum("plm,plmj->plj", ratex, pair) \
        .reshape(P, pods, lpp, J).sum(2)                  # (P, pods, J)
    loadB_dn = jnp.einsum("plm,plmj->pmj", ratex, pair) \
        .reshape(P, pods, lpp, J).sum(2)
    fA_up, fA_dn = _bottleneck(cfg, up, down, loadA_up, loadA_dn)
    fB_up, fB_dn = _bottleneck(cfg, up2, down2, loadB_up, loadB_dn)
    sA = jnp.minimum(fA_up[:, :, aj][:, :, None, :],
                     fA_dn[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
    sB = jnp.minimum(fB_up[:, pol, :][:, :, None, :],
                     fB_dn[:, pol, :][:, None, :, :])
    scale_pair = jnp.where(cross, jnp.minimum(sA, sB), sA)
    path_scale = (pair * scale_pair).sum(-1).reshape(P, L * L)
    through = fabric_rate * path_scale[:, pair_idx].T
    qmean = (pair * q).sum(-1).reshape(P, L * L)[:, pair_idx].T
    if not cfg.react:
        return loadA_up, loadA_dn, loadB_up, loadB_dn, through, qmean
    # physical per-pair path capacity (the visible `cap` above steered
    # the split; a dead *physical* path blackholes what landed on it)
    capA_p = jnp.minimum(
        up[:, :, aj][:, :, None, :],
        down[:, aj, :].transpose(0, 2, 1)[:, None, :, :])
    capB_p = jnp.minimum(up2[:, pol, :][:, :, None, :],
                         down2[:, pol, :][:, None, :, :])
    cap_p = jnp.where(cross, jnp.minimum(capA_p, capB_p), capA_p)
    bh = (rate_pair[..., None] * pair * (cap_p <= _EPS)).sum()
    return loadA_up, loadA_dn, loadB_up, loadB_dn, through, qmean, bh


def _route_ecmp_ft(cfg: JxConfig, carry: SimCarry,
                   fabric_rate: jnp.ndarray, up: jnp.ndarray,
                   down: jnp.ndarray, up2: jnp.ndarray,
                   down2: jnp.ndarray, fb: FlowBatch,
                   assign_segments: jnp.ndarray, load_fn: Callable,
                   seg: jnp.ndarray):
    """Fat-tree ECMP: the hash picks a path (= core) index; the serving
    agg follows from the canonical wiring.  Load plans stack stage-A
    up/down buckets and stage-B up/down buckets (cross-pod flows only)
    into one permutation matrix — see `_ecmp_load_plan`."""
    P, L, A = cfg.n_planes, cfg.n_leaves, cfg.n_aggs
    J, cpa = cfg.n_paths, cfg.cores_per_agg
    pods, lpp = cfg.n_pods, cfg.leaves_per_pod
    assign = assign_segments[seg]                         # (F, P)
    a_of = assign // cpa
    pod_s = fb.src_leaf // lpp
    pod_d = fb.dst_leaf // lpp
    cross = (pod_s != pod_d)[:, None]                     # (F, 1)
    p_iota = jnp.arange(P)[None, :].repeat(fabric_rate.shape[0], 0)
    if cfg.agg_mode == "sparse":
        pk = jnp.arange(P)[None, :]
        kAu = pk * (L * A) + fb.src_leaf[:, None] * A + a_of
        kAd = pk * (A * L) + a_of * L + fb.dst_leaf[:, None]
        kBu = pk * (pods * J) + pod_s[:, None] * J + assign
        kBd = pk * (pods * J) + pod_d[:, None] * J + assign
        # intra-pod flows add exact 0.0 to the stage-B buckets — the
        # NumPy engine does the same, so this is bit-equivalent to the
        # dense plan's masked exclusion
        vB = jnp.where(cross, fabric_rate, 0.0)
        loadA_up = segment_load(fabric_rate, kAu,
                                P * L * A).reshape(P, L, A)
        loadA_dn = segment_load(fabric_rate, kAd,
                                P * A * L).reshape(P, A, L)
        loadB_up = segment_load(vB, kBu,
                                P * pods * J).reshape(P, pods, J)
        loadB_dn = segment_load(vB, kBd,
                                P * pods * J).reshape(P, pods, J)
        fA_up, fA_dn = _bottleneck(cfg, up, down, loadA_up, loadA_dn)
        fB_up, fB_dn = _bottleneck(cfg, up2, down2, loadB_up, loadB_dn)
    else:
        padT = jnp.concatenate(
            [fabric_rate, jnp.zeros((1, P), fabric_rate.dtype)], 0).T
        pidx = jnp.arange(P)[:, None, None]
        g = padT[pidx, load_fn(seg)]        # (P, LA+AL+2*pods*J, C)
        o1, o2 = L * A, L * A + A * L
        o3 = o2 + pods * J
        cap = jnp.concatenate(
            [up.reshape(P, o1), down.reshape(P, o2 - o1),
             up2.reshape(P, pods * J), down2.reshape(P, pods * J)], 1)
        loads, fracs = bucket_load_bottleneck(
            g, cap, eps=_EPS, use_pallas=cfg.use_pallas)
        loadA_up = loads[:, :o1].reshape(P, L, A)
        loadA_dn = loads[:, o1:o2].reshape(P, A, L)
        loadB_up = loads[:, o2:o3].reshape(P, pods, J)
        loadB_dn = loads[:, o3:].reshape(P, pods, J)
        fA_up = fracs[:, :o1].reshape(P, L, A)
        fA_dn = fracs[:, o1:o2].reshape(P, A, L)
        fB_up = fracs[:, o2:o3].reshape(P, pods, J)
        fB_dn = fracs[:, o3:].reshape(P, pods, J)
    sA = jnp.minimum(fA_up[p_iota, fb.src_leaf[:, None], a_of],
                     fA_dn[p_iota, a_of, fb.dst_leaf[:, None]])
    sB = jnp.minimum(fB_up[p_iota, pod_s[:, None], assign],
                     fB_dn[p_iota, pod_d[:, None], assign])
    scale_f = jnp.where(cross, jnp.minimum(sA, sB), sA)
    through = fabric_rate * scale_f
    qA = (carry.q_up[p_iota, fb.src_leaf[:, None], a_of] +
          carry.q_down[p_iota, a_of, fb.dst_leaf[:, None]])
    qB = (carry.q2_up[p_iota, pod_s[:, None], assign] +
          carry.q2_down[p_iota, pod_d[:, None], assign])
    qmean = qA + jnp.where(cross, qB, 0.0)
    if not cfg.react:
        return loadA_up, loadA_dn, loadB_up, loadB_dn, through, qmean
    capAf = jnp.minimum(up[p_iota, fb.src_leaf[:, None], a_of],
                        down[p_iota, a_of, fb.dst_leaf[:, None]])
    capBf = jnp.minimum(up2[p_iota, pod_s[:, None], assign],
                        down2[p_iota, pod_d[:, None], assign])
    capF = jnp.where(cross, jnp.minimum(capAf, capBf), capAf)
    bh = (fabric_rate * (capF <= _EPS)).sum()
    return loadA_up, loadA_dn, loadB_up, loadB_dn, through, qmean, bh


def _slot_step(cfg: JxConfig, fb: FlowBatch, pair_idx: jnp.ndarray,
               aggs: _AggPerms, assign_segments: jnp.ndarray,
               seg_up: jnp.ndarray, seg_down: jnp.ndarray,
               seg_acc: jnp.ndarray, seg_up2: jnp.ndarray,
               seg_down2: jnp.ndarray, seg_dem: jnp.ndarray,
               seg_vup: jnp.ndarray, seg_vdown: jnp.ndarray,
               seg_vup2: jnp.ndarray, seg_vdown2: jnp.ndarray,
               stack: Optional[StackIdx],
               load_fn: Callable, carry: SimCarry, xs):
    # timelines are piecewise-constant, so the scan carries only the
    # (n_seg, ...) boundary snapshots and gathers the current segment
    t, seg = xs
    up = seg_up[seg] * cfg.uplink_cap                     # (P, L, S|A)
    down = seg_down[seg] * cfg.uplink_cap                 # (P, S|A, L)
    acc = (seg_acc[seg] * cfg.access_cap).T               # (H, P)
    up2 = seg_up2[seg] * cfg.core_cap                     # (P, pods, C)
    down2 = seg_down2[seg] * cfg.core_cap
    if cfg.react:
        # routing-visible (detection-lagged) fabric view; access never
        # lags (NIC probes see host faults directly)
        upv = seg_vup[seg] * cfg.uplink_cap
        downv = seg_vdown[seg] * cfg.uplink_cap
        up2v = seg_vup2[seg] * cfg.core_cap
        down2v = seg_vdown2[seg] * cfg.core_cap
    else:
        # dead operands: routing sees physical truth, the traced
        # program is identical to the pre-reaction engine
        upv, downv, up2v, down2v = up, down, up2, down2

    demand = jnp.where(carry.done | (t < fb.start_slot), 0.0, fb.demand)
    if cfg.n_phases:
        # schedule workloads: piecewise-constant per-phase demand
        # multipliers, gathered per segment exactly like the capacity
        # snapshots above (lane 0 is the always-1.0 lane)
        demand = demand * seg_dem[seg][fb.phase]
    offered = _plane_split(cfg, carry.nic, demand, stack)  # (F, P)
    fabric_rate = jnp.where(fb.same_leaf[:, None], 0.0, offered)

    # ---- link loads + per-flow fabric throughput/queue, without any
    # (F, P, J) load intermediate: AR/WAR fractions are leaf-pair
    # quantities, so flows aggregate to (P, L, L) before touching the
    # path axis; ECMP's one-hot path choice reduces to (F, P) gathers +
    # padded bucket sums.  The topology kind is static, so the branch
    # list holds that kind's pair/ecmp implementations (fat-tree ones
    # also return stage-B loads); under traced dispatch `lax.switch`
    # evaluates both branches for the whole batch and selects per
    # element.
    use_war = cfg.routing == "war" if stack is None else stack.is_war
    if cfg.kind == "fat_tree":
        branches = [
            partial(_route_pair_ft, cfg, carry, fabric_rate, up, down,
                    up2, down2, upv, downv, up2v, down2v, aggs,
                    pair_idx, use_war),
            partial(_route_ecmp_ft, cfg, carry, fabric_rate, up, down,
                    up2, down2, fb, assign_segments, load_fn, seg)]
    else:
        branches = [
            partial(_route_pair, cfg, carry, fabric_rate, up, down,
                    upv, downv, aggs, pair_idx, use_war),
            partial(_route_ecmp, cfg, carry, fabric_rate, up, down,
                    fb, assign_segments, load_fn, seg)]
    if stack is None:
        routed = branches[1 if cfg.routing == "ecmp" else 0]()
    elif isinstance(stack.route, int):
        # lane-sorted megabatch: the dispatcher grouped elements by
        # route, so the per-element index is concrete within the
        # lane and only that branch is traced (no switch tax)
        routed = branches[stack.route]()
    else:
        routed = jax.lax.switch(stack.route, branches)
    bh = routed[-1] if cfg.react else None
    routed = routed[:-1] if cfg.react else routed
    if cfg.kind == "fat_tree":
        load_up, load_down, loadB_up, loadB_dn, through, qmean = routed
    else:
        load_up, load_down, through, qmean = routed

    load_acc_tx = _host_sum(cfg, offered, fb.src, aggs.src)  # (H, P)
    load_acc_rx = _host_sum(cfg, offered, fb.dst, aggs.dst)

    # ---- bottleneck scaling (access; fabric scaling lives in the
    # routing branches) ----
    f_acc_tx = _k_bottleneck(acc, load_acc_tx, eps=_EPS,
                             use_pallas=cfg.use_pallas)
    f_acc_rx = _k_bottleneck(acc, load_acc_rx, eps=_EPS,
                             use_pallas=cfg.use_pallas)
    up_alive_tx = acc[fb.src] > _EPS                      # (F, P)
    up_alive_rx = acc[fb.dst] > _EPS

    local = jnp.where(fb.same_leaf[:, None], offered, 0.0)
    acc_scale = jnp.minimum(f_acc_tx[fb.src], f_acc_rx[fb.dst])
    achieved_pp = (through + local) * acc_scale
    achieved_pp = jnp.where(up_alive_tx & up_alive_rx, achieved_pp, 0.0)
    qmean = jnp.where(fb.same_leaf[:, None], 0.0, qmean)

    # ---- queue evolution (stage B only exists on fat_tree; the kind
    # is static, so leaf_spine programs carry the placeholders through
    # untouched) ----
    q_up, util = _k_queue_update(carry.q_up, load_up, up,
                                 q_cap=cfg.q_cap, eps=_EPS,
                                 use_pallas=cfg.use_pallas)
    q_down, _ = _k_queue_update(carry.q_down, load_down, down,
                                q_cap=cfg.q_cap, eps=_EPS,
                                use_pallas=cfg.use_pallas)
    if cfg.kind == "fat_tree":
        q2_up, _ = _k_queue_update(carry.q2_up, loadB_up, up2,
                                   q_cap=cfg.q_cap, eps=_EPS,
                                   use_pallas=cfg.use_pallas)
        q2_down, _ = _k_queue_update(carry.q2_down, loadB_dn, down2,
                                     q_cap=cfg.q_cap, eps=_EPS,
                                     use_pallas=cfg.use_pallas)
    else:
        q2_up, q2_down = carry.q2_up, carry.q2_down

    # ---- NIC control update (pre-stall rates, as in run_sim; rtt/ecn
    # derive from qmean inside the fused kernel) ----
    probe_ok = (acc[fb.src] > _EPS) & (acc[fb.dst] > _EPS)
    nic, rtt, ecn = _nic_update(cfg, carry.nic, qmean, probe_ok, t,
                                stack)

    # ---- packet-loss stall + completion ----
    stalled = ((offered > 1e-9) & (achieved_pp <= 1e-9)).any(1)
    achieved = jnp.where(stalled, 0.0, achieved_pp.sum(1))

    remaining = carry.remaining - achieved
    newly = (~carry.done) & (remaining <= 0)
    w = jnp.maximum(offered, _EPS)
    qdelay = (((rtt * w).sum(1) / w.sum(1)) - cfg.base_rtt_us) \
        / cfg.slot_us
    completion = jnp.where(
        newly, t + jnp.ceil(qdelay).astype(carry.completion.dtype),
        carry.completion)
    done = carry.done | newly

    # ---- post-warmup accumulation (replaces dense (T, F) recording) ----
    r = cfg.record_every
    n_rec = (cfg.slots + r - 1) // r
    w0 = int(n_rec * cfg.warmup_frac)
    rec = (t % r) == 0
    if n_rec > w0:
        counted = rec & ((t // r) >= w0)
    else:
        counted = rec
    goodput_sum = carry.goodput_sum + jnp.where(counted, achieved, 0.0)

    new_carry = SimCarry(
        q_up=q_up, q_down=q_down, q2_up=q2_up, q2_down=q2_down,
        nic=nic, remaining=remaining, done=done, completion=completion,
        goodput_sum=goodput_sum, util_up=util)
    extras = (bh,) if cfg.react else ()
    if not cfg.trace.enabled:
        if not cfg.react:
            return new_carry, achieved.sum()
        return new_carry, (achieved.sum(),) + extras
    # Trace outputs ride the scan's stacked ys (never the donated
    # carry); decimation happens in `_simulate`.  Padded flows offer
    # zero, so their host_bw contribution is exactly zero and the
    # megabatch finalizer only strips the flow-axis fields.
    sig = {
        "host_bw": lambda: _host_sum(
            cfg, jnp.where(stalled[:, None], 0.0, achieved_pp), fb.src,
            aggs.src),
        "util": lambda: util,
        "queue": lambda: q_up,
        "ecn": lambda: ecn,
        "eligible": lambda: nic.eligible,
    }
    return new_carry, ((achieved.sum(),) + extras +
                       tuple(sig[f]() for f in cfg.trace.active_fields()))


def _simulate(cfg: JxConfig, fb: FlowBatch, seg_up, seg_down, seg_acc,
              seg_up2, seg_down2, seg_dem, seg_vup, seg_vdown, seg_vup2,
              seg_vdown2, assign_segments, aggs, seg_id,
              stack=None, carry0=None, ecmp_table=None, uid=None):
    if cfg.flow_chunk:
        # streaming path: the flow axis runs through the slot step in
        # fixed-size chunks (sparse aggregation only — `aggs`/the ECMP
        # plan table are never gathered there)
        from . import chunked
        return chunked.simulate_chunked(
            cfg, fb, seg_up, seg_down, seg_acc, seg_up2, seg_down2,
            seg_dem, seg_vup, seg_vdown, seg_vup2, seg_vdown2,
            assign_segments, seg_id, stack=stack, carry0=carry0)
    if carry0 is None:
        carry0 = init_carry(fb, cfg)
    if ecmp_table is None:
        def load_fn(seg):
            return aggs.ecmp_load[seg]
    else:
        # batch-deduplicated plan table: `uid` picks this element's row
        def load_fn(seg):
            return ecmp_table[uid, seg]
    pair_idx = fb.src_leaf * cfg.n_leaves + fb.dst_leaf
    xs = (jnp.arange(cfg.slots), seg_id)
    step = partial(_slot_step, cfg, fb, pair_idx, aggs, assign_segments,
                   jnp.asarray(seg_up), jnp.asarray(seg_down),
                   jnp.asarray(seg_acc), jnp.asarray(seg_up2),
                   jnp.asarray(seg_down2), jnp.asarray(seg_dem),
                   jnp.asarray(seg_vup), jnp.asarray(seg_vdown),
                   jnp.asarray(seg_vup2), jnp.asarray(seg_vdown2),
                   stack, load_fn)
    carry, ys = jax.lax.scan(step, carry0, xs)
    # ys layout: raw scalar (no trace, no react) | tuple of
    # (total, [blackhole], *trace-fields) — blackhole stays full-rate
    # (T,), trace fields decimate by trace.every
    bh = ()
    if cfg.trace.enabled or cfg.react:
        totals = ys[0]
        rest = ys[1:]
        if cfg.react:
            bh = (rest[0],)
            rest = rest[1:]
        tail = tuple(y[::cfg.trace.every] for y in rest)
    else:
        totals, tail = ys, ()
    r = cfg.record_every
    n_rec = (cfg.slots + r - 1) // r
    w0 = int(n_rec * cfg.warmup_frac)
    frames = (n_rec - w0) if n_rec > w0 else n_rec
    return (carry.goodput_sum / frames, carry.completion, totals,
            carry.util_up) + bh + tail


def _simulate_mb(cfg: JxConfig, stack: StackIdx, carry0: SimCarry,
                 fb: FlowBatch, seg_up, seg_down, seg_acc, seg_up2,
                 seg_down2, seg_dem, seg_vup, seg_vdown, seg_vup2,
                 seg_vdown2, assign_segments, aggs, uid, seg_id,
                 ecmp_table):
    """Megabatch element: traced branch dispatch + donated carry.  Every
    argument between `stack` and `seg_id` (inclusive) is vmapped;
    `ecmp_table` is batch-constant (the deduplicated ECMP plan table)."""
    return _simulate(cfg, fb, seg_up, seg_down, seg_acc, seg_up2,
                     seg_down2, seg_dem, seg_vup, seg_vdown, seg_vup2,
                     seg_vdown2, assign_segments, aggs, seg_id,
                     stack=stack, carry0=carry0, ecmp_table=ecmp_table,
                     uid=uid)


def _jitted(cfg: JxConfig, batched: bool, n_shards: int = 1):
    """Compiled per-group entry point, memoized on (cfg, batch form,
    shard count, *and the visible device set*) — a `pmap` callable built
    for N devices must not be silently reused if the device set changes
    mid-process (regression-tested)."""
    key = ("group", cfg, batched, n_shards, _device_fingerprint())
    fn = _JIT_CACHE.get(key)
    if fn is not None:
        return fn
    fn = partial(_simulate, cfg)
    if not batched:
        fn = jax.jit(fn)
    else:
        fn = jax.vmap(fn, in_axes=(0,) * 13 + (None,))
        if n_shards == 1:
            fn = jax.jit(fn)
        else:
            # shard the batch axis over host devices: XLA CPU serializes
            # separate executions even across devices, but one pmap
            # launch runs its per-device shards on parallel threads —
            # the single-process equivalent of the NumPy backend's
            # process pool
            fn = jax.pmap(fn, in_axes=(0,) * 13 + (None,))
    _JIT_CACHE[key] = fn
    return fn


def lane_mesh(n_shards: int) -> "jax.sharding.Mesh":
    """1-D device mesh over the megabatch lane (batch) axis.  Today the
    axis spans local host devices; under `jax.distributed` the same
    `Mesh(("lane",))` layout extends to multi-process global devices —
    `_jitted_mb`'s NamedSharding code path is written against the mesh,
    not the device list, so only this constructor changes."""
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:n_shards]), ("lane",))


def _jitted_mb(cfg: JxConfig, n_shards: int = 1,
               lanes: Optional[Tuple[Tuple[int, int], ...]] = None):
    """Compiled megabatch entry point: one `jit(vmap)` covering every
    (routing, nic) via traced `StackIdx`, with the initial scan carry
    donated — the step rewrites it wholesale, so XLA reuses its buffers
    instead of allocating a second batch.  With `n_shards > 1` the
    batch axis is `jax.sharding`-partitioned over a 1-D "lane" device
    mesh (`lane_mesh`): operands arrive flat `(B, ...)`, `in_shardings`
    places them, and the per-shard computation stays device-local via a
    shard-axis `vmap` — the modern replacement for the old device-major
    `pmap` layout, structured to extend to `jax.distributed` meshes.

    `lanes` is the dispatcher's static per-shard layout: a tuple of
    `(route_index, n_elements)` runs.  Elements are lane-sorted by the
    dispatcher, so within a run the route index is concrete and only
    that routing branch is traced; `None` falls back to the fully
    per-element `lax.switch` (every branch evaluated batch-wide,
    selected per element) — semantically identical, slower."""
    key = ("mega", cfg, n_shards, lanes, _device_fingerprint())
    fn = _JIT_CACHE.get(key)
    if fn is not None:
        return fn
    if lanes is None:
        body = jax.vmap(partial(_simulate_mb, cfg),
                        in_axes=(0,) * 17 + (None,))
    else:
        stack_axes = StackIdx(route=None, is_war=0, nic=0, is_esr=0)
        v = jax.vmap(partial(_simulate_mb, cfg),
                     in_axes=(stack_axes,) + (0,) * 16 + (None,))
        tm = jax.tree_util.tree_map

        def body(stack, carry0, fb, up, down, acc, up2, down2, dem,
                 vup, vdown, vup2, vdown2, assign, aggs, uid, seg_id,
                 table):
            outs, off = [], 0
            for route, n in lanes:
                def cut(x, off=off, n=n):
                    return jax.lax.slice_in_dim(x, off, off + n, axis=0)
                st = tm(cut, stack)._replace(route=route)
                outs.append(v(st, tm(cut, carry0), tm(cut, fb), cut(up),
                              cut(down), cut(acc), cut(up2), cut(down2),
                              cut(dem), cut(vup), cut(vdown), cut(vup2),
                              cut(vdown2), cut(assign), tm(cut, aggs),
                              cut(uid), cut(seg_id), table))
                off += n
            return tuple(jnp.concatenate(parts, 0)
                         for parts in zip(*outs))

    if n_shards == 1:
        fn = jax.jit(body, donate_argnums=(1,))
    else:
        from jax.sharding import NamedSharding, PartitionSpec

        mesh = lane_mesh(n_shards)
        lane = NamedSharding(mesh, PartitionSpec("lane"))
        repl = NamedSharding(mesh, PartitionSpec())
        tm = jax.tree_util.tree_map

        def sharded(*args):
            # flat (B, ...) operands -> (shards, per, ...) so the lanes
            # body (whose run lengths are per-shard) vmaps over the
            # shard axis with device-local data; outputs flatten back.
            # The reshape splits the already-lane-sharded leading axis
            # evenly, so no resharding happens at either end.
            mapped, table = args[:-1], args[-1]
            per = np.shape(jax.tree_util.tree_leaves(
                mapped[0])[0])[0] // n_shards

            def split(x):
                return jnp.reshape(
                    x, (n_shards, per) + tuple(x.shape[1:]))

            out = jax.vmap(body, in_axes=(0,) * 17 + (None,))(
                *tm(split, mapped), table)
            return tm(lambda x: jnp.reshape(
                x, (n_shards * per,) + tuple(x.shape[2:])), out)

        fn = jax.jit(
            sharded,
            in_shardings=(lane,) * 17 + (repl,),
            out_shardings=lane,
            donate_argnums=(1,))
    _JIT_CACHE[key] = fn
    return fn


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

_F32_WARNED: set = set()
_F32_OVERFLOWS: List[Dict] = []


def strict_f32() -> bool:
    """`REPRO_JX_STRICT_F32=1` turns the float32 bytes_total overflow
    warning into a hard error."""
    return bool(_env_flag("REPRO_JX_STRICT_F32"))


def f32_overflow_log() -> Tuple[Dict, ...]:
    """Every float32 bytes_total overflow condition seen this process,
    in detection order — `{"spec": name, "max_bytes": float}` each.
    Executors slice this by length to attach the overflows of one run
    to its flight record."""
    with _STATS_LOCK:
        return tuple(dict(d) for d in _F32_OVERFLOWS)


def _warn_f32_bytes(name: str, fa: FlowArrays, stacklevel: int = 3
                    ) -> None:
    if jax.config.jax_enable_x64:
        return
    finite = fa.bytes_total[np.isfinite(fa.bytes_total)]
    if not (finite.size and finite.max() > 2 ** 24):
        return
    msg = (f"{name}: bytes_total up to {finite.max():.3g} "
           "exceeds float32 integer resolution (2^24); remaining-"
           "bytes tracking will stall and transfers may never "
           "complete — enable x64 (JAX_ENABLE_X64=1) or rescale "
           "bytes_total")
    with _STATS_LOCK:
        _F32_OVERFLOWS.append(
            {"spec": name, "max_bytes": float(finite.max())})
        first = name not in _F32_WARNED
        _F32_WARNED.add(name)
    if strict_f32():
        raise ValueError(msg)
    if first:
        # stdlib warnings dedup by (message, category, module, lineno) —
        # i.e. by *call site* — so a second spec tripping the same
        # condition would be silently swallowed under the default
        # filter.  Dedup per spec name ourselves and always register
        # the condition in `f32_overflow_log` above.
        import warnings
        warnings.warn(msg, stacklevel=stacklevel)


def _prepared(compiled
              ) -> Tuple[JxConfig, FlowArrays, FaultTimeline,
                         Optional[np.ndarray],
                         Optional[FaultTimeline]]:
    """Returns `(cfg, flow arrays, physical timeline, phase mult,
    visible timeline)` — the visible timeline is the reaction-lagged
    view (None when reaction is off, or the physical timeline itself
    when the reaction's total lag is zero)."""
    from repro.scenarios.spec import reaction_lag
    spec = compiled.spec
    cfg = JxConfig.from_sim(compiled.cfg, spec.topo)
    fa = FlowArrays.build(compiled.flows, compiled.topo)
    _warn_f32_bytes(spec.name, fa, stacklevel=4)
    pm = getattr(compiled, "phase_mult", None)
    if pm is not None:
        cfg = replace(cfg, n_phases=int(pm.shape[1]))
    tl = compile_fault_timeline(spec)
    vtl = None
    r = spec.reaction
    if r is not None and r.enabled:
        cfg = replace(cfg, react=True)
        lag = reaction_lag(r, spec.sim.routing)
        vtl = lagged_timeline(tl, lag) if lag > 0 else tl
    chunk = flow_chunk_default(len(fa), cfg.n_planes, cfg.agg_mode)
    if chunk and not cfg.trace.enabled:
        # chunked streaming implies sparse aggregation (a forced
        # REPRO_JX_FLOW_CHUNK coerces it; the auto heuristic only fires
        # on already-sparse shapes)
        cfg = replace(cfg, agg_mode="sparse", flow_chunk=chunk)
    return cfg, fa, tl, pm, vtl


def phase_boundaries(pm: Optional[np.ndarray]) -> List[int]:
    """Slots where any phase-multiplier lane changes value ([0] always
    included) — unioned with the fault timeline's `change_slots()` so
    the piecewise-constant segment machinery covers both.  Phase changes
    never alter path capacity, so the ECMP re-hash replay draws no extra
    RNG at these boundaries and numpy↔jax parity is preserved."""
    if pm is None:
        return [0]
    diff = np.any(pm[1:] != pm[:-1], axis=1)
    return [0] + (np.flatnonzero(diff) + 1).tolist()


def _seg_dem(pm: Optional[np.ndarray], boundaries) -> np.ndarray:
    """(n_seg, K) demand-multiplier snapshots; a (n_seg, 1) ones
    placeholder when no schedule is present (cfg.n_phases == 0 compiles
    the gather away — the operand is dead)."""
    b = list(boundaries)
    if pm is None:
        return np.ones((len(b), 1))
    return np.asarray(pm)[b]


def _seg_id(boundaries, slots: int) -> np.ndarray:
    """(T,) index of the capacity segment governing each slot."""
    return (np.searchsorted(np.asarray(list(boundaries)),
                            np.arange(slots), side="right") - 1) \
        .astype(np.int32)


def _assign_for(cfg: JxConfig, fa: FlowArrays, tl: FaultTimeline,
                seed: int, boundaries,
                vtl: Optional[FaultTimeline] = None,
                mode: str = "instant",
                backup: Optional[np.ndarray] = None) -> np.ndarray:
    if cfg.routing == "ecmp":
        return ecmp_assign_segments(
            fa.src_leaf, fa.dst_leaf, tl, seed, cfg.n_paths, boundaries,
            uplink_cap=cfg.uplink_cap, core_cap=cfg.core_cap,
            cores_per_agg=cfg.cores_per_agg,
            leaves_per_pod=cfg.leaves_per_pod,
            vis_timeline=vtl, mode=mode, backup=backup)
    return np.zeros((1, len(fa), cfg.n_planes), np.int32)


def _seg_caps(tl: FaultTimeline, boundaries
              ) -> Tuple[np.ndarray, ...]:
    """Compress a dense timeline to its boundary snapshots
    ((n_seg, ...) each) — the engine re-expands via `_seg_id` gathers.
    Stage-B snapshots are (n_seg, P, 1, 1) ones on leaf_spine (passed
    through but never read by that kind's traced program)."""
    b = list(boundaries)
    if tl.up2 is not None:
        return (tl.up[b], tl.down[b], tl.access[b], tl.up2[b],
                tl.down2[b])
    P = tl.up.shape[1]
    dummy = np.ones((len(b), P, 1, 1))
    return tl.up[b], tl.down[b], tl.access[b], dummy, dummy


def _vis_seg_caps(vtl: Optional[FaultTimeline], boundaries,
                  n_planes: int) -> Tuple[np.ndarray, ...]:
    """The four routing-visible fabric snapshots (up, down, up2, down2);
    inert `(n_seg, P, 1, 1)` ones when reaction is off (`cfg.react=False`
    never reads them — the operands are dead)."""
    b = list(boundaries)
    if vtl is None:
        dummy = np.ones((len(b), n_planes, 1, 1))
        return dummy, dummy, dummy, dummy
    if vtl.up2 is not None:
        return vtl.up[b], vtl.down[b], vtl.up2[b], vtl.down2[b]
    dummy = np.ones((len(b), n_planes, 1, 1))
    return vtl.up[b], vtl.down[b], dummy, dummy


def _masked_perm_matrix(keys: np.ndarray, mask: np.ndarray,
                        n_buckets: int, width: int,
                        pad: int) -> np.ndarray:
    """`_perm_matrix` over only the flows where `mask` — the stage-B
    fat-tree plans exclude intra-pod flows (which never touch a core
    link; the NumPy path adds exact 0.0 for them, so exclusion is
    bit-equivalent).  Flow order is preserved within buckets."""
    perm = np.full((n_buckets, width), pad, np.int32)
    idx = np.flatnonzero(mask)
    sub = np.asarray(keys)[idx]
    order = np.argsort(sub, kind="stable")
    sk = sub[order]
    counts = np.bincount(sk, minlength=n_buckets)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    ranks = np.arange(len(sk)) - starts[sk]
    perm[sk, ranks] = idx[order]
    return perm


def _ft_ecmp_keys(cfg: JxConfig, fa: FlowArrays, assign_gp: np.ndarray
                  ) -> Tuple[Tuple[np.ndarray, np.ndarray, int], ...]:
    """The four fat-tree load-bucket key families for one (segment,
    plane) assignment column: (keys, mask, n_buckets) each, in plan
    row order (A-up, A-down, B-up, B-down)."""
    L, A = cfg.n_leaves, cfg.n_aggs
    J, pods = cfg.n_paths, cfg.n_pods
    a_of = assign_gp // cfg.cores_per_agg
    pod_s = fa.src_leaf // cfg.leaves_per_pod
    pod_d = fa.dst_leaf // cfg.leaves_per_pod
    cross = pod_s != pod_d
    every = np.ones(len(fa), bool)
    return ((fa.src_leaf * A + a_of, every, L * A),
            (a_of * L + fa.dst_leaf, every, A * L),
            (pod_s * J + assign_gp, cross, pods * J),
            (pod_d * J + assign_gp, cross, pods * J))


def _plan_rows(cfg: JxConfig) -> int:
    """Row count of one ECMP load plan: stage-A up+down buckets, plus
    the two stage-B bucket families on fat_tree."""
    if cfg.kind == "fat_tree":
        L, A = cfg.n_leaves, cfg.n_aggs
        return L * A + A * L + 2 * cfg.n_pods * cfg.n_paths
    return 2 * cfg.n_leaves * cfg.n_spines


def _agg_widths(cfg: JxConfig, fa: FlowArrays,
                assign: np.ndarray) -> Tuple[int, ...]:
    """Max bucket sizes for each aggregation axis (shared across a batch
    so the padded perm matrices stack)."""
    if cfg.agg_mode == "sparse":
        # sparse aggregation never materializes the gather plans, so
        # their widths are irrelevant (and the bincount sweep over every
        # (segment, plane) column would dominate prep time at scale)
        return (1, 1, 1, 1)

    def w(keys, n, mask=None):
        if mask is not None:
            keys = keys[mask]
            if keys.size == 0:
                return 1
        return max(1, int(np.bincount(keys, minlength=n).max()))
    H, L, S, P = cfg.n_hosts, cfg.n_leaves, cfg.n_spines, cfg.n_planes
    wu = 1
    if cfg.routing == "ecmp":
        for g in range(assign.shape[0]):
            for p in range(P):
                if cfg.kind == "fat_tree":
                    wu = max([wu] + [
                        w(keys, n, mask) for keys, mask, n in
                        _ft_ecmp_keys(cfg, fa, assign[g][:, p])])
                else:
                    wu = max(wu,
                             w(fa.src_leaf * S + assign[g][:, p], L * S),
                             w(assign[g][:, p] * L + fa.dst_leaf, S * L))
    return (w(fa.src, H), w(fa.dst, H),
            w(fa.src_leaf * L + fa.dst_leaf, L * L), wu)


def _ecmp_load_plan(cfg: JxConfig, fa: FlowArrays, assign: np.ndarray,
                    wu: int, pad: int) -> np.ndarray:
    """(n_seg, P, `_plan_rows(cfg)`, wu) ECMP load-aggregation plan (see
    `_AggPerms.ecmp_load`) — the single builder shared by the per-group
    and megabatch paths, so their 1e-5 row-identity cannot drift."""
    P, L, S = cfg.n_planes, cfg.n_leaves, cfg.n_spines

    def plane(g, p):
        if cfg.kind == "fat_tree":
            return np.concatenate([
                _masked_perm_matrix(keys, mask, n, wu, pad)
                for keys, mask, n in
                _ft_ecmp_keys(cfg, fa, assign[g][:, p])])
        return np.concatenate([
            _perm_matrix(fa.src_leaf * S + assign[g][:, p],
                         L * S, wu, pad),
            _perm_matrix(assign[g][:, p] * L + fa.dst_leaf,
                         S * L, wu, pad)])

    return np.stack([
        np.stack([plane(g, p) for p in range(P)])
        for g in range(assign.shape[0])])


def _aggs_for(cfg: JxConfig, fa: FlowArrays, assign: np.ndarray,
              widths: Tuple[int, ...],
              pad: Optional[int] = None) -> _AggPerms:
    """`pad` is the index that reads the appended zero row in
    `_seg_sum` — the row count of the (possibly flow-padded) batch, not
    necessarily `len(fa)`."""
    ws, wd, wp, wu = widths
    H, L, P = cfg.n_hosts, cfg.n_leaves, cfg.n_planes
    F = len(fa) if pad is None else pad
    if cfg.agg_mode == "sparse":
        # sparse mode aggregates by (plane, link) keys computed from the
        # flow batch inside the traced program; the gather plans are
        # never indexed, so ship inert minimal placeholders
        z = np.zeros((1, 1), np.int32)
        return _AggPerms(src=z, dst=z, pair=z,
                         ecmp_load=np.zeros((1, P, 1, 1), np.int32))
    if cfg.routing == "ecmp":
        load = _ecmp_load_plan(cfg, fa, assign, wu, F)
    else:
        load = np.full((1, P, 1, 1), F, np.int32)
    return _AggPerms(
        src=_perm_matrix(fa.src, H, ws, F),
        dst=_perm_matrix(fa.dst, H, wd, F),
        pair=_perm_matrix(fa.src_leaf * L + fa.dst_leaf, L * L, wp, F),
        ecmp_load=load)


def _wrap(cfg: JxConfig, fa: FlowArrays, out) -> JxSimResult:
    mean_goodput, completion, totals, util = \
        (np.asarray(o) for o in out[:4])
    idx = 4
    bh = None
    if cfg.react:
        bh = np.asarray(out[idx])
        idx += 1
    trace = None
    if cfg.trace.enabled:
        trace = {"slot": cfg.trace.recorded_slots(cfg.slots)}
        trace.update((name, np.asarray(arr)) for name, arr
                     in zip(cfg.trace.active_fields(), out[idx:]))
    return JxSimResult(
        mean_goodput=mean_goodput,
        completion_slot=completion.astype(np.int64),
        total_goodput=totals[::cfg.record_every],
        util_up_last=util, groups=fa.groups, group_of=fa.group,
        slot_us=cfg.slot_us, trace=trace, blackhole_timeline=bh)


def run_compiled(compiled) -> JxSimResult:
    """Simulate one `CompiledScenario` on the JAX backend."""
    global _BACKEND_USED
    _BACKEND_USED = True
    cfg, fa, tl, pm, vtl = _prepared(compiled)
    boundaries = set(tl.change_slots()) | set(phase_boundaries(pm))
    if vtl is not None:
        boundaries |= set(vtl.change_slots())
    boundaries = tuple(sorted(boundaries))
    r = compiled.spec.reaction
    segs = _assign_for(cfg, fa, tl, compiled.cfg.seed, boundaries,
                       vtl=vtl, mode=r.mode if cfg.react else "instant",
                       backup=getattr(compiled, "backup", None))
    aggs = _aggs_for(cfg, fa, segs, _agg_widths(cfg, fa, segs))
    up, down, acc, up2, down2 = _seg_caps(tl, boundaries)
    vup, vdown, vup2, vdown2 = _vis_seg_caps(
        vtl if cfg.react else None, boundaries, cfg.n_planes)
    args = (FlowBatch.from_arrays(fa), up, down, acc, up2, down2,
            _seg_dem(pm, boundaries), vup, vdown, vup2, vdown2, segs,
            aggs, _seg_id(boundaries, cfg.slots))
    _record_launch("group", (cfg, False, 1), args)
    out = _jitted(cfg, False)(*args)
    return _wrap(cfg, fa, out)


def dispatch_compiled_batch(points: List):
    """Build and asynchronously dispatch one batch of structurally
    identical `CompiledScenario`s (same scenario / routing / nic /
    slots — only seeds differ).  Returns an opaque handle for
    `finalize_batch`; the computation runs concurrently with whatever
    the caller does next (JAX CPU execution is async).  With
    `XLA_FLAGS=--xla_force_host_platform_device_count=N` the batch axis
    is `pmap`-sharded over the N host devices (padding the batch by
    replicating the last point if needed), keeping every core busy
    without a process pool."""
    global _BACKEND_USED
    _BACKEND_USED = True
    prepared = [_prepared(c) for c in points]
    cfg = prepared[0][0]
    F = len(prepared[0][1])
    for c, (cfg_i, fa_i, _, _, _) in zip(points, prepared):
        if cfg_i != cfg or len(fa_i) != F:
            raise ValueError(
                "batched points must be structurally identical "
                f"(got {cfg_i} with {len(fa_i)} flows vs {cfg} with {F}); "
                "group grid points by (scenario, routing, nic) first")
    # shared segment boundaries: union of capacity-change AND
    # phase-change slots (and visible-capacity changes under reaction),
    # so every element's ECMP re-hash replay sees each capacity change
    # exactly once and the demand timeline is piecewise-constant per
    # segment
    boundaries = tuple(sorted(
        {b for _, _, tl, _, _ in prepared for b in tl.change_slots()}
        | {b for _, _, _, pm, _ in prepared
           for b in phase_boundaries(pm)}
        | {b for _, _, _, _, vtl in prepared if vtl is not None
           for b in vtl.change_slots()}))
    assigns = [
        _assign_for(
            cfg, fa, tl, c.cfg.seed, boundaries, vtl=vtl,
            mode=(c.spec.reaction.mode if cfg.react else "instant"),
            backup=getattr(c, "backup", None))
        for c, (_, fa, tl, _, vtl) in zip(points, prepared)]
    widths = tuple(map(max, zip(*(
        _agg_widths(cfg, fa, a)
        for (_, fa, _, _, _), a in zip(prepared, assigns)))))
    aggs = [_aggs_for(cfg, fa, a, widths)
            for (_, fa, _, _, _), a in zip(prepared, assigns)]
    fb = FlowBatch.stack([fa for _, fa, _, _, _ in prepared])
    caps = [_seg_caps(tl, boundaries) for _, _, tl, _, _ in prepared]
    up, down, acc, up2, down2 = (np.stack(col) for col in zip(*caps))
    vcaps = [_vis_seg_caps(vtl if cfg.react else None, boundaries,
                           cfg.n_planes)
             for _, _, _, _, vtl in prepared]
    vup, vdown, vup2, vdown2 = (np.stack(col) for col in zip(*vcaps))
    dem = np.stack([_seg_dem(pm, boundaries)
                    for _, _, _, pm, _ in prepared])
    seg_id = _seg_id(boundaries, cfg.slots)
    aggs_b = _AggPerms(*(np.stack(col) for col in zip(*aggs)))
    args = [fb, up, down, acc, up2, down2, dem, vup, vdown, vup2,
            vdown2, np.stack(assigns), aggs_b]
    B = len(points)
    n_dev = len(jax.devices())
    shards = min(B, n_dev) if n_dev > 1 and B > 1 else 1
    if shards > 1:
        padded = -B % shards

        def shape(a):
            if padded:
                a = np.concatenate(
                    [np.asarray(a),
                     np.repeat(np.asarray(a)[-1:], padded, 0)])
            return np.asarray(a).reshape(
                (shards, (B + padded) // shards) + np.shape(a)[1:])

        args = [jax.tree_util.tree_map(shape, a) for a in args]
    _record_launch("group", (cfg, True, shards), args)
    out = _jitted(cfg, True, shards)(*args, seg_id)
    # keep only what finalize needs — dropping the dense per-point
    # timelines here frees O(B*T*fabric) host memory while the batch
    # computes
    return cfg, [fa for _, fa, _, _, _ in prepared], shards, out


def finalize_batch(handle) -> List[JxSimResult]:
    """Block on a `dispatch_compiled_batch` handle and unpack per-point
    results (dropping any pmap padding)."""
    cfg, fas, shards, out = handle
    outs = [np.asarray(o) for o in out]
    if shards > 1:
        outs = [o.reshape((-1,) + o.shape[2:]) for o in outs]
    return [_wrap(cfg, fa, [o[b] for o in outs])
            for b, fa in enumerate(fas)]


def run_compiled_batch(points: List) -> List[JxSimResult]:
    """Simulate a batch of `CompiledScenario`s that share structure as
    one batched (vmap, pmap-sharded when multiple host devices exist)
    computation — the JAX replacement for the process-pool sweep."""
    return finalize_batch(dispatch_compiled_batch(points))
