"""Jitted slot loop: the JAX twin of `netsim.sim.run_sim`.

One slot is a pure function `(SimCarry, slot inputs) -> SimCarry` that
reproduces, operation for operation, the NumPy pipeline:

  PLB plane split -> routing fractions (AR / weighted-AR from the queue
  carry, ECMP from precompiled assignment segments) -> per-link bottleneck
  scaling -> queue/ECN/RTT evolution -> NIC control update
  (`spx|dcqcn|global|esr|swlb`) -> loss-stall masking -> transfer
  completion.

The loop runs under `lax.scan`; whole sweep axes (seeds, each with its own
flow population and fault timeline) run as one `jax.vmap` batch.  Fault
schedules are compiled to capacity-multiplier timelines by `events.py` and
enter the scan compressed to their piecewise-constant segment snapshots
(per-slot segment-id gathers re-expand them); ECMP spine assignments
arrive as step-function segments precomputed by
`events.ecmp_assign_segments` (the dead-path re-hash depends only on the
static timeline, so its RNG stream is replayed exactly on the host).

With x64 enabled the trajectory matches the NumPy backend within 1e-5
(registry-wide parity is enforced by `tests/test_jx_parity.py`); without
x64 it runs float32 — faster, looser tolerance.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim.cc import (DCQCN_AI, DCQCN_ALPHA_G, MIN_RATE,
                             PROBE_TIMEOUT, SPX_AI, SPX_MD, SPX_RTT_GAIN,
                             TARGET_RTT_US)
from repro.netsim.fabric import (AR_TEMPERATURE, ECN_QUEUE_THRESH,
                                 JSQ_BINS, Q_CAP, FlowArrays)
from repro.netsim.sim import SimConfig

from .events import (FaultTimeline, compile_fault_timeline,
                     ecmp_assign_segments)
from .state import FlowBatch, NicCarry, SimCarry, init_carry

_EPS = 1e-12

# flipped on first dispatch; scenarios.runner consults it to decide
# whether forking a process pool is still safe in this process
_BACKEND_USED = False


@dataclass(frozen=True)
class JxConfig:
    """Static (hashable) simulation parameters: everything `lax.scan`
    needs resolved at trace time — sim knobs, topology shape, and the
    `FluidFabric` constants."""
    slots: int
    slot_us: float
    routing: str
    nic: str
    base_rtt_us: float
    warmup_frac: float
    record_every: int
    sw_lb_delay_slots: int
    n_planes: int
    n_leaves: int
    n_spines: int
    n_hosts: int
    uplink_cap: float
    access_cap: float
    target_rtt_us: float = TARGET_RTT_US
    probe_timeout: int = PROBE_TIMEOUT
    ecn_queue_thresh: float = ECN_QUEUE_THRESH
    ar_temperature: float = AR_TEMPERATURE
    jsq_bins: int = JSQ_BINS
    q_cap: float = Q_CAP

    @classmethod
    def from_sim(cls, cfg: SimConfig, topo) -> "JxConfig":
        """`topo` is a `TopologySpec` (or anything with the same shape
        attributes and a uniform base capacity)."""
        return cls(
            slots=cfg.slots, slot_us=cfg.slot_us, routing=cfg.routing,
            nic=cfg.nic, base_rtt_us=cfg.base_rtt_us,
            warmup_frac=cfg.warmup_frac, record_every=cfg.record_every,
            sw_lb_delay_slots=cfg.sw_lb_delay_slots(),
            n_planes=topo.n_planes, n_leaves=topo.n_leaves,
            n_spines=topo.n_spines, n_hosts=topo.n_hosts,
            uplink_cap=topo.link_cap * topo.parallel_links,
            access_cap=topo.access_cap)


@dataclass
class JxSimResult:
    """Distilled run output — the fields `scenarios.runner` consumes.
    Unlike the NumPy `SimResult` there is no dense `(T, F)` goodput
    record; the per-flow mean and the per-slot total are accumulated
    inside the scan instead."""
    mean_goodput: np.ndarray     # (F,) post-warmup average
    completion_slot: np.ndarray  # (F,) -1 = unfinished
    total_goodput: np.ndarray    # (T_rec,) summed over flows per frame
    util_up_last: np.ndarray     # (P, L, S)
    groups: List[str]
    group_of: np.ndarray
    slot_us: float

    def group_mean(self, group: str) -> float:
        gi = self.groups.index(group)
        return float(self.mean_goodput[self.group_of == gi].mean())


# ---------------------------------------------------------------------------
# NIC: plane split + control update (port of netsim.cc.NicState)
# ---------------------------------------------------------------------------

def _plane_split(cfg: JxConfig, nic: NicCarry,
                 demand: jnp.ndarray) -> jnp.ndarray:
    P = cfg.n_planes
    if cfg.nic == "dcqcn":
        w = jnp.ones_like(nic.rate) / P
        return jnp.minimum(demand[:, None] * w, nic.rate)
    if cfg.nic == "swlb":
        elig = nic.eligible
        n_up = jnp.maximum(elig.sum(1, keepdims=True), 1)
        return jnp.where(elig, demand[:, None] / n_up, 0.0)
    if cfg.nic in ("global", "esr"):
        elig = nic.eligible
        n_up = jnp.maximum(elig.sum(1, keepdims=True), 1)
        shared = nic.rate.min(1, keepdims=True)
        return jnp.where(elig, demand[:, None] * shared / n_up, 0.0)
    # spx: rate-filter then weight by allowance
    elig = nic.eligible & (nic.rate > MIN_RATE + 1e-9)
    any_ok = elig.any(1, keepdims=True)
    elig = jnp.where(any_ok, elig, nic.eligible)
    w = jnp.where(elig, nic.rate, 0.0)
    s = w.sum(1, keepdims=True)
    w = jnp.where(s > 0, w / jnp.maximum(s, 1e-12), 1.0 / P)
    return jnp.minimum(demand[:, None] * w,
                       jnp.where(elig, nic.rate, 0.0))


def _probe(cfg: JxConfig, nic: NicCarry, rate: jnp.ndarray,
           probe_ok: jnp.ndarray, slot: jnp.ndarray) -> NicCarry:
    miss = ~probe_ok
    probe_miss = jnp.where(miss, nic.probe_miss + 1, 0)
    dead = probe_miss >= cfg.probe_timeout
    eligible, pending = nic.eligible, nic.pending_fail
    if cfg.nic == "swlb" and cfg.sw_lb_delay_slots > 0:
        newly = dead & eligible & (pending == 0)
        pending = jnp.where(newly, slot + cfg.sw_lb_delay_slots, pending)
        fire = (pending > 0) & (slot >= pending)
        eligible = jnp.where(fire & dead, False, eligible)
        healed = ~dead & ~eligible
        eligible = jnp.where(healed, True, eligible)
        pending = jnp.where(~dead, 0, pending)
    else:
        was = eligible
        eligible = ~dead
        just_back = eligible & ~was
        rate = jnp.where(just_back, 0.5, rate)
    rate = jnp.where(~eligible, MIN_RATE, rate)
    return NicCarry(rate=rate, alpha=nic.alpha, probe_miss=probe_miss,
                    eligible=eligible, pending_fail=pending)


def _nic_update(cfg: JxConfig, nic: NicCarry, rtt: jnp.ndarray,
                ecn: jnp.ndarray, probe_ok: jnp.ndarray,
                slot: jnp.ndarray) -> NicCarry:
    if cfg.nic == "dcqcn":
        ecn_any = ecn.max(1, keepdims=True)
        alpha = ((1 - DCQCN_ALPHA_G) * nic.alpha +
                 DCQCN_ALPHA_G * (ecn_any > 0))
        cut = nic.rate * (1 - alpha / 2)
        grow = jnp.minimum(nic.rate + DCQCN_AI, 1.0)
        rate = jnp.clip(jnp.where(ecn_any > 0, cut, grow), MIN_RATE, 1.0)
        return nic._replace(rate=rate, alpha=alpha)

    if cfg.nic in ("global", "esr"):
        agg_ecn = ecn.max(1, keepdims=True)
        agg_rtt = rtt.max(1, keepdims=True)
        cut = nic.rate * SPX_MD
        rtt_err = (agg_rtt - cfg.target_rtt_us) / cfg.target_rtt_us
        trim = nic.rate * (1 - SPX_RTT_GAIN * jnp.clip(rtt_err, 0, 2))
        grow = jnp.minimum(nic.rate + SPX_AI, 1.0)
        new = jnp.where(agg_ecn > 0, cut,
                        jnp.where(rtt_err > 0.25, trim, grow))
        if cfg.nic == "esr":
            new = jnp.where(agg_ecn > 0, new * 0.85, new)
        rate = jnp.clip(new, MIN_RATE, 1.0)
        return _probe(cfg, nic, rate, probe_ok, slot)

    # spx / swlb: per-plane contexts
    rtt_err = (rtt - cfg.target_rtt_us) / cfg.target_rtt_us
    cut = nic.rate * (SPX_MD + (1 - SPX_MD) * jnp.clip(1 - ecn, 0, 1))
    trim = nic.rate * (1 - SPX_RTT_GAIN * jnp.clip(rtt_err, 0, 2))
    grow = jnp.minimum(nic.rate + SPX_AI, 1.0)
    rate = jnp.clip(
        jnp.where(ecn > 0, cut, jnp.where(rtt_err > 0.25, trim, grow)),
        MIN_RATE, 1.0)
    return _probe(cfg, nic, rate, probe_ok, slot)


# ---------------------------------------------------------------------------
# routing fractions (port of FluidFabric.pair_fractions / ecmp_fractions)
# ---------------------------------------------------------------------------

def _pair_fractions(cfg: JxConfig, q_up: jnp.ndarray, q_down: jnp.ndarray,
                    up: jnp.ndarray, down: jnp.ndarray,
                    remote_weights: Optional[jnp.ndarray]) -> jnp.ndarray:
    """(P, L_src, L_dst, S) spine split; 'war' folds in remote weights."""
    cap = jnp.minimum(up[:, :, None, :],
                      jnp.swapaxes(down, 1, 2)[:, None, :, :])
    up_mask = cap > 1e-9
    q = (q_up[:, :, None, :] +
         jnp.swapaxes(q_down, 1, 2)[:, None, :, :])
    qbin = jnp.floor(jnp.clip(q / 8.0, 0, 1 - 1e-9) * cfg.jsq_bins) + 1.0
    w = cap
    if remote_weights is not None:
        w = w * jnp.swapaxes(remote_weights, 1, 2)[:, None, :, :]
    score = qbin / jnp.maximum(w, 1e-9)
    logit = jnp.where(up_mask, -score / cfg.ar_temperature, -1e30)
    logit -= logit.max(-1, keepdims=True)
    e = jnp.exp(logit)
    sums = e.sum(-1, keepdims=True)
    return jnp.where(sums > 0, e / jnp.maximum(sums, 1e-30), 0.0)


# ---------------------------------------------------------------------------
# one slot
# ---------------------------------------------------------------------------

class _AggPerms(NamedTuple):
    """Flow -> bucket aggregation plans.  XLA CPU scatters (and one-hot
    matmuls) are an order of magnitude slower than gathers, so every
    per-slot "sum flows into buckets" becomes: gather flows into a
    `(n_buckets, width)` layout (rows padded with index F, which reads a
    zero row) and sum the width axis.  The permutations are static per
    run — ECMP's spine assignment is piecewise-constant, so it gets one
    plan per capacity segment.

    The ECMP plan (`ecmp_load`) stacks uplink and downlink buckets into
    one `(n_seg, P, L*S + S*L, C)` matrix.  In float64 (parity mode) its
    width axis is summed strictly left-to-right (flow order): those sums
    feed the queue integrators, where a last-ulp tree-reduction
    difference vs NumPy's sequential `np.add.at` can walk a queue across
    an ECN threshold and fork the trajectory.  Float32 runs take the
    fast tree reduction instead — they drift from the f64 reference at
    ulp level regardless.  AR/WAR fractions are smooth in the loads, so
    their aggregations tolerate tree reduction at either precision."""
    src: jnp.ndarray        # (H, Cs)  flows by src host
    dst: jnp.ndarray        # (H, Cd)  flows by dst host
    pair: jnp.ndarray       # (L*L, Cp) flows by (src_leaf, dst_leaf)
    ecmp_load: jnp.ndarray  # (n_seg, P, L*S + S*L, Cu)


def _perm_matrix(keys: np.ndarray, n_buckets: int, width: int,
                 pad: int) -> np.ndarray:
    """(n_buckets, width) flow indices grouped by key, flow order
    preserved within a bucket, padded with `pad`."""
    perm = np.full((n_buckets, width), pad, np.int32)
    keys = np.asarray(keys)
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    counts = np.bincount(sk, minlength=n_buckets)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    ranks = np.arange(len(sk)) - starts[sk]
    perm[sk, ranks] = order
    return perm


def _seg_sum(vals: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """vals (F, P), perm (K, C) -> (K, P) bucket sums."""
    pad = jnp.concatenate(
        [vals, jnp.zeros((1, vals.shape[1]), vals.dtype)], 0)
    return pad[perm].sum(1)


def _slot_step(cfg: JxConfig, fb: FlowBatch, pair_idx: jnp.ndarray,
               aggs: _AggPerms, assign_segments: jnp.ndarray,
               seg_up: jnp.ndarray, seg_down: jnp.ndarray,
               seg_acc: jnp.ndarray, carry: SimCarry, xs):
    # timelines are piecewise-constant, so the scan carries only the
    # (n_seg, ...) boundary snapshots and gathers the current segment
    t, seg = xs
    P, L, S = cfg.n_planes, cfg.n_leaves, cfg.n_spines
    up = seg_up[seg] * cfg.uplink_cap                     # (P, L, S)
    down = seg_down[seg] * cfg.uplink_cap                 # (P, S, L)
    acc = (seg_acc[seg] * cfg.access_cap).T               # (H, P)

    demand = jnp.where(carry.done | (t < fb.start_slot), 0.0, fb.demand)
    offered = _plane_split(cfg, carry.nic, demand)        # (F, P)
    fabric_rate = jnp.where(fb.same_leaf[:, None], 0.0, offered)

    # ---- link loads + per-flow path scale/queue, without any (F, P, S)
    # intermediate: AR/WAR fractions are leaf-pair quantities, so flows
    # aggregate to (P, L, L) before touching the spine axis; ECMP's
    # one-hot spine choice reduces to (F, P) gathers + padded bucket sums.
    if cfg.routing == "ecmp":
        assign = assign_segments[seg]                     # (F, P)
        p_iota = jnp.arange(P)[None, :].repeat(fabric_rate.shape[0], 0)
        padT = jnp.concatenate(
            [fabric_rate, jnp.zeros((1, P), fabric_rate.dtype)], 0).T
        pidx = jnp.arange(P)[:, None, None]
        g = padT[pidx, aggs.ecmp_load[seg]]               # (P, LS+SL, C)
        if g.dtype == jnp.float64:
            # parity mode: accumulate in flow order — see _AggPerms.
            # fori_loop (not a Python unroll) keeps the traced graph
            # O(1) in the bucket width for huge flow populations.
            loads = jax.lax.fori_loop(
                1, g.shape[2],
                lambda c, acc: acc + jax.lax.dynamic_index_in_dim(
                    g, c, 2, keepdims=False),
                g[:, :, 0])
        else:
            # float32 production mode diverges from NumPy at ulp level
            # regardless, so take the fast tree reduction
            loads = g.sum(-1)
        load_up = loads[:, :L * S].reshape(P, L, S)
        load_down = loads[:, L * S:].reshape(P, S, L)
    else:
        rw = None
        if cfg.routing == "war":
            rw = down / jnp.maximum(down.max(axis=1, keepdims=True), 1e-9)
        pair = _pair_fractions(cfg, carry.q_up, carry.q_down, up, down, rw)
        rate_pair = _seg_sum(fabric_rate, aggs.pair).T.reshape(P, L, L)
        load_up = jnp.einsum("plm,plms->pls", rate_pair, pair)
        load_down = jnp.einsum("plm,plms->psm", rate_pair, pair)
    load_acc_tx = _seg_sum(offered, aggs.src)             # (H, P)
    load_acc_rx = _seg_sum(offered, aggs.dst)

    # ---- bottleneck scaling ----
    f_up = jnp.minimum(1.0, up / jnp.maximum(load_up, _EPS))
    f_down = jnp.minimum(1.0, down / jnp.maximum(load_down, _EPS))
    f_acc_tx = jnp.minimum(1.0, acc / jnp.maximum(load_acc_tx, _EPS))
    f_acc_rx = jnp.minimum(1.0, acc / jnp.maximum(load_acc_rx, _EPS))
    up_alive_tx = acc[fb.src] > _EPS                      # (F, P)
    up_alive_rx = acc[fb.dst] > _EPS

    # ---- achieved + queue delay per (flow, plane) ----
    if cfg.routing == "ecmp":
        scale_f = jnp.minimum(
            f_up[p_iota, fb.src_leaf[:, None], assign],
            f_down[p_iota, assign, fb.dst_leaf[:, None]])
        through = fabric_rate * scale_f
        qmean = (carry.q_up[p_iota, fb.src_leaf[:, None], assign] +
                 carry.q_down[p_iota, assign, fb.dst_leaf[:, None]])
    else:
        scale_pair = jnp.minimum(
            f_up[:, :, None, :],
            f_down.transpose(0, 2, 1)[:, None, :, :])     # (P, L, L, S)
        path_scale = (pair * scale_pair).sum(-1).reshape(P, L * L)
        through = fabric_rate * path_scale[:, pair_idx].T
        q_pair = (carry.q_up[:, :, None, :] +
                  carry.q_down.transpose(0, 2, 1)[:, None, :, :])
        qmean = (pair * q_pair).sum(-1).reshape(P, L * L)[:, pair_idx].T
    local = jnp.where(fb.same_leaf[:, None], offered, 0.0)
    acc_scale = jnp.minimum(f_acc_tx[fb.src], f_acc_rx[fb.dst])
    achieved_pp = (through + local) * acc_scale
    achieved_pp = jnp.where(up_alive_tx & up_alive_rx, achieved_pp, 0.0)
    qmean = jnp.where(fb.same_leaf[:, None], 0.0, qmean)
    rtt = cfg.base_rtt_us + qmean * cfg.slot_us * 0.5
    ecn = jnp.where(qmean > cfg.ecn_queue_thresh,
                    jnp.minimum(1.0, qmean / (4 * cfg.ecn_queue_thresh)),
                    0.0)

    # ---- queue evolution ----
    q_up = jnp.clip(carry.q_up + (load_up - up) / jnp.maximum(up, _EPS),
                    0.0, cfg.q_cap)
    q_up = jnp.where(up <= _EPS, 0.0, q_up)
    q_down = jnp.clip(carry.q_down + (load_down - down) /
                      jnp.maximum(down, _EPS), 0.0, cfg.q_cap)
    q_down = jnp.where(down <= _EPS, 0.0, q_down)
    util = load_up / jnp.maximum(up, _EPS)

    # ---- NIC control update (pre-stall rates, as in run_sim) ----
    probe_ok = (acc[fb.src] > _EPS) & (acc[fb.dst] > _EPS)
    nic = _nic_update(cfg, carry.nic, rtt, ecn, probe_ok, t)

    # ---- packet-loss stall + completion ----
    stalled = ((offered > 1e-9) & (achieved_pp <= 1e-9)).any(1)
    achieved = jnp.where(stalled, 0.0, achieved_pp.sum(1))

    remaining = carry.remaining - achieved
    newly = (~carry.done) & (remaining <= 0)
    w = jnp.maximum(offered, _EPS)
    qdelay = (((rtt * w).sum(1) / w.sum(1)) - cfg.base_rtt_us) \
        / cfg.slot_us
    completion = jnp.where(
        newly, t + jnp.ceil(qdelay).astype(carry.completion.dtype),
        carry.completion)
    done = carry.done | newly

    # ---- post-warmup accumulation (replaces dense (T, F) recording) ----
    r = cfg.record_every
    n_rec = (cfg.slots + r - 1) // r
    w0 = int(n_rec * cfg.warmup_frac)
    rec = (t % r) == 0
    if n_rec > w0:
        counted = rec & ((t // r) >= w0)
    else:
        counted = rec
    goodput_sum = carry.goodput_sum + jnp.where(counted, achieved, 0.0)

    new_carry = SimCarry(
        q_up=q_up, q_down=q_down, nic=nic, remaining=remaining,
        done=done, completion=completion, goodput_sum=goodput_sum,
        util_up=util)
    return new_carry, achieved.sum()


def _simulate(cfg: JxConfig, fb: FlowBatch, seg_up, seg_down, seg_acc,
              assign_segments, aggs, seg_id):
    carry0 = init_carry(fb, cfg.n_planes, cfg.n_leaves, cfg.n_spines)
    pair_idx = fb.src_leaf * cfg.n_leaves + fb.dst_leaf
    xs = (jnp.arange(cfg.slots), seg_id)
    step = partial(_slot_step, cfg, fb, pair_idx, aggs, assign_segments,
                   jnp.asarray(seg_up), jnp.asarray(seg_down),
                   jnp.asarray(seg_acc))
    carry, totals = jax.lax.scan(step, carry0, xs)
    r = cfg.record_every
    n_rec = (cfg.slots + r - 1) // r
    w0 = int(n_rec * cfg.warmup_frac)
    frames = (n_rec - w0) if n_rec > w0 else n_rec
    return (carry.goodput_sum / frames, carry.completion, totals,
            carry.util_up)


@lru_cache(maxsize=None)
def _jitted(cfg: JxConfig, batched: bool, n_shards: int = 1):
    fn = partial(_simulate, cfg)
    if not batched:
        return jax.jit(fn)
    fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, None))
    if n_shards == 1:
        return jax.jit(fn)
    # shard the batch axis over host devices: XLA CPU serializes separate
    # executions even across devices, but one pmap launch runs its
    # per-device shards on parallel threads — the single-process
    # equivalent of the NumPy backend's process pool
    return jax.pmap(fn, in_axes=(0, 0, 0, 0, 0, 0, None))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _prepared(compiled) -> Tuple[JxConfig, FlowArrays, FaultTimeline]:
    spec = compiled.spec
    cfg = JxConfig.from_sim(compiled.cfg, spec.topo)
    fa = FlowArrays.build(compiled.flows, compiled.topo)
    if not jax.config.jax_enable_x64:
        finite = fa.bytes_total[np.isfinite(fa.bytes_total)]
        if finite.size and finite.max() > 2 ** 24:
            import warnings
            warnings.warn(
                f"{spec.name}: bytes_total up to {finite.max():.3g} "
                "exceeds float32 integer resolution (2^24); remaining-"
                "bytes tracking will stall and transfers may never "
                "complete — enable x64 (JAX_ENABLE_X64=1) or rescale "
                "bytes_total", stacklevel=3)
    return cfg, fa, compile_fault_timeline(spec)


def _seg_id(boundaries, slots: int) -> np.ndarray:
    """(T,) index of the capacity segment governing each slot."""
    return (np.searchsorted(np.asarray(list(boundaries)),
                            np.arange(slots), side="right") - 1) \
        .astype(np.int32)


def _assign_for(cfg: JxConfig, fa: FlowArrays, tl: FaultTimeline,
                seed: int, boundaries) -> np.ndarray:
    if cfg.routing == "ecmp":
        return ecmp_assign_segments(fa.src_leaf, fa.dst_leaf, tl, seed,
                                    cfg.n_spines, boundaries,
                                    uplink_cap=cfg.uplink_cap)
    return np.zeros((1, len(fa), cfg.n_planes), np.int32)


def _seg_caps(tl: FaultTimeline, boundaries
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Compress a dense timeline to its boundary snapshots
    ((n_seg, ...) each) — the engine re-expands via `_seg_id` gathers."""
    b = list(boundaries)
    return tl.up[b], tl.down[b], tl.access[b]


def _agg_widths(cfg: JxConfig, fa: FlowArrays,
                assign: np.ndarray) -> Tuple[int, ...]:
    """Max bucket sizes for each aggregation axis (shared across a batch
    so the padded perm matrices stack)."""
    def w(keys, n):
        return max(1, int(np.bincount(keys, minlength=n).max()))
    H, L, S, P = cfg.n_hosts, cfg.n_leaves, cfg.n_spines, cfg.n_planes
    wu = 1
    if cfg.routing == "ecmp":
        for g in range(assign.shape[0]):
            for p in range(P):
                wu = max(wu,
                         w(fa.src_leaf * S + assign[g][:, p], L * S),
                         w(assign[g][:, p] * L + fa.dst_leaf, S * L))
    return (w(fa.src, H), w(fa.dst, H),
            w(fa.src_leaf * L + fa.dst_leaf, L * L), wu)


def _aggs_for(cfg: JxConfig, fa: FlowArrays, assign: np.ndarray,
              widths: Tuple[int, ...]) -> _AggPerms:
    ws, wd, wp, wu = widths
    H, L, S, P = cfg.n_hosts, cfg.n_leaves, cfg.n_spines, cfg.n_planes
    F = len(fa)
    if cfg.routing == "ecmp":
        load = np.stack([
            np.stack([np.concatenate([
                _perm_matrix(fa.src_leaf * S + assign[g][:, p],
                             L * S, wu, F),
                _perm_matrix(assign[g][:, p] * L + fa.dst_leaf,
                             S * L, wu, F)]) for p in range(P)])
            for g in range(assign.shape[0])])
    else:
        load = np.full((1, P, 1, 1), F, np.int32)
    return _AggPerms(
        src=_perm_matrix(fa.src, H, ws, F),
        dst=_perm_matrix(fa.dst, H, wd, F),
        pair=_perm_matrix(fa.src_leaf * L + fa.dst_leaf, L * L, wp, F),
        ecmp_load=load)


def _wrap(cfg: JxConfig, fa: FlowArrays, out) -> JxSimResult:
    mean_goodput, completion, totals, util = (np.asarray(o) for o in out)
    return JxSimResult(
        mean_goodput=mean_goodput,
        completion_slot=completion.astype(np.int64),
        total_goodput=totals[::cfg.record_every],
        util_up_last=util, groups=fa.groups, group_of=fa.group,
        slot_us=cfg.slot_us)


def run_compiled(compiled) -> JxSimResult:
    """Simulate one `CompiledScenario` on the JAX backend."""
    global _BACKEND_USED
    _BACKEND_USED = True
    cfg, fa, tl = _prepared(compiled)
    boundaries = tuple(tl.change_slots())
    segs = _assign_for(cfg, fa, tl, compiled.cfg.seed, boundaries)
    aggs = _aggs_for(cfg, fa, segs, _agg_widths(cfg, fa, segs))
    up, down, acc = _seg_caps(tl, boundaries)
    out = _jitted(cfg, False)(
        FlowBatch.from_arrays(fa), up, down, acc, segs, aggs,
        _seg_id(boundaries, cfg.slots))
    return _wrap(cfg, fa, out)


def dispatch_compiled_batch(points: List):
    """Build and asynchronously dispatch one batch of structurally
    identical `CompiledScenario`s (same scenario / routing / nic /
    slots — only seeds differ).  Returns an opaque handle for
    `finalize_batch`; the computation runs concurrently with whatever
    the caller does next (JAX CPU execution is async).  With
    `XLA_FLAGS=--xla_force_host_platform_device_count=N` the batch axis
    is `pmap`-sharded over the N host devices (padding the batch by
    replicating the last point if needed), keeping every core busy
    without a process pool."""
    global _BACKEND_USED
    _BACKEND_USED = True
    prepared = [_prepared(c) for c in points]
    cfg = prepared[0][0]
    F = len(prepared[0][1])
    for c, (cfg_i, fa_i, _) in zip(points, prepared):
        if cfg_i != cfg or len(fa_i) != F:
            raise ValueError(
                "batched points must be structurally identical "
                f"(got {cfg_i} with {len(fa_i)} flows vs {cfg} with {F}); "
                "group grid points by (scenario, routing, nic) first")
    # shared segment boundaries: union of capacity-change slots, so every
    # element's ECMP re-hash replay sees each change exactly once
    boundaries = tuple(sorted({b for _, _, tl in prepared
                               for b in tl.change_slots()}))
    assigns = [_assign_for(cfg, fa, tl, c.cfg.seed, boundaries)
               for c, (_, fa, tl) in zip(points, prepared)]
    widths = tuple(map(max, zip(*(
        _agg_widths(cfg, fa, a)
        for (_, fa, _), a in zip(prepared, assigns)))))
    aggs = [_aggs_for(cfg, fa, a, widths)
            for (_, fa, _), a in zip(prepared, assigns)]
    fb = FlowBatch.stack([fa for _, fa, _ in prepared])
    caps = [_seg_caps(tl, boundaries) for _, _, tl in prepared]
    up = np.stack([u for u, _, _ in caps])
    down = np.stack([d for _, d, _ in caps])
    acc = np.stack([a for _, _, a in caps])
    seg_id = _seg_id(boundaries, cfg.slots)
    aggs_b = _AggPerms(*(np.stack(col) for col in zip(*aggs)))
    args = [fb, up, down, acc, np.stack(assigns), aggs_b]
    B = len(points)
    n_dev = len(jax.devices())
    shards = min(B, n_dev) if n_dev > 1 and B > 1 else 1
    if shards > 1:
        padded = -B % shards

        def shape(a):
            if padded:
                a = np.concatenate(
                    [np.asarray(a),
                     np.repeat(np.asarray(a)[-1:], padded, 0)])
            return np.asarray(a).reshape(
                (shards, (B + padded) // shards) + np.shape(a)[1:])

        args = [jax.tree_util.tree_map(shape, a) for a in args]
    out = _jitted(cfg, True, shards)(*args, seg_id)
    # keep only what finalize needs — dropping the dense per-point
    # timelines here frees O(B*T*fabric) host memory while the batch
    # computes
    return cfg, [fa for _, fa, _ in prepared], shards, out


def finalize_batch(handle) -> List[JxSimResult]:
    """Block on a `dispatch_compiled_batch` handle and unpack per-point
    results (dropping any pmap padding)."""
    cfg, fas, shards, out = handle
    outs = [np.asarray(o) for o in out]
    if shards > 1:
        outs = [o.reshape((-1,) + o.shape[2:]) for o in outs]
    return [_wrap(cfg, fa, [o[b] for o in outs])
            for b, fa in enumerate(fas)]


def run_compiled_batch(points: List) -> List[JxSimResult]:
    """Simulate a batch of `CompiledScenario`s that share structure as
    one batched (vmap, pmap-sharded when multiple host devices exist)
    computation — the JAX replacement for the process-pool sweep."""
    return finalize_batch(dispatch_compiled_batch(points))
