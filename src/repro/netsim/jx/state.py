"""Pytree carries for the JAX engine.

`NamedTuple`s so everything is a pytree for free: `FlowBatch` is the
static flow population (one leading batch axis when vmapped), `NicCarry`
mirrors `netsim.cc.NicState`'s mutable arrays, and `SimCarry` is the full
`lax.scan` carry — fabric queues, NIC state, transfer progress, and the
post-warmup goodput accumulator that replaces the NumPy backend's dense
`(T, F)` recording.
"""
from __future__ import annotations

from typing import List, NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from repro.netsim.fabric import FlowArrays


class FlowBatch(NamedTuple):
    src: jnp.ndarray           # (F,) int
    dst: jnp.ndarray           # (F,) int
    src_leaf: jnp.ndarray      # (F,) int
    dst_leaf: jnp.ndarray      # (F,) int
    demand: jnp.ndarray        # (F,) float
    bytes_total: jnp.ndarray   # (F,) float (inf = open-loop)
    start_slot: jnp.ndarray    # (F,) int
    same_leaf: jnp.ndarray     # (F,) bool
    phase: jnp.ndarray         # (F,) int demand-timeline lane

    @classmethod
    def from_arrays(cls, fa: FlowArrays) -> "FlowBatch":
        return cls(
            src=jnp.asarray(fa.src), dst=jnp.asarray(fa.dst),
            src_leaf=jnp.asarray(fa.src_leaf),
            dst_leaf=jnp.asarray(fa.dst_leaf),
            demand=jnp.asarray(fa.demand),
            bytes_total=jnp.asarray(fa.bytes_total),
            start_slot=jnp.asarray(fa.start_slot),
            same_leaf=jnp.asarray(fa.src_leaf == fa.dst_leaf),
            phase=jnp.asarray(fa.phase))

    @classmethod
    def stack(cls, fas: List[FlowArrays]) -> "FlowBatch":
        """(B, F) batch for `vmap` — flow counts must match (they do for
        grid points of one scenario: only seeds differ, not structure)."""
        cols = {
            "src": [fa.src for fa in fas],
            "dst": [fa.dst for fa in fas],
            "src_leaf": [fa.src_leaf for fa in fas],
            "dst_leaf": [fa.dst_leaf for fa in fas],
            "demand": [fa.demand for fa in fas],
            "bytes_total": [fa.bytes_total for fa in fas],
            "start_slot": [fa.start_slot for fa in fas],
            "same_leaf": [fa.src_leaf == fa.dst_leaf for fa in fas],
            "phase": [fa.phase for fa in fas],
        }
        return cls(**{k: jnp.asarray(np.stack(v))
                      for k, v in cols.items()})


class NicCarry(NamedTuple):
    rate: jnp.ndarray          # (F, P) allowances
    alpha: jnp.ndarray         # (F, P) dcqcn alpha
    probe_miss: jnp.ndarray    # (F, P) int
    eligible: jnp.ndarray      # (F, P) bool
    pending_fail: jnp.ndarray  # (F, P) int (swlb delayed reaction)


class SimCarry(NamedTuple):
    """Stage-A queues (`q_up`/`q_down`) are leaf↔spine on leaf_spine
    and leaf↔agg on fat_tree; stage-B queues (`q2_up`/`q2_down`) are
    the fat-tree pod↔core tier — (P, 1, 1) placeholders on leaf_spine,
    never read there (the topology kind is static at trace time)."""
    q_up: jnp.ndarray          # (P, L, S|A) queue, slot*cap units
    q_down: jnp.ndarray        # (P, S|A, L)
    q2_up: jnp.ndarray         # (P, pods, C) fat_tree; (P, 1, 1) else
    q2_down: jnp.ndarray       # (P, pods, C) fat_tree; (P, 1, 1) else
    nic: NicCarry
    remaining: jnp.ndarray     # (F,)
    done: jnp.ndarray          # (F,) bool
    completion: jnp.ndarray    # (F,) int, -1 = unfinished
    goodput_sum: jnp.ndarray   # (F,) sum of achieved over counted frames
    util_up: jnp.ndarray       # (P, L, S|A) last slot's uplink utilization


def stage_shapes(cfg) -> Tuple[Tuple[int, int, int], Tuple[int, int, int]]:
    """((P, L, n_up), (P, pods_b, cores_b)) queue/capacity shapes for a
    `JxConfig`-like object — the single source of truth both backends'
    carry builders use."""
    P, L = cfg.n_planes, cfg.n_leaves
    if cfg.kind == "fat_tree":
        return (P, L, cfg.n_aggs), (P, cfg.n_pods, cfg.n_cores)
    return (P, L, cfg.n_spines), (P, 1, 1)


def probe_miss_dtype(cfg, float_dtype) -> jnp.dtype:
    """int8 under the compact carry (float32 runs only — the probe
    counter saturates at `probe_timeout`, far inside int8 range); the
    default integer width otherwise.  Shared by `init_carry` and the
    megabatch host-side carry builder."""
    if (getattr(cfg, "compact_carry", False)
            and jnp.dtype(float_dtype) == jnp.float32):
        return jnp.dtype(jnp.int8)
    return jnp.asarray(np.int64(0)).dtype


def init_carry(fb: FlowBatch, cfg) -> SimCarry:
    F = fb.src.shape[0]
    (P, L, U), b_shape = stage_shapes(cfg)
    dtype = jnp.asarray(0.0).dtype          # float64 iff x64 enabled
    itype = jnp.asarray(np.int64(0)).dtype
    nic = NicCarry(
        rate=jnp.ones((F, P), dtype),
        alpha=jnp.zeros((F, P), dtype),
        probe_miss=jnp.zeros((F, P), probe_miss_dtype(cfg, dtype)),
        eligible=jnp.ones((F, P), bool),
        pending_fail=jnp.zeros((F, P), itype))
    return SimCarry(
        q_up=jnp.zeros((P, L, U), dtype),
        q_down=jnp.zeros((P, U, L), dtype),
        q2_up=jnp.zeros(b_shape, dtype),
        q2_down=jnp.zeros(b_shape, dtype),
        nic=nic,
        remaining=fb.bytes_total.astype(dtype),
        done=jnp.zeros(F, bool),
        completion=jnp.full(F, -1, itype),
        goodput_sum=jnp.zeros(F, dtype),
        util_up=jnp.zeros((P, L, U), dtype))
