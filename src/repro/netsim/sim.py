"""Simulation runner: topology + flows + NIC stack + events -> metrics."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.trace import TraceSpec

from .cc import NicState
from .fabric import Flow, FlowArrays, FluidFabric
from .topology import Fabric, LeafSpine


@dataclass
class SimConfig:
    slots: int = 2000
    slot_us: float = 10.0
    routing: str = "ar"          # 'ar' | 'war' | 'ecmp'
    nic: str = "spx"             # 'spx' | 'dcqcn' | 'global' | 'esr' | 'swlb'
    base_rtt_us: float = 4.0
    warmup_frac: float = 0.25
    sw_lb_delay_ms: float = 1000.0
    seed: int = 0
    record_every: int = 1
    backend: str = "numpy"       # 'numpy' | 'jax' (see repro.netsim.jx)
    trace: TraceSpec = TraceSpec()

    def sw_lb_delay_slots(self) -> int:
        """swlb reaction delay in slots (0 for hardware-PLB stacks) —
        shared by both backends so the conversion cannot drift."""
        return (int(self.sw_lb_delay_ms * 1000 / self.slot_us)
                if self.nic == "swlb" else 0)


@dataclass
class SimResult:
    goodput: np.ndarray          # (T_rec, F) achieved per flow over time
    rtt: np.ndarray              # (T_rec, F) mean-plane rtt proxy
    completion_slot: np.ndarray  # (F,) slot when bytes_total finished
    mean_goodput: np.ndarray     # (F,) post-warmup average
    util_up_last: np.ndarray
    groups: List[str]
    group_of: np.ndarray
    slot_us: float
    trace: Optional[Dict[str, np.ndarray]] = None
    # (slots,) bytes/slot offered onto physically-dead fabric paths —
    # populated only when a failure-reaction spec is active (None keeps
    # no-reaction runs byte-identical to the pre-reaction engine)
    blackhole_timeline: Optional[np.ndarray] = None

    def group_mean(self, group: str) -> float:
        gi = self.groups.index(group)
        return float(self.mean_goodput[self.group_of == gi].mean())

    @property
    def total_goodput(self) -> np.ndarray:
        """(T_rec,) goodput summed over flows — the field shared with the
        JAX backend's `JxSimResult` (which never materializes `goodput`)."""
        return self.goodput.sum(1)


def rehash_dead_assign(alive: np.ndarray, assign: np.ndarray,
                       rng: np.random.Generator, n_spines: int
                       ) -> np.ndarray:
    """Re-hash ECMP assignments whose path died onto a surviving path
    (`n_spines` is the path-axis size: spines on leaf_spine, cores on
    fat_tree).

    `alive`: (F, P, J) path liveness; `assign`: (F, P) current path per
    (flow, plane).  Draws from `rng` only when some assignment is dead
    with an alive alternative — the JAX backend's host-side replay
    (`netsim.jx.events.ecmp_assign_segments`) shares this function so
    both backends consume the RNG stream draw-for-draw."""
    cur = np.take_along_axis(alive, assign[:, :, None], axis=2)[:, :, 0]
    bad = ~cur & alive.any(-1)
    if bad.any():
        # deterministic re-hash: first alive spine after a seeded offset
        off = rng.integers(0, n_spines, size=assign.shape)
        order = (off[:, :, None] + np.arange(n_spines)[None, None]) \
            % n_spines
        alive_ord = np.take_along_axis(alive, order, axis=2)
        first = np.argmax(alive_ord, axis=2)
        new = np.take_along_axis(order, first[:, :, None],
                                 axis=2)[:, :, 0]
        assign = np.where(bad, new, assign)
    return assign


def backup_reassign(alive: np.ndarray, assign: np.ndarray,
                    backup: np.ndarray) -> np.ndarray:
    """Fast-reroute: walk each dead assignment down the precomputed
    backup chain (`backup[j]` = successor path of j, a single J-cycle —
    see `topology.backup_path_table`) to the first alive path.  RNG-free
    and deterministic, so the JAX backend's host-side boundary replay
    shares this function exactly like `rehash_dead_assign`.

    `alive`: (F, P, J) path liveness as *routing* sees it; `assign`:
    (F, P).  Entries whose whole path axis is dead keep their
    assignment (same contract as the re-hash)."""
    cur = np.take_along_axis(alive, assign[:, :, None], axis=2)[:, :, 0]
    bad = ~cur & alive.any(-1)
    if not bad.any():
        return assign
    new = assign.copy()
    for _ in range(alive.shape[-1] - 1):
        dead_now = ~np.take_along_axis(alive, new[:, :, None],
                                       axis=2)[:, :, 0]
        step = bad & dead_now
        if not step.any():
            break
        new = np.where(step, backup[new], new)
    return np.where(bad, new, assign)


def run_sim(topo: Fabric, flows: List[Flow], cfg: SimConfig,
            events: Optional[Callable[[int, Fabric], None]] = None,
            phase_mult: Optional[np.ndarray] = None,
            reaction=None, vis_topo: Optional[Fabric] = None,
            vis_events: Optional[Callable[[int, Fabric], None]] = None,
            backup: Optional[np.ndarray] = None,
            ) -> SimResult:
    """`phase_mult`: optional (slots, K) demand-multiplier timeline; each
    flow's offered demand is scaled by `phase_mult[t, flow.phase]` — the
    schedule-workload lane (lane 0 is the always-1.0 lane by
    convention).

    Failure reaction (`reaction` = a `scenarios.spec.ReactionSpec`):
    routing steers against `vis_topo`, a second pristine fabric copy
    that replays `vis_events` lagged by `reaction_lag` slots — so a dead
    link keeps attracting traffic (tracked per slot in
    `blackhole_timeline`) until detection (+ convergence, mode='rehash')
    fires.  ECMP mode='backup' swaps the seeded re-hash for the RNG-free
    `backup_reassign` chain walk over `backup`.  `reaction=None` leaves
    every code path bit-identical to the pre-reaction engine."""
    from repro.scenarios.spec import reaction_lag
    rng = np.random.default_rng(cfg.seed)
    fa = FlowArrays.build(flows, topo)
    F, P, J = len(fa), topo.n_planes, topo.n_paths
    react = reaction is not None and reaction.enabled
    lag = reaction_lag(reaction, cfg.routing) if react else 0
    rt = vis_topo if (react and lag > 0 and vis_topo is not None) \
        else topo
    fabric = FluidFabric(topo, base_rtt_us=cfg.base_rtt_us,
                         slot_us=cfg.slot_us,
                         route_topo=rt if rt is not topo else None)
    nic = NicState(
        mode=cfg.nic, n_flows=F, n_planes=P,
        sw_lb_delay_slots=cfg.sw_lb_delay_slots())

    # ECMP static assignment: one path per (flow, plane) — a spine on
    # leaf_spine, an (agg, core) tuple on fat_tree, where the canonical
    # wiring makes the core index determine the agg on both ends so the
    # hash is a single draw over [0, n_paths).  Routing withdraws dead
    # paths (slow control plane), so flows whose assigned path died are
    # re-hashed onto survivors — ECMP's problem is imbalance, not
    # black-holing.
    assign = rng.integers(0, J, size=(F, P))

    def _rehash_dead(assign):
        # liveness as *routing* sees it (rt lags physical under a
        # reaction spec; identical to physical otherwise)
        cap = rt.path_capacity(fa.src_leaf, fa.dst_leaf)      # (F, P, J)
        if react and reaction.mode == "backup":
            return backup_reassign(cap > 1e-12, assign, backup)
        return rehash_dead_assign(cap > 1e-12, assign, rng, J)
    remaining = fa.bytes_total.copy()
    done = np.zeros(F, bool)
    completion = np.full(F, -1, np.int64)

    tr = cfg.trace
    rec_tr: Dict[str, list] = ({f: [] for f in tr.active_fields()}
                               if tr.enabled else {})
    n_hosts = topo.access.shape[1]

    bh_tl = np.zeros(cfg.slots) if react else None
    rec_g, rec_r = [], []
    for t in range(cfg.slots):
        if events is not None:
            events(t, topo)
        if rt is not topo and t >= lag and vis_events is not None:
            # the visible fabric replays the same (pure, seeded) event
            # closures `lag` slots late
            vis_events(t - lag, rt)
        demand = np.where(done | (t < fa.start_slot), 0.0, fa.demand)
        if phase_mult is not None:
            demand = demand * phase_mult[t, fa.phase]
        offered = nic.plane_split(demand)
        pair = None
        if cfg.routing == "ecmp":
            assign = _rehash_dead(assign)
            frac = fabric.ecmp_fractions(fa, assign)
        else:
            rw = None
            if cfg.routing == "war":
                # remote weight = normalized healthy down-capacity
                # (stage-composed on fat_tree)
                rw = fabric.remote_weights()
            pair = fabric.pair_fractions("war" if rw is not None else "ar",
                                         rw)
            frac = pair[:, fa.src_leaf, fa.dst_leaf, :].transpose(1, 0, 2)
        if react:
            # black-holed bytes: fabric traffic routed onto paths that
            # are physically dead (routing hasn't seen the failure yet)
            dead = topo.path_capacity(fa.src_leaf,
                                      fa.dst_leaf) <= 1e-12    # (F, P, J)
            fr = np.where((fa.src_leaf == fa.dst_leaf)[:, None],
                          0.0, offered)
            bh_tl[t] = (fr[:, :, None] * frac * dead).sum()
        res = fabric.step(fa, offered, frac, pair=pair)
        # RTT probes: a plane is reachable iff both endpoints' access links
        # on that plane are up (probes run independently of data traffic)
        probe_ok = ((topo.access.T[fa.src] > 1e-12) &
                    (topo.access.T[fa.dst] > 1e-12))          # (F, P)
        nic.update(offered, res.plane_rates, res.rtt, res.ecn, t,
                   probe_ok=probe_ok)
        # Packet-loss stall: while a plane carries offered traffic but
        # delivers nothing (undetected failure), in-order completion of the
        # whole transfer stalls on lost packets (§2.2 blast radius).  The
        # stall clears once the PLB stops offering to that plane.
        stalled = ((offered > 1e-9) & (res.plane_rates <= 1e-9)).any(1)
        res.achieved = np.where(stalled, 0.0, res.achieved)

        remaining = remaining - res.achieved
        newly = (~done) & (remaining <= 0)
        # the last packet drains behind the path queues: completion is
        # delayed by the queuing delay at finish time (in slots)
        w = np.maximum(offered, 1e-12)
        qdelay = (((res.rtt * w).sum(1) / w.sum(1)) -
                  cfg.base_rtt_us) / cfg.slot_us
        completion[newly] = t + np.ceil(qdelay[newly]).astype(np.int64)
        done |= newly

        if t % cfg.record_every == 0:
            rec_g.append(res.achieved.copy())
            w = np.maximum(offered, 1e-12)
            rec_r.append((res.rtt * w).sum(1) / w.sum(1))

        if tr.enabled and t % tr.every == 0:
            # Mirrors the jx engine's per-slot trace outputs exactly
            # (pinned by tests/test_trace.py parity).
            if "host_bw" in rec_tr:
                hb = np.zeros((n_hosts, P))
                np.add.at(hb, fa.src,
                          np.where(stalled[:, None], 0.0,
                                   res.plane_rates))
                rec_tr["host_bw"].append(hb)
            if "util" in rec_tr:
                rec_tr["util"].append(res.util_up.copy())
            if "queue" in rec_tr:
                rec_tr["queue"].append(fabric.state.q_up.copy())
            if "ecn" in rec_tr:
                rec_tr["ecn"].append(res.ecn.copy())
            if "eligible" in rec_tr:
                rec_tr["eligible"].append(nic.eligible.copy())

    goodput = np.asarray(rec_g)
    rtt = np.asarray(rec_r)
    w0 = int(goodput.shape[0] * cfg.warmup_frac)
    return SimResult(
        goodput=goodput, rtt=rtt, completion_slot=completion,
        mean_goodput=goodput[w0:].mean(0) if goodput.shape[0] > w0
        else goodput.mean(0),
        util_up_last=res.util_up, groups=fa.groups, group_of=fa.group,
        slot_us=cfg.slot_us,
        trace=({"slot": tr.recorded_slots(cfg.slots),
                **{k: np.asarray(v) for k, v in rec_tr.items()}}
               if tr.enabled else None),
        blackhole_timeline=bh_tl)
