"""The LM: embeddings -> prefix layers -> scanned pattern periods -> head.

Layer parameters of the repeated ``block_pattern`` are stacked over periods
and consumed by ``lax.scan`` so the lowered HLO is O(pattern) rather than
O(n_layers) — essential for the 512-device AOT dry-run of 48–60-layer
configs.  Cross-entropy is computed in sequence chunks so (B, S, vocab)
logits are never materialized (gemma3's 262k vocab at 4k tokens would be
multiple GiB per device otherwise).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (array_builder, axes_builder, embed_tokens, init_embed,
                     lm_logits, rms_norm, softcap)
from .blocks import apply_block, init_block, init_block_cache
from ..parallel.sharding import (ShardCtx, local_ctx, shard_cache,
                                 shard_logits, shard_residual)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stacked_builder(make, n: int):
    def smake(name, shape, axes, scale):
        return make(name, (n,) + tuple(shape), ("layers",) + tuple(axes),
                    scale)
    return smake


def _init_tree(make, cfg: ModelConfig) -> Dict:
    p: Dict = {"embed": init_embed(make, cfg.vocab, cfg.d_model,
                                   cfg.tie_embeddings),
               "final_ln": make("final_ln", (cfg.d_model,), ("embed",), 0.0)}
    p["prefix"] = [
        init_block(make, cfg, "a", False, f"prefix{i}")
        for i in range(cfg.n_prefix_layers)
    ]
    smake = _stacked_builder(make, cfg.n_periods)
    p["period"] = [
        init_block(smake, cfg, kind, cfg.is_moe_pos(pos), f"pat{pos}")
        for pos, kind in enumerate(cfg.block_pattern)
    ]
    if cfg.frontend != "none":
        p["frontend_proj"] = make("frontend_proj",
                                  (cfg.d_model, cfg.d_model),
                                  ("embed", "embed2"), 1.0)
    return p


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    return _init_tree(array_builder(rng, dtype), cfg)


def logical_axes(cfg: ModelConfig) -> Dict:
    return _init_tree(axes_builder(), cfg)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    """Cache pytree: prefix list + per-pattern-position stacked caches."""
    caches: Dict = {
        "prefix": [init_block_cache(cfg, "a", batch, max_len, dtype)
                   for _ in range(cfg.n_prefix_layers)],
        "period": [],
    }
    for pos, kind in enumerate(cfg.block_pattern):
        one = init_block_cache(cfg, kind, batch, max_len, dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), one)
        caches["period"].append(stacked)
    return caches


def shard_caches(caches: Dict, ctx: ShardCtx) -> Dict:
    def f(x):
        if x.ndim >= 3:
            return shard_cache(x, ctx, kv_heads_axis=x.ndim - 2)
        return x
    return jax.tree.map(f, caches)


def param_count(params: Dict) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    if cfg.remat == "kv":
        # save the all-gathered K/V (tagged in attention.py) so the
        # backward pass does not re-gather them over the model axis
        pol = jax.checkpoint_policies.save_only_these_names("kv_gathered")
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def backbone(params: Dict, cfg: ModelConfig, x: jax.Array,
             positions: jax.Array, ctx: ShardCtx,
             caches: Optional[Dict] = None,
             ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """x: (B,S,d) embedded input. Returns (hidden, caches', aux)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix = []
    for i, bp in enumerate(params["prefix"]):
        c = caches["prefix"][i] if caches is not None else None
        x, c, aux = apply_block(bp, cfg, x, positions, "a", False, ctx, c)
        aux_total += aux
        new_prefix.append(c)

    def period_core(carry, pparams, pcaches):
        x, aux_acc = carry
        new_caches = []
        for pos, kind in enumerate(cfg.block_pattern):
            c = pcaches[pos] if pcaches is not None else None
            x, c, aux = apply_block(pparams[pos], cfg, x, positions, kind,
                                    cfg.is_moe_pos(pos), ctx, c)
            aux_acc = aux_acc + aux
            new_caches.append(c)
        return (x, aux_acc), new_caches

    pcaches = caches["period"] if caches is not None else None
    n_periods = cfg.n_periods
    if not cfg.scan_layers:
        # Unrolled stack (exact per-layer HLO accounting for the dry-run
        # roofline; lax.scan bodies are counted once by cost_analysis).
        body = _remat_wrap(lambda c, xs: period_core(c, xs[0], xs[1]), cfg)
        period_outs = []
        for i in range(n_periods):
            pp = jax.tree.map(lambda a: a[i], params["period"])
            pc = (jax.tree.map(lambda a: a[i], pcaches)
                  if pcaches is not None else None)
            (x, aux_total), nc = body((x, aux_total), (pp, pc))
            period_outs.append(nc)
        new_caches = None
        if pcaches is not None:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *period_outs)
            new_caches = {"prefix": new_prefix, "period": stacked}
    elif pcaches is None:
        body = _remat_wrap(
            lambda c, pp: (period_core(c, pp, None)[0],
                           jnp.zeros((), jnp.int32)), cfg)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params["period"])
        new_caches = None
    else:
        body = _remat_wrap(
            lambda c, xs: period_core(c, xs[0], xs[1]), cfg)
        (x, aux_total), ys = jax.lax.scan(
            body, (x, aux_total), (params["period"], pcaches))
        new_caches = {"prefix": new_prefix, "period": ys}
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, new_caches, aux_total


def embed_input(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                ctx: ShardCtx,
                frontend_embeds: Optional[jax.Array] = None) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"], tokens, dtype)
    if frontend_embeds is not None and cfg.frontend != "none":
        fe = jnp.einsum("bfd,de->bfe", frontend_embeds.astype(dtype),
                        params["frontend_proj"].astype(dtype))
        f = fe.shape[1]
        x = jnp.concatenate([fe, x[:, f:]], axis=1)
    return shard_residual(x, ctx)


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def chunked_ce_loss(params: Dict, cfg: ModelConfig, hidden: jax.Array,
                    labels: jax.Array, mask: jax.Array, ctx: ShardCtx,
                    chunk: int = 0) -> jax.Array:
    """Next-token CE without materializing full (B,S,V) logits."""
    B, S, D = hidden.shape
    chunk = min(chunk or cfg.loss_chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    hs = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    def body(acc, xs):
        h, l, m = xs
        logits = lm_logits(params["embed"], h, jnp.dtype(cfg.dtype),
                           cfg.logit_softcap)
        logits = shard_logits(logits, ctx)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * m
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(m)), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if cfg.unroll_loops or nc == 1:
        acc = init
        for i in range(nc):
            acc, _ = body(acc, (hs[i], ls[i], ms[i]))
        tot, cnt = acc
    else:
        (tot, cnt), _ = jax.lax.scan(body, init, (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict, ctx: ShardCtx,
            aux_weight: float = 0.01) -> Tuple[jax.Array, Dict]:
    tokens = batch["tokens"]
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    else:
        mask = mask.astype(jnp.float32)
    if cfg.frontend != "none" and cfg.frontend_tokens:
        fmask = jnp.ones_like(mask).at[:, :cfg.frontend_tokens].set(0.0)
        mask = mask * fmask
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed_input(params, cfg, tokens, ctx,
                    batch.get("frontend_embeds"))
    hidden, _, aux = backbone(params, cfg, x, positions, ctx)
    ce = chunked_ce_loss(params, cfg, hidden, labels, mask, ctx)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


def prefill_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                 ctx: ShardCtx, caches: Dict,
                 frontend_embeds: Optional[jax.Array] = None,
                 ) -> Tuple[jax.Array, Dict]:
    """Process a full prompt, fill caches, return last-token logits."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed_input(params, cfg, tokens, ctx, frontend_embeds)
    hidden, caches, _ = backbone(params, cfg, x, positions, ctx, caches)
    last = hidden[:, -1:]
    logits = lm_logits(params["embed"], last, jnp.dtype(cfg.dtype),
                       cfg.logit_softcap)
    return shard_logits(logits, ctx), caches


def decode_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                position: jax.Array, ctx: ShardCtx, caches: Dict,
                ) -> Tuple[jax.Array, Dict]:
    """One token per sequence. tokens: (B,1); position: (B,) int32."""
    B = tokens.shape[0]
    positions = position[:, None].astype(jnp.int32)
    x = embed_input(params, cfg, tokens, ctx)
    hidden, caches, _ = backbone(params, cfg, x, positions, ctx, caches)
    logits = lm_logits(params["embed"], hidden, jnp.dtype(cfg.dtype),
                       cfg.logit_softcap)
    return shard_logits(logits, ctx), caches
