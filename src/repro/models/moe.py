"""Mixture-of-Experts with expert parallelism.

Two execution modes, both built on the same local dispatch/combine math:

* **a2a mode** (train / prefill, sequence-parallel residual): tokens are
  sharded over every mesh axis; dispatch buffers are exchanged with
  ``lax.all_to_all`` over the tensor axis so each device runs only its local
  experts — the All2All traffic pattern of the paper's evaluation.
* **psum mode** (decode, sequence replicated over tp): each tp shard runs its
  local experts over the full (tiny) token set and contributions are summed
  with ``lax.psum`` — gather-free EP.

Without a mesh the same functions run locally (smoke tests / oracles).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import Builder, act_fn, init_mlp, apply_mlp
from ..parallel.sharding import ShardCtx


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_moe(make: Builder, cfg: ModelConfig, prefix: str) -> Dict:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    p = {
        "router": make(f"{prefix}.router", (d, e), ("embed", "experts"), 1.0),
        # expert weights: contracted dims stay UNSHARDED over the FSDP
        # axis (embed_e -> None); the FFN dim shards over data (mlp_e) —
        # output-dim sharding needs no gather at the shard_map boundary,
        # unlike contraction-dim FSDP which all-gathers the full bank.
        "wi": make(f"{prefix}.wi", (e, d, f),
                   ("experts", "embed_e", "mlp_e"), 1.0),
        "wg": make(f"{prefix}.wg", (e, d, f),
                   ("experts", "embed_e", "mlp_e"), 1.0),
        "wo": make(f"{prefix}.wo", (e, f, d),
                   ("experts", "mlp_e", "embed_e"), 1.0),
    }
    if cfg.moe_shared:
        p["shared"] = init_mlp(make, d, cfg.moe_shared * f,
                               f"{prefix}.shared")
    return p


# ---------------------------------------------------------------------------
# local dispatch / combine
# ---------------------------------------------------------------------------

def _topk_route(router_w, x_flat, cfg: ModelConfig):
    """x_flat: (T, d) -> (weights (T,k), experts (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_topk)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    w = w * cfg.router_scale
    # Switch-style load-balance aux loss
    e = cfg.moe_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)
    return w.astype(x_flat.dtype), idx, aux


def _ranks_within_expert(eids: jax.Array, n_experts: int) -> jax.Array:
    """eids: flat (N,) expert ids -> arrival rank of each entry within its
    expert (stable order)."""
    n = eids.shape[0]
    order = jnp.argsort(eids, stable=True)
    sorted_e = eids[order]
    start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - start.astype(jnp.int32)
    return jnp.zeros(n, jnp.int32).at[order].set(rank_sorted)


def _dispatch(x_flat, eids, ranks, n_experts, capacity):
    """Scatter tokens into (E, C, d) buffers; overflow tokens dropped.

    f32 accumulator: the scatter's cross-shard combine lowers to an
    all-reduce whose dtype follows the operand; bf16 ARs crash XLA:CPU's
    AllReducePromotion pass. Cast back after — the a2a moves bf16."""
    t, d = x_flat.shape
    k = eids.shape[-1]
    flat_e = eids.reshape(-1)
    flat_r = ranks.reshape(-1)
    valid = flat_r < capacity
    src = jnp.repeat(x_flat.astype(jnp.float32), k, axis=0)
    src = jnp.where(valid[:, None], src, 0)
    buf = jnp.zeros((n_experts, capacity, d), jnp.float32)
    buf = buf.at[flat_e, jnp.minimum(flat_r, capacity - 1)].add(src)
    return buf.astype(x_flat.dtype)


def _combine(buf, weights, eids, ranks, capacity):
    """Gather expert outputs back per (token, k) and weight-sum."""
    t, k = eids.shape
    flat_e = eids.reshape(-1)
    flat_r = ranks.reshape(-1)
    valid = (flat_r < capacity).astype(buf.dtype)
    got = buf[flat_e, jnp.minimum(flat_r, capacity - 1)]      # (t*k, d)
    got = got * valid[:, None]
    got = got.reshape(t, k, -1)
    return jnp.einsum("tkd,tk->td", got, weights.astype(buf.dtype))


def _expert_ffn(p: Dict, buf: jax.Array, act: str, e_slice=None):
    """buf: (E_loc, C, d) -> (E_loc, C, d) through gated FFN.

    f32 ACCUMULATION on every contraction: keeps the FSDP partial-sum
    all-reduces (fwd and weight-grad bwd) in f32 — bf16 ARs crash XLA:CPU's
    AllReducePromotion — while weights/activations stay bf16 on the wire."""
    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    if e_slice is not None:
        wi, wg, wo = wi[e_slice], wg[e_slice], wo[e_slice]
    dt = buf.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    h = act_fn(act)(g) * h
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(dt),
                      preferred_element_type=jnp.float32).astype(dt)


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens * cfg.moe_topk / cfg.moe_experts
                      * cfg.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)


# ---------------------------------------------------------------------------
# the three execution modes
# ---------------------------------------------------------------------------

def _moe_local(p, cfg: ModelConfig, x, tp_axis: Optional[str],
               ep_mode: str, pmean_axes: Tuple[str, ...] = ()):
    """Per-device MoE body. tp_axis is None when run without a mesh."""
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    w, idx, aux = _topk_route(p["router"], x_flat, cfg)
    ranks = _ranks_within_expert(idx.reshape(-1),
                                 cfg.moe_experts).reshape(idx.shape)
    cap = _capacity(b * s, cfg)
    if pmean_axes:
        aux = jax.lax.pmean(aux, pmean_axes)

    if tp_axis is None or ep_mode == "none":
        buf = _dispatch(x_flat, idx, ranks, cfg.moe_experts, cap)
        buf = _expert_ffn(p, buf, cfg.act)
        out = _combine(buf, w, idx, ranks, cap)
        return out.reshape(b, s, d), aux

    m = jax.lax.axis_size(tp_axis)
    e_loc = cfg.moe_experts // m

    if ep_mode == "a2a":
        buf = _dispatch(x_flat, idx, ranks, cfg.moe_experts, cap)
        # (E, C, d) -> (E/m, m*C, d): exchange expert dim over tp peers
        buf = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=1,
                                 tiled=True)
        buf = _expert_ffn(p, buf, cfg.act)     # weights arrive as local E/m
        buf = jax.lax.all_to_all(buf, tp_axis, split_axis=1, concat_axis=0,
                                 tiled=True)
        out = _combine(buf, w, idx, ranks, cap)
        return out.reshape(b, s, d), aux

    if ep_mode == "psum":
        mi = jax.lax.axis_index(tp_axis)
        e0 = mi * e_loc
        local = idx - e0
        in_range = (local >= 0) & (local < e_loc)
        local_ids = jnp.where(in_range, local, 0)
        local_ranks = jnp.where(in_range, ranks, cap)   # force-drop remote
        buf = _dispatch(x_flat, local_ids, local_ranks, e_loc, cap)
        buf = _expert_ffn(p, buf, cfg.act)
        out = _combine(buf, w * in_range.astype(w.dtype),
                       local_ids, local_ranks, cap)
        # f32 all-reduce: bf16 ARs trip XLA:CPU's AllReducePromotion pass,
        # and f32 accumulation is the right numeric anyway.
        out = jax.lax.psum(out.astype(jnp.float32), tp_axis)
        out = out.astype(x.dtype)
        return out.reshape(b, s, d), aux

    raise ValueError(ep_mode)


def apply_moe(p: Dict, cfg: ModelConfig, x: jax.Array, ctx: ShardCtx,
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) residual-sharded. Returns (out, aux_loss)."""
    b, s, d = x.shape
    shared_out = None
    if "shared" in p:
        shared_out = apply_mlp(p["shared"], x, cfg.act, x.dtype)

    if ctx.mesh is None:
        out, aux = _moe_local(p, cfg, x, None, "none")
    else:
        # Manual ONLY over the tensor axis: DP axes stay automatic, so this
        # region nests cleanly inside the dp-manual train-step shard_map.
        tp = ctx.tp_axis
        m = ctx.tp_size
        seq_ok = ctx.seq_sharded and s % m == 0 and s >= m
        ep_mode = "a2a" if seq_ok else "psum"
        if cfg.moe_experts % m:
            ep_mode = "none"        # cannot shard experts; run replicated
        seq_spec = tp if seq_ok else None
        x_spec = P(None, seq_spec, None)
        router_spec = P(None, None)
        ew_spec = P(tp, None, None) if ep_mode != "none" else P(None, None,
                                                                None)
        in_specs = ({"router": router_spec, "wi": ew_spec, "wg": ew_spec,
                     "wo": ew_spec}, x_spec)
        routed = {k: p[k] for k in ("router", "wi", "wg", "wo")}
        # Inside an outer (dp-manual) shard_map the context mesh must be
        # used; at top level we pass the concrete mesh explicitly.
        ambient = jax.sharding.get_abstract_mesh()
        mesh_arg = None if not ambient.empty else ctx.mesh
        out, aux = jax.shard_map(
            lambda pp, xx: _moe_local(pp, cfg, xx, tp, ep_mode, (tp,)),
            mesh=mesh_arg, in_specs=in_specs, out_specs=(x_spec, P()),
            axis_names={tp}, check_vma=False)(routed, x)

    if shared_out is not None:
        out = out + shared_out
    return out, aux
