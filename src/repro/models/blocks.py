"""Transformer / hybrid block composition.

A block = pre-norm mixer (attention | MLA | mamba) + pre-norm FFN
(dense | MoE), both with residual connections.  The block kind is a token
from ``cfg.block_pattern``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Builder, init_mlp, apply_mlp, rms_norm
from .attention import (apply_attn, apply_mla, init_attn, init_mla,
                        init_kv_cache, init_mla_cache)
from .moe import apply_moe, init_moe
from .ssm import apply_mamba, init_mamba, init_ssm_cache
from ..parallel.sharding import ShardCtx, shard_residual


def init_block(make: Builder, cfg: ModelConfig, kind: str, moe: bool,
               prefix: str) -> Dict:
    p: Dict = {
        "ln1": make(f"{prefix}.ln1", (cfg.d_model,), ("embed",), 0.0),
        "ln2": make(f"{prefix}.ln2", (cfg.d_model,), ("embed",), 0.0),
    }
    if kind == "m":
        p["mixer"] = init_mamba(make, cfg, f"{prefix}.mamba")
    elif cfg.use_mla:
        p["mixer"] = init_mla(make, cfg, f"{prefix}.mla")
    else:
        p["mixer"] = init_attn(make, cfg, f"{prefix}.attn")
    if moe:
        p["mlp"] = init_moe(make, cfg, f"{prefix}.moe")
    elif cfg.d_ff > 0:
        p["mlp"] = init_mlp(make, cfg.d_model, cfg.d_ff, f"{prefix}.mlp",
                            cfg.gated_mlp)
    else:
        del p["ln2"]            # mixer-only block (mamba2)
    return p


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype) -> Dict:
    if kind == "m":
        return init_ssm_cache(cfg, batch, dtype)
    if cfg.use_mla:
        return init_mla_cache(cfg, batch, max_len, dtype)
    return init_kv_cache(cfg, batch, max_len, kind, dtype)


def apply_block(p: Dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, kind: str, moe: bool, ctx: ShardCtx,
                cache: Optional[Dict] = None,
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x', cache', aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "m":
        mix, cache = apply_mamba(p["mixer"], cfg, h, positions, cache)
    elif cfg.use_mla:
        mix, cache = apply_mla(p["mixer"], cfg, h, positions, cache, ctx)
    else:
        mix, cache = apply_attn(p["mixer"], cfg, h, positions,
                                "l" if kind == "l" else "a", cache, ctx)
    x = shard_residual(x + mix, ctx)

    if "mlp" not in p:              # mixer-only block (mamba2)
        return x, cache, aux
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe:
        f, aux = apply_moe(p["mlp"], cfg, h, ctx)
    else:
        f = apply_mlp(p["mlp"], h, cfg.act, x.dtype)
    x = shard_residual(x + f, ctx)
    return x, cache, aux
