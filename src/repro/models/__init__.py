from .config import ModelConfig
from .transformer import (init_params, logical_axes, init_caches,
                          shard_caches, loss_fn, prefill_step, decode_step,
                          param_count, backbone, embed_input)

__all__ = [
    "ModelConfig", "init_params", "logical_axes", "init_caches",
    "shard_caches", "loss_fn", "prefill_step", "decode_step", "param_count",
    "backbone", "embed_input",
]
