"""Attention variants: full/sliding-window GQA-MQA, and DeepSeek-V2 MLA.

Prefill/train attention is *chunked* over the KV axis (lax.scan + online
softmax) so the lowered HLO never materializes an (S, S) score tensor — the
pure-JAX analogue of the Pallas flash kernel in ``repro.kernels``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Builder, apply_rope, rms_norm
from ..parallel.sharding import ShardCtx, shard_heads

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_attn(make: Builder, cfg: ModelConfig, prefix: str) -> Dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": make(f"{prefix}.wq", (d, hq, dh), ("embed", "heads", "head"), 1.0),
        "wk": make(f"{prefix}.wk", (d, hkv, dh), ("embed", "kv", "head"), 1.0),
        "wv": make(f"{prefix}.wv", (d, hkv, dh), ("embed", "kv", "head"), 1.0),
        "wo": make(f"{prefix}.wo", (hq, dh, d), ("heads", "head", "embed"), 1.0),
    }
    if cfg.qk_norm:
        p["q_gamma"] = make(f"{prefix}.qg", (dh,), ("head",), 0.0)
        p["k_gamma"] = make(f"{prefix}.kg", (dh,), ("head",), 0.0)
    return p


def init_mla(make: Builder, cfg: ModelConfig, prefix: str) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.nope_head_dim + cfg.rope_head_dim
    return {
        "wq_a": make(f"{prefix}.wq_a", (d, cfg.q_lora), ("embed", "qlora"), 1.0),
        "q_gamma": make(f"{prefix}.qn", (cfg.q_lora,), ("qlora",), 0.0),
        "wq_b": make(f"{prefix}.wq_b", (cfg.q_lora, h, qd),
                     ("qlora", "heads", "head"), 1.0),
        "wkv_a": make(f"{prefix}.wkv_a", (d, cfg.kv_lora + cfg.rope_head_dim),
                      ("embed", "kvlora"), 1.0),
        "kv_gamma": make(f"{prefix}.kvn", (cfg.kv_lora,), ("kvlora",), 0.0),
        "wkv_b": make(f"{prefix}.wkv_b",
                      (cfg.kv_lora, h, cfg.nope_head_dim + cfg.v_head_dim),
                      ("kvlora", "heads", "head"), 1.0),
        "wo": make(f"{prefix}.wo", (h, cfg.v_head_dim, d),
                   ("heads", "head", "embed"), 1.0),
    }


# ---------------------------------------------------------------------------
# chunked flash-style attention (pure jnp; oracle-equivalent to kernels/)
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array,
                      *, window: int = 0, chunk: int = 1024,
                      causal: bool = True, unroll: bool = False
                      ) -> jax.Array:
    """q: (B,Sq,Hq,D); k/v: (B,Sk,Hkv,Dk|Dv); positions int32 (B,Sq)/(B,Sk).

    window > 0 limits attention to the last `window` positions (inclusive of
    self).  Returns (B,Sq,Hq,Dv) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.array(D, jnp.float32))
    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = k.shape[1] // chunk

    # Operands stay in the model dtype (bf16) with fp32 ACCUMULATION
    # (preferred_element_type) — MXU semantics.  Carrying fp32 q/k/v
    # through the sharding boundaries doubles the TP all-gather bytes.
    kc = k.reshape(B, n_chunks, chunk, Hkv, k.shape[-1])
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dv)
    pc = k_pos.reshape(B, n_chunks, chunk)

    m0 = jnp.full((B, Sq, Hq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hq), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hq, Dv), jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, pb = blk                                   # (B,C,Hkv,*),(B,C)
        if G > 1:
            # repeat KV to Hq heads: keeps the head axis cleanly sharded
            # even when the mesh axis does not factor as Hkv x G.
            kb = jnp.repeat(kb, G, axis=2)
            vb = jnp.repeat(vb, G, axis=2)
        s = jnp.einsum("bqhd,bchd->bqhc", q, kb,
                       preferred_element_type=jnp.float32) * scale
        valid = (pb >= 0)[:, None, :]                      # (B,1,C)
        if causal:
            valid = valid & (pb[:, None, :] <= q_pos[:, :, None])
        if window > 0:
            valid = valid & (pb[:, None, :] >
                             q_pos[:, :, None] - window)
        s = jnp.where(valid[:, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    if unroll or n_chunks == 1:
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, (kc[:, i], vc[:, i], pc[:, i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
             jnp.moveaxis(pc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# standard (GQA / MQA / MHA) attention with optional KV cache
# ---------------------------------------------------------------------------

def _maybe_qk_norm(p: Dict, q: jax.Array, k: jax.Array, eps: float):
    if "q_gamma" in p:
        q = rms_norm(q, p["q_gamma"], eps)
        k = rms_norm(k, p["k_gamma"], eps)
    return q, k


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  kind: str, dtype) -> Dict:
    """Ring-buffer cache. 'l' layers cap the buffer at cfg.window."""
    size = min(max_len, cfg.window) if kind == "l" else max_len
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, hkv, dh), dtype),
        "v": jnp.zeros((batch, size, hkv, dh), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype) -> Dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        "kr": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def _cache_write(cache: Dict, names: Tuple[str, ...], values, positions):
    """Write (B,S,...) entries at ring slots positions % size.

    Prefill fast paths: when the prompt covers the cache exactly (S ==
    size) or wraps it a whole number of times, the write is a buffer
    replace/slice — the general scatter makes GSPMD replicate the full
    global K/V on every device (a 6+ GiB all-gather per layer at 32k)."""
    size = cache["pos"].shape[1]
    S = positions.shape[1]
    new = dict(cache)
    if S == size or (S > size and S % size == 0):
        for n, val in zip(names, values):
            new[n] = val[:, -size:].astype(cache[n].dtype)
        new["pos"] = positions[:, -size:]
        return new
    slots = positions % size                                 # (B,S)
    bidx = jnp.arange(cache["pos"].shape[0])[:, None]
    for n, val in zip(names, values):
        new[n] = cache[n].at[bidx, slots].set(val)
    new["pos"] = cache["pos"].at[bidx, slots].set(positions)
    return new


def apply_attn(p: Dict, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array, kind: str,
               cache: Optional[Dict] = None,
               ctx: Optional[ShardCtx] = None,
               ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B,S,d). positions: (B,S). Returns (out, updated cache)."""
    dt = x.dtype
    window = cfg.window if kind == "l" else 0
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q, k = _maybe_qk_norm(p, q, k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_base)
    k = apply_rope(k, positions, cfg.rope_base)
    wo = p["wo"].astype(dt)
    if cache is not None:
        cache = _cache_write(cache, ("k", "v"), (k, v), positions)

    if cache is not None and q.shape[1] == 1:
        # Decode: one query — direct single-block attention over the cache.
        out = chunked_attention(q, cache["k"], cache["v"], positions,
                                cache["pos"], window=window,
                                chunk=cache["k"].shape[1])
        return jnp.einsum("bshk,hkd->bsd", out, wo), cache

    # Train / prefill: attend over the prompt's own K/V (the ring cache
    # may be smaller than the prompt for sliding-window layers; cache
    # state above is persisted for decode — assumes it starts empty).
    if ctx is not None and ctx.mesh is not None:
        tp = ctx.tp_size
        hq, hkv = q.shape[2], k.shape[2]
        if hq % tp:
            # Pad heads to a tp multiple so attention shards by head
            # instead of falling back to sequence-gathered KV (which
            # all-gathers K/V every layer).  wo is zero-padded, so padded
            # heads contribute exactly zero — numerics unchanged, at
            # ~(pad/H) extra attention FLOPs.
            hq_pad = -hq % tp
            kv_pad = -hkv % tp if (hq + hq_pad) % hkv else 0
            if kv_pad and (hq + hq_pad) % (hkv + kv_pad):
                hq_pad = (-hq) % (hkv + kv_pad)
            q = jnp.pad(q, ((0, 0), (0, 0), (0, hq_pad), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
            wo = jnp.pad(wo, ((0, hq_pad), (0, 0), (0, 0)))
        q = shard_heads(q, ctx)
    if cfg.remat == "kv":
        from jax.ad_checkpoint import checkpoint_name
        k = checkpoint_name(k, "kv_gathered")
        v = checkpoint_name(v, "kv_gathered")
    out = chunked_attention(q, k, v, positions, positions,
                            window=window, chunk=cfg.attn_chunk,
                            unroll=cfg.unroll_loops)
    out = jnp.einsum("bshk,hkd->bsd", out, wo)
    return out, cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): latent KV cache, absorbed decode
# ---------------------------------------------------------------------------

def apply_mla(p: Dict, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array,
              cache: Optional[Dict] = None,
              ctx: Optional[ShardCtx] = None,
              ) -> Tuple[jax.Array, Optional[Dict]]:
    dt = x.dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim

    cq = rms_norm(jnp.einsum("bsd,dq->bsq", x, p["wq_a"].astype(dt)),
                  p["q_gamma"], cfg.norm_eps)
    qf = jnp.einsum("bsq,qhk->bshk", cq, p["wq_b"].astype(dt))
    if ctx is not None:
        qf = shard_heads(qf, ctx)
    q_nope, q_rope = qf[..., :nd], qf[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_base)

    kva = jnp.einsum("bsd,dk->bsk", x, p["wkv_a"].astype(dt))
    ckv = rms_norm(kva[..., :cfg.kv_lora], p["kv_gamma"], cfg.norm_eps)
    k_rope = apply_rope(kva[..., None, cfg.kv_lora:], positions,
                        cfg.rope_base)[:, :, 0]              # (B,S,rd)

    scale = 1.0 / jnp.sqrt(jnp.array(nd + rd, jnp.float32))

    if cache is None:
        # ---- prefill / train: expand per-head K,V (honest FLOPs) ----
        kvf = jnp.einsum("bsk,khd->bshd", ckv, p["wkv_b"].astype(dt))
        k_nope, vv = kvf[..., :nd], kvf[..., nd:]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, rd))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = chunked_attention(q_full, k_full, vv, positions, positions,
                                chunk=cfg.attn_chunk,
                                unroll=cfg.unroll_loops)
        new_cache = None
    else:
        # ---- decode: absorbed attention over the latent cache ----
        cache = _cache_write(cache, ("ckv", "kr"), (ckv, k_rope), positions)
        wkv_b = p["wkv_b"].astype(dt)
        w_uk, w_uv = wkv_b[..., :nd], wkv_b[..., nd:]
        q_lat = jnp.einsum("bshd,khd->bshk", q_nope, w_uk)   # (B,S,H,kv_lora)
        s = (jnp.einsum("bshk,btk->bhst", q_lat, cache["ckv"]) +
             jnp.einsum("bshr,btr->bhst", q_rope, cache["kr"]))
        s = s.astype(jnp.float32) * scale
        valid = (cache["pos"] >= 0)[:, None, None, :] & \
                (cache["pos"][:, None, None, :] <= positions[:, None, :, None])
        s = jnp.where(valid, s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1).astype(dt)
        ctx = jnp.einsum("bhst,btk->bshk", a, cache["ckv"])
        out = jnp.einsum("bshk,khd->bshd", ctx, w_uv)        # (B,S,H,vd)
        new_cache = cache

    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dt))
    return y, new_cache
