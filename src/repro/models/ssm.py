"""Mamba2 (SSD — state-space duality) mixer, arXiv:2405.21060.

Chunked SSD: within-chunk quadratic (attention-like, MXU-friendly) plus an
inter-chunk state recurrence carried by ``lax.scan``.  Decode is an O(1)
state update.  Multi-group B/C (``ssm_groups``) gives the tensor-parallel
sharding surface (groups/heads over 'model').
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Builder, rms_norm

NEG_INF = -1e30


def _groups(cfg: ModelConfig) -> int:
    g = getattr(cfg, "ssm_groups", 1) or 1
    return g


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_mamba(make: Builder, cfg: ModelConfig, prefix: str) -> Dict:
    d, din = cfg.d_model, cfg.ssm_heads * cfg.ssm_head_dim
    g, n, h = _groups(cfg), cfg.ssm_state, cfg.ssm_heads
    cc = din + 2 * g * n
    return {
        "in_z": make(f"{prefix}.in_z", (d, din), ("embed", "ssm_heads"), 1.0),
        "in_x": make(f"{prefix}.in_x", (d, din), ("embed", "ssm_heads"), 1.0),
        "in_bc": make(f"{prefix}.in_bc", (d, 2 * g * n),
                      ("embed", "ssm_state"), 1.0),
        "in_dt": make(f"{prefix}.in_dt", (d, h), ("embed", "ssm_heads"), 1.0),
        "conv_w": make(f"{prefix}.conv_w", (cfg.conv_width, cc),
                       ("conv", "ssm_heads"), 1.0),
        "conv_b": make(f"{prefix}.conv_b", (cc,), ("ssm_heads",), 0.0),
        "A_log": make(f"{prefix}.A_log", (h,), ("ssm_heads",), 0.0),
        "D": make(f"{prefix}.D", (h,), ("ssm_heads",), 0.0),
        "dt_bias": make(f"{prefix}.dt_bias", (h,), ("ssm_heads",), 0.0),
        "gamma": make(f"{prefix}.gamma", (din,), ("ssm_heads",), 0.0),
        "out": make(f"{prefix}.out", (din, d), ("ssm_heads", "embed"), 1.0),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    g, n = _groups(cfg), cfg.ssm_state
    din = cfg.ssm_heads * cfg.ssm_head_dim
    cc = din + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cc), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          n), jnp.float32),
    }


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, A, B, C, chunk: int,
             init_state: Optional[jax.Array] = None,
             unroll: bool = False):
    """x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,g,n).

    Returns (y:(b,s,h,p), final_state:(b,h,p,n)) — fp32 state."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    l = chunk

    xb = jnp.moveaxis(x.reshape(b, nc, l, h, p).astype(jnp.float32), 1, 0)
    dtb = jnp.moveaxis(dt.reshape(b, nc, l, h).astype(jnp.float32), 1, 0)
    Bb = jnp.moveaxis(B.reshape(b, nc, l, g, n).astype(jnp.float32), 1, 0)
    Cb = jnp.moveaxis(C.reshape(b, nc, l, g, n).astype(jnp.float32), 1, 0)
    A32 = A.astype(jnp.float32)

    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def body(state, inp):
        xc, dtc, Bc, Cc = inp          # (b,l,h,p) (b,l,h) (b,l,g,n)
        dA = dtc * A32                 # (b,l,h) — negative
        cs = jnp.cumsum(dA, axis=1)    # inclusive
        # inter-chunk: y_i += C_i . state0 decayed to i
        state_g = state.reshape(b, g, hg, p, n)
        y_inter = jnp.einsum("blgn,bghpn->blghp", Cc, state_g)
        y_inter = y_inter.reshape(b, l, h, p) * jnp.exp(cs)[..., None]
        # intra-chunk quadratic
        scores = jnp.einsum("bign,bjgn->bijg", Cc, Bc)       # (b,l,l,g)
        csr = cs.reshape(b, l, g, hg)
        diff = csr[:, :, None] - csr[:, None]                # (b,i,j,g,hg)
        ii = jnp.arange(l)
        mask = (ii[:, None] >= ii[None, :])[None, :, :, None, None]
        L = jnp.exp(jnp.where(mask, diff, NEG_INF))
        xdt = (xc * dtc[..., None]).reshape(b, l, g, hg, p)
        y_intra = jnp.einsum("bijg,bijgq,bjgqp->bigqp",
                             scores, L, xdt).reshape(b, l, h, p)
        # state update
        decay_last = jnp.exp(cs[:, -1])                      # (b,h)
        decay_g = jnp.exp(cs[:, -1][:, None] - cs            # (b,l,h)
                          ).reshape(b, l, g, hg)
        contrib = jnp.einsum("blgq,blgn,blgqp->bgqpn",
                             decay_g, Bc, xdt).reshape(b, h, p, n)
        state_new = state * decay_last[..., None, None] + contrib
        return state_new, y_inter + y_intra

    if unroll or nc == 1:
        state, ys_list = state0, []
        for i in range(nc):
            state, yi = body(state, (xb[i], dtb[i], Bb[i], Cb[i]))
            ys_list.append(yi)
        final, ys = state, jnp.stack(ys_list)
    else:
        final, ys = jax.lax.scan(body, state0, (xb, dtb, Bb, Cb))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_step(state, x, dt, A, B, C):
    """One decode step. state:(b,h,p,n) x:(b,h,p) dt:(b,h) B,C:(b,g,n)."""
    b, h, p, n = state.shape
    g = B.shape[1]
    hg = h // g
    da = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))   # (b,h)
    Bh = jnp.repeat(B, hg, axis=1).astype(jnp.float32)             # (b,h,n)
    Ch = jnp.repeat(C, hg, axis=1).astype(jnp.float32)
    inc = jnp.einsum("bh,bhn,bhp->bhpn", dt.astype(jnp.float32), Bh,
                     x.astype(jnp.float32))
    state = state * da[..., None, None] + inc
    y = jnp.einsum("bhn,bhpn->bhp", Ch, state)
    return state, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# full mixer
# ---------------------------------------------------------------------------

def _causal_conv(xbc, w, bias, cache: Optional[jax.Array]):
    """xbc:(b,s,cc), w:(width,cc). Returns (out, new_cache)."""
    b, s, cc = xbc.shape
    width = w.shape[0]
    if cache is None:
        padded = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
        new_cache = None
    else:
        padded = jnp.concatenate([cache.astype(xbc.dtype), xbc], axis=1)
        new_cache = padded[:, -(width - 1):] if width > 1 else cache
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + padded[:, i:i + s] * w[i].astype(xbc.dtype)
    out = out + bias.astype(xbc.dtype)
    return jax.nn.silu(out), new_cache


def apply_mamba(p: Dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array,
                cache: Optional[Dict] = None,
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B,S,d). Returns (out, new_cache)."""
    dt_ = x.dtype
    b, s, d = x.shape
    h, pdim, g, n = (cfg.ssm_heads, cfg.ssm_head_dim, _groups(cfg),
                     cfg.ssm_state)
    din = h * pdim

    z = jnp.einsum("bsd,de->bse", x, p["in_z"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"].astype(dt_))
    bc = jnp.einsum("bsd,de->bse", x, p["in_bc"].astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["in_dt"].astype(dt_))

    xbc = jnp.concatenate([xs, bc], axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_cache)
    xs, Bm, Cm = (xbc[..., :din],
                  xbc[..., din:din + g * n],
                  xbc[..., din + g * n:])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(b, s, h, pdim)
    Bm = Bm.reshape(b, s, g, n)
    Cm = Cm.reshape(b, s, g, n)

    if cache is None:
        y, _ = ssd_scan(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                        unroll=cfg.unroll_loops)
        new_cache = None
    elif s == 1:
        st, y1 = ssd_step(cache["ssm"], xh[:, 0], dt[:, 0], A,
                          Bm[:, 0], Cm[:, 0])
        y = y1[:, None]
        new_cache = {"conv": new_conv, "ssm": st}
    else:
        y, st = ssd_scan(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                         init_state=cache["ssm"], unroll=cfg.unroll_loops)
        new_cache = {"conv": new_conv, "ssm": st}

    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(b, s, din)
    y = rms_norm(y * jax.nn.silu(z), p["gamma"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out"].astype(dt_))
    return out, new_cache
