"""Shared layer primitives: norms, rotary embeddings, activations, init.

All parameters are plain jnp arrays in nested dicts.  Every initializer is
written against a ``Builder`` callback so the same code path can emit either
(a) real parameter arrays or (b) logical-axis annotations (for sharding) —
keeping the two trees structurally identical by construction.
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# A Builder receives (name, shape, logical_axes, scale) and returns a leaf.
Builder = Callable[[str, Tuple[int, ...], Tuple[str, ...], float], jax.Array]


def array_builder(rng: jax.Array, dtype=jnp.float32) -> Builder:
    """Builder that materializes truncated-normal parameter arrays."""
    count = [0]

    def make(name, shape, axes, scale):
        count[0] += 1
        key = jax.random.fold_in(rng, count[0])
        if scale == 0.0:
            return jnp.zeros(shape, dtype)
        fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
        std = scale / np.sqrt(fan_in)
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
                * std)

    return make


def axes_builder() -> Builder:
    """Builder that records logical axis names instead of arrays."""
    def make(name, shape, axes, scale):
        assert len(axes) == len(shape), (name, shape, axes)
        return axes
    return make


def ones_like_axes(name, shape, axes, scale):
    return jnp.ones(shape, jnp.float32)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, base: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, base: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    freqs = rope_freqs(x.shape[-1], base)                     # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs    # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated or plain) params + apply
# ---------------------------------------------------------------------------

def init_mlp(make: Builder, d_model: int, d_ff: int, prefix: str,
             gated: bool = True) -> Dict:
    p = {
        "wi": make(f"{prefix}.wi", (d_model, d_ff), ("embed", "mlp"), 1.0),
        "wo": make(f"{prefix}.wo", (d_ff, d_model), ("mlp", "embed"), 1.0),
    }
    if gated:
        p["wg"] = make(f"{prefix}.wg", (d_model, d_ff), ("embed", "mlp"),
                       1.0)
    return p


def apply_mlp(p: Dict, x: jax.Array, act: str, dtype) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dtype))
    if "wg" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dtype))
        h = act_fn(act)(g) * h
    else:
        h = act_fn(act)(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embed(make: Builder, vocab: int, d_model: int,
               tie: bool) -> Dict:
    # the table's d_model dim uses its own logical axis ('embed_t', never
    # sharded): a gather whose operand is sharded on BOTH dims crash-checks
    # XLA's SPMD partitioner on 3-axis meshes. vocab x model is the proven
    # layout; per-device table bytes stay bounded by the model axis.
    p = {"tok": make("embed.tok", (vocab, d_model),
                     ("vocab", "embed_t"), 1.0)}
    if not tie:
        p["head"] = make("embed.head", (d_model, vocab),
                         ("embed", "vocab"), 1.0)
    return p


def embed_tokens(p: Dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["tok"].astype(dtype), tokens, axis=0)


def lm_logits(p: Dict, x: jax.Array, dtype, cap: float = 0.0) -> jax.Array:
    if "head" in p:
        logits = jnp.einsum("bsd,dv->bsv", x, p["head"].astype(dtype))
    else:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"].astype(dtype))
    return softcap(logits.astype(jnp.float32), cap)
