"""Model configuration for every assigned architecture family.

A single ``ModelConfig`` covers dense / GQA / MQA / MLA transformers, MoE
(top-k routed + shared experts), Mamba2-SSD layers, hybrid interleaves
(Jamba) and local:global sliding-window patterns (Gemma-3).

The layer stack is expressed as ``prefix`` layers (unstacked, e.g. the first
dense layer of DeepSeek-V2) followed by ``n_periods`` repetitions of
``block_pattern`` whose parameters are stacked for ``lax.scan``.

Block pattern tokens:
  'a' full (global) causal attention
  'l' sliding-window (local) causal attention
  'g' explicit global attention (synonym of 'a'; used in local:global mixes)
  'm' Mamba2 (SSD) mixer
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # --- activations / norms ---
    act: str = "silu"                 # 'silu' (SwiGLU) | 'gelu' (GeGLU)
    gated_mlp: bool = True            # False: plain 2-matmul FFN
    norm_eps: float = 1e-6
    qk_norm: bool = False
    logit_softcap: float = 0.0        # gemma-style final-logit softcapping

    # --- attention pattern ---
    block_pattern: Tuple[str, ...] = ("a",)
    n_prefix_layers: int = 0          # unstacked leading layers (dense MLP)
    window: int = 4096                # sliding window for 'l' layers
    rope_base: float = 10000.0

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 0
    moe_shared: int = 0               # number of shared (always-on) experts
    moe_d_ff: int = 0                 # per-expert intermediate size
    moe_every: int = 1                # MoE on pattern positions where
    moe_offset: int = 0               # (pos % moe_every) == moe_offset
    capacity_factor: float = 1.25
    router_scale: float = 1.0         # routed-output scaling (DeepSeek)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    ssm_expand: int = 2

    # --- embeddings / head ---
    tie_embeddings: bool = False
    frontend: str = "none"            # 'none' | 'audio' | 'vision'
    frontend_tokens: int = 0          # prepended continuous-embedding tokens

    # --- numerics / execution ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"               # 'none' | 'full' | 'dots'
    attn_chunk: int = 1024            # kv-block size for chunked attention
    loss_chunk: int = 512             # seq-block size for chunked CE
    scan_layers: bool = True
    # Unroll inner lax.scan loops (attention KV blocks, SSD chunks, CE
    # chunks) — used by the dry-run so HLO cost_analysis counts every
    # iteration (scan bodies are otherwise counted once).
    unroll_loops: bool = False

    # ------------------------------------------------------------------
    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_scanned(self) -> int:
        return self.n_layers - self.n_prefix_layers

    @property
    def n_periods(self) -> int:
        n, p = self.n_scanned, self.pattern_len
        if n % p:
            raise ValueError(f"{self.name}: {n} scanned layers not divisible "
                             f"by pattern of {p}")
        return n // p

    def is_moe_pos(self, pos: int) -> bool:
        """MoE predicate for a position inside the block pattern."""
        if self.moe_experts == 0:
            return False
        return (pos % self.moe_every) == self.moe_offset

    @property
    def d_inner(self) -> int:         # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def is_hybrid(self) -> bool:
        return "m" in self.block_pattern and any(
            t in self.block_pattern for t in ("a", "l", "g"))

    @property
    def is_attention_free(self) -> bool:
        return set(self.block_pattern) == {"m"} and self.n_prefix_layers == 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k decode: no unbounded full-attention KV,
        or the full-attention share is bounded (hybrid / local:global)."""
        toks = set(self.block_pattern)
        if toks == {"m"}:
            return True
        if "m" in toks:               # hybrid: bounded attention share
            return True
        if "l" in toks:               # local:global sliding window mix
            return True
        return False

    def validate(self) -> None:
        assert self.n_prefix_layers + self.n_periods * self.pattern_len == \
            self.n_layers
        if any(t in self.block_pattern for t in ("a", "l", "g")) or \
                self.n_prefix_layers:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.moe_experts:
            assert 0 < self.moe_topk <= self.moe_experts
            assert self.moe_d_ff > 0
        if "m" in self.block_pattern:
            assert self.ssm_state > 0 and self.ssm_heads > 0

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        pat = self.block_pattern
        small = dict(
            n_layers=max(self.n_prefix_layers, 0) + 2 * len(pat),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab=256,
            window=32,
            attn_chunk=32,
            ssm_chunk=16,
            remat="none",
        )
        if self.use_mla:
            small.update(q_lora=32, kv_lora=32, rope_head_dim=8,
                         nope_head_dim=16, v_head_dim=16)
        if self.moe_experts:
            small.update(moe_experts=4, moe_topk=min(self.moe_topk, 2),
                         moe_shared=min(self.moe_shared, 1), moe_d_ff=64)
        if self.ssm_heads:
            small.update(ssm_heads=4, ssm_head_dim=8, ssm_state=16,
                         ssm_groups=min(self.ssm_groups, 2))
        if self.frontend_tokens:
            small.update(frontend_tokens=8)
        small.update(overrides)
        return dataclasses.replace(self, **small)
