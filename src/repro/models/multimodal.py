"""Modality frontend STUBS per the assignment: ``[audio]`` (musicgen over
EnCodec tokens) and ``[vlm]`` (llava anyres patches) supply *precomputed*
frame/patch embeddings; the backbone consumes them via
``frontend_embeds`` in the input batch.  ``input_specs()`` in launch/ uses
these shapes; here we also provide deterministic synthetic generators for
smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def frontend_shape(cfg: ModelConfig, batch: int):
    if cfg.frontend == "none" or cfg.frontend_tokens == 0:
        return None
    return (batch, cfg.frontend_tokens, cfg.d_model)


def synth_frontend(cfg: ModelConfig, batch: int, seed: int = 0):
    shape = frontend_shape(cfg, batch)
    if shape is None:
        return None
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, jnp.dtype(cfg.dtype)) * 0.02
