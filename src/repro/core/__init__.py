from .planes import PlaneConfig, apportion, plane_loads, effective_bandwidth
from .plb import PLBState, plb_init, plb_update, select_plane, plane_weights
from .congestion import SpxCCConfig, DcqcnConfig, spx_cc_update, dcqcn_update
from .collectives import plane_allreduce, stream_report, int8_encode, int8_decode
from .fault_tolerance import (FailoverController, poisson_flaps,
                              concurrent_failure_pmf, elastic_mesh_plan)
