"""Fault injection and failover control (§4.4, §6.5–6.6).

* Poisson link-flap schedules with the paper's MTBF methodology (10
  flaps/min fleet-wide, 10 s flap duration; concurrent-failure count is
  Poisson-distributed).
* ``FailoverController`` — host-side controller that feeds plane-health
  signals into the jitted PLB update and tracks recovery latency in steps,
  mirroring the <3 ms hardware PLB vs ~1 s software LB comparison.
* Elastic mesh planning for permanent node loss (checkpoint/restart path).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from .planes import PlaneConfig
from .plb import PLBState, plb_init, plb_update, plane_weights


# ---------------------------------------------------------------------------
# flap schedules (§6.6 methodology)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FlapEvent:
    link: int
    t_down: float
    t_up: float


def poisson_flaps(rng: np.random.Generator, n_links: int,
                  flaps_per_minute: float, duration_s: float,
                  horizon_s: float) -> List[FlapEvent]:
    """Fleet-wide flap rate -> per-link exponential inter-arrival times."""
    lam_per_link = flaps_per_minute / 60.0 / max(n_links, 1)
    events: List[FlapEvent] = []
    for link in range(n_links):
        t = 0.0
        while True:
            t += rng.exponential(1.0 / max(lam_per_link, 1e-12))
            if t >= horizon_s:
                break
            events.append(FlapEvent(link, t, t + duration_s))
    events.sort(key=lambda e: e.t_down)
    return events


def concurrent_failure_pmf(flaps_per_minute: float, duration_s: float,
                           max_k: int = 10) -> np.ndarray:
    """Poisson pmf over the number of concurrently failed links — the
    weighting the paper uses to compose per-k simulations into an expected
    P99 CCT."""
    lam = flaps_per_minute / 60.0 * duration_s
    k = np.arange(max_k + 1)
    logp = k * np.log(max(lam, 1e-12)) - lam - \
        np.array([np.sum(np.log(np.arange(1, kk + 1))) if kk else 0.0
                  for kk in k])
    p = np.exp(logp)
    return p / p.sum()


def links_down_at(events: List[FlapEvent], t: float) -> List[int]:
    return [e.link for e in events if e.t_down <= t < e.t_up]


# ---------------------------------------------------------------------------
# failover controller (host side; drives the jitted PLB update)
# ---------------------------------------------------------------------------

@dataclass
class RecoveryRecord:
    plane: int
    fail_step: int
    converged_step: Optional[int] = None

    @property
    def recovery_steps(self) -> Optional[int]:
        if self.converged_step is None:
            return None
        return self.converged_step - self.fail_step


class FailoverController:
    """Threads PLBState through the train loop; injects plane failures and
    measures convergence (steps until weights match plane health)."""

    def __init__(self, cfg: PlaneConfig):
        self.cfg = cfg
        self.state: PLBState = plb_init(cfg.n_planes)
        self.plane_up = np.ones(cfg.n_planes, bool)
        self.step = 0
        self.records: List[RecoveryRecord] = []
        self._open: Dict[int, RecoveryRecord] = {}

    def fail_plane(self, plane: int) -> None:
        if self.plane_up[plane]:
            self.plane_up[plane] = False
            rec = RecoveryRecord(plane, self.step)
            self.records.append(rec)
            self._open[plane] = rec

    def restore_plane(self, plane: int) -> None:
        self.plane_up[plane] = True

    def on_step(self, plane_queue: Optional[np.ndarray] = None,
                plane_rtt_us: Optional[np.ndarray] = None) -> np.ndarray:
        """Advance one step; returns current plane weights (numpy)."""
        p = self.cfg.n_planes
        up = jnp.asarray(self.plane_up)
        rtt = (jnp.asarray(plane_rtt_us, jnp.float32)
               if plane_rtt_us is not None
               else jnp.where(up, 6.0, 1e3).astype(jnp.float32))
        ecn = jnp.zeros((p,), jnp.float32)
        delivered = jnp.where(up, 1.0, 0.0).astype(jnp.float32)
        queue = (jnp.asarray(plane_queue, jnp.float32)
                 if plane_queue is not None
                 else jnp.where(up, 0.1, 1.0).astype(jnp.float32))
        self.state = plb_update(self.state, rtt, ecn, delivered, up, queue,
                                self.cfg)
        self.step += 1
        w = np.asarray(plane_weights(self.state))
        # convergence check for open failures: failed plane weight ~ 0
        for plane, rec in list(self._open.items()):
            if not self.plane_up[plane] and w[plane] < 1e-3:
                rec.converged_step = self.step
                del self._open[plane]
        return w

    def weights(self) -> np.ndarray:
        return np.asarray(plane_weights(self.state))


# ---------------------------------------------------------------------------
# elastic scaling (permanent failures -> re-mesh plan)
# ---------------------------------------------------------------------------

def elastic_mesh_plan(n_devices: int, model_parallel: int,
                      pods: int = 1) -> Tuple[int, ...]:
    """Largest (pod, data, model) mesh that fits the surviving devices,
    keeping TP intact and shrinking DP — the checkpoint-restart re-mesh
    used after permanent node loss."""
    if n_devices < model_parallel:
        raise ValueError("fewer devices than one TP group")
    per_pod = n_devices // pods
    dp = per_pod // model_parallel
    if dp < 1:
        raise ValueError("cannot form a single DP replica per pod")
    if pods > 1:
        return (pods, dp, model_parallel)
    return (dp, model_parallel)
