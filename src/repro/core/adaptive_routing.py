"""Switch per-packet Adaptive Routing (§4.1): quantized Join-Shortest-Queue
over the ECMP group, extended with Weighted AR (§4.4.2) for remote capacity
asymmetry.

These are the pure functions behind both the network simulator's switches
and the ``jsq_route`` Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = jnp.float32(1e30)


def quantize_queue(q: jax.Array, nbins: int = 16,
                   qmax: float = 1.0) -> jax.Array:
    """Quantized queue score (the hardware compares coarse bins, not exact
    byte counts)."""
    return jnp.floor(jnp.clip(q / qmax, 0.0, 1.0 - 1e-6) * nbins)


def ar_scores(queues: jax.Array, up_mask: jax.Array,
              weights: jax.Array | None = None,
              nbins: int = 16, qmax: float = 1.0) -> jax.Array:
    """Per-port AR score: lower is better.  Weighted AR divides the local
    queue score by the remote-capacity weight so degraded destinations
    attract proportionally less traffic.  Failed ports score +inf."""
    s = quantize_queue(queues, nbins, qmax) + 1.0
    if weights is not None:
        s = s / jnp.maximum(weights, 1e-6)
    return jnp.where(up_mask, s, BIG)


def jsq_select(queues: jax.Array, up_mask: jax.Array, key: jax.Array,
               weights: jax.Array | None = None,
               nbins: int = 16, qmax: float = 1.0) -> jax.Array:
    """Pick one egress port for a packet: min score, random tie-break."""
    s = ar_scores(queues, up_mask, weights, nbins, qmax)
    noise = jax.random.uniform(key, s.shape, minval=0.0, maxval=0.5)
    return jnp.argmin(s + noise, axis=-1)


def ecmp_select(flow_hash: jax.Array, up_mask: jax.Array) -> jax.Array:
    """Static ECMP: hash modulo the number of *up* ports (rehash on
    failure).  flow_hash: int32 (...,)."""
    n_up = jnp.maximum(jnp.sum(up_mask.astype(jnp.int32), -1), 1)
    idx = flow_hash % n_up
    # map rank-among-up -> physical port
    order = jnp.cumsum(up_mask.astype(jnp.int32), -1) - 1
    port = jnp.argmax((order == idx[..., None]) & up_mask, axis=-1)
    return port


def spray_fractions(queues: jax.Array, up_mask: jax.Array,
                    weights: jax.Array | None = None,
                    nbins: int = 16, qmax: float = 1.0,
                    temperature: float = 1.0) -> jax.Array:
    """Fluid-model AR: the fraction of arriving load each egress port
    receives this slot.  A softmin over AR scores — at temperature->0 it is
    exact JSQ; finite temperature models the quantized/delayed decision."""
    s = ar_scores(queues, up_mask, weights, nbins, qmax)
    logit = -s / jnp.maximum(temperature, 1e-6)
    logit = jnp.where(up_mask, logit, -BIG)
    return jax.nn.softmax(logit, axis=-1)


def ecmp_fractions(n_flows: jax.Array, up_mask: jax.Array,
                   key: jax.Array) -> jax.Array:
    """Fluid ECMP: flows hash uniformly to up ports -> multinomial load
    split (balls into bins), capturing hash-collision imbalance."""
    ports = up_mask.shape[-1]
    probs = up_mask / jnp.maximum(jnp.sum(up_mask, -1, keepdims=True), 1)
    counts = jax.random.multinomial(key, n_flows, probs)  # may broadcast
    return counts / jnp.maximum(n_flows, 1)
