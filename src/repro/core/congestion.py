"""Congestion-control rate laws (§4.2): SPX CC and a DCQCN baseline.

Both are pure, vectorizable update rules shared by the NIC PLB contexts and
the network simulator.  Rates are normalized to line rate (1.0 = 100 %).

SPX CC design points from the paper:
  * ECN marks only when in-network load balancing is exhausted; the sender
    reacts *only* to those marks (no reaction to transient micro-bursts that
    adaptive routing resolves sub-RTT).
  * RTT probes guide precise rate adjustment around a target delay.
  * Fast additive recovery so a collective recovers within itself.

DCQCN baseline: classic alpha-based multiplicative decrease on any ECN,
slow byte-counter recovery — the "overreacts to synchronized bursts"
behaviour evaluated in §6.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SpxCCConfig:
    target_rtt_us: float = 8.0     # jitter-free fabric RTT target
    base_rtt_us: float = 4.0
    md_factor: float = 0.7         # multiplicative decrease on ECN
    ai_rate: float = 0.05          # additive increase per update (fast)
    rtt_gain: float = 0.15         # proportional RTT-error correction
    min_rate: float = 0.01


@dataclass(frozen=True)
class DcqcnConfig:
    alpha_g: float = 0.0625        # alpha EWMA gain
    rate_ai: float = 0.005         # slow additive increase
    min_rate: float = 0.01


def spx_cc_update(rate: jax.Array, rtt_us: jax.Array, ecn: jax.Array,
                  cfg: SpxCCConfig = SpxCCConfig()) -> jax.Array:
    """rate/rtt/ecn: same-shape arrays. ecn in [0,1] = marked fraction.

    Only ECN (LB-exhaustion signal) triggers decrease; RTT error trims the
    rate toward the target delay; otherwise fast additive increase."""
    rtt_err = (rtt_us - cfg.target_rtt_us) / cfg.target_rtt_us
    decrease = rate * (cfg.md_factor + (1.0 - cfg.md_factor) *
                       jnp.clip(1.0 - ecn, 0.0, 1.0))
    trimmed = rate * (1.0 - cfg.rtt_gain * jnp.clip(rtt_err, 0.0, 2.0))
    increase = jnp.minimum(rate + cfg.ai_rate, 1.0)
    out = jnp.where(ecn > 0.0, decrease,
                    jnp.where(rtt_err > 0.25, trimmed, increase))
    return jnp.clip(out, cfg.min_rate, 1.0)


def dcqcn_update(rate: jax.Array, alpha: jax.Array, ecn: jax.Array,
                 cfg: DcqcnConfig = DcqcnConfig()):
    """Returns (rate', alpha'). Cuts on any ECN; recovers slowly."""
    alpha_new = (1.0 - cfg.alpha_g) * alpha + cfg.alpha_g * (ecn > 0)
    cut = rate * (1.0 - alpha_new / 2.0)
    grow = jnp.minimum(rate + cfg.rate_ai, 1.0)
    rate_new = jnp.where(ecn > 0, cut, grow)
    return jnp.clip(rate_new, cfg.min_rate, 1.0), alpha_new
