"""Plane-sharded collectives — the paper's technique as a first-class
gradient-sync engine.

Every gradient leaf is split into micro-chunks; each micro-chunk is an
independent collective stream assigned to a plane by the PLB weights
(assignment is pure scheduling — numerics are invariant).  Streams are
lowered either as plain ``lax.psum`` or as an explicit ring decomposition
(``psum_scatter`` + ``all_gather``) whose all-gather phase can carry
int8-compressed payloads (stochastic rounding, unbiased) — the
distributed-optimization extension beyond the paper.

All functions here run INSIDE a ``shard_map`` that is manual over the DP
axes and automatic over the model axis, so TP shardings pass through
untouched.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .planes import PlaneConfig, apportion


# ---------------------------------------------------------------------------
# int8 codec (pure-jnp twin of kernels/int8_codec.py)
# ---------------------------------------------------------------------------

def int8_encode(x: jax.Array, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row (last-dim) scaled int8 with stochastic rounding (unbiased)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    y = x / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# chunking
# ---------------------------------------------------------------------------

def _chunk_bounds(n0: int, k: int) -> List[Tuple[int, int]]:
    """np.array_split-style bounds of axis-0 into <=k chunks."""
    k = min(k, n0)
    sizes = [n0 // k + (1 if i < n0 % k else 0) for i in range(k)]
    bounds, off = [], 0
    for s in sizes:
        bounds.append((off, off + s))
        off += s
    return bounds


def _scatter_dim(shape: Tuple[int, ...], dp: int) -> int:
    for d in range(min(2, len(shape))):
        if shape[d] % dp == 0 and shape[d] >= dp:
            return d
    return -1


def _psum_chunk(x, dp_axes, mode: str, key, dp_size: int):
    """One micro-chunk collective stream."""
    if mode == "psum":
        return jax.lax.psum(x, dp_axes)
    sd = _scatter_dim(x.shape, dp_size)
    if sd < 0:
        return jax.lax.psum(x, dp_axes)
    # ring decomposition: reduce-scatter then all-gather
    red = jax.lax.psum_scatter(x, dp_axes, scatter_dimension=sd, tiled=True)
    if mode == "rs_ag":
        return jax.lax.all_gather(red, dp_axes, axis=sd, tiled=True)
    if mode == "rs_ag_int8":
        if sd >= x.ndim - 1:
            # cannot compress along the scaling dim (1-D bias/gamma chunks)
            return jax.lax.all_gather(red, dp_axes, axis=sd, tiled=True)
        q, scale = int8_encode(red, key)
        qg = jax.lax.all_gather(q, dp_axes, axis=sd, tiled=True)
        sg = jax.lax.all_gather(scale, dp_axes, axis=sd, tiled=True)
        return int8_decode(qg, sg)
    raise ValueError(mode)


def plane_allreduce(grads, dp_axes: Sequence[str], cfg: PlaneConfig,
                    key: jax.Array | None = None,
                    mode: str | None = None, mean: bool = True):
    """Sum (or mean) gradients over the DP axes via micro-chunk streams.

    Must be called inside shard_map(axis_names=set(dp_axes))."""
    dp_axes = tuple(dp_axes)
    dp_size = 1
    for a in dp_axes:
        dp_size *= jax.lax.axis_size(a)
    if mode is None:
        mode = {"none": "psum", "int8": "rs_ag_int8"}.get(
            cfg.compression, cfg.compression)
    if key is None:
        key = jax.random.PRNGKey(0)

    leaves, treedef = jax.tree.flatten(grads)
    out = []
    kidx = 0
    for leaf in leaves:
        if leaf.ndim == 0 or leaf.size <= cfg.microchunks:
            out.append(jax.lax.psum(leaf, dp_axes))
            continue
        pieces = []
        for (lo, hi) in _chunk_bounds(leaf.shape[0], cfg.microchunks):
            kidx += 1
            ck = jax.random.fold_in(key, kidx)
            piece = jax.lax.slice_in_dim(leaf, lo, hi, axis=0)
            pieces.append(_psum_chunk(piece, dp_axes, mode, ck, dp_size))
        out.append(jnp.concatenate(pieces, axis=0).astype(leaf.dtype))
    g = jax.tree.unflatten(treedef, out)
    if mean:
        g = jax.tree.map(lambda x: x / dp_size, g)
    return g


# ---------------------------------------------------------------------------
# host-side stream accounting (scheduling/telemetry; numerics-free)
# ---------------------------------------------------------------------------

@dataclass
class StreamReport:
    chunk_bytes: np.ndarray      # (n_chunks,)
    assignment: np.ndarray       # (n_chunks,) plane ids
    bytes_per_plane: np.ndarray  # (P,)


def stream_report(grads, cfg: PlaneConfig,
                  weights: np.ndarray | None = None) -> StreamReport:
    """Compute the micro-chunk -> plane assignment for this step's gradient
    pytree given current PLB weights (host-side; drives telemetry and the
    failover performance model)."""
    if weights is None:
        weights = np.ones(cfg.n_planes) / cfg.n_planes
    sizes = []
    for leaf in jax.tree.leaves(grads):
        shape = getattr(leaf, "shape", ())
        dt = getattr(leaf, "dtype", None)
        # shape-only leaves (e.g. jax.eval_shape structs without a dtype)
        # fall back to f32's 4 bytes/element
        itemsize = np.dtype(dt).itemsize if dt is not None else 4
        if len(shape) == 0 or int(np.prod(shape)) <= cfg.microchunks:
            sizes.append(int(np.prod(shape)) * itemsize)
            continue
        per = int(np.prod(shape[1:])) if len(shape) > 1 else 1
        for (lo, hi) in _chunk_bounds(shape[0], cfg.microchunks):
            sizes.append((hi - lo) * per * itemsize)
    chunk_bytes = np.asarray(sizes, np.float64)
    assignment = greedy_assign(chunk_bytes, np.asarray(weights))
    bpp = np.zeros(cfg.n_planes)
    np.add.at(bpp, assignment, chunk_bytes)
    return StreamReport(chunk_bytes=chunk_bytes, assignment=assignment,
                        bytes_per_plane=bpp)


def greedy_assign(chunk_bytes: np.ndarray,
                  weights: np.ndarray) -> np.ndarray:
    """Byte-aware LPT assignment: largest chunk first onto the plane with
    the smallest weighted load. Chunk-count apportionment leaves planes
    imbalanced when chunk sizes are skewed (the embedding chunk alone can
    be 10x a layer chunk)."""
    P = weights.shape[0]
    w = np.asarray(weights, np.float64)
    if w.sum() <= 0:
        w = np.ones(P)
    w = np.maximum(w / w.sum(), 0.0)
    loads = np.zeros(P)
    out = np.zeros(chunk_bytes.shape[0], np.int64)
    order = np.argsort(-chunk_bytes, kind="stable")
    eligible = w > 1e-12
    for i in order:
        score = np.where(eligible,
                         (loads + chunk_bytes[i]) / np.maximum(w, 1e-12),
                         np.inf)
        p = int(np.argmin(score))
        out[i] = p
        loads[p] += chunk_bytes[i]
    return out
