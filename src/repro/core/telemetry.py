"""High-Frequency Telemetry (§5): per-µs bandwidth histograms, symmetry
groups, and straggler classification.

The paper's operational insights, made executable:
  * §5.1 — AR traffic is structurally uniform; any symmetry-group outlier
    flags a fault or misconfiguration.
  * §5.2 — healthy ranks blocked on a straggler show a *bi-modal* BW
    histogram (line rate or idle); the straggler itself fluctuates
    mid-range.
  * §5.3 — HFT time series (100 µs – 10 ms sampling) expose transient BW
    drops that standard polling misses.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# bandwidth histograms (§5.2)
# ---------------------------------------------------------------------------

def bw_histogram(samples: np.ndarray, nbins: int = 20) -> np.ndarray:
    """Per-µs BW samples normalized to line rate -> histogram (nbins,)."""
    h, _ = np.histogram(np.clip(samples, 0.0, 1.0), bins=nbins,
                        range=(0.0, 1.0))
    return h.astype(np.float64)


def classify_histogram(hist: np.ndarray,
                       edge_frac: float = 0.15) -> str:
    """'healthy-blocked' = bi-modal (idle | line rate) — a rank stalled on
    someone else; 'straggler' = mass in the mid-range — the slow rank
    itself; 'line-rate' = top-bin dominated."""
    n = hist.shape[0]
    total = hist.sum()
    if total <= 0:
        return "idle"            # no samples / no mass: nothing flowed
    # Clamp the edge windows to disjoint halves: with nbins < 1/edge_frac
    # the naive k would make hist[:k] and hist[-k:] overlap, double-count
    # the shared bins, and drive `mid` negative.
    k = max(1, min(int(n * edge_frac), n // 2)) if n > 1 else 1
    low, high = hist[:k].sum() / total, hist[-k:].sum() / total
    if n == 1:                   # single bin is both edges; all mass "mid"
        low = high = 0.0
    mid = max(0.0, 1.0 - low - high)
    if high > 0.85:
        return "line-rate"
    if mid < 0.25 and low > 0.05 and high > 0.05:
        return "healthy-blocked"
    if mid >= 0.25:
        return "straggler"
    return "idle" if low > 0.85 else "healthy-blocked"


def find_stragglers(per_rank_samples: np.ndarray) -> List[int]:
    """per_rank_samples: (ranks, T) normalized BW. Returns straggler ids."""
    out = []
    for r in range(per_rank_samples.shape[0]):
        if classify_histogram(bw_histogram(per_rank_samples[r])) == \
                "straggler":
            out.append(r)
    return out


# ---------------------------------------------------------------------------
# symmetry groups (§5.1)
# ---------------------------------------------------------------------------

@dataclass
class SymmetryReport:
    group: str
    uniform: bool
    cv: float                 # coefficient of variation
    outliers: List[int]


def symmetry_check(group: str, port_bw: np.ndarray,
                   cv_tol: float = 0.05, z_tol: float = 3.0
                   ) -> SymmetryReport:
    """AR produces structurally uniform load across a symmetry group (leaf
    uplinks, rails, planes); deviations indicate faults/misconfig."""
    bw = np.asarray(port_bw, np.float64)
    mu = bw.mean()
    sd = bw.std()
    cv = sd / mu if mu > 0 else 0.0
    z = np.abs(bw - mu) / max(sd, 1e-12)
    outliers = [int(i) for i in np.nonzero((z > z_tol) & (sd > 1e-9))[0]]
    return SymmetryReport(group=group, uniform=cv <= cv_tol, cv=float(cv),
                          outliers=outliers)


# ---------------------------------------------------------------------------
# HFT ring buffer + step-time straggler tracking (framework level)
# ---------------------------------------------------------------------------

@dataclass
class HFTBuffer:
    """Time-series telemetry at 100µs–10ms-equivalent cadence (here: per
    train-loop event)."""
    capacity: int = 4096
    records: Deque = field(default_factory=deque)

    def record(self, t: float, metrics: Dict[str, float]) -> None:
        self.records.append((t, dict(metrics)))
        while len(self.records) > self.capacity:
            self.records.popleft()

    def series(self, key: str) -> np.ndarray:
        return np.array([(t, m[key]) for t, m in self.records
                         if key in m])

    def drops(self, key: str, frac: float = 0.5) -> List[float]:
        """Timestamps where the metric transiently drops below frac×median
        (the §5.3 daemon-interference signature)."""
        s = self.series(key)
        if s.shape[0] < 4:
            return []
        med = np.median(s[:, 1])
        return [float(t) for t, v in s if v < frac * med]


class StepTimeTracker:
    """EWMA per-host step times -> straggler mitigation signal."""

    def __init__(self, n_hosts: int, ewma: float = 0.7,
                 threshold: float = 1.3):
        self.ewma = np.zeros(n_hosts)
        self.alpha = ewma
        self.threshold = threshold
        self.count = 0

    def update(self, step_times: np.ndarray) -> List[int]:
        st = np.asarray(step_times, np.float64)
        if self.count == 0:
            self.ewma = st.copy()
        else:
            self.ewma = self.alpha * self.ewma + (1 - self.alpha) * st
        self.count += 1
        med = np.median(self.ewma)
        return [int(i) for i in
                np.nonzero(self.ewma > self.threshold * med)[0]]
