"""NIC Plane Load Balancer (§4.3, Fig. 4) — per-(destination,)plane CC
contexts and the two-stage per-packet plane selection:

  1. **Rate filter** (E2E congestion): planes whose CC allowance falls below
     the current transmission rate are excluded.
  2. **Local queue selection**: among eligible planes, pick the shallowest
     local egress queue (mirrors switch adaptive routing).

State also tracks probe timeouts: consecutive missed RTT probes on a plane
remove it from the eligible set within a few RTTs (§4.4.1), entirely in
"hardware" (i.e. inside the jitted update, no host round-trip).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .congestion import SpxCCConfig, spx_cc_update
from .planes import PlaneConfig


@jax.tree_util.register_dataclass
@dataclass
class PLBState:
    rate_allow: jax.Array     # (P,) CC rate allowance per plane (0..1)
    ewma_goodput: jax.Array   # (P,) smoothed delivered fraction
    local_queue: jax.Array    # (P,) NIC egress queue proxy (0..1)
    probe_miss: jax.Array     # (P,) consecutive RTT-probe timeouts
    eligible: jax.Array       # (P,) bool: in the eligible set


def plb_init(n_planes: int) -> PLBState:
    p = n_planes
    return PLBState(
        rate_allow=jnp.ones((p,), jnp.float32),
        ewma_goodput=jnp.ones((p,), jnp.float32),
        local_queue=jnp.zeros((p,), jnp.float32),
        probe_miss=jnp.zeros((p,), jnp.int32),
        eligible=jnp.ones((p,), bool),
    )


def select_plane(state: PLBState, key: jax.Array,
                 tx_rate: float | jax.Array = 0.25) -> jax.Array:
    """Two-stage hierarchical selection for one packet (Fig. 4)."""
    ok = state.eligible & (state.rate_allow >= tx_rate)
    # if the rate filter empties the set, fall back to eligible planes
    any_ok = jnp.any(ok)
    ok = jnp.where(any_ok, ok, state.eligible)
    q = jnp.where(ok, state.local_queue, jnp.inf)
    noise = jax.random.uniform(key, q.shape, maxval=1e-3)
    return jnp.argmin(q + noise)


def select_planes(state: PLBState, keys: jax.Array,
                  tx_rate: float = 0.25) -> jax.Array:
    """Vectorized per-packet selection; keys: (N, 2) uint32 PRNG keys."""
    return jax.vmap(lambda k: select_plane(state, k, tx_rate))(keys)


def plb_update(state: PLBState, plane_rtt_us: jax.Array,
               plane_ecn: jax.Array, plane_delivered: jax.Array,
               probe_ok: jax.Array, plane_queue: jax.Array,
               cfg: PlaneConfig = PlaneConfig(),
               cc: SpxCCConfig = SpxCCConfig()) -> PLBState:
    """One control interval (a few RTTs): update per-plane CC contexts from
    their own signals — a congested/failed plane never throttles healthy
    ones (the paper's Global-CC failure mode)."""
    rate = spx_cc_update(state.rate_allow, plane_rtt_us, plane_ecn, cc)
    miss = jnp.where(probe_ok, 0, state.probe_miss + 1)
    eligible = miss < cfg.probe_timeout
    # a failed plane's allowance collapses; restored planes ramp from ewma
    rate = jnp.where(eligible, rate, cc.min_rate)
    just_restored = eligible & ~state.eligible
    rate = jnp.where(just_restored, jnp.maximum(rate, 0.5), rate)
    gp = cfg.ewma * state.ewma_goodput + (1 - cfg.ewma) * plane_delivered
    return PLBState(rate_allow=rate, ewma_goodput=gp,
                    local_queue=plane_queue.astype(jnp.float32),
                    probe_miss=miss, eligible=eligible)


def plane_weights(state: PLBState) -> jax.Array:
    """Normalized chunk weights for the collective engine: healthy planes
    weighted by their CC allowance."""
    w = jnp.where(state.eligible, state.rate_allow, 0.0)
    s = jnp.sum(w)
    p = w.shape[0]
    return jnp.where(s > 0, w / jnp.maximum(s, 1e-9),
                     jnp.full((p,), 1.0 / p))
