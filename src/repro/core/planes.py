"""Multi-plane configuration and payload-split math (§3.1, §4.3).

A plane at the framework level is a parallel collective *stream*: every DP
gradient bucket is split into micro-chunks and each micro-chunk is assigned
to a plane.  Assignment never changes numerics (summation commutes — the
paper's out-of-order-tolerance analogue); it drives stream scheduling,
telemetry, and the failover performance model.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class PlaneConfig:
    n_planes: int = 4
    microchunks: int = 16         # collective streams per bucket (>= planes)
    bucket_mb: float = 4.0
    compression: str = "none"     # 'none' | 'int8'
    recovery_steps: int = 2       # PLB convergence budget ("a few RTTs")
    probe_timeout: int = 3        # consecutive probe misses -> plane failed
    ewma: float = 0.5             # per-plane goodput/latency EWMA factor

    def __post_init__(self):
        assert self.n_planes >= 1
        assert self.microchunks >= self.n_planes


def apportion(weights: np.ndarray, k: int) -> np.ndarray:
    """Largest-remainder apportionment of k micro-chunks to planes.

    weights: (P,) nonnegative; returns (k,) plane ids.  Zero-weight planes
    receive no chunks.  Deterministic.
    """
    w = np.asarray(weights, np.float64)
    P = w.shape[0]
    if w.sum() <= 0:
        w = np.ones(P)
    w = w / w.sum()
    ideal = w * k
    base = np.floor(ideal).astype(int)
    rem = k - base.sum()
    order = np.argsort(-(ideal - base), kind="stable")
    for i in range(rem):
        base[order[i % P]] += 1
    out = np.repeat(np.arange(P), base)
    assert out.shape[0] == k
    return out


def plane_loads(assignment: np.ndarray, n_planes: int,
                chunk_bytes: np.ndarray | float) -> np.ndarray:
    """Bytes per plane for a chunk->plane assignment."""
    loads = np.zeros(n_planes)
    cb = np.broadcast_to(np.asarray(chunk_bytes, np.float64),
                         assignment.shape)
    np.add.at(loads, assignment, cb)
    return loads


def effective_bandwidth(weights: np.ndarray, assignment: np.ndarray,
                        plane_rate: np.ndarray) -> float:
    """Normalized goodput of a chunked transfer: the slowest plane finishing
    its assigned share gates completion (the paper's 'dictated by the
    slowest plane' failure mode for load-oblivious spraying)."""
    P = plane_rate.shape[0]
    loads = plane_loads(assignment, P, 1.0)
    loads = loads / max(loads.sum(), 1e-12)
    t = np.where(loads > 0, loads / np.maximum(plane_rate, 1e-9), 0.0)
    tmax = t.max()
    return 1.0 / (P * tmax) if tmax > 0 else 1.0
