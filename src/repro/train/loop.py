"""Training loop: plane-split DP sync, straggler mitigation, failover,
checkpoint/restart.

Step structure (multi-pod mesh, FSDP on):
  * grads over the scale-out ('pod') axis are synchronized EXPLICITLY by the
    plane collective engine (the paper's multi-plane NIC traffic);
  * FSDP ('data') reduce-scatters and TP ('model') collectives are GSPMD-
    inserted (the intra-pod NVLink/ICI domain, out of scope for the paper).

The loop threads a host-side ``FailoverController`` (PLB state) and
telemetry through steps; plane failures re-weight micro-chunk streams
within ``recovery_steps`` without touching numerics.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.collectives import plane_allreduce, stream_report
from ..core.fault_tolerance import FailoverController
from ..core.planes import PlaneConfig, effective_bandwidth, apportion
from ..core.telemetry import HFTBuffer, StepTimeTracker
from ..models import loss_fn
from ..models.config import ModelConfig
from ..optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                           cosine_schedule)
from ..parallel.sharding import ShardCtx


@dataclass(frozen=True)
class TrainerConfig:
    plane: PlaneConfig = PlaneConfig()
    adamw: AdamWConfig = AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 1000
    aux_weight: float = 0.01
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    ckpt_keep: int = 3
    # Cast >=2-D fp32 params to bf16 BEFORE the layer stack consumes them,
    # so FSDP/TP weight all-gathers move bf16 (2x wire reduction). The
    # model casts at use anyway; master params/optimizer stay fp32.
    cast_params_bf16: bool = True


def make_train_step(cfg: ModelConfig, ctx: ShardCtx, tcfg: TrainerConfig):
    """Returns jitted step(params, opt_state, batch, step, key) ->
    (params, opt_state, metrics)."""
    plane_axes = ctx.plane_axes if ctx.mesh is not None else ()
    plane_axes = tuple(a for a in plane_axes
                       if ctx.mesh is not None and ctx.mesh.shape[a] > 1)

    def _cast(params):
        if not tcfg.cast_params_bf16:
            return params
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if (p.dtype == jnp.float32 and p.ndim > 1) else p, params)

    def local_loss(params, batch):
        return loss_fn(_cast(params), cfg, batch, ctx, tcfg.aux_weight)[0]

    def grads_fn(params, batch, key):
        if not plane_axes:
            return jax.value_and_grad(local_loss)(params, batch)

        def dp_body(p, b, k):
            loss, grads = jax.value_and_grad(
                lambda pp: local_loss(pp, b))(p)
            grads = plane_allreduce(grads, plane_axes, tcfg.plane, key=k)
            return jax.lax.pmean(loss, plane_axes), grads

        bspec = jax.tree.map(
            lambda x: P(plane_axes if x.shape[0] % _axes_size(ctx,
                        plane_axes) == 0 else None), batch)
        return jax.shard_map(
            dp_body, mesh=ctx.mesh,
            in_specs=(P(), bspec, P()),
            out_specs=(P(), P()),
            axis_names=set(plane_axes), check_vma=False)(params, batch, key)

    def step_fn(params, opt_state, batch, step, key):
        loss, grads = grads_fn(params, batch, key)
        lr_scale = cosine_schedule(step, tcfg.warmup_steps, tcfg.total_steps)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             tcfg.adamw, lr_scale)
        metrics = {"loss": loss, "grad_norm": om["grad_norm"],
                   "lr_scale": lr_scale}
        return params, opt_state, metrics

    return jax.jit(step_fn, donate_argnums=(0, 1))


def _axes_size(ctx: ShardCtx, axes) -> int:
    n = 1
    for a in axes:
        n *= ctx.mesh.shape[a]
    return n


class Trainer:
    """Host-side orchestration: data, telemetry, failover, checkpoints."""

    def __init__(self, cfg: ModelConfig, ctx: ShardCtx, tcfg: TrainerConfig,
                 params, opt_state=None, start_step: int = 0):
        self.cfg, self.ctx, self.tcfg = cfg, ctx, tcfg
        self.params = params
        self.opt_state = opt_state if opt_state is not None else \
            adamw_init(params)
        self.step = start_step
        self.step_fn = make_train_step(cfg, ctx, tcfg)
        self.failover = FailoverController(tcfg.plane)
        self.hft = HFTBuffer()
        n_hosts = 1 if ctx.mesh is None else ctx.mesh.devices.size
        self.step_times = StepTimeTracker(min(n_hosts, 64))
        self.history: list = []
        self._report = None

    # -- fault hooks -------------------------------------------------------
    def inject_plane_failure(self, plane: int) -> None:
        self.failover.fail_plane(plane)

    def heal_plane(self, plane: int) -> None:
        self.failover.restore_plane(plane)

    # -- one step ----------------------------------------------------------
    def train_step(self, batch: Dict[str, Any]) -> Dict[str, float]:
        t0 = time.perf_counter()
        weights = self.failover.on_step()
        key = jax.random.fold_in(jax.random.PRNGKey(17), self.step)
        self.params, self.opt_state, metrics = self.step_fn(
            self.params, self.opt_state, batch,
            jnp.asarray(self.step, jnp.int32), key)
        metrics = {k: float(v) for k, v in metrics.items()}
        wall = time.perf_counter() - t0

        # plane-level accounting: the slowest plane gates the collective
        # (byte-aware LPT stream assignment; see core.collectives)
        report = stream_report(self.params, self.tcfg.plane, weights)
        self._report = report
        plane_rate = np.where(self.failover.plane_up, 1.0, 1e-3)
        share = report.bytes_per_plane / max(report.chunk_bytes.sum(), 1e-9)
        t = np.where(share > 0, share / np.maximum(plane_rate, 1e-9), 0.0)
        tmax = float(t.max())
        eff = 1.0 / (self.tcfg.plane.n_planes * tmax) if tmax > 0 else 1.0
        metrics.update(step_time_s=wall, plane_eff_bw=float(eff),
                       planes_up=int(self.failover.plane_up.sum()))
        self.hft.record(float(self.step), metrics)
        self.history.append(metrics)
        self.step += 1

        if (self.tcfg.ckpt_dir and
                self.step % self.tcfg.ckpt_every == 0):
            self.save()
        return metrics

    # -- checkpointing -----------------------------------------------------
    def save(self) -> str:
        from ..checkpoint.ckpt import save_checkpoint, prune_checkpoints
        path = save_checkpoint(
            self.tcfg.ckpt_dir, self.step,
            {"params": self.params, "opt": self.opt_state},
            extras={"model": self.cfg.name})
        prune_checkpoints(self.tcfg.ckpt_dir, self.tcfg.ckpt_keep)
        return path

    @classmethod
    def restore(cls, cfg: ModelConfig, ctx: ShardCtx, tcfg: TrainerConfig,
                template_params, shardings=None) -> "Trainer":
        from ..checkpoint.ckpt import restore_checkpoint
        from ..optim.adamw import adamw_init
        tmpl = {"params": template_params,
                "opt": adamw_init(template_params)}
        tree, step, _ = restore_checkpoint(tcfg.ckpt_dir, tmpl, shardings)
        return cls(cfg, ctx, tcfg, tree["params"], tree["opt"],
                   start_step=step)
