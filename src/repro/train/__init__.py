from .loop import Trainer, TrainerConfig, make_train_step
from .serving import ServeEngine, Request
