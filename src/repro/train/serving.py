"""Batched serving engine: slot-based continuous batching over a fixed
decode batch, with jitted prefill and decode steps.

The decode step is the artifact lowered for the ``decode_*`` / ``long_*``
dry-run shapes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_caches, prefill_step
from ..models.config import ModelConfig
from ..parallel.sharding import ShardCtx


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, ctx: ShardCtx, params,
                 batch: int, max_len: int, greedy: bool = True):
        self.cfg, self.ctx, self.params = cfg, ctx, params
        self.batch, self.max_len = batch, max_len
        self.greedy = greedy
        dtype = jnp.dtype(cfg.dtype)
        self.caches = init_caches(cfg, batch, max_len, dtype)
        self.slots: List[Optional[Request]] = [None] * batch
        self.positions = np.zeros(batch, np.int32)
        self.next_tok = np.zeros(batch, np.int32)

        self._prefill = jax.jit(
            lambda p, t, c: prefill_step(p, cfg, t, ctx, c))
        self._decode = jax.jit(
            lambda p, t, q, c: decode_step(p, cfg, t, q, ctx, c))

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        """Prefill a request into a free slot (one-slot batch prefill)."""
        try:
            slot = self.slots.index(None)
        except ValueError:
            return False
        s = req.prompt.shape[0]
        toks = np.zeros((self.batch, s), np.int32)
        toks[slot] = req.prompt
        # per-slot prefill: re-run prefill for this slot only by masking —
        # caches are per-slot along batch so other slots are untouched only
        # if we write solely slot rows; simplest correct route: prefill all
        # rows but restore other slots' cache rows afterwards.
        logits, new_caches = self._prefill(self.params, jnp.asarray(toks),
                                           self.caches)
        self.caches = jax.tree.map(
            lambda old, new: old.at[slot].set(new[slot])
            if hasattr(old, "at") and old.shape[:1] == (self.batch,)
            else new, self.caches, new_caches)
        self.slots[slot] = req
        self.positions[slot] = s
        self.next_tok[slot] = int(jnp.argmax(logits[slot, -1]))
        return True

    def step(self) -> None:
        """One decode step for all active slots."""
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return
        toks = jnp.asarray(self.next_tok[:, None])
        pos = jnp.asarray(self.positions)
        logits, self.caches = self._decode(self.params, toks, pos,
                                           self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for i in active:
            req = self.slots[i]
            req.out.append(int(self.next_tok[i]))
            self.positions[i] += 1
            self.next_tok[i] = nxt[i]
            if (len(req.out) >= req.max_new or
                    self.positions[i] >= self.max_len - 1):
                req.done = True
                self.slots[i] = None

    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> List[Request]:
        pending = list(requests)
        finished: List[Request] = []
        steps = 0
        while (pending or any(self.slots)) and steps < max_steps:
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            self.step()
            finished.extend(r for r in requests
                            if r.done and r not in finished)
            steps += 1
        return finished
