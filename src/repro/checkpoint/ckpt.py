"""Sharded checkpointing with atomic commit and elastic restore.

Layout:  <dir>/step_<k>/
            manifest.json       tree structure, shapes, dtypes, step, extras
            arrays.npz          flattened leaves (host-gathered)
         <dir>/LATEST           committed pointer (written last — atomic)

Restore re-shards onto whatever mesh the surviving cluster offers (elastic
restart after permanent failures) via ``jax.device_put`` with the new
sharding tree.  Leaves are addressed by tree path so a restore works even
if auxiliary fields were added/removed.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree,
                    extras: Optional[Dict] = None) -> str:
    """Host-gather all leaves and commit atomically."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extras": extras or {},
    }
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(f"step_{step:08d}")
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, target_tree,
                       shardings=None, step: Optional[int] = None,
                       ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``target_tree``; missing keys keep the
    target's value, extra keys are ignored (elastic / forward-compatible).
    ``shardings``: optional matching tree of NamedSharding for re-shard."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_target = _flatten_with_paths(target_tree)
    flat_shard = (_flatten_with_paths(shardings)
                  if shardings is not None else {})
    out = {}
    for key, tgt in flat_target.items():
        if key in data.files:
            arr = data[key]
            if list(arr.shape) != list(np.shape(tgt)):
                raise ValueError(
                    f"checkpoint leaf {key} shape {arr.shape} != "
                    f"target {np.shape(tgt)} — reshard topology mismatch")
            val = arr.astype(np.asarray(tgt).dtype if hasattr(tgt, "dtype")
                             else arr.dtype)
            if key in flat_shard:
                val = jax.device_put(val, flat_shard[key])
            out[key] = val
        else:
            out[key] = tgt

    leaves_with_path = jax.tree_util.tree_flatten_with_path(target_tree)
    treedef = leaves_with_path[1]
    ordered = []
    for pth, _ in leaves_with_path[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        ordered.append(out[key])
    return (jax.tree_util.tree_unflatten(treedef, ordered), step,
            manifest["extras"])


def prune_checkpoints(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
