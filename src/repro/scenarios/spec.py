"""Declarative scenario DSL: tenants, workloads, faults, and simulation
parameters composed into a single picklable `ScenarioSpec`.

A scenario is data, not code: the spec layer carries *what* to simulate
(topology shape, which hosts belong to which tenant, which collective each
tenant runs, which links fail when), `compile.py` lowers it to the
`(topo, flows, events)` triple `netsim.sim.run_sim` consumes, and
`runner.py` sweeps it over (seed, routing, nic) grids.  Everything here is
a frozen dataclass so specs hash, compare, and cross process boundaries.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

from repro.trace import TraceSpec

WORKLOAD_KINDS = ("bisection", "all2all", "allreduce", "incast",
                  "permutation", "storage", "pairs", "one2many",
                  "schedule")
FAULT_KINDS = ("link_kill", "link_flap", "access_kill", "access_flap",
               "cascade", "straggler", "leaf_trim", "random_fail",
               "core_kill", "poisson_flap")
PLACEMENTS = ("block", "interleave", "random", "remainder", "explicit")
ROUTINGS = ("ar", "war", "ecmp")
NICS = ("spx", "dcqcn", "global", "esr", "swlb")
BACKENDS = ("numpy", "jax")
TOPOLOGY_KINDS = ("leaf_spine", "fat_tree")


class FaultBoundsError(ValueError):
    """A `FaultSpec` addresses a plane/leaf/spine/agg/pod/core/host
    outside the scenario's topology shape."""


@dataclass(frozen=True)
class TopologySpec:
    """Shape of the fabric.

    kind:
      'leaf_spine' — flat multiplane leaf–spine (mirrors `LeafSpine`):
                     one switching stage, `n_spines` paths per plane.
      'fat_tree'   — 3-tier leaf–agg–core baseline (mirrors `FatTree`):
                     `n_pods` pods of `n_leaves / n_pods` leaves and
                     `n_aggs` agg switches each, `n_cores` core switches
                     (a multiple of `n_aggs`; core `j` serves agg
                     `j // (n_cores // n_aggs)` in every pod).  `n_spines`
                     is unused.  `core_link_cap` <= 0 inherits
                     `uplink_cap`; oversubscription = host capacity
                     per leaf vs `n_aggs * uplink_cap` (stage A) and
                     agg ingress vs its core bundle (stage B).

    The fat-tree fields elide from content hashes at their defaults
    (`HASH_ELIDE_DEFAULTS`), so pre-existing leaf-spine specs keep their
    cache keys across this schema extension.
    """
    n_leaves: int = 8
    n_spines: int = 8
    hosts_per_leaf: int = 8
    n_planes: int = 1
    parallel_links: int = 1
    link_cap: float = 1.0
    access_cap: float = 1.0
    kind: str = "leaf_spine"
    n_pods: int = 1
    n_aggs: int = 1
    n_cores: int = 1
    core_link_cap: float = 0.0

    HASH_ELIDE_DEFAULTS = ("kind", "n_pods", "n_aggs", "n_cores",
                           "core_link_cap")

    @property
    def n_hosts(self) -> int:
        return self.n_leaves * self.hosts_per_leaf

    @property
    def uplink_cap(self) -> float:
        return self.link_cap * self.parallel_links

    @property
    def leaves_per_pod(self) -> int:
        return self.n_leaves // self.n_pods

    @property
    def core_cap(self) -> float:
        return (self.core_link_cap if self.core_link_cap > 0
                else self.uplink_cap)

    @property
    def n_paths(self) -> int:
        """Per-(leaf pair, plane) routing-choice axis: spines for
        leaf_spine, cores for fat_tree."""
        return self.n_spines if self.kind == "leaf_spine" else self.n_cores

    def validate(self, name: str = "topo") -> "TopologySpec":
        if self.kind not in TOPOLOGY_KINDS:
            raise ValueError(f"{name}: unknown topology kind "
                             f"{self.kind!r}; known: {TOPOLOGY_KINDS}")
        if self.kind == "fat_tree":
            if self.n_pods < 2:
                raise ValueError(
                    f"{name}: fat_tree requires n_pods >= 2 "
                    f"(got {self.n_pods}); use kind='leaf_spine' for a "
                    "single-stage fabric")
            if self.n_leaves % self.n_pods != 0:
                raise ValueError(
                    f"{name}: n_leaves ({self.n_leaves}) must be "
                    f"divisible by n_pods ({self.n_pods})")
            if self.n_aggs < 1 or self.n_cores % self.n_aggs != 0 \
                    or self.n_cores < self.n_aggs:
                raise ValueError(
                    f"{name}: n_cores ({self.n_cores}) must be a "
                    f"positive multiple of n_aggs ({self.n_aggs})")
        return self


@dataclass(frozen=True)
class TenantSpec:
    """A named set of hosts.  Tenants are resolved in declaration order and
    never overlap; each workload targets one tenant by name.

    placement:
      'explicit'   — use `hosts` verbatim.
      'block'      — `n_hosts` consecutive hosts starting at `offset`.
      'interleave' — every `stride`-th host starting at `offset`
                     (the paper's random-uniform placement proxy).
      'random'     — `n_hosts` drawn without replacement from the
                     still-unassigned pool (consumes workload rng).
      'remainder'  — every host not claimed by an earlier tenant.
    """
    name: str
    placement: str = "remainder"
    hosts: Tuple[int, ...] = ()
    n_hosts: Optional[int] = None
    offset: int = 0
    stride: int = 1


@dataclass(frozen=True)
class ScheduleSpec:
    """A training-step collective schedule to co-simulate (kind='schedule').

    Pure data: `model` names an entry in `repro.configs.ARCHS`; byte
    volumes are derived at compile time by `repro.comms` from the model's
    parameter pytree (dtype-aware micro-chunk streams), MoE capacity math,
    and pipeline activation sizes — nothing heavy happens at spec time.

    Rank layout over the tenant's hosts is tp-fastest:
    ``rank = t + tp * (d + dp * p)`` for tp-coordinate `t`, dp-coordinate
    `d`, pp-stage `p`; the tenant must own at least ``dp * tp * pp`` hosts.

    `reduced` swaps in `ModelConfig.reduced()` (same family, tiny dims) so
    registry scenarios stay numpy-fast for golden snapshots; production
    sweeps set it False.  `line_rate_gbps` calibrates real bytes to
    simulator units: 1.0 capacity = one slot at line rate, i.e.
    ``sim_bytes = real_bytes / (line_rate_gbps * 125 * slot_us)``.
    `ckpt_every` > 0 adds background checkpoint-write flows after every
    k-th step (group 'ckpt').
    """
    model: str = "llama3-8b"
    dp: int = 2
    tp: int = 1
    pp: int = 1
    steps: int = 2
    microbatches: int = 4
    tokens_per_rank: int = 2048
    line_rate_gbps: float = 400.0
    ckpt_every: int = 0
    reduced: bool = True

    @property
    def n_ranks(self) -> int:
        return self.dp * self.tp * self.pp

    def validate(self, name: str) -> "ScheduleSpec":
        for f in ("dp", "tp", "pp", "steps", "microbatches",
                  "tokens_per_rank"):
            if getattr(self, f) < 1:
                raise ValueError(
                    f"{name}: schedule.{f} must be >= 1, got "
                    f"{getattr(self, f)}")
        if self.line_rate_gbps <= 0:
            raise ValueError(
                f"{name}: schedule.line_rate_gbps must be > 0, got "
                f"{self.line_rate_gbps}")
        if self.ckpt_every < 0:
            raise ValueError(
                f"{name}: schedule.ckpt_every must be >= 0, got "
                f"{self.ckpt_every}")
        if self.dp < 2:
            raise ValueError(
                f"{name}: schedule requires dp >= 2 (got {self.dp}) — "
                "the per-step DP gradient sync is what defines step "
                "completion")
        return self


@dataclass(frozen=True)
class WorkloadSpec:
    """One traffic pattern bound to a tenant.

    kind:
      'bisection'   — worst-case cross-spine pairing at line rate (Fig 8).
      'all2all'     — full-mesh, per-flow demand 1/(n-1) (Fig 9).
      'allreduce'   — ring neighbor streams (AllGather/ReduceScatter).
      'incast'      — every non-sink tenant host sends to `sinks` sinks.
      'permutation' — random ring over a shuffled host order.
      'storage'     — low-rate background: each host to `fanout` random
                      peers (checkpoint/dataset traffic proxy).
      'pairs'       — explicit (src, dst) list.
      'one2many'    — the tenant's first `srcs` hosts each stream to
                      every remaining host, per-flow demand
                      `demand / n_dsts` (Fig 15's burst pattern).
      'schedule'    — a compiled training-step collective schedule
                      (`schedule` field): DP ring allreduce streams, MoE
                      all2all dispatch, PP send/recv edges, and optional
                      checkpoint writes, phased over time via the
                      demand-multiplier timeline (`repro.comms`).

    `demand` scales the builder's native per-flow rate ('incast',
    'permutation', 'storage', 'pairs' use it directly as the per-flow
    offered rate).  `bytes_total` turns an open-loop stream into a
    finite transfer (enables completion-tail metrics); `start_slot`
    delays admission (staggered bursts).
    """
    kind: str
    tenant: str = "main"
    demand: float = 1.0
    bytes_total: float = float("inf")
    start_slot: int = 0
    sinks: int = 1                       # incast
    fanout: int = 2                      # storage
    srcs: int = 1                        # one2many
    pairs: Tuple[Tuple[int, int], ...] = ()
    group: Optional[str] = None          # metric group; default = tenant
    schedule: Optional[ScheduleSpec] = None   # kind='schedule' only

    # `schedule` elides from content hashes at its default so every
    # pre-existing spec keeps its cache key across this schema extension.
    HASH_ELIDE_DEFAULTS = ("schedule",)


@dataclass(frozen=True)
class FaultSpec:
    """One failure/degradation schedule applied to the topology.

    kind:
      'link_kill'   — remove `frac` of (plane, leaf, spine) uplink at
                      `start_slot`; restore at `stop_slot` if set.
      'link_flap'   — periodic kill/restore of one uplink: down for
                      `duty`×`period` slots of every `period`, between
                      `start_slot` and `stop_slot`.
      'access_kill' — host NIC-plane port down at `start_slot`
                      (restored at `stop_slot` if set).
      'access_flap' — periodic version of access_kill.
      'cascade'     — rolling switch loss: spine `spines[i]` dies (all
                      leaves) at `start_slot + i*period`.  On fat_tree
                      the indices address agg switches of pod `pod`,
                      and the whole switch dies: its leaf links AND its
                      core links.
      'straggler'   — host access capacity scaled to `frac` between
                      `start_slot` and `stop_slot` (slow-rank injection).
      'leaf_trim'   — leaf uplink capacity scaled to `frac` at
                      `start_slot` (Fig 16 consolidation).
      'random_fail' — random fabric link failures at `start_slot`:
                      `count` = 0 fails each link independently with
                      probability `frac` (Fig 1c / §6.4); `count` > 0
                      draws exactly `count` fabric links per selected
                      plane and multiplies each by `1 - frac` — `frac=1`
                      kills the link outright (Fig 14a's k-concurrent-
                      failure sweeps).  On fat_tree both stages (leaf–agg
                      and pod–core links) are in the draw population.
      'core_kill'   — fat_tree only: remove `frac` of the (plane, pod,
                      core) stage-B link pair at `start_slot`; restore at
                      `stop_slot` if set (the tier the multiplane design
                      deletes — §3.1).
      'poisson_flap'— fleet-MTBF flap storm (§6.6): every fabric link on
                      the selected plane(s) flaps independently with
                      exponential inter-arrivals so the *fleet-wide* rate
                      is `flaps_per_min`; each flap multiplies the link
                      by `1 - frac` for `down_slots` slots.  Arrival
                      times come from `core.fault_tolerance.poisson_flaps`
                      seeded by (workload_seed, fault index), so both
                      backends replay the identical schedule.

    `plane` = -1 applies to every plane.  On fat_tree topologies `spine`
    addresses the pod-local agg index for link faults.  `validate()`
    bound-checks every index a fault uses against the topology shape and
    raises `FaultBoundsError` otherwise.

    New tier fields (`pod`, `core`) elide from content hashes at their
    defaults so pre-existing specs keep their cache keys.
    """
    kind: str
    start_slot: int = 0
    stop_slot: Optional[int] = None
    period: int = 0
    duty: float = 0.5
    plane: int = 0
    leaf: int = 0
    spine: int = 0
    spines: Tuple[int, ...] = ()
    host: int = 0
    frac: float = 1.0
    count: int = 0                       # random_fail: exact-k mode
    pod: int = 0                         # core_kill / fat_tree cascade
    core: int = 0                        # core_kill
    flaps_per_min: float = 0.0           # poisson_flap: fleet-wide rate
    down_slots: int = 0                  # poisson_flap: outage length

    HASH_ELIDE_DEFAULTS = ("pod", "core", "flaps_per_min", "down_slots")


REACTION_MODES = ("instant", "rehash", "backup")


@dataclass(frozen=True)
class ReactionSpec:
    """How routing *reacts* to fabric faults — the paper's <3 ms
    hardware failover vs ~1 s software LB distinction (§6.4, and the
    MRC/SRv6 precomputed-backup design point).

    Without a reaction spec (the default), routing sees every capacity
    change the same slot it happens — instantaneous, perfect detection.
    With one, routing steers against a *visible* copy of the fabric that
    lags physical state by `detect_slots`: a failed link keeps
    attracting traffic (black-holed bytes) until detection fires.

    mode:
      'instant' — reproduce the no-reaction behavior bit-identically
                  (requires both delays zero; useful as a sweep axis
                  baseline).
      'rehash'  — software-LB analog: after detection, the control
                  plane takes a further `converge_slots` to push new
                  state; ECMP flows on dead paths then re-hash onto
                  survivors (the usual seeded draw).  Total lag =
                  `detect_slots + converge_slots`.
      'backup'  — hardware fast-reroute analog (MRC/SRv6): the slot
                  detection fires, affected (flow, plane) entries switch
                  to the next alive path in a backup table precomputed
                  per fabric kind at compile time — no RNG, no extra
                  convergence.  Total lag = `detect_slots`.

    `converge_slots` is read by 'rehash' only; 'backup' ignores it (so a
    sweep can hold it fixed while toggling the mode axis)."""
    detect_slots: int = 0
    mode: str = "instant"
    converge_slots: int = 0

    @property
    def enabled(self) -> bool:
        """True when the reaction layer changes behavior at all."""
        return self.mode != "instant"


def reaction_lag(reaction: Optional[ReactionSpec], routing: str) -> int:
    """Slots by which the routing-visible fabric lags physical state.
    One number per run — shared by both backends so the lowering cannot
    drift.  `routing` is accepted for future mode/routing interplay;
    today the lag is routing-independent."""
    if reaction is None or not reaction.enabled:
        return 0
    lag = reaction.detect_slots
    if reaction.mode == "rehash":
        lag += reaction.converge_slots
    return lag


@dataclass(frozen=True)
class SimSpec:
    """Simulation parameters (mirrors `netsim.sim.SimConfig`)."""
    slots: int = 400
    slot_us: float = 10.0
    routing: str = "ar"          # 'ar' | 'war' | 'ecmp'
    nic: str = "spx"             # 'spx' | 'dcqcn' | 'global' | 'esr' | 'swlb'
    base_rtt_us: float = 4.0
    warmup_frac: float = 0.25
    sw_lb_delay_ms: float = 1000.0
    seed: int = 0
    record_every: int = 1
    backend: str = "numpy"       # 'numpy' | 'jax'
    trace: TraceSpec = TraceSpec()

    # Tracing never changes simulated physics, and the default spec is
    # elided from the canonical hash, so pre-trace cache entries and
    # spec keys stay valid.
    HASH_ELIDE_DEFAULTS = ("trace",)


@dataclass(frozen=True)
class ScenarioSpec:
    """A complete, self-describing experiment."""
    name: str
    topo: TopologySpec = field(default_factory=TopologySpec)
    tenants: Tuple[TenantSpec, ...] = (TenantSpec("main"),)
    workloads: Tuple[WorkloadSpec, ...] = ()
    faults: Tuple[FaultSpec, ...] = ()
    sim: SimSpec = field(default_factory=SimSpec)
    workload_seed: int = 0
    description: str = ""
    reaction: Optional[ReactionSpec] = None

    # `reaction` elides from content hashes at its default so every
    # pre-existing spec keeps its cache key across this schema extension.
    HASH_ELIDE_DEFAULTS = ("reaction",)

    # ---- ergonomic copies -------------------------------------------------
    def with_sim(self, **kw) -> "ScenarioSpec":
        """Copy with SimSpec fields replaced (nic/routing/slots/seed/...)."""
        return replace(self, sim=replace(self.sim, **kw))

    def with_workload_seed(self, seed: int) -> "ScenarioSpec":
        return replace(self, workload_seed=seed)

    def validate(self) -> "ScenarioSpec":
        self.topo.validate(f"{self.name}: topo")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate tenant names {names}")
        for t in self.tenants:
            if t.placement not in PLACEMENTS:
                raise ValueError(
                    f"{self.name}: unknown placement {t.placement!r}")
            if t.placement == "explicit" and not t.hosts:
                raise ValueError(
                    f"{self.name}: tenant {t.name} explicit but no hosts")
        for w in self.workloads:
            if w.kind not in WORKLOAD_KINDS:
                raise ValueError(f"{self.name}: unknown workload {w.kind!r}")
            if w.tenant not in names:
                raise ValueError(
                    f"{self.name}: workload targets unknown tenant "
                    f"{w.tenant!r}")
            if w.kind == "one2many" and w.srcs < 1:
                raise ValueError(
                    f"{self.name}: one2many requires srcs >= 1, got "
                    f"{w.srcs}")
            if w.kind == "pairs":
                bad = [p for p in w.pairs
                       for h in p if not 0 <= h < self.topo.n_hosts]
                if bad:
                    raise ValueError(
                        f"{self.name}: pairs endpoints outside "
                        f"[0, {self.topo.n_hosts}): {bad}")
            if w.kind == "schedule":
                if w.schedule is None:
                    raise ValueError(
                        f"{self.name}: schedule workload requires the "
                        "schedule field")
                w.schedule.validate(self.name)
                if w.schedule.n_ranks > self.topo.n_hosts:
                    raise ValueError(
                        f"{self.name}: schedule needs "
                        f"{w.schedule.n_ranks} ranks but the topology "
                        f"has only {self.topo.n_hosts} hosts")
            elif w.schedule is not None:
                raise ValueError(
                    f"{self.name}: schedule field set on a "
                    f"{w.kind!r} workload (only kind='schedule' uses it)")
        for f in self.faults:
            if f.kind not in FAULT_KINDS:
                raise ValueError(f"{self.name}: unknown fault {f.kind!r}")
            if f.kind in ("link_flap", "access_flap", "cascade") \
                    and f.period <= 0:
                raise ValueError(
                    f"{self.name}: {f.kind} requires period > 0, got "
                    f"{f.period}")
            if f.kind == "cascade" and not f.spines:
                raise ValueError(f"{self.name}: cascade requires spines")
            if f.kind == "poisson_flap":
                if f.flaps_per_min <= 0:
                    raise ValueError(
                        f"{self.name}: poisson_flap requires "
                        f"flaps_per_min > 0, got {f.flaps_per_min}")
                if f.down_slots <= 0:
                    raise ValueError(
                        f"{self.name}: poisson_flap requires "
                        f"down_slots >= 1, got {f.down_slots}")
            else:
                if f.flaps_per_min or f.down_slots:
                    raise ValueError(
                        f"{self.name}: flaps_per_min/down_slots apply "
                        f"only to poisson_flap, not {f.kind!r}")
            if f.count < 0:
                raise ValueError(
                    f"{self.name}: fault count must be >= 0, got "
                    f"{f.count}")
            if f.count and f.kind != "random_fail":
                raise ValueError(
                    f"{self.name}: count applies only to random_fail, "
                    f"not {f.kind!r}")
            _check_fault_bounds(self.name, f, self.topo)
        if self.reaction is not None:
            r = self.reaction
            if r.mode not in REACTION_MODES:
                raise ValueError(
                    f"{self.name}: unknown reaction mode {r.mode!r}; "
                    f"known: {REACTION_MODES}")
            if r.detect_slots < 0 or r.converge_slots < 0:
                raise ValueError(
                    f"{self.name}: reaction delays must be >= 0, got "
                    f"detect_slots={r.detect_slots} "
                    f"converge_slots={r.converge_slots}")
            if r.mode == "instant" and (r.detect_slots or
                                        r.converge_slots):
                raise ValueError(
                    f"{self.name}: reaction mode 'instant' requires "
                    "zero detect_slots/converge_slots (got "
                    f"detect_slots={r.detect_slots} "
                    f"converge_slots={r.converge_slots}); pick 'rehash' "
                    "or 'backup' for a delayed reaction")
            bad_kinds = sorted({f.kind for f in self.faults
                                if f.kind == "straggler"})
            if r.enabled and bad_kinds:
                raise ValueError(
                    f"{self.name}: reaction mode {r.mode!r} is "
                    f"incompatible with fault kinds {bad_kinds} — a "
                    "straggler degrades host access capacity, which NIC "
                    "probes observe directly; fabric reroute reaction "
                    "does not apply")
        if self.sim.routing not in ROUTINGS:
            raise ValueError(
                f"{self.name}: unknown routing {self.sim.routing!r}")
        if self.sim.nic not in NICS:
            raise ValueError(f"{self.name}: unknown nic {self.sim.nic!r}")
        if self.sim.backend not in BACKENDS:
            raise ValueError(
                f"{self.name}: unknown backend {self.sim.backend!r}")
        try:
            self.sim.trace.validate()
        except ValueError as e:
            raise ValueError(f"{self.name}: {e}") from None
        return self


def _check_fault_bounds(name: str, f: FaultSpec,
                        topo: TopologySpec) -> None:
    """Bound-check every index a fault actually uses against the
    topology shape (satellite of ISSUE 5: out-of-range indices used to
    pass validation and die — or silently wrap via negative indexing —
    deep inside the event closures / the jx timeline compiler)."""
    def bad(field: str, value: int, n: int, axis: str) -> None:
        raise FaultBoundsError(
            f"{name}: fault {f.kind!r} {field}={value} outside "
            f"[0, {n}) ({axis})")

    if not (f.plane == -1 or 0 <= f.plane < topo.n_planes):
        raise FaultBoundsError(
            f"{name}: fault {f.kind!r} plane={f.plane} outside "
            f"[0, {topo.n_planes}) (and not -1 = all planes)")
    n_up = topo.n_spines if topo.kind == "leaf_spine" else topo.n_aggs
    up_axis = "spines" if topo.kind == "leaf_spine" else "aggs per pod"
    if f.kind in ("link_kill", "link_flap"):
        if not 0 <= f.leaf < topo.n_leaves:
            bad("leaf", f.leaf, topo.n_leaves, "leaves")
        if not 0 <= f.spine < n_up:
            bad("spine", f.spine, n_up, up_axis)
    elif f.kind == "leaf_trim":
        if not 0 <= f.leaf < topo.n_leaves:
            bad("leaf", f.leaf, topo.n_leaves, "leaves")
    elif f.kind == "cascade":
        for s in f.spines:
            if not 0 <= s < n_up:
                bad("spines[...]", s, n_up, up_axis)
        if topo.kind == "fat_tree" and not 0 <= f.pod < topo.n_pods:
            bad("pod", f.pod, topo.n_pods, "pods")
    elif f.kind in ("access_kill", "access_flap", "straggler"):
        if not 0 <= f.host < topo.n_hosts:
            bad("host", f.host, topo.n_hosts, "hosts")
    elif f.kind == "core_kill":
        if topo.kind != "fat_tree":
            raise FaultBoundsError(
                f"{name}: fault 'core_kill' requires a fat_tree "
                f"topology (got kind={topo.kind!r})")
        if not 0 <= f.pod < topo.n_pods:
            bad("pod", f.pod, topo.n_pods, "pods")
        if not 0 <= f.core < topo.n_cores:
            bad("core", f.core, topo.n_cores, "cores")


def fault_planes(f: FaultSpec, n_planes: int) -> Tuple[int, ...]:
    """Planes a fault applies to (`plane=-1` means every plane)."""
    return tuple(range(n_planes)) if f.plane < 0 else (f.plane,)


def flap_phase(t: int, f: FaultSpec) -> str:
    """'fail' | 'restore' | '' for a periodic *_flap fault at slot `t`.
    Single source of truth for the duty/period/stop arithmetic — the
    event-callback path (`compile.make_events`) and the JAX timeline
    compiler (`netsim.jx.events`) must agree bit-for-bit."""
    stop = float("inf") if f.stop_slot is None else f.stop_slot
    if f.start_slot <= t < stop:
        ph = (t - f.start_slot) % f.period
        down = max(1, int(f.period * f.duty))
        if ph == 0:
            return "fail"
        if ph == down:
            return "restore"
    elif f.stop_slot is not None and t == f.stop_slot:
        return "restore"
    return ""


def fault_transition_slots(f: FaultSpec, horizon: int, sched=None
                           ) -> Tuple[Tuple[int, str], ...]:
    """Slots (< horizon) at which this fault *degrades* the fabric —
    the instants the runner measures recovery from.  Restores are not
    transitions.  `sched` is the precomputed per-link slot schedule for
    kind='poisson_flap' (see `scenarios.compile.poisson_flap_schedule`)
    — arrival times are seeded draws, so the schedule must be computed
    once and shared with the event/timeline lowering."""
    out = []
    if f.kind == "poisson_flap":
        return tuple(sorted({(int(t), "poisson_flap")
                             for t, _, _, _ in (sched or ())
                             if t < horizon}))
    if f.kind in ("link_kill", "access_kill", "straggler", "leaf_trim",
                  "random_fail", "core_kill"):
        if f.start_slot < horizon:
            out.append((f.start_slot, f.kind))
    elif f.kind in ("link_flap", "access_flap"):
        stop = horizon if f.stop_slot is None else min(f.stop_slot, horizon)
        t = f.start_slot
        while t < stop:
            out.append((t, f.kind))
            t += f.period
    elif f.kind == "cascade":
        for i, _ in enumerate(f.spines):
            t = f.start_slot + i * f.period
            if t < horizon:
                out.append((t, f"cascade[{i}]"))
    return tuple(out)
