"""Batched scenario execution: (seed × routing × nic) grids, parallelized
across processes, each run distilled into one `ScenarioMetrics` record.

Metrics (per run):
  * per-tenant goodput mean / p01 / p99 across the tenant's flows
    (post-warmup, normalized to line rate; p01 is the straggler tail
    that gates collectives, p99 the best-flow upper tail);
  * isolation index — Jain fairness across tenants' demand-normalized
    goodput (1.0 = perfectly proportional sharing);
  * recovery slots after each fault transition — first slot at which
    total goodput re-attains 90% of the post-fault steady state;
  * completion-tail ratio — p99 / median completion slot over finite
    transfers;
  * §5.1 symmetry check on final uplink utilization via
    `core.telemetry.symmetry_check` — non-uniform planes and outlier
    spines are flagged automatically.
"""
from __future__ import annotations

import multiprocessing
import os
import sys
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.telemetry import symmetry_check

from .compile import CompiledScenario, compile_scenario
from .registry import get_scenario
from .spec import NICS, ROUTINGS, ScenarioSpec


@dataclass(frozen=True)
class SweepGrid:
    """The cartesian run grid.  Each seed perturbs both the sim seed and
    the workload seed (placement / pairing / ECMP hashes all re-draw).
    `routings`/`nics` of None inherit the spec's own setting; unknown or
    empty values raise immediately rather than silently falling back."""
    seeds: Tuple[int, ...] = (0,)
    routings: Optional[Tuple[str, ...]] = None
    nics: Optional[Tuple[str, ...]] = None
    slots: Optional[int] = None          # override spec.sim.slots

    def points(self, spec: ScenarioSpec) -> List[ScenarioSpec]:
        routings = (self.routings if self.routings is not None
                    else (spec.sim.routing,))
        nics = self.nics if self.nics is not None else (spec.sim.nic,)
        if not routings or not nics:
            raise ValueError(
                f"{spec.name}: sweep grid has an empty "
                f"{'routings' if not routings else 'nics'} tuple — pass "
                "None to inherit the spec's setting")
        for r in routings:
            if r not in ROUTINGS:
                raise ValueError(
                    f"{spec.name}: unknown routing {r!r} in sweep grid; "
                    f"known: {ROUTINGS}")
        for n in nics:
            if n not in NICS:
                raise ValueError(
                    f"{spec.name}: unknown nic {n!r} in sweep grid; "
                    f"known: {NICS}")
        out = []
        for seed in self.seeds:
            for routing in routings:
                for nic in nics:
                    s = spec.with_sim(seed=spec.sim.seed + seed,
                                      routing=routing, nic=nic,
                                      **({"slots": self.slots}
                                         if self.slots else {}))
                    out.append(s.with_workload_seed(
                        spec.workload_seed + seed))
        return out


@dataclass
class ScenarioMetrics:
    scenario: str
    seed: int
    routing: str
    nic: str
    mean_goodput: float
    tenant_mean: Dict[str, float]
    tenant_p01: Dict[str, float]     # straggler tail — gates collectives
    tenant_p99: Dict[str, float]     # best-flow upper tail
    isolation_index: float
    recovery_slots: Tuple[Tuple[int, str, int], ...]  # (slot, label, rec)
    completion_tail: float
    symmetry_cv: float
    symmetry_uniform: bool
    symmetry_outliers: Tuple[Tuple[int, int], ...]    # (plane, spine)
    extra: Dict[str, float] = field(default_factory=dict)

    CSV_FIELDS = ("scenario", "seed", "routing", "nic", "mean_goodput",
                  "isolation_index", "completion_tail", "symmetry_cv",
                  "worst_recovery_slots", "tenants")

    @staticmethod
    def csv_header() -> str:
        return ",".join(ScenarioMetrics.CSV_FIELDS)

    def worst_recovery(self) -> int:
        recs = [r for _, _, r in self.recovery_slots]
        return max(recs) if recs else 0

    def to_row(self) -> str:
        tenants = ";".join(f"{k}={v:.3f}"
                           for k, v in sorted(self.tenant_mean.items()))
        ct = "nan" if np.isnan(self.completion_tail) \
            else f"{self.completion_tail:.2f}"
        return (f"{self.scenario},{self.seed},{self.routing},{self.nic},"
                f"{self.mean_goodput:.4f},{self.isolation_index:.4f},"
                f"{ct},{self.symmetry_cv:.4f},"
                f"{self.worst_recovery()},{tenants}")


# ---------------------------------------------------------------------------
# single run -> metrics
# ---------------------------------------------------------------------------

def _jain(x: np.ndarray) -> float:
    x = np.asarray(x, np.float64)
    if x.size == 0 or (x <= 0).all():
        return 1.0
    return float(x.sum() ** 2 / (x.size * (x ** 2).sum() + 1e-30))


def _recovery(total: np.ndarray, fault_slots, record_every: int,
              horizon: int) -> Tuple[Tuple[int, str, int], ...]:
    """Slots until total goodput re-attains 90% of the steady state that
    establishes itself before the next fault (or the run end).  -1 = never
    recovered inside the window."""
    out = []
    bounds = [s for s, _ in fault_slots] + [horizon]
    for i, (slot, label) in enumerate(fault_slots):
        lo = slot // record_every + 1
        hi = min(bounds[i + 1] // record_every, total.shape[0])
        post = total[lo:hi]
        if post.size == 0:
            out.append((slot, label, -1))
            continue
        tail = post[-max(1, post.size // 4):]
        steady = float(np.median(tail))
        ok = np.flatnonzero(post >= 0.9 * steady)
        rec = int((ok[0] + 1) * record_every) if ok.size else -1
        out.append((slot, label, rec))
    return tuple(out)


def run_point(spec: ScenarioSpec) -> ScenarioMetrics:
    """Compile + simulate one grid point (on `spec.sim.backend`) and
    distill metrics."""
    c = compile_scenario(spec)
    return distill_metrics(spec, c, c.run())


def distill_metrics(spec: ScenarioSpec, c: CompiledScenario,
                    res) -> ScenarioMetrics:
    """Shared metric distillation — `res` is a NumPy `SimResult` or a JAX
    `JxSimResult`; both expose mean_goodput / completion_slot /
    total_goodput / util_up_last / groups / group_of."""
    demand = np.array([f.demand for f in c.flows])
    tenant_mean: Dict[str, float] = {}
    tenant_p01: Dict[str, float] = {}
    tenant_p99: Dict[str, float] = {}
    norm: List[float] = []
    for gi, gname in enumerate(res.groups):
        sel = res.group_of == gi
        gp = res.mean_goodput[sel]
        tenant_mean[gname] = float(gp.mean())
        tenant_p01[gname] = float(np.quantile(gp, 0.01))
        tenant_p99[gname] = float(np.quantile(gp, 0.99))
        d = max(float(demand[sel].mean()), 1e-12)
        norm.append(float(gp.mean()) / d)

    total = np.asarray(res.total_goodput)
    denom = max(float(demand.sum()), 1e-12)
    recovery = _recovery(total / denom, c.fault_slots,
                         spec.sim.record_every, spec.sim.slots)

    finite = res.completion_slot[res.completion_slot >= 0]
    if finite.size >= 2 and np.median(finite) > 0:
        tail = float(np.quantile(finite, 0.99) / np.median(finite))
    else:
        tail = float("nan")

    # §5.1: per-plane spine-aggregate utilization should be uniform under
    # AR; outliers flag faults (expected when the scenario injects them).
    worst_cv, uniform, outliers = 0.0, True, []
    for p in range(res.util_up_last.shape[0]):
        rep = symmetry_check(f"plane{p}.spines",
                             res.util_up_last[p].sum(0))
        worst_cv = max(worst_cv, rep.cv)
        uniform &= rep.uniform
        outliers += [(p, s) for s in rep.outliers]

    return ScenarioMetrics(
        scenario=spec.name, seed=spec.sim.seed, routing=spec.sim.routing,
        nic=spec.sim.nic,
        mean_goodput=float(res.mean_goodput.mean()),
        tenant_mean=tenant_mean, tenant_p01=tenant_p01,
        tenant_p99=tenant_p99,
        isolation_index=_jain(np.asarray(norm)),
        recovery_slots=recovery, completion_tail=tail,
        symmetry_cv=float(worst_cv), symmetry_uniform=bool(uniform),
        symmetry_outliers=tuple(outliers))


# ---------------------------------------------------------------------------
# sweeps
# ---------------------------------------------------------------------------

def _resolve(spec_or_name) -> ScenarioSpec:
    if isinstance(spec_or_name, str):
        return get_scenario(spec_or_name)
    return spec_or_name


def sweep(spec_or_name, grid: Optional[SweepGrid] = None,
          processes: Optional[int] = None,
          backend: Optional[str] = None) -> List[ScenarioMetrics]:
    """Run one scenario over the grid.  `backend=None` inherits the
    spec's `sim.backend`.  'numpy' fans grid points out over a process
    pool (`processes=0/1` forces serial; None sizes the pool to
    min(n_points, cpus)); 'jax' runs each (routing, nic) group's seed
    axis as one vmapped computation in this process — `processes` is
    ignored."""
    spec = _resolve(spec_or_name)
    points = (grid or SweepGrid()).points(spec)
    return _execute(points, processes, backend)


def sweep_many(names: Sequence, grid: Optional[SweepGrid] = None,
               processes: Optional[int] = None,
               backend: Optional[str] = None) -> List[ScenarioMetrics]:
    """Run several scenarios over one shared grid, batched through a
    single process pool (numpy) or per-group vmapped batches (jax).
    `backend=None` inherits from the specs (which must agree)."""
    points: List[ScenarioSpec] = []
    g = grid or SweepGrid()
    for n in names:
        points += g.points(_resolve(n))
    return _execute(points, processes, backend)


def _execute(points: List[ScenarioSpec], processes: Optional[int],
             backend: Optional[str] = None) -> List[ScenarioMetrics]:
    if backend is None:
        inherited = {p.sim.backend for p in points}
        if len(inherited) > 1:
            raise ValueError(
                f"sweep mixes spec backends {sorted(inherited)}; pass "
                "backend= explicitly")
        backend = inherited.pop() if inherited else "numpy"
    if backend == "jax":
        return _execute_jax(points)
    if backend != "numpy":
        raise ValueError(
            f"unknown backend {backend!r}; expected 'numpy' or 'jax'")
    # make the override symmetric: run_point honors each spec's own
    # sim.backend, so pin it to numpy or a backend="numpy" sweep of
    # jax-backend specs would silently still run on JAX
    points = [replace(p, sim=replace(p.sim, backend="numpy"))
              if p.sim.backend != "numpy" else p for p in points]
    if processes is None:
        processes = min(len(points), os.cpu_count() or 1)
    if processes <= 1 or len(points) <= 1:
        return [run_point(p) for p in points]
    # forking a parent whose XLA backend is live (multithreaded) can
    # deadlock the workers, so after a backend="jax" sweep ran in this
    # process switch to the spawn family.  Merely having jax *imported*
    # is fine — repro.core pulls it in transitively, and penalizing
    # every NumPy sweep with spawn start-up costs would be wrong.
    # Spawn/forkserver re-import __main__, which is impossible for
    # stdin/heredoc programs — fall back to serial there rather than
    # crash or risk the fork.
    if _xla_backend_live():
        main_file = getattr(sys.modules.get("__main__"), "__file__", None)
        if main_file is not None and not os.path.exists(main_file):
            return [run_point(p) for p in points]
        methods = multiprocessing.get_all_start_methods()
        ctx = multiprocessing.get_context(
            "forkserver" if "forkserver" in methods else "spawn")
    else:
        ctx = multiprocessing.get_context()
    with ProcessPoolExecutor(max_workers=processes, mp_context=ctx) as ex:
        return list(ex.map(run_point, points))


def _xla_backend_live() -> bool:
    """True iff an XLA backend (and its thread pools) was plausibly
    created in this process — not merely `import jax`.  First line: our
    own jax engine's dispatch flag (set on actual use, not import).
    Second line: jax's backend cache (private, so probed defensively —
    if jax renames it we degrade to the first check)."""
    if getattr(sys.modules.get("repro.netsim.jx.engine"),
               "_BACKEND_USED", False):
        return True
    xb = sys.modules.get("jax._src.xla_bridge")
    return bool(getattr(xb, "_backends", None))


def _execute_jax(points: List[ScenarioSpec]) -> List[ScenarioMetrics]:
    """Batched single-process sweep: group grid points that share
    structure (same scenario / routing / nic / slots — i.e. everything
    except the seeds), run each group as one `vmap` batch, and distill
    in the original point order.

    All groups are dispatched before any is awaited (JAX CPU execution
    is async, so host-side prep of group N+1 overlaps group N's
    compute), and with
    `XLA_FLAGS=--xla_force_host_platform_device_count=N` each group's
    batch axis is pmap-sharded over the N host devices (the
    single-process analogue of the NumPy backend's process pool)."""
    from repro.netsim.jx.engine import (dispatch_compiled_batch,
                                        finalize_batch)

    order: List = []
    groups: Dict = {}
    for i, p in enumerate(points):
        key = replace(p, sim=replace(p.sim, seed=0, backend="numpy"),
                      workload_seed=0)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(i)
    dispatched = []
    for key in order:
        idxs = groups[key]
        compiled = [compile_scenario(points[i]) for i in idxs]
        dispatched.append((idxs, compiled,
                           dispatch_compiled_batch(compiled)))
    results: List[Optional[ScenarioMetrics]] = [None] * len(points)
    for idxs, compiled, handle in dispatched:
        for i, c, r in zip(idxs, compiled, finalize_batch(handle)):
            results[i] = distill_metrics(points[i], c, r)
    return results


def metrics_csv(rows: Iterable[ScenarioMetrics]) -> str:
    return "\n".join([ScenarioMetrics.csv_header()] +
                     [m.to_row() for m in rows])
