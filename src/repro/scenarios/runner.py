"""Scenario execution and metric distillation.

One grid point -> one `ScenarioMetrics` record:
  * per-tenant goodput mean / p01 / p99 across the tenant's flows
    (post-warmup, normalized to line rate; p01 is the straggler tail
    that gates collectives, p99 the best-flow upper tail);
  * isolation index — Jain fairness across tenants' demand-normalized
    goodput (1.0 = perfectly proportional sharing);
  * recovery slots after each fault transition — first slot at which
    total goodput re-attains 90% of the post-fault steady state;
  * completion-tail ratio — p99 / median completion slot over finite
    transfers;
  * §5.1 symmetry check on final uplink utilization via
    `core.telemetry.symmetry_check` — non-uniform planes and outlier
    spines are flagged automatically.

Batched execution lives in `repro.experiments`: the `Experiment` API
sweeps arbitrary spec axes into a columnar `ResultSet` with an on-disk
run cache.  The (seed × routing × nic) `sweep`/`sweep_many` entry points
kept here are thin shims over that executor for backward compatibility.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.telemetry import symmetry_check
from repro.trace import trace_summary

from .compile import CompiledScenario, compile_scenario
from .registry import get_scenario
from .spec import NICS, ROUTINGS, ScenarioSpec


@dataclass(frozen=True)
class SweepGrid:
    """The cartesian run grid.  Each seed perturbs both the sim seed and
    the workload seed (placement / pairing / ECMP hashes all re-draw).
    `routings`/`nics` of None inherit the spec's own setting; unknown or
    empty values raise immediately rather than silently falling back."""
    seeds: Tuple[int, ...] = (0,)
    routings: Optional[Tuple[str, ...]] = None
    nics: Optional[Tuple[str, ...]] = None
    slots: Optional[int] = None          # override spec.sim.slots

    def points(self, spec: ScenarioSpec) -> List[ScenarioSpec]:
        routings = (self.routings if self.routings is not None
                    else (spec.sim.routing,))
        nics = self.nics if self.nics is not None else (spec.sim.nic,)
        if not routings or not nics:
            raise ValueError(
                f"{spec.name}: sweep grid has an empty "
                f"{'routings' if not routings else 'nics'} tuple — pass "
                "None to inherit the spec's setting")
        for r in routings:
            if r not in ROUTINGS:
                raise ValueError(
                    f"{spec.name}: unknown routing {r!r} in sweep grid; "
                    f"known: {ROUTINGS}")
        for n in nics:
            if n not in NICS:
                raise ValueError(
                    f"{spec.name}: unknown nic {n!r} in sweep grid; "
                    f"known: {NICS}")
        out = []
        for seed in self.seeds:
            for routing in routings:
                for nic in nics:
                    s = spec.with_sim(seed=spec.sim.seed + seed,
                                      routing=routing, nic=nic,
                                      **({"slots": self.slots}
                                         if self.slots else {}))
                    out.append(s.with_workload_seed(
                        spec.workload_seed + seed))
        return out


# ---------------------------------------------------------------------------
# metric field table — the single source of truth for every serialization
# of a ScenarioMetrics record.  `kind` drives typed (de)serialization in
# `repro.experiments.resultset`; `value` extracts the column value.
# Names double as the legacy CSV header and the ResultSet column names.
# ---------------------------------------------------------------------------

METRIC_FIELDS: Tuple[Tuple[str, str, Callable], ...] = (
    ("scenario",             "str",   lambda m: m.scenario),
    ("seed",                 "int",   lambda m: m.seed),
    ("routing",              "str",   lambda m: m.routing),
    ("nic",                  "str",   lambda m: m.nic),
    ("mean_goodput",         "float", lambda m: m.mean_goodput),
    ("isolation_index",      "float", lambda m: m.isolation_index),
    ("completion_tail",      "float", lambda m: m.completion_tail),
    ("symmetry_cv",          "float", lambda m: m.symmetry_cv),
    ("worst_recovery_slots", "int",   lambda m: m.worst_recovery()),
    ("symmetry_uniform",     "bool",  lambda m: m.symmetry_uniform),
    ("hft_transient_drops",  "int",   lambda m: m.hft_transient_drops),
    ("bimodal_frac",         "float", lambda m: m.bimodal_frac),
    ("blackholed_bytes",     "float", lambda m: m.blackholed_bytes),
    ("reaction_slots",       "int",   lambda m: m.reaction_slots),
    ("tenant_mean",          "json",  lambda m: m.tenant_mean),
    ("tenant_p01",           "json",  lambda m: m.tenant_p01),
    ("tenant_p99",           "json",  lambda m: m.tenant_p99),
    ("recovery_slots",       "json",  lambda m: m.recovery_slots),
    ("symmetry_outliers",    "json",  lambda m: m.symmetry_outliers),
    ("straggler_ranks",      "json",  lambda m: m.straggler_ranks),
    ("extra",                "json",  lambda m: m.extra),
)

METRIC_KINDS: Dict[str, str] = {n: k for n, k, _ in METRIC_FIELDS}
_METRIC_VALUE: Dict[str, Callable] = {n: v for n, _, v in METRIC_FIELDS}

# Columns added after a serialization already existed get filled with
# these when absent, so pre-trace ResultSet JSON/CSV and cache entries
# keep loading (see `resultset.from_json` / `ScenarioMetrics.from_dict`).
TRACE_METRIC_DEFAULTS: Dict[str, object] = {
    "hft_transient_drops": -1,
    "bimodal_frac": float("nan"),
    "straggler_ranks": (),
    "blackholed_bytes": -1.0,
    "reaction_slots": -1,
}


def metric_value(m: "ScenarioMetrics", name: str):
    """Column value of one metric field (see `METRIC_FIELDS`)."""
    return _METRIC_VALUE[name](m)


def _fmt_tenants(m: "ScenarioMetrics") -> str:
    return ";".join(f"{k}={v:.3f}" for k, v in sorted(m.tenant_mean.items()))


def _fmt_tail(m: "ScenarioMetrics") -> str:
    return ("nan" if np.isnan(m.completion_tail)
            else f"{m.completion_tail:.2f}")


# legacy flat-CSV view (`metrics_csv`): column -> cell formatter.  Header
# and rows both derive from this one table.
_CSV_COLUMNS: Tuple[Tuple[str, Callable[["ScenarioMetrics"], str]], ...] = (
    ("scenario",             lambda m: m.scenario),
    ("seed",                 lambda m: str(m.seed)),
    ("routing",              lambda m: m.routing),
    ("nic",                  lambda m: m.nic),
    ("mean_goodput",         lambda m: f"{m.mean_goodput:.4f}"),
    ("isolation_index",      lambda m: f"{m.isolation_index:.4f}"),
    ("completion_tail",      _fmt_tail),
    ("symmetry_cv",          lambda m: f"{m.symmetry_cv:.4f}"),
    ("worst_recovery_slots", lambda m: str(m.worst_recovery())),
    ("tenants",              _fmt_tenants),
)


@dataclass
class ScenarioMetrics:
    scenario: str
    seed: int
    routing: str
    nic: str
    mean_goodput: float
    tenant_mean: Dict[str, float]
    tenant_p01: Dict[str, float]     # straggler tail — gates collectives
    tenant_p99: Dict[str, float]     # best-flow upper tail
    isolation_index: float
    recovery_slots: Tuple[Tuple[int, str, int], ...]  # (slot, label, rec)
    completion_tail: float
    symmetry_cv: float
    symmetry_uniform: bool
    symmetry_outliers: Tuple[Tuple[int, int], ...]    # (plane, spine)
    extra: Dict[str, float] = field(default_factory=dict)
    # §5 trace-derived columns — meaningful only when the point ran with
    # `sim.trace` enabled; the defaults mark "no trace captured"
    hft_transient_drops: int = -1
    bimodal_frac: float = float("nan")
    straggler_ranks: Tuple[int, ...] = ()
    # failure-reaction columns — meaningful only when the spec carries an
    # enabled `ReactionSpec`; the defaults mark "no reaction modeled"
    blackholed_bytes: float = -1.0
    reaction_slots: int = -1

    CSV_FIELDS = tuple(name for name, _ in _CSV_COLUMNS)

    @staticmethod
    def csv_header() -> str:
        return ",".join(ScenarioMetrics.CSV_FIELDS)

    def worst_recovery(self) -> int:
        recs = [r for _, _, r in self.recovery_slots]
        return max(recs) if recs else 0

    def to_row(self) -> str:
        return ",".join(fmt(self) for _, fmt in _CSV_COLUMNS)

    # ---- lossless dict round-trip (run cache / ResultSet JSON) ----------
    def to_dict(self) -> Dict:
        return {
            "scenario": self.scenario, "seed": int(self.seed),
            "routing": self.routing, "nic": self.nic,
            "mean_goodput": float(self.mean_goodput),
            "tenant_mean": dict(self.tenant_mean),
            "tenant_p01": dict(self.tenant_p01),
            "tenant_p99": dict(self.tenant_p99),
            "isolation_index": float(self.isolation_index),
            "recovery_slots": [list(r) for r in self.recovery_slots],
            "completion_tail": float(self.completion_tail),
            "symmetry_cv": float(self.symmetry_cv),
            "symmetry_uniform": bool(self.symmetry_uniform),
            "symmetry_outliers": [list(o) for o in self.symmetry_outliers],
            "extra": dict(self.extra),
            "hft_transient_drops": int(self.hft_transient_drops),
            "bimodal_frac": float(self.bimodal_frac),
            "straggler_ranks": [int(r) for r in self.straggler_ranks],
            "blackholed_bytes": float(self.blackholed_bytes),
            "reaction_slots": int(self.reaction_slots),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ScenarioMetrics":
        return cls(
            scenario=str(d["scenario"]), seed=int(d["seed"]),
            routing=str(d["routing"]), nic=str(d["nic"]),
            mean_goodput=float(d["mean_goodput"]),
            tenant_mean={str(k): float(v)
                         for k, v in d["tenant_mean"].items()},
            tenant_p01={str(k): float(v)
                        for k, v in d["tenant_p01"].items()},
            tenant_p99={str(k): float(v)
                        for k, v in d["tenant_p99"].items()},
            isolation_index=float(d["isolation_index"]),
            recovery_slots=tuple((int(s), str(l), int(r))
                                 for s, l, r in d["recovery_slots"]),
            completion_tail=float(d["completion_tail"]),
            symmetry_cv=float(d["symmetry_cv"]),
            symmetry_uniform=bool(d["symmetry_uniform"]),
            symmetry_outliers=tuple((int(p), int(s))
                                    for p, s in d["symmetry_outliers"]),
            extra={str(k): v for k, v in d.get("extra", {}).items()},
            hft_transient_drops=int(d.get("hft_transient_drops", -1)),
            bimodal_frac=float(d.get("bimodal_frac", float("nan"))),
            straggler_ranks=tuple(
                int(r) for r in d.get("straggler_ranks", ())),
            blackholed_bytes=float(d.get("blackholed_bytes", -1.0)),
            reaction_slots=int(d.get("reaction_slots", -1)))


# ---------------------------------------------------------------------------
# single run -> metrics
# ---------------------------------------------------------------------------

def _jain(x: np.ndarray) -> float:
    x = np.asarray(x, np.float64)
    if x.size == 0 or (x <= 0).all():
        return 1.0
    return float(x.sum() ** 2 / (x.size * (x ** 2).sum() + 1e-30))


def _reaction_slots(bh: np.ndarray, fault_slots) -> int:
    """Worst-case slots from a fault transition until its blackhole window
    closes — first slot at or after the transition where blackholed bytes
    go positive, then back to zero.  A window still open at the horizon
    counts to the horizon; transitions that never blackhole contribute 0."""
    worst = 0
    for slot, _label in fault_slots:
        seg = bh[slot:]
        pos = np.flatnonzero(seg > 1e-12)
        if pos.size == 0:
            continue
        closed = np.flatnonzero(seg[pos[0]:] <= 1e-12)
        worst = max(worst, int(pos[0] + closed[0]) if closed.size
                    else int(seg.size))
    return worst


def _recovery(total: np.ndarray, fault_slots, record_every: int,
              horizon: int) -> Tuple[Tuple[int, str, int], ...]:
    """Slots until total goodput re-attains 90% of the steady state that
    establishes itself before the next fault (or the run end).  -1 = never
    recovered inside the window."""
    out = []
    bounds = [s for s, _ in fault_slots] + [horizon]
    for i, (slot, label) in enumerate(fault_slots):
        lo = slot // record_every + 1
        hi = min(bounds[i + 1] // record_every, total.shape[0])
        post = total[lo:hi]
        if post.size == 0:
            out.append((slot, label, -1))
            continue
        tail = post[-max(1, post.size // 4):]
        steady = float(np.median(tail))
        ok = np.flatnonzero(post >= 0.9 * steady)
        rec = int((ok[0] + 1) * record_every) if ok.size else -1
        out.append((slot, label, rec))
    return tuple(out)


def run_point(spec: ScenarioSpec,
              derive: Optional[Callable] = None) -> ScenarioMetrics:
    """Compile + simulate one grid point (on `spec.sim.backend`) and
    distill metrics.  `derive(spec, compiled, result) -> dict` computes
    per-run `extra` metrics from the raw simulation result (it must be a
    picklable module-level function so process-pool sweeps can ship it)."""
    c = compile_scenario(spec)
    res = c.run()
    m = distill_metrics(spec, c, res)
    if derive is not None:
        m.extra.update(derive(spec, c, res))
    return m


def distill_metrics(spec: ScenarioSpec, c: CompiledScenario,
                    res) -> ScenarioMetrics:
    """Shared metric distillation — `res` is a NumPy `SimResult` or a JAX
    `JxSimResult`; both expose mean_goodput / completion_slot /
    total_goodput / util_up_last / groups / group_of."""
    demand = np.array([f.demand for f in c.flows])
    tenant_mean: Dict[str, float] = {}
    tenant_p01: Dict[str, float] = {}
    tenant_p99: Dict[str, float] = {}
    norm: List[float] = []
    for gi, gname in enumerate(res.groups):
        sel = res.group_of == gi
        gp = res.mean_goodput[sel]
        tenant_mean[gname] = float(gp.mean())
        tenant_p01[gname] = float(np.quantile(gp, 0.01))
        tenant_p99[gname] = float(np.quantile(gp, 0.99))
        d = max(float(demand[sel].mean()), 1e-12)
        norm.append(float(gp.mean()) / d)

    total = np.asarray(res.total_goodput)
    denom = max(float(demand.sum()), 1e-12)
    recovery = _recovery(total / denom, c.fault_slots,
                         spec.sim.record_every, spec.sim.slots)

    finite = res.completion_slot[res.completion_slot >= 0]
    if finite.size >= 2 and np.median(finite) > 0:
        tail = float(np.quantile(finite, 0.99) / np.median(finite))
    else:
        tail = float("nan")

    # §5.1: per-plane spine-aggregate utilization should be uniform under
    # AR; outliers flag faults (expected when the scenario injects them).
    worst_cv, uniform, outliers = 0.0, True, []
    for p in range(res.util_up_last.shape[0]):
        rep = symmetry_check(f"plane{p}.spines",
                             res.util_up_last[p].sum(0))
        worst_cv = max(worst_cv, rep.cv)
        uniform &= rep.uniform
        outliers += [(p, s) for s in rep.outliers]

    # failure-reaction columns — present only when the run modeled
    # detection latency (spec.reaction enabled on either backend)
    bh = getattr(res, "blackhole_timeline", None)
    if bh is not None:
        bh = np.asarray(bh, np.float64)
        blackholed = float(bh.sum())
        react_slots = _reaction_slots(bh, c.fault_slots)
    else:
        blackholed, react_slots = -1.0, -1

    # §5.2/§5.3: trace-derived columns when the point captured one
    trace = getattr(res, "trace", None)
    extra: Dict = {}
    summ = dict(TRACE_METRIC_DEFAULTS)
    if trace is not None:
        summ = trace_summary(trace, spec.topo.access_cap,
                             spec.topo.n_planes)
        if "port_classes" in summ:
            extra["port_classes"] = summ["port_classes"]

    return ScenarioMetrics(
        scenario=spec.name, seed=spec.sim.seed, routing=spec.sim.routing,
        nic=spec.sim.nic,
        mean_goodput=float(res.mean_goodput.mean()),
        tenant_mean=tenant_mean, tenant_p01=tenant_p01,
        tenant_p99=tenant_p99,
        isolation_index=_jain(np.asarray(norm)),
        recovery_slots=recovery, completion_tail=tail,
        symmetry_cv=float(worst_cv), symmetry_uniform=bool(uniform),
        symmetry_outliers=tuple(outliers), extra=extra,
        hft_transient_drops=int(summ["hft_transient_drops"]),
        bimodal_frac=float(summ["bimodal_frac"]),
        straggler_ranks=tuple(summ["straggler_ranks"]),
        blackholed_bytes=blackholed, reaction_slots=react_slots)


# ---------------------------------------------------------------------------
# sweeps — deprecated shims over repro.experiments.execute
# ---------------------------------------------------------------------------

def _resolve(spec_or_name) -> ScenarioSpec:
    if isinstance(spec_or_name, str):
        return get_scenario(spec_or_name)
    return spec_or_name


def sweep(spec_or_name, grid: Optional[SweepGrid] = None,
          processes: Optional[int] = None,
          backend: Optional[str] = None) -> List[ScenarioMetrics]:
    """Run one scenario over a (seed × routing × nic) grid.

    Deprecated shim: lowers onto `repro.experiments.execute_points` (the
    `Experiment` API's executor) — same process-pool / grouped-vmap
    dispatch, same row order.  Prefer `repro.experiments.Experiment`,
    which also sweeps arbitrary spec axes, caches, and resumes."""
    from repro.experiments.execute import execute_points
    spec = _resolve(spec_or_name)
    points = (grid or SweepGrid()).points(spec)
    return execute_points(points, processes=processes, backend=backend)


def sweep_many(names: Sequence, grid: Optional[SweepGrid] = None,
               processes: Optional[int] = None,
               backend: Optional[str] = None) -> List[ScenarioMetrics]:
    """Run several scenarios over one shared grid.

    Deprecated shim over `repro.experiments.execute_points` (use an
    `Experiment` with a `scenario` axis instead); kept because the grid
    batches through a single process pool / vmap dispatch either way."""
    from repro.experiments.execute import execute_points
    points: List[ScenarioSpec] = []
    g = grid or SweepGrid()
    for n in names:
        points += g.points(_resolve(n))
    return execute_points(points, processes=processes, backend=backend)


def metrics_csv(rows: Iterable[ScenarioMetrics]) -> str:
    """Legacy flat CSV (see `_CSV_COLUMNS`).  `ResultSet.to_csv` is the
    lossless replacement."""
    return "\n".join([ScenarioMetrics.csv_header()] +
                     [m.to_row() for m in rows])
