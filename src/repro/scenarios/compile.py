"""Lower a `ScenarioSpec` to the `(topo, flows, events)` triple that
`netsim.sim.run_sim` consumes.

Compilation is deterministic: the same (spec, workload_seed) produces
byte-identical flow lists and an events closure with identical effects.
All randomness flows through one `np.random.default_rng(workload_seed)`
consumed in declaration order (tenants first, then workloads), plus one
derived per-fault stream for 'random_fail'.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.fault_tolerance import poisson_flaps
from repro.netsim.fabric import Flow
from repro.netsim.sim import SimConfig, SimResult, run_sim
from repro.netsim.topology import (Fabric, FatTree, LeafSpine,
                                   backup_path_table)
from repro.netsim.workloads import (all2all, bisection_pairs, one_to_many,
                                    ring_neighbors)

from .spec import (FaultSpec, ScenarioSpec, TenantSpec, WorkloadSpec,
                   fault_planes, fault_transition_slots, flap_phase,
                   reaction_lag)


@dataclass
class CompiledScenario:
    """Single-use run bundle: `topo` is mutated in place by `events` on
    the NumPy backend, so compile again (cheap) for a fresh run."""
    spec: ScenarioSpec
    topo: Fabric
    flows: List[Flow]
    cfg: SimConfig
    events: Callable[[int, Fabric], None]
    tenants: Dict[str, List[int]]
    fault_slots: Tuple[Tuple[int, str], ...]   # (slot, label), sorted
    # schedule workloads only: (slots, K) demand-multiplier timeline
    # (lane 0 always 1.0) + per-schedule `comms.TrainSchedule` metadata
    phase_mult: Optional[np.ndarray] = None
    schedules: Tuple = ()
    # failure-reaction lowering (spec.reaction with a non-zero lag):
    # a second pristine fabric the event closures replay into `lag`
    # slots late — routing steers against it.  `backup` is the
    # precomputed fast-reroute successor table (mode='backup').
    vis_topo: Optional[Fabric] = None
    backup: Optional[np.ndarray] = None

    def run(self, backend: Optional[str] = None):
        """Simulate.  `backend` overrides the spec's `sim.backend`;
        'jax' lowers the fault schedule to a static timeline and runs the
        jitted engine (lazy import keeps NumPy pool workers JAX-free)."""
        backend = backend or self.cfg.backend
        if backend == "jax":
            from repro.netsim.jx.engine import run_compiled
            return run_compiled(self)
        if backend != "numpy":
            raise ValueError(
                f"unknown backend {backend!r}; expected 'numpy' or 'jax'")
        if self.spec.reaction is None:
            # pre-reaction call shape, byte-identical
            return run_sim(self.topo, self.flows, self.cfg,
                           events=self.events, phase_mult=self.phase_mult)
        return run_sim(
            self.topo, self.flows, self.cfg, events=self.events,
            phase_mult=self.phase_mult, reaction=self.spec.reaction,
            vis_topo=self.vis_topo,
            vis_events=self.events if self.vis_topo is not None else None,
            backup=self.backup)


# ---------------------------------------------------------------------------
# tenants
# ---------------------------------------------------------------------------

def resolve_tenants(spec: ScenarioSpec, rng: np.random.Generator
                    ) -> Dict[str, List[int]]:
    n = spec.topo.n_hosts
    taken: set = set()
    out: Dict[str, List[int]] = {}
    for t in spec.tenants:
        if t.placement == "explicit":
            hosts = list(t.hosts)
        elif t.placement == "block":
            count = n - t.offset if t.n_hosts is None else t.n_hosts
            hosts = list(range(t.offset, t.offset + count))
        elif t.placement == "interleave":
            hosts = list(range(t.offset, n, t.stride))
            if t.n_hosts is not None:
                hosts = hosts[:t.n_hosts]
        elif t.placement == "random":
            pool = np.array(sorted(set(range(n)) - taken))
            count = len(pool) if t.n_hosts is None else t.n_hosts
            hosts = sorted(int(h) for h in
                           rng.choice(pool, size=count, replace=False))
        elif t.placement == "remainder":
            hosts = sorted(set(range(n)) - taken)
            if t.n_hosts is not None:
                hosts = hosts[:t.n_hosts]
        else:                                          # pragma: no cover
            raise ValueError(t.placement)
        if len(set(hosts)) != len(hosts):
            dupes = sorted({h for h in hosts if hosts.count(h) > 1})
            raise ValueError(
                f"{spec.name}: tenant {t.name} lists hosts {dupes} "
                "more than once")
        clash = taken & set(hosts)
        if clash:
            raise ValueError(
                f"{spec.name}: tenant {t.name} overlaps hosts {clash}")
        bad = [h for h in hosts if not 0 <= h < n]
        if bad:
            raise ValueError(
                f"{spec.name}: tenant {t.name} hosts {bad} outside "
                f"[0, {n})")
        taken |= set(hosts)
        out[t.name] = hosts
    return out


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------

def _build_workload(w: WorkloadSpec, topo: LeafSpine, hosts: List[int],
                    rng: np.random.Generator, group: str) -> List[Flow]:
    if w.kind == "bisection":
        flows = bisection_pairs(topo, hosts, rng, group=group)
        for f in flows:
            f.demand *= w.demand
            f.bytes_total = w.bytes_total
        return flows
    if w.kind == "all2all":
        flows = all2all(topo, hosts, group=group,
                        bytes_per_pair=w.bytes_total)
        for f in flows:
            f.demand *= w.demand
        return flows
    if w.kind == "allreduce":
        flows = ring_neighbors(hosts, group=group,
                               bytes_per_hop=w.bytes_total)
        for f in flows:
            f.demand *= w.demand
        return flows
    if w.kind == "incast":
        sinks, srcs = hosts[:w.sinks], hosts[w.sinks:]
        return [Flow(int(a), int(b), w.demand, w.bytes_total, group=group)
                for a in srcs for b in sinks]
    if w.kind == "permutation":
        order = rng.permutation(hosts)
        return [Flow(int(order[i]), int(order[(i + 1) % len(order)]),
                     w.demand, w.bytes_total, group=group)
                for i in range(len(order))]
    if w.kind == "storage":
        flows = []
        arr = np.asarray(hosts)
        for h in hosts:
            peers = arr[arr != h]
            dsts = rng.choice(peers, size=min(w.fanout, len(peers)),
                              replace=False)
            flows += [Flow(int(h), int(d), w.demand, w.bytes_total,
                           group=group) for d in dsts]
        return flows
    if w.kind == "one2many":
        srcs, dsts = hosts[:w.srcs], hosts[w.srcs:]
        if not dsts:
            raise ValueError(
                f"one2many workload for tenant {w.tenant!r}: srcs="
                f"{w.srcs} leaves no destination hosts")
        flows = one_to_many(topo, srcs, dsts, group=group,
                            bytes_per_flow=w.bytes_total)
        for f in flows:
            f.demand *= w.demand
        return flows
    if w.kind == "pairs":
        foreign = sorted({h for p in w.pairs for h in p} - set(hosts))
        if foreign:
            raise ValueError(
                f"pairs workload for tenant {w.tenant!r} references "
                f"hosts {foreign} outside the tenant")
        return [Flow(int(a), int(b), w.demand, w.bytes_total, group=group)
                for a, b in w.pairs]
    raise ValueError(f"unknown workload kind {w.kind!r}")


def build_flows(spec: ScenarioSpec, topo: LeafSpine,
                tenants: Dict[str, List[int]],
                rng: np.random.Generator
                ) -> Tuple[List[Flow], Optional[np.ndarray], Tuple]:
    """Lower every workload.  Returns `(flows, phase_mult, schedules)`:
    `phase_mult` is the (slots, K) demand-multiplier timeline (None when
    no schedule workload is present) and `schedules` the matching
    `comms.TrainSchedule` metadata, flow indices already rebased onto
    the global flow list.  Multiple schedule workloads stack their lanes
    column-wise; lane 0 stays the shared always-1.0 lane."""
    flows: List[Flow] = []
    pm: Optional[np.ndarray] = None
    schedules: List = []
    for w in spec.workloads:
        group = w.group or w.tenant
        if w.kind == "schedule":
            # Lazy import: `repro.comms` pulls in JAX for parameter
            # pytrees; NumPy pool workers stay JAX-free otherwise.
            from repro.comms import lower_schedule
            lane_off = 0 if pm is None else pm.shape[1] - 1
            fl, wpm, sched = lower_schedule(
                w, tenants[w.tenant], spec.topo, spec.sim, group,
                lane_offset=lane_off)
            schedules.append(sched.shifted(len(flows)))
            pm = wpm if pm is None else np.concatenate(
                [pm, wpm[:, 1:]], axis=1)
            flows += fl          # start slots are schedule-internal
            continue
        fl = _build_workload(w, topo, tenants[w.tenant], rng, group)
        if w.start_slot:
            for f in fl:
                f.start_slot = w.start_slot
        flows += fl
    return flows, pm, tuple(schedules)


# ---------------------------------------------------------------------------
# fault schedule -> events closure
# ---------------------------------------------------------------------------

def _planes(f: FaultSpec, topo: Fabric) -> List[int]:
    return list(fault_planes(f, topo.n_planes))


def _fail_random_link(topo: Fabric, p: int, rng: np.random.Generator,
                      frac: float) -> None:
    """One uniformly-drawn fabric-link kill for random_fail's exact-k
    mode.  Draw-for-draw shared semantics with the jx timeline compiler
    (`netsim.jx.events._apply_fault`): leaf_spine draws (leaf, spine);
    fat_tree draws one index over leaf–agg links followed by pod–core
    links."""
    if topo.kind == "leaf_spine":
        topo.fail_uplink(p, int(rng.integers(topo.n_leaves)),
                         int(rng.integers(topo.n_spines)), frac)
        return
    L, A = topo.n_leaves, topo.n_aggs
    n_stage_a = L * A
    idx = int(rng.integers(n_stage_a + topo.n_pods * topo.n_cores))
    if idx < n_stage_a:
        topo.fail_uplink(p, idx // A, idx % A, frac)
    else:
        rem = idx - n_stage_a
        topo.fail_core_link(p, rem // topo.n_cores, rem % topo.n_cores,
                            frac)


def _flap(t: int, f: FaultSpec, fail, restore) -> None:
    """Periodic kill/restore for *_flap faults (phase math shared with
    the JAX timeline compiler via `spec.flap_phase`)."""
    ph = flap_phase(t, f)
    if ph == "fail":
        fail()
    elif ph == "restore":
        restore()


def poisson_flap_schedule(spec: ScenarioSpec, index: int
                          ) -> Tuple[Tuple[int, int, int, int], ...]:
    """Slot-level schedule for a kind='poisson_flap' fault: sorted
    `(down_slot, up_slot, plane, link)` rows.  The §6.6 MTBF methodology
    (`core.fault_tolerance.poisson_flaps`) draws per-link exponential
    inter-arrivals so the *fleet* (every fabric link on every selected
    plane) flaps `flaps_per_min` times per minute; draws are seeded by
    `(workload_seed, 6007, fault_index)` so the event-closure path and
    the JAX timeline compiler replay the identical schedule.

    `link` indexes leaf–spine uplinks row-major on leaf_spine and, on
    fat_tree, leaf–agg links followed by pod–core links (the same decode
    as random_fail's exact-k draws).  `up_slot = down_slot + down_slots`
    exactly — duration converts through whole slots, so no float
    boundary can disagree between backends."""
    f = spec.faults[index]
    topo = spec.topo
    planes = list(fault_planes(f, topo.n_planes))
    if topo.kind == "fat_tree":
        n_links = (topo.n_leaves * topo.n_aggs
                   + topo.n_pods * topo.n_cores)
    else:
        n_links = topo.n_leaves * topo.n_spines
    slot_s = spec.sim.slot_us * 1e-6
    stop = spec.sim.slots if f.stop_slot is None \
        else min(f.stop_slot, spec.sim.slots)
    window = stop - f.start_slot
    if window <= 0:
        return ()
    rng = np.random.default_rng((spec.workload_seed, 6007, index))
    evs = poisson_flaps(rng, len(planes) * n_links, f.flaps_per_min,
                        duration_s=f.down_slots * slot_s,
                        horizon_s=window * slot_s)
    out = []
    for ev in evs:
        dn = f.start_slot + int(ev.t_down // slot_s)
        out.append((dn, dn + f.down_slots,
                    planes[ev.link // n_links], ev.link % n_links))
    return tuple(sorted(out))


def apply_poisson_flap(t: int, f: FaultSpec, sched, topo: Fabric) -> None:
    """Apply one slot of a poisson_flap schedule to a runtime fabric.
    Restores run before kills so a back-to-back flap re-kills; schedule
    order is fixed, so both backends mutate identically.  Restore sets
    the link back to its full capacity (link_flap semantics) even if
    outages overlapped."""
    L = topo.n_leaves
    A = topo.n_aggs if topo.kind == "fat_tree" else topo.n_spines
    n_stage_a = L * A

    def place(link):
        if topo.kind != "fat_tree" or link < n_stage_a:
            return "a", link // A, link % A
        rem = link - n_stage_a
        return "b", rem // topo.n_cores, rem % topo.n_cores

    for dn, up, p, link in sched:
        if t != up:
            continue
        stage, x, y = place(link)
        if stage == "a":
            cap = topo.link_cap * topo.parallel_links
            topo.up[p, x, y] = cap
            topo.down[p, y, x] = cap
        else:
            topo.up2[p, x, y] = topo.core_cap
            topo.down2[p, x, y] = topo.core_cap
    for dn, up, p, link in sched:
        if t != dn:
            continue
        stage, x, y = place(link)
        if stage == "a":
            topo.fail_uplink(p, x, y, f.frac)
        else:
            topo.fail_core_link(p, x, y, f.frac)


def make_events(spec: ScenarioSpec
                ) -> Tuple[Callable[[int, Fabric], None],
                           Tuple[Tuple[int, str], ...]]:
    cap_link = spec.topo.uplink_cap
    cap_acc = spec.topo.access_cap
    faults = spec.faults
    # per-fault derived streams so 'random_fail' draws don't depend on
    # how many other faults exist or fire first
    fail_seeds = {i: (spec.workload_seed, 7919, i)
                  for i, f in enumerate(faults) if f.kind == "random_fail"}
    scheds = {i: poisson_flap_schedule(spec, i)
              for i, f in enumerate(faults) if f.kind == "poisson_flap"}

    def _restore_uplink(topo, p, leaf, spine):
        topo.up[p, leaf, spine] = cap_link
        topo.down[p, spine, leaf] = cap_link

    def events(t: int, topo: Fabric) -> None:
        for i, f in enumerate(faults):
            if f.kind == "link_kill":
                if t == f.start_slot:
                    for p in _planes(f, topo):
                        topo.fail_uplink(p, f.leaf, f.spine, f.frac)
                elif f.stop_slot is not None and t == f.stop_slot:
                    for p in _planes(f, topo):
                        _restore_uplink(topo, p, f.leaf, f.spine)
            elif f.kind == "link_flap":
                _flap(t, f,
                      lambda: [topo.fail_uplink(p, f.leaf, f.spine, f.frac)
                               for p in _planes(f, topo)],
                      lambda: [_restore_uplink(topo, p, f.leaf, f.spine)
                               for p in _planes(f, topo)])
            elif f.kind == "access_kill":
                if t == f.start_slot:
                    for p in _planes(f, topo):
                        topo.fail_access(p, f.host)
                elif f.stop_slot is not None and t == f.stop_slot:
                    for p in _planes(f, topo):
                        topo.restore_access(p, f.host)
            elif f.kind == "access_flap":
                _flap(t, f,
                      lambda: [topo.fail_access(p, f.host)
                               for p in _planes(f, topo)],
                      lambda: [topo.restore_access(p, f.host)
                               for p in _planes(f, topo)])
            elif f.kind == "cascade":
                for j, s in enumerate(f.spines):
                    if t == f.start_slot + j * f.period:
                        for p in _planes(f, topo):
                            if topo.kind == "fat_tree":
                                # whole-switch loss: the agg's leaf AND
                                # core links die together
                                topo.fail_agg(p, f.pod, s)
                            else:
                                topo.up[p, :, s] = 0.0
                                topo.down[p, s, :] = 0.0
            elif f.kind == "straggler":
                if t == f.start_slot:
                    for p in _planes(f, topo):
                        topo.access[p, f.host] = cap_acc * f.frac
                elif f.stop_slot is not None and t == f.stop_slot:
                    for p in _planes(f, topo):
                        topo.access[p, f.host] = cap_acc
            elif f.kind == "leaf_trim":
                if t == f.start_slot:
                    for p in _planes(f, topo):
                        topo.trim_leaf_uplinks(p, f.leaf, f.frac)
            elif f.kind == "random_fail":
                if t == f.start_slot:
                    rng = np.random.default_rng(fail_seeds[i])
                    if f.count:
                        # exact-k mode: `count` fabric-link draws per
                        # plane (repeats compound, like the Fig 14a
                        # proxy); on fat_tree both stages are in the
                        # draw population
                        for p in _planes(f, topo):
                            for _ in range(f.count):
                                _fail_random_link(topo, p, rng, f.frac)
                    else:
                        topo.random_link_failures(rng, f.frac)
            elif f.kind == "core_kill":
                if t == f.start_slot:
                    for p in _planes(f, topo):
                        topo.fail_core_link(p, f.pod, f.core, f.frac)
                elif f.stop_slot is not None and t == f.stop_slot:
                    for p in _planes(f, topo):
                        topo.up2[p, f.pod, f.core] = topo.core_cap
                        topo.down2[p, f.pod, f.core] = topo.core_cap
            elif f.kind == "poisson_flap":
                apply_poisson_flap(t, f, scheds[i], topo)

    slots = sorted(
        {sl for i, f in enumerate(faults)
         for sl in fault_transition_slots(f, spec.sim.slots,
                                          sched=scheds.get(i))},
        key=lambda x: (x[0], x[1]))
    return events, tuple(slots)


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------

def build_topology(ts) -> Fabric:
    """Instantiate the runtime fabric a `TopologySpec` describes."""
    if ts.kind == "fat_tree":
        return FatTree(
            n_pods=ts.n_pods, leaves_per_pod=ts.leaves_per_pod,
            n_aggs=ts.n_aggs, n_cores=ts.n_cores,
            hosts_per_leaf=ts.hosts_per_leaf, n_planes=ts.n_planes,
            parallel_links=ts.parallel_links, link_cap=ts.link_cap,
            core_link_cap=ts.core_link_cap, access_cap=ts.access_cap)
    return LeafSpine(
        n_leaves=ts.n_leaves, n_spines=ts.n_spines,
        hosts_per_leaf=ts.hosts_per_leaf, n_planes=ts.n_planes,
        parallel_links=ts.parallel_links, link_cap=ts.link_cap,
        access_cap=ts.access_cap)


def compile_scenario(spec: ScenarioSpec) -> CompiledScenario:
    spec.validate()
    topo = build_topology(spec.topo)
    rng = np.random.default_rng(spec.workload_seed)
    tenants = resolve_tenants(spec, rng)
    flows, phase_mult, schedules = build_flows(spec, topo, tenants, rng)
    if not flows:
        raise ValueError(f"{spec.name}: scenario compiled to zero flows")
    events, fault_slots = make_events(spec)
    cfg = SimConfig(
        slots=spec.sim.slots, slot_us=spec.sim.slot_us,
        routing=spec.sim.routing, nic=spec.sim.nic,
        base_rtt_us=spec.sim.base_rtt_us,
        warmup_frac=spec.sim.warmup_frac,
        sw_lb_delay_ms=spec.sim.sw_lb_delay_ms,
        seed=spec.sim.seed, record_every=spec.sim.record_every,
        backend=spec.sim.backend, trace=spec.sim.trace)
    vis_topo = backup = None
    if spec.reaction is not None and spec.reaction.enabled:
        if reaction_lag(spec.reaction, spec.sim.routing) > 0:
            # pristine twin for the lagged routing view; the shared
            # events closure replays into it `lag` slots late
            vis_topo = build_topology(spec.topo)
        if spec.reaction.mode == "backup":
            cpa = (spec.topo.n_cores // spec.topo.n_aggs
                   if spec.topo.kind == "fat_tree" else 1)
            backup = backup_path_table(spec.topo.kind, spec.topo.n_paths,
                                       cores_per_agg=cpa)
    return CompiledScenario(spec=spec, topo=topo, flows=flows, cfg=cfg,
                            events=events, tenants=tenants,
                            fault_slots=fault_slots,
                            phase_mult=phase_mult, schedules=schedules,
                            vis_topo=vis_topo, backup=backup)


def run_scenario(spec: ScenarioSpec) -> SimResult:
    """Compile + simulate in one call (fresh topology every time)."""
    return compile_scenario(spec).run()
