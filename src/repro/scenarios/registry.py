"""Named scenario library.

Ports the paper-figure experiments (fig8/fig9/fig10/fig11/fig12) to
declarative specs — the `benchmarks/fig*.py` scripts pull their setups
from here — and adds new multi-tenant / failure-compound scenarios the
bespoke scripts never covered.  Every entry is a zero-argument factory so
specs stay immutable and cheap to parameterize via `.with_sim(...)`.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from .spec import (FaultSpec, ReactionSpec, ScenarioSpec, ScheduleSpec,
                   SimSpec, TenantSpec, TopologySpec, WorkloadSpec)

SCENARIOS: Dict[str, Callable[[], ScenarioSpec]] = {}

_TESTBED = TopologySpec(n_leaves=8, n_spines=8, hosts_per_leaf=8,
                        n_planes=1)


def register(fn: Callable[[], ScenarioSpec]) -> Callable[[], ScenarioSpec]:
    spec = fn()
    spec.validate()
    SCENARIOS[spec.name] = fn
    return fn


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return SCENARIOS[name]()
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}"
        ) from None


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# paper-figure ports
# ---------------------------------------------------------------------------

@register
def fig8_bisection() -> ScenarioSpec:
    return ScenarioSpec(
        name="fig8_bisection",
        description="Fig 8 / §6.2: RDMA bisection at maximum load, "
                    "64 endpoints, worst-case cross-spine pairing.",
        topo=_TESTBED,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("bisection"),),
        sim=SimSpec(slots=600, seed=1),
        workload_seed=0)


@register
def fig9_single_all2all() -> ScenarioSpec:
    return ScenarioSpec(
        name="fig9_single_all2all",
        description="Fig 9 (left) / §6.3: one 32-rank All2All, capacity "
                    "ceiling per stack.",
        topo=_TESTBED,
        tenants=(TenantSpec("main", placement="block", n_hosts=32),),
        workloads=(WorkloadSpec("all2all"),),
        sim=SimSpec(slots=400, seed=2))


@register
def fig9_victim_noise() -> ScenarioSpec:
    return ScenarioSpec(
        name="fig9_victim_noise",
        description="Fig 9 (right) / §6.3: 16-rank victim All2All "
                    "interleaved with a 48-rank noise All2All.",
        topo=_TESTBED,
        tenants=(TenantSpec("victim", placement="interleave", stride=4,
                            n_hosts=16),
                 TenantSpec("noise", placement="remainder")),
        workloads=(WorkloadSpec("all2all", tenant="victim"),
                   WorkloadSpec("all2all", tenant="noise")),
        sim=SimSpec(slots=400, seed=2))


@register
def fig10_victim_alone() -> ScenarioSpec:
    return ScenarioSpec(
        name="fig10_victim_alone",
        description="Fig 10 baseline: 16-rank training All2All with the "
                    "fabric otherwise idle.",
        topo=_TESTBED,
        tenants=(TenantSpec("victim", placement="interleave", stride=4,
                            n_hosts=16),),
        workloads=(WorkloadSpec("all2all", tenant="victim"),),
        sim=SimSpec(slots=400, seed=4),
        workload_seed=3)


@register
def fig10_victim_noise() -> ScenarioSpec:
    return ScenarioSpec(
        name="fig10_victim_noise",
        description="Fig 10: training All2All next to RDMA-bisection "
                    "noise; step-time dilation per stack.",
        topo=_TESTBED,
        tenants=(TenantSpec("victim", placement="interleave", stride=4,
                            n_hosts=16),
                 TenantSpec("noise", placement="remainder")),
        workloads=(WorkloadSpec("all2all", tenant="victim"),
                   WorkloadSpec("bisection", tenant="noise")),
        sim=SimSpec(slots=400, seed=4),
        workload_seed=3)


def fig11_partial_uplink(keep: float) -> ScenarioSpec:
    """Fig 1d / Fig 11 / §6.4 port, parameterized by surviving-uplink
    fraction on leaf 0 (whole discrete links are disabled)."""
    t = _TESTBED
    n_keep = max(1, round(t.n_spines * keep))
    faults = tuple(FaultSpec("link_kill", start_slot=0, plane=0, leaf=0,
                             spine=s)
                   for s in range(n_keep, t.n_spines))
    return ScenarioSpec(
        name=f"fig11_keep{int(keep * 100)}pct",
        description="Fig 11 / §6.4: All2All with leaf-0 uplinks reduced "
                    f"to {int(keep * 100)}% capacity.",
        topo=t,
        tenants=(TenantSpec("main", placement="block", n_hosts=48),),
        workloads=(WorkloadSpec("all2all"),),
        faults=faults,
        sim=SimSpec(slots=400, seed=5, routing="war"))


@register
def fig11_degraded_leaf() -> ScenarioSpec:
    from dataclasses import replace
    return replace(fig11_partial_uplink(0.5), name="fig11_degraded_leaf")


@register
def fig12_plane_flap() -> ScenarioSpec:
    return ScenarioSpec(
        name="fig12_plane_flap",
        description="Fig 12 / §6.4: one host-plane link dies at slot 50; "
                    "hardware PLB vs software LB recovery "
                    "(swlb via .with_sim(nic='swlb', slots=12000)).",
        topo=TopologySpec(n_leaves=2, n_spines=2, hosts_per_leaf=4,
                          n_planes=4, access_cap=0.25),
        tenants=(TenantSpec("main", placement="explicit", hosts=(0, 4)),),
        workloads=(WorkloadSpec("pairs", pairs=((0, 4),)),),
        faults=(FaultSpec("access_kill", start_slot=50, plane=1, host=0),),
        sim=SimSpec(slots=600, slot_us=100.0, seed=6))


# ---------------------------------------------------------------------------
# new scenarios
# ---------------------------------------------------------------------------

@register
def multi_tenant_50_50() -> ScenarioSpec:
    return ScenarioSpec(
        name="multi_tenant_50_50",
        description="Two equal 32-rank All2All tenants interleaved on "
                    "every leaf — symmetric-contention isolation probe.",
        topo=_TESTBED,
        tenants=(TenantSpec("a", placement="interleave", stride=2,
                            n_hosts=32),
                 TenantSpec("b", placement="remainder")),
        workloads=(WorkloadSpec("all2all", tenant="a"),
                   WorkloadSpec("all2all", tenant="b")),
        sim=SimSpec(slots=400, seed=7))


@register
def multi_tenant_75_25() -> ScenarioSpec:
    return ScenarioSpec(
        name="multi_tenant_75_25",
        description="Asymmetric split: a 16-rank tenant shares leaves "
                    "with a 48-rank tenant (small-tenant starvation "
                    "probe).",
        topo=_TESTBED,
        tenants=(TenantSpec("small", placement="interleave", stride=4,
                            n_hosts=16),
                 TenantSpec("large", placement="remainder")),
        workloads=(WorkloadSpec("all2all", tenant="small"),
                   WorkloadSpec("all2all", tenant="large")),
        sim=SimSpec(slots=400, seed=8))


@register
def flap_during_incast() -> ScenarioSpec:
    return ScenarioSpec(
        name="flap_during_incast",
        description="30-source incast onto 2 sinks while a sink-leaf "
                    "uplink flaps every 60 slots — reaction time under "
                    "sustained congestion.",
        topo=_TESTBED,
        tenants=(TenantSpec("main", placement="block", n_hosts=32),),
        workloads=(WorkloadSpec("incast", sinks=2, demand=0.5),),
        faults=(FaultSpec("link_flap", start_slot=100, period=60,
                          duty=0.34, plane=0, leaf=0, spine=0),),
        sim=SimSpec(slots=400, seed=9))


@register
def cascading_spine_loss() -> ScenarioSpec:
    return ScenarioSpec(
        name="cascading_spine_loss",
        description="Rolling cascade: spines 7, 6, 5 die 80 slots apart "
                    "under a 48-rank All2All (weighted-AR re-balance "
                    "after each loss).",
        topo=_TESTBED,
        tenants=(TenantSpec("main", placement="block", n_hosts=48),),
        workloads=(WorkloadSpec("all2all"),),
        faults=(FaultSpec("cascade", start_slot=100, period=80,
                          spines=(7, 6, 5)),),
        sim=SimSpec(slots=400, seed=10, routing="war"))


@register
def straggler_failure_compound() -> ScenarioSpec:
    return ScenarioSpec(
        name="straggler_failure_compound",
        description="Compound fault: host 5 slows to 30% for slots "
                    "80-280 while an unrelated uplink dies at slot 150 "
                    "(§5.2 telemetry signatures under overlap).",
        topo=_TESTBED,
        tenants=(TenantSpec("main", placement="block", n_hosts=32),),
        workloads=(WorkloadSpec("all2all"),),
        faults=(FaultSpec("straggler", start_slot=80, stop_slot=280,
                          host=5, frac=0.3, plane=-1),
                FaultSpec("link_kill", start_slot=150, plane=0, leaf=1,
                          spine=2)),
        sim=SimSpec(slots=400, seed=11))


@register
def storage_background_mix() -> ScenarioSpec:
    return ScenarioSpec(
        name="storage_background_mix",
        description="32-rank training All2All sharing the fabric with "
                    "low-rate storage/checkpoint background traffic from "
                    "the other 32 hosts.",
        topo=_TESTBED,
        tenants=(TenantSpec("train", placement="interleave", stride=2,
                            n_hosts=32),
                 TenantSpec("storage", placement="remainder")),
        workloads=(WorkloadSpec("all2all", tenant="train"),
                   WorkloadSpec("storage", tenant="storage", demand=0.25,
                                fanout=3)),
        sim=SimSpec(slots=400, seed=12))


@register
def permutation_stress() -> ScenarioSpec:
    return ScenarioSpec(
        name="permutation_stress",
        description="Random permutation at line rate over all 64 hosts — "
                    "ECMP's classic collision workload "
                    "(.with_sim(routing='ecmp', nic='dcqcn') for the ETH "
                    "baseline).",
        topo=_TESTBED,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("permutation"),),
        sim=SimSpec(slots=400, seed=13))


@register
def staggered_incast_bursts() -> ScenarioSpec:
    return ScenarioSpec(
        name="staggered_incast_bursts",
        description="Two 15-source incasts on disjoint tenants, the "
                    "second admitted 150 slots late — burst-on-busy "
                    "admission dynamics.",
        topo=_TESTBED,
        tenants=(TenantSpec("early", placement="block", n_hosts=16),
                 TenantSpec("late", placement="block", offset=16,
                            n_hosts=16)),
        workloads=(WorkloadSpec("incast", tenant="early", demand=0.8),
                   WorkloadSpec("incast", tenant="late", demand=0.8,
                                start_slot=150)),
        sim=SimSpec(slots=400, seed=14))


# ---------------------------------------------------------------------------
# topology-kind scenarios: flat multiplane vs 3-tier fat-tree (§3.1)
# ---------------------------------------------------------------------------
#
# The comparison pair is equal-*bisection*: both fabrics deliver 1:1
# host bandwidth pre-failure, with the same per-leaf fabric-link count
# granularity — which already costs the fat-tree ~2x the link budget
# (two stages instead of one), the paper's first argument for replacing
# hierarchical depth with topological parallelism.  The resiliency
# scenario then shows the second: under the same uniform link-failure
# fraction the multiplane degrades capacity-proportionally while the
# fat-tree's four-hop cross-pod paths (min-cut across stages) strand
# surviving capacity — see `topo_kind_resiliency` in
# `repro.experiments.library`.

# multiplane: 2 planes x 8 spines -> per-leaf fabric capacity 4.32 for
# 4 hosts at line rate.  The slightly over-provisioned non-dyadic cap
# (0.27, not 0.25) keeps queue integrators off exact quantization-bin
# edges, where the two backends' different (mathematically equal)
# summation orders would fork the trajectory.
_BISECT_LS = TopologySpec(n_leaves=4, n_spines=8, hosts_per_leaf=4,
                          n_planes=2, link_cap=0.27)
# fat-tree: 2 pods x 2 leaves, 8 aggs/pod (0.54-cap leaf links), 8
# cores on 1.08-cap pod links -> same per-leaf fabric capacity 4.32
_BISECT_FT = TopologySpec(kind="fat_tree", n_leaves=4, hosts_per_leaf=4,
                          n_pods=2, n_aggs=8, n_cores=8, link_cap=0.54,
                          core_link_cap=1.08)


def _bisection_resiliency(name: str, topo: TopologySpec,
                          which: str) -> ScenarioSpec:
    return ScenarioSpec(
        name=name,
        description=f"Equal-bisection {which} under 25% uniform random "
                    "fabric link failures at slot 150 — the §3.1/§6.4 "
                    "multiplane-vs-hierarchy resiliency probe "
                    "(post-warmup mean goodput = post-failure bisection "
                    "throughput; at this failure rate the fat-tree's "
                    "4-hop cross-pod min-cuts strand surviving capacity "
                    "and the multiplane wins by ~30%+ on any seed).",
        topo=topo,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("bisection"),),
        faults=(FaultSpec("random_fail", start_slot=150, frac=0.25,
                          plane=-1),),
        sim=SimSpec(slots=400, seed=16, routing="war",
                    warmup_frac=0.45),
        workload_seed=4)


@register
def bisection_multiplane() -> ScenarioSpec:
    return _bisection_resiliency("bisection_multiplane", _BISECT_LS,
                                 "2-plane leaf-spine")


@register
def bisection_fat_tree() -> ScenarioSpec:
    return _bisection_resiliency("bisection_fat_tree", _BISECT_FT,
                                 "3-tier fat-tree")


# the 64-host fat-tree testbed: 2 pods x 4 leaves x 8 hosts, 4 aggs/pod
# (2.0-cap leaf links), 8 cores on 4.0-cap pod links — non-blocking at
# both stages, mirroring _TESTBED's scale
_FT_TESTBED = TopologySpec(kind="fat_tree", n_leaves=8, hosts_per_leaf=8,
                           n_pods=2, n_aggs=4, n_cores=8, link_cap=2.0,
                           core_link_cap=4.0)


@register
def ft_cross_pod_all2all() -> ScenarioSpec:
    return ScenarioSpec(
        name="ft_cross_pod_all2all",
        description="64-rank All2All on the fat-tree testbed — half the "
                    "pairs cross pods and ride leaf-agg-core-agg-leaf "
                    "paths (4 bottleneck stages vs the multiplane's 2).",
        topo=_FT_TESTBED,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("all2all"),),
        sim=SimSpec(slots=400, seed=17))


@register
def ft_core_failure_resiliency() -> ScenarioSpec:
    return ScenarioSpec(
        name="ft_core_failure_resiliency",
        description="Fat-tree core-tier faults under a cross-pod "
                    "bisection load: two of pod 0's core links die at "
                    "slot 100 (one heals at slot 260) — the tier the "
                    "multiplane design deletes, weighted-AR steering "
                    "around the stranded agg paths (Fig 1c / §6.4).",
        topo=_FT_TESTBED,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("bisection"),),
        faults=(FaultSpec("core_kill", start_slot=100, pod=0, core=0),
                FaultSpec("core_kill", start_slot=100, stop_slot=260,
                          pod=0, core=2),),
        sim=SimSpec(slots=400, seed=18, routing="war"))


@register
def allreduce_under_random_failures() -> ScenarioSpec:
    return ScenarioSpec(
        name="allreduce_under_random_failures",
        description="Ring allreduce over 64 hosts with 10% uniform "
                    "random fabric link failures at slot 100 "
                    "(Fig 1c / §6.4 operating point).",
        topo=_TESTBED,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("allreduce", bytes_total=220.0),),
        faults=(FaultSpec("random_fail", start_slot=100, frac=0.10),),
        sim=SimSpec(slots=400, seed=15, routing="war"))


# ---------------------------------------------------------------------------
# failure-reaction scenarios: detection latency + reroute policy (§6.4/§6.6)
# ---------------------------------------------------------------------------
#
# Same 10%-failure operating point as `allreduce_under_random_failures`,
# but routing no longer reacts instantly: for `detect_slots` after a
# fault the dead paths keep attracting traffic (blackholed bytes), then
# either the precomputed backup table kicks in (hardware PLB-style, §6.4
# "<3 ms failover") or ECMP re-randomizes after a further
# `converge_slots` (software LB-style, ~1 s).  ECMP routing so the
# policies differ maximally — adaptive modes steer around residual
# capacity and mask the contrast.

_REROUTE_REACTION = ReactionSpec(detect_slots=2, mode="backup",
                                 converge_slots=60)


@register
def reroute_random_failures() -> ScenarioSpec:
    return ScenarioSpec(
        name="reroute_random_failures",
        description="Ring allreduce over 64 hosts, 10% random fabric "
                    "link failures at slot 100 under delayed detection "
                    "(2 slots) with precomputed backup-path failover; "
                    "sweep reaction.mode='rehash' for the software-LB "
                    "contrast (§6.4).",
        topo=_TESTBED,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("allreduce", bytes_total=220.0),),
        faults=(FaultSpec("random_fail", start_slot=100, frac=0.10),),
        reaction=_REROUTE_REACTION,
        sim=SimSpec(slots=400, seed=15, routing="ecmp"))


@register
def reroute_random_failures_ft() -> ScenarioSpec:
    return ScenarioSpec(
        name="reroute_random_failures_ft",
        description="Fat-tree variant of reroute_random_failures: the "
                    "backup table chains agg-then-core alternates, so "
                    "failover shifts traffic across both stages.",
        topo=_FT_TESTBED,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("allreduce", bytes_total=220.0),),
        faults=(FaultSpec("random_fail", start_slot=100, frac=0.10),),
        reaction=_REROUTE_REACTION,
        sim=SimSpec(slots=400, seed=15, routing="ecmp"))


@register
def poisson_flap_storm() -> ScenarioSpec:
    return ScenarioSpec(
        name="poisson_flap_storm",
        description="Fleet-MTBF flap storm (§6.6): every fabric link "
                    "flaps by Poisson arrival (the giga-fleet rate "
                    "time-compressed into the 35 ms window — ~15 flaps, "
                    "12-slot outages) under a 48-rank All2All with "
                    "delayed detection and backup failover — survival "
                    "means blackhole windows stay bounded by "
                    "detect_slots per flap.",
        topo=_TESTBED,
        tenants=(TenantSpec("main", placement="block", n_hosts=48),),
        workloads=(WorkloadSpec("all2all"),),
        faults=(FaultSpec("poisson_flap", start_slot=50,
                          flaps_per_min=24000.0, down_slots=12,
                          frac=1.0),),
        reaction=_REROUTE_REACTION,
        sim=SimSpec(slots=400, slot_us=100.0, seed=19, routing="ecmp"))


# ---------------------------------------------------------------------------
# training-step co-simulation (repro.comms): real collective schedules
# compiled into the fabric
# ---------------------------------------------------------------------------
#
# 8 ranks on a fig12-style 4-plane fabric (access 0.25 x line per
# plane).  Two hosts per leaf puts every other DP ring hop and every PP
# edge on the fabric, so both access-plane and fabric events shape the
# schedule.  line_rate_gbps calibrates the reduced() model's byte
# volumes so one DP sync stream spans tens of slots — wide enough that
# a mid-sync plane flap visibly inflates derived step time.
_TRAIN_TOPO = TopologySpec(n_leaves=4, n_spines=2, hosts_per_leaf=2,
                           n_planes=4, access_cap=0.25)

# dense: llama3-8b (reduced), dp=4 x pp=2.  Compiled windows are
# deterministic: w_fwd=11, w_bwd=22, w_sync=28, period 63, steps at
# slots 0/63/126 — step 1's gradient-sync window is [96, 124).
_TRAIN_DENSE = ScheduleSpec(model="llama3-8b", dp=4, tp=1, pp=2, steps=3,
                            microbatches=4, tokens_per_rank=1024,
                            line_rate_gbps=1.0, ckpt_every=2)

# MoE: phi3.5-moe (reduced), dp=4 x tp=2 — adds per-step EP all2all
# dispatch (capacity math) and TP streams.  Windows: w_fwd=27, w_bwd=54,
# w_sync=40, period 123, steps at 0/123/246 — step 1 sync = [204, 244).
_TRAIN_MOE = ScheduleSpec(model="phi3.5-moe-42b-a6.6b", dp=4, tp=2, pp=1,
                          steps=3, microbatches=4, tokens_per_rank=512,
                          line_rate_gbps=1.0)


@register
def train_step_baseline() -> ScenarioSpec:
    return ScenarioSpec(
        name="train_step_baseline",
        description="Training co-simulation baseline: 3 steps of a dense "
                    "llama3-8b (reduced) dp=4 x pp=2 schedule — DP ring "
                    "sync + pipeline edges phased by the demand-"
                    "multiplier timeline, checkpoint write after step 2.",
        topo=_TRAIN_TOPO,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("schedule", schedule=_TRAIN_DENSE),),
        sim=SimSpec(slots=260, slot_us=100.0, seed=22))


@register
def train_step_flap() -> ScenarioSpec:
    return ScenarioSpec(
        name="train_step_flap",
        description="Plane flap during training: rank 0 loses NIC plane "
                    "1 for exactly step 1's gradient-sync window "
                    "(slots 96-126) — fabric slowdown -> step-time "
                    "inflation -> recovery by step 2.",
        topo=_TRAIN_TOPO,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("schedule", schedule=_TRAIN_DENSE),),
        faults=(FaultSpec("access_kill", start_slot=96, stop_slot=126,
                          plane=1, host=0),),
        sim=SimSpec(slots=260, slot_us=100.0, seed=22))


@register
def train_step_flap_moe() -> ScenarioSpec:
    return ScenarioSpec(
        name="train_step_flap_moe",
        description="MoE variant: phi3.5-moe (reduced) dp=4 x tp=2 "
                    "schedule with per-step EP all2all dispatch; the "
                    "same plane flap covers step 1's sync window "
                    "(slots 204-246).",
        topo=_TRAIN_TOPO,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("schedule", schedule=_TRAIN_MOE),),
        faults=(FaultSpec("access_kill", start_slot=204, stop_slot=246,
                          plane=1, host=0),),
        sim=SimSpec(slots=420, slot_us=100.0, seed=23))


@register
def giga_fabric_storage() -> ScenarioSpec:
    """The large-scale acceptance shape for the kernelized engine: a
    4096-host / 102,400-flow multiplane leaf-spine point in the style of
    Fig 14's giga-scale resiliency sweeps.  At this size the dense
    (leaves x leaves x paths x planes) load matrices are the memory
    bottleneck, so `agg_mode_default` flips the JAX engine to the
    sparse segment-summed path — `benchmarks/backend_bench.py --large`
    and `benchmarks/fig14_large_scale.py --giga` both time it."""
    return ScenarioSpec(
        name="giga_fabric_storage",
        description="Giga-scale point: 256 leaves x 16 hosts, 2 planes, "
                    "102,400 storage flows (fanout 25), 8 random fabric "
                    "link kills mid-run (Fig 14a-style concurrent "
                    "failures at scale).",
        topo=TopologySpec(n_leaves=256, n_spines=16, hosts_per_leaf=16,
                          n_planes=2),
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("storage", demand=0.3, fanout=25),),
        faults=(FaultSpec("random_fail", start_slot=30, count=8,
                          frac=1.0, plane=-1),),
        # numpy default keeps the golden snapshot f64-deterministic;
        # the benchmarks dispatch it through backend="jax" explicitly
        sim=SimSpec(slots=60, seed=21, routing="ecmp", nic="spx"))
