"""Declarative scenario engine: spec DSL -> compiler -> named registry ->
batched multi-seed runner (see ISSUE/README scenario table)."""
from .spec import (FaultBoundsError, FaultSpec, ScenarioSpec, SimSpec,
                   TenantSpec, TopologySpec, WorkloadSpec)
from .compile import (CompiledScenario, compile_scenario, run_scenario)
from .registry import (SCENARIOS, fig11_partial_uplink, get_scenario,
                       list_scenarios, register)
from .runner import (ScenarioMetrics, SweepGrid, distill_metrics,
                     metrics_csv, run_point, sweep, sweep_many)
