import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh)
cell on the production meshes, with memory/cost analysis and HLO collective
accounting — no device allocation (ShapeDtypeStruct only).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both   # fan out (subprocesses)
"""
import argparse                                            # noqa: E402
import json                                                # noqa: E402
import re                                                  # noqa: E402
import subprocess                                          # noqa: E402
import sys                                                 # noqa: E402
import time                                                # noqa: E402
import traceback                                           # noqa: E402

import jax                                                 # noqa: E402
import jax.numpy as jnp                                    # noqa: E402
import numpy as np                                         # noqa: E402

from repro.configs import (ARCHS, ASSIGNED, SHAPES, get_config,  # noqa
                           shape_applicable)
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.specs import (batch_specs, cache_specs, decode_specs,  # noqa
                                make_ctx, opt_specs, param_specs)
from repro.models import decode_step, loss_fn, prefill_step  # noqa: E402
from repro.train.loop import TrainerConfig, make_train_step  # noqa: E402
from repro.core.planes import PlaneConfig                  # noqa: E402


DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collectives(hlo: str):
    """Sum per-op payload bytes for every collective in optimized HLO.

    Shapes are per-PARTITION under SPMD; 'bytes' is the op's output payload
    per device; 'wire_bytes' applies ring-algorithm factors with the
    replica-group size."""
    ops = []
    # e.g.:  %all-reduce.1 = bf16[59,1024,128]{...} all-reduce(...),
    #        replica_groups={{0,1,2,3},...} or [8,64]<=[512]{...}
    pat = re.compile(
        r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^a-z]*"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(")
    grp_pat = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
    grp_pat2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    for line in hlo.splitlines():
        m = pat.search(line)
        if not m:
            continue
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        size = n * DTYPE_BYTES[dt]
        gsize = 1
        g = grp_pat.search(line)
        if g:
            gsize = len(g.group(1).split(","))
        else:
            g2 = grp_pat2.search(line)
            if g2:
                gsize = int(g2.group(2))
        f = (gsize - 1) / max(gsize, 1)
        wire = {"all-reduce": 2 * size * f,
                "all-gather": size * f,
                "reduce-scatter": size * f,
                "all-to-all": size * f,
                "collective-permute": size}[kind]
        ops.append({"kind": kind, "bytes": size, "group": gsize,
                    "wire_bytes": wire})
    return ops


def accounting_config(cfg, shape, mesh):
    """Dry-run lowering config: every loop unrolled (or trip-count-1) so
    cost_analysis counts all iterations; block sizes chosen so the largest
    attention score block stays ~<=1 GiB/device and unrolled bodies stay
    bounded."""
    import dataclasses
    n_dev = int(np.prod(list(mesh.shape.values())))
    tp = mesh.shape.get("model", 1)
    dp = n_dev // tp
    if shape.mode == "train":
        b_loc = max(shape.global_batch // dp, 1)
        sq = shape.seq_len
    elif shape.mode == "prefill":
        b_loc = max(shape.global_batch // dp, 1)
        sq = shape.seq_len
    else:
        b_loc, sq = max(shape.global_batch // dp, 1), 1
    h_loc = max((cfg.n_heads or 1) // tp, 1)
    budget = 1 << 30                      # 1 GiB fp32 score block
    chunk = budget // max(b_loc * h_loc * sq * 4, 1)
    chunk = max(512, min(1 << (chunk.bit_length() - 1) if chunk else 512,
                         8192, shape.seq_len))
    ssm_chunk = min(2048, shape.seq_len) if cfg.ssm_heads else cfg.ssm_chunk
    loss_chunk = max(256, min(2048, (budget // 4) //
                              max(b_loc * cfg.vocab // tp, 1) or 256))
    return dataclasses.replace(
        cfg, scan_layers=False, unroll_loops=True, attn_chunk=chunk,
        ssm_chunk=ssm_chunk, loss_chunk=loss_chunk)


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               accounting: bool = True, n_periods: int | None = None,
               remat: str | None = None):
    cfg = get_config(arch)
    if remat is None:
        remat = os.environ.get("REPRO_REMAT") or None
    if remat:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ctx = make_ctx(mesh, cfg)
    if accounting:
        cfg = accounting_config(cfg, shape, mesh)
    if n_periods is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, n_layers=cfg.n_prefix_layers +
            n_periods * cfg.pattern_len)
    tcfg = TrainerConfig(
        plane=PlaneConfig(n_planes=4, microchunks=16),
        cast_params_bf16=not os.environ.get("REPRO_NOCAST"))

    if shape.mode == "train":
        step = make_train_step(cfg, ctx, tcfg)
        ps = param_specs(cfg, ctx)
        os_ = opt_specs(ps)
        bs = batch_specs(cfg, shape, ctx)
        lowered = step.lower(ps, os_, bs,
                             jnp.zeros((), jnp.int32),
                             jax.random.PRNGKey(0))
    elif shape.mode == "prefill":
        ps = param_specs(cfg, ctx)
        bs = batch_specs(cfg, shape, ctx)
        cs = cache_specs(cfg, shape.global_batch, shape.seq_len, ctx)
        fn = jax.jit(lambda p, t, c, f=None:
                     prefill_step(p, cfg, t, ctx, c, f))
        args = [ps, bs["tokens"], cs]
        if "frontend_embeds" in bs:
            lowered = jax.jit(
                lambda p, t, c, f: prefill_step(p, cfg, t, ctx, c, f)
            ).lower(ps, bs["tokens"], cs, bs["frontend_embeds"])
        else:
            lowered = jax.jit(
                lambda p, t, c: prefill_step(p, cfg, t, ctx, c)
            ).lower(ps, bs["tokens"], cs)
    else:                                    # decode / long-context decode
        ps = param_specs(cfg, ctx)
        ds = decode_specs(cfg, shape, ctx)
        lowered = jax.jit(
            lambda p, t, q, c: decode_step(p, cfg, t, q, ctx, c)
        ).lower(ps, ds["tokens"], ds["position"], ds["caches"])
    return lowered, ctx


def _analyze(compiled, rec: dict, prefix: str = "") -> None:
    try:
        mem = compiled.memory_analysis()
        rec[prefix + "memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        print(prefix + "memory_analysis:", rec[prefix + "memory"], flush=True)
    except Exception as e:                                 # noqa: BLE001
        rec[prefix + "memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec[prefix + "cost"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float)) and
                                k in ("flops", "bytes accessed",
                                      "transcendentals", "optimal_seconds")}
        print(prefix + "cost_analysis:", rec[prefix + "cost"], flush=True)
    except Exception as e:                                 # noqa: BLE001
        rec[prefix + "cost"] = {"error": str(e)}
    try:
        ops = parse_collectives(compiled.as_text())
        agg = {}
        for op in ops:
            a = agg.setdefault(op["kind"],
                               {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
            a["count"] += 1
            a["bytes"] += op["bytes"]
            a["wire_bytes"] += op["wire_bytes"]
        rec[prefix + "collectives"] = agg
        rec[prefix + "collective_wire_bytes"] = sum(
            a["wire_bytes"] for a in agg.values())
        print(prefix + "collectives:", json.dumps(agg), flush=True)
    except Exception as e:                                 # noqa: BLE001
        rec[prefix + "collectives"] = {"error": str(e)}


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "ok": False}
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        rec.update(skipped=True, reason=why)
        return rec

    # Pass 1 — PRODUCTION config (scan-over-layers): proves lower+compile
    # on the mesh; memory_analysis reflects the deployable program.
    t0 = time.time()
    lowered, ctx = lower_cell(arch, shape_name, mesh_kind, accounting=False)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    _analyze(compiled, rec, prefix="")
    del compiled, lowered

    # Pass 2 — ACCOUNTING (all loops unrolled, two-point extrapolation):
    # every pattern period is identical, so lowering with 1 and 2 periods
    # and extrapolating  X(n) = X(1) + (n-1) * (X(2) - X(1))  gives exact
    # per-iteration FLOPs / bytes / collective counts without compiling 60
    # unrolled layers (lax.scan bodies are counted once by cost analysis).
    try:
        t0 = time.time()
        recs = []
        for k in (1, 2):
            lw, _ = lower_cell(arch, shape_name, mesh_kind,
                               accounting=True, n_periods=k)
            cp = lw.compile()
            r = {}
            _analyze(cp, r, prefix=f"p{k}_")
            recs.append(r)
            del cp, lw
        n = get_config(arch).n_periods
        rec["acct_compile_s"] = round(time.time() - t0, 2)
        rec["acct_cost"] = _extrapolate_dict(
            recs[0].get("p1_cost", {}), recs[1].get("p2_cost", {}), n)
        rec["acct_collectives"] = _extrapolate_coll(
            recs[0].get("p1_collectives", {}),
            recs[1].get("p2_collectives", {}), n)
        rec["acct_collective_wire_bytes"] = sum(
            a.get("wire_bytes", 0.0)
            for a in rec["acct_collectives"].values()
            if isinstance(a, dict))
        print("acct_cost:", rec["acct_cost"], flush=True)
        print("acct_collectives:", json.dumps(rec["acct_collectives"]),
              flush=True)
    except Exception:                                      # noqa: BLE001
        rec["acct_error"] = traceback.format_exc()[-2000:]
    rec["ok"] = True
    return rec


def _extrapolate_dict(x1: dict, x2: dict, n: int) -> dict:
    out = {}
    for k in set(x1) | set(x2):
        a, b = float(x1.get(k, 0.0)), float(x2.get(k, 0.0))
        out[k] = a + (n - 1) * (b - a)
    return out


def _extrapolate_coll(c1: dict, c2: dict, n: int) -> dict:
    out = {}
    for kind in set(c1) | set(c2):
        a = c1.get(kind, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
        b = c2.get(kind, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
        if not isinstance(a, dict) or not isinstance(b, dict):
            continue
        out[kind] = {key: a.get(key, 0.0) +
                     (n - 1) * (b.get(key, 0.0) - a.get(key, 0.0))
                     for key in ("count", "bytes", "wire_bytes")}
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = 0
        for mesh_kind in meshes:
            for arch in ASSIGNED:
                for shape in SHAPES:
                    tag = f"{arch}__{shape}__{mesh_kind}".replace("/", "_")
                    out_file = os.path.join(args.out, tag + ".json")
                    if os.path.exists(out_file):
                        with open(out_file) as f:
                            prev = json.load(f)
                        if prev.get("ok") or prev.get("skipped"):
                            continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", mesh_kind, "--out", args.out]
                    print(">>>", " ".join(cmd), flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    if r.returncode != 0:
                        failures += 1
                        rec = {"arch": arch, "shape": shape,
                               "mesh": mesh_kind, "ok": False,
                               "error": r.stdout[-2000:] + r.stderr[-4000:]}
                        with open(out_file, "w") as f:
                            json.dump(rec, f, indent=1)
                        print(f"FAIL {tag}", flush=True)
                    else:
                        print(f"OK   {tag}", flush=True)
        return 1 if failures else 0

    assert args.arch and args.shape
    mesh_kinds = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
    rc = 0
    for mk in mesh_kinds:
        tag = f"{args.arch}__{args.shape}__{mk}".replace("/", "_")
        try:
            rec = run_cell(args.arch, args.shape, mk)
        except Exception as e:                             # noqa: BLE001
            rec = {"arch": args.arch, "shape": args.shape, "mesh": mk,
                   "ok": False, "error": traceback.format_exc()[-4000:]}
            rc = 1
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        keys = ["arch", "shape", "mesh", "ok"] + \
            (["skipped"] if "skipped" in rec else [])
        print(json.dumps({k: rec[k] for k in keys}, default=str))
        if not rec.get("ok") and not rec.get("skipped"):
            print(rec.get("error", ""), file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
