"""ShapeDtypeStruct input builders for the dry-run: weak-type-correct,
shardable, zero device allocation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ShapeSpec
from ..models import init_caches, init_params, logical_axes
from ..models.config import ModelConfig
from ..optim.adamw import adamw_init
from ..parallel.sharding import (ShardCtx, make_rules, param_shardings,
                                 spec_for_axes)

SDS = jax.ShapeDtypeStruct

FSDP_PARAM_THRESHOLD = 5e9     # params above this shard over the data axis


def make_ctx(mesh: Optional[Mesh], cfg: Optional[ModelConfig] = None,
             fsdp: Optional[bool] = None) -> ShardCtx:
    if mesh is None:
        return ShardCtx(mesh=None)
    multi = "pod" in mesh.axis_names
    dp = ("pod", "data") if multi else ("data",)
    if fsdp is None and cfg is not None:
        fsdp = analytic_param_count(cfg) > FSDP_PARAM_THRESHOLD
    fsdp_axis = "data" if fsdp else None
    return ShardCtx(mesh=mesh, dp_axes=dp, tp_axis="model",
                    fsdp_axis=fsdp_axis, rules=make_rules(fsdp_axis))


def analytic_param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


# ---------------------------------------------------------------------------
# sharded ShapeDtypeStruct trees
# ---------------------------------------------------------------------------

def _with_sharding(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: SDS(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def param_specs(cfg: ModelConfig, ctx: ShardCtx):
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if ctx.mesh is None:
        return shapes
    shardings = param_shardings(logical_axes(cfg), ctx, shapes)
    return _with_sharding(shapes, shardings)


def opt_specs(params_specs):
    return jax.eval_shape(adamw_init, params_specs)


def _cache_leaf_sharding(shape: Tuple[int, ...], ctx: ShardCtx
                         ) -> NamedSharding:
    """Mirror parallel.sharding.shard_cache heuristics for spec trees."""
    mesh, tp = ctx.mesh, ctx.tp_axis
    tps = ctx.tp_size
    dp = ctx.dp_spec
    dp_size = 1
    for a in ctx.dp_axes:
        dp_size *= mesh.shape[a]
    b_ok = shape[0] % dp_size == 0 and shape[0] >= dp_size
    bspec = dp if b_ok else None
    if len(shape) == 4:             # (B, S|W, H, D) kv  or (B,H,P,N) ssm
        if shape[2] % tps == 0 and shape[2] >= tps:
            return NamedSharding(mesh, P(bspec, None, tp, None))
        if shape[1] % tps == 0 and shape[1] >= tps:
            return NamedSharding(mesh, P(bspec, tp, None, None))
        return NamedSharding(mesh, P(bspec, None, None, None))
    if len(shape) == 3:             # (B, S, L) latent / (B, w, cc) conv
        if shape[2] % tps == 0 and shape[2] >= tps and shape[2] > shape[1]:
            return NamedSharding(mesh, P(bspec, None, tp))
        if shape[1] % tps == 0 and shape[1] >= tps:
            return NamedSharding(mesh, P(bspec, tp, None))
        return NamedSharding(mesh, P(bspec, None, None))
    if len(shape) == 2:             # (B, S) pos
        if shape[1] % tps == 0 and shape[1] >= tps:
            return NamedSharding(mesh, P(bspec, tp))
        return NamedSharding(mesh, P(bspec, None))
    return NamedSharding(mesh, P())


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                ctx: ShardCtx):
    dtype = jnp.dtype(cfg.dtype)
    shapes = jax.eval_shape(
        lambda: init_caches(cfg, batch, max_len, dtype))
    if ctx.mesh is None:
        return shapes
    return jax.tree.map(
        lambda s: SDS(s.shape, s.dtype,
                      sharding=_cache_leaf_sharding(s.shape, ctx)),
        shapes)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardCtx):
    B, S = shape.global_batch, shape.seq_len
    if ctx.mesh is None:
        sh = {"tokens": None, "labels": None}
    else:
        dp_size = 1
        for a in ctx.dp_axes:
            dp_size *= ctx.mesh.shape[a]
        bspec = ctx.dp_spec if B % dp_size == 0 else None
        sh = {
            "tokens": NamedSharding(ctx.mesh, P(bspec, None)),
            "labels": NamedSharding(ctx.mesh, P(bspec, None)),
        }
    out = {
        "tokens": SDS((B, S), jnp.int32, sharding=sh["tokens"]),
        "labels": SDS((B, S), jnp.int32, sharding=sh["labels"]),
    }
    if cfg.frontend != "none" and cfg.frontend_tokens:
        fsh = None
        if ctx.mesh is not None:
            dp_size = 1
            for a in ctx.dp_axes:
                dp_size *= ctx.mesh.shape[a]
            bspec = ctx.dp_spec if B % dp_size == 0 else None
            fsh = NamedSharding(ctx.mesh, P(bspec, None, None))
        out["frontend_embeds"] = SDS(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=fsh)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardCtx):
    """Inputs for serve_step: one new token against a seq_len KV cache."""
    B = shape.global_batch
    if ctx.mesh is not None:
        dp_size = 1
        for a in ctx.dp_axes:
            dp_size *= ctx.mesh.shape[a]
        bspec = ctx.dp_spec if B % dp_size == 0 else None
        tsh = NamedSharding(ctx.mesh, P(bspec, None))
        psh = NamedSharding(ctx.mesh, P(bspec))
    else:
        tsh = psh = None
    return {
        "tokens": SDS((B, 1), jnp.int32, sharding=tsh),
        "position": SDS((B,), jnp.int32, sharding=psh),
        "caches": cache_specs(cfg, B, shape.seq_len, ctx),
    }
