"""Training driver.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch spx-100m \
      --steps 50 --batch 4 --seq 256 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 20 --fail-plane 5:1 --heal-plane 12:1
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.planes import PlaneConfig
from repro.data import DataConfig, DataLoader
from repro.models import init_params, param_count
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import local_ctx
from repro.train import Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="spx-100m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config of the same family")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--planes", type=int, default=4)
    ap.add_argument("--microchunks", type=int, default=16)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-plane", default=None,
                    help="step:plane plane-failure injection")
    ap.add_argument("--heal-plane", default=None, help="step:plane")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg.validate()
    ctx = local_ctx()

    tcfg = TrainerConfig(
        plane=PlaneConfig(n_planes=args.planes,
                          microchunks=args.microchunks,
                          compression=args.compression),
        adamw=AdamWConfig(lr=args.lr),
        warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)

    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.name} params={param_count(params):,}", flush=True)
    if args.resume and args.ckpt_dir:
        trainer = Trainer.restore(cfg, ctx, tcfg, params)
        print(f"resumed at step {trainer.step}", flush=True)
    else:
        trainer = Trainer(cfg, ctx, tcfg, params)

    fail = tuple(map(int, args.fail_plane.split(":"))) \
        if args.fail_plane else None
    heal = tuple(map(int, args.heal_plane.split(":"))) \
        if args.heal_plane else None

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch,
                      frontend_tokens=cfg.frontend_tokens,
                      d_model=cfg.d_model)
    dl = DataLoader(dcfg, start_step=trainer.step)
    for i, batch in zip(range(trainer.step, args.steps), dl):
        if fail and i == fail[0]:
            trainer.inject_plane_failure(fail[1])
            print(f"step {i}: plane {fail[1]} FAILED", flush=True)
        if heal and i == heal[0]:
            trainer.heal_plane(heal[1])
            print(f"step {i}: plane {heal[1]} healed", flush=True)
        m = trainer.train_step({k: jnp.asarray(v)
                                for k, v in batch.items()})
        if i % args.log_every == 0:
            print(f"step {i:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} "
                  f"t {m['step_time_s'] * 1e3:.0f}ms "
                  f"planes {m['planes_up']} "
                  f"eff_bw {m['plane_eff_bw']:.2f}", flush=True)
    if args.ckpt_dir:
        trainer.save()
        print("final checkpoint saved", flush=True)
    recs = [{"plane": r.plane, "fail_step": r.fail_step,
             "recovery_steps": r.recovery_steps}
            for r in trainer.failover.records]
    print(json.dumps({"final_loss": trainer.history[-1]["loss"],
                      "failovers": recs}), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
