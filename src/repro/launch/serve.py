"""Serving driver: batched decode over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params, param_count
from repro.parallel.sharding import local_ctx
from repro.train import Request, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="spx-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg.validate()
    ctx = local_ctx()
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.name} params={param_count(params):,}", flush=True)

    eng = ServeEngine(cfg, ctx, params, batch=args.batch,
                      max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len,
                                    dtype=np.int32), args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s)", flush=True)
    for r in done[:4]:
        print(f"  req {r.rid}: {r.out}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
