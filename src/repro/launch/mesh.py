"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing
jax; smoke tests and benchmarks see the real (1-device) topology.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for(devices: int, model_parallel: int,
                  pods: int = 1):
    """Elastic mesh for whatever devices survive (see
    core.fault_tolerance.elastic_mesh_plan)."""
    from ..core.fault_tolerance import elastic_mesh_plan
    shape = elastic_mesh_plan(devices, model_parallel, pods)
    axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
