from .synthetic import DataConfig, DataLoader, batch_at
