"""Deterministic synthetic data pipeline.

A seeded, stateless token stream (same sequence for a given (seed, step,
shard) triple) so training runs are reproducible and restart-consistent:
after checkpoint restore at step k, batch k+1 is identical to an
uninterrupted run — required for the fault-tolerance tests.

The generator is a order-5 linear-congruential mix over (seed, step,
position), cheap enough to build batches on the host for any vocab.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    frontend_tokens: int = 0
    d_model: int = 0              # for frontend embeds


def _mix(a: np.ndarray) -> np.ndarray:
    a = (a ^ (a >> 16)) * np.uint64(0x45d9f3b45d9f3b)
    a = (a ^ (a >> 31)) * np.uint64(0x9E3779B97F4A7C15)
    return a ^ (a >> 29)


def batch_at(cfg: DataConfig, step: int,
             shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """The (step, shard)-th batch. tokens/labels: (B_shard, S) int32."""
    assert cfg.global_batch % n_shards == 0
    b = cfg.global_batch // n_shards
    rows = np.arange(b, dtype=np.uint64) + \
        np.uint64(shard * b + step * cfg.global_batch)
    cols = np.arange(cfg.seq_len + 1, dtype=np.uint64)
    grid = _mix((rows[:, None] << np.uint64(20)) ^ cols[None, :] ^
                np.uint64(cfg.seed))
    toks = (grid % np.uint64(cfg.vocab)).astype(np.int32)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend_tokens and cfg.d_model:
        f = _mix(grid[:, :cfg.frontend_tokens].astype(np.uint64) +
                 np.uint64(7))
        emb = ((f % np.uint64(2048)).astype(np.float32) / 1024.0 - 1.0)
        out["frontend_embeds"] = np.repeat(
            emb[:, :, None], cfg.d_model, axis=2).astype(np.float32) * 0.02
    return out


class DataLoader:
    """Host-side prefetching iterator over deterministic batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 shard: int = 0, n_shards: int = 1):
        self.cfg = cfg
        self.step = start_step
        self.shard = shard
        self.n_shards = n_shards

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = batch_at(self.cfg, self.step, self.shard, self.n_shards)
        self.step += 1
        return b

    def state(self) -> Dict:
        return {"step": self.step, "shard": self.shard,
                "n_shards": self.n_shards}

    @classmethod
    def restore(cls, cfg: DataConfig, state: Dict) -> "DataLoader":
        return cls(cfg, start_step=state["step"], shard=state["shard"],
                   n_shards=state["n_shards"])
