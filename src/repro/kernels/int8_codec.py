"""Per-row int8 gradient codec (stochastic rounding) for compressed
all-gather collectives — Pallas kernels for the encode/decode hot path.

Encode: per (row-block, col) tile — row-max |x| -> scale; q = clip(round(
x/scale + u)), u ~ U(-0.5, 0.5) supplied as an input buffer (determinism
under jit; the TPU PRNG variant is a drop-in).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(x_ref, noise_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                   # (br, C)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    y = x / scale + noise_ref[...].astype(jnp.float32)
    q_ref[...] = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    scale_ref[...] = scale


def _decode_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) *
                  scale_ref[...].astype(jnp.float32)).astype(x_ref.dtype)


def int8_encode(x: jax.Array, noise: jax.Array, *, br: int = 256,
                interpret: bool = False):
    """x, noise: (R, C). Returns (q int8 (R, C), scale f32 (R, 1))."""
    R, C = x.shape
    br = min(br, R)
    assert R % br == 0
    q, scale = pl.pallas_call(
        _encode_kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x, noise)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array, *, br: int = 256,
                dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    R, C = q.shape
    br = min(br, R)
    assert R % br == 0
    return pl.pallas_call(
        _decode_kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), dtype),
        interpret=interpret,
    )(q, scale)
