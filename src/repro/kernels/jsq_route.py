"""Switch per-packet adaptive routing (quantized JSQ + weighted-AR, §4.1,
§4.4.2) as a Pallas kernel — the simulator's hot loop and the kernel-level
expression of the paper's in-network mechanism.

For each packet: score every egress port by quantized queue depth divided
by its remote-capacity weight; pick the min-score port with a hash-based
tie-break; failed ports score +inf.  Pure VPU work: (bp, ports) vector
ops per block of packets.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _jsq_kernel(q_ref, up_ref, w_ref, hash_ref, port_ref,
                *, nbins: int, qmax: float, n_ports: int, bp: int):
    queues = q_ref[...].astype(jnp.float32)            # (1, ports)
    up = up_ref[...] > 0                               # (1, ports)
    w = w_ref[...].astype(jnp.float32)
    qbin = jnp.floor(jnp.clip(queues / qmax, 0.0, 1.0 - 1e-6) * nbins)
    score = (qbin + 1.0) / jnp.maximum(w, 1e-6)
    score = jnp.where(up, score, BIG)                  # (1, ports)

    h = hash_ref[...].astype(jnp.uint32)               # (bp, 1)
    ports = jax.lax.broadcasted_iota(jnp.uint32, (bp, n_ports), 1)
    # per-packet hashed tie-break in [0, 1): decorrelates equal-score picks
    mix = (h * jnp.uint32(2654435761) + ports * jnp.uint32(40503))
    mix = mix ^ (mix >> 16)
    tie = (mix & jnp.uint32(0xFFFF)).astype(jnp.float32) / 65536.0
    total = score + tie * 0.5                          # (bp, ports)
    port_ref[...] = jnp.argmin(total, axis=1,
                               keepdims=True).astype(jnp.int32)


def _pair_score_kernel(q_ref, cap_ref, w_ref, out_ref, *, nbins: int,
                       temperature: float, qmax: float):
    """One block of (src-leaf, dst-leaf) rows: quantized-JSQ scoring +
    softmax over the spine axis (`ref.pair_score_softmax_ref`)."""
    q = q_ref[...].astype(jnp.float32)                   # (br, S)
    cap = cap_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    up = cap > 1e-9
    qbin = jnp.floor(jnp.clip(q / qmax, 0.0, 1.0 - 1e-9) * nbins) + 1.0
    score = qbin / jnp.maximum(w, 1e-9)
    logit = jnp.where(up, -score / temperature, -BIG)
    logit -= jnp.max(logit, axis=-1, keepdims=True)
    e = jnp.exp(logit)
    sums = jnp.sum(e, axis=-1, keepdims=True)
    out_ref[...] = jnp.where(sums > 0, e / jnp.maximum(sums, 1e-30), 0.0)


def pair_fractions(q: jax.Array, cap: jax.Array, w: jax.Array, *,
                   nbins: int = 16, temperature: float = 1.0,
                   qmax: float = 8.0, br: int = 128,
                   use_pallas: bool = False,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Spine-selection fractions for every (plane, src-leaf, dst-leaf)
    path — the per-slot AR/WAR hot path of the simulator.  `q`/`cap`/`w`
    are (..., S): summed up+down queue depth, min(up, down) path
    capacity, and the capacity-(×remote)-weight; returns (..., S)
    fractions summing to 1 over alive spines.

    With `use_pallas=False` this is exactly `ref.pair_score_softmax_ref`
    (bit-identical to the engine's historical jnp math).  The Pallas
    path flattens the leading axes into rows of `br` and scores each on
    the VPU in float32; `interpret=None` resolves via
    `backend.pallas_interpret` (interpret everywhere but TPU)."""
    from . import backend, ref

    if not use_pallas:
        return ref.pair_score_softmax_ref(q, cap, w, nbins=nbins,
                                          temperature=temperature,
                                          qmax=qmax)
    lead = q.shape[:-1]
    S = q.shape[-1]
    R = 1
    for d in lead:
        R *= d
    q2, cap2, w2 = (a.reshape(R, S) for a in (q, cap, w))
    br = min(br, R)
    pad = (-R) % br
    if pad:
        q2 = jnp.pad(q2, ((0, pad), (0, 0)))
        cap2 = jnp.pad(cap2, ((0, pad), (0, 0)))
        w2 = jnp.pad(w2, ((0, pad), (0, 0)))
    n_blk = q2.shape[0] // br
    kernel = functools.partial(_pair_score_kernel, nbins=nbins,
                               temperature=temperature, qmax=qmax)
    out = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((br, S), lambda i: (i, 0)),
            pl.BlockSpec((br, S), lambda i: (i, 0)),
            pl.BlockSpec((br, S), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, S), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q2.shape[0], S), jnp.float32),
        interpret=backend.pallas_interpret(interpret),
    )(q2.astype(jnp.float32), cap2.astype(jnp.float32),
      w2.astype(jnp.float32))
    return out[:R].reshape(*lead, S).astype(q.dtype)


def jsq_route(queues: jax.Array, up_mask: jax.Array, weights: jax.Array,
              pkt_hash: jax.Array, *, nbins: int = 16, qmax: float = 1.0,
              bp: int = 256,
              interpret: Optional[bool] = None) -> jax.Array:
    """queues/up_mask/weights: (ports,); pkt_hash: (N,) uint32.
    Returns (N,) int32 egress port per packet."""
    from . import backend

    (n_ports,) = queues.shape
    N = pkt_hash.shape[0]
    bp = min(bp, N)
    pad = (-N) % bp
    if pad:
        pkt_hash = jnp.pad(pkt_hash, (0, pad))
    n_blk = pkt_hash.shape[0] // bp

    kernel = functools.partial(_jsq_kernel, nbins=nbins, qmax=qmax,
                               n_ports=n_ports, bp=bp)
    out = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((1, n_ports), lambda i: (0, 0)),
            pl.BlockSpec((1, n_ports), lambda i: (0, 0)),
            pl.BlockSpec((1, n_ports), lambda i: (0, 0)),
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pkt_hash.shape[0], 1), jnp.int32),
        interpret=backend.pallas_interpret(interpret),
    )(queues[None, :].astype(jnp.float32),
      up_mask[None, :].astype(jnp.float32),
      weights[None, :].astype(jnp.float32),
      pkt_hash[:, None].astype(jnp.uint32))
    return out[:N, 0]
