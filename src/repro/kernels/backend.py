"""Pallas dispatch gate shared by the simulator-facing kernels.

The netsim hot-path kernels (`plb_select.plane_split`,
`jsq_route.pair_fractions`) have two implementations: a Pallas kernel
(TPU) and a pure-jnp fallback (`ref.py` — also the test oracle).  On
CPU/GPU the fallback is both faster and bit-identical to the engine's
historical math, so Pallas is enabled only when the default JAX backend
is TPU, unless `REPRO_NETSIM_PALLAS` forces it (1/0).
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def pallas_enabled(override: Optional[bool] = None) -> bool:
    """Whether simulator kernels should lower through Pallas."""
    if override is not None:
        return override
    env = os.environ.get("REPRO_NETSIM_PALLAS")
    if env is not None:
        return env.lower() in ("1", "true", "t", "yes", "y", "on")
    return jax.default_backend() == "tpu"


def pallas_interpret(override: Optional[bool] = None) -> bool:
    """Whether a Pallas kernel must run in interpret mode: required on
    every non-TPU backend (`pallas_call` without `interpret=True` fails
    off-TPU).  Kernel entry points resolve this when their `interpret`
    argument is None, so `REPRO_NETSIM_PALLAS=1` exercises the kernel
    bodies on CPU CI without per-call-site plumbing."""
    if override is not None:
        return override
    return jax.default_backend() != "tpu"
