"""Fused link-load accumulation + bottleneck scaling (the stage-A /
stage-B half of the simulator's per-slot hot path) as Pallas kernels.

Three entry points, mirroring how the engine consumes loads:

  * `bucket_load_bottleneck` — reduce a gathered (P, rows, C) ECMP load
    plan to per-link loads AND their min(1, cap/load) scale factors in
    one pass (dense aggregation mode: the plan rows are leaf×path link
    buckets).
  * `bottleneck` — the elementwise scale factor alone, for loads that
    arrive pre-aggregated (AR/WAR einsums, access links, and the sparse
    aggregation mode).
  * `segment_load` — sparse flow→link accumulation via
    `jax.ops.segment_sum`: memory is bounded by flow count, not
    `leaves² · planes`.  Scatter-adds stay on XLA (TPU scatter lowers
    to efficient sorted-segment ops; a Pallas scatter would serialize
    on the VPU) — kept here so the engine has a single swap point.
    On XLA CPU float64 the scatter expander applies updates in index
    order, i.e. flow order — bit-identical to the NumPy engine's
    sequential `np.add.at` (pinned by tests/test_sparse_agg.py).

With `use_pallas=False` every path is exactly the `ref.py` oracle —
bit-identical to the engine's historical jnp math, which the x64 parity
suite pins.  Pallas paths run float32 row blocks on the VPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-12


def _load_bottleneck_kernel(g_ref, cap_ref, load_ref, frac_ref,
                            *, eps: float):
    g = g_ref[...].astype(jnp.float32)                   # (br, C)
    cap = cap_ref[...].astype(jnp.float32)               # (br, 1)
    load = jnp.sum(g, axis=1, keepdims=True)
    load_ref[...] = load
    frac_ref[...] = jnp.minimum(1.0, cap / jnp.maximum(load, eps))


def bucket_load_bottleneck(g: jax.Array, cap: jax.Array, *,
                           eps: float = EPS,
                           ordered: Optional[bool] = None, br: int = 128,
                           use_pallas: bool = False,
                           interpret: Optional[bool] = None):
    """Fused bucket-sum + bottleneck over a gathered load plan.

    `g`: (P, rows, C) flow rates gathered into link buckets (padded
    entries read a zero row); `cap`: (P, rows) link capacities in the
    same row layout.  Returns `(load, frac)`, both (P, rows).

    `ordered=None` resolves to `g.dtype == float64` — parity mode, where
    the width axis must accumulate strictly left-to-right in flow order
    (see `ref.bucket_sum_ref`).  Ordered sums always take the fallback:
    a sequential loop has no VPU win, and f64 parity never runs Pallas.
    """
    from . import backend, ref

    if ordered is None:
        ordered = g.dtype == jnp.float64
    if not use_pallas or ordered:
        return ref.load_bottleneck_ref(g, cap, eps=eps, ordered=ordered)
    P, R, C = g.shape
    g2 = g.reshape(P * R, C)
    cap2 = cap.reshape(P * R, 1)
    rows = P * R
    br = min(br, rows)
    pad = (-rows) % br
    if pad:
        g2 = jnp.pad(g2, ((0, pad), (0, 0)))
        cap2 = jnp.pad(cap2, ((0, pad), (0, 0)))
    n_blk = g2.shape[0] // br
    kernel = functools.partial(_load_bottleneck_kernel, eps=eps)
    load, frac = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((br, C), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g2.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((g2.shape[0], 1), jnp.float32),
        ],
        interpret=backend.pallas_interpret(interpret),
    )(g2.astype(jnp.float32), cap2.astype(jnp.float32))
    return (load[:rows, 0].reshape(P, R).astype(g.dtype),
            frac[:rows, 0].reshape(P, R).astype(g.dtype))


def _bottleneck_kernel(cap_ref, load_ref, out_ref, *, eps: float):
    cap = cap_ref[...].astype(jnp.float32)
    load = load_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.minimum(1.0, cap / jnp.maximum(load, eps))


def bottleneck(cap: jax.Array, load: jax.Array, *, eps: float = EPS,
               bp: int = 1024, use_pallas: bool = False,
               interpret: Optional[bool] = None) -> jax.Array:
    """Elementwise min(1, cap/load) scale factor, any matching shape."""
    from . import backend, ref

    if not use_pallas:
        return ref.bottleneck_ref(cap, load, eps=eps)
    shape = cap.shape
    n = cap.size
    bp = min(bp, max(n, 1))
    pad = (-n) % bp
    cap2 = cap.reshape(-1)
    load2 = load.reshape(-1)
    if pad:
        cap2 = jnp.pad(cap2, (0, pad))
        load2 = jnp.pad(load2, (0, pad))
    n_blk = cap2.shape[0] // bp
    kernel = functools.partial(_bottleneck_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((1, bp), lambda i: (i, 0)),
            pl.BlockSpec((1, bp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blk, bp), jnp.float32),
        interpret=backend.pallas_interpret(interpret),
    )(cap2.reshape(n_blk, bp).astype(jnp.float32),
      load2.reshape(n_blk, bp).astype(jnp.float32))
    return out.reshape(-1)[:n].reshape(shape).astype(cap.dtype)


def segment_load(vals: jax.Array, keys: jax.Array,
                 num_segments: int) -> jax.Array:
    """Sparse flow→link accumulation: sum `vals` (any shape) into
    `num_segments` buckets keyed by `keys` (same shape).  Flattening is
    row-major, so per-bucket updates arrive in flow order — the f64
    bit-exactness contract the engine's parity mode relies on."""
    return jax.ops.segment_sum(vals.reshape(-1), keys.reshape(-1),
                               num_segments=num_segments)


def segment_load_chunk(acc: jax.Array, vals: jax.Array,
                       keys: jax.Array) -> jax.Array:
    """One streaming step of `segment_load`: add this chunk's `vals`
    into the flat accumulator `acc` (shape `(num_segments,)`), keyed by
    `keys`.  Both this scatter-add and `segment_sum` apply duplicate
    updates in index (= flow) order on the XLA CPU f64 expander, so
    folding chunks left-to-right reproduces the monolithic call's
    per-bucket addition chain bit for bit — the invariant the chunked
    engine's x64 parity tests pin.  Pad flows must carry exact +0.0
    values (the engine's inert-pad contract), which cannot perturb any
    partial sum of non-negative rates."""
    return acc.at[keys.reshape(-1)].add(vals.reshape(-1))
