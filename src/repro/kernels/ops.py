"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends the kernels execute via ``interpret=True`` (Python
interpreter of the kernel body — used for CPU validation); on TPU they
compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import decode_attention as _da
from . import jsq_route as _jr
from . import link_load as _ll
from . import plb_select as _ps
from . import int8_codec as _ic
from . import queue_ecn as _qe


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq",
                                             "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    """(B,H,S,D) fused attention; GQA callers repeat KV heads first."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=_interpret())


def flash_attention_bshd(q, k, v, *, causal: bool = True,
                         window: int = 0, bq: int = 128, bk: int = 128):
    """Model-layout wrapper: q (B,S,Hq,D), k/v (B,S,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    out = flash_attention(q.transpose(0, 2, 1, 3),
                          k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3),
                          causal=causal, window=window, bq=bq, bk=bk)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("bk",))
def decode_attention(q, k, v, lengths, *, bk: int = 512):
    return _da.decode_attention(q, k, v, lengths, bk=bk,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("nbins", "qmax", "bp"))
def jsq_route(queues, up_mask, weights, pkt_hash, *, nbins: int = 16,
              qmax: float = 1.0, bp: int = 256):
    return _jr.jsq_route(queues, up_mask, weights, pkt_hash, nbins=nbins,
                         qmax=qmax, bp=bp, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bp",))
def plb_select(rate_allow, eligible, local_queue, tx_rate, pkt_hash,
               *, bp: int = 256):
    return _ps.plb_select(rate_allow, eligible, local_queue, tx_rate,
                          pkt_hash, bp=bp, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("mode", "min_rate", "bp"))
def plane_split(rate, eligible, demand, *, mode: str,
                min_rate: float = 0.0, bp: int = 256):
    """Batched (F, P) fluid plane split (Pallas path; the simulator
    itself dispatches via `plb_select.plane_split` so non-TPU backends
    keep the bit-exact jnp fallback)."""
    return _ps.plane_split(rate, eligible, demand, mode=mode,
                           min_rate=min_rate, bp=bp, use_pallas=True,
                           interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("nbins", "temperature",
                                             "qmax", "br"))
def pair_fractions(q, cap, w, *, nbins: int = 16,
                   temperature: float = 1.0, qmax: float = 8.0,
                   br: int = 128):
    """(…, S) quantized-JSQ spine fractions (Pallas path; see
    `jsq_route.pair_fractions` for the dispatching entry point)."""
    return _jr.pair_fractions(q, cap, w, nbins=nbins,
                              temperature=temperature, qmax=qmax, br=br,
                              use_pallas=True, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "br"))
def bucket_load_bottleneck(g, cap, *, eps: float = _ll.EPS,
                           br: int = 128):
    """Fused (P, rows, C) load-plan sum + bottleneck scaling (Pallas
    path; the simulator dispatches via
    `link_load.bucket_load_bottleneck`, keeping the bit-exact jnp
    fallback off-TPU and the ordered f64 parity sum everywhere)."""
    return _ll.bucket_load_bottleneck(g, cap, eps=eps, ordered=False,
                                      br=br, use_pallas=True,
                                      interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "bp"))
def bottleneck(cap, load, *, eps: float = _ll.EPS, bp: int = 1024):
    """Elementwise min(1, cap/load) link scale factor (Pallas path)."""
    return _ll.bottleneck(cap, load, eps=eps, bp=bp, use_pallas=True,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("q_cap", "eps", "bp"))
def queue_update(q, load, cap, *, q_cap: float, eps: float = _qe.EPS,
                 bp: int = 1024):
    """Fluid queue integrator + utilization (Pallas path; see
    `queue_ecn.queue_update` for the dispatching entry point)."""
    return _qe.queue_update(q, load, cap, q_cap=q_cap, eps=eps, bp=bp,
                            use_pallas=True, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=(
    "mode", "base_rtt_us", "slot_us", "ecn_thresh", "target_rtt_us",
    "min_rate", "md", "ai", "rtt_gain", "dcqcn_ai", "alpha_g", "bp"))
def nic_update(qmean, rate, alpha, esr, *, mode: str, base_rtt_us: float,
               slot_us: float, ecn_thresh: float, target_rtt_us: float,
               min_rate: float, md: float, ai: float, rtt_gain: float,
               dcqcn_ai: float, alpha_g: float, bp: int = 256):
    """Fused RTT/ECN + CC rate step (Pallas path; see
    `queue_ecn.nic_update` for the dispatching entry point)."""
    return _qe.nic_update(qmean, rate, alpha, esr, mode=mode,
                          base_rtt_us=base_rtt_us, slot_us=slot_us,
                          ecn_thresh=ecn_thresh,
                          target_rtt_us=target_rtt_us,
                          min_rate=min_rate, md=md, ai=ai,
                          rtt_gain=rtt_gain, dcqcn_ai=dcqcn_ai,
                          alpha_g=alpha_g, bp=bp, use_pallas=True,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("br",))
def int8_encode(x, noise, *, br: int = 256):
    return _ic.int8_encode(x, noise, br=br, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("br", "dtype"))
def int8_decode(q, scale, *, br: int = 256, dtype=jnp.float32):
    return _ic.int8_decode(q, scale, br=br, dtype=dtype,
                           interpret=_interpret())
