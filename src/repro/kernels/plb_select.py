"""NIC Plane Load Balancer per-packet selection (Fig. 4) as a Pallas
kernel: two-stage hierarchy —

  1. rate filter: mask planes whose CC allowance < the packet's tx rate
     (or that are ineligible: probe-timed-out);
  2. local queue: among eligible planes pick the shallowest NIC egress
     queue, hash tie-break.

E2E congestion state takes precedence; queue depth breaks ties among
uncongested planes — exactly the paper's hierarchy.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _plb_kernel(rate_ref, elig_ref, queue_ref, tx_ref, hash_ref, out_ref,
                *, n_planes: int, bp: int):
    rate = rate_ref[...].astype(jnp.float32)            # (1, P)
    elig = elig_ref[...] > 0
    queue = queue_ref[...].astype(jnp.float32)
    tx = tx_ref[...].astype(jnp.float32)                # (bp, 1)

    # stage 1 — rate filter (E2E congestion precedence)
    ok = elig & (rate >= tx)                            # (bp, P) broadcast
    any_ok = jnp.any(ok, axis=1, keepdims=True)
    ok = jnp.where(any_ok, ok, elig)                    # fallback: eligible

    # stage 2 — shallowest local egress queue, hashed tie-break
    h = hash_ref[...].astype(jnp.uint32)                # (bp, 1)
    planes = jax.lax.broadcasted_iota(jnp.uint32, (bp, n_planes), 1)
    mix = (h * jnp.uint32(2654435761) + planes * jnp.uint32(97))
    mix = mix ^ (mix >> 16)
    tie = (mix & jnp.uint32(0xFFFF)).astype(jnp.float32) / 65536.0
    score = jnp.where(ok, queue + 1e-3 * tie, BIG)
    out_ref[...] = jnp.argmin(score, axis=1,
                              keepdims=True).astype(jnp.int32)


def _plane_split_kernel(rate_ref, elig_ref, demand_ref, out_ref,
                        *, mode: str, n_planes: int, min_rate: float):
    """One block of flows: fluid plane split for a static NIC `mode`
    (see `ref.plane_split_ref`).  Pure VPU work on (bp, P) tiles."""
    rate = rate_ref[...].astype(jnp.float32)             # (bp, P)
    elig = elig_ref[...] > 0
    demand = demand_ref[...].astype(jnp.float32)         # (bp, 1)
    if mode == "dcqcn":
        out = jnp.minimum(demand * (1.0 / n_planes), rate)
    elif mode == "swlb":
        n_up = jnp.maximum(jnp.sum(elig, axis=1, keepdims=True), 1)
        out = jnp.where(elig, demand / n_up, 0.0)
    elif mode == "agg":
        n_up = jnp.maximum(jnp.sum(elig, axis=1, keepdims=True), 1)
        shared = jnp.min(rate, axis=1, keepdims=True)
        out = jnp.where(elig, demand * shared / n_up, 0.0)
    else:  # spx: rate filter (E2E precedence) then allowance weighting
        ok = elig & (rate > min_rate + 1e-9)
        any_ok = jnp.any(ok, axis=1, keepdims=True)
        ok = jnp.where(any_ok, ok, elig)
        w = jnp.where(ok, rate, 0.0)
        s = jnp.sum(w, axis=1, keepdims=True)
        w = jnp.where(s > 0, w / jnp.maximum(s, 1e-12), 1.0 / n_planes)
        out = jnp.minimum(demand * w, jnp.where(ok, rate, 0.0))
    out_ref[...] = out


def plane_split(rate: jax.Array, eligible: jax.Array, demand: jax.Array,
                *, mode: str, min_rate: float = 0.0, bp: int = 256,
                use_pallas: bool = False,
                interpret: Optional[bool] = None) -> jax.Array:
    """Batched fluid plane split — the per-slot NIC hot path of the
    simulator.  `rate`/`eligible`: (F, P); `demand`: (F,).  Returns the
    (F, P) offered matrix.

    With `use_pallas=False` (the default on non-TPU backends, see
    `kernels.backend.pallas_enabled`) this is exactly
    `ref.plane_split_ref` — bit-identical to the engine's historical
    jnp math, which the x64 parity suite pins.  The Pallas path runs
    float32 blocks of `bp` flows on the VPU; `interpret=None` resolves
    via `backend.pallas_interpret` (interpret everywhere but TPU)."""
    from . import backend, ref

    if not use_pallas:
        return ref.plane_split_ref(rate, eligible, demand, mode=mode,
                                   min_rate=min_rate)
    F, P = rate.shape
    bp = min(bp, F)
    pad = (-F) % bp
    if pad:
        rate = jnp.pad(rate, ((0, pad), (0, 0)))
        eligible = jnp.pad(eligible, ((0, pad), (0, 0)))
        demand = jnp.pad(demand, (0, pad))
    n_blk = rate.shape[0] // bp
    kernel = functools.partial(_plane_split_kernel, mode=mode,
                               n_planes=P, min_rate=min_rate)
    out = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((bp, P), lambda i: (i, 0)),
            pl.BlockSpec((bp, P), lambda i: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bp, P), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rate.shape[0], P), jnp.float32),
        interpret=backend.pallas_interpret(interpret),
    )(rate.astype(jnp.float32), eligible.astype(jnp.float32),
      demand[:, None].astype(jnp.float32))
    return out[:F].astype(rate.dtype)


def plb_select(rate_allow: jax.Array, eligible: jax.Array,
               local_queue: jax.Array, tx_rate: jax.Array,
               pkt_hash: jax.Array, *, bp: int = 256,
               interpret: Optional[bool] = None) -> jax.Array:
    """rate_allow/eligible/local_queue: (P,); tx_rate/pkt_hash: (N,).
    Returns (N,) int32 plane per packet."""
    from . import backend

    (P,) = rate_allow.shape
    N = pkt_hash.shape[0]
    bp = min(bp, N)
    pad = (-N) % bp
    if pad:
        pkt_hash = jnp.pad(pkt_hash, (0, pad))
        tx_rate = jnp.pad(tx_rate, (0, pad))
    n_blk = pkt_hash.shape[0] // bp

    kernel = functools.partial(_plb_kernel, n_planes=P, bp=bp)
    out = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((1, P), lambda i: (0, 0)),
            pl.BlockSpec((1, P), lambda i: (0, 0)),
            pl.BlockSpec((1, P), lambda i: (0, 0)),
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pkt_hash.shape[0], 1), jnp.int32),
        interpret=backend.pallas_interpret(interpret),
    )(rate_allow[None, :].astype(jnp.float32),
      eligible[None, :].astype(jnp.float32),
      local_queue[None, :].astype(jnp.float32),
      tx_rate[:, None].astype(jnp.float32),
      pkt_hash[:, None].astype(jnp.uint32))
    return out[:N, 0]
