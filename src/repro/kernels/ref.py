"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """q: (B,H,Sq,D); k/v: (B,H,Sk,D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths) -> jax.Array:
    """q: (B,H,1,D); k/v: (B,H,S,D); lengths: (B,)."""
    B, H, _, D = q.shape
    S = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    valid = jnp.arange(S)[None, None, None, :] < \
        lengths[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def jsq_route_ref(queues, up_mask, weights, pkt_hash, *, nbins: int = 16,
                  qmax: float = 1.0) -> jax.Array:
    qbin = jnp.floor(jnp.clip(queues / qmax, 0.0, 1.0 - 1e-6) * nbins)
    score = (qbin + 1.0) / jnp.maximum(weights, 1e-6)
    score = jnp.where(up_mask > 0, score, 1e30)
    n_ports = queues.shape[0]
    ports = jnp.arange(n_ports, dtype=jnp.uint32)[None, :]
    h = pkt_hash.astype(jnp.uint32)[:, None]
    mix = (h * jnp.uint32(2654435761) + ports * jnp.uint32(40503))
    mix = mix ^ (mix >> 16)
    tie = (mix & jnp.uint32(0xFFFF)).astype(jnp.float32) / 65536.0
    return jnp.argmin(score[None, :] + tie * 0.5, axis=1).astype(jnp.int32)


def plb_select_ref(rate_allow, eligible, local_queue, tx_rate,
                   pkt_hash) -> jax.Array:
    P = rate_allow.shape[0]
    elig = eligible > 0
    ok = elig[None, :] & (rate_allow[None, :] >= tx_rate[:, None])
    any_ok = jnp.any(ok, axis=1, keepdims=True)
    ok = jnp.where(any_ok, ok, elig[None, :])
    planes = jnp.arange(P, dtype=jnp.uint32)[None, :]
    h = pkt_hash.astype(jnp.uint32)[:, None]
    mix = (h * jnp.uint32(2654435761) + planes * jnp.uint32(97))
    mix = mix ^ (mix >> 16)
    tie = (mix & jnp.uint32(0xFFFF)).astype(jnp.float32) / 65536.0
    score = jnp.where(ok, local_queue[None, :] + 1e-3 * tie, 1e30)
    return jnp.argmin(score, axis=1).astype(jnp.int32)


def plane_split_ref(rate, eligible, demand, *, mode: str,
                    min_rate: float = 0.0) -> jax.Array:
    """Fluid NIC plane split — the batched (F, P) twin of `plb_select`
    that the simulator's slot step runs every slot (and the jnp fallback
    on non-TPU backends).  `rate`/`eligible`: (F, P) per-plane CC
    allowance and PLB eligibility; `demand`: (F,) offered rate.

    mode:
      'spx'   — rate-filter planes (allowance > min_rate), then weight
                by allowance: the paper's two-stage PLB hierarchy in
                fluid form.
      'dcqcn' — plane-oblivious equal split, capped by allowance.
      'agg'   — one aggregate context ('global'/'esr' NICs): min
                allowance shared equally across eligible planes.
      'swlb'  — software LB: equal split over eligible planes only.
    """
    P = rate.shape[-1]
    if mode == "dcqcn":
        w = jnp.ones_like(rate) / P
        return jnp.minimum(demand[:, None] * w, rate)
    if mode == "swlb":
        elig = eligible
        n_up = jnp.maximum(elig.sum(1, keepdims=True), 1)
        return jnp.where(elig, demand[:, None] / n_up, 0.0)
    if mode == "agg":
        elig = eligible
        n_up = jnp.maximum(elig.sum(1, keepdims=True), 1)
        shared = rate.min(1, keepdims=True)
        return jnp.where(elig, demand[:, None] * shared / n_up, 0.0)
    if mode != "spx":
        raise ValueError(f"unknown plane-split mode {mode!r}")
    elig = eligible & (rate > min_rate + 1e-9)
    any_ok = elig.any(1, keepdims=True)
    elig = jnp.where(any_ok, elig, eligible)
    w = jnp.where(elig, rate, 0.0)
    s = w.sum(1, keepdims=True)
    w = jnp.where(s > 0, w / jnp.maximum(s, 1e-12), 1.0 / P)
    return jnp.minimum(demand[:, None] * w, jnp.where(elig, rate, 0.0))


def pair_score_softmax_ref(q, cap, w, *, nbins: int, temperature: float,
                           qmax: float = 8.0) -> jax.Array:
    """Quantized-JSQ spine scoring + softmax over the trailing spine
    axis — the select/aggregate core of the switch AR path (`jsq_route`'s
    fluid twin).  `q`/`cap`/`w`: (..., S) summed pair queue, path
    capacity, and path weight; returns (..., S) spine fractions."""
    up_mask = cap > 1e-9
    qbin = jnp.floor(jnp.clip(q / qmax, 0, 1 - 1e-9) * nbins) + 1.0
    score = qbin / jnp.maximum(w, 1e-9)
    logit = jnp.where(up_mask, -score / temperature, -1e30)
    logit -= logit.max(-1, keepdims=True)
    e = jnp.exp(logit)
    sums = e.sum(-1, keepdims=True)
    return jnp.where(sums > 0, e / jnp.maximum(sums, 1e-30), 0.0)


def bottleneck_ref(cap, load, *, eps: float = 1e-12) -> jax.Array:
    """Per-link bottleneck scaling factor min(1, cap/load) — the fluid
    fair-share clamp applied after every load accumulation.  Elementwise
    and bit-identical to the engine's historical jnp math."""
    return jnp.minimum(1.0, cap / jnp.maximum(load, eps))


def bucket_sum_ref(g, *, ordered: bool = False) -> jax.Array:
    """Sum the trailing bucket-width axis of a gathered (..., rows, C)
    load plan.  `ordered=True` accumulates strictly left-to-right (flow
    order) — float64 parity mode, where a last-ulp tree-reduction
    difference vs NumPy's sequential `np.add.at` can walk a queue across
    an ECN threshold and fork the trajectory; `ordered=False` takes the
    fast tree reduction."""
    if ordered:
        return jax.lax.fori_loop(
            1, g.shape[-1],
            lambda c, acc: acc + jax.lax.dynamic_index_in_dim(
                g, c, g.ndim - 1, keepdims=False),
            g[..., 0])
    return g.sum(-1)


def load_bottleneck_ref(g, cap, *, eps: float = 1e-12,
                        ordered: bool = False):
    """Fused stage-A/stage-B load-accumulate + bottleneck: reduce a
    gathered (P, rows, C) plan to per-link loads and their scale
    factors.  Returns `(load, frac)`, both (P, rows)."""
    load = bucket_sum_ref(g, ordered=ordered)
    return load, bottleneck_ref(cap, load, eps=eps)


def queue_update_ref(q, load, cap, *, q_cap: float, eps: float = 1e-12):
    """Fluid queue integrator: one slot of (load - cap)/cap growth,
    clipped to [0, q_cap], dead links (cap <= eps) pinned to empty.
    Returns `(q_new, util)` with util = load/cap."""
    q_new = jnp.clip(q + (load - cap) / jnp.maximum(cap, eps),
                     0.0, q_cap)
    q_new = jnp.where(cap <= eps, 0.0, q_new)
    util = load / jnp.maximum(cap, eps)
    return q_new, util


def nic_update_ref(qmean, rate, alpha, esr, *, mode: str,
                   base_rtt_us: float, slot_us: float, ecn_thresh: float,
                   target_rtt_us: float, min_rate: float, md: float,
                   ai: float, rtt_gain: float, dcqcn_ai: float,
                   alpha_g: float):
    """Fused per-slot NIC control update: queue-derived RTT/ECN signals
    plus one step of the CC rate law for a static `mode`.  All inputs
    (F, P) except `esr` (F, 1) bool — ESR's extra multiplicative cut,
    only read by 'agg'.  Returns `(rtt, ecn, rate_new, alpha_new)`;
    alpha passes through untouched except under 'dcqcn'.

    mode:
      'spx'   — per-plane AIMD with ECN-proportional cut and RTT trim
                (also the swlb rate law; probe/eligibility bookkeeping
                stays in the engine).
      'dcqcn' — DCQCN: EWMA alpha, multiplicative cut on any-plane ECN.
      'agg'   — one aggregate context across planes ('global'/'esr').
    """
    rtt = base_rtt_us + qmean * slot_us * 0.5
    ecn = jnp.where(qmean > ecn_thresh,
                    jnp.minimum(1.0, qmean / (4 * ecn_thresh)), 0.0)
    if mode == "dcqcn":
        ecn_any = ecn.max(-1, keepdims=True)
        alpha_new = (1 - alpha_g) * alpha + alpha_g * (ecn_any > 0)
        cut = rate * (1 - alpha_new / 2)
        grow = jnp.minimum(rate + dcqcn_ai, 1.0)
        new = jnp.clip(jnp.where(ecn_any > 0, cut, grow), min_rate, 1.0)
        return rtt, ecn, new, alpha_new
    if mode == "agg":
        agg_ecn = ecn.max(-1, keepdims=True)
        agg_rtt = rtt.max(-1, keepdims=True)
        cut = rate * md
        rtt_err = (agg_rtt - target_rtt_us) / target_rtt_us
        trim = rate * (1 - rtt_gain * jnp.clip(rtt_err, 0, 2))
        grow = jnp.minimum(rate + ai, 1.0)
        new = jnp.where(agg_ecn > 0, cut,
                        jnp.where(rtt_err > 0.25, trim, grow))
        new = new * jnp.where(jnp.logical_and(esr, agg_ecn > 0),
                              0.85, 1.0)
        return rtt, ecn, jnp.clip(new, min_rate, 1.0), alpha
    if mode != "spx":
        raise ValueError(f"unknown nic-update mode {mode!r}")
    rtt_err = (rtt - target_rtt_us) / target_rtt_us
    cut = rate * (md + (1 - md) * jnp.clip(1 - ecn, 0, 1))
    trim = rate * (1 - rtt_gain * jnp.clip(rtt_err, 0, 2))
    grow = jnp.minimum(rate + ai, 1.0)
    new = jnp.clip(
        jnp.where(ecn > 0, cut, jnp.where(rtt_err > 0.25, trim, grow)),
        min_rate, 1.0)
    return rtt, ecn, new, alpha


def int8_encode_ref(x, noise):
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale + noise), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decode_ref(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)
