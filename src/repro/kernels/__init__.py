from . import ops, ref
