"""Fused queue/ECN/NIC-update (the control half of the simulator's
per-slot hot path) as Pallas kernels.

Two entry points:

  * `queue_update` — the fluid queue integrator + utilization for one
    link stage: `q' = clip(q + (load-cap)/cap, 0, q_cap)`, dead links
    pinned empty.  Elementwise over any (matching) shape.
  * `nic_update` — queue-derived RTT/ECN signals fused with one step of
    the CC rate law (`spx` per-plane AIMD — also swlb's law — `dcqcn`,
    or the aggregate `agg` context used by 'global'/'esr' NICs).  The
    probe/eligibility bookkeeping stays in the engine: it is bool/int
    select logic with no arithmetic to fuse.

With `use_pallas=False` both are exactly the `ref.py` oracles —
bit-identical to the engine's historical jnp math, which the x64 parity
suite pins.  Pallas paths run float32 blocks of `bp` flows on the VPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-12


def _queue_update_kernel(q_ref, load_ref, cap_ref, qn_ref, util_ref,
                         *, q_cap: float, eps: float):
    q = q_ref[...].astype(jnp.float32)
    load = load_ref[...].astype(jnp.float32)
    cap = cap_ref[...].astype(jnp.float32)
    denom = jnp.maximum(cap, eps)
    qn = jnp.clip(q + (load - cap) / denom, 0.0, q_cap)
    qn_ref[...] = jnp.where(cap <= eps, 0.0, qn)
    util_ref[...] = load / denom


def queue_update(q: jax.Array, load: jax.Array, cap: jax.Array, *,
                 q_cap: float, eps: float = EPS, bp: int = 1024,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None):
    """One slot of fluid queue evolution.  Returns `(q_new, util)`."""
    from . import backend, ref

    if not use_pallas:
        return ref.queue_update_ref(q, load, cap, q_cap=q_cap, eps=eps)
    shape = q.shape
    n = q.size
    bp = min(bp, max(n, 1))
    pad = (-n) % bp
    flat = [a.reshape(-1) for a in (q, load, cap)]
    if pad:
        flat = [jnp.pad(a, (0, pad)) for a in flat]
    n_blk = flat[0].shape[0] // bp
    kernel = functools.partial(_queue_update_kernel, q_cap=q_cap,
                               eps=eps)
    qn, util = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[pl.BlockSpec((1, bp), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((1, bp), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((n_blk, bp), jnp.float32)] * 2,
        interpret=backend.pallas_interpret(interpret),
    )(*(a.reshape(n_blk, bp).astype(jnp.float32) for a in flat))
    return (qn.reshape(-1)[:n].reshape(shape).astype(q.dtype),
            util.reshape(-1)[:n].reshape(shape).astype(q.dtype))


def _nic_update_kernel(qmean_ref, rate_ref, alpha_ref, esr_ref,
                       rtt_ref, ecn_ref, rate_out_ref, alpha_out_ref,
                       *, mode: str, base_rtt_us: float, slot_us: float,
                       ecn_thresh: float, target_rtt_us: float,
                       min_rate: float, md: float, ai: float,
                       rtt_gain: float, dcqcn_ai: float, alpha_g: float):
    qmean = qmean_ref[...].astype(jnp.float32)           # (bp, P)
    rate = rate_ref[...].astype(jnp.float32)
    alpha = alpha_ref[...].astype(jnp.float32)
    esr = esr_ref[...] > 0                               # (bp, 1)
    rtt = base_rtt_us + qmean * slot_us * 0.5
    ecn = jnp.where(qmean > ecn_thresh,
                    jnp.minimum(1.0, qmean / (4 * ecn_thresh)), 0.0)
    rtt_ref[...] = rtt
    ecn_ref[...] = ecn
    if mode == "dcqcn":
        ecn_any = jnp.max(ecn, axis=1, keepdims=True)
        alpha_new = (1 - alpha_g) * alpha + alpha_g * (ecn_any > 0)
        cut = rate * (1 - alpha_new / 2)
        grow = jnp.minimum(rate + dcqcn_ai, 1.0)
        new = jnp.clip(jnp.where(ecn_any > 0, cut, grow), min_rate, 1.0)
        rate_out_ref[...] = new
        alpha_out_ref[...] = alpha_new
        return
    if mode == "agg":
        agg_ecn = jnp.max(ecn, axis=1, keepdims=True)
        agg_rtt = jnp.max(rtt, axis=1, keepdims=True)
        cut = rate * md
        rtt_err = (agg_rtt - target_rtt_us) / target_rtt_us
        trim = rate * (1 - rtt_gain * jnp.clip(rtt_err, 0, 2))
        grow = jnp.minimum(rate + ai, 1.0)
        new = jnp.where(agg_ecn > 0, cut,
                        jnp.where(rtt_err > 0.25, trim, grow))
        new = new * jnp.where(jnp.logical_and(esr, agg_ecn > 0),
                              0.85, 1.0)
        rate_out_ref[...] = jnp.clip(new, min_rate, 1.0)
        alpha_out_ref[...] = alpha
        return
    rtt_err = (rtt - target_rtt_us) / target_rtt_us
    cut = rate * (md + (1 - md) * jnp.clip(1 - ecn, 0, 1))
    trim = rate * (1 - rtt_gain * jnp.clip(rtt_err, 0, 2))
    grow = jnp.minimum(rate + ai, 1.0)
    rate_out_ref[...] = jnp.clip(
        jnp.where(ecn > 0, cut, jnp.where(rtt_err > 0.25, trim, grow)),
        min_rate, 1.0)
    alpha_out_ref[...] = alpha


def nic_update(qmean: jax.Array, rate: jax.Array, alpha: jax.Array,
               esr: jax.Array, *, mode: str, base_rtt_us: float,
               slot_us: float, ecn_thresh: float, target_rtt_us: float,
               min_rate: float, md: float, ai: float, rtt_gain: float,
               dcqcn_ai: float, alpha_g: float, bp: int = 256,
               use_pallas: bool = False,
               interpret: Optional[bool] = None):
    """Fused RTT/ECN + CC rate step.  `qmean`/`rate`/`alpha`: (F, P);
    `esr`: (F, 1) bool.  Returns `(rtt, ecn, rate_new, alpha_new)`."""
    from . import backend, ref

    if mode not in ("spx", "dcqcn", "agg"):
        raise ValueError(f"unknown nic-update mode {mode!r}")
    if not use_pallas:
        return ref.nic_update_ref(
            qmean, rate, alpha, esr, mode=mode, base_rtt_us=base_rtt_us,
            slot_us=slot_us, ecn_thresh=ecn_thresh,
            target_rtt_us=target_rtt_us, min_rate=min_rate, md=md, ai=ai,
            rtt_gain=rtt_gain, dcqcn_ai=dcqcn_ai, alpha_g=alpha_g)
    F, P = qmean.shape
    bp = min(bp, F)
    pad = (-F) % bp
    q2, r2, a2 = qmean, rate, alpha
    e2 = esr
    if pad:
        q2 = jnp.pad(q2, ((0, pad), (0, 0)))
        r2 = jnp.pad(r2, ((0, pad), (0, 0)))
        a2 = jnp.pad(a2, ((0, pad), (0, 0)))
        e2 = jnp.pad(e2, ((0, pad), (0, 0)))
    n_blk = q2.shape[0] // bp
    kernel = functools.partial(
        _nic_update_kernel, mode=mode, base_rtt_us=base_rtt_us,
        slot_us=slot_us, ecn_thresh=ecn_thresh,
        target_rtt_us=target_rtt_us, min_rate=min_rate, md=md, ai=ai,
        rtt_gain=rtt_gain, dcqcn_ai=dcqcn_ai, alpha_g=alpha_g)
    rtt, ecn, rate_new, alpha_new = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((bp, P), lambda i: (i, 0)),
            pl.BlockSpec((bp, P), lambda i: (i, 0)),
            pl.BlockSpec((bp, P), lambda i: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((bp, P), lambda i: (i, 0))] * 4,
        out_shape=[jax.ShapeDtypeStruct((q2.shape[0], P),
                                        jnp.float32)] * 4,
        interpret=backend.pallas_interpret(interpret),
    )(q2.astype(jnp.float32), r2.astype(jnp.float32),
      a2.astype(jnp.float32), e2.astype(jnp.float32))
    return (rtt[:F].astype(qmean.dtype), ecn[:F].astype(qmean.dtype),
            rate_new[:F].astype(rate.dtype),
            alpha_new[:F].astype(alpha.dtype))
