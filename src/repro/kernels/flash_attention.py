"""Fused flash attention (prefill) — pl.pallas_call with explicit BlockSpec
VMEM tiling.

TPU-native design: the MXU consumes (bq, d) x (d, bk) tiles; the online-
softmax running state (m, l, acc) lives in VMEM scratch across the
sequential k-block grid dimension.  Causal and sliding-window masks are
computed from absolute block offsets.  Validated on CPU via interpret=True
against ``ref.flash_attention_ref``.

Layout: q (B, H, Sq, D); k/v (B, H, Sk, D) — GQA is resolved by the ops.py
wrapper (kv heads repeated to q heads before the kernel; zero-copy on TPU
for the broadcast dimension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, n_k: int, causal: bool,
                  window: int, sm_scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    run = True
    if causal:
        run = jnp.any(q_pos >= k_pos) if False else True  # masked below

    q = q_ref[0].astype(jnp.float32) * sm_scale           # (bq, d)
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)                        # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, H, Sk, D). Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "caller pads to block multiples"
    n_q, n_k = Sq // bq, Sk // bk
    sm_scale = 1.0 / (D ** 0.5)

    qr = q.reshape(B * H, Sq, D)
    kr = k.reshape(B * H, Sk, D)
    vr = v.reshape(B * H, Sk, D)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal,
        window=window, sm_scale=sm_scale)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, D)
