"""Split-K decode attention: one query token against a long KV cache.

Grid: (B*H, n_k).  Each k-block computes a partial (max, sum, acc) in VMEM
scratch; the final block normalizes.  A per-batch ``length`` scalar
(prefetched to SMEM) masks cache slots beyond the valid length — the
block-table-free analogue of paged decode for ring caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref,
                   *, bk: int, n_k: int, heads: int, sm_scale: float):
    bh = pl.program_id(0)
    ik = pl.program_id(1)
    b = bh // heads

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale            # (1, d)
    k = k_ref[0].astype(jnp.float32)                       # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bk)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    valid = k_pos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, bk: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, 1, D); k/v: (B, H, S, D); lengths: (B,) int32 valid-cache
    sizes. Returns (B, H, 1, D)."""
    B, H, _, D = q.shape
    S = k.shape[2]
    bk = min(bk, S)
    assert S % bk == 0
    n_k = S // bk
    sm_scale = 1.0 / (D ** 0.5)

    qr = q.reshape(B * H, 1, D)
    kr = k.reshape(B * H, S, D)
    vr = v.reshape(B * H, S, D)

    kernel = functools.partial(_decode_kernel, bk=bk, n_k=n_k, heads=H,
                               sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, j, lens: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, lens: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, lens: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, j, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, 1, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qr, kr, vr)
    return out.reshape(B, H, 1, D)
