"""Lower a planned schedule to fabric flows + a demand-multiplier
timeline.

Rank layout over the tenant's hosts is tp-fastest:
``rank(t, d, p) = t + tp * (d + dp * p)``, so TP groups land on
adjacent hosts (same leaf when possible — NVLink-domain locality),
DP peers stride across leaves, and PP stages stride furthest.

Two flow classes come out:

  * **closed transfers** (lane 0, finite `bytes_total`, staggered
    `start_slot`): the per-step DP ring streams, MoE all2all exchanges,
    and checkpoint writes.  They are *not* window-gated — under
    congestion they simply finish late, which is exactly the step-time
    inflation signal the resiliency experiment measures.
  * **pulsed open-loop streams** (lanes >= 1, infinite bytes): PP
    activation / gradient edges and TP collective streams, gated by the
    fwd / bwd / compute windows of the `(T, K)` phase-multiplier
    timeline (lane 0 is the global always-1.0 lane).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.netsim.fabric import Flow

from .schedule import (BWD_LANE, COMPUTE_LANE, FWD_LANE,
                       LANES_PER_SCHEDULE, Phase, TrainSchedule,
                       plan_schedule)


def lower_schedule(w, hosts: List[int], topo, sim, group: str,
                   lane_offset: int = 0
                   ) -> Tuple[List[Flow], np.ndarray, TrainSchedule]:
    """WorkloadSpec(kind='schedule') -> (flows, phase_mult, schedule).

    `phase_mult` is `(sim.slots, LANES_PER_SCHEDULE)` with this
    schedule's lanes in local positions 1..3; flows already carry
    `lane_offset`-adjusted global lane ids so multiple schedules can
    stack timelines column-wise (`scenarios.compile.build_flows`).
    """
    ss = w.schedule
    plan = plan_schedule(ss, sim.slot_us, sim.slots,
                         start_slot=w.start_slot, n_planes=topo.n_planes)
    n_ranks = ss.n_ranks
    if len(hosts) < n_ranks:
        raise ValueError(
            f"schedule workload for tenant {w.tenant!r} needs "
            f"{n_ranks} ranks but the tenant owns {len(hosts)} hosts")
    hh = [int(h) for h in hosts[:n_ranks]]
    dp, tp, pp = ss.dp, ss.tp, ss.pp

    def rank(t: int, d: int, p: int) -> int:
        return t + tp * (d + dp * p)

    lane = lambda k: lane_offset + k  # noqa: E731
    flows: List[Flow] = []

    # --- pulsed open-loop streams (window-gated, infinite bytes) -------
    if tp > 1:
        for p in range(pp):
            for d in range(dp):
                ring = [hh[rank(t, d, p)] for t in range(tp)]
                flows += [Flow(ring[i], ring[(i + 1) % tp],
                               demand=w.demand, group=group,
                               phase=lane(COMPUTE_LANE))
                          for i in range(tp)]
    if pp > 1:
        for d in range(dp):
            for t in range(tp):
                for p in range(pp - 1):
                    a, b = hh[rank(t, d, p)], hh[rank(t, d, p + 1)]
                    flows.append(Flow(a, b, demand=w.demand, group=group,
                                      phase=lane(FWD_LANE)))
                    flows.append(Flow(b, a, demand=w.demand, group=group,
                                      phase=lane(BWD_LANE)))

    # --- per-step closed transfers -------------------------------------
    phases: List[Phase] = []
    step_flows: List[Tuple[int, ...]] = []
    for s in range(ss.steps):
        t0 = plan.step_starts[s]
        t_bwd = t0 + plan.w_fwd
        t_sync = t_bwd + plan.w_bwd
        t_end = t_sync + plan.w_sync
        idx: List[int] = []

        # MoE all2all dispatch/combine: launched with the forward pass,
        # ordered pairs within each EP (= DP) group.
        n0 = len(flows)
        if plan.a2a_pair > 0 and dp > 1:
            for p in range(pp):
                for t in range(tp):
                    for d1 in range(dp):
                        for d2 in range(dp):
                            if d1 == d2:
                                continue
                            flows.append(Flow(
                                hh[rank(t, d1, p)], hh[rank(t, d2, p)],
                                demand=w.demand / (dp - 1),
                                bytes_total=plan.a2a_pair,
                                start_slot=t0, group=group))
            idx += range(n0, len(flows))
        phases.append(Phase("fwd", s, t0, t_bwd,
                            sim_bytes=plan.a2a_pair * (len(flows) - n0),
                            n_flows=len(flows) - n0))
        phases.append(Phase("bwd", s, t_bwd, t_sync, 0.0, 0))

        # DP gradient sync: one ring stream per rank, launched when the
        # backward pass drains.
        n0 = len(flows)
        for p in range(pp):
            for t in range(tp):
                for d in range(dp):
                    flows.append(Flow(
                        hh[rank(t, d, p)], hh[rank(t, (d + 1) % dp, p)],
                        demand=w.demand, bytes_total=plan.ar_flow,
                        start_slot=t_sync, group=group))
        idx += range(n0, len(flows))
        phases.append(Phase("sync", s, t_sync, t_end,
                            sim_bytes=plan.ar_flow * (len(flows) - n0),
                            n_flows=len(flows) - n0))

        # Background checkpoint write after every k-th step (excluded
        # from the step-completion index — it rides the pad window and
        # beyond).
        if ss.ckpt_every and (s + 1) % ss.ckpt_every == 0:
            n0 = len(flows)
            for r in range(n_ranks):
                flows.append(Flow(
                    hh[r], hh[(r + n_ranks // 2) % n_ranks],
                    demand=w.demand, bytes_total=plan.ckpt_rank,
                    start_slot=t_end, group="ckpt"))
            phases.append(Phase("ckpt", s, t_end, t0 + plan.step_period,
                                sim_bytes=plan.ckpt_rank * n_ranks,
                                n_flows=n_ranks))
        step_flows.append(tuple(idx))

    # --- (T, K) demand-multiplier timeline -----------------------------
    pm = np.zeros((sim.slots, LANES_PER_SCHEDULE))
    pm[:, 0] = 1.0
    for s in range(ss.steps):
        t0 = plan.step_starts[s]
        pm[t0:t0 + plan.w_fwd, FWD_LANE] = 1.0
        pm[t0 + plan.w_fwd:t0 + plan.w_fwd + plan.w_bwd, BWD_LANE] = 1.0
    pm[:, COMPUTE_LANE] = np.maximum(pm[:, FWD_LANE], pm[:, BWD_LANE])

    sched = TrainSchedule(
        model=plan.model, dp=dp, tp=tp, pp=pp, steps=ss.steps,
        n_ranks=n_ranks, w_fwd=plan.w_fwd, w_bwd=plan.w_bwd,
        w_sync=plan.w_sync, pad=plan.pad,
        step_starts=plan.step_starts, phases=tuple(phases),
        step_flows=tuple(step_flows), lane_offset=lane_offset,
        grad_bytes_real=plan.grad_bytes_real,
        a2a_bytes_real=plan.a2a_bytes_real,
        ckpt_bytes_real=plan.ckpt_bytes_real)
    return flows, pm, sched
