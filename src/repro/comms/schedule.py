"""Byte accounting and the static step skeleton for schedule workloads.

`plan_schedule` resolves a `ScheduleSpec` against the architecture
registry and produces a `SchedulePlan`: calibrated per-collective byte
volumes (simulator units) plus the per-step window layout that
`comms.lower` turns into flows and a demand-multiplier timeline.

Real-byte sources — each collective's volume comes from the subsystem
that actually moves those bytes in training, not from ad-hoc constants:

  * DP gradient sync — dtype-aware micro-chunk sizes from
    `core.collectives.stream_report` over the `jax.eval_shape` parameter
    pytree (no weights are materialized); the ring / RS+AG volume per
    rank is ``2 (D-1)/D`` of the rank's gradient shard.
  * MoE all2all — `models.moe` capacity math: two ``(E, C, d_model)``
    dispatch/combine buffers per MoE layer at compute dtype, cross-rank
    share ``(m-1)/m`` over the EP group (= the DP group here).
  * PP activations — tokens-per-microbatch × d_model at compute dtype
    per pipeline edge, forward; backward carries the same volume in
    gradients (modelled as a 2× window, matching the usual fwd:bwd
    FLOP ratio).
  * Checkpoint writes — the rank's parameter-shard bytes (exactly the
    leaves `checkpoint.ckpt.save_checkpoint` host-gathers).

Calibration: fabric capacity 1.0 moves ``line_rate_gbps`` for one slot,
so ``sim_bytes = real_bytes / (line_rate_gbps * 125 * slot_us)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Tuple

import numpy as np

# Demand-multiplier lane layout of one lowered schedule: lane 0 is the
# global always-1.0 lane, then fwd / bwd / compute(fwd|bwd) windows.
LANES_PER_SCHEDULE = 4
FWD_LANE, BWD_LANE, COMPUTE_LANE = 1, 2, 3

# Minimum window width in slots — keeps the step skeleton well-formed
# even when a collective's calibrated volume rounds to under one slot.
MIN_WINDOW = 4
STEP_PAD = 2


def sim_bytes(real_bytes: float, line_rate_gbps: float,
              slot_us: float) -> float:
    """Real bytes -> simulator byte units (1 Gbit/s = 125 bytes/us)."""
    return real_bytes / (line_rate_gbps * 125.0 * slot_us)


def _itemsize(dtype_name: str) -> int:
    import jax.numpy as jnp
    return jnp.dtype(dtype_name).itemsize


def resolve_model(ss):
    """ScheduleSpec -> the `ModelConfig` whose traffic it compiles
    (`reduced()` family shrink when `ss.reduced` — registry scenarios
    stay numpy-fast while keeping dense/MoE structure)."""
    from repro.configs import get_config
    cfg = get_config(ss.model)
    return cfg.reduced() if ss.reduced else cfg


def grad_chunk_bytes(cfg, n_planes: int) -> np.ndarray:
    """Dtype-aware gradient micro-chunk sizes for the whole model —
    `stream_report` over the `jax.eval_shape` parameter pytree, i.e. the
    exact chunking the plane-sharded allreduce engine would stream."""
    import jax
    import jax.numpy as jnp
    from repro.core.collectives import stream_report
    from repro.core.planes import PlaneConfig
    from repro.models.transformer import init_params
    tree = jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))
    # Pin every leaf to param_dtype: gradients stream at master-weight
    # precision, and byte volumes must not depend on whether the host
    # process enabled x64 (init leaves widen to f64 there).
    dt = jnp.dtype(cfg.param_dtype)
    tree = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, dt), tree)
    rep = stream_report(tree, PlaneConfig(
        n_planes=n_planes, microchunks=max(16, n_planes)))
    return rep.chunk_bytes


def moe_a2a_bytes_per_rank(cfg, ss) -> float:
    """Real all2all bytes one rank exchanges per step: two (E, C, d)
    buffers per MoE layer at compute dtype, cross-rank share (m-1)/m
    over the EP group (the DP group)."""
    if cfg.moe_experts == 0:
        return 0.0
    from repro.models.moe import _capacity
    per_period = sum(cfg.is_moe_pos(p) for p in range(cfg.pattern_len))
    n_moe = cfg.n_periods * per_period
    if n_moe == 0:
        return 0.0
    cap = _capacity(ss.tokens_per_rank, cfg)
    buf = cfg.moe_experts * cap * cfg.d_model * _itemsize(cfg.dtype)
    m = ss.dp
    return n_moe * 2.0 * buf * (m - 1) / m


@dataclass(frozen=True)
class SchedulePlan:
    """Calibrated volumes (simulator byte units) + step skeleton."""
    model: str                 # resolved ModelConfig.name
    ar_flow: float             # one DP ring stream, per rank per step
    a2a_pair: float            # one ordered EP pair, per step
    act_edge: float            # per microbatch per pp edge (fwd)
    ckpt_rank: float           # one rank's checkpoint shard
    w_fwd: int
    w_bwd: int
    w_sync: int
    pad: int
    step_starts: Tuple[int, ...]
    grad_bytes_real: float     # whole-model gradient bytes (dtype-aware)
    a2a_bytes_real: float      # per rank per step
    act_bytes_real: float      # per microbatch per edge
    ckpt_bytes_real: float     # per rank

    @property
    def step_period(self) -> int:
        return self.w_fwd + self.w_bwd + self.w_sync + self.pad


def plan_schedule(ss, slot_us: float, slots: int, start_slot: int = 0,
                  n_planes: int = 1) -> SchedulePlan:
    """Byte-account a `ScheduleSpec` and lay out its step windows.

    Raises if the simulation horizon cannot hold `ss.steps` full steps —
    a schedule that silently truncates would corrupt step-time metrics.
    """
    cfg = resolve_model(ss)
    sb = lambda b: sim_bytes(b, ss.line_rate_gbps, slot_us)  # noqa: E731

    grad_real = float(grad_chunk_bytes(cfg, n_planes).sum())
    shard_real = grad_real / (ss.tp * ss.pp)
    ar_real = (2.0 * (ss.dp - 1) / ss.dp) * shard_real
    a2a_real = moe_a2a_bytes_per_rank(cfg, ss)
    act_real = ((ss.tokens_per_rank / ss.microbatches)
                * cfg.d_model * _itemsize(cfg.dtype)) if ss.pp > 1 else 0.0

    ar_flow = sb(ar_real)
    a2a_pair = sb(a2a_real) / max(ss.dp - 1, 1)
    act_edge = sb(act_real)
    ckpt_rank = sb(shard_real)

    # Static skeleton: forward window long enough to stream every
    # microbatch's activations at line rate, backward 2x (fwd:bwd FLOP
    # ratio), sync window sized to the uncongested ring stream.  The
    # compute windows must also drain the EP all2all (it overlaps
    # fwd+bwd = 3 w_fwd; with TP streams sharing the NIC its effective
    # rate halves) or back-to-back steps pile up unboundedly.
    a2a_rank = a2a_pair * max(ss.dp - 1, 1)
    overlap = 2.0 if ss.tp > 1 else 1.0
    w_fwd = max(MIN_WINDOW, math.ceil(ss.microbatches * act_edge),
                math.ceil(overlap * a2a_rank / 3.0))
    w_bwd = 2 * w_fwd
    w_sync = max(MIN_WINDOW, math.ceil(ar_flow))
    period = w_fwd + w_bwd + w_sync + STEP_PAD
    need = start_slot + ss.steps * period
    if slots < need:
        raise ValueError(
            f"schedule for {ss.model!r} needs {need} slots "
            f"({ss.steps} steps x {period}-slot period from slot "
            f"{start_slot}) but sim.slots = {slots}")
    step_starts = tuple(start_slot + s * period for s in range(ss.steps))
    return SchedulePlan(
        model=cfg.name, ar_flow=ar_flow, a2a_pair=a2a_pair,
        act_edge=act_edge, ckpt_rank=ckpt_rank,
        w_fwd=w_fwd, w_bwd=w_bwd, w_sync=w_sync, pad=STEP_PAD,
        step_starts=step_starts,
        grad_bytes_real=grad_real, a2a_bytes_real=a2a_real,
        act_bytes_real=act_real, ckpt_bytes_real=shard_real)


@dataclass(frozen=True)
class Phase:
    """One row of the compiled phase table (golden-tested)."""
    name: str                  # 'fwd' | 'bwd' | 'sync' | 'ckpt'
    step: int
    start_slot: int
    stop_slot: int
    sim_bytes: float           # closed-transfer volume scheduled here
    n_flows: int               # closed flows launched at start_slot


@dataclass(frozen=True)
class TrainSchedule:
    """Compiled-schedule metadata carried on `CompiledScenario` — enough
    to derive per-step completion times from either backend's
    `completion_slot` without re-running the compiler."""
    model: str
    dp: int
    tp: int
    pp: int
    steps: int
    n_ranks: int
    w_fwd: int
    w_bwd: int
    w_sync: int
    pad: int
    step_starts: Tuple[int, ...]
    phases: Tuple[Phase, ...]
    # Per-step indices of the closed flows whose completion defines the
    # step (DP sync + MoE a2a; checkpoint writes are background and
    # excluded).  Local to the lowered flow list until `shifted()`.
    step_flows: Tuple[Tuple[int, ...], ...]
    lane_offset: int           # global lane of this schedule's FWD_LANE - 1
    grad_bytes_real: float
    a2a_bytes_real: float
    ckpt_bytes_real: float

    @property
    def step_period(self) -> int:
        return self.w_fwd + self.w_bwd + self.w_sync + self.pad

    def shifted(self, offset: int) -> "TrainSchedule":
        """Rebase `step_flows` onto the scenario's global flow list."""
        return replace(self, step_flows=tuple(
            tuple(i + offset for i in s) for s in self.step_flows))

    def step_times(self, completion_slot, horizon: int) -> np.ndarray:
        """(steps,) slots from each scheduled step start to its last
        closed-flow completion (unfinished flows count as `horizon` —
        a step that never syncs is maximally late, not missing)."""
        comp = np.asarray(completion_slot, np.float64)
        out = []
        for s, idx in enumerate(self.step_flows):
            if not idx:
                out.append(float("nan"))
                continue
            c = comp[list(idx)]
            c = np.where(c < 0, float(horizon), c)
            out.append(float(c.max()) - self.step_starts[s])
        return np.asarray(out)
