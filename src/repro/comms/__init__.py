"""Collective-schedule co-simulation: compile a real training step's
parallelism plan (DP/TP/PP/EP over a `repro.configs` model) into the
fabric simulator's flow + demand-timeline representation.

Pipeline:
  `ScheduleSpec` (pure data, `scenarios.spec`)
    -> `plan_schedule`  (byte accounting + static step skeleton, here)
    -> `lower_schedule` (flows + (T, K) phase-multiplier timeline +
                         `TrainSchedule` step metadata, `comms.lower`)
    -> both netsim backends, via `WorkloadSpec(kind='schedule')`.

This package imports JAX (parameter pytrees come from `jax.eval_shape`),
so the scenario compiler pulls it in lazily — NumPy pool workers stay
JAX-free unless a schedule workload is actually present.
"""
from .schedule import (LANES_PER_SCHEDULE, Phase, SchedulePlan,
                       TrainSchedule, plan_schedule, sim_bytes)
from .lower import lower_schedule

__all__ = [
    "LANES_PER_SCHEDULE", "Phase", "SchedulePlan", "TrainSchedule",
    "plan_schedule", "sim_bytes", "lower_schedule",
]
