from .sharding import ShardCtx, local_ctx, param_shardings, spec_for_axes
