"""Logical-axis sharding rules and the ShardCtx threaded through the model.

Parameters are annotated with *logical* axes at init time (see
``layers.axes_builder``); ``rules`` maps logical axes to mesh axes.  The
default rules implement Megatron-style TP over 'model', DP over
('pod','data'), sequence-parallel residual activations, and expert
parallelism over 'model'.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: Dict[str, Any] = {
    "embed": None,          # d_model: replicated
    "mlp": "model",         # FFN intermediate
    "heads": "model",       # attention heads
    "kv": "model",          # kv heads (may be fewer than model size -> None)
    "head": None,           # per-head dim
    "vocab": "model",       # embedding/vocab dim
    "embed_t": None,        # embedding-table d_model dim (never sharded)
    "experts": "model",     # MoE expert dim
    "embed_e": None,        # expert d_model dim (contracted; never FSDP)
    "mlp_e": None,          # expert FFN dim (FSDP-sharded when enabled)
    "qlora": None,
    "kvlora": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": "model",
    "layers": None,         # stacked-scan leading dim
    "ff_tokens": None,
}


def make_rules(fsdp_axis: Optional[str] = None) -> Dict[str, Any]:
    """Param sharding rules; fsdp_axis additionally shards the 'embed'
    (d_model) dim of weights over a DP axis — ZeRO-3-style, with GSPMD
    inserting the per-layer all-gathers under the layer scan.

    Under FSDP the vocab dim stays unsharded: a gather whose operand is
    sharded on BOTH dims (vocab x model, embed x data) crash-checks XLA's
    SPMD partitioner on >2D meshes; d_model x data sharding already bounds
    the table's per-device bytes."""
    rules = dict(DEFAULT_RULES)
    if fsdp_axis is not None:
        rules["embed"] = fsdp_axis
        rules["mlp_e"] = fsdp_axis
        # qlora/kvlora stay unsharded: they are CONTRACTED dims of the big
        # MLA projections — FSDP-sharding them makes every MLA matmul emit
        # bf16 partial-sum all-reduces (XLA:CPU promotion crash), and the
        # tensors are small (<10 MB/device under the model axis).
    return rules


@dataclass(frozen=True)
class ShardCtx:
    """Distribution context threaded through model apply functions."""
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    seq_sharded: bool = True          # sequence-parallel residual stream
    fsdp_axis: Optional[str] = None
    rules: Dict[str, Any] = field(default_factory=lambda: dict(DEFAULT_RULES))

    @property
    def plane_axes(self) -> Tuple[str, ...]:
        """DP axes the plane collective engine synchronizes explicitly.
        With FSDP, grads over the fsdp axis are reduce-scattered by GSPMD;
        the plane engine owns the remaining (scale-out) DP axes — the
        paper's inter-pod network."""
        return tuple(a for a in self.dp_axes if a != self.fsdp_axis)

    @property
    def tp_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_spec(self):
        return tuple(self.dp_axes) if len(self.dp_axes) > 1 else \
            self.dp_axes[0]

    def with_seq(self, seq_sharded: bool) -> "ShardCtx":
        return replace(self, seq_sharded=seq_sharded)


def local_ctx() -> ShardCtx:
    return ShardCtx(mesh=None)


# ---------------------------------------------------------------------------
# parameter sharding
# ---------------------------------------------------------------------------

def spec_for_axes(axes: Tuple[str, ...], ctx: ShardCtx,
                  shape: Optional[Tuple[int, ...]] = None) -> P:
    """Logical axes -> PartitionSpec, dropping shardings that don't divide."""
    out = []
    for i, ax in enumerate(axes):
        mesh_ax = ctx.rules.get(ax)
        if mesh_ax is None or ctx.mesh is None:
            out.append(None)
            continue
        size = ctx.mesh.shape[mesh_ax]
        if shape is not None and shape[i] % size != 0:
            out.append(None)        # e.g. kv=1 (MQA) cannot shard 16-way
        else:
            out.append(mesh_ax)
    # a mesh axis may appear at most once in a spec
    seen = set()
    for i, ax in enumerate(out):
        if ax is None:
            continue
        if ax in seen:
            out[i] = None
        seen.add(ax)
    return P(*out)


def param_shardings(axes_tree, ctx: ShardCtx, shapes_tree=None):
    """Build a NamedSharding tree mirroring the params tree."""
    def one(axes, shape):
        spec = spec_for_axes(tuple(axes), ctx,
                             tuple(shape) if shape is not None else None)
        return NamedSharding(ctx.mesh, spec)

    if shapes_tree is None:
        return jax.tree.map(lambda a: one(a, None), axes_tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(lambda a, s: one(a, s.shape), axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

def _manual_axes() -> frozenset:
    ambient = jax.sharding.get_abstract_mesh()
    if ambient.empty:
        return frozenset()
    return frozenset(
        n for n, t in zip(ambient.axis_names, ambient.axis_types)
        if t == jax.sharding.AxisType.Manual)


def _strip_manual(spec: P, manual: frozenset) -> P:
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a not in manual)
            out.append(kept if kept else None)
        else:
            out.append(None if entry in manual else entry)
    return P(*out)


def _constraint(x, ctx: ShardCtx, spec: P):
    """Sharding constraint that composes with partial-manual shard_map:
    axes already manual in the ambient mesh are dropped from the spec
    (those dims are local blocks there)."""
    if ctx.mesh is None:
        return x
    manual = _manual_axes()
    if manual:
        spec = _strip_manual(spec, manual)
        mesh = jax.sharding.get_abstract_mesh()
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def shard_residual(x, ctx: ShardCtx):
    """(B, S, D): B over dp, S over tp when sequence-parallel."""
    if ctx.mesh is None:
        return x
    seq_ax = ctx.tp_axis if (ctx.seq_sharded and
                             x.shape[1] % ctx.tp_size == 0 and
                             x.shape[1] >= ctx.tp_size) else None
    return _constraint(x, ctx, P(ctx.dp_spec, seq_ax, None))


def shard_heads(x, ctx: ShardCtx):
    """(B, S, H, D): heads over tp; when heads don't divide the mesh axis
    (MQA / few-head archs), fall back to sequence-sharded attention so the
    per-device work still scales 1/tp."""
    if ctx.mesh is None:
        return x
    if x.shape[2] % ctx.tp_size == 0:
        return _constraint(x, ctx, P(ctx.dp_spec, None, ctx.tp_axis, None))
    if x.shape[1] % ctx.tp_size == 0 and x.shape[1] >= ctx.tp_size:
        return _constraint(x, ctx, P(ctx.dp_spec, ctx.tp_axis, None, None))
    return _constraint(x, ctx, P(ctx.dp_spec, None, None, None))


def shard_ff(x, ctx: ShardCtx):
    """(B, S, F): FFN intermediate over tp."""
    if ctx.mesh is None:
        return x
    f_ax = ctx.tp_axis if x.shape[-1] % ctx.tp_size == 0 else None
    return _constraint(x, ctx, P(ctx.dp_spec, None, f_ax))


def shard_logits(x, ctx: ShardCtx):
    """(B, S, V): vocab over tp."""
    if ctx.mesh is None:
        return x
    v_ax = ctx.tp_axis if x.shape[-1] % ctx.tp_size == 0 else None
    return _constraint(x, ctx, P(ctx.dp_spec, None, v_ax))


def shard_cache(x, ctx: ShardCtx, kv_heads_axis: int = 2):
    """KV cache (B, S, Hkv, D) — Hkv over tp if divisible, else S over tp.

    Long-context decode (B=1) relies on the S fallback: the 524k-entry cache
    shards over the model axis even when kv heads cannot."""
    if ctx.mesh is None or x.ndim < 3:
        return x
    if x.ndim == 4:
        B, S, H = x.shape[0], x.shape[1], x.shape[2]
        if H % ctx.tp_size == 0:
            return _constraint(x, ctx, P(ctx.dp_spec if B > 1 else None,
                                         None, ctx.tp_axis, None))
        if S % ctx.tp_size == 0:
            return _constraint(x, ctx, P(ctx.dp_spec if B > 1 else None,
                                         ctx.tp_axis, None, None))
        return x
    # (B, S, L) latent caches: shard S over tp
    B, S = x.shape[0], x.shape[1]
    if S % ctx.tp_size == 0 and S >= ctx.tp_size:
        return _constraint(x, ctx, P(ctx.dp_spec if B > 1 else None,
                                     ctx.tp_axis, None))
    return x
