"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus per-arch
input-shape sets for the dry-run matrix.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig

from .musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from .jamba_v0_1_52b import CONFIG as JAMBA_52B
from .mamba2_780m import CONFIG as MAMBA2_780M
from .deepseek_v2_236b import CONFIG as DEEPSEEK_V2_236B
from .phi35_moe_42b import CONFIG as PHI35_MOE
from .llama3_8b import CONFIG as LLAMA3_8B
from .gemma_2b import CONFIG as GEMMA_2B
from .gemma3_12b import CONFIG as GEMMA3_12B
from .granite_20b import CONFIG as GRANITE_20B
from .llava_next_mistral_7b import CONFIG as LLAVA_NEXT
from .spx_paper import DEEPSEEK_V3_PROXY, SPX_100M

ARCHS: Dict[str, ModelConfig] = {
    "musicgen-medium": MUSICGEN_MEDIUM,
    "jamba-v0.1-52b": JAMBA_52B,
    "mamba2-780m": MAMBA2_780M,
    "deepseek-v2-236b": DEEPSEEK_V2_236B,
    "phi3.5-moe-42b-a6.6b": PHI35_MOE,
    "llama3-8b": LLAMA3_8B,
    "gemma-2b": GEMMA_2B,
    "gemma3-12b": GEMMA3_12B,
    "granite-20b": GRANITE_20B,
    "llava-next-mistral-7b": LLAVA_NEXT,
    # paper-native extras (not part of the 40-cell matrix)
    "deepseek-v3-proxy": DEEPSEEK_V3_PROXY,
    "spx-100m": SPX_100M,
}

ASSIGNED = [n for n in ARCHS if n not in
            ("deepseek-v3-proxy", "spx-100m")]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    cfg.validate()
    return cfg


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (skip pure full-attention
    archs per the assignment; see DESIGN.md §Arch-applicability)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 524k-token decode KV is "
                       "quadratic-cost prefill territory; skipped per "
                       "assignment")
    return True, ""


def matrix():
    """All 40 (arch x shape) cells with applicability flags."""
    cells = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = shape_applicable(cfg, shape)
            cells.append((arch, shape, ok, why))
    return cells
