"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2 on every layer.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2
[hf microsoft/Phi-3.5-MoE-instruct]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32064,
    block_pattern=("a",),
    moe_experts=16,
    moe_topk=2,
    moe_d_ff=6400,
)
