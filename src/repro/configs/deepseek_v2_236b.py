"""deepseek-v2-236b [moe] — MLA latent attention + fine-grained MoE.

60L d_model=5120 128H d_ff=1536(routed expert) vocab=102400,
MoE 160 routed top-6 + 2 shared; MLA kv_lora=512, q_lora=1536,
rope_head_dim=64, nope=128, v=128.  First layer is a dense FFN
(intermediate 12288), layers 2..60 are MoE.  [arXiv:2405.04434; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,          # nope 128 + rope 64
    d_ff=12288,            # dense prefix layer intermediate
    vocab=102400,
    n_prefix_layers=1,
    block_pattern=("a",),
    use_mla=True,
    q_lora=1536,
    kv_lora=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    moe_experts=160,
    moe_topk=6,
    moe_shared=2,
    moe_d_ff=1536,
)
