"""llava-next-mistral-7b [vlm] — Mistral-7B backbone, anyres tiling.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf llava-hf/llava-v1.6-mistral-7b-hf]

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (576 base-resolution tokens; anyres adds
tiles).  The backbone is Mistral-7B-v0.2 (full attention, rope 1e6).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    block_pattern=("a",),
    rope_base=1_000_000.0,
    frontend="vision",
    frontend_tokens=576,
)
