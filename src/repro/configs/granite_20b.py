"""granite-20b [dense] — llama-arch code model, MQA.

52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf ibm-granite]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    block_pattern=("a",),
)
