"""gemma-2b [dense] — GeGLU, head_dim=256, MQA.

18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000
[arXiv:2403.08295; hf google/gemma-2b]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    act="gelu",
    block_pattern=("a",),
    tie_embeddings=True,
)
