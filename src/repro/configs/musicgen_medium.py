"""musicgen-medium [audio] — decoder-only LM over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24 -> MHA) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf facebook/musicgen-medium]

Audio frontend is a STUB per the assignment: the text-conditioning prefix
arrives as precomputed continuous embeddings (frontend_tokens); the EnCodec
codebook tokens are the LM vocabulary itself.  MusicGen's FFN is ungated
GELU (plain transformer decoder).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    act="gelu",
    gated_mlp=False,
    block_pattern=("a",),
    frontend="audio",
    frontend_tokens=64,
)
