"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf ai21labs/Jamba-v0.1]

Period of 8 layers with attention at offset 4 (attn_layer_period=8,
attn_layer_offset=4) and MoE every 2 layers at offset 1
(expert_layer_period=2, expert_layer_offset=1).  The SSM mixer here is the
SSD (Mamba2-style) formulation with Jamba's d_state=16, expand=2
(d_inner=8192 -> 128 heads x 64), 8 B/C groups for TP.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    block_pattern=("m", "m", "m", "m", "a", "m", "m", "m"),
    moe_experts=16,
    moe_topk=2,
    moe_d_ff=14336,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_heads=128,
    ssm_head_dim=64,
    ssm_groups=8,
    conv_width=4,
)
