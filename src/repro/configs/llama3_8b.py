"""llama3-8b [dense] — GQA, 128k vocab.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[arXiv:2407.21783]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    block_pattern=("a",),
    rope_base=500000.0,
)
