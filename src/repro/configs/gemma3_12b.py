"""gemma3-12b [dense] — 5:1 local:global sliding-window, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf google/gemma-3-12b-pt]

Pattern: 5 sliding-window (1024) layers then 1 global layer, x8 periods.
QK-norm per gemma3; GeGLU; head_dim=256.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    act="gelu",
    block_pattern=("l", "l", "l", "l", "l", "g"),
    window=1024,
    qk_norm=True,
    rope_base=1_000_000.0,
    tie_embeddings=True,
)
