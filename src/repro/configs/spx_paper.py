"""Paper-native workload configs.

* ``deepseek-v3-proxy`` — the paper's §6.3 isolation workload ("DeepSeek-V3
  16N NVL8 proxy"): an MLA+MoE model scaled so a 16-node slice trains it;
  used by the fig9/fig10 isolation benchmarks.
* ``spx-100m`` — the ~100M-parameter model for the end-to-end training
  example (examples/train_e2e.py).
"""
from repro.models.config import ModelConfig

DEEPSEEK_V3_PROXY = ModelConfig(
    name="deepseek-v3-proxy",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,
    d_ff=8192,
    vocab=32768,
    n_prefix_layers=1,
    block_pattern=("a",),
    use_mla=True,
    q_lora=768,
    kv_lora=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    moe_experts=64,
    moe_topk=8,
    moe_shared=1,
    moe_d_ff=1024,
)

SPX_100M = ModelConfig(
    name="spx-100m",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab=32768,
    block_pattern=("a",),
    remat="none",
)
