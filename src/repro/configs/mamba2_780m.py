"""mamba2-780m [ssm] — pure SSD (state-space duality), attention-free.

48L d_model=1536 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; state-spaces/mamba2-780m]

Mixer-only blocks (no MLP sublayer, d_ff=0); expand=2 -> d_inner=3072,
head_dim=64 -> 48 heads, n_groups=1.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    block_pattern=("m",),
    ssm_state=128,
    ssm_heads=48,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_width=4,
    tie_embeddings=True,
)
