"""High-frequency trace capture (§5.3): what the simulators record per
slot, and what falls out of it.

`TraceSpec` is the paper's 100 µs – 10 ms sampling knob: which per-slot
signals to keep (`fields`, canonical order `TRACE_FIELDS`) and at what
decimation (`every`, in slots — with `slot_us=100` the default records
every 100 µs, `every=100` every 10 ms).  It is threaded through
`SimSpec`/`SimConfig` into both backends; the numpy loop appends at
recorded slots, the jx engine stacks all slots as extra `lax.scan`
outputs and strides them inside the jitted program, so both produce the
slot set `range(0, slots, every)`.

A captured trace is a plain dict of numpy arrays (T = recorded slots,
H hosts, P planes, L leaves, U uplinks-per-leaf, F flows):

    slot      (T,)       recorded slot indices
    host_bw   (T, H, P)  per-host per-plane delivered goodput
                         (stall-masked, fabric-rate units)
    util      (T, P, L, U)  stage-A uplink utilization
    queue     (T, P, L, U)  stage-A uplink queue depth (post-update)
    ecn       (T, F, P)  per-flow per-plane ECN mark indicator
    eligible  (T, F, P)  per-flow plane eligibility (SPX failover mask;
                         a flip here IS the reroute/failover event)

`trace_summary` feeds the dormant §5 analyses
(`bw_histogram`/`classify_histogram`/`find_stragglers`) and produces the
derived metric columns `hft_transient_drops`, `straggler_ranks` and
`bimodal_frac`; `trace_to_npz`/`trace_to_perfetto` export raw traces for
offline tooling (Perfetto / `chrome://tracing` open the JSON directly).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.telemetry import bw_histogram, classify_histogram, \
    find_stragglers

# Canonical field order — capture code in both backends and the
# megabatch finalizer rely on this ordering, never on dict order.
TRACE_FIELDS: Tuple[str, ...] = ("host_bw", "util", "queue", "ecn",
                                 "eligible")

# Fields whose second axis (after time) is the flow axis; megabatch pads
# flows to pow2 buckets and must strip these back to the true count.
FLOW_AXIS_FIELDS = frozenset(("ecn", "eligible"))

# A port whose time-mean normalized goodput is below this never carried
# traffic; it is excluded from the bi-modal census.
ACTIVE_PORT_THRESH = 0.01


@dataclass(frozen=True)
class TraceSpec:
    """What to record per slot, and at what decimation.

    Hashable/frozen on purpose: it rides inside `SimConfig`/`JxConfig`,
    so a distinct spec forks jit-program identity (tracing on compiles a
    different program; tracing off leaves the HLO byte-identical to a
    build that never heard of tracing).
    """
    enabled: bool = False
    every: int = 1
    fields: Tuple[str, ...] = TRACE_FIELDS

    def __post_init__(self):
        object.__setattr__(self, "fields", tuple(self.fields))

    def active_fields(self) -> Tuple[str, ...]:
        """Requested fields in canonical order (capture order)."""
        return tuple(f for f in TRACE_FIELDS if f in self.fields)

    def validate(self) -> None:
        if self.every < 1:
            raise ValueError(f"trace.every must be >= 1, got {self.every}")
        unknown = sorted(set(self.fields) - set(TRACE_FIELDS))
        if unknown:
            raise ValueError(
                f"unknown trace fields {unknown}; valid: {TRACE_FIELDS}")
        if self.enabled and not self.active_fields():
            raise ValueError("trace enabled with no fields selected")

    def recorded_slots(self, n_slots: int) -> np.ndarray:
        return np.arange(0, n_slots, self.every, dtype=np.int64)


# ---------------------------------------------------------------------------
# §5 analyses over a captured trace
# ---------------------------------------------------------------------------

def trace_summary(trace: Optional[Dict[str, np.ndarray]],
                  access_cap: float, n_planes: int) -> Dict[str, object]:
    """Derived metric columns from a captured trace.

    * `bimodal_frac` — fraction of active (host, plane) ports whose
      normalized BW histogram classifies "healthy-blocked" (§5.2's
      bi-modal signature: line rate or idle, stalled on someone else).
    * `straggler_ranks` — hosts whose host-level series classifies
      "straggler" (mid-range mass — the slow rank itself).
    * `hft_transient_drops` — recorded slots where aggregate goodput
      fell below half its median (§5.3's transient-drop signature);
      -1 when no usable trace.
    """
    out: Dict[str, object] = {"hft_transient_drops": -1,
                              "straggler_ranks": (),
                              "bimodal_frac": float("nan")}
    if not trace:
        return out
    hb = np.asarray(trace.get("host_bw", np.empty((0, 0, 0))), np.float64)
    if hb.ndim != 3 or hb.shape[0] < 2 or hb.size == 0:
        return out
    line = max(float(access_cap), 1e-12)
    port = hb / line                                   # (T, H, P)
    host = hb.sum(axis=2) / (line * max(n_planes, 1))  # (T, H)

    active = port.mean(axis=0) > ACTIVE_PORT_THRESH    # (H, P)
    port_classes: Dict[str, int] = {}
    n_bimodal = 0
    for h, p in zip(*np.nonzero(active)):
        cls = classify_histogram(bw_histogram(port[:, h, p]))
        port_classes[cls] = port_classes.get(cls, 0) + 1
        if cls == "healthy-blocked":
            n_bimodal += 1
    n_active = int(active.sum())

    agg = hb.sum(axis=(1, 2))
    drops = 0
    if agg.shape[0] >= 4:
        med = float(np.median(agg))
        if med > 1e-12:
            drops = int((agg < 0.5 * med).sum())

    out["hft_transient_drops"] = drops
    out["straggler_ranks"] = tuple(find_stragglers(host.T))
    out["bimodal_frac"] = (n_bimodal / n_active if n_active
                           else float("nan"))
    out["port_classes"] = port_classes
    return out


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def trace_to_npz(path: str, trace: Dict[str, np.ndarray],
                 slot_us: float = 1.0, label: str = "sim") -> None:
    """Compressed npz of the raw trace arrays plus slot_us metadata."""
    payload = {k: np.asarray(v) for k, v in trace.items()}
    payload["slot_us"] = np.float64(slot_us)
    payload["label"] = np.str_(label)
    np.savez_compressed(path, **payload)


def _counter(events, name, ts_us, value):
    events.append({"name": name, "ph": "C", "ts": float(ts_us),
                   "pid": 0, "args": {"value": float(value)}})


def trace_to_perfetto(path: str, trace: Dict[str, np.ndarray],
                      slot_us: float = 1.0, label: str = "sim") -> None:
    """Chrome-trace / Perfetto JSON timeline of the fabric reacting.

    Counter tracks: per-host goodput, per-plane mean utilization and
    queue depth, fabric-wide ECN mark rate.  Instant events mark every
    plane-eligibility flip (the SPX failover / reroute signal).
    """
    slots = np.asarray(trace.get("slot", ()), np.int64)
    events = []
    hb = trace.get("host_bw")
    if hb is not None:
        hb = np.asarray(hb, np.float64)
        for t, s in enumerate(slots[:hb.shape[0]]):
            ts = float(s) * slot_us
            for h in range(hb.shape[1]):
                _counter(events, f"host{h}.goodput", ts, hb[t, h].sum())
    for key, fmt in (("util", "plane{p}.util"),
                     ("queue", "plane{p}.queue")):
        arr = trace.get(key)
        if arr is None:
            continue
        arr = np.asarray(arr, np.float64)
        for t, s in enumerate(slots[:arr.shape[0]]):
            ts = float(s) * slot_us
            for p in range(arr.shape[1]):
                _counter(events, fmt.format(p=p), ts, arr[t, p].mean())
    ecn = trace.get("ecn")
    if ecn is not None:
        ecn = np.asarray(ecn, np.float64)
        for t, s in enumerate(slots[:ecn.shape[0]]):
            _counter(events, "fabric.ecn_rate", float(s) * slot_us,
                     ecn[t].mean())
    elig = trace.get("eligible")
    if elig is not None and np.asarray(elig).shape[0] > 1:
        elig = np.asarray(elig, bool)
        flips = elig[1:] != elig[:-1]                  # (T-1, F, P)
        for t, f, p in zip(*np.nonzero(flips)):
            gained = bool(elig[t + 1, f, p])
            events.append({
                "name": (f"flow{f}.plane{p} "
                         f"{'restored' if gained else 'failover'}"),
                "ph": "i", "ts": float(slots[t + 1]) * slot_us,
                "pid": 0, "tid": 0, "s": "g"})
    doc = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": {"label": label, "slot_us": slot_us,
                         "recorded_slots": int(slots.shape[0])}}
    with open(path, "w") as fh:
        json.dump(doc, fh)
