"""NumPy-vs-JAX backend parity.

Every registry scenario compiles to a static fault timeline, so the JAX
backend must reproduce the NumPy trajectory.  With x64 enabled the two
engines agree within 1e-5 on mean goodput, completion slots, the total
goodput time series, and every distilled per-tenant metric — across
routings (ar | war | ecmp) and NIC stacks (spx | dcqcn).  Giga-scale
scenarios (>= 4096 hosts) are the one exception: there, cross-engine
summation-order ulps fork a bounded handful of host trajectories at
ECN thresholds, so parity is asserted as contained-fork + tight
aggregates instead (_assert_parity_chaotic).

Fast cross-product cases run in tier-1; the full-length all-registry
sweep and the batched-sweep equivalence run under `-m slow` (the CI
jax-backend job includes them).
"""
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.netsim.jx import compile_fault_timeline, has_static_timeline
from repro.scenarios import (SweepGrid, compile_scenario, distill_metrics,
                             get_scenario, list_scenarios, sweep)

TOL = 1e-5


def _run_both(spec):
    with enable_x64():
        ref = compile_scenario(spec).run(backend="numpy")
        jres = compile_scenario(spec).run(backend="jax")
    return ref, jres


def _assert_parity(spec, ref, jres):
    np.testing.assert_allclose(jres.mean_goodput, ref.mean_goodput,
                               atol=TOL, rtol=TOL)
    np.testing.assert_array_equal(jres.completion_slot,
                                  ref.completion_slot)
    np.testing.assert_allclose(jres.total_goodput, ref.total_goodput,
                               atol=TOL * len(ref.mean_goodput), rtol=TOL)
    np.testing.assert_allclose(jres.util_up_last, ref.util_up_last,
                               atol=TOL, rtol=TOL)
    assert jres.groups == ref.groups
    np.testing.assert_array_equal(jres.group_of, ref.group_of)
    # failure-reaction observables: both backends must expose the same
    # per-slot blackholed-byte series (or neither, when no reaction)
    bh_r = getattr(ref, "blackhole_timeline", None)
    bh_j = getattr(jres, "blackhole_timeline", None)
    assert (bh_r is None) == (bh_j is None)
    if bh_r is not None:
        np.testing.assert_allclose(bh_j, bh_r,
                                   atol=TOL * len(ref.mean_goodput),
                                   rtol=TOL)
    c = compile_scenario(spec)
    m_ref = distill_metrics(spec, c, ref)
    m_jx = distill_metrics(spec, c, jres)
    for t in m_ref.tenant_mean:
        assert m_jx.tenant_mean[t] == pytest.approx(m_ref.tenant_mean[t],
                                                    abs=TOL)
        assert m_jx.tenant_p01[t] == pytest.approx(m_ref.tenant_p01[t],
                                                   abs=TOL)
        assert m_jx.tenant_p99[t] == pytest.approx(m_ref.tenant_p99[t],
                                                   abs=TOL)
    assert m_jx.isolation_index == pytest.approx(m_ref.isolation_index,
                                                 abs=TOL)
    assert m_jx.recovery_slots == m_ref.recovery_slots
    assert m_jx.reaction_slots == m_ref.reaction_slots


def _assert_parity_chaotic(spec, ref, jres, fork_frac=0.05):
    """Parity for giga-scale scenarios (>= 4096 hosts), where exact
    per-host agreement is physically unattainable: the fluid queues
    integrate load, so the last-ulp summation-order difference between
    XLA reductions and numpy accumulation forks individual host
    trajectories at ECN thresholds.  With O(100k) flows some queue is
    always sitting on a threshold, so instead of 1e-5 everywhere we
    assert the fork stays *contained*: almost all hosts still agree at
    1e-5, forked hosts stay bounded, and every aggregate metric agrees
    tightly.  (Dense-vs-sparse aggregation and repeated jax runs remain
    bit-identical at this scale — see tests/test_sparse_agg.py — the
    spread here is strictly cross-engine.)"""
    r = np.asarray(ref.mean_goodput)
    j = np.asarray(jres.mean_goodput)
    forked = ~np.isclose(j, r, atol=TOL, rtol=TOL)
    assert forked.mean() <= fork_frac, (
        f"{forked.sum()}/{forked.size} hosts forked "
        f"({forked.mean():.2%} > {fork_frac:.0%})")
    assert np.abs(j - r).max() <= 0.05
    assert abs(j.mean() - r.mean()) <= 1e-3
    comp_diff = np.mean(jres.completion_slot != ref.completion_slot)
    assert comp_diff <= fork_frac
    np.testing.assert_allclose(jres.total_goodput, ref.total_goodput,
                               rtol=2e-2,
                               atol=1e-3 * len(r))
    # Instantaneous last-slot link utilization is the most fork-exposed
    # observable: one forked host's CC rate moves its whole link, so we
    # bound the spread (fraction + p99 + mean), not the worst link.
    util_diff = np.abs(np.asarray(jres.util_up_last)
                       - np.asarray(ref.util_up_last))
    assert (util_diff > TOL).mean() <= 3 * fork_frac
    assert np.quantile(util_diff, 0.99) <= 0.01
    assert util_diff.mean() <= 1e-3
    assert jres.groups == ref.groups
    np.testing.assert_array_equal(jres.group_of, ref.group_of)
    c = compile_scenario(spec)
    m_ref = distill_metrics(spec, c, ref)
    m_jx = distill_metrics(spec, c, jres)
    for t in m_ref.tenant_mean:
        assert m_jx.tenant_mean[t] == pytest.approx(m_ref.tenant_mean[t],
                                                    abs=1e-3)
        assert m_jx.tenant_p01[t] == pytest.approx(m_ref.tenant_p01[t],
                                                   abs=2e-2)
        assert m_jx.tenant_p99[t] == pytest.approx(m_ref.tenant_p99[t],
                                                   abs=2e-2)
    assert m_jx.isolation_index == pytest.approx(m_ref.isolation_index,
                                                 abs=1e-2)
    # recovery_slots: tuple of (start_slot, kind, slots_to_recover);
    # a forked trajectory may shift the recovery detection by a slot.
    assert len(m_jx.recovery_slots) == len(m_ref.recovery_slots)
    for (s_j, k_j, n_j), (s_r, k_r, n_r) in zip(m_jx.recovery_slots,
                                                m_ref.recovery_slots):
        assert (s_j, k_j) == (s_r, k_r)
        if n_j is None or n_r is None:
            assert n_j == n_r
        else:
            assert abs(n_j - n_r) <= 2


# ---------------------------------------------------------------------------
# tier-1: routing x nic cross on representative scenarios (reduced slots)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("routing", ["ar", "war", "ecmp"])
@pytest.mark.parametrize("nic", ["spx", "dcqcn", "global", "esr", "swlb"])
def test_parity_routing_nic_cross(routing, nic):
    spec = get_scenario("flap_during_incast").with_sim(
        slots=160, routing=routing, nic=nic)
    ref, jres = _run_both(spec)
    _assert_parity(spec, ref, jres)


@pytest.mark.parametrize("routing", ["ar", "war", "ecmp"])
@pytest.mark.parametrize("nic", ["spx", "dcqcn", "global", "esr", "swlb"])
def test_parity_fat_tree_routing_nic_cross(routing, nic):
    """Fat-tree twin of the routing x nic cross: core-tier faults plus
    random two-stage failures on the 3-tier testbed.  The numpy
    fat-tree step mirrors the jx pair-aggregated op order, so parity
    holds at machine precision even where AR's symmetric fractions park
    queues on quantization-bin edges."""
    from dataclasses import replace

    from repro.scenarios import FaultSpec
    spec = get_scenario("ft_core_failure_resiliency")
    spec = replace(spec, faults=spec.faults + (
        FaultSpec("random_fail", start_slot=60, frac=0.15),
        FaultSpec("link_kill", start_slot=45, leaf=0, spine=1),))
    spec = spec.with_sim(slots=160, routing=routing, nic=nic)
    ref, jres = _run_both(spec)
    _assert_parity(spec, ref, jres)


def test_parity_swlb_delayed_exclusion():
    """swlb's software-timescale plane exclusion (pending_fail firing)
    must match: run fig12 long enough for the delayed reaction."""
    spec = get_scenario("fig12_plane_flap").with_sim(nic="swlb",
                                                     slots=2000)
    ref, jres = _run_both(spec)
    _assert_parity(spec, ref, jres)


@pytest.mark.parametrize("name,kw", [
    ("fig9_victim_noise", dict(slots=120)),           # two tenants, AR
    ("fig12_plane_flap", dict()),                     # 4 planes, probe loss
    ("cascading_spine_loss", dict(slots=200)),        # WAR + cascade
    ("allreduce_under_random_failures", dict()),      # finite transfers
    ("straggler_failure_compound", dict(slots=200)),  # compound faults
])
def test_parity_representative(name, kw):
    spec = get_scenario(name).with_sim(**kw) if kw else get_scenario(name)
    ref, jres = _run_both(spec)
    _assert_parity(spec, ref, jres)


@pytest.mark.parametrize("routing", ["ecmp", "war"])
@pytest.mark.parametrize("name,mode", [
    ("reroute_random_failures", "backup"),      # leaf-spine backup table
    ("reroute_random_failures", "rehash"),      # post-detect re-draw
    ("reroute_random_failures_ft", "backup"),   # two-stage backup chain
    ("poisson_flap_storm", "backup"),           # flap storm + reaction
])
def test_parity_reaction(name, mode, routing):
    """Reaction-layer parity: the lagged visible-topology twin, the
    blackhole accumulator, and the backup/rehash reassignments must all
    agree across backends — including the new blackhole_timeline and
    reaction_slots observables."""
    from dataclasses import replace

    spec = get_scenario(name)
    spec = replace(spec, reaction=replace(spec.reaction, mode=mode))
    spec = spec.with_sim(slots=200, routing=routing)
    ref, jres = _run_both(spec)
    assert ref.blackhole_timeline is not None
    _assert_parity(spec, ref, jres)


def test_every_registry_scenario_has_static_timeline():
    for name in list_scenarios():
        spec = get_scenario(name)
        assert has_static_timeline(spec)
        tl = compile_fault_timeline(spec)
        assert tl.up.shape[0] == spec.sim.slots


def test_dynamic_event_closures_rejected():
    import dataclasses
    spec = get_scenario("fig8_bisection")
    bogus = dataclasses.replace(spec, faults=(lambda t, topo: None,))
    with pytest.raises(ValueError, match="dynamic"):
        compile_fault_timeline(bogus)


# ---------------------------------------------------------------------------
# slow: full-length parity over the whole registry + batched sweeps
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("routing", ["ar", "war", "ecmp"])
@pytest.mark.parametrize("nic", ["spx", "dcqcn"])
@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_parity_full_registry_cross(name, routing, nic):
    """The acceptance claim verbatim: every registry scenario, full
    length, across ar|war|ecmp x spx|dcqcn, within 1e-5 in float64.
    Giga-scale scenarios (>= 4096 hosts) use the contained-fork
    criterion instead — see _assert_parity_chaotic."""
    spec = get_scenario(name).with_sim(routing=routing, nic=nic)
    ref, jres = _run_both(spec)
    n_hosts = spec.topo.n_leaves * spec.topo.hosts_per_leaf
    if n_hosts >= 4096:
        _assert_parity_chaotic(spec, ref, jres)
    else:
        _assert_parity(spec, ref, jres)


@pytest.mark.slow
@pytest.mark.parametrize("routing", ["ar", "ecmp"])
def test_parity_batched_sweep_matches_serial(routing):
    grid = SweepGrid(seeds=(0, 1, 2), routings=(routing,), slots=150)
    with enable_x64():
        serial = sweep("fig9_victim_noise", grid, processes=1)
        batched = sweep("fig9_victim_noise", grid, backend="jax")
    assert [m.to_row() for m in serial] == [m.to_row() for m in batched]
