"""Unified experiment API: override paths, axis combinators, columnar
ResultSet round-trips (NaN and tuple-valued columns included), the
content-hashed run cache (hit / miss / corrupted entry / resume after an
interrupt), sweep_many parity, and the fig11 benchmark migration."""
import json
import math
import os

import numpy as np
import pytest

from repro.experiments import (Axis, Experiment, OverridePathError,
                               ResultSet, RunCache, apply_override, chain,
                               get_experiment, get_path, product,
                               run_experiment, spec_key, zip_axes)
from repro.experiments import execute as execute_mod
from repro.scenarios import (FaultSpec, ScenarioMetrics, ScenarioSpec,
                             SimSpec, SweepGrid, TenantSpec, TopologySpec,
                             WorkloadSpec, get_scenario, sweep_many)
from repro.scenarios.registry import fig11_partial_uplink


def _tiny(name="tiny", slots=40, **sim) -> ScenarioSpec:
    return ScenarioSpec(
        name=name,
        topo=TopologySpec(n_leaves=2, n_spines=2, hosts_per_leaf=2),
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("all2all"),),
        faults=(FaultSpec("link_kill", start_slot=10, plane=0, leaf=0,
                          spine=0, frac=0.5),),
        sim=SimSpec(slots=slots, **sim))


def _metric(**kw) -> ScenarioMetrics:
    base = dict(scenario="s", seed=0, routing="ar", nic="spx",
                mean_goodput=0.5, tenant_mean={"main": 0.5},
                tenant_p01={"main": 0.4}, tenant_p99={"main": 0.6},
                isolation_index=1.0,
                recovery_slots=((10, "link_kill", 3),),
                completion_tail=float("nan"), symmetry_cv=0.1,
                symmetry_uniform=True, symmetry_outliers=((0, 1),),
                extra={"x": 1.5})
    base.update(kw)
    return ScenarioMetrics(**base)


# ---------------------------------------------------------------------------
# override paths
# ---------------------------------------------------------------------------

def test_apply_override_nested_paths():
    spec = _tiny()
    s2 = apply_override(spec, "sim.routing", "ecmp")
    assert s2.sim.routing == "ecmp" and spec.sim.routing == "ar"
    s3 = apply_override(spec, "faults[0].frac", 0.25)
    assert s3.faults[0].frac == 0.25
    s4 = apply_override(spec, "topo.n_planes", 4)
    assert s4.topo.n_planes == 4
    # int -> float promotion at a float leaf
    s5 = apply_override(spec, "faults[0].frac", 1)
    assert s5.faults[0].frac == 1.0 and isinstance(s5.faults[0].frac,
                                                   float)
    # whole-tuple override
    s6 = apply_override(spec, "faults", ())
    assert s6.faults == ()
    assert get_path(spec, "faults[0].frac") == 0.5


def test_override_unknown_field_lists_known():
    with pytest.raises(OverridePathError, match="no field 'routinggg'"):
        apply_override(_tiny(), "sim.routinggg", "ar")
    with pytest.raises(OverridePathError, match="known fields"):
        apply_override(_tiny(), "nonsense", 1)


def test_override_index_errors():
    with pytest.raises(OverridePathError, match="out of range"):
        apply_override(_tiny(), "faults[2].frac", 0.1)
    with pytest.raises(OverridePathError, match="not a sequence"):
        apply_override(_tiny(), "sim[0]", 1)


def test_override_type_mismatch():
    with pytest.raises(OverridePathError, match="expected int"):
        apply_override(_tiny(), "topo.n_planes", 2.5)
    with pytest.raises(OverridePathError, match="expected str"):
        apply_override(_tiny(), "sim.routing", 3)
    with pytest.raises(OverridePathError, match="expected float"):
        apply_override(_tiny(), "faults[0].frac", "half")
    # bool is not an acceptable int (it's a subclass, but means a flag)
    with pytest.raises(OverridePathError, match="expected int, got bool"):
        apply_override(_tiny(), "sim.slots", True)


def test_override_malformed_paths():
    for bad in ("", "sim..routing", "faults[x].frac", "sim.routing[", "1ab"):
        with pytest.raises(OverridePathError):
            apply_override(_tiny(), bad, 1)


# ---------------------------------------------------------------------------
# axes
# ---------------------------------------------------------------------------

def test_product_order_last_axis_fastest():
    g = product(Axis("a", (1, 2)), Axis("b", ("x", "y")))
    labels = [tuple(l for _, _, l in pt) for pt in g.points()]
    assert labels == [(1, "x"), (1, "y"), (2, "x"), (2, "y")]


def test_zip_and_chain():
    z = zip_axes(Axis("a", (1, 2)), Axis("b", ("x", "y")))
    assert [tuple(l for _, _, l in pt) for pt in z.points()] \
        == [(1, "x"), (2, "y")]
    with pytest.raises(ValueError, match="equal-length"):
        zip_axes(Axis("a", (1, 2)), Axis("b", ("x",))).points()
    c = chain(Axis("a", (1,)), Axis("b", (2, 3)))
    assert len(c.points()) == 3
    assert c.paths() == ("a", "b")


def test_duplicate_path_in_product_raises():
    with pytest.raises(ValueError, match="more than once"):
        product(Axis("a", (1,)), Axis("a", (2,))).points()


def test_axis_label_validation():
    with pytest.raises(ValueError, match="labels"):
        Axis("a", (1, 2), labels=(1,))
    with pytest.raises(ValueError, match="no values"):
        Axis("a", ())


# ---------------------------------------------------------------------------
# ResultSet round-trips and queries
# ---------------------------------------------------------------------------

def _toy_resultset() -> ResultSet:
    rs = ResultSet(coord_names=["faults[0].frac", "topo.n_planes"])
    for i, (frac, planes) in enumerate(
            [(0.1, 1), (0.1, 2), (0.2, 1), (0.2, 2)]):
        rs.append(_metric(seed=i, mean_goodput=0.5 + 0.1 * i,
                          completion_tail=(float("nan") if i % 2
                                           else 1.5)),
                  coords={"faults[0].frac": frac,
                          "topo.n_planes": planes})
    return rs


def test_resultset_json_roundtrip_nan_and_tuples():
    rs = _toy_resultset()
    rs2 = ResultSet.from_json(rs.to_json())
    assert len(rs2) == 4
    assert rs2.coord_names == rs.coord_names
    assert rs2.column("axis.faults[0].frac") == [0.1, 0.1, 0.2, 0.2]
    a, b = rs.to_metrics(), rs2.to_metrics()
    for ma, mb in zip(a, b):
        assert ma.to_row() == mb.to_row()
        assert mb.recovery_slots == ((10, "link_kill", 3),)
        assert mb.symmetry_outliers == ((0, 1),)
        assert mb.extra == {"x": 1.5}
    assert math.isnan(b[1].completion_tail)
    assert b[0].completion_tail == 1.5


def test_resultset_csv_roundtrip_lossless():
    rs = _toy_resultset()
    rs2 = ResultSet.from_csv(rs.to_csv())
    assert rs2.coord_names == rs.coord_names
    assert rs2.column("axis.topo.n_planes") == [1, 2, 1, 2]
    for ma, mb in zip(rs.to_metrics(), rs2.to_metrics()):
        # exact float round-trip, tuple columns reconstructed
        assert ma.mean_goodput == mb.mean_goodput
        assert ma.recovery_slots == mb.recovery_slots
        assert (math.isnan(mb.completion_tail)
                if math.isnan(ma.completion_tail)
                else ma.completion_tail == mb.completion_tail)


def test_resultset_schema_version_checked():
    rs = _toy_resultset()
    d = json.loads(rs.to_json())
    d["schema_version"] = 999
    with pytest.raises(ValueError, match="schema version"):
        ResultSet.from_json(json.dumps(d))


def test_resultset_queries():
    rs = _toy_resultset()
    assert len(rs.filter(**{"axis.faults[0].frac": 0.1})) == 2
    assert len(rs.filter(lambda r: r["mean_goodput"] > 0.65)) == 2
    groups = rs.group_by("axis.topo.n_planes")
    assert set(groups) == {(1,), (2,)}
    piv = rs.pivot("axis.faults[0].frac", "axis.topo.n_planes",
                   "mean_goodput")
    assert piv[0.1][1] == pytest.approx(0.5)
    assert piv[0.2][2] == pytest.approx(0.8)
    s = rs.summary(values=("mean_goodput",))[()]
    assert s["mean_goodput"]["count"] == 4
    assert s["mean_goodput"]["mean"] == pytest.approx(0.65)
    with pytest.raises(KeyError, match="unknown column"):
        rs.filter(nonexistent=1)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_spec_key_content_sensitivity():
    a = spec_key(_tiny())
    assert a == spec_key(_tiny())
    assert a != spec_key(apply_override(_tiny(), "faults[0].frac", 0.9))
    assert a != spec_key(_tiny(), salt="derive.tag")


def test_new_topology_fault_fields_elide_from_cache_keys():
    """Migration contract (ISSUE 5): the fat-tree schema extension must
    not re-key pre-existing cache entries — `TopologySpec`'s and
    `FaultSpec`'s new fields are omitted from the canonical form while
    they hold their defaults, and appear once set."""
    from repro.experiments.cache import canonicalize
    from repro.scenarios import TopologySpec

    t = canonicalize(TopologySpec())["fields"]
    for field in ("kind", "n_pods", "n_aggs", "n_cores", "core_link_cap"):
        assert field not in t, field
    ft = canonicalize(TopologySpec(kind="fat_tree", n_pods=2, n_aggs=2,
                                   n_cores=4))["fields"]
    assert ft["kind"] == "fat_tree" and ft["n_pods"] == 2
    f = canonicalize(FaultSpec("link_kill", leaf=1))["fields"]
    assert "pod" not in f and "core" not in f
    fc = canonicalize(FaultSpec("core_kill", pod=1, core=2))["fields"]
    assert fc["pod"] == 1 and fc["core"] == 2


def test_cache_hit_miss_and_corruption(tmp_path):
    cache = RunCache(str(tmp_path))
    key = spec_key(_tiny())
    assert cache.get(key) is None
    cache.put(key, _tiny(), _metric())
    m = cache.get(key)
    assert m is not None and m.to_row() == _metric().to_row()
    # corrupted entry -> miss, not a crash
    with open(cache.path_for(key), "w") as f:
        f.write("{not json")
    assert cache.get(key) is None
    # version-skewed entry -> miss
    cache.put(key, _tiny(), _metric())
    with open(cache.path_for(key)) as f:
        entry = json.load(f)
    entry["cache_version"] = 999
    with open(cache.path_for(key), "w") as f:
        json.dump(entry, f)
    assert cache.get(key) is None
    # key-mismatched (moved) entry -> miss
    entry["cache_version"] = 1
    entry["key"] = "0" * 64
    with open(cache.path_for(key), "w") as f:
        json.dump(entry, f)
    assert cache.get(key) is None


def test_experiment_cache_corrupted_entry_recomputed(tmp_path):
    exp = Experiment(name="corrupt", base=_tiny(),
                     axes=Axis("seed", (0, 1, 2)))
    rs = run_experiment(exp, processes=0, cache=str(tmp_path))
    assert (rs.cache_hits, rs.cache_misses) == (0, 3)
    cache = RunCache(str(tmp_path))
    key = spec_key(exp.points()[1].spec)
    with open(cache.path_for(key), "w") as f:
        f.write("garbage")
    rs2 = run_experiment(exp, processes=0, cache=str(tmp_path))
    assert (rs2.cache_hits, rs2.cache_misses) == (2, 1)
    assert [m.to_row() for m in rs.to_metrics()] \
        == [m.to_row() for m in rs2.to_metrics()]


def test_resume_after_interrupt(tmp_path, monkeypatch):
    """An interrupt mid-grid loses only in-flight points: completed rows
    are already in the cache, and the re-run serves them as hits."""
    exp = Experiment(name="resume", base=_tiny(),
                     axes=Axis("seed", (0, 1, 2, 3)))
    real = execute_mod.run_point
    calls = {"n": 0}

    def dying_run_point(spec, derive=None):
        if calls["n"] == 2:
            raise KeyboardInterrupt("simulated interrupt")
        calls["n"] += 1
        return real(spec, derive)

    monkeypatch.setattr(execute_mod, "run_point", dying_run_point)
    with pytest.raises(KeyboardInterrupt):
        run_experiment(exp, processes=0, cache=str(tmp_path))
    monkeypatch.setattr(execute_mod, "run_point", real)
    rs = run_experiment(exp, processes=0, cache=str(tmp_path))
    assert (rs.cache_hits, rs.cache_misses) == (2, 2)
    assert len(rs) == 4
    # rows land in grid order regardless of the cache/live split
    assert rs.column("seed") == [s.sim.seed + off for s, off in
                                 [(_tiny(), o) for o in (0, 1, 2, 3)]]


# ---------------------------------------------------------------------------
# parity with the deprecated sweep API
# ---------------------------------------------------------------------------

def test_scenario_axis_after_overrides_rejected():
    # a late 'scenario' axis would discard the nic override while its
    # coordinate still labels the row — must refuse, not mislabel
    exp = Experiment(
        name="bad_order", base="fig9_victim_noise",
        axes=product(Axis("sim.nic", ("dcqcn",)),
                     Axis("scenario", ("fig8_bisection",))))
    with pytest.raises(ValueError, match="must come before"):
        exp.points()
    # without a base, the first override already has nothing to act on
    with pytest.raises(ValueError, match="no base scenario"):
        Experiment(
            name="no_base",
            axes=product(Axis("sim.nic", ("dcqcn",)),
                         Axis("scenario", ("fig8_bisection",)))).points()


def test_run_experiment_rejects_unknown_backend():
    exp = Experiment(name="b", base=_tiny(), axes=Axis("seed", (0,)))
    with pytest.raises(ValueError, match="unknown backend"):
        run_experiment(exp, backend="npy")


def test_experiment_reproduces_sweep_many_rows_exactly():
    names = ("multi_tenant_50_50", "permutation_stress")
    grid = SweepGrid(seeds=(0, 1), routings=("ar", "ecmp"),
                     nics=("spx", "dcqcn"), slots=40)
    legacy = sweep_many(names, grid, processes=0)
    exp = Experiment(
        name="parity",
        axes=product(Axis("scenario", names), Axis("seed", (0, 1)),
                     Axis("sim.routing", ("ar", "ecmp")),
                     Axis("sim.nic", ("spx", "dcqcn")),
                     Axis("sim.slots", (40,))))
    rs = run_experiment(exp, processes=0)
    assert len(rs) == len(legacy) == 16
    assert [m.to_row() for m in rs.to_metrics()] \
        == [m.to_row() for m in legacy]


# ---------------------------------------------------------------------------
# non-(routing, nic) axes end-to-end on both backends
# ---------------------------------------------------------------------------

def test_nonrouting_axis_runs_on_both_backends():
    exp = Experiment(
        name="frac_x_backend", base=_tiny(),
        axes=product(Axis("faults[0].frac", (0.5, 1.0)),
                     Axis("sim.backend", ("numpy", "jax"))))
    rs = run_experiment(exp, processes=0)
    assert len(rs) == 4
    assert rs.column("axis.sim.backend") == ["numpy", "jax"] * 2
    by_backend = rs.group_by("axis.sim.backend")
    for (frac,), grp in rs.group_by("axis.faults[0].frac").items():
        vals = grp.column("mean_goodput")
        assert np.isfinite(vals).all()
        # numpy and jax agree on the same point (f32 tolerance)
        assert vals[0] == pytest.approx(vals[1], abs=5e-3)
    # the axis had an effect
    piv = rs.pivot("axis.faults[0].frac", "axis.sim.backend",
                   "symmetry_cv")
    assert piv[0.5]["numpy"] != piv[1.0]["numpy"]
    assert set(by_backend) == {("numpy",), ("jax",)}


# ---------------------------------------------------------------------------
# fig11 benchmark migration: row-identical numbers
# ---------------------------------------------------------------------------

def test_fig11_experiment_matches_legacy_loop():
    from repro.experiments.library import fig11_metrics
    keep = 0.5
    legacy = {}
    base = fig11_partial_uplink(keep)
    for nic, routing in (("dcqcn", "ecmp"), ("spx", "war")):
        from repro.scenarios import run_scenario
        r = run_scenario(base.with_sim(nic=nic, routing=routing))
        per_rank = r.mean_goodput.reshape(48, -1).sum(1)
        legacy[nic] = (float(per_rank.mean()),
                       float(r.mean_goodput.min() * 47))
    exp = get_experiment("fig11_static_resiliency")
    rows = run_experiment(exp).filter(**{"axis.faults": 50}).rows()
    assert len(rows) == 2
    for row in rows:
        want = legacy[row["nic"]]
        assert (row["extra"]["bw_frac"],
                row["extra"]["cct_gated_bw"]) == want


# ---------------------------------------------------------------------------
# DSL additions backing the fig14/fig15 migrations
# ---------------------------------------------------------------------------

def test_one2many_workload_compiles():
    from repro.scenarios.compile import compile_scenario
    spec = ScenarioSpec(
        name="o2m",
        topo=TopologySpec(n_leaves=2, n_spines=2, hosts_per_leaf=4),
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("one2many", srcs=2, demand=0.5),),
        sim=SimSpec(slots=20))
    c = compile_scenario(spec)
    assert len(c.flows) == 2 * 6            # 2 srcs x 6 dsts
    assert c.flows[0].demand == pytest.approx(0.5 / 6)
    with pytest.raises(ValueError, match="srcs >= 1"):
        ScenarioSpec(
            name="bad", topo=spec.topo, tenants=spec.tenants,
            workloads=(WorkloadSpec("one2many", srcs=0),),
            sim=spec.sim).validate()


def test_random_fail_count_mode_kills_exactly_k():
    from repro.scenarios.compile import compile_scenario
    spec = ScenarioSpec(
        name="countk",
        topo=TopologySpec(n_leaves=4, n_spines=4, hosts_per_leaf=2),
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("all2all"),),
        faults=(FaultSpec("random_fail", start_slot=0, count=3,
                          frac=1.0),),
        sim=SimSpec(slots=4))
    c = compile_scenario(spec)
    c.events(0, c.topo)
    dead = int((c.topo.up[0] == 0).sum())
    assert 1 <= dead <= 3                   # draws may repeat
    with pytest.raises(ValueError, match="count applies only"):
        ScenarioSpec(
            name="bad", topo=spec.topo, tenants=spec.tenants,
            workloads=spec.workloads,
            faults=(FaultSpec("link_kill", count=2),),
            sim=spec.sim).validate()
