"""Core (paper-mechanism) unit tests: planes, PLB, CC, AR, failover."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DcqcnConfig, FailoverController, PlaneConfig,
                        SpxCCConfig, apportion, concurrent_failure_pmf,
                        dcqcn_update, effective_bandwidth,
                        elastic_mesh_plan, plane_loads, spx_cc_update)
from repro.core.adaptive_routing import (ar_scores, ecmp_select, jsq_select,
                                         spray_fractions)
from repro.core.plb import (plb_init, plb_update, plane_weights,
                            select_plane)


def test_apportion_exact_and_zero_weight():
    a = apportion(np.array([1.0, 1.0, 0.0, 1.0]), 16)
    assert a.shape == (16,)
    loads = plane_loads(a, 4, 1.0)
    assert loads[2] == 0.0
    assert loads.sum() == 16


def test_effective_bandwidth_slowest_plane_gates():
    w = np.array([0.25, 0.25, 0.25, 0.25])
    a = apportion(w, 16)
    assert effective_bandwidth(w, a, np.ones(4)) == 1.0
    # one plane at 10% rate drags the whole transfer
    slow = effective_bandwidth(w, a, np.array([1, 1, 1, 0.1]))
    assert slow < 0.5


def test_plb_two_stage_selection():
    st = plb_init(4)
    st.rate_allow = jnp.array([1.0, 0.1, 1.0, 1.0])
    st.local_queue = jnp.array([0.5, 0.0, 0.2, 0.6])
    # plane 1 is rate-filtered despite the shallowest queue
    picks = [int(select_plane(st, jax.random.PRNGKey(i), tx_rate=0.25))
             for i in range(20)]
    assert 1 not in picks
    assert set(picks) <= {0, 2, 3}
    assert max(set(picks), key=picks.count) == 2    # shallowest eligible


def test_plb_probe_timeout_excludes_and_recovers():
    cfg = PlaneConfig(n_planes=4, probe_timeout=3)
    st = plb_init(4)
    down = jnp.array([True, True, False, True])
    for _ in range(3):
        st = plb_update(st, jnp.full(4, 6.0), jnp.zeros(4),
                        down.astype(jnp.float32), down,
                        jnp.zeros(4), cfg)
    w = np.asarray(plane_weights(st))
    assert w[2] < 1e-3 and abs(w.sum() - 1) < 1e-5
    # plane heals -> re-included with ramped rate
    up = jnp.ones(4, bool)
    st = plb_update(st, jnp.full(4, 6.0), jnp.zeros(4),
                    jnp.ones(4), up, jnp.zeros(4), cfg)
    assert bool(st.eligible[2])
    assert float(st.rate_allow[2]) >= 0.5


def test_spx_cc_only_cuts_on_ecn():
    r = jnp.full(4, 0.8)
    # no ECN, low RTT -> additive increase
    r2 = spx_cc_update(r, jnp.full(4, 6.0), jnp.zeros(4))
    assert bool((r2 > r).all())
    # ECN -> multiplicative decrease
    r3 = spx_cc_update(r, jnp.full(4, 6.0), jnp.ones(4))
    assert bool((r3 < r).all())
    assert bool((r3 >= SpxCCConfig().min_rate).all())


def test_dcqcn_slow_recovery_vs_spx():
    r_spx = r_dcq = jnp.array([0.3])
    alpha = jnp.array([0.5])
    for _ in range(20):
        r_spx = spx_cc_update(r_spx, jnp.array([6.0]), jnp.zeros(1))
        r_dcq, alpha = dcqcn_update(r_dcq, alpha, jnp.zeros(1))
    assert float(r_spx[0]) > float(r_dcq[0])   # SPX recovers faster


def test_jsq_prefers_shallow_and_skips_down():
    q = jnp.array([0.9, 0.1, 0.5, 0.2])
    up = jnp.array([True, False, True, True])
    picks = [int(jsq_select(q, up, jax.random.PRNGKey(i)))
             for i in range(20)]
    assert 1 not in picks
    assert max(set(picks), key=picks.count) == 3


def test_weighted_ar_shifts_from_degraded():
    q = jnp.zeros(4)
    up = jnp.ones(4, bool)
    w = jnp.array([1.0, 1.0, 0.25, 1.0])
    fr = spray_fractions(q, up, w, temperature=0.5)
    assert float(fr[2]) < float(fr[0])


def test_ecmp_rehash_on_failure():
    up = jnp.array([True, True, False, True])
    ports = ecmp_select(jnp.arange(100), up)
    assert 2 not in np.asarray(ports)
    assert set(np.unique(np.asarray(ports))) <= {0, 1, 3}


def test_failover_controller_recovery_within_budget():
    cfg = PlaneConfig(n_planes=4, probe_timeout=3)
    fc = FailoverController(cfg)
    for _ in range(3):
        fc.on_step()
    fc.fail_plane(1)
    for _ in range(6):
        w = fc.on_step()
    rec = fc.records[0]
    assert rec.recovery_steps is not None
    assert rec.recovery_steps <= cfg.probe_timeout + cfg.recovery_steps
    assert w[1] < 1e-3


def test_concurrent_failure_pmf_normalized():
    p = concurrent_failure_pmf(10, 10, max_k=10)
    assert abs(p.sum() - 1) < 1e-9
    assert p[1] > p[5]      # ~1.7 expected concurrent failures


def test_elastic_mesh_plan():
    assert elastic_mesh_plan(256, 16) == (16, 16)
    assert elastic_mesh_plan(240, 16) == (15, 16)
    assert elastic_mesh_plan(512, 16, pods=2) == (2, 16, 16)
