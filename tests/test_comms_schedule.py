"""Collective-schedule co-simulation (`repro.comms`).

Pins the schedule compiler end to end:

  * golden phase tables for one dense (llama3-8b, dp4/pp2) and one MoE
    (phi3.5-moe, dp4/tp2) plan — window widths, phase counts, per-phase
    byte totals and flow counts;
  * hypothesis property: total scheduled (closed-transfer) bytes are
    invariant under the fabric plane count and under permutations of the
    tenant host order (DP-peer relabeling);
  * the flap resiliency signature: a plane flap during the DP sync
    window inflates the derived step time by a pinned margin and the
    post-heal step recovers within a pinned budget — on both backends;
  * megabatch: a seed grid over one schedule scenario is ONE dispatch
    and ONE compile;
  * satellite regressions: `workloads.all2all` emits the full ordered
    pair set (the historical dead-loop produced none), the analytic CCT
    helpers match their closed forms, and `stream_report` is
    dtype-aware with a 4-byte fallback for shape-only leaves.
"""
import math
import types

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.comms import plan_schedule, sim_bytes
from repro.comms.lower import lower_schedule
from repro.core.collectives import stream_report
from repro.core.planes import PlaneConfig
from repro.netsim.topology import LeafSpine
from repro.netsim.workloads import (all2all, all2all_cct_us,
                                    bus_bandwidth_gbps,
                                    ring_collective_cct_us)
from repro.scenarios import (ScenarioSpec, SimSpec, TenantSpec,
                             TopologySpec, WorkloadSpec, compile_scenario,
                             get_scenario)
from repro.scenarios.spec import ScheduleSpec

TOL = 1e-5


def _steps(c, res):
    """Derived per-step completion times for the first schedule."""
    sched = c.schedules[0]
    return sched.step_times(np.asarray(res.completion_slot),
                            c.spec.sim.slots)


# ---------------------------------------------------------------------------
# golden phase tables (dense + MoE)
# ---------------------------------------------------------------------------

def test_dense_plan_windows_and_phase_table():
    c = compile_scenario(get_scenario("train_step_baseline"))
    assert len(c.schedules) == 1
    s = c.schedules[0]
    assert (s.model, s.dp, s.tp, s.pp, s.n_ranks) == (
        "llama3-8b", 4, 1, 2, 8)
    # Window skeleton pinned: any byte-accounting drift lands here.
    assert (s.w_fwd, s.w_bwd, s.w_sync, s.pad) == (11, 22, 28, 2)
    assert s.step_period == 63
    assert s.step_starts == (0, 63, 126)
    # 3 steps x (fwd, bwd, sync) + one ckpt after step 2 (ckpt_every=2)
    names = [(p.name, p.step) for p in s.phases]
    assert names == [("fwd", 0), ("bwd", 0), ("sync", 0),
                     ("fwd", 1), ("bwd", 1), ("sync", 1), ("ckpt", 1),
                     ("fwd", 2), ("bwd", 2), ("sync", 2)]
    by = {(p.name, p.step): p for p in s.phases}
    # dense model: no a2a bytes in the fwd phase
    assert by[("fwd", 0)].n_flows == 0
    assert by[("fwd", 0)].sim_bytes == 0.0
    # DP sync: one ring stream per rank, 2(D-1)/D of the grad shard
    ar = sim_bytes(2.0 * 3 / 4 * (s.grad_bytes_real / 2), 1.0, 100.0)
    sync = by[("sync", 0)]
    assert sync.n_flows == 8
    assert sync.sim_bytes == pytest.approx(8 * ar)
    assert sync.start_slot == 33 and sync.stop_slot == 61
    # step-1 sync window [96, 124) is what the registry flap targets
    assert by[("sync", 1)].start_slot == 96
    assert by[("sync", 1)].stop_slot == 124
    ck = by[("ckpt", 1)]
    assert ck.n_flows == 8
    assert ck.sim_bytes == pytest.approx(
        8 * sim_bytes(s.grad_bytes_real / 2, 1.0, 100.0))
    # every step's completion set is the 8 sync streams (ckpt excluded)
    assert all(len(ix) == 8 for ix in s.step_flows)


def test_moe_plan_windows_and_phase_table():
    c = compile_scenario(get_scenario("train_step_flap_moe"))
    s = c.schedules[0]
    assert (s.model, s.dp, s.tp, s.pp, s.n_ranks) == (
        "phi3.5-moe-42b-a6.6b", 4, 2, 1, 8)
    assert (s.w_fwd, s.w_bwd, s.w_sync, s.pad) == (27, 54, 40, 2)
    assert s.step_period == 123
    assert s.step_starts == (0, 123, 246)
    assert [p.name for p in s.phases] == ["fwd", "bwd", "sync"] * 3
    by = {(p.name, p.step): p for p in s.phases}
    # EP all2all: ordered pairs within each DP group, per TP member
    fwd = by[("fwd", 0)]
    assert fwd.n_flows == 2 * 4 * 3            # tp * dp * (dp-1)
    assert fwd.sim_bytes > 0
    # total = per-rank a2a volume x all 8 ranks
    assert fwd.sim_bytes == pytest.approx(
        8 * sim_bytes(s.a2a_bytes_real, 1.0, 100.0))
    assert by[("sync", 1)].start_slot == 204   # registry flap window
    assert by[("sync", 1)].stop_slot == 244
    # completion set: 24 a2a exchanges + 8 sync streams per step
    assert all(len(ix) == 32 for ix in s.step_flows)


def test_schedule_plan_rejects_short_horizon():
    ss = ScheduleSpec(model="llama3-8b", dp=4, pp=2, line_rate_gbps=1.0)
    with pytest.raises(ValueError, match="slots"):
        plan_schedule(ss, slot_us=100.0, slots=10)


def test_schedule_spec_validation():
    with pytest.raises(ValueError, match="dp >= 2"):
        ScheduleSpec(dp=1).validate("x")
    topo = TopologySpec(n_leaves=2, n_spines=2, hosts_per_leaf=2)
    with pytest.raises(ValueError):        # 8 ranks > 4 hosts
        ScenarioSpec(
            name="x", topo=topo,
            workloads=(WorkloadSpec("schedule",
                                    schedule=ScheduleSpec(dp=8)),),
            sim=SimSpec(slots=400)).validate()
    with pytest.raises(ValueError, match="schedule"):
        ScenarioSpec(
            name="x", topo=topo,
            workloads=(WorkloadSpec("allreduce",
                                    schedule=ScheduleSpec()),),
            sim=SimSpec(slots=40)).validate()
    with pytest.raises(ValueError, match="schedule"):
        ScenarioSpec(
            name="x", topo=topo,
            workloads=(WorkloadSpec("schedule"),),
            sim=SimSpec(slots=40)).validate()


def test_phase_mult_lane_layout():
    """Lane 0 is always-on; fwd/bwd lanes tile the compute windows and
    never overlap; the compute lane is their union."""
    c = compile_scenario(get_scenario("train_step_baseline"))
    pm = c.phase_mult
    s = c.schedules[0]
    assert pm.shape == (c.spec.sim.slots, 4)
    assert (pm[:, 0] == 1.0).all()
    assert not np.any((pm[:, 1] > 0) & (pm[:, 2] > 0))
    np.testing.assert_array_equal(pm[:, 3],
                                  np.maximum(pm[:, 1], pm[:, 2]))
    t0 = s.step_starts[1]
    assert (pm[t0:t0 + s.w_fwd, 1] == 1.0).all()
    assert (pm[t0 + s.w_fwd:t0 + s.w_fwd + s.w_bwd, 2] == 1.0).all()
    # sync + pad windows: no pulsed compute traffic
    assert (pm[t0 + s.w_fwd + s.w_bwd:t0 + s.step_period, 1:] == 0).all()


# ---------------------------------------------------------------------------
# property: scheduled bytes invariant under plane count / host relabeling
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container without hypothesis: the
    HAVE_HYPOTHESIS = False  # deterministic sweep below still runs

if HAVE_HYPOTHESIS:
    SCHED = st.builds(
        ScheduleSpec,
        model=st.sampled_from(["llama3-8b", "phi3.5-moe-42b-a6.6b"]),
        dp=st.integers(2, 4), tp=st.integers(1, 2), pp=st.integers(1, 2),
        steps=st.integers(1, 2), microbatches=st.sampled_from([2, 4]),
        tokens_per_rank=st.sampled_from([256, 512]),
        line_rate_gbps=st.just(1.0),
        ckpt_every=st.integers(0, 2))


def _closed_bytes(flows):
    return sorted(f.bytes_total for f in flows
                  if math.isfinite(f.bytes_total))


def _lower(ss, n_planes, hosts=None):
    topo = TopologySpec(n_leaves=4, n_spines=2, hosts_per_leaf=4,
                        n_planes=n_planes)
    plan = plan_schedule(ss, 100.0, 10 ** 9, n_planes=n_planes)
    sim = SimSpec(slots=ss.steps * plan.step_period, slot_us=100.0)
    w = WorkloadSpec("schedule", schedule=ss)
    if hosts is None:
        hosts = list(range(ss.n_ranks))
    return lower_schedule(w, hosts, topo, sim, "main")


def _check_bytes_invariant(ss, planes, seed):
    fl1, pm1, s1 = _lower(ss, n_planes=1)
    flp, pmp, sp = _lower(ss, n_planes=planes)
    # plane count changes gradient chunking, never total volume
    assert _closed_bytes(flp) == pytest.approx(_closed_bytes(fl1))
    assert sp.grad_bytes_real == pytest.approx(s1.grad_bytes_real)
    np.testing.assert_array_equal(pmp, pm1)
    # DP-peer relabeling (host permutation) preserves the byte multiset,
    # the flow count, and the phase table
    rng = np.random.default_rng(seed)
    perm = [int(h) for h in rng.permutation(ss.n_ranks)]
    flh, pmh, sh = _lower(ss, n_planes=1, hosts=perm)
    assert len(flh) == len(fl1)
    assert _closed_bytes(flh) == pytest.approx(_closed_bytes(fl1))
    assert sh.phases == s1.phases
    # phase table accounts exactly for the closed bytes scheduled
    assert sum(p.sim_bytes for p in s1.phases) == pytest.approx(
        sum(_closed_bytes(fl1)))
    assert sum(p.n_flows for p in s1.phases) == len(_closed_bytes(fl1))


if HAVE_HYPOTHESIS:
    @given(ss=SCHED, planes=st.integers(2, 8),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=15, deadline=None)
    def test_scheduled_bytes_invariant(ss, planes, seed):
        _check_bytes_invariant(ss, planes, seed)


@pytest.mark.parametrize("ss,planes,seed", [
    (ScheduleSpec(model="llama3-8b", dp=4, tp=1, pp=2, steps=2,
                  line_rate_gbps=1.0, tokens_per_rank=512,
                  ckpt_every=1), 4, 0),
    (ScheduleSpec(model="llama3-8b", dp=2, tp=2, pp=2, steps=1,
                  line_rate_gbps=1.0, tokens_per_rank=256), 8, 1),
    (ScheduleSpec(model="phi3.5-moe-42b-a6.6b", dp=4, tp=2, pp=1,
                  steps=2, line_rate_gbps=1.0,
                  tokens_per_rank=512), 3, 2),
    (ScheduleSpec(model="phi3.5-moe-42b-a6.6b", dp=3, tp=1, pp=2,
                  steps=1, line_rate_gbps=1.0, tokens_per_rank=256,
                  ckpt_every=1), 2, 3),
])
def test_scheduled_bytes_invariant_fixed(ss, planes, seed):
    """Deterministic anchor for the invariance property (always runs,
    even where hypothesis is unavailable)."""
    _check_bytes_invariant(ss, planes, seed)


# ---------------------------------------------------------------------------
# flap resiliency signature (numpy tier-1; jax parity below)
# ---------------------------------------------------------------------------

def test_baseline_steps_are_steady():
    c = compile_scenario(get_scenario("train_step_baseline"))
    stp = _steps(c, c.run(backend="numpy"))
    assert stp.shape == (3,)
    # uncongested: every step completes at the same offset
    assert np.ptp(stp) == 0.0
    assert stp[0] <= c.schedules[0].step_period


def test_flap_inflates_step_time_and_recovers():
    cb = compile_scenario(get_scenario("train_step_baseline"))
    base = _steps(cb, cb.run(backend="numpy"))
    cf = compile_scenario(get_scenario("train_step_flap"))
    flap = _steps(cf, cf.run(backend="numpy"))
    # step 0 is pre-fault: identical to baseline
    assert flap[0] == base[0]
    # the flap hits step 1's sync window: pinned inflation margin
    assert flap[1] / flap[0] >= 1.2
    # step 2 (post-heal) recovers within the pinned budget
    assert flap[2] / flap[0] <= 1.1


def test_flap_moe_signature():
    c = compile_scenario(get_scenario("train_step_flap_moe"))
    stp = _steps(c, c.run(backend="numpy"))
    assert stp[1] / stp[0] >= 1.2
    assert stp[2] / stp[0] <= 1.1


# ---------------------------------------------------------------------------
# backend parity + megabatch single-compile
# ---------------------------------------------------------------------------

def test_schedule_backend_parity():
    spec = get_scenario("train_step_flap")
    with enable_x64():
        c = compile_scenario(spec)
        ref = c.run(backend="numpy")
        jres = c.run(backend="jax")
    np.testing.assert_array_equal(jres.completion_slot,
                                  ref.completion_slot)
    np.testing.assert_allclose(jres.mean_goodput, ref.mean_goodput,
                               atol=TOL, rtol=TOL)
    np.testing.assert_array_equal(_steps(c, jres), _steps(c, ref))


def test_schedule_megabatch_single_compile():
    """A seed grid over one schedule scenario fuses into ONE dispatch
    and ONE compile — the phase timeline must not fragment buckets."""
    from repro.experiments.axes import Axis
    from repro.experiments.execute import execute_points
    from repro.experiments.experiment import Experiment
    from repro.netsim.jx import dispatch_stats, reset_dispatch_stats

    exp = Experiment(name="test_comms.smoke", base="train_step_flap",
                     axes=Axis("seed", (0, 1)))
    points = [p.spec for p in exp.points()]
    reset_dispatch_stats()
    rows = execute_points(points, backend="jax",
                          jx_dispatch="megabatch")
    stats = dispatch_stats()
    assert stats["dispatches"] == 1
    assert stats["compiles"] == 1
    assert len(rows) == 2


# ---------------------------------------------------------------------------
# satellite regressions: all2all builder, CCT helpers, stream_report
# ---------------------------------------------------------------------------

def test_all2all_emits_full_ordered_pair_set():
    """Regression for the dead loop that yielded zero flows."""
    t = LeafSpine(n_leaves=2, n_spines=2, hosts_per_leaf=4)
    hosts = list(range(6))
    flows = all2all(t, hosts, bytes_per_pair=7.0)
    assert len(flows) == 6 * 5
    assert {(f.src, f.dst) for f in flows} == {
        (a, b) for a in hosts for b in hosts if a != b}
    assert all(f.demand == pytest.approx(1.0 / 5) for f in flows)
    assert all(f.bytes_total == 7.0 for f in flows)


def test_all2all_cct_closed_form():
    # payload = (n-1)/n * msg; latency paid once per chunk round
    msg, n, bw, lat = 64e6, 8, 400.0, 10.0
    payload = msg * 7 / 8
    want = payload * 8.0 / (bw * 1e3) + math.ceil(
        payload / (4 << 20)) * lat
    assert all2all_cct_us(msg, n, bw, lat) == pytest.approx(want)
    # sub-chunk message still pays one latency round
    small = all2all_cct_us(1024.0, 4, bw, lat)
    assert small == pytest.approx(1024 * 0.75 * 8 / (bw * 1e3) + lat)


def test_ring_collective_cct_closed_form():
    msg, n, bw, lat = 64e6, 8, 400.0, 10.0
    step = (msg / n) * 8.0 / (bw * 1e3) + lat
    assert ring_collective_cct_us(msg, n, bw, lat) == pytest.approx(
        (n - 1) * step)
    # latency-dominated regime: doubling latency ~doubles CCT
    lo = ring_collective_cct_us(1.0, 8, 400.0, 10.0)
    hi = ring_collective_cct_us(1.0, 8, 400.0, 20.0)
    assert hi / lo == pytest.approx(2.0, rel=1e-3)


def test_bus_bandwidth_normalization():
    msg, n, bw, lat = 64e6, 8, 400.0, 0.0
    cct = all2all_cct_us(msg, n, bw, lat)
    # zero latency, algbw == busbw * n/(n-1) == line rate
    assert bus_bandwidth_gbps(msg, cct, n) == pytest.approx(bw)
    assert bus_bandwidth_gbps(msg, 0.0, n) > 0  # guarded denominator


def test_stream_report_is_dtype_aware():
    import jax.numpy as jnp
    cfg = PlaneConfig(n_planes=2, microchunks=2)
    f32 = {"w": jnp.zeros((64, 8), jnp.float32)}
    bf16 = {"w": jnp.zeros((64, 8), jnp.bfloat16)}
    b32 = stream_report(f32, cfg).chunk_bytes.sum()
    b16 = stream_report(bf16, cfg).chunk_bytes.sum()
    assert b32 == 64 * 8 * 4
    assert b16 == 64 * 8 * 2          # pre-fix: dtype ignored -> 4x8x64
    # shape-only leaves (no dtype attribute) fall back to 4 bytes/elem
    shell = [types.SimpleNamespace(shape=(16, 4))]
    assert stream_report(shell, cfg).chunk_bytes.sum() == 16 * 4 * 4
