"""Sparse (segment-summed) load aggregation vs the dense gather-plan
path, and the compact-carry / fp32-drift contracts.

The sparse path keys `segment_sum` by (plane, link) exactly in flow
order, which on CPU f64 matches the sequential `np.add.at` of the numpy
engine bit-for-bit — so dense-vs-sparse must agree to the same 1e-5 the
numpy↔jax parity suite pins, across both topology kinds and every
routing mode.  Hypothesis drives the shapes/seeds.
"""
import os

import numpy as np
import pytest
from jax.experimental import enable_x64

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # deterministic coverage below still runs
    HAVE_HYPOTHESIS = False

from repro.scenarios.registry import get_scenario
from repro.scenarios.runner import run_point

SETTINGS = dict(max_examples=8, deadline=None)

SCN = {"leaf_spine": "fig12_plane_flap",
       "fat_tree": "ft_core_failure_resiliency"}


def _run_agg(spec, mode):
    prev = os.environ.get("REPRO_JX_AGG")
    os.environ["REPRO_JX_AGG"] = mode
    try:
        return run_point(spec).to_dict()
    finally:
        if prev is None:
            del os.environ["REPRO_JX_AGG"]
        else:
            os.environ["REPRO_JX_AGG"] = prev


def _assert_close(a, b, rtol, path=""):
    assert type(a) is type(b) or (
        isinstance(a, (int, float)) and isinstance(b, (int, float))), path
    if isinstance(a, dict):
        assert a.keys() == b.keys(), path
        for k in a:
            _assert_close(a[k], b[k], rtol, f"{path}.{k}")
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_close(x, y, rtol, f"{path}[{i}]")
    elif isinstance(a, float):
        assert np.isclose(a, b, rtol=rtol, atol=1e-7, equal_nan=True), \
            f"{path}: {a} vs {b}"
    else:
        assert a == b, f"{path}: {a} vs {b}"


def _check_sparse_matches_dense(kind, routing, nic, seed):
    with enable_x64():
        spec = get_scenario(SCN[kind]).with_sim(
            slots=40, routing=routing, nic=nic, seed=seed,
            backend="jax")
        dense = _run_agg(spec, "dense")
        sparse = _run_agg(spec, "sparse")
    _assert_close(dense, sparse, rtol=1e-5)


@pytest.mark.parametrize("kind", ["leaf_spine", "fat_tree"])
@pytest.mark.parametrize("routing", ["ar", "war", "ecmp"])
def test_sparse_matches_dense_x64(kind, routing):
    """Deterministic cross: both topology kinds x every routing mode."""
    _check_sparse_matches_dense(kind, routing, "dcqcn", 0)


if HAVE_HYPOTHESIS:
    @settings(**SETTINGS)
    @given(kind=st.sampled_from(["leaf_spine", "fat_tree"]),
           routing=st.sampled_from(["ar", "war", "ecmp"]),
           nic=st.sampled_from(["spx", "dcqcn", "esr"]),
           seed=st.integers(0, 3))
    def test_sparse_matches_dense_x64_property(kind, routing, nic, seed):
        _check_sparse_matches_dense(kind, routing, nic, seed)


def test_sparse_matches_numpy_engine_x64():
    """Under x64 the sparse segment-sum is flow-ordered like the numpy
    engine's `np.add.at`, so it must hit the full cross-backend parity
    tolerance too — not just agree with the dense jax path."""
    with enable_x64():
        spec = get_scenario("fig12_plane_flap").with_sim(
            slots=40, routing="war", nic="dcqcn", backend="jax")
        sparse = _run_agg(spec, "sparse")
        ref = run_point(spec.with_sim(backend="numpy")).to_dict()
    _assert_close(ref, sparse, rtol=1e-5)


def test_compact_carry_bit_identical_f32():
    """REPRO_JX_COMPACT only narrows the probe counter to int8; the
    saturating bump (`min(miss+1, probe_timeout)`) is applied in both
    paths, so f32 results are bit-identical, not merely close."""
    spec = get_scenario("fig12_plane_flap").with_sim(
        slots=40, routing="ar", nic="esr", backend="jax")
    base = run_point(spec).to_dict()
    prev = os.environ.get("REPRO_JX_COMPACT")
    os.environ["REPRO_JX_COMPACT"] = "1"
    try:
        compact = run_point(spec).to_dict()
    finally:
        if prev is None:
            del os.environ["REPRO_JX_COMPACT"]
        else:
            os.environ["REPRO_JX_COMPACT"] = prev
    _assert_close(base, compact, rtol=0.0)


def test_f32_carry_drift_vs_f64_bounded():
    """Parity mode off (f32 carry) is the large-scale production
    configuration; pin how far its headline metrics may drift from the
    f64 reference so a silently-catastrophic precision regression (e.g.
    accumulating goodput in f16, or the old un-clamped probe counter
    overflowing) fails loudly."""
    spec = get_scenario("fig12_plane_flap").with_sim(
        slots=60, routing="war", nic="dcqcn", backend="jax")
    f32 = run_point(spec)
    with enable_x64():
        f64 = run_point(spec)
    assert f32.mean_goodput == pytest.approx(f64.mean_goodput, rel=1e-3)
    assert f32.isolation_index == pytest.approx(f64.isolation_index,
                                                rel=1e-3, abs=1e-6)
    # open-loop scenario: no finite transfers, so the tail is NaN in
    # both precisions — anything else is a drift bug
    assert np.isnan(f32.completion_tail) == np.isnan(f64.completion_tail)
    if not np.isnan(f64.completion_tail):
        assert f32.completion_tail == pytest.approx(
            f64.completion_tail, rel=1e-3, abs=1e-6)
