"""Failure-reaction layer: detection latency, precomputed fast-reroute,
and flap-storm behavior.

Covers the reaction subsystem end to end:
  * `ReactionSpec` validation — every named error message;
  * `backup_path_table` is a single J-cycle on both fabric kinds (the
    `backup_reassign` chain walk relies on it);
  * the §6.6 Poisson flap schedule is seed-pinned (both backends replay
    the identical event list, so the pin guards the draw order);
  * `reaction=None` and `mode='instant'` reproduce the pre-reaction
    engine bit-identically and share one compiled JAX program;
  * the megabatch path fuses a whole mode x detect reaction grid into
    one launch and one compile;
  * the acceptance signature: backup failover closes its blackhole
    window within detect_slots of the fault while rehash stays dark
    >= 10x longer, at <= 1.10x p50 completion inflation (§6.4's "7%
    at 10% failures" operating point).
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.netsim.topology import backup_path_table
from repro.scenarios import compile_scenario, get_scenario
from repro.scenarios.spec import (FaultSpec, ReactionSpec, ScenarioSpec,
                                  SimSpec, TenantSpec, TopologySpec,
                                  WorkloadSpec, reaction_lag)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _spec(**kw):
    base = dict(
        name="react_test",
        topo=TopologySpec(n_leaves=4, n_spines=4, hosts_per_leaf=2,
                          n_planes=1),
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("allreduce", bytes_total=40.0),),
        faults=(FaultSpec("random_fail", start_slot=40, frac=0.25),),
        sim=SimSpec(slots=331, seed=3, routing="ecmp"))
    base.update(kw)
    return ScenarioSpec(**base)


# ---------------------------------------------------------------------------
# validation — every named error
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("reaction,msg", [
    (ReactionSpec(mode="flood"), "unknown reaction mode"),
    (ReactionSpec(detect_slots=-1), "reaction delays must be >= 0"),
    (ReactionSpec(converge_slots=-2), "reaction delays must be >= 0"),
    (ReactionSpec(detect_slots=3, mode="instant"),
     "reaction mode 'instant' requires zero"),
])
def test_reaction_validation_errors(reaction, msg):
    with pytest.raises(ValueError, match=msg):
        _spec(reaction=reaction).validate()


def test_reaction_rejects_straggler_faults():
    spec = _spec(
        faults=(FaultSpec("straggler", start_slot=10, stop_slot=50,
                          host=1, frac=0.5, plane=-1),),
        reaction=ReactionSpec(detect_slots=2, mode="backup"))
    with pytest.raises(ValueError, match="incompatible with fault kinds"):
        spec.validate()


@pytest.mark.parametrize("fault,msg", [
    (FaultSpec("poisson_flap", start_slot=0, flaps_per_min=0.0,
               down_slots=4), "flaps_per_min > 0"),
    (FaultSpec("poisson_flap", start_slot=0, flaps_per_min=100.0,
               down_slots=0), "down_slots >= 1"),
    (FaultSpec("link_kill", start_slot=0, leaf=0, spine=0,
               flaps_per_min=5.0),
     "apply only to poisson_flap"),
])
def test_poisson_flap_validation_errors(fault, msg):
    with pytest.raises(ValueError, match=msg):
        _spec(faults=(fault,)).validate()


def test_reaction_lag_by_mode():
    assert reaction_lag(None, "ecmp") == 0
    assert reaction_lag(ReactionSpec(), "ecmp") == 0
    assert reaction_lag(ReactionSpec(detect_slots=3, mode="backup",
                                     converge_slots=60), "ecmp") == 3
    assert reaction_lag(ReactionSpec(detect_slots=3, mode="rehash",
                                     converge_slots=60), "war") == 63


# ---------------------------------------------------------------------------
# backup tables — one full J-cycle per fabric kind
# ---------------------------------------------------------------------------

def _cycle_len(table):
    j, seen = 0, 0
    while True:
        j = int(table[j])
        seen += 1
        if j == 0:
            return seen


@pytest.mark.parametrize("n_paths", [2, 4, 8, 16])
def test_leaf_spine_backup_table_is_full_cycle(n_paths):
    t = backup_path_table("leaf_spine", n_paths)
    assert sorted(t) == list(range(n_paths))       # permutation
    assert _cycle_len(t) == n_paths                # single cycle


@pytest.mark.parametrize("n_paths,cpa", [(8, 2), (8, 4), (12, 3), (6, 1)])
def test_fat_tree_backup_table_is_full_cycle(n_paths, cpa):
    t = backup_path_table("fat_tree", n_paths, cores_per_agg=cpa)
    assert sorted(t) == list(range(n_paths))
    assert _cycle_len(t) == n_paths
    # next-agg-first: a non-wrapping core falls over to the core with
    # the same offset under the next agg
    assert t[0] == cpa % n_paths or n_paths <= cpa


# ---------------------------------------------------------------------------
# §6.6 Poisson flap schedule — seed-pinned replay
# ---------------------------------------------------------------------------

def test_poisson_flap_schedule_pinned():
    from repro.scenarios.compile import poisson_flap_schedule
    spec = get_scenario("poisson_flap_storm")
    sched = poisson_flap_schedule(spec, 0)
    assert len(sched) == 17
    assert sched[:3] == ((60, 72, 0, 10), (60, 72, 0, 29),
                         (67, 79, 0, 17))
    for dn, up, plane, link in sched:
        assert up - dn == spec.faults[0].down_slots
        assert dn >= spec.faults[0].start_slot
        assert 0 <= plane < spec.topo.n_planes
        assert 0 <= link < spec.topo.n_leaves * spec.topo.n_spines


def test_poisson_flap_schedule_respects_stop_slot():
    from repro.scenarios.compile import poisson_flap_schedule
    spec = get_scenario("poisson_flap_storm")
    stopped = replace(
        spec, faults=(replace(spec.faults[0], stop_slot=100),))
    sched = poisson_flap_schedule(stopped, 0)
    assert sched and all(dn < 100 for dn, _, _, _ in sched)


# ---------------------------------------------------------------------------
# bit-identity + compile sharing: reaction=None == mode='instant'
# ---------------------------------------------------------------------------

def test_instant_is_bit_identical_and_shares_program():
    from repro.netsim.jx.engine import collect_dispatch
    none_spec = _spec()
    inst_spec = _spec(reaction=ReactionSpec())
    r_none = compile_scenario(none_spec).run(backend="jax")
    with collect_dispatch() as ctr:
        r_inst = compile_scenario(inst_spec).run(backend="jax")
    # an instant reaction lowers to the exact same compiled program:
    # 0 new compiles and byte-identical outputs
    assert ctr.snapshot()["compiles"] == 0
    np.testing.assert_array_equal(r_inst.mean_goodput, r_none.mean_goodput)
    np.testing.assert_array_equal(r_inst.completion_slot,
                                  r_none.completion_slot)
    np.testing.assert_array_equal(r_inst.total_goodput,
                                  r_none.total_goodput)
    assert r_none.blackhole_timeline is None
    assert r_inst.blackhole_timeline is None

    # numpy backend: same bit-identity contract
    n_none = compile_scenario(none_spec).run(backend="numpy")
    n_inst = compile_scenario(inst_spec).run(backend="numpy")
    np.testing.assert_array_equal(n_inst.mean_goodput, n_none.mean_goodput)
    np.testing.assert_array_equal(n_inst.completion_slot,
                                  n_none.completion_slot)


# ---------------------------------------------------------------------------
# megabatch: a reaction grid fuses into one launch + one compile
# ---------------------------------------------------------------------------

def test_megabatch_reaction_grid_single_launch():
    from repro.netsim.jx.engine import collect_dispatch
    from repro.netsim.jx.megabatch import run_megabatch
    grid = [
        _spec(name=f"mb-react-{mode}-{det}",
              reaction=ReactionSpec(detect_slots=det, mode=mode,
                                    converge_slots=12))
        for mode in ("backup", "rehash") for det in (1, 3)]
    pts = [compile_scenario(s) for s in grid]
    with collect_dispatch() as ctr:
        res = run_megabatch(pts)
    stats = ctr.snapshot()
    assert stats["dispatches"] == 1
    assert stats["compiles"] <= 1          # 0 when another test warmed it
    # rows match the per-scenario path, blackhole column included
    for s, r in zip(grid, res):
        ref = compile_scenario(s).run(backend="jax")
        np.testing.assert_allclose(r.total_goodput, ref.total_goodput,
                                   rtol=1e-5, atol=1e-8)
        np.testing.assert_array_equal(r.completion_slot,
                                      ref.completion_slot)
        np.testing.assert_allclose(r.blackhole_timeline,
                                   ref.blackhole_timeline,
                                   rtol=1e-5, atol=1e-8)


# ---------------------------------------------------------------------------
# acceptance signature — backup vs rehash at the §6.4 operating point
# ---------------------------------------------------------------------------

def _registry_variant(mode, frac, detect=4, slots=280):
    spec = get_scenario("reroute_random_failures").with_sim(slots=slots)
    spec = replace(spec, faults=(replace(spec.faults[0], frac=frac),),
                   reaction=replace(spec.reaction, mode=mode,
                                    detect_slots=detect))
    return spec


def test_backup_beats_rehash_reaction_window():
    from repro.scenarios.runner import distill_metrics
    runs = {}
    for mode in ("backup", "rehash"):
        spec = _registry_variant(mode, frac=0.10)
        c = compile_scenario(spec)
        runs[mode] = (spec, c, c.run())
    m_b = distill_metrics(*runs["backup"])
    m_r = distill_metrics(*runs["rehash"])
    det = runs["backup"][0].reaction.detect_slots
    # backup recovers within detect_slots (+3 slack); rehash stays dark
    # detect + converge — >= 10x slower at the registry defaults
    assert 0 < m_b.reaction_slots <= det + 3
    assert m_r.reaction_slots >= 10 * m_b.reaction_slots
    assert m_r.blackholed_bytes > m_b.blackholed_bytes > 0


def test_backup_completion_inflation_bounded():
    def p50(spec):
        res = compile_scenario(spec).run()
        comp = res.completion_slot[res.completion_slot >= 0]
        assert comp.size
        return float(np.median(comp))

    clean = p50(_registry_variant("backup", frac=0.0))
    faulted = p50(_registry_variant("backup", frac=0.10))
    # §6.4: ~7% completion inflation at 10% link failures — the backup
    # policy keeps the p50 within 1.10x of the clean fabric
    assert faulted <= 1.10 * clean
    # and rehash completions never beat backup at the same detection
    rehash = p50(_registry_variant("rehash", frac=0.10))
    assert faulted <= rehash
