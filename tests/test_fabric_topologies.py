"""Tier-generic fabric unit anchors (ISSUE 5): the fat-tree's exact
min-cut max-flow values, stage-composed path capacities, tier-aware
fault injection, and the plane-summing generalization of
`maxflow_matrix`/`leaf_pair_maxflow`.  The hypothesis property suite
over both topology kinds lives in `test_topology_properties.py`.
"""
import numpy as np

from repro.netsim.topology import FatTree, LeafSpine, leaf_pair_maxflow, \
    maxflow_matrix

# ---------------------------------------------------------------------------
# unit anchors: exact min-cut values
# ---------------------------------------------------------------------------

def _ft() -> FatTree:
    return FatTree(n_pods=2, leaves_per_pod=2, n_aggs=2, n_cores=8,
                   hosts_per_leaf=4, link_cap=2.0, core_link_cap=1.0)


def test_fat_tree_healthy_maxflow():
    t = _ft()
    mf = maxflow_matrix(t)
    # intra-pod (0,1): 2 aggs x 2.0; cross-pod (0,2): per agg
    # min(leaf link 2.0, bundle 4x1.0) = 2.0 -> same 4.0
    assert np.allclose(mf, 4.0)
    assert np.allclose(mf, mf.T)


def test_fat_tree_core_kill_binds_only_when_bundle_below_leaf_link():
    t = _ft()
    t.fail_core_link(0, 0, 0)
    mf = maxflow_matrix(t)
    # bundle 4 -> 3 still >= the 2.0 leaf-agg link: nothing binds
    assert np.allclose(mf, 4.0)
    for c in (1, 2):
        t.fail_core_link(0, 0, c)
    mf = maxflow_matrix(t)
    # agg 0's pod-0 bundle is now 1.0 < 2.0: cross-pod pairs touching
    # pod 0 lose exactly 1.0; intra-pod pairs are untouched
    assert mf[0, 1] == 4.0 and mf[2, 3] == 4.0
    assert mf[0, 2] == 3.0 and mf[1, 3] == 3.0
    assert leaf_pair_maxflow(t, 2, 0) == 3.0


def test_fat_tree_agg_loss_kills_leaf_and_core_links():
    t = _ft()
    t.fail_agg(0, 0, 0)
    assert (t.up[0, :2, 0] == 0).all() and (t.down[0, 0, :2] == 0).all()
    assert (t.up2[0, 0, :4] == 0).all()          # agg 0's cores
    assert (t.up2[0, 0, 4:] == 1.0).all()        # agg 1's untouched
    # intra-pod pod-0 pairs: one agg left; cross-pod via agg 1 only
    mf = maxflow_matrix(t)
    assert mf[0, 1] == 2.0 and mf[0, 2] == 2.0 and mf[2, 3] == 4.0


def test_leaf_spine_maxflow_sums_planes():
    t = LeafSpine(n_leaves=4, n_spines=4, hosts_per_leaf=2, n_planes=3)
    assert maxflow_matrix(t)[0, 1] == 12.0           # 3 planes x 4 spines
    assert maxflow_matrix(t, plane=0)[0, 1] == 4.0   # old per-plane view
    t.fail_uplink(2, 0, 0)
    assert leaf_pair_maxflow(t, 0, 1) == 11.0
    assert leaf_pair_maxflow(t, 0, 1, plane=2) == 3.0


def test_path_capacity_composes_stages():
    t = _ft()
    src = np.array([0, 0])
    dst = np.array([1, 2])                           # intra-pod, cross-pod
    cap = t.path_capacity(src, dst)                  # (F, P, J)
    assert cap.shape == (2, 1, 8)
    assert (cap[0, 0] == 2.0).all()                  # leaf links bind
    assert (cap[1, 0] == 1.0).all()                  # core links bind
    t.fail_core_link(0, 1, 5)
    cap = t.path_capacity(src, dst)
    assert cap[0, 0, 5] == 2.0                       # intra-pod unaffected
    assert cap[1, 0, 5] == 0.0                       # cross-pod path dead
