"""Hypothesis property tests for the scenario compiler and the JAX
fault-timeline compiler.

Invariants:
  * `resolve_tenants` partitions the host set exactly — tenants are
    pairwise disjoint, in range, and a trailing 'remainder' tenant makes
    the union cover every host.
  * `compile_fault_timeline` is consistent with the callback-driven
    path: on random `FaultSpec` schedules the dense multiplier timeline
    equals (slot by slot) the capacities `make_events`'s closure leaves
    on a mutated `LeafSpine`, and multipliers are always non-negative.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property tests skipped")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.netsim.jx.events import compile_fault_timeline  # noqa: E402
from repro.netsim.topology import LeafSpine  # noqa: E402
from repro.scenarios import (FaultSpec, ScenarioSpec, SimSpec,  # noqa: E402
                             TenantSpec, TopologySpec, WorkloadSpec)
from repro.scenarios.compile import (compile_scenario,  # noqa: E402
                                     make_events, resolve_tenants)

SETTINGS = dict(max_examples=40, deadline=None)

TOPO = st.builds(
    TopologySpec,
    n_leaves=st.integers(2, 4), n_spines=st.integers(2, 4),
    hosts_per_leaf=st.integers(2, 4), n_planes=st.integers(1, 3))


# ---------------------------------------------------------------------------
# tenant placement partitions hosts
# ---------------------------------------------------------------------------

@st.composite
def _tenant_lists(draw):
    """Random but overlap-free layouts: an interleave head OR a run of
    blocks from host 0, then random tenants (which draw from the
    still-unassigned pool, so they never clash), then a 'remainder'."""
    topo = draw(TOPO)
    n = topo.n_hosts
    tenants, budget = [], n - 1     # leave >= 1 host for the remainder
    if draw(st.booleans()):
        stride = draw(st.integers(2, 4))
        offset = draw(st.integers(0, stride - 1))
        avail = len(range(offset, n, stride))
        take = draw(st.integers(1, max(1, min(avail, budget))))
        tenants.append(TenantSpec("iv", placement="interleave",
                                  offset=offset, stride=stride,
                                  n_hosts=take))
        budget -= take
    else:
        offset = 0
        for i in range(draw(st.integers(0, 2))):
            if budget <= 0:
                break
            take = draw(st.integers(1, budget))
            tenants.append(TenantSpec(f"b{i}", placement="block",
                                      offset=offset, n_hosts=take))
            offset += take
            budget -= take
    for i in range(draw(st.integers(0, 2))):
        if budget <= 0:
            break
        take = draw(st.integers(1, budget))
        tenants.append(TenantSpec(f"r{i}", placement="random",
                                  n_hosts=take))
        budget -= take
    tenants.append(TenantSpec("rest", placement="remainder"))
    return topo, tuple(tenants)


@given(data=_tenant_lists(), seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_tenant_placements_partition_hosts(data, seed):
    topo, tenants = data
    spec = ScenarioSpec(
        name="prop", topo=topo, tenants=tenants,
        workloads=(WorkloadSpec("allreduce", tenant="rest"),),
        workload_seed=seed)
    placed = resolve_tenants(spec, np.random.default_rng(seed))
    all_hosts = [h for hosts in placed.values() for h in hosts]
    # pairwise disjoint and in range...
    assert len(all_hosts) == len(set(all_hosts))
    assert all(0 <= h < topo.n_hosts for h in all_hosts)
    # ...and the trailing remainder makes it a full partition
    assert set(all_hosts) == set(range(topo.n_hosts))
    # declared sizes honored
    for t in tenants:
        if t.n_hosts is not None:
            assert len(placed[t.name]) == t.n_hosts


# ---------------------------------------------------------------------------
# compiled timelines == callback-driven mutations, slot by slot
# ---------------------------------------------------------------------------

def _fault_strategy(topo: TopologySpec, slots: int):
    planes = st.integers(-1, topo.n_planes - 1)
    start = st.integers(0, slots - 1)
    stop = st.one_of(st.none(), st.integers(1, slots + 10))
    frac = st.sampled_from([0.25, 0.5, 1.0])
    leaf = st.integers(0, topo.n_leaves - 1)
    spine = st.integers(0, topo.n_spines - 1)
    host = st.integers(0, topo.n_hosts - 1)
    period = st.integers(1, slots)
    return st.one_of(
        st.builds(FaultSpec, kind=st.just("link_kill"), start_slot=start,
                  stop_slot=stop, plane=planes, leaf=leaf, spine=spine,
                  frac=frac),
        st.builds(FaultSpec, kind=st.just("link_flap"), start_slot=start,
                  stop_slot=stop, period=period,
                  duty=st.sampled_from([0.25, 0.5, 0.9]), plane=planes,
                  leaf=leaf, spine=spine, frac=frac),
        st.builds(FaultSpec, kind=st.just("access_kill"),
                  start_slot=start, stop_slot=stop, plane=planes,
                  host=host),
        st.builds(FaultSpec, kind=st.just("access_flap"),
                  start_slot=start, stop_slot=stop, period=period,
                  duty=st.sampled_from([0.25, 0.5]), plane=planes,
                  host=host),
        st.builds(FaultSpec, kind=st.just("cascade"), start_slot=start,
                  period=period,
                  spines=st.lists(spine, min_size=1, max_size=3,
                                  unique=True).map(tuple),
                  plane=planes),
        st.builds(FaultSpec, kind=st.just("straggler"), start_slot=start,
                  stop_slot=stop, plane=planes, host=host, frac=frac),
        st.builds(FaultSpec, kind=st.just("leaf_trim"), start_slot=start,
                  plane=planes, leaf=leaf, frac=frac),
        st.builds(FaultSpec, kind=st.just("random_fail"),
                  start_slot=start, frac=st.sampled_from([0.1, 0.5])),
        st.builds(FaultSpec, kind=st.just("random_fail"),
                  start_slot=start, plane=planes,
                  frac=st.sampled_from([0.5, 1.0]),
                  count=st.integers(1, 3)),
        # fleet rate scaled to the tiny (<= 40-slot, 10 us) horizon so
        # the Poisson draw actually lands a handful of flaps
        st.builds(FaultSpec, kind=st.just("poisson_flap"),
                  start_slot=start, plane=planes,
                  flaps_per_min=st.sampled_from([2e5, 2e6]),
                  down_slots=st.integers(1, 8),
                  frac=st.sampled_from([0.5, 1.0])),
    )


@st.composite
def _fault_specs(draw):
    topo = draw(TOPO)
    slots = draw(st.integers(4, 40))
    faults = draw(st.lists(_fault_strategy(topo, slots), min_size=0,
                           max_size=3))
    seed = draw(st.integers(0, 2 ** 16))
    return ScenarioSpec(
        name="prop_faults", topo=topo,
        workloads=(WorkloadSpec("pairs", pairs=((0, topo.n_hosts - 1),)),),
        faults=tuple(faults), sim=SimSpec(slots=slots),
        workload_seed=seed).validate()


@given(spec=_fault_specs())
@settings(**SETTINGS)
def test_timeline_matches_callback_mutations(spec):
    tl = compile_fault_timeline(spec)
    assert (tl.up >= 0).all() and (tl.down >= 0).all() \
        and (tl.access >= 0).all()
    events, _ = make_events(spec)
    topo = LeafSpine(
        n_leaves=spec.topo.n_leaves, n_spines=spec.topo.n_spines,
        hosts_per_leaf=spec.topo.hosts_per_leaf,
        n_planes=spec.topo.n_planes)
    for t in range(spec.sim.slots):
        events(t, topo)
        np.testing.assert_allclose(
            tl.up[t] * spec.topo.uplink_cap, topo.up, rtol=0, atol=1e-12,
            err_msg=f"uplinks diverge at slot {t}")
        np.testing.assert_allclose(
            tl.down[t] * spec.topo.uplink_cap, topo.down, rtol=0,
            atol=1e-12, err_msg=f"downlinks diverge at slot {t}")
        np.testing.assert_allclose(
            tl.access[t] * spec.topo.access_cap, topo.access, rtol=0,
            atol=1e-12, err_msg=f"access diverges at slot {t}")


@given(spec=_fault_specs())
@settings(max_examples=15, deadline=None)
def test_timeline_change_slots_are_sound(spec):
    """`change_slots` must list slot 0 plus exactly the slots where the
    fabric differs from the previous slot (the ECMP re-hash replay and
    the batched sweep rely on this)."""
    tl = compile_fault_timeline(spec)
    changes = tl.change_slots()
    assert changes[0] == 0
    assert changes == sorted(set(changes))
    for t in range(1, spec.sim.slots):
        changed = not (np.array_equal(tl.up[t], tl.up[t - 1])
                       and np.array_equal(tl.down[t], tl.down[t - 1])
                       and np.array_equal(tl.access[t], tl.access[t - 1]))
        assert (t in changes) == changed


# ---------------------------------------------------------------------------
# failure-reaction invariants (numpy backend)
# ---------------------------------------------------------------------------

@st.composite
def _reaction_cases(draw):
    """Small ECMP scenarios with exactly-k link kills (k < n_spines, so
    the backup chain always reaches an alive path and no residual
    blackholing survives the reaction — which makes the window algebra
    below exact, not statistical)."""
    topo = draw(st.builds(
        TopologySpec,
        n_leaves=st.integers(2, 3), n_spines=st.integers(3, 4),
        hosts_per_leaf=st.just(2), n_planes=st.integers(1, 2)))
    slots = draw(st.integers(40, 60))
    start = draw(st.integers(8, 20))
    fault = FaultSpec("random_fail", start_slot=start, frac=1.0,
                      count=draw(st.integers(1, 2)), plane=-1)
    detect = draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2 ** 10))
    return topo, slots, fault, detect, seed


def _run_reaction(topo, slots, fault, seed, reaction):
    from repro.scenarios.spec import ReactionSpec  # noqa: F401
    spec = ScenarioSpec(
        name="prop_react", topo=topo,
        workloads=(WorkloadSpec("all2all"),),
        faults=(fault,), reaction=reaction,
        sim=SimSpec(slots=slots, routing="ecmp", seed=seed),
        workload_seed=seed).validate()
    return compile_scenario(spec).run()


@given(case=_reaction_cases())
@settings(max_examples=10, deadline=None)
def test_reaction_blackhole_invariants(case):
    from repro.scenarios.spec import ReactionSpec
    topo, slots, fault, detect, seed = case
    args = (topo, slots, fault, seed)
    none = _run_reaction(*args, None)
    instant = _run_reaction(*args, ReactionSpec())
    backup = _run_reaction(
        *args, ReactionSpec(detect_slots=detect, mode="backup"))
    backup_late = _run_reaction(
        *args, ReactionSpec(detect_slots=detect + 2, mode="backup"))
    rehash = _run_reaction(
        *args, ReactionSpec(detect_slots=detect, mode="rehash",
                            converge_slots=6))

    # mode='instant' reproduces no-reaction bit-identically
    np.testing.assert_array_equal(instant.mean_goodput, none.mean_goodput)
    np.testing.assert_array_equal(instant.completion_slot,
                                  none.completion_slot)

    # no traffic is blackholed before the fault exists
    for r in (backup, backup_late, rehash):
        bh = np.asarray(r.blackhole_timeline)
        assert (bh[:fault.start_slot] == 0).all()
        assert (bh >= 0).all()

    # slower detection can only blackhole more...
    assert backup_late.blackhole_timeline.sum() \
        >= backup.blackhole_timeline.sum()
    # ...and rehash (detect + converge dark) at least as much as backup
    # (dark only until detection) at the same detection latency
    assert rehash.blackhole_timeline.sum() \
        >= backup.blackhole_timeline.sum()
    # with k < n_spines kills the reaction fully clears the blackhole:
    # nothing is dark once the slowest policy has converged
    last = fault.start_slot + detect + 6
    assert rehash.blackhole_timeline[last + 1:].sum() == 0
    assert backup.blackhole_timeline[fault.start_slot + detect + 1:
                                     ].sum() == 0


def test_compiled_scenario_tenant_partition_concrete():
    """Non-hypothesis anchor: registry scenarios partition all hosts."""
    from repro.scenarios import get_scenario, list_scenarios
    for name in list_scenarios():
        c = compile_scenario(get_scenario(name))
        hosts = [h for hs in c.tenants.values() for h in hs]
        assert len(hosts) == len(set(hosts))
        assert all(0 <= h < c.spec.topo.n_hosts for h in hosts)
