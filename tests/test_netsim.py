"""Network simulator invariants and paper-claim orderings."""
import numpy as np
import pytest

from repro.netsim import (Flow, LeafSpine, all2all, bisection_pairs,
                          jsq_delay_sim, maxflow_matrix, ring_neighbors)
from repro.netsim.sim import SimConfig, run_sim


def _bisect(nic, routing, topo=None, slots=400):
    rng = np.random.default_rng(0)
    t = topo or LeafSpine(n_leaves=4, n_spines=4, hosts_per_leaf=4,
                          n_planes=1)
    flows = bisection_pairs(t, range(t.n_hosts), rng)
    return run_sim(t.copy(), flows,
                   SimConfig(slots=slots, nic=nic, routing=routing,
                             seed=1)), flows


def test_goodput_never_exceeds_demand_or_capacity():
    r, flows = _bisect("spx", "ar")
    assert (r.goodput <= 1.0 + 1e-9).all()
    assert (r.goodput >= -1e-12).all()


def test_ar_beats_ecmp_tail():
    r_eth, _ = _bisect("dcqcn", "ecmp")
    r_spx, _ = _bisect("spx", "ar")
    p01 = lambda r: np.quantile(r.mean_goodput, 0.01)
    assert p01(r_spx) > 0.95                 # ~98% of line rate
    assert p01(r_spx) > p01(r_eth) + 0.2     # ECMP collides


def test_ar_traffic_is_symmetric():
    """§5.1: AR spreads uplink load uniformly across a symmetry group."""
    from repro.core.telemetry import symmetry_check
    r, _ = _bisect("spx", "ar")
    util = r.util_up_last[0]                 # (L, S)
    rep = symmetry_check("leaf0-uplinks", util[0], cv_tol=0.2)
    assert rep.uniform, rep


def test_capacity_proportional_degradation():
    """§6.4: bandwidth tracks remaining capacity under failures (SPX),
    within ~10%."""
    base = LeafSpine(n_leaves=4, n_spines=4, hosts_per_leaf=4, n_planes=1)
    r0, _ = _bisect("spx", "war", base.copy())
    degraded = base.copy()
    degraded.trim_leaf_uplinks(0, 0, 0.5)
    r1, _ = _bisect("spx", "war", degraded)
    # leaf-0 hosts are capped near 0.5; others unaffected
    leaf0 = r1.mean_goodput[:8]
    assert np.mean(r1.mean_goodput) > 0.6
    assert np.mean(r0.mean_goodput) > 0.95


def test_plane_failover_ordering_hw_vs_sw():
    def ev(t, topo):
        if t == 20:
            topo.fail_access(1, 0)

    def recovery(nic, delay_ms, slots):
        t = LeafSpine(n_leaves=2, n_spines=2, hosts_per_leaf=2,
                      n_planes=4, access_cap=0.25)
        r = run_sim(t, [Flow(0, 2, 1.0)],
                    SimConfig(slots=slots, slot_us=100.0, nic=nic,
                              routing="ar", sw_lb_delay_ms=delay_ms,
                              seed=2), events=ev)
        g = r.goodput[:, 0]
        post = np.flatnonzero((np.arange(len(g)) > 20) & (g >= 0.67))
        return post[0] - 20 if len(post) else 10 ** 9

    hw = recovery("spx", 0.0, 200)
    sw = recovery("swlb", 100.0, 2000)
    assert hw <= 5                       # a few RTT-scale slots
    assert sw >= 100                     # software timescale
    assert sw / hw > 50


def test_jsq_delay_queue_growth():
    """Fig 1b: queues grow several-fold from 100ns to 2.5us decision
    delay."""
    q_fast = jsq_delay_sim(n_ports=64, load=0.9, decision_delay_ns=100,
                           slots=8000).mean_queue
    q_slow = jsq_delay_sim(n_ports=64, load=0.9, decision_delay_ns=2500,
                           slots=8000).mean_queue
    assert q_slow > 2.0 * max(q_fast, 0.05)


def test_maxflow_matrix_symmetric_healthy():
    t = LeafSpine(n_leaves=8, n_spines=8, hosts_per_leaf=4)
    mf = maxflow_matrix(t)
    assert np.allclose(mf, mf.T)
    assert np.allclose(mf, mf[0, 1])


def test_global_cc_collapses_under_asymmetry():
    """Fig 15: per-plane CC isolates a degraded plane; global CC does
    not."""
    def bw(nic):
        t = LeafSpine(n_leaves=3, n_spines=2, hosts_per_leaf=8,
                      n_planes=4, parallel_links=8, link_cap=0.25,
                      access_cap=0.25)
        t.trim_leaf_uplinks(2, 1, 0.25)
        t.trim_leaf_uplinks(3, 2, 0.25)
        fl = all2all(t, range(t.n_hosts), group="main")
        r = run_sim(t, fl, SimConfig(slots=300, nic=nic, routing="ar",
                                     seed=3))
        return float(np.mean(r.mean_goodput.reshape(t.n_hosts, -1).sum(1)))

    assert bw("spx") > bw("global") + 0.1


def test_ring_collective_flows_complete():
    t = LeafSpine(n_leaves=4, n_spines=4, hosts_per_leaf=4, n_planes=1)
    fl = ring_neighbors(range(16), bytes_per_hop=20.0)
    r = run_sim(t, fl, SimConfig(slots=300, nic="spx", routing="ar",
                                 seed=4))
    assert (r.completion_slot >= 0).all()
