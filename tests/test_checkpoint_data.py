"""Checkpoint / data-pipeline tests: atomic commit, roundtrip, elastic
restore, restart determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, prune_checkpoints,
                              restore_checkpoint, save_checkpoint)
from repro.data import DataConfig, DataLoader, batch_at


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                  "d": jnp.zeros((3,))},
            "lst": [jnp.ones((2,)), jnp.full((2, 2), 3.0)]}


def test_checkpoint_roundtrip_and_latest():
    with tempfile.TemporaryDirectory() as d:
        t1 = _tree(1)
        save_checkpoint(d, 10, t1, extras={"note": "x"})
        t2 = _tree(2)
        save_checkpoint(d, 20, t2)
        assert latest_step(d) == 20
        restored, step, extras = restore_checkpoint(d, _tree(0))
        assert step == 20
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restore a specific older step
        r1, s1, e1 = restore_checkpoint(d, _tree(0), step=10)
        assert s1 == 10 and e1 == {"note": "x"}


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            restore_checkpoint(d, {"a": jnp.zeros((5,))})


def test_checkpoint_prune_keeps_latest():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(d, s, {"a": jnp.zeros(2)})
        prune_checkpoints(d, keep=2)
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [4, 5]
        assert latest_step(d) == 5


def test_checkpoint_forward_compatible_extra_field():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.ones(3)})
        tgt = {"a": jnp.zeros(3), "new_field": jnp.full((2,), 7.0)}
        restored, _, _ = restore_checkpoint(d, tgt)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.ones(3))
        np.testing.assert_array_equal(np.asarray(restored["new_field"]),
                                      np.full((2,), 7.0))


def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    b1 = batch_at(cfg, 5)
    b2 = batch_at(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # restart from loader state reproduces the stream
    dl = DataLoader(cfg)
    for _ in range(3):
        next(dl)
    state = dl.state()
    a = next(dl)
    dl2 = DataLoader.restore(cfg, state)
    b = next(dl2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_sharding_partitions_batch():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    full = batch_at(cfg, 0)["tokens"]
    parts = [batch_at(cfg, 0, shard=i, n_shards=4)["tokens"]
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_data_tokens_in_vocab():
    cfg = DataConfig(vocab=317, seq_len=64, global_batch=4)
    b = batch_at(cfg, 123)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 317
