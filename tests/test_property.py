"""Hypothesis property tests on system invariants."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property tests skipped")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.collectives import int8_decode, int8_encode
from repro.core.planes import apportion, plane_loads
from repro.core.congestion import spx_cc_update, dcqcn_update
from repro.core.plb import plb_init, plb_update, plane_weights
from repro.core.planes import PlaneConfig

SETTINGS = dict(max_examples=25, deadline=None)


@given(weights=st.lists(st.floats(0.0, 10.0), min_size=1, max_size=8),
       k=st.integers(1, 64))
@settings(**SETTINGS)
def test_apportion_invariants(weights, k):
    w = np.asarray(weights)
    a = apportion(w, k)
    assert a.shape == (k,)
    loads = plane_loads(a, len(weights), 1.0)
    assert int(loads.sum()) == k
    if w.sum() > 0:
        # zero-weight planes receive nothing
        for i, wi in enumerate(w):
            if wi == 0.0:
                assert loads[i] == 0
        # proportionality within 1 chunk (largest remainder method)
        ideal = w / w.sum() * k
        assert np.all(np.abs(loads - ideal) <= 1.0 + 1e-9)


@given(rate=st.lists(st.floats(0.01, 1.0), min_size=1, max_size=8),
       rtt=st.floats(1.0, 100.0), ecn=st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_cc_laws_stay_bounded(rate, rtt, ecn):
    r = jnp.asarray(rate, jnp.float32)
    r2 = spx_cc_update(r, jnp.full_like(r, rtt), jnp.full_like(r, ecn))
    assert bool((r2 >= 0.009).all() and (r2 <= 1.0).all())
    r3, a3 = dcqcn_update(r, jnp.zeros_like(r), jnp.full_like(r, ecn))
    assert bool((r3 >= 0.009).all() and (r3 <= 1.0).all())
    assert bool((a3 >= 0).all() and (a3 <= 1).all())


@given(ecn=st.floats(0.01, 1.0))
@settings(**SETTINGS)
def test_spx_cc_cut_is_monotone_in_ecn(ecn):
    r = jnp.asarray([0.9], jnp.float32)
    low = spx_cc_update(r, jnp.asarray([6.0]), jnp.asarray([ecn * 0.5]))
    high = spx_cc_update(r, jnp.asarray([6.0]), jnp.asarray([ecn]))
    assert float(high[0]) <= float(low[0]) + 1e-7


@given(down=st.lists(st.booleans(), min_size=2, max_size=8))
@settings(**SETTINGS)
def test_plane_weights_normalized_and_exclude_dead(down):
    p = len(down)
    cfg = PlaneConfig(n_planes=p, probe_timeout=2)
    st_ = plb_init(p)
    up = jnp.asarray([not d for d in down])
    for _ in range(3):
        st_ = plb_update(st_, jnp.full(p, 6.0), jnp.zeros(p),
                         up.astype(jnp.float32), up, jnp.zeros(p), cfg)
    w = np.asarray(plane_weights(st_))
    assert abs(w.sum() - 1.0) < 1e-5
    assert (w >= -1e-9).all()
    if any(not d for d in down):
        for i, d in enumerate(down):
            if d:
                assert w[i] < 1e-3


@given(rows=st.integers(1, 8), cols=st.integers(1, 64),
       scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2 ** 20))
@settings(**SETTINGS)
def test_int8_codec_error_bounded(rows, cols, scale, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, cols)) * scale
    q, s = int8_encode(x, jax.random.fold_in(key, 1))
    xd = int8_decode(q, s)
    err = np.abs(np.asarray(xd - x))
    assert (err <= np.asarray(s) * 1.001 + 1e-9).all()


@given(seq=st.integers(8, 64), chunk=st.integers(2, 64),
       seed=st.integers(0, 2 ** 20))
@settings(max_examples=10, deadline=None)
def test_chunked_attention_equals_softmax(seq, chunk, seed):
    from repro.models.attention import chunked_attention
    from repro.kernels.ref import flash_attention_ref
    key = jax.random.PRNGKey(seed)
    B, H, D = 1, 2, 8
    q = jax.random.normal(key, (B, seq, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, seq, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, seq, H, D))
    pos = jnp.arange(seq)[None]
    out = chunked_attention(q, k, v, pos, pos, chunk=chunk)
    want = flash_attention_ref(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(b=st.integers(1, 3), s=st.integers(4, 32),
       chunk=st.integers(2, 16), seed=st.integers(0, 2 ** 20))
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_size_invariance(b, s, chunk, seed):
    from repro.models.ssm import ssd_scan
    key = jax.random.PRNGKey(seed)
    h, p, g, n = 4, 4, 2, 4
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, g, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, g, n))
    y1, f1 = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y2, f2 = ssd_scan(x, dt, A, B, C, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=3e-4, atol=3e-4)
