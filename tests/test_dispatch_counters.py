"""Regression tests for the executor bookkeeping bugs fixed in the
kernelization PR:

* `dispatch_stats()` was a pair of module globals mutated without
  synchronization — two sweeps on different threads corrupted each
  other's deltas.  Launch attribution is now per-collector
  (`collect_dispatch`) with the global counters behind a lock.
* `_warn_f32_bytes` used `warnings.warn`, whose once-per-call-site
  dedup meant the SECOND spec to overflow float32 byte counters never
  warned.  It now dedups per spec name, logs every occurrence to the
  flight recorder, and can raise under `REPRO_JX_STRICT_F32`.
"""
import itertools
import threading
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.netsim.jx import engine

# the seen-program set is process-lifetime (it mirrors jax's executable
# caches), so every test mints fresh tags to get deterministic compile
# counts even if the module runs twice in one process
_uniq = itertools.count()


def _launch(program, shape):
    engine._record_launch(program, None, [np.zeros(shape, np.float32)])


def test_collect_dispatch_threaded_attribution():
    """Two concurrent collectors each see exactly their own launches;
    the global counters see the union."""
    engine.reset_dispatch_stats()
    run = next(_uniq)
    barrier = threading.Barrier(2)
    snaps = {}

    def sweep(name, n):
        with engine.collect_dispatch() as counter:
            barrier.wait()
            for i in range(n):
                _launch(f"prog_{run}_{name}", (4 + i, 4))
            snaps[name] = counter.snapshot()

    t1 = threading.Thread(target=sweep, args=("a", 7))
    t2 = threading.Thread(target=sweep, args=("b", 11))
    t1.start(); t2.start(); t1.join(); t2.join()

    assert snaps["a"] == {"dispatches": 7, "compiles": 7}
    assert snaps["b"] == {"dispatches": 11, "compiles": 11}
    g = engine.dispatch_stats()
    assert g["dispatches"] == 18
    assert g["compiles"] == 18


def test_collect_dispatch_nested_and_warm():
    run = next(_uniq)
    with engine.collect_dispatch() as outer:
        _launch(f"p0_{run}", (8, 8))
        with engine.collect_dispatch() as inner:
            _launch(f"p0_{run}", (8, 8))   # warm: same program+shape
        _launch(f"p1_{run}", (8, 8))
    assert inner.snapshot() == {"dispatches": 1, "compiles": 0}
    assert outer.snapshot() == {"dispatches": 3, "compiles": 2}
    # collector popped: further launches touch only the globals
    _launch(f"p2_{run}", (2, 2))
    assert outer.snapshot()["dispatches"] == 3


def test_execute_points_flight_has_dispatch_stats():
    from repro.experiments.execute import execute_points
    from repro.scenarios.registry import get_scenario

    spec = get_scenario("fig9_single_all2all").with_sim(
        slots=20, backend="jax")
    flight = {}
    out = execute_points([spec, spec.with_sim(seed=1)], flight=flight)
    assert len(out) == 2
    stats = flight["dispatch_stats"]
    assert stats["dispatches"] >= 1
    assert stats["compiles"] >= 0
    assert isinstance(flight["f32_overflows"], list)


def _overflowing(max_bytes=1e9):
    # finite bytes_total above 2^24: float32 integer resolution loss
    return SimpleNamespace(bytes_total=np.array([1.0, max_bytes, np.inf]))


@pytest.fixture
def f32_mode():
    prev = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", False)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", prev)


def test_warn_f32_bytes_once_per_spec(f32_mode, recwarn, monkeypatch):
    monkeypatch.delenv("REPRO_JX_STRICT_F32", raising=False)
    n0 = len(engine.f32_overflow_log())
    fa = _overflowing()
    engine._warn_f32_bytes("spec-once-A", fa)
    engine._warn_f32_bytes("spec-once-A", fa)
    warned = [w for w in recwarn.list
              if "spec-once-A" in str(w.message)]
    assert len(warned) == 1, "must warn exactly once per spec name"
    # ... but every overflow occurrence reaches the flight recorder
    log = engine.f32_overflow_log()[n0:]
    assert [e["spec"] for e in log] == ["spec-once-A", "spec-once-A"]
    assert all(e["max_bytes"] > 2 ** 24 for e in log)
    # a DIFFERENT spec warns again (the stdlib-warnings dedup regression:
    # one call site, so the second spec used to be silently swallowed)
    engine._warn_f32_bytes("spec-once-B", fa)
    assert any("spec-once-B" in str(w.message) for w in recwarn.list)


def test_warn_f32_bytes_strict_raises(f32_mode, monkeypatch):
    monkeypatch.setenv("REPRO_JX_STRICT_F32", "1")
    with pytest.raises(ValueError, match="spec-strict"):
        engine._warn_f32_bytes("spec-strict", _overflowing())


def test_warn_f32_bytes_silent_when_safe(f32_mode, recwarn):
    n0 = len(engine.f32_overflow_log())
    fa = SimpleNamespace(bytes_total=np.array([1.0, np.inf, 1e6]))
    engine._warn_f32_bytes("spec-safe", fa)
    assert not [w for w in recwarn.list if "spec-safe" in str(w.message)]
    assert len(engine.f32_overflow_log()) == n0
