"""Scenario engine: spec -> compile determinism, registry completeness,
fault schedules, runner metrics, and planes.apportion edge cases."""
import numpy as np
import pytest

from repro.core.planes import apportion, plane_loads
from repro.scenarios import (FaultSpec, ScenarioSpec, SimSpec, SweepGrid,
                             TenantSpec, TopologySpec, WorkloadSpec,
                             compile_scenario, get_scenario,
                             list_scenarios, run_point, sweep)

SMALL = TopologySpec(n_leaves=2, n_spines=2, hosts_per_leaf=2)


def _flow_tuples(flows):
    return [(f.src, f.dst, f.demand, f.bytes_total, f.group, f.start_slot)
            for f in flows]


# ---------------------------------------------------------------------------
# compile determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["fig8_bisection", "permutation_stress",
                                  "storage_background_mix"])
def test_compile_is_deterministic(name):
    spec = get_scenario(name)
    a = compile_scenario(spec)
    b = compile_scenario(spec)
    assert _flow_tuples(a.flows) == _flow_tuples(b.flows)
    assert a.fault_slots == b.fault_slots
    assert a.tenants == b.tenants


def test_workload_seed_changes_random_draws():
    spec = get_scenario("permutation_stress")
    a = compile_scenario(spec)
    b = compile_scenario(spec.with_workload_seed(spec.workload_seed + 1))
    assert _flow_tuples(a.flows) != _flow_tuples(b.flows)


def test_same_seed_identical_sim_trajectory():
    spec = get_scenario("straggler_failure_compound").with_sim(slots=60)
    r1 = compile_scenario(spec).run()
    r2 = compile_scenario(spec).run()
    np.testing.assert_array_equal(r1.goodput, r2.goodput)
    np.testing.assert_array_equal(r1.completion_slot, r2.completion_slot)


# ---------------------------------------------------------------------------
# registry completeness
# ---------------------------------------------------------------------------

def test_registry_has_required_coverage():
    names = list_scenarios()
    assert len(names) >= 10
    ports = [n for n in names if n.startswith("fig")]
    assert len(ports) >= 4
    assert len(names) - len(ports) >= 6


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_every_scenario_compiles_and_runs(name):
    spec = get_scenario(name)
    if not any(w.kind == "schedule" for w in spec.workloads):
        # schedule scenarios pin their own horizon (the compiler
        # rejects a sim too short to hold every training step)
        spec = spec.with_sim(slots=50)
    c = compile_scenario(spec)
    assert len(c.flows) > 0
    m = run_point(spec)
    assert np.isfinite(m.mean_goodput) and m.mean_goodput >= 0
    assert 0.0 < m.isolation_index <= 1.0 + 1e-9
    assert set(m.tenant_mean) == set(m.tenant_p99) == set(m.tenant_p01)
    for t, v in m.tenant_mean.items():
        assert np.isfinite(v)
        assert m.tenant_p01[t] <= m.tenant_p99[t] + 1e-12


# ---------------------------------------------------------------------------
# tenants / workloads / faults
# ---------------------------------------------------------------------------

def test_tenant_overlap_rejected():
    spec = ScenarioSpec(
        name="overlap", topo=SMALL,
        tenants=(TenantSpec("a", placement="block", n_hosts=3),
                 TenantSpec("b", placement="block", offset=2, n_hosts=2)),
        workloads=(WorkloadSpec("all2all", tenant="a"),))
    with pytest.raises(ValueError, match="overlap"):
        compile_scenario(spec)


def test_unknown_kinds_rejected():
    with pytest.raises(ValueError, match="workload"):
        ScenarioSpec(name="bad", topo=SMALL,
                     workloads=(WorkloadSpec("warp"),)).validate()
    with pytest.raises(ValueError, match="fault"):
        ScenarioSpec(name="bad", topo=SMALL,
                     workloads=(WorkloadSpec("all2all"),),
                     faults=(FaultSpec("meteor"),)).validate()


def _spec_with_fault(fault, topo=SMALL):
    return ScenarioSpec(name="bounds", topo=topo,
                        workloads=(WorkloadSpec("all2all"),),
                        faults=(fault,))


def test_fault_indices_bound_checked():
    """Regression (ISSUE 5 satellite): out-of-range fault indices used
    to pass validation and die with a bare IndexError — or silently
    wrap via negative indexing — deep in the event closures / the jx
    timeline compiler.  They must raise `FaultBoundsError` at
    `validate()` time."""
    from repro.scenarios.spec import FaultBoundsError

    bad = [
        FaultSpec("link_kill", plane=2),               # n_planes = 1
        FaultSpec("link_kill", plane=-2),              # only -1 = all
        FaultSpec("link_kill", leaf=2),                # n_leaves = 2
        FaultSpec("link_kill", spine=-1),
        FaultSpec("link_flap", period=4, spine=2),     # n_spines = 2
        FaultSpec("leaf_trim", leaf=-1),
        FaultSpec("cascade", period=4, spines=(0, 2)),
        FaultSpec("access_kill", host=4),              # n_hosts = 4
        FaultSpec("access_flap", period=4, host=-1),
        FaultSpec("straggler", host=17),
        FaultSpec("core_kill"),                        # not a fat_tree
    ]
    for fault in bad:
        with pytest.raises(FaultBoundsError):
            _spec_with_fault(fault).validate()

    ft = TopologySpec(kind="fat_tree", n_leaves=2, hosts_per_leaf=2,
                      n_pods=2, n_aggs=2, n_cores=4)
    bad_ft = [
        FaultSpec("link_kill", spine=2),               # n_aggs = 2
        FaultSpec("core_kill", pod=2),                 # n_pods = 2
        FaultSpec("core_kill", core=4),                # n_cores = 4
        FaultSpec("cascade", period=4, spines=(0,), pod=-1),
    ]
    for fault in bad_ft:
        with pytest.raises(FaultBoundsError):
            _spec_with_fault(fault, ft).validate()

    # in-range faults (including the fat-tree agg addressing) still pass
    _spec_with_fault(FaultSpec("link_kill", leaf=1, spine=1)).validate()
    _spec_with_fault(FaultSpec("random_fail", plane=-1, frac=0.5)).validate()
    _spec_with_fault(FaultSpec("core_kill", pod=1, core=3), ft).validate()
    _spec_with_fault(FaultSpec("cascade", period=4, spines=(1,), pod=1),
                     ft).validate()


def test_fat_tree_topology_shape_validated():
    with pytest.raises(ValueError, match="n_pods"):
        TopologySpec(kind="fat_tree", n_pods=1).validate()
    with pytest.raises(ValueError, match="divisible"):
        TopologySpec(kind="fat_tree", n_leaves=3, n_pods=2).validate()
    with pytest.raises(ValueError, match="n_cores"):
        TopologySpec(kind="fat_tree", n_pods=2, n_aggs=3,
                     n_cores=4).validate()
    with pytest.raises(ValueError, match="kind"):
        TopologySpec(kind="clos").validate()


def test_flap_schedule_restores_capacity():
    spec = ScenarioSpec(
        name="flap", topo=SMALL,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("pairs", pairs=((0, 2),)),),
        faults=(FaultSpec("link_flap", start_slot=4, stop_slot=20,
                          period=8, duty=0.5, leaf=0, spine=0),),
        sim=SimSpec(slots=30))
    c = compile_scenario(spec)
    # transitions at every period start inside [start, stop)
    assert [s for s, _ in c.fault_slots] == [4, 12]
    cap = spec.topo.uplink_cap
    up = []
    for t in range(30):
        c.events(t, c.topo)
        up.append(c.topo.up[0, 0, 0])
    assert up[4] == 0.0 and up[8] == cap     # down then restored
    assert up[12] == 0.0 and up[16] == cap   # second flap cycle
    assert up[29] == cap                      # healthy after stop


def test_straggler_slows_then_restores():
    spec = ScenarioSpec(
        name="strag", topo=SMALL,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("allreduce"),),
        faults=(FaultSpec("straggler", start_slot=2, stop_slot=6, host=1,
                          frac=0.25, plane=-1),),
        sim=SimSpec(slots=10))
    c = compile_scenario(spec)
    for t in range(10):
        c.events(t, c.topo)
        if 2 <= t < 6:
            assert np.allclose(c.topo.access[:, 1], 0.25)
        if t >= 6:
            assert np.allclose(c.topo.access[:, 1], 1.0)


def test_cascade_kills_spines_in_order():
    spec = ScenarioSpec(
        name="casc", topo=SMALL,
        tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("all2all"),),
        faults=(FaultSpec("cascade", start_slot=1, period=3,
                          spines=(1, 0)),),
        sim=SimSpec(slots=8))
    c = compile_scenario(spec)
    for t in range(5):
        c.events(t, c.topo)
    assert (c.topo.up[0, :, 1] == 0).all()     # spine 1 died at t=1
    assert (c.topo.up[0, :, 0] == 0).all()     # spine 0 died at t=4
    assert [lbl for _, lbl in c.fault_slots] == ["cascade[0]",
                                                 "cascade[1]"]


# ---------------------------------------------------------------------------
# runner metrics
# ---------------------------------------------------------------------------

def test_sweep_grid_shape_and_inheritance():
    spec = get_scenario("fig11_degraded_leaf")
    grid = SweepGrid(seeds=(0, 1), slots=40)
    points = grid.points(spec)
    assert len(points) == 2
    # routing/nic inherit from the spec when the grid leaves them None
    assert all(p.sim.routing == "war" and p.sim.nic == "spx"
               for p in points)
    assert points[0].sim.seed != points[1].sim.seed
    assert points[0].workload_seed != points[1].workload_seed


def test_sweep_grid_rejects_unknown_routing_and_nic():
    spec = get_scenario("fig8_bisection")
    with pytest.raises(ValueError, match="unknown routing 'warp'"):
        SweepGrid(routings=("ar", "warp")).points(spec)
    with pytest.raises(ValueError, match="unknown nic 'tcp'"):
        SweepGrid(nics=("tcp",)).points(spec)


def test_sweep_grid_rejects_empty_tuples():
    # () used to silently fall back to the spec's own routing/nic —
    # only None may inherit
    spec = get_scenario("fig8_bisection")
    with pytest.raises(ValueError, match="empty routings"):
        SweepGrid(routings=()).points(spec)
    with pytest.raises(ValueError, match="empty nics"):
        SweepGrid(nics=()).points(spec)


def test_pairs_endpoints_validated():
    out_of_range = ScenarioSpec(
        name="bad_pairs", topo=SMALL, tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("pairs", pairs=((0, 99),)),))
    with pytest.raises(ValueError, match="pairs endpoints"):
        out_of_range.validate()
    negative = ScenarioSpec(
        name="neg_pairs", topo=SMALL, tenants=(TenantSpec("main"),),
        workloads=(WorkloadSpec("pairs", pairs=((0, -3),)),))
    with pytest.raises(ValueError, match="pairs endpoints"):
        negative.validate()
    foreign = ScenarioSpec(
        name="foreign_pairs", topo=SMALL,
        tenants=(TenantSpec("a", placement="block", n_hosts=2),
                 TenantSpec("b", placement="remainder")),
        workloads=(WorkloadSpec("pairs", tenant="a", pairs=((0, 3),)),))
    with pytest.raises(ValueError, match="outside the tenant"):
        compile_scenario(foreign)


def test_duplicate_explicit_tenant_hosts_rejected():
    spec = ScenarioSpec(
        name="dup_hosts", topo=SMALL,
        tenants=(TenantSpec("a", placement="explicit", hosts=(1, 1, 2)),),
        workloads=(WorkloadSpec("all2all", tenant="a"),))
    with pytest.raises(ValueError, match="more than once"):
        compile_scenario(spec)


def test_unknown_backend_rejected():
    spec = get_scenario("fig8_bisection").with_sim(slots=20)
    with pytest.raises(ValueError, match="backend"):
        spec.with_sim(backend="torch").validate()
    with pytest.raises(ValueError, match="backend"):
        compile_scenario(spec).run(backend="torch")


def test_backend_field_dispatches_jax():
    from jax.experimental import enable_x64
    spec = get_scenario("fig12_plane_flap").with_sim(slots=80,
                                                     backend="jax")
    with enable_x64():   # f32 trajectories may fork at CC thresholds
        m = run_point(spec)
        ref = run_point(spec.with_sim(backend="numpy"))
    assert m.mean_goodput == pytest.approx(ref.mean_goodput, abs=1e-5)


def test_sweep_backend_override_beats_spec_backend():
    # sweep(backend="numpy") must not silently run jax-backend specs on
    # JAX: the engine's dispatch flag stays untouched
    import sys
    from repro.scenarios import sweep
    spec = get_scenario("fig12_plane_flap").with_sim(slots=40,
                                                     backend="jax")
    engine = sys.modules.get("repro.netsim.jx.engine")
    was = getattr(engine, "_BACKEND_USED", False) if engine else False
    try:
        if engine is not None:
            engine._BACKEND_USED = False
        sweep(spec, SweepGrid(seeds=(0,)), backend="numpy")
        engine = sys.modules.get("repro.netsim.jx.engine")
        assert not getattr(engine, "_BACKEND_USED", False)
    finally:
        if engine is not None:
            engine._BACKEND_USED = was


def test_sweep_parallel_matches_serial():
    grid = SweepGrid(seeds=(0, 1), slots=40)
    serial = sweep("multi_tenant_50_50", grid, processes=1)
    parallel = sweep("multi_tenant_50_50", grid, processes=2)
    assert [m.to_row() for m in serial] == [m.to_row() for m in parallel]


def test_recovery_reported_for_fault_scenarios():
    m = run_point(get_scenario("fig12_plane_flap"))
    assert len(m.recovery_slots) == 1
    slot, label, rec = m.recovery_slots[0]
    assert slot == 50 and label == "access_kill"
    assert 0 < rec < 20       # hardware PLB: a handful of slots


def test_completion_tail_on_finite_transfers():
    m = run_point(get_scenario("allreduce_under_random_failures"))
    assert np.isfinite(m.completion_tail)
    assert m.completion_tail >= 1.0


def test_symmetry_outliers_flag_injected_asymmetry():
    healthy = run_point(get_scenario("fig8_bisection").with_sim(slots=80))
    degraded = run_point(get_scenario("fig11_degraded_leaf")
                         .with_sim(slots=80))
    assert healthy.symmetry_cv < degraded.symmetry_cv


# ---------------------------------------------------------------------------
# planes.apportion edge cases (satellite)
# ---------------------------------------------------------------------------

def test_apportion_all_zero_weights_uniform():
    a = apportion(np.zeros(4), 8)
    loads = plane_loads(a, 4, 1.0)
    np.testing.assert_array_equal(loads, np.full(4, 2.0))


def test_apportion_k_equals_n_planes():
    a = apportion(np.ones(6), 6)
    loads = plane_loads(a, 6, 1.0)
    np.testing.assert_array_equal(loads, np.ones(6))


def test_apportion_k_equals_n_planes_with_dead_plane():
    a = apportion(np.array([1.0, 0.0, 1.0, 1.0]), 4)
    loads = plane_loads(a, 4, 1.0)
    assert loads[1] == 0.0
    assert loads.sum() == 4


def test_apportion_single_chunk():
    a = apportion(np.array([0.2, 0.8]), 1)
    assert a.shape == (1,)
    assert a[0] == 1
