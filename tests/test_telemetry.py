"""Telemetry (§5) tests: histogram classification, symmetry groups, HFT."""
import numpy as np

from repro.core.telemetry import (HFTBuffer, StepTimeTracker, bw_histogram,
                                  classify_histogram, find_stragglers,
                                  symmetry_check)


def test_bimodal_is_healthy_blocked():
    """§5.2: healthy ranks stalled on a straggler are at line rate or
    idle."""
    samples = np.concatenate([np.full(500, 0.02), np.full(500, 0.99)])
    assert classify_histogram(bw_histogram(samples)) == "healthy-blocked"


def test_midrange_is_straggler():
    rng = np.random.default_rng(0)
    samples = rng.uniform(0.3, 0.7, 1000)
    assert classify_histogram(bw_histogram(samples)) == "straggler"


def test_line_rate_classified():
    samples = np.full(1000, 0.98)
    assert classify_histogram(bw_histogram(samples)) == "line-rate"


def test_find_stragglers_among_ranks():
    rng = np.random.default_rng(1)
    ranks = np.zeros((8, 1000))
    for r in range(8):
        if r == 3:
            ranks[r] = rng.uniform(0.3, 0.6, 1000)      # the straggler
        else:
            bi = rng.random(1000) < 0.5
            ranks[r] = np.where(bi, 0.99, 0.01)
    assert find_stragglers(ranks) == [3]


def test_symmetry_group_outlier():
    """§5.1: AR traffic is uniform; an outlier port flags a fault."""
    bw = np.full(32, 100.0)
    rep = symmetry_check("leaf-uplinks", bw)
    assert rep.uniform and rep.outliers == []
    bw[7] = 40.0
    rep = symmetry_check("leaf-uplinks", bw)
    assert not rep.uniform
    assert rep.outliers == [7]


def test_hft_detects_transient_drops():
    """§5.3: the daemon-interference signature — sharp transient BW
    drops."""
    buf = HFTBuffer()
    for t in range(100):
        bw = 0.95 if t not in (40, 41, 70) else 0.2
        buf.record(float(t), {"bw": bw})
    drops = buf.drops("bw")
    assert set(drops) == {40.0, 41.0, 70.0}


def test_step_time_tracker_flags_slow_host():
    tr = StepTimeTracker(n_hosts=8)
    for _ in range(5):
        times = np.ones(8)
        times[2] = 1.8
        slow = tr.update(times)
    assert slow == [2]


# ---------------------------------------------------------------------------
# classify_histogram edge behavior + bi-modal/straggler/idle boundaries
# (regression coverage for the all-zero and nbins < 1/edge_frac fixes)
# ---------------------------------------------------------------------------

def test_all_zero_histogram_is_idle():
    """No mass means nothing flowed — must not classify 'straggler'."""
    assert classify_histogram(np.zeros(20)) == "idle"
    assert classify_histogram(np.zeros(3)) == "idle"
    assert classify_histogram(np.zeros(1)) == "idle"


def test_idle_port_series_is_idle():
    assert classify_histogram(bw_histogram(np.zeros(500))) == "idle"
    near = np.full(500, 0.004)                  # all mass in bin 0
    assert classify_histogram(bw_histogram(near)) == "idle"


def test_degenerate_small_nbins_never_negative_mid():
    """nbins < 1/edge_frac used to overlap the edge windows and drive
    the mid-mass negative; the windows are now clamped to disjoint
    halves, so every class is a valid label for every bin count."""
    valid = {"idle", "line-rate", "healthy-blocked", "straggler"}
    rng = np.random.default_rng(7)
    for nbins in (1, 2, 3, 4, 5, 6, 20, 40):
        for _ in range(20):
            hist = rng.integers(0, 50, nbins).astype(float)
            assert classify_histogram(hist) in valid
    # bi-modal mass with 3 bins: edges are single disjoint bins
    assert classify_histogram(np.array([50.0, 0.0, 50.0])) == \
        "healthy-blocked"
    # 2 bins: everything is edge mass; low-heavy -> idle-ish, not crash
    assert classify_histogram(np.array([100.0, 1.0])) == "idle"
    assert classify_histogram(np.array([10.0, 90.0])) in valid


def test_single_bin_histogram_is_mid_dominated():
    """One bin has no edge resolution: all mass counts as mid-range."""
    assert classify_histogram(np.array([42.0])) == "straggler"


def test_classification_boundaries_sweep():
    """Property sweep over two-point mixtures low/high: the label moves
    idle -> healthy-blocked -> line-rate as mass shifts to the top bin,
    and injecting mid-range mass >= 25% always yields 'straggler'."""
    n = 1000
    for k in range(0, n + 1, 50):
        frac_high = k / n
        samples = np.concatenate([np.full(n - k, 0.01), np.full(k, 0.99)])
        cls = classify_histogram(bw_histogram(samples))
        if frac_high <= 0.05:
            assert cls == "idle", frac_high
        elif frac_high > 0.85:
            assert cls == "line-rate", frac_high
        else:
            assert cls == "healthy-blocked", frac_high
    for frac_mid in (0.26, 0.5, 0.75, 1.0):
        k = int(n * frac_mid)
        samples = np.concatenate([
            np.full((n - k) // 2, 0.01), np.full((n - k) // 2, 0.99),
            np.full(k, 0.5)])
        assert classify_histogram(bw_histogram(samples)) == "straggler", \
            frac_mid


def test_find_stragglers_ignores_idle_ranks():
    """A rank that never sent anything is idle, not a straggler."""
    ranks = np.zeros((4, 500))
    ranks[1] = 0.5                              # the actual straggler
    ranks[2] = 0.99                             # line rate
    assert find_stragglers(ranks) == [1]
