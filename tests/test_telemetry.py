"""Telemetry (§5) tests: histogram classification, symmetry groups, HFT."""
import numpy as np

from repro.core.telemetry import (HFTBuffer, StepTimeTracker, bw_histogram,
                                  classify_histogram, find_stragglers,
                                  symmetry_check)


def test_bimodal_is_healthy_blocked():
    """§5.2: healthy ranks stalled on a straggler are at line rate or
    idle."""
    samples = np.concatenate([np.full(500, 0.02), np.full(500, 0.99)])
    assert classify_histogram(bw_histogram(samples)) == "healthy-blocked"


def test_midrange_is_straggler():
    rng = np.random.default_rng(0)
    samples = rng.uniform(0.3, 0.7, 1000)
    assert classify_histogram(bw_histogram(samples)) == "straggler"


def test_line_rate_classified():
    samples = np.full(1000, 0.98)
    assert classify_histogram(bw_histogram(samples)) == "line-rate"


def test_find_stragglers_among_ranks():
    rng = np.random.default_rng(1)
    ranks = np.zeros((8, 1000))
    for r in range(8):
        if r == 3:
            ranks[r] = rng.uniform(0.3, 0.6, 1000)      # the straggler
        else:
            bi = rng.random(1000) < 0.5
            ranks[r] = np.where(bi, 0.99, 0.01)
    assert find_stragglers(ranks) == [3]


def test_symmetry_group_outlier():
    """§5.1: AR traffic is uniform; an outlier port flags a fault."""
    bw = np.full(32, 100.0)
    rep = symmetry_check("leaf-uplinks", bw)
    assert rep.uniform and rep.outliers == []
    bw[7] = 40.0
    rep = symmetry_check("leaf-uplinks", bw)
    assert not rep.uniform
    assert rep.outliers == [7]


def test_hft_detects_transient_drops():
    """§5.3: the daemon-interference signature — sharp transient BW
    drops."""
    buf = HFTBuffer()
    for t in range(100):
        bw = 0.95 if t not in (40, 41, 70) else 0.2
        buf.record(float(t), {"bw": bw})
    drops = buf.drops("bw")
    assert set(drops) == {40.0, 41.0, 70.0}


def test_step_time_tracker_flags_slow_host():
    tr = StepTimeTracker(n_hosts=8)
    for _ in range(5):
        times = np.ones(8)
        times[2] = 1.8
        slow = tr.update(times)
    assert slow == [2]
