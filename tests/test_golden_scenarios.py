"""Golden-metric regression tests.

`tests/golden/scenarios.json` snapshots the distilled `ScenarioMetrics`
of every registry scenario at seed 0 (each spec's own defaults, NumPy
backend).  Any engine, compiler, or registry change that shifts goodput,
isolation, recovery, or tail metrics fails here — deliberately.

To re-baseline after an *intentional* behavior change:

    PYTHONPATH=src python -m pytest tests/test_golden_scenarios.py \
        --update-golden

then review and commit the JSON diff alongside the change that caused it.
"""
import json
import math
from pathlib import Path

import pytest

from repro.scenarios import get_scenario, list_scenarios, run_point

GOLDEN = Path(__file__).parent / "golden" / "scenarios.json"
# float64 ops are deterministic, but libm/SIMD exp() may differ by an
# ulp across platforms; 1e-6 relative absorbs that without hiding
# behavioral drift
RTOL, ATOL = 1e-6, 1e-9


def _snapshot(name: str) -> dict:
    m = run_point(get_scenario(name))
    return {
        "mean_goodput": m.mean_goodput,
        "tenant_mean": m.tenant_mean,
        "tenant_p01": m.tenant_p01,
        "tenant_p99": m.tenant_p99,
        "isolation_index": m.isolation_index,
        "recovery_slots": [list(r) for r in m.recovery_slots],
        "completion_tail": (None if math.isnan(m.completion_tail)
                            else m.completion_tail),
        "symmetry_cv": m.symmetry_cv,
        "symmetry_uniform": m.symmetry_uniform,
    }


def _assert_close(got, want, path):
    if isinstance(want, dict):
        assert set(got) == set(want), f"{path}: keys {set(got)}^{set(want)}"
        for k in want:
            _assert_close(got[k], want[k], f"{path}.{k}")
    elif isinstance(want, list):
        assert len(got) == len(want), f"{path}: length"
        for i, (g, w) in enumerate(zip(got, want)):
            _assert_close(g, w, f"{path}[{i}]")
    elif isinstance(want, float) and not isinstance(want, bool):
        assert got == pytest.approx(want, rel=RTOL, abs=ATOL), path
    else:
        assert got == want, f"{path}: {got!r} != {want!r}"


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_golden_scenario(name, request):
    got = _snapshot(name)
    if request.config.getoption("--update-golden"):
        data = (json.loads(GOLDEN.read_text()) if GOLDEN.exists() else {})
        data[name] = got
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(data, indent=2, sort_keys=True) +
                          "\n")
        pytest.skip(f"golden updated for {name}")
    assert GOLDEN.exists(), \
        "tests/golden/scenarios.json missing — run with --update-golden"
    data = json.loads(GOLDEN.read_text())
    assert name in data, f"{name} not in golden file — run --update-golden"
    _assert_close(got, data[name], name)


def test_golden_covers_whole_registry():
    data = json.loads(GOLDEN.read_text())
    assert sorted(data) == sorted(list_scenarios()), \
        "golden file out of sync with the scenario registry"
