"""Chunked flow streaming (`JxConfig.flow_chunk`) vs the monolithic
sparse path.

The streaming engine (`netsim/jx/chunked.py`) runs the flow axis
through `_slot_step`'s sparse path in fixed-size chunks, folding each
chunk's scatter-add into flat per-link accumulators.  On CPU f64 both
that fold and the monolithic `segment_sum` apply per-bucket updates in
flow order, and the per-flow NIC/completion tail runs monolithically
outside the chunk scan — so chunked results are *bit-identical* to the
monolithic engine at x64, for every chunk length including ones that
don't divide the flow count.  These tests pin that contract on both
topology kinds, its composition with `REPRO_JX_COMPACT`, the megabatch
dispatch path, and the chunk-size-independence of delivered bytes.
"""
import os
import warnings

import numpy as np
import pytest
from jax.experimental import enable_x64

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # deterministic coverage below still runs
    HAVE_HYPOTHESIS = False

from repro.scenarios.registry import get_scenario
from repro.scenarios.runner import run_point

from test_sparse_agg import _assert_close

SETTINGS = dict(max_examples=6, deadline=None)

# real flow populations (64 each), one per topology kind — chunk sizes
# below exercise singleton chunks, a non-divisible tail (17), and a
# chunk longer than the flow axis
SCN = {"leaf_spine": "fig8_bisection",
       "fat_tree": "ft_core_failure_resiliency"}
CHUNKS = (1, 17, 1024, 64)


def _run_chunk(spec, chunk, extra_env=()):
    """`run_point` with `REPRO_JX_FLOW_CHUNK` (and any extra env pairs)
    pinned for the call; 0/None restores the monolithic path."""
    pairs = (("REPRO_JX_FLOW_CHUNK",
              str(chunk) if chunk else None),) + tuple(extra_env)
    prev = {k: os.environ.get(k) for k, _ in pairs}
    for k, v in pairs:
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    try:
        return run_point(spec).to_dict()
    finally:
        for k, v in prev.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


@pytest.mark.parametrize("kind", ["leaf_spine", "fat_tree"])
@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_bit_identical_x64(kind, chunk):
    """The tentpole contract: every chunk length — singleton, a
    non-divisible 17, longer-than-F, and exactly F — reproduces the
    monolithic sparse engine bit for bit at x64."""
    with enable_x64():
        spec = get_scenario(SCN[kind]).with_sim(slots=48, backend="jax")
        mono = _run_chunk(spec, 0)
        chunked = _run_chunk(spec, chunk)
    _assert_close(mono, chunked, rtol=0.0)


@pytest.mark.parametrize("routing", ["ar", "war", "ecmp"])
def test_chunked_bit_identical_x64_routings(routing):
    """Every routing branch has its own chunked transcription (pair
    tables vs per-stage ECMP fractions) — pin each at the awkward
    non-divisible chunk length."""
    with enable_x64():
        spec = get_scenario("fig8_bisection").with_sim(
            slots=48, routing=routing, nic="dcqcn", backend="jax")
        mono = _run_chunk(spec, 0)
        chunked = _run_chunk(spec, 17)
    _assert_close(mono, chunked, rtol=0.0)


if HAVE_HYPOTHESIS:
    @settings(**SETTINGS)
    @given(kind=st.sampled_from(["leaf_spine", "fat_tree"]),
           chunk=st.integers(1, 96))
    def test_delivered_bytes_chunk_size_invariant(kind, chunk):
        """The named invariant: total delivered bytes (flow count x the
        mean per-flow goodput integral the engine reports) do not depend
        on how the flow axis was chunked."""
        with enable_x64():
            spec = get_scenario(SCN[kind]).with_sim(slots=36,
                                                    backend="jax")
            mono = _run_chunk(spec, 0)
            chunked = _run_chunk(spec, chunk)
        assert chunked["mean_goodput"] == mono["mean_goodput"]
        _assert_close(mono, chunked, rtol=0.0)


def test_compact_carry_composes_with_flow_chunk_f32():
    """S1: `REPRO_JX_COMPACT` (int8 probe counters) and `flow_chunk`
    compose — the chunked scan carries the compact NIC state through
    the chunk axis, and f32 results stay bit-identical to the
    wide-carry chunked run."""
    spec = get_scenario("fig8_bisection").with_sim(
        slots=40, routing="ar", nic="esr", backend="jax")
    wide = _run_chunk(spec, 17)
    compact = _run_chunk(spec, 17, extra_env=(("REPRO_JX_COMPACT", "1"),))
    _assert_close(wide, compact, rtol=0.0)


def test_chunked_megabatch_row_identity_x64():
    """The megabatch dispatcher wires `flow_chunk` through its
    structural cfg and rounds the flow bucket to a chunk multiple; a
    forced awkward chunk must leave every row of a mixed grid identical
    to the monolithic megabatch run."""
    from repro.experiments import Axis, Experiment, execute_points, product

    exp = Experiment(
        name="test_flow_chunk.mb", base="flap_during_incast",
        axes=product(Axis("sim.routing", ("ar", "war", "ecmp")),
                     Axis("sim.nic", ("spx", "swlb")),
                     Axis("seed", (0, 1)),
                     Axis("sim.slots", (80,))))
    points = [p.spec for p in exp.points()]
    with enable_x64():
        mono = execute_points(points, backend="jax",
                              jx_dispatch="megabatch")
        prev = os.environ.get("REPRO_JX_FLOW_CHUNK")
        os.environ["REPRO_JX_FLOW_CHUNK"] = "17"
        try:
            chunked = execute_points(points, backend="jax",
                                     jx_dispatch="megabatch")
        finally:
            if prev is None:
                del os.environ["REPRO_JX_FLOW_CHUNK"]
            else:
                os.environ["REPRO_JX_FLOW_CHUNK"] = prev
    for p, a, b in zip(points, mono, chunked):
        assert a.to_row() == b.to_row(), p.name
        assert b.mean_goodput == pytest.approx(a.mean_goodput, abs=1e-5)


def test_chunked_no_donation_warnings_leak():
    """S1: the chunked megabatch launch donates its host-built carry;
    the expected 'donated buffers were not usable' compile chatter must
    be swallowed by the dispatcher, not surface to sweep callers."""
    from repro.experiments import execute_points

    spec = get_scenario("fig8_bisection").with_sim(slots=30,
                                                   backend="jax")
    prev = os.environ.get("REPRO_JX_FLOW_CHUNK")
    os.environ["REPRO_JX_FLOW_CHUNK"] = "16"
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            execute_points([spec], backend="jax",
                           jx_dispatch="megabatch")
    finally:
        if prev is None:
            del os.environ["REPRO_JX_FLOW_CHUNK"]
        else:
            os.environ["REPRO_JX_FLOW_CHUNK"] = prev
    leaked = [w for w in caught
              if "donated" in str(w.message).lower()]
    assert not leaked, [str(w.message) for w in leaked]
