"""Interpret-mode oracle parity for the netsim hot-path Pallas kernels.

These kernels are TPU-gated in production (`kernels.backend
.pallas_enabled`), so without this suite their Pallas bodies would never
execute in CI.  Every test forces `use_pallas=True, interpret=True` on
CPU and checks the kernel against its `ref.py` oracle — including
non-power-of-two block tails and float64 inputs (the kernels must cast
their operands to float32 themselves; historically `pair_fractions`
passed x64 operands straight into a float32 `pallas_call` and crashed).

The last test drives the whole engine with `REPRO_NETSIM_PALLAS=1`, the
way the CI interpret job runs it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import jsq_route, link_load, plb_select, queue_ecn, ref

RNG = np.random.default_rng


def _f64(rng, *shape, lo=0.0, hi=1.0):
    # float64 host arrays: canonicalized to f32 without x64, genuine
    # f64 operands (the historical crash) when the x64 CI job runs this
    return rng.uniform(lo, hi, shape)


@pytest.mark.parametrize("mode", ["spx", "dcqcn", "agg", "swlb"])
@pytest.mark.parametrize("F,P,bp", [(37, 3, 16), (64, 2, 256),
                                    (129, 4, 64)])
def test_plane_split_interpret(mode, F, P, bp):
    rng = RNG(0)
    rate = _f64(rng, F, P, lo=0.05)
    elig = rng.uniform(size=(F, P)) > 0.25
    elig[:, 0] = True
    demand = _f64(rng, F)
    got = plb_select.plane_split(
        jnp.asarray(rate), jnp.asarray(elig), jnp.asarray(demand),
        mode=mode, min_rate=0.05, bp=bp, use_pallas=True, interpret=True)
    want = ref.plane_split_ref(
        jnp.asarray(rate), jnp.asarray(elig), jnp.asarray(demand),
        mode=mode, min_rate=0.05)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("P,L,S,br", [(3, 5, 7, 16), (1, 8, 8, 128),
                                      (2, 9, 4, 32)])
def test_pair_fractions_interpret(P, L, S, br):
    rng = RNG(1)
    q = _f64(rng, P, L, L, S, hi=8.0)
    cap = _f64(rng, P, L, L, S)
    cap[rng.uniform(size=cap.shape) < 0.15] = 0.0
    cap[..., 0] = np.maximum(cap[..., 0], 0.1)       # one alive spine
    w = cap * _f64(rng, P, L, L, S, lo=0.25)
    got = jsq_route.pair_fractions(
        jnp.asarray(q), jnp.asarray(cap), jnp.asarray(w), nbins=16,
        temperature=1.0, qmax=8.0, br=br, use_pallas=True,
        interpret=True)
    want = ref.pair_score_softmax_ref(
        jnp.asarray(q), jnp.asarray(cap), jnp.asarray(w), nbins=16,
        temperature=1.0, qmax=8.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, atol=1e-5)


@pytest.mark.parametrize("N,bp", [(37, 16), (300, 256)])
def test_plb_select_interpret(N, bp):
    rng = RNG(2)
    P = 4
    ra = jnp.asarray(_f64(rng, P))
    el = jnp.asarray((rng.uniform(size=P) > 0.2).astype(np.float64))
    el = el.at[0].set(1.0)
    lq = jnp.asarray(_f64(rng, P))
    tx = jnp.asarray(_f64(rng, N, hi=0.5))
    h = jnp.asarray(rng.integers(0, 1 << 30, N), jnp.uint32)
    got = plb_select.plb_select(ra, el, lq, tx, h, bp=bp,
                                interpret=True)
    want = ref.plb_select_ref(ra, el, lq, tx, h)
    assert bool((got == want).all())


@pytest.mark.parametrize("N,bp", [(37, 16), (512, 256)])
def test_jsq_route_interpret(N, bp):
    rng = RNG(3)
    ports = 16
    queues = jnp.asarray(_f64(rng, ports))
    up = jnp.asarray((np.arange(ports) % 7 != 0).astype(np.float64))
    w = jnp.asarray(_f64(rng, ports, lo=0.25))
    h = jnp.asarray(rng.integers(0, 1 << 30, N), jnp.uint32)
    got = jsq_route.jsq_route(queues, up, w, h, bp=bp, interpret=True)
    want = ref.jsq_route_ref(queues, up, w, h)
    assert bool((got == want).all())


@pytest.mark.parametrize("P,R,C,br", [(3, 37, 11, 16), (2, 64, 8, 128)])
def test_bucket_load_bottleneck_interpret(P, R, C, br):
    rng = RNG(4)
    g = jnp.asarray(_f64(rng, P, R, C))
    cap = jnp.asarray(_f64(rng, P, R, lo=0.1, hi=2.0))
    got_l, got_f = link_load.bucket_load_bottleneck(
        g, cap, ordered=False, br=br, use_pallas=True, interpret=True)
    want_l, want_f = ref.load_bottleneck_ref(g, cap, eps=link_load.EPS,
                                             ordered=False)
    np.testing.assert_allclose(np.asarray(got_l), np.asarray(want_l),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                               rtol=1e-5, atol=1e-6)


def test_bottleneck_interpret_odd_shape():
    rng = RNG(5)
    cap = jnp.asarray(_f64(rng, 2, 5, 7, lo=0.1))
    load = jnp.asarray(_f64(rng, 2, 5, 7, hi=2.0))
    got = link_load.bottleneck(cap, load, bp=16, use_pallas=True,
                               interpret=True)
    want = ref.bottleneck_ref(cap, load, eps=link_load.EPS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    assert got.shape == cap.shape


def test_queue_update_interpret():
    rng = RNG(6)
    q = jnp.asarray(_f64(rng, 2, 8, 8, hi=4.0))
    load = jnp.asarray(_f64(rng, 2, 8, 8, hi=2.0))
    cap = jnp.asarray(_f64(rng, 2, 8, 8))
    cap = cap.at[0, 0, 0].set(0.0)                  # dead link
    got_q, got_u = queue_ecn.queue_update(
        q, load, cap, q_cap=16.0, bp=16, use_pallas=True, interpret=True)
    want_q, want_u = ref.queue_update_ref(q, load, cap, q_cap=16.0)
    np.testing.assert_allclose(np.asarray(got_q), np.asarray(want_q),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_u), np.asarray(want_u),
                               rtol=1e-5, atol=1e-5)
    assert float(got_q[0, 0, 0]) == 0.0


_NIC_KW = dict(base_rtt_us=6.0, slot_us=10.0, ecn_thresh=4.0,
               target_rtt_us=12.0, min_rate=0.01, md=0.7, ai=0.08,
               rtt_gain=0.15, dcqcn_ai=0.01, alpha_g=0.0625)


@pytest.mark.parametrize("mode", ["spx", "dcqcn", "agg"])
@pytest.mark.parametrize("F,P,bp", [(37, 3, 16), (300, 2, 128)])
def test_nic_update_interpret(mode, F, P, bp):
    rng = RNG(7)
    qmean = jnp.asarray(_f64(rng, F, P, hi=12.0))
    rate = jnp.asarray(_f64(rng, F, P, lo=0.05))
    alpha = jnp.asarray(_f64(rng, F, P))
    esr = jnp.asarray(rng.uniform(size=(F, 1)) > 0.5)
    got = queue_ecn.nic_update(qmean, rate, alpha, esr, mode=mode,
                               bp=bp, use_pallas=True, interpret=True,
                               **_NIC_KW)
    want = ref.nic_update_ref(qmean, rate, alpha, esr, mode=mode,
                              **_NIC_KW)
    for g, w, name in zip(got, want, ("rtt", "ecn", "rate", "alpha")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6, err_msg=name)


def test_engine_pallas_interpret_smoke(monkeypatch):
    """The whole slot loop through the Pallas (interpret) kernels — the
    configuration the CI `REPRO_NETSIM_PALLAS=1` job runs.  f32 interpret
    kernels track the jnp fallback closely but not bit-exactly, so pin a
    loose envelope on the headline metric."""
    from repro.scenarios.registry import get_scenario
    from repro.scenarios.runner import run_point

    spec = get_scenario("fig9_single_all2all").with_sim(
        slots=60, backend="jax")
    base = run_point(spec).mean_goodput
    monkeypatch.setenv("REPRO_NETSIM_PALLAS", "1")
    got = run_point(spec).mean_goodput
    assert np.isfinite(got) and got > 0
    assert got == pytest.approx(base, rel=0.05)
