"""Trainer integration: loop, failover, checkpoint-restart determinism,
serving."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PlaneConfig
from repro.data import DataConfig, DataLoader
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.parallel.sharding import local_ctx
from repro.train import Request, ServeEngine, Trainer, TrainerConfig

CFG = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
                  attn_chunk=32, remat="none")
CTX = local_ctx()


def _trainer(ckpt_dir=None, ckpt_every=100):
    params = init_params(jax.random.PRNGKey(0), CFG)
    tcfg = TrainerConfig(plane=PlaneConfig(4, 8), ckpt_dir=ckpt_dir,
                         ckpt_every=ckpt_every, warmup_steps=2,
                         total_steps=50)
    return Trainer(CFG, CTX, tcfg, params), tcfg


def _data(start=0):
    return DataLoader(DataConfig(vocab=256, seq_len=32, global_batch=4),
                      start_step=start)


def test_loss_decreases_on_learnable_data():
    """Constant-token batches are perfectly learnable."""
    tr, _ = _trainer()
    batch = {"tokens": jnp.full((4, 32), 7, jnp.int32),
             "labels": jnp.full((4, 32), 7, jnp.int32)}
    losses = [tr.train_step(batch)["loss"] for _ in range(8)]
    assert losses[-1] < losses[0] * 0.7


def test_failover_during_training_reweights_and_recovers():
    tr, tcfg = _trainer()
    dl = _data()
    for _, b in zip(range(2), dl):
        tr.train_step({k: jnp.asarray(v) for k, v in b.items()})
    tr.inject_plane_failure(2)
    for _, b in zip(range(5), dl):
        m = tr.train_step({k: jnp.asarray(v) for k, v in b.items()})
    assert m["planes_up"] == 3
    rec = tr.failover.records[0]
    assert rec.recovery_steps is not None and rec.recovery_steps <= 5
    w = tr.failover.weights()
    assert w[2] < 1e-3
    tr.heal_plane(2)
    for _, b in zip(range(3), dl):
        m = tr.train_step({k: jnp.asarray(v) for k, v in b.items()})
    assert m["planes_up"] == 4


def test_checkpoint_restart_is_bitwise_deterministic():
    """Restart from a checkpoint reproduces the uninterrupted run exactly
    (deterministic data + optimizer)."""
    with tempfile.TemporaryDirectory() as d:
        tr, tcfg = _trainer(ckpt_dir=d, ckpt_every=3)
        dl = _data()
        for _, b in zip(range(5), dl):
            m_ref = tr.train_step({k: jnp.asarray(v)
                                   for k, v in b.items()})
        # restore at step 3 (the only committed checkpoint), replay 4..5
        tr2 = Trainer.restore(CFG, CTX, tcfg,
                              init_params(jax.random.PRNGKey(0), CFG))
        assert tr2.step == 3
        dl2 = _data(start=3)
        for _, b in zip(range(2), dl2):
            m_replay = tr2.train_step({k: jnp.asarray(v)
                                       for k, v in b.items()})
        assert np.isclose(m_replay["loss"], m_ref["loss"], rtol=1e-6)
        for a, b_ in zip(jax.tree.leaves(tr.params),
                         jax.tree.leaves(tr2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-6)


def test_serve_engine_batched_requests():
    tr, _ = _trainer()
    eng = ServeEngine(CFG, CTX, tr.params, batch=2, max_len=64)
    reqs = [Request(i, np.arange(4, dtype=np.int32) + i, max_new=5)
            for i in range(4)]     # 4 requests through 2 slots
    done = eng.run(reqs)
    assert len(done) == 4
    for r in done:
        assert len(r.out) == 5
        assert all(0 <= t < CFG.vocab for t in r.out)


def test_stream_report_tracks_plane_weights():
    from repro.core import stream_report
    tr, _ = _trainer()
    rep = stream_report(tr.params, PlaneConfig(4, 16),
                        np.array([0.5, 0.5, 0.0, 0.0]))
    assert rep.bytes_per_plane[2] == 0.0 and rep.bytes_per_plane[3] == 0.0
    assert rep.bytes_per_plane[0] > 0
