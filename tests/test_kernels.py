"""Per-kernel allclose vs the ref.py oracles — shape/dtype sweeps,
interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(1, 2, 128, 64), (2, 4, 256, 128),
                                   (1, 1, 384, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 64)])
def test_flash_attention_sweep(shape, dtype, causal, window):
    B, H, S, D = shape
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, shape, dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape, dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape, dtype)
    bq = bk = min(128, S)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_gqa_wrapper():
    key = jax.random.PRNGKey(1)
    B, S, Hq, Hkv, D = 2, 128, 8, 2, 64
    q = jax.random.normal(key, (B, S, Hq, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    out = ops.flash_attention_bshd(q, k, v, bq=64, bk=64)
    kr = jnp.repeat(k, Hq // Hkv, 2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, Hq // Hkv, 2).transpose(0, 2, 1, 3)
    want = ref.flash_attention_ref(q.transpose(0, 2, 1, 3), kr, vr
                                   ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,bk", [(256, 64), (512, 512), (384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(S, bk, dtype):
    key = jax.random.PRNGKey(2)
    B, H, D = 2, 4, 64
    q = jax.random.normal(key, (B, H, 1, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D), dtype)
    lengths = jnp.array([S // 2, S], jnp.int32)
    out = ops.decode_attention(q, k, v, lengths, bk=bk)
    want = ref.decode_attention_ref(q, k, v, lengths)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("ports", [16, 64, 256])
def test_jsq_route_sweep(ports):
    key = jax.random.PRNGKey(3)
    queues = jax.random.uniform(key, (ports,))
    up = (jnp.arange(ports) % 7 != 0).astype(jnp.float32)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (ports,),
                           minval=0.25, maxval=1.0)
    h = jax.random.randint(key, (512,), 0, 1 << 30).astype(jnp.uint32)
    got = ops.jsq_route(queues, up, w, h)
    want = ref.jsq_route_ref(queues, up, w, h)
    assert bool((got == want).all())
    # never routes to a down port
    assert not set(np.asarray(got)) & set(
        np.flatnonzero(np.asarray(up) == 0))


@pytest.mark.parametrize("planes", [2, 4, 8])
def test_plb_select_sweep(planes):
    key = jax.random.PRNGKey(4)
    ra = jax.random.uniform(key, (planes,))
    el = (jax.random.uniform(jax.random.fold_in(key, 1), (planes,))
          > 0.2).astype(jnp.float32)
    if float(el.sum()) == 0:
        el = el.at[0].set(1.0)
    lq = jax.random.uniform(jax.random.fold_in(key, 2), (planes,))
    tx = jax.random.uniform(jax.random.fold_in(key, 3), (300,),
                            maxval=0.5)
    h = jax.random.randint(key, (300,), 0, 1 << 30).astype(jnp.uint32)
    got = ops.plb_select(ra, el, lq, tx, h)
    want = ref.plb_select_ref(ra, el, lq, tx, h)
    assert bool((got == want).all())
    # never selects an ineligible plane
    bad = set(np.flatnonzero(np.asarray(el) == 0))
    assert not set(np.asarray(got)) & bad


@pytest.mark.parametrize("mode", ["spx", "dcqcn", "agg", "swlb"])
@pytest.mark.parametrize("F,P", [(64, 1), (300, 4), (1000, 8)])
def test_plane_split_batched_vs_ref(mode, F, P):
    """The simulator's per-slot NIC plane split: Pallas batched layout
    vs the jnp oracle that the engine itself runs on non-TPU backends."""
    key = jax.random.PRNGKey(6)
    rate = jax.random.uniform(key, (F, P), minval=0.05)
    elig = jax.random.uniform(jax.random.fold_in(key, 1), (F, P)) > 0.25
    elig = elig.at[:, 0].set(True)          # each flow has a live plane
    demand = jax.random.uniform(jax.random.fold_in(key, 2), (F,))
    got = ops.plane_split(rate, elig, demand, mode=mode, min_rate=0.05)
    want = ref.plane_split_ref(rate, elig, demand, mode=mode,
                               min_rate=0.05)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # conservation: a flow never sends more than its demand
    assert (np.asarray(got).sum(1) <= np.asarray(demand) + 1e-5).all()


@pytest.mark.parametrize("P,L,S", [(1, 8, 8), (4, 4, 8), (2, 16, 4)])
@pytest.mark.parametrize("war", [False, True])
def test_pair_fractions_batched_vs_ref(P, L, S, war):
    """The switch AR/WAR spine scoring + softmax (quantized JSQ): Pallas
    rowwise layout vs the jnp oracle, including dead paths and weighted
    remote capacity."""
    key = jax.random.PRNGKey(7)
    q = jax.random.uniform(key, (P, L, L, S), maxval=8.0)
    cap = jax.random.uniform(jax.random.fold_in(key, 1), (P, L, L, S))
    cap = jnp.where(jax.random.uniform(jax.random.fold_in(key, 2),
                                       cap.shape) < 0.15, 0.0, cap)
    cap = cap.at[..., 0].set(jnp.maximum(cap[..., 0], 0.1))  # alive spine
    w = cap
    if war:
        w = cap * jax.random.uniform(jax.random.fold_in(key, 3),
                                     cap.shape, minval=0.25)
    got = ops.pair_fractions(q, cap, w, nbins=16, temperature=1.0)
    want = ref.pair_score_softmax_ref(q, cap, w, nbins=16,
                                      temperature=1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    sums = np.asarray(got).sum(-1)
    np.testing.assert_allclose(sums, 1.0, atol=1e-5)   # softmax rows
    assert (np.asarray(got)[np.asarray(cap) <= 1e-9] == 0).all()


@pytest.mark.parametrize("shape", [(256, 128), (512, 64), (1024, 512)])
def test_int8_codec_sweep(shape):
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, shape) * 5
    noise = jax.random.uniform(jax.random.fold_in(key, 1), shape,
                               minval=-0.5, maxval=0.5)
    q, s = ops.int8_encode(x, noise)
    qr, sr = ref.int8_encode_ref(x, noise)
    assert bool((q == qr).all())
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = ops.int8_decode(q, s)
    err = np.abs(np.asarray(xd - x))
    # error bounded by one quantization step (stochastic rounding)
    bound = np.asarray(s) * 1.001 + 1e-6
    assert (err <= bound).all()
