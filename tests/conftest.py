import os

# Tests run single-device (the dry-run alone uses 512 fake devices).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

# x64 stays off for tier-1 (model/kernel tests expect f32); the CI
# jax-backend job exports JAX_ENABLE_X64=1 for the parity/golden suites.
# Backend-parity tests additionally scope x64 via
# jax.experimental.enable_x64, so they hold under either default.
jax.config.update(
    "jax_enable_x64",
    os.environ.get("JAX_ENABLE_X64", "0").lower() in ("1", "true", "t",
                                                      "yes", "y", "on"))


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="re-baseline tests/golden/scenarios.json from the current "
             "NumPy backend instead of comparing against it (commit the "
             "diff deliberately — it redefines the regression baseline)")
