import os

# Tests run single-device (the dry-run alone uses 512 fake devices).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
