"""Megabatch dispatch: one fused launch per sweep, row-identical to the
per-group path.

The megabatch path lifts routing/nic into per-element traced branch
selectors and stacks whole grids into one `jit(vmap)` launch
(`repro.netsim.jx.megabatch`).  These tests pin:

  * row-identity (1e-5, x64) against the per-group executor across the
    full routing × nic cross, mixed fault timelines (fault axes with
    differing segment counts), and mixed scenarios whose flow counts
    land in different padding buckets;
  * the single-launch property: a multi-axis grid = 1 dispatch and 1
    program compile (the bench JSON's acceptance metric);
  * the `_jitted` device-fingerprint regression (a pmap built for N
    devices must not be reused when the device set changes).
"""
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.experiments import Axis, Experiment, execute_points, product
from repro.netsim.jx import dispatch_stats, reset_dispatch_stats
from repro.netsim.jx import engine
from repro.scenarios import list_scenarios

TOL = 1e-5


def _grid_points(base, axes):
    exp = Experiment(name=f"test_megabatch.{base}", base=base,
                     axes=product(*axes))
    return [p.spec for p in exp.points()]


def _run_both(points):
    with enable_x64():
        group = execute_points(points, backend="jax",
                               jx_dispatch="group")
        mega = execute_points(points, backend="jax",
                              jx_dispatch="megabatch")
    return group, mega


def _assert_rows_identical(points, group, mega):
    for p, a, b in zip(points, group, mega):
        where = f"{p.name} {p.sim.routing}/{p.sim.nic} seed={p.sim.seed}"
        assert b.to_row() == a.to_row(), where
        assert b.mean_goodput == pytest.approx(a.mean_goodput, abs=TOL)
        assert b.isolation_index == pytest.approx(a.isolation_index,
                                                  abs=TOL)
        assert b.recovery_slots == a.recovery_slots, where
        for t in a.tenant_mean:
            assert b.tenant_mean[t] == pytest.approx(a.tenant_mean[t],
                                                     abs=TOL)
            assert b.tenant_p01[t] == pytest.approx(a.tenant_p01[t],
                                                    abs=TOL)
        if not (np.isnan(a.completion_tail)
                and np.isnan(b.completion_tail)):
            assert b.completion_tail == pytest.approx(a.completion_tail,
                                                      abs=TOL)


def test_megabatch_full_routing_nic_cross_row_identity():
    """The acceptance claim: every (routing, nic) pair of the registry
    cross, fused into one launch, matches the per-group dispatch."""
    points = _grid_points("flap_during_incast", [
        Axis("sim.routing", ("ar", "war", "ecmp")),
        Axis("sim.nic", ("spx", "dcqcn", "global", "esr", "swlb")),
        Axis("seed", (0, 1)),
        Axis("sim.slots", (100,)),
    ])
    reset_dispatch_stats()
    group, mega = _run_both(points)
    _assert_rows_identical(points, group, mega)


def test_megabatch_mixed_fault_timelines():
    """Fault axes change the timeline data and the number of
    piecewise-constant segments per point — segment-count padding must
    stay inert."""
    points = _grid_points("flap_during_incast", [
        Axis("sim.routing", ("ar", "ecmp")),
        Axis("faults[0].frac", (0.3, 0.9)),
        Axis("faults[0].period", (40, 70)),
        Axis("seed", (0, 1)),
        Axis("sim.slots", (160,)),
    ])
    group, mega = _run_both(points)
    _assert_rows_identical(points, group, mega)


def test_megabatch_mixed_scenarios_flow_buckets():
    """Scenarios with different flow populations share a launch when
    they land in the same power-of-two flow bucket (60 and 64 flows ->
    bucket 64) and split into another when they don't (30 -> 32); the
    finite-transfer scenario also exercises completion slots through
    the flow padding."""
    points = _grid_points(None, [
        Axis("scenario", ("flap_during_incast",
                          "allreduce_under_random_failures",
                          "staggered_incast_bursts")),
        Axis("sim.routing", ("ar", "ecmp")),
        Axis("seed", (0, 1)),
        Axis("sim.slots", (120,)),
    ])
    reset_dispatch_stats()
    group, mega = _run_both(points)
    stats = dispatch_stats()
    # megabatch: two flow buckets -> exactly 2 fused launches for 12
    # points (the per-group path dispatched 6 structures before it)
    assert stats["dispatches"] - 6 == 2
    _assert_rows_identical(points, group, mega)


def test_megabatch_multi_axis_grid_single_compile():
    """A 3-axis grid (nic x fault x seed) is ONE dispatch and ONE
    program compile — the dispatch-count metric CI asserts from
    BENCH_backend.json.  slots=101 keeps the program fingerprint unique
    to this test regardless of suite order."""
    points = _grid_points("flap_during_incast", [
        Axis("sim.routing", ("ar", "war", "ecmp")),
        Axis("sim.nic", ("spx", "dcqcn")),
        Axis("faults[0].frac", (0.4, 0.8)),
        Axis("seed", (0, 1)),
        Axis("sim.slots", (101,)),
    ])
    reset_dispatch_stats()
    execute_points(points, backend="jax", jx_dispatch="megabatch")
    stats = dispatch_stats()
    assert stats["dispatches"] == 1
    assert stats["compiles"] == 1
    # warm re-run: same program, no new compile
    reset_dispatch_stats()
    execute_points(points, backend="jax", jx_dispatch="megabatch")
    stats = dispatch_stats()
    assert stats["dispatches"] == 1
    assert stats["compiles"] == 0


def test_megabatch_mixed_topology_kinds_one_compile_per_bucket():
    """A grid mixing leaf_spine and fat_tree points (the topology-axis
    experiment shape) fuses into exactly one launch and one program
    compile per topology-kind shape bucket, row-identical to the
    per-group dispatch."""
    points = _grid_points(None, [
        Axis("scenario", ("bisection_multiplane", "bisection_fat_tree")),
        Axis("sim.routing", ("war", "ecmp")),
        Axis("seed", (0, 1)),
        Axis("sim.slots", (200,)),      # random_fail at 150 still fires
    ])
    reset_dispatch_stats()
    with enable_x64():
        mega = execute_points(points, backend="jax",
                              jx_dispatch="megabatch")
    stats = dispatch_stats()
    assert stats["dispatches"] == 2, stats   # one per topology kind
    assert stats["compiles"] == 2, stats
    with enable_x64():
        group = execute_points(points, backend="jax", jx_dispatch="group")
    _assert_rows_identical(points, group, mega)


def test_jitted_rebuilds_on_device_set_change(monkeypatch):
    """Regression: `_jitted` used to key its memo on `JxConfig` only, so
    a pmap callable built for N host devices was silently reused after
    the visible device set changed."""
    from repro.scenarios import compile_scenario, get_scenario

    spec = get_scenario("flap_during_incast").with_sim(slots=50)
    cfg = engine.JxConfig.from_sim(compile_scenario(spec).cfg, spec.topo)
    fn_a = engine._jitted(cfg, batched=True, n_shards=1)
    assert engine._jitted(cfg, batched=True, n_shards=1) is fn_a
    monkeypatch.setattr(engine, "_device_fingerprint",
                        lambda: (("cpu", 0), ("cpu", 1)))
    fn_b = engine._jitted(cfg, batched=True, n_shards=1)
    assert fn_b is not fn_a
    monkeypatch.undo()
    assert engine._jitted(cfg, batched=True, n_shards=1) is fn_a


def test_stack_idx_covers_every_routing_nic():
    from repro.scenarios.spec import NICS, ROUTINGS

    seen = set()
    for r in ROUTINGS:
        for n in NICS:
            row = engine.stack_idx_for(r, n)
            assert row[0] in (engine.ROUTE_PAIR, engine.ROUTE_ECMP)
            assert (row[0] == engine.ROUTE_ECMP) == (r == "ecmp")
            assert row[1] == (r == "war")
            assert row[3] == (n == "esr")
            seen.add(row)
    # every (routing, nic) pair maps to a distinct selector row
    # (global/esr share branch indices but differ in is_esr)
    assert len(seen) == len(ROUTINGS) * len(NICS)


@pytest.mark.slow
@pytest.mark.parametrize("routing", ["ar", "war", "ecmp"])
@pytest.mark.parametrize("nic", ["spx", "dcqcn"])
def test_megabatch_registry_wide_row_identity(routing, nic):
    """Registry-wide: every scenario (mixed flow buckets, timelines,
    finite transfers) through one executor call per (routing, nic),
    megabatch vs per-group.  Schedule scenarios pin their own horizon
    (the compiler rejects a sim too short for every training step), so
    they keep their registry slots instead of the 150-slot shrink."""
    from repro.scenarios import get_scenario
    sched = tuple(n for n in list_scenarios() if any(
        w.kind == "schedule" for w in get_scenario(n).workloads))
    rest = tuple(n for n in list_scenarios() if n not in sched)
    points = _grid_points(None, [
        Axis("scenario", rest),
        Axis("sim.routing", (routing,)),
        Axis("sim.nic", (nic,)),
        Axis("sim.slots", (150,)),
    ]) + _grid_points(None, [
        Axis("scenario", sched),
        Axis("sim.routing", (routing,)),
        Axis("sim.nic", (nic,)),
    ])
    group, mega = _run_both(points)
    _assert_rows_identical(points, group, mega)
