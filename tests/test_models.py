"""Per-architecture smoke tests (reduced configs) + numeric invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import (decode_step, init_caches, init_params, loss_fn,
                          param_count, prefill_step)
from repro.models.config import ModelConfig
from repro.parallel.sharding import local_ctx

CTX = local_ctx()


def _batch(cfg, B=2, S=32):
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    out = {"tokens": toks, "labels": toks}
    if cfg.frontend != "none" and cfg.frontend_tokens:
        out["frontend_embeds"] = jnp.zeros(
            (B, min(cfg.frontend_tokens, S), cfg.d_model),
            jnp.dtype(cfg.dtype))
    return out


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one forward/train step on CPU, output
    shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    cfg.validate()
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert param_count(params) > 0
    batch = _batch(cfg)
    if cfg.frontend_tokens:
        batch["frontend_embeds"] = jnp.zeros(
            (2, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    loss, metrics = jax.jit(
        lambda p, b: loss_fn(p, cfg, b, CTX))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: loss_fn(p, cfg, batch, CTX)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    B, S = 2, 16
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    caches = init_caches(cfg, B, 64, jnp.dtype(cfg.dtype))
    logits, caches = prefill_step(params, cfg, toks, CTX, caches)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = decode_step(params, cfg, nxt,
                             jnp.full((B,), S, jnp.int32), CTX, caches)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("family", ["gqa", "mla", "ssm", "hybrid"])
def test_decode_matches_full_forward(family):
    kw = {
        "gqa": dict(block_pattern=("a", "l"), window=16, n_kv_heads=2),
        "mla": dict(use_mla=True, q_lora=32, kv_lora=32, rope_head_dim=8,
                    nope_head_dim=16, v_head_dim=16),
        "ssm": dict(block_pattern=("m",), ssm_state=16, ssm_heads=4,
                    ssm_head_dim=8, ssm_groups=2, ssm_chunk=8),
        "hybrid": dict(block_pattern=("m", "a"), ssm_state=16, ssm_heads=4,
                       ssm_head_dim=8, ssm_groups=2, ssm_chunk=8,
                       n_kv_heads=2, moe_experts=4, moe_topk=2,
                       moe_d_ff=64, moe_every=2, capacity_factor=8.0),
    }[family]
    cfg = ModelConfig(name=family, n_layers=4, d_model=64, n_heads=4,
                      n_kv_heads=kw.pop("n_kv_heads", 4), head_dim=16,
                      d_ff=128, vocab=128, attn_chunk=16, remat="none",
                      dtype="float32", param_dtype="float32", **kw)
    p = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(42), (B, S + 1), 0, 128)
    lg_full, _ = prefill_step(p, cfg, toks, CTX,
                              init_caches(cfg, B, 64, jnp.float32))
    caches = init_caches(cfg, B, 64, jnp.float32)
    _, caches = prefill_step(p, cfg, toks[:, :S], CTX, caches)
    lg_dec, _ = decode_step(p, cfg, toks[:, S:S + 1],
                            jnp.full((B,), S, jnp.int32), CTX, caches)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_masks_far_tokens():
    """An 'l' layer must ignore tokens beyond the window."""
    from repro.models.attention import chunked_attention
    B, S, H, D = 1, 32, 2, 8
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    pos = jnp.arange(S)[None]
    out1 = chunked_attention(q, k, v, pos, pos, window=4, chunk=8)
    # perturb tokens far outside every query's window
    k2 = k.at[:, :8].set(99.0)
    v2 = v.at[:, :8].set(99.0)
    out2 = chunked_attention(q, k2, v2, pos, pos, window=4, chunk=8)
    np.testing.assert_allclose(np.asarray(out1[:, 16:]),
                               np.asarray(out2[:, 16:]), rtol=1e-5)


def test_chunk_size_invariance():
    """Chunked attention is exact for any block size."""
    from repro.models.attention import chunked_attention
    B, S, H, D = 2, 48, 2, 8
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    pos = jnp.arange(S)[None].repeat(B, 0)
    outs = [chunked_attention(q, k, v, pos, pos, chunk=c)
            for c in (8, 16, 48)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= topk*E/E every token fits; loss must match a
    full-capacity run."""
    base = dict(name="m", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab=64, moe_experts=4, moe_topk=2,
                moe_d_ff=32, attn_chunk=16, remat="none", dtype="float32",
                param_dtype="float32")
    cfg_hi = ModelConfig(capacity_factor=8.0, **base)
    cfg_lo = ModelConfig(capacity_factor=0.25, **base)
    p = init_params(jax.random.PRNGKey(0), cfg_hi)
    batch = _batch(cfg_hi, B=2, S=16)
    l_hi, _ = loss_fn(p, cfg_hi, batch, CTX)
    l_lo, _ = loss_fn(p, cfg_lo, batch, CTX)
    assert bool(jnp.isfinite(l_hi)) and bool(jnp.isfinite(l_lo))
    assert abs(float(l_hi) - float(l_lo)) < 2.0   # drops degrade, not NaN


def test_ssd_scan_matches_naive_recurrence():
    from repro.models.ssm import ssd_scan
    key = jax.random.PRNGKey(0)
    b, s, h, p, g, n = 2, 24, 4, 8, 2, 8
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, g, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, g, n))
    y, final = ssd_scan(x, dt, A, B, C, chunk=8)
    # naive sequential recurrence
    hg = h // g
    Bh = jnp.repeat(B, hg, axis=2)
    Ch = jnp.repeat(C, hg, axis=2)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * A)                        # (b,h)
        inc = jnp.einsum("bh,bhn,bhp->bhpn", dt[:, t], Bh[:, t], x[:, t])
        state = state * da[..., None, None] + inc
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch[:, t], state))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               rtol=2e-4, atol=2e-4)
