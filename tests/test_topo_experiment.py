"""ISSUE 5 acceptance: the registered topology-axis experiment
(`topo_kind_resiliency`) sweeps kind ∈ {leaf_spine, fat_tree} x routing
x fault-frac through the megabatch path with numpy↔jax row parity at
1e-5 (x64), and the multiplane fabric shows strictly higher post-failure
bisection throughput than the equal-cost fat-tree in the resiliency
scenario."""
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.experiments import (Axis, Experiment, get_experiment, product,
                               run_experiment)
from repro.scenarios import get_scenario, run_point

TOL = 1e-5


def _row_parity(exp):
    rs_np = run_experiment(exp, backend="numpy", processes=2)
    with enable_x64():
        rs_jx = run_experiment(exp, backend="jax")   # megabatch default
    rows_np, rows_jx = rs_np.to_metrics(), rs_jx.to_metrics()
    assert len(rows_np) == len(rows_jx) == len(exp.points())
    kinds = set()
    for p, a, b in zip(exp.points(), rows_np, rows_jx):
        kinds.add(p.spec.topo.kind)
        where = f"{a.scenario} {a.routing} {p.coords}"
        assert b.mean_goodput == pytest.approx(a.mean_goodput,
                                               abs=TOL), where
        assert b.isolation_index == pytest.approx(a.isolation_index,
                                                  abs=TOL), where
        assert b.recovery_slots == a.recovery_slots, where
        for key in ("post_failure_bw", "post_failure_p01"):
            assert b.extra[key] == pytest.approx(a.extra[key],
                                                 abs=TOL), where
    assert kinds == {"leaf_spine", "fat_tree"}
    return rows_np


def test_topo_kind_experiment_megabatch_row_parity():
    """Reduced-horizon version of the registered grid for tier-1: same
    axes, slots cut to 200 (the slot-150 fault still fires)."""
    base = get_experiment("topo_kind_resiliency")
    exp = Experiment(name="topo_kind_resiliency.t1",
                     axes=product(base.grid(),
                                  Axis("sim.slots", (200,))),
                     derive=base.derive)
    _row_parity(exp)


@pytest.mark.slow
def test_topo_kind_experiment_full_length():
    """The registered experiment verbatim, both backends."""
    _row_parity(get_experiment("topo_kind_resiliency"))


def test_multiplane_beats_equal_cost_fat_tree_post_failure():
    """The §3.1 headline, strict: at the resiliency scenario's operating
    point (25% uniform link failures, SPX + weighted-AR) the flat
    multiplane's post-failure bisection throughput exceeds the
    equal-bisection fat-tree's — the 4-hop cross-pod min-cuts strand
    surviving capacity that the 2-hop multiplane keeps usable."""
    ls = run_point(get_scenario("bisection_multiplane"))
    ft = run_point(get_scenario("bisection_fat_tree"))
    assert np.isfinite(ls.mean_goodput) and np.isfinite(ft.mean_goodput)
    assert ls.mean_goodput > ft.mean_goodput, (ls.mean_goodput,
                                               ft.mean_goodput)
    # the margin is structural (~30%+ across seeds), not noise
    assert ls.mean_goodput > 1.15 * ft.mean_goodput
