"""HFT trace capture (§5.3): backend parity, megabatch fusion with
tracing on, program identity with tracing off, exports, and the fig12
§5.2 acceptance signature.

Unique `sim.slots` values (137, 91, 73) keep jit program fingerprints
local to this file regardless of suite order.
"""
import json

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core.telemetry import bw_histogram, classify_histogram, \
    find_stragglers
from repro.experiments import ResultSet, execute_points
from repro.netsim.jx import dispatch_stats, reset_dispatch_stats
from repro.netsim.jx.engine import run_compiled
from repro.scenarios import compile_scenario, get_scenario
from repro.scenarios.runner import run_point
from repro.trace import (TRACE_FIELDS, TraceSpec, trace_summary,
                         trace_to_npz, trace_to_perfetto)

TOL = 1e-5


def _fig12(slots, **trace_kw):
    return get_scenario("fig12_plane_flap").with_sim(
        slots=slots, trace=TraceSpec(enabled=True, **trace_kw))


def _assert_traces_close(a, b, where=""):
    assert set(a) == set(b), where
    for k in a:
        x = np.asarray(a[k], np.float64)
        y = np.asarray(b[k], np.float64)
        assert x.shape == y.shape, f"{where} {k}: {x.shape} vs {y.shape}"
        assert np.abs(x - y).max() < TOL, f"{where} {k}"


def test_trace_spec_validation():
    with pytest.raises(ValueError):
        TraceSpec(enabled=True, every=0).validate()
    with pytest.raises(ValueError):
        TraceSpec(fields=("host_bw", "nope")).validate()
    with pytest.raises(ValueError):
        TraceSpec(enabled=True, fields=()).validate()
    with pytest.raises(ValueError):
        get_scenario("fig12_plane_flap").with_sim(
            trace=TraceSpec(enabled=True, every=-3)).validate()
    assert TraceSpec(fields=("queue", "host_bw")).active_fields() == \
        ("host_bw", "queue")        # canonical capture order


def test_trace_numpy_jax_parity_fig12():
    """Every trace field matches 1e-5 (x64) between the numpy loop and
    the jx scan on the fig12 flap scenario."""
    spec = _fig12(slots=137)
    rn = compile_scenario(spec).run()
    with enable_x64():
        rj = run_compiled(compile_scenario(spec))
    assert set(rn.trace) == set(TRACE_FIELDS) | {"slot"}
    _assert_traces_close(rn.trace, rj.trace, "fig12")


def test_trace_decimation_and_field_subset():
    """`every=7` records slots 0,7,14,... on both backends; a fields
    subset captures only those fields."""
    spec = _fig12(slots=137, every=7, fields=("host_bw", "queue"))
    rn = compile_scenario(spec).run()
    with enable_x64():
        rj = run_compiled(compile_scenario(spec))
    expect = np.arange(0, 137, 7)
    assert np.array_equal(rn.trace["slot"], expect)
    assert set(rn.trace) == {"slot", "host_bw", "queue"}
    assert rn.trace["host_bw"].shape[0] == expect.shape[0]
    _assert_traces_close(rn.trace, rj.trace, "decimated")


def test_trace_off_no_capture_and_program_reuse():
    """Tracing off: `res.trace` is None on both backends, and the jx
    program is byte-for-byte the pre-trace program — enabling tracing
    compiles a *different* program, after which the trace-off grid still
    reuses its original compile (0 new compiles)."""
    base = get_scenario("flap_during_incast").with_sim(slots=91)
    points_off = [base.with_sim(routing=r) for r in ("ar", "ecmp")]
    points_on = [p.with_sim(trace=TraceSpec(enabled=True))
                 for p in points_off]
    assert compile_scenario(points_off[0]).run().trace is None

    reset_dispatch_stats()
    res_off = execute_points(points_off, backend="jax",
                             jx_dispatch="megabatch")
    assert dispatch_stats() == {"dispatches": 1, "compiles": 1}
    assert all(np.isnan(m.bimodal_frac) for m in res_off)
    assert all(m.hft_transient_drops == -1 for m in res_off)

    reset_dispatch_stats()
    execute_points(points_on, backend="jax", jx_dispatch="megabatch")
    assert dispatch_stats() == {"dispatches": 1, "compiles": 1}

    # back to trace-off: the original fused program serves the grid warm
    reset_dispatch_stats()
    execute_points(points_off, backend="jax", jx_dispatch="megabatch")
    assert dispatch_stats() == {"dispatches": 1, "compiles": 0}


def test_megabatch_traced_one_compile_per_bucket_and_trace_parity():
    """A traced multi-scenario grid still fuses to one compile per flow
    bucket, and every point's (bucket-padded, lane-sorted) raw trace
    matches the single-point jx reference — pinning the flow-axis strip
    in `finalize_group`."""
    from repro.netsim.jx.megabatch import (dispatch_megabatch,
                                           finalize_group)

    ts = TraceSpec(enabled=True)
    points = [get_scenario(s).with_sim(slots=73, routing=r, trace=ts)
              for s in ("flap_during_incast", "staggered_incast_bursts")
              for r in ("ar", "ecmp")]
    with enable_x64():
        compiled = [compile_scenario(p) for p in points]
        reset_dispatch_stats()
        res = {}
        for idxs, handle in dispatch_megabatch(compiled):
            for i, r in zip(idxs, finalize_group(handle)):
                res[i] = r
        stats = dispatch_stats()
        assert stats["dispatches"] == 2, stats   # two flow buckets
        assert stats["compiles"] == 2, stats
        for i, (p, c) in enumerate(zip(points, compiled)):
            ref = run_compiled(compile_scenario(p))
            _assert_traces_close(res[i].trace, ref.trace, p.name)
            assert res[i].trace["ecn"].shape[1] == len(c.flows)


def test_fig12_acceptance_signature():
    """§5.2 on the full fig12 run: the flapped (host 0, plane 1) port is
    bi-modal healthy-blocked, the surviving ports are line-rate, host 0
    is the named straggler, and a quarter of active ports are bi-modal."""
    spec = _fig12(slots=600)
    res = compile_scenario(spec).run()
    cap = spec.topo.access_cap
    port = res.trace["host_bw"] / cap
    assert classify_histogram(bw_histogram(port[:, 0, 1])) == \
        "healthy-blocked"
    for plane in (0, 2, 3):
        assert classify_histogram(bw_histogram(port[:, 0, plane])) == \
            "line-rate"
    host = res.trace["host_bw"].sum(2) / (cap * spec.topo.n_planes)
    assert find_stragglers(host.T) == [0]

    summ = trace_summary(res.trace, cap, spec.topo.n_planes)
    assert summ["straggler_ranks"] == (0,)
    assert summ["bimodal_frac"] == pytest.approx(0.25)
    assert summ["hft_transient_drops"] >= 0

    m = run_point(spec)
    assert m.straggler_ranks == (0,)
    assert m.bimodal_frac == pytest.approx(0.25)
    assert m.extra["port_classes"]["healthy-blocked"] == 1


def test_trace_exports_roundtrip(tmp_path):
    spec = _fig12(slots=137)
    res = compile_scenario(spec).run()
    npz = tmp_path / "t.npz"
    pft = tmp_path / "t.json"
    trace_to_npz(str(npz), res.trace, slot_us=spec.sim.slot_us)
    trace_to_perfetto(str(pft), res.trace, slot_us=spec.sim.slot_us,
                      label="fig12")
    z = np.load(str(npz))
    assert np.array_equal(z["host_bw"], res.trace["host_bw"])
    assert float(z["slot_us"]) == spec.sim.slot_us
    doc = json.loads(pft.read_text())
    events = doc["traceEvents"]
    assert events and all("ts" in e for e in events)
    # the plane-1 access kill at slot 50 shows up as a failover instant
    instants = [e for e in events if e["ph"] == "i"]
    assert any("plane1 failover" in e["name"] for e in instants)
    # counter tracks exist for every host and plane
    names = {e["name"] for e in events}
    assert "host0.goodput" in names and "plane1.util" in names


def test_trace_metrics_in_resultset_and_backfill():
    """Trace-derived columns ride ResultSet JSON/CSV round-trips, and
    serializations written before the columns existed still load (the
    defaults are backfilled)."""
    m = run_point(_fig12(slots=137))
    rs = ResultSet()
    rs.append(m)
    rt = ResultSet.from_json(rs.to_json()).to_metrics()[0]
    assert rt.straggler_ranks == m.straggler_ranks
    assert rt.bimodal_frac == pytest.approx(m.bimodal_frac)
    assert rt.hft_transient_drops == m.hft_transient_drops
    rc = ResultSet.from_csv(rs.to_csv()).to_metrics()[0]
    assert rc.straggler_ranks == m.straggler_ranks

    # pre-trace JSON: new columns absent entirely
    d = json.loads(rs.to_json())
    for col in ("hft_transient_drops", "bimodal_frac", "straggler_ranks"):
        del d["columns"][col]
    old = ResultSet.from_json(json.dumps(d)).to_metrics()[0]
    assert old.hft_transient_drops == -1
    assert np.isnan(old.bimodal_frac)
    assert old.straggler_ranks == ()


def test_flight_recorder_attached():
    from repro.experiments import Axis, Experiment, run_experiment

    exp = Experiment(name="test_trace.flight", base="fig12_plane_flap",
                     axes=Axis("sim.slots", (137,)))
    rs = run_experiment(exp, backend="numpy")
    fl = rs.flight
    assert fl["cache_misses"] == 1
    [ex] = fl["executions"]
    assert ex["backend"] == "numpy" and ex["n_points"] == 1
    assert ex["points"][0]["wall_s"] > 0
    assert ResultSet.from_json(rs.to_json()).flight == fl

    rs2 = run_experiment(exp, backend="jax")
    [ex2] = rs2.flight["executions"]
    assert ex2["mode"] == "megabatch"
    assert "dispatches" in ex2["dispatch_stats"]
