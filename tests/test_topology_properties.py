"""Hypothesis property suite for topology invariants (ISSUE 5
satellite) over BOTH fabric kinds:

  * max-flow symmetry — every fault primitive degrades up/down link
    pairs together, so `maxflow_matrix` stays symmetric under any fault
    schedule;
  * monotone non-increase — no fault may increase any pair's max-flow;
  * capacity-proportional bisection after `random_fail` — the surviving
    cross-cut max-flow brackets between the per-path survival law of
    the fabric's hop count ((1-f)^2 for the 2-stage leaf-spine,
    (1-f)^4 for 4-hop cross-pod fat-tree paths) and the raw capacity
    fraction (1-f): the quantitative form of §6.4's claim that the
    multiplane degrades capacity-proportionally while the hierarchy
    strands surviving capacity;
  * the fat-tree fault-timeline compiler matches the callback-driven
    event closures slot by slot (the leaf-spine twin lives in
    `test_scenario_properties.py`).
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property tests skipped")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.netsim.jx.events import compile_fault_timeline  # noqa: E402
from repro.netsim.topology import (FatTree, LeafSpine,  # noqa: E402
                                   maxflow_matrix)
from repro.scenarios import (FaultSpec, ScenarioSpec, SimSpec,  # noqa: E402
                             TopologySpec, WorkloadSpec)
from repro.scenarios.compile import build_topology, make_events  # noqa: E402

SETTINGS = dict(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# hypothesis: fault invariants on both kinds
# ---------------------------------------------------------------------------

def _ls_topos():
    return st.builds(
        lambda L, S, P: LeafSpine(n_leaves=L, n_spines=S,
                                  hosts_per_leaf=2, n_planes=P),
        st.integers(2, 4), st.integers(2, 6), st.integers(1, 3))


def _ft_topos():
    return st.builds(
        lambda pods, lpp, A, cpa, P: FatTree(
            n_pods=pods, leaves_per_pod=lpp, n_aggs=A, n_cores=A * cpa,
            hosts_per_leaf=2, n_planes=P),
        st.integers(2, 3), st.integers(1, 3), st.integers(1, 4),
        st.integers(1, 3), st.integers(1, 2))


def _apply_random_fault(t, rng) -> None:
    kind = rng.integers(5 if t.kind == "fat_tree" else 3)
    p = int(rng.integers(t.n_planes))
    if kind == 0:
        t.fail_uplink(p, int(rng.integers(t.n_leaves)),
                      int(rng.integers(t.up.shape[2])),
                      float(rng.choice([0.5, 1.0])))
    elif kind == 1:
        t.trim_leaf_uplinks(p, int(rng.integers(t.n_leaves)),
                            float(rng.choice([0.25, 0.75])))
    elif kind == 2:
        t.random_link_failures(rng, float(rng.choice([0.1, 0.3])))
    elif kind == 3:
        t.fail_core_link(p, int(rng.integers(t.n_pods)),
                         int(rng.integers(t.n_cores)),
                         float(rng.choice([0.5, 1.0])))
    else:
        t.fail_agg(p, int(rng.integers(t.n_pods)),
                   int(rng.integers(t.n_aggs)))


@given(data=st.one_of(_ls_topos(), _ft_topos()),
       seed=st.integers(0, 2 ** 16), n_faults=st.integers(0, 4))
@settings(**SETTINGS)
def test_maxflow_symmetric_and_monotone_under_faults(data, seed, n_faults):
    t = data
    rng = np.random.default_rng(seed)
    prev = maxflow_matrix(t)
    assert np.allclose(prev, prev.T)
    for _ in range(n_faults):
        _apply_random_fault(t, rng)
        mf = maxflow_matrix(t)
        assert np.allclose(mf, mf.T), "symmetric capacities -> symmetric"
        assert (mf <= prev + 1e-9).all(), "faults never increase max-flow"
        assert (mf >= -1e-12).all()
        prev = mf


@given(kind=st.sampled_from(["leaf_spine", "fat_tree"]),
       seed=st.integers(0, 2 ** 16),
       frac=st.sampled_from([0.05, 0.1, 0.2]))
@settings(**SETTINGS)
def test_capacity_proportional_bisection_after_random_fail(kind, seed,
                                                          frac):
    """Cross-cut max-flow after uniform random link failures brackets
    between the hop-count survival law and raw capacity proportionality
    (±10% for per-draw noise).  The fat-tree's 4-hop exponent IS the
    hierarchy penalty the multiplane design deletes."""
    if kind == "leaf_spine":
        t = LeafSpine(n_leaves=8, n_spines=16, hosts_per_leaf=2,
                      n_planes=2)
        hops = 2
    else:
        t = FatTree(n_pods=2, leaves_per_pod=4, n_aggs=8, n_cores=16,
                    hosts_per_leaf=2, core_link_cap=4.0)
        hops = 4
    L = t.n_leaves
    left = np.arange(L // 2)
    right = np.arange(L // 2, L)
    base = maxflow_matrix(t)[np.ix_(left, right)].sum()
    t.random_link_failures(np.random.default_rng(seed), frac)
    after = maxflow_matrix(t)[np.ix_(left, right)].sum()
    ratio = after / base
    assert (1 - frac) ** hops - 0.10 <= ratio <= (1 - frac) + 0.10, \
        (kind, frac, ratio)


# ---------------------------------------------------------------------------
# fat-tree fault timeline == callback mutations, slot by slot
# ---------------------------------------------------------------------------

FT_TOPO = st.builds(
    TopologySpec, kind=st.just("fat_tree"),
    n_leaves=st.just(4), n_pods=st.just(2),
    n_aggs=st.sampled_from([1, 2]), n_cores=st.sampled_from([2, 4]),
    hosts_per_leaf=st.integers(2, 3), n_planes=st.integers(1, 2))


def _ft_fault_strategy(topo: TopologySpec, slots: int):
    planes = st.integers(-1, topo.n_planes - 1)
    start = st.integers(0, slots - 1)
    stop = st.one_of(st.none(), st.integers(1, slots + 10))
    frac = st.sampled_from([0.25, 0.5, 1.0])
    leaf = st.integers(0, topo.n_leaves - 1)
    agg = st.integers(0, topo.n_aggs - 1)
    period = st.integers(1, slots)
    return st.one_of(
        st.builds(FaultSpec, kind=st.just("link_kill"), start_slot=start,
                  stop_slot=stop, plane=planes, leaf=leaf, spine=agg,
                  frac=frac),
        st.builds(FaultSpec, kind=st.just("link_flap"), start_slot=start,
                  stop_slot=stop, period=period,
                  duty=st.sampled_from([0.25, 0.5]), plane=planes,
                  leaf=leaf, spine=agg, frac=frac),
        st.builds(FaultSpec, kind=st.just("core_kill"), start_slot=start,
                  stop_slot=stop, plane=planes,
                  pod=st.integers(0, topo.n_pods - 1),
                  core=st.integers(0, topo.n_cores - 1), frac=frac),
        st.builds(FaultSpec, kind=st.just("cascade"), start_slot=start,
                  period=period, plane=planes,
                  pod=st.integers(0, topo.n_pods - 1),
                  spines=st.lists(agg, min_size=1, max_size=2,
                                  unique=True).map(tuple)),
        st.builds(FaultSpec, kind=st.just("leaf_trim"), start_slot=start,
                  plane=planes, leaf=leaf, frac=frac),
        st.builds(FaultSpec, kind=st.just("random_fail"),
                  start_slot=start, frac=st.sampled_from([0.2, 0.5])),
        st.builds(FaultSpec, kind=st.just("random_fail"),
                  start_slot=start, plane=planes, frac=st.just(1.0),
                  count=st.integers(1, 3)),
        st.builds(FaultSpec, kind=st.just("straggler"), start_slot=start,
                  stop_slot=stop, plane=planes,
                  host=st.integers(0, topo.n_hosts - 1), frac=frac),
    )


@st.composite
def _ft_fault_specs(draw):
    topo = draw(FT_TOPO)
    slots = draw(st.integers(4, 30))
    faults = draw(st.lists(_ft_fault_strategy(topo, slots), min_size=0,
                           max_size=3))
    return ScenarioSpec(
        name="prop_ft_faults", topo=topo,
        workloads=(WorkloadSpec("pairs", pairs=((0, topo.n_hosts - 1),)),),
        faults=tuple(faults), sim=SimSpec(slots=slots),
        workload_seed=draw(st.integers(0, 2 ** 16))).validate()


@given(spec=_ft_fault_specs())
@settings(**SETTINGS)
def test_ft_timeline_matches_callback_mutations(spec):
    tl = compile_fault_timeline(spec)
    for arr in (tl.up, tl.down, tl.access, tl.up2, tl.down2):
        assert (arr >= 0).all()
    events, _ = make_events(spec)
    topo = build_topology(spec.topo)
    for t in range(spec.sim.slots):
        events(t, topo)
        np.testing.assert_allclose(
            tl.up[t] * spec.topo.uplink_cap, topo.up, rtol=0, atol=1e-12,
            err_msg=f"stage-A uplinks diverge at slot {t}")
        np.testing.assert_allclose(
            tl.down[t] * spec.topo.uplink_cap, topo.down, rtol=0,
            atol=1e-12, err_msg=f"stage-A downlinks diverge at slot {t}")
        np.testing.assert_allclose(
            tl.up2[t] * spec.topo.core_cap, topo.up2, rtol=0, atol=1e-12,
            err_msg=f"stage-B uplinks diverge at slot {t}")
        np.testing.assert_allclose(
            tl.down2[t] * spec.topo.core_cap, topo.down2, rtol=0,
            atol=1e-12, err_msg=f"stage-B downlinks diverge at slot {t}")
        np.testing.assert_allclose(
            tl.access[t] * spec.topo.access_cap, topo.access, rtol=0,
            atol=1e-12, err_msg=f"access diverges at slot {t}")
