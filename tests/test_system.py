"""End-to-end behaviour tests for the paper's system.

1. Sharded-vs-local numerics (8 fake devices, subprocess so the device
   count doesn't leak into other tests).
2. The full reproduction pipeline: train with plane-split collectives ->
   inject plane failure -> recover -> checkpoint -> serve.
"""
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import init_params, loss_fn
from repro.models.config import ModelConfig
from repro.models.transformer import logical_axes
from repro.parallel.sharding import ShardCtx, param_shardings, local_ctx
from repro.core import PlaneConfig, plane_allreduce

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
ctx = ShardCtx(mesh=mesh, dp_axes=("pod", "data"), tp_axis="model")
cfg = ModelConfig(name="m", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
                  moe_experts=4, moe_topk=2, moe_d_ff=64, attn_chunk=32,
                  remat="none", capacity_factor=8.0, dtype="float32",
                  param_dtype="float32")
params = init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
batch = {"tokens": toks, "labels": toks}
g_ref = jax.jit(jax.grad(
    lambda p, b: loss_fn(p, cfg, b, local_ctx(), aux_weight=0.0)[0]
))(params, batch)
params_s = jax.device_put(params,
                          param_shardings(logical_axes(cfg), ctx, params))
bshard = NamedSharding(mesh, P(("pod", "data"), None))
batch_s = jax.device_put(batch, {"tokens": bshard, "labels": bshard})

def dp_body(p, b, key):
    loss, grads = jax.value_and_grad(
        lambda pp: loss_fn(pp, cfg, b, ctx, aux_weight=0.0)[0])(p)
    grads = plane_allreduce(grads, ("pod", "data"), PlaneConfig(4, 8),
                            key=key)
    return jax.lax.pmean(loss, ("pod", "data")), grads

step = jax.jit(jax.shard_map(
    dp_body, mesh=mesh, in_specs=(P(), P(("pod", "data"), None), P()),
    out_specs=(P(), P()), axis_names={"pod", "data"}, check_vma=False))
loss, grads = step(params_s, batch_s, jax.random.PRNGKey(7))
err = max(
    float(jnp.max(jnp.abs(a - b))) / (float(jnp.max(jnp.abs(b))) + 1e-9)
    for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(g_ref)))
print(json.dumps({"rel_err": err, "loss": float(loss)}))
"""


def test_plane_allreduce_matches_global_gradient_8dev():
    """Plane-split DP sync == implicit global gradient (multi-pod mesh)."""
    r = subprocess.run(
        [sys.executable, "-c", DIST_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["rel_err"] < 1e-3, out


def test_full_pipeline_train_fail_recover_checkpoint_serve():
    from repro.core import PlaneConfig
    from repro.data import DataConfig, DataLoader
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.parallel.sharding import local_ctx
    from repro.train import Request, ServeEngine, Trainer, TrainerConfig

    cfg = ModelConfig(name="e2e", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
                      attn_chunk=32, remat="none")
    ctx = local_ctx()
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(plane=PlaneConfig(4, 8), ckpt_dir=d,
                             ckpt_every=4, warmup_steps=1, total_steps=20)
        tr = Trainer(cfg, ctx, tcfg,
                     init_params(jax.random.PRNGKey(0), cfg))
        dl = DataLoader(DataConfig(vocab=128, seq_len=32, global_batch=4))
        for i, b in zip(range(8), dl):
            if i == 3:
                tr.inject_plane_failure(0)
            if i == 6:
                tr.heal_plane(0)
            m = tr.train_step({k: jnp.asarray(v) for k, v in b.items()})
            assert np.isfinite(m["loss"])
        assert tr.failover.records[0].recovery_steps is not None
        from repro.checkpoint import latest_step
        assert latest_step(d) == 8
        eng = ServeEngine(cfg, ctx, tr.params, batch=2, max_len=48)
        done = eng.run([Request(0, np.arange(6, dtype=np.int32), 4)])
        assert done and len(done[0].out) == 4
