"""Scenario grid sweeps: seeds × stacks over named registry scenarios,
lowered onto `Experiment` definitions (scenario × seed axes per stack)
with an optional on-disk run cache and ResultSet JSON output.

CLI (also invoked by CI as a cached 2-point smoke):

  PYTHONPATH=src python -m benchmarks.scenario_sweep \
      --scenarios multi_tenant_50_50 flap_during_incast \
      --seeds 2 --slots 120 --processes 2 \
      --cache-dir /tmp/expcache --json-out sweep_resultset.json
"""
from __future__ import annotations

import argparse
import functools
import os
import sys
from typing import List, Optional

from repro.experiments import (Axis, Experiment, ResultSet, RunCache,
                               compile_cache_entries, product,
                               run_experiment)
from repro.scenarios import get_scenario, list_scenarios
from repro.trace import TraceSpec, trace_to_npz, trace_to_perfetto

from .common import emit, timeit

DEFAULT_SCENARIOS = ("multi_tenant_50_50", "flap_during_incast",
                     "cascading_spine_loss", "straggler_failure_compound")


def export_trace(spec, compiled, result, out_dir: str) -> dict:
    """Derive hook (module-level: process pools pickle it) writing each
    point's HFT trace as npz + Perfetto-JSON under `out_dir`."""
    trace = getattr(result, "trace", None)
    if trace is None:
        return {}
    stem = (f"{spec.name}.{spec.sim.nic}.{spec.sim.routing}"
            f".s{spec.sim.seed}")
    trace_to_npz(os.path.join(out_dir, f"{stem}.npz"), trace,
                 slot_us=spec.sim.slot_us, label=stem)
    trace_to_perfetto(os.path.join(out_dir, f"{stem}.perfetto.json"),
                      trace, slot_us=spec.sim.slot_us, label=stem)
    return {"trace_stem": stem}


def stack_experiment(scenarios, nic: str, routing: str, n_seeds: int,
                     slots: Optional[int],
                     trace_out: Optional[str] = None,
                     trace_every: int = 1) -> Experiment:
    """One stack's grid: scenario × seed, with the stack and horizon as
    single-value axes so they land in the ResultSet coordinates.  With
    `trace_out` the scenario axis carries pre-traced specs (labelled by
    name as usual) and the derive hook exports each point's trace."""
    specs = tuple(get_scenario(s) for s in scenarios)
    derive = None
    if trace_out:
        ts = TraceSpec(enabled=True, every=trace_every)
        specs = tuple(s.with_sim(trace=ts) for s in specs)
        derive = functools.partial(export_trace, out_dir=trace_out)
    axes = [Axis("scenario", specs),
            Axis("seed", tuple(range(n_seeds))),
            Axis("sim.nic", (nic,)),
            Axis("sim.routing", (routing,))]
    if slots:
        axes.append(Axis("sim.slots", (slots,)))
    return Experiment(name=f"scenario_sweep.{nic}.{routing}",
                      axes=product(*axes), derive=derive)


def run(scenarios=DEFAULT_SCENARIOS, n_seeds: int = 2,
        slots: Optional[int] = 200, processes: Optional[int] = None,
        stacks=(("spx", "ar"), ("dcqcn", "ecmp")),
        backend: str = "numpy",
        cache_dir: Optional[str] = None,
        json_out: Optional[str] = None,
        compile_cache_dir: Optional[str] = None,
        trace_out: Optional[str] = None,
        trace_every: int = 1) -> ResultSet:
    # the paper pairs stacks (SPX NIC + AR, DCQCN + ECMP); sweep each
    # pairing over seeds × scenarios rather than a nic × routing product
    cache = RunCache(cache_dir) if cache_dir else None
    if trace_out:
        os.makedirs(trace_out, exist_ok=True)
    merged: Optional[ResultSet] = None
    hits = misses = 0
    flights: List[dict] = []
    cc_before = (compile_cache_entries(compile_cache_dir)
                 if compile_cache_dir else 0)

    def _all() -> None:
        nonlocal merged, hits, misses
        for nic, routing in stacks:
            exp = stack_experiment(scenarios, nic, routing, n_seeds,
                                   slots, trace_out=trace_out,
                                   trace_every=trace_every)
            rs = run_experiment(exp, processes=processes,
                                backend=backend, cache=cache,
                                compile_cache_dir=compile_cache_dir)
            hits += rs.cache_hits
            misses += rs.cache_misses
            if rs.flight:
                flights.append(rs.flight)
            if merged is None:
                merged = rs
            else:
                merged.extend(rs)

    us = timeit(_all, iters=1, warmup=0)
    rows = merged.to_metrics() if merged is not None else []
    n = max(len(rows), 1)
    for m in rows:
        emit(f"sweep.{m.scenario}.s{m.seed}.{m.nic}.{m.routing}", us / n,
             f"goodput={m.mean_goodput:.4f},"
             f"isolation={m.isolation_index:.3f},"
             f"recovery_slots={m.worst_recovery()},"
             f"sym_cv={m.symmetry_cv:.3f},"
             f"outliers={len(m.symmetry_outliers)}")
    # flight-recorder digest: executor wall time and dispatch counts per
    # stack, one line (greppable) regardless of stack count
    execs = [e for fl in flights for e in fl.get("executions", ())]
    if execs:
        wall = sum(e.get("wall_s", 0.0) for e in execs)
        disp = sum(e.get("dispatch_stats", {}).get("dispatches", 0)
                   for e in execs)
        comp = sum(e.get("dispatch_stats", {}).get("compiles", 0)
                   for e in execs)
        pts = sum(e.get("n_points", 0) for e in execs)
        line = (f"# flight: points={pts} exec_wall_s={wall:.3f} "
                f"hits={hits} misses={misses}")
        if backend == "jax":
            line += f" dispatches={disp} compiles={comp}"
        print(line, flush=True)
    if trace_out:
        n_files = len([f for f in os.listdir(trace_out)
                       if f.endswith(".npz")])
        print(f"# traces: {trace_out} ({n_files} npz + perfetto pairs)",
              flush=True)
    if cache is not None:
        print(f"# cache: hits={hits} misses={misses}", flush=True)
    if compile_cache_dir:
        after = compile_cache_entries(compile_cache_dir)
        print(f"# compile-cache: dir={compile_cache_dir} "
              f"entries={after} new={after - cc_before}", flush=True)
    if json_out and merged is not None:
        with open(json_out, "w", encoding="utf-8") as f:
            f.write(merged.to_json())
        print(f"# resultset: {json_out} ({len(merged)} rows)",
              flush=True)
    return merged if merged is not None else ResultSet()


def _parse_stack(s: str):
    nic, sep, routing = s.partition(":")
    if not sep or not nic or not routing:
        raise argparse.ArgumentTypeError(
            f"stack {s!r} must be nic:routing (e.g. spx:ar)")
    return nic, routing


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scenarios", nargs="+", default=list(DEFAULT_SCENARIOS),
                   choices=list_scenarios(), metavar="NAME")
    p.add_argument("--seeds", type=int, default=2)
    p.add_argument("--slots", type=int, default=200)
    p.add_argument("--processes", type=int, default=None)
    p.add_argument("--backend", default="numpy", choices=["numpy", "jax"],
                   help="numpy: process-pool; jax: batched vmap sweeps")
    p.add_argument("--stacks", nargs="+", type=_parse_stack,
                   default=[("spx", "ar"), ("dcqcn", "ecmp")],
                   metavar="NIC:ROUTING",
                   help="paired stacks to sweep (default spx:ar "
                        "dcqcn:ecmp)")
    p.add_argument("--cache-dir", default=None,
                   help="run-cache directory; re-runs serve completed "
                        "points from cache and resume interrupted grids")
    p.add_argument("--compile-cache-dir", default=None,
                   help="persistent XLA compilation cache (jax backend):"
                        " fused sweep programs survive process restarts")
    p.add_argument("--json-out", default=None,
                   help="write the merged ResultSet JSON here")
    p.add_argument("--trace-out", default=None, metavar="DIR",
                   help="enable HFT trace capture and write one npz + "
                        "Perfetto JSON per point into DIR")
    p.add_argument("--trace-every", type=int, default=1,
                   help="trace decimation: record every Nth slot "
                        "(paper's 100us-10ms knob; default 1)")
    args = p.parse_args(argv)
    print("name,us_per_call,derived")
    run(tuple(args.scenarios), n_seeds=args.seeds, slots=args.slots,
        processes=args.processes, stacks=tuple(args.stacks),
        backend=args.backend, cache_dir=args.cache_dir,
        json_out=args.json_out,
        compile_cache_dir=args.compile_cache_dir,
        trace_out=args.trace_out, trace_every=args.trace_every)


if __name__ == "__main__":
    main(sys.argv[1:])
