"""Scenario grid sweeps: seeds × stacks over named registry scenarios,
lowered onto `Experiment` definitions (scenario × seed axes per stack)
with an optional on-disk run cache and ResultSet JSON output.

CLI (also invoked by CI as a cached 2-point smoke):

  PYTHONPATH=src python -m benchmarks.scenario_sweep \
      --scenarios multi_tenant_50_50 flap_during_incast \
      --seeds 2 --slots 120 --processes 2 \
      --cache-dir /tmp/expcache --json-out sweep_resultset.json
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.experiments import (Axis, Experiment, ResultSet, RunCache,
                               compile_cache_entries, product,
                               run_experiment)
from repro.scenarios import list_scenarios

from .common import emit, timeit

DEFAULT_SCENARIOS = ("multi_tenant_50_50", "flap_during_incast",
                     "cascading_spine_loss", "straggler_failure_compound")


def stack_experiment(scenarios, nic: str, routing: str, n_seeds: int,
                     slots: Optional[int]) -> Experiment:
    """One stack's grid: scenario × seed, with the stack and horizon as
    single-value axes so they land in the ResultSet coordinates."""
    axes = [Axis("scenario", tuple(scenarios)),
            Axis("seed", tuple(range(n_seeds))),
            Axis("sim.nic", (nic,)),
            Axis("sim.routing", (routing,))]
    if slots:
        axes.append(Axis("sim.slots", (slots,)))
    return Experiment(name=f"scenario_sweep.{nic}.{routing}",
                      axes=product(*axes))


def run(scenarios=DEFAULT_SCENARIOS, n_seeds: int = 2,
        slots: Optional[int] = 200, processes: Optional[int] = None,
        stacks=(("spx", "ar"), ("dcqcn", "ecmp")),
        backend: str = "numpy",
        cache_dir: Optional[str] = None,
        json_out: Optional[str] = None,
        compile_cache_dir: Optional[str] = None) -> ResultSet:
    # the paper pairs stacks (SPX NIC + AR, DCQCN + ECMP); sweep each
    # pairing over seeds × scenarios rather than a nic × routing product
    cache = RunCache(cache_dir) if cache_dir else None
    merged: Optional[ResultSet] = None
    hits = misses = 0
    cc_before = (compile_cache_entries(compile_cache_dir)
                 if compile_cache_dir else 0)

    def _all() -> None:
        nonlocal merged, hits, misses
        for nic, routing in stacks:
            exp = stack_experiment(scenarios, nic, routing, n_seeds,
                                   slots)
            rs = run_experiment(exp, processes=processes,
                                backend=backend, cache=cache,
                                compile_cache_dir=compile_cache_dir)
            hits += rs.cache_hits
            misses += rs.cache_misses
            if merged is None:
                merged = rs
            else:
                merged.extend(rs)

    us = timeit(_all, iters=1, warmup=0)
    rows = merged.to_metrics() if merged is not None else []
    n = max(len(rows), 1)
    for m in rows:
        emit(f"sweep.{m.scenario}.s{m.seed}.{m.nic}.{m.routing}", us / n,
             f"goodput={m.mean_goodput:.4f},"
             f"isolation={m.isolation_index:.3f},"
             f"recovery_slots={m.worst_recovery()},"
             f"sym_cv={m.symmetry_cv:.3f},"
             f"outliers={len(m.symmetry_outliers)}")
    if cache is not None:
        print(f"# cache: hits={hits} misses={misses}", flush=True)
    if compile_cache_dir:
        after = compile_cache_entries(compile_cache_dir)
        print(f"# compile-cache: dir={compile_cache_dir} "
              f"entries={after} new={after - cc_before}", flush=True)
    if json_out and merged is not None:
        with open(json_out, "w", encoding="utf-8") as f:
            f.write(merged.to_json())
        print(f"# resultset: {json_out} ({len(merged)} rows)",
              flush=True)
    return merged if merged is not None else ResultSet()


def _parse_stack(s: str):
    nic, sep, routing = s.partition(":")
    if not sep or not nic or not routing:
        raise argparse.ArgumentTypeError(
            f"stack {s!r} must be nic:routing (e.g. spx:ar)")
    return nic, routing


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scenarios", nargs="+", default=list(DEFAULT_SCENARIOS),
                   choices=list_scenarios(), metavar="NAME")
    p.add_argument("--seeds", type=int, default=2)
    p.add_argument("--slots", type=int, default=200)
    p.add_argument("--processes", type=int, default=None)
    p.add_argument("--backend", default="numpy", choices=["numpy", "jax"],
                   help="numpy: process-pool; jax: batched vmap sweeps")
    p.add_argument("--stacks", nargs="+", type=_parse_stack,
                   default=[("spx", "ar"), ("dcqcn", "ecmp")],
                   metavar="NIC:ROUTING",
                   help="paired stacks to sweep (default spx:ar "
                        "dcqcn:ecmp)")
    p.add_argument("--cache-dir", default=None,
                   help="run-cache directory; re-runs serve completed "
                        "points from cache and resume interrupted grids")
    p.add_argument("--compile-cache-dir", default=None,
                   help="persistent XLA compilation cache (jax backend):"
                        " fused sweep programs survive process restarts")
    p.add_argument("--json-out", default=None,
                   help="write the merged ResultSet JSON here")
    args = p.parse_args(argv)
    print("name,us_per_call,derived")
    run(tuple(args.scenarios), n_seeds=args.seeds, slots=args.slots,
        processes=args.processes, stacks=tuple(args.stacks),
        backend=args.backend, cache_dir=args.cache_dir,
        json_out=args.json_out,
        compile_cache_dir=args.compile_cache_dir)


if __name__ == "__main__":
    main(sys.argv[1:])
