"""Scenario grid sweeps: seeds × routing × nic over named registry
scenarios, parallelized across processes by the scenario runner.

CLI (also invoked by CI as a 2-scenario smoke):

  PYTHONPATH=src python -m benchmarks.scenario_sweep \
      --scenarios multi_tenant_50_50 flap_during_incast \
      --seeds 2 --slots 120 --processes 2
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.scenarios import SweepGrid, list_scenarios, sweep_many

from .common import emit, timeit

DEFAULT_SCENARIOS = ("multi_tenant_50_50", "flap_during_incast",
                     "cascading_spine_loss", "straggler_failure_compound")


def run(scenarios=DEFAULT_SCENARIOS, n_seeds: int = 2,
        slots: Optional[int] = 200, processes: Optional[int] = None,
        stacks=(("spx", "ar"), ("dcqcn", "ecmp")),
        backend: str = "numpy") -> None:
    # the paper pairs stacks (SPX NIC + AR, DCQCN + ECMP); sweep each
    # pairing over seeds × scenarios rather than a nic × routing product
    rows: List = []

    def _all() -> None:
        for nic, routing in stacks:
            grid = SweepGrid(seeds=tuple(range(n_seeds)), nics=(nic,),
                             routings=(routing,), slots=slots)
            rows.extend(sweep_many(scenarios, grid, processes=processes,
                                   backend=backend))

    us = timeit(_all, iters=1, warmup=0)
    n = max(len(rows), 1)
    for m in rows:
        emit(f"sweep.{m.scenario}.s{m.seed}.{m.nic}.{m.routing}", us / n,
             f"goodput={m.mean_goodput:.4f},"
             f"isolation={m.isolation_index:.3f},"
             f"recovery_slots={m.worst_recovery()},"
             f"sym_cv={m.symmetry_cv:.3f},"
             f"outliers={len(m.symmetry_outliers)}")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scenarios", nargs="+", default=list(DEFAULT_SCENARIOS),
                   choices=list_scenarios(), metavar="NAME")
    p.add_argument("--seeds", type=int, default=2)
    p.add_argument("--slots", type=int, default=200)
    p.add_argument("--processes", type=int, default=None)
    p.add_argument("--backend", default="numpy", choices=["numpy", "jax"],
                   help="numpy: process-pool; jax: batched vmap sweeps")
    args = p.parse_args(argv)
    print("name,us_per_call,derived")
    run(tuple(args.scenarios), n_seeds=args.seeds, slots=args.slots,
        processes=args.processes, backend=args.backend)


if __name__ == "__main__":
    main(sys.argv[1:])
