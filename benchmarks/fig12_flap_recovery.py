"""Fig 12 — single host-plane link flap: hardware PLB recovers to 3/4 line
rate in <3 ms; a software LB (reaction above the NCCL layer) needs ~1 s —
~400x slower."""
from __future__ import annotations

import numpy as np

from repro.netsim import LeafSpine, Flow
from repro.netsim.sim import SimConfig, run_sim

from .common import emit


def run() -> None:
    slot_us = 100.0
    fail_slot = 50

    def events(t, topo):
        if t == fail_slot:
            topo.fail_access(1, 0)          # plane 1 of host 0 dies

    for name, nic, delay_ms in (("hw_plb", "spx", 0.0),
                                ("sw_lb", "swlb", 1000.0)):
        t = LeafSpine(n_leaves=2, n_spines=2, hosts_per_leaf=4, n_planes=4,
                      access_cap=0.25)   # NIC = 4 x (line/4) plane ports
        flows = [Flow(0, 4, 1.0)]
        slots = 600 if name == "hw_plb" else 12000
        r = run_sim(t, flows,
                    SimConfig(slots=slots, slot_us=slot_us, nic=nic,
                              routing="ar", sw_lb_delay_ms=delay_ms,
                              seed=6), events=events)
        g = r.goodput[:, 0]
        # recovery = first slot after failure with goodput >= 0.9 x the
        # 3-plane steady state (0.75 of original line rate)
        post = np.flatnonzero((np.arange(len(g)) > fail_slot) &
                              (g >= 0.9 * 0.75))
        rec_ms = ((post[0] - fail_slot) * slot_us / 1000.0
                  if len(post) else float("inf"))
        emit(f"fig12.flap_recovery.{name}", rec_ms * 1e3,
             f"recovery_ms={rec_ms:.2f},steady={g[-10:].mean():.3f},"
             f"pre_fail={g[fail_slot - 5]:.3f}")


if __name__ == "__main__":
    run()
