"""Fig 12 — single host-plane link flap: hardware PLB recovers to 3/4 line
rate in <3 ms; a software LB (reaction above the NCCL layer) needs ~1 s —
~400x slower.

The `fig12_flap_recovery` experiment zips the NIC stack with the horizon
and software-LB delay over the registry's 'fig12_plane_flap' scenario."""
from __future__ import annotations

from repro.experiments import get_experiment, run_experiment
from repro.experiments.library import STACK_NAMES

from .common import emit


def run() -> None:
    rs = run_experiment(get_experiment("fig12_flap_recovery"))
    for row in rs.rows():
        x = row["extra"]
        name = "hw_plb" if row["nic"] == "spx" else "sw_lb"
        emit(f"fig12.flap_recovery.{name}", x["recovery_ms"] * 1e3,
             f"recovery_ms={x['recovery_ms']:.2f},"
             f"steady={x['steady']:.3f},"
             f"pre_fail={x['pre_fail']:.3f}")


if __name__ == "__main__":
    run()
