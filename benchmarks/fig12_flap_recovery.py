"""Fig 12 — single host-plane link flap: hardware PLB recovers to 3/4 line
rate in <3 ms; a software LB (reaction above the NCCL layer) needs ~1 s —
~400x slower.

Setup comes from the scenario registry ('fig12_plane_flap'); the software
LB curve only swaps the NIC stack and lengthens the horizon."""
from __future__ import annotations

import numpy as np

from repro.scenarios import get_scenario, run_scenario

from .common import emit


def run() -> None:
    base = get_scenario("fig12_plane_flap")
    slot_us = base.sim.slot_us
    fail_slot = base.faults[0].start_slot

    for name, nic, delay_ms, slots in (("hw_plb", "spx", 0.0, 600),
                                       ("sw_lb", "swlb", 1000.0, 12000)):
        r = run_scenario(base.with_sim(nic=nic, slots=slots,
                                       sw_lb_delay_ms=delay_ms))
        g = r.goodput[:, 0]
        # recovery = first slot after failure with goodput >= 0.9 x the
        # 3-plane steady state (0.75 of original line rate)
        post = np.flatnonzero((np.arange(len(g)) > fail_slot) &
                              (g >= 0.9 * 0.75))
        rec_ms = ((post[0] - fail_slot) * slot_us / 1000.0
                  if len(post) else float("inf"))
        emit(f"fig12.flap_recovery.{name}", rec_ms * 1e3,
             f"recovery_ms={rec_ms:.2f},steady={g[-10:].mean():.3f},"
             f"pre_fail={g[fail_slot - 5]:.3f}")


if __name__ == "__main__":
    run()
