"""Roofline analysis from the dry-run artifacts (§Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOPs            [s]
  memory term     = HLO_bytes_per_device / HBM_bw                [s]
  collective term = collective_wire_bytes_per_device / ICI_bw    [s]
(plus MODEL_FLOPS = 6*N*D / 6*N_active*D and the useful-compute ratio).

HLO numbers are per-device (SPMD module); chips cancel out of the
assignment's formulas.  'bytes accessed' from the CPU HLO pass is an
upper bound on TPU HBM traffic (CPU applies fewer fusions) — stated in
EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_config

from .common import HBM_BW, ICI_BW, PEAK_FLOPS, emit

ICI_LINKS = 4          # v5e: 4 usable ICI links per chip in a 2D torus


def active_params(name: str) -> float:
    """N (dense) or N_active (MoE) — analytic from the config."""
    cfg = get_config(name)
    d, v = cfg.d_model, cfg.vocab
    n = v * d * (1 if cfg.tie_embeddings else 2)
    for pos, kind in enumerate(cfg.block_pattern * cfg.n_periods):
        pos = pos % cfg.pattern_len
        if kind == "m":
            din = cfg.ssm_heads * cfg.ssm_head_dim
            g, s = cfg.ssm_groups, cfg.ssm_state
            n += 2 * d * din + d * (2 * g * s) + d * cfg.ssm_heads + \
                din * d
        elif cfg.use_mla:
            n += d * cfg.q_lora + cfg.q_lora * cfg.n_heads * \
                (cfg.nope_head_dim + cfg.rope_head_dim)
            n += d * (cfg.kv_lora + cfg.rope_head_dim) + \
                cfg.kv_lora * cfg.n_heads * \
                (cfg.nope_head_dim + cfg.v_head_dim)
            n += cfg.n_heads * cfg.v_head_dim * d
        else:
            n += d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim + \
                cfg.n_heads * cfg.head_dim * d
        if cfg.is_moe_pos(pos) and cfg.moe_experts:
            per = (3 if True else 2) * d * cfg.moe_d_ff
            n += cfg.moe_topk * per + cfg.moe_shared * per
        elif cfg.d_ff:
            n += (3 if cfg.gated_mlp else 2) * d * cfg.d_ff
    for _ in range(cfg.n_prefix_layers):
        n += d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
        n += (3 if cfg.gated_mlp else 2) * d * cfg.d_ff
    return float(n)


def _attn_layer_counts(cfg):
    """(#full-attn layers, #window layers, #ssm layers) per model."""
    full = win = ssm = 0
    pattern = list(cfg.block_pattern) * cfg.n_periods
    for kind in pattern:
        if kind == "m":
            ssm += 1
        elif kind == "l":
            win += 1
        else:
            full += 1
    full += cfg.n_prefix_layers
    return full, win, ssm


def attention_flops(arch: str, shape_name: str) -> float:
    """Global attention-score/PV FLOPs (not captured by 6N*D)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    full, win, ssm = _attn_layer_counts(cfg)
    qk = cfg.n_heads * cfg.head_dim if not cfg.use_mla else \
        cfg.n_heads * (cfg.nope_head_dim + cfg.rope_head_dim)
    if shape.mode in ("train", "prefill"):
        # causal: S^2/2 per layer pair; window: S*W
        per_full = 4 * B * (S * S // 2) * qk
        per_win = 4 * B * S * min(cfg.window, S) * qk
        f = (per_full * full + per_win * win)
        if shape.mode == "train":
            f *= 3          # fwd + 2x bwd
        # SSD intra-chunk quadratic + state path
        if ssm:
            l = cfg.ssm_chunk
            din = cfg.ssm_heads * cfg.ssm_head_dim
            per_ssm = (2 * B * S * l * cfg.ssm_groups * cfg.ssm_state +
                       2 * B * S * l * din +
                       4 * B * S * din * cfg.ssm_state)
            f += per_ssm * ssm * (3 if shape.mode == "train" else 1)
        return f
    # decode: one token attends the whole cache
    per_full = 4 * B * S * qk
    per_win = 4 * B * min(cfg.window, S) * qk
    per_ssm = 4 * B * (cfg.ssm_heads * cfg.ssm_head_dim) * cfg.ssm_state
    return per_full * full + per_win * win + per_ssm * ssm


def model_flops(arch: str, shape_name: str, train: bool = False) -> float:
    """Useful FLOPs: 6/2 x N_active x tokens + attention term."""
    shape = SHAPES[shape_name]
    n_act = active_params(arch)
    attn = attention_flops(arch, shape_name)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens + attn
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens + attn
    return 2.0 * n_act * shape.global_batch + attn


def _kv_bytes_per_token(cfg) -> float:
    """KV-cache bytes per (token, all layers), bf16."""
    full, win, ssm = _attn_layer_counts(cfg)
    if cfg.use_mla:
        per = (cfg.kv_lora + cfg.rope_head_dim) * 2
        return per * (full + win)
    per = 2 * cfg.n_kv_heads * cfg.head_dim * 2
    return per * (full + win)      # window layers capped at W tokens


def analytic_hbm_bytes(arch: str, shape_name: str, chips: int) -> float:
    """Per-device HBM traffic model (TPU-fused program):

    train   ~ 12 passes over fp32 params+opt (fwd, bwd, remat, grad, Adam
              m/v r+w, param w) + ~8 passes over bf16 activations
    prefill ~ params once (bf16) + 4x activations + KV write
    decode  ~ params once + full KV-cache read (the decode bottleneck)
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    n = active_params(arch)
    # total (not active) params move through HBM for MoE weights
    n_total = _total_params(cfg)
    p_dev = n_total / min(chips, 256)          # pod-replicated
    d, L = cfg.d_model, cfg.n_layers
    full, win, ssm = _attn_layer_counts(cfg)
    if shape.mode == "train":
        tok_dev = B * S / chips
        act = 8.0 * tok_dev * d * L * 2
        return 12.0 * p_dev * 4 + act
    if shape.mode == "prefill":
        tok_dev = B * S / chips
        act = 4.0 * tok_dev * d * L * 2
        kv = tok_dev * _kv_bytes_per_token(cfg)
        return p_dev * 2 + act + kv
    # decode
    kv_tokens = (full * S + win * min(cfg.window, S)) * B / chips
    per_layer = ((cfg.kv_lora + cfg.rope_head_dim) * 2 if cfg.use_mla
                 else 2 * cfg.n_kv_heads * cfg.head_dim * 2)
    kv = kv_tokens * per_layer
    ssm_state = (ssm * B * cfg.ssm_heads * cfg.ssm_head_dim *
                 cfg.ssm_state * 4 * 2) / chips
    return p_dev * 2 + kv + ssm_state + B * d * L * 2 * 8 / chips


def _total_params(cfg) -> float:
    shapes = None
    import jax as _jax
    from repro.models import init_params as _ip
    shapes = _jax.eval_shape(lambda: _ip(_jax.random.PRNGKey(0), cfg))
    return float(sum(int(np.prod(x.shape))
                     for x in _jax.tree.leaves(shapes)))


def load_cells(out_dir: str = "experiments/dryrun") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    mesh_kind = rec["mesh"]
    chips = 512 if mesh_kind == "multi" else 256
    cost = rec.get("acct_cost") or rec.get("cost") or {}
    coll = rec.get("acct_collective_wire_bytes",
                   rec.get("collective_wire_bytes", 0.0))
    flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    t_comp = flops / PEAK_FLOPS
    # memory term: analytic fused-program HBM traffic (the CPU HLO
    # 'bytes accessed' is fusion-blind and 10-100x inflated; kept as an
    # upper bound only)
    mem_bytes = analytic_hbm_bytes(rec["arch"], rec["shape"], chips)
    t_mem = mem_bytes / HBM_BW
    t_coll = float(coll) / (ICI_BW * ICI_LINKS)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    mf_dev = mf / chips
    ratio = min(mf_dev / flops, 1.0) if flops else 0.0
    bound = max(terms.values())
    frac = (mf_dev / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": mesh_kind,
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dom,
        "model_flops_per_dev": mf_dev, "hlo_flops_per_dev": flops,
        "hlo_bytes_upper_s": hlo_bytes / HBM_BW,
        "useful_ratio": ratio, "roofline_fraction": min(frac, 1.0),
    }


def run() -> None:
    cells = load_cells()
    rows = [r for r in (roofline_row(c) for c in cells) if r]
    for r in sorted(rows, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        emit(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
             r["t_compute_s"] * 1e6,
             f"mem_us={r['t_memory_s'] * 1e6:.1f},"
             f"coll_us={r['t_collective_s'] * 1e6:.1f},"
             f"dominant={r['dominant']},"
             f"useful={r['useful_ratio']:.2f},"
             f"roofline_frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    run()
