"""Shared benchmark plumbing: timing, CSV emission, hardware constants."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

# TPU v5e target (per chip)
PEAK_FLOPS = 197e12            # bf16
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s/link
LINE_RATE_GBPS = 400.0         # per simulated NIC port (SPX testbed scale)

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def pctl(x, q) -> float:
    return float(np.quantile(np.asarray(x), q))
