"""Fig 1c — leaf-to-leaf max-flow distribution under uniform random link
failures, computed on the WHOLE fabric (`maxflow_matrix` sums across
planes — a P-plane fabric's max-flow is P× a single plane's, so the
multiplane claims are no longer evaluated on 1/P of the capacity).

Three fabrics at ~32K endpoints:
  * the paper's single-plane leaf–spine;
  * an equal-capacity 4-plane multiplane split (each plane 1/4 of the
    links — degradation should stay capacity-proportional, §6.4);
  * an equal-bisection 3-tier fat-tree baseline, where a failed link can
    strand capacity behind the surviving stage (min-cut mismatch), so
    the tail degrades *worse* than capacity-proportional.
"""
from __future__ import annotations

import numpy as np

from repro.netsim.topology import FatTree, LeafSpine, maxflow_matrix

from .common import emit, pctl


def _emit_dist(tag: str, t, frac: float) -> None:
    rng = np.random.default_rng(7)
    if frac:
        t.random_link_failures(rng, frac)
    mf = maxflow_matrix(t)          # all planes (generalized path)
    L = t.n_leaves
    off = ~np.eye(L, dtype=bool)
    vals = mf[off] / mf.max()
    emit(f"fig1c.maxflow.{tag}.fail{int(frac * 100)}pct", 0.0,
         f"min={vals.min():.3f},p01={pctl(vals, 0.01):.3f},"
         f"median={np.median(vals):.3f}")


def run() -> None:
    for frac in (0.0, 0.01, 0.03, 0.05, 0.10):
        # 32K endpoints: 256 leaves x 128 hosts, 128 spines
        _emit_dist("plane1", LeafSpine(n_leaves=256, n_spines=128,
                                       hosts_per_leaf=128), frac)
        # equal capacity, split 4 ways into independent planes
        _emit_dist("plane4", LeafSpine(n_leaves=256, n_spines=32,
                                       hosts_per_leaf=128, n_planes=4),
                   frac)
        # equal-bisection 3-tier baseline: 16 pods x 16 leaves with the
        # same 128-unit-link leaf granularity, but the core tier
        # concentrated into 16x-capacity links — the hierarchy's blast
        # radius: one core-link failure strands a whole agg path
        _emit_dist("fat_tree", FatTree(n_pods=16, leaves_per_pod=16,
                                       n_aggs=128, n_cores=128,
                                       hosts_per_leaf=128,
                                       core_link_cap=16.0), frac)


if __name__ == "__main__":
    run()
