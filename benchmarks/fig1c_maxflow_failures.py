"""Fig 1c — leaf-to-leaf max-flow distribution under uniform random link
failures (32K-endpoint leaf-spine)."""
from __future__ import annotations

import numpy as np

from repro.netsim.topology import LeafSpine, maxflow_matrix

from .common import emit, pctl


def run() -> None:
    # 32K endpoints: 256 leaves x 128 hosts, 128 spines
    for frac in (0.0, 0.01, 0.03, 0.05, 0.10):
        t = LeafSpine(n_leaves=256, n_spines=128, hosts_per_leaf=128)
        rng = np.random.default_rng(7)
        if frac:
            t.random_link_failures(rng, frac)
        mf = maxflow_matrix(t)
        off = ~np.eye(256, dtype=bool)
        vals = mf[off] / mf.max()
        emit(f"fig1c.maxflow.fail{int(frac * 100)}pct", 0.0,
             f"min={vals.min():.3f},p01={pctl(vals, 0.01):.3f},"
             f"median={np.median(vals):.3f}")


if __name__ == "__main__":
    run()
