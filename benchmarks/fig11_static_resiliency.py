"""Fig 1d / Fig 11 — All2All bandwidth under partial uplink failure on one
leaf: SPX (weighted-AR) degrades proportionally to remaining capacity; ETH
degrades non-proportionally (hash collisions on survivors + DCQCN
overreaction).  §6.4: at 10% fabric failures SPX keeps within 3-10% of the
capacity-proportional ideal.

Setup comes from the parameterized scenario factory
`fig11_partial_uplink(keep)` (registry entry 'fig11_degraded_leaf' is the
canonical keep=0.5 point)."""
from __future__ import annotations

from repro.scenarios import fig11_partial_uplink, run_scenario

from .common import emit


def run() -> None:
    n_hosts_used = 48
    for keep in (1.0, 0.75, 0.5, 0.25):
        base = fig11_partial_uplink(keep)
        for name, nic, routing in (("eth", "dcqcn", "ecmp"),
                                   ("spx", "spx", "war")):
            r = run_scenario(base.with_sim(nic=nic, routing=routing))
            per_rank = r.mean_goodput.reshape(n_hosts_used, -1).sum(1)
            # the degraded leaf's ranks gate the collective (§2.1)
            gated = float(r.mean_goodput.min() * (n_hosts_used - 1))
            emit(f"fig11.a2a.keep{int(keep * 100)}pct.{name}", 0.0,
                 f"bw_frac={per_rank.mean():.3f},"
                 f"cct_gated_bw={gated:.3f}")


if __name__ == "__main__":
    run()
