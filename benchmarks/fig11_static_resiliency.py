"""Fig 1d / Fig 11 — All2All bandwidth under partial uplink failure on one
leaf: SPX (weighted-AR) degrades proportionally to remaining capacity; ETH
degrades non-proportionally (hash collisions on survivors + DCQCN
overreaction).  §6.4: at 10% fabric failures SPX keeps within 3-10% of the
capacity-proportional ideal.

The surviving-uplink fraction is a `faults` axis of the
`fig11_static_resiliency` experiment (tuples from
`fig11_partial_uplink(keep)`), so the whole figure is one cached grid."""
from __future__ import annotations

from repro.experiments import get_experiment, run_experiment
from repro.experiments.library import STACK_NAMES

from .common import emit


def run() -> None:
    rs = run_experiment(get_experiment("fig11_static_resiliency"))
    for row in rs.rows():
        x = row["extra"]
        emit(f"fig11.a2a.keep{row['axis.faults']}pct."
             f"{STACK_NAMES[row['nic']]}", 0.0,
             f"bw_frac={x['bw_frac']:.3f},"
             f"cct_gated_bw={x['cct_gated_bw']:.3f}")


if __name__ == "__main__":
    run()
