"""Fig 1d / Fig 11 — All2All bandwidth under partial uplink failure on one
leaf: SPX (weighted-AR) degrades proportionally to remaining capacity; ETH
degrades non-proportionally (hash collisions on survivors + DCQCN
overreaction).  §6.4: at 10% fabric failures SPX keeps within 3-10% of the
capacity-proportional ideal."""
from __future__ import annotations

import numpy as np

from repro.netsim import LeafSpine, all2all
from repro.netsim.sim import SimConfig, run_sim

from .common import emit


def run() -> None:
    n_hosts_used = 48
    for keep in (1.0, 0.75, 0.5, 0.25):
        for name, nic, routing in (("eth", "dcqcn", "ecmp"),
                                   ("spx", "spx", "war")):
            t = LeafSpine(n_leaves=8, n_spines=8, hosts_per_leaf=8,
                          n_planes=1)
            # drop whole uplinks of leaf 0 (the paper systematically
            # disables discrete links — ECMP must rehash onto survivors)
            n_keep = max(1, round(t.n_spines * keep))
            for s in range(n_keep, t.n_spines):
                t.fail_uplink(0, 0, s)
            flows = all2all(t, range(n_hosts_used), group="main")
            r = run_sim(t, flows,
                        SimConfig(slots=400, nic=nic, routing=routing,
                                  seed=5))
            per_rank = r.mean_goodput.reshape(n_hosts_used, -1).sum(1)
            # the degraded leaf's ranks gate the collective (§2.1)
            gated = float(r.mean_goodput.min() * (n_hosts_used - 1))
            emit(f"fig11.a2a.keep{int(keep * 100)}pct.{name}", 0.0,
                 f"bw_frac={per_rank.mean():.3f},"
                 f"cct_gated_bw={gated:.3f}")


if __name__ == "__main__":
    run()
