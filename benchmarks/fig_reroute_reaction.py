"""Failure-reaction policies — detection latency and reroute speed.

The `reroute_reaction` experiment sweeps reaction mode (precomputed
backup-path failover vs post-detection ECMP re-randomization) x topology
kind x failure fraction x detection latency on the §6.4 10%-failure
operating point.  Emitted per row: the worst blackhole window converted
to microseconds (the paper's "<3 ms hardware failover vs ~1 s software
LB" axis), total blackholed bytes, and the p50 completion slot (for the
"7% inflation at 10% failures" check against the frac=0 rows)."""
from __future__ import annotations

from repro.experiments import get_experiment, run_experiment
from repro.scenarios import get_scenario

from .common import emit


def run() -> None:
    rs = run_experiment(get_experiment("reroute_reaction"))
    slot_us = {n: get_scenario(n).sim.slot_us
               for n in ("reroute_random_failures",
                         "reroute_random_failures_ft")}
    for row in rs.rows():
        name = row["axis.scenario"]
        kind = "ft" if name.endswith("_ft") else "ls"
        label = (f"reroute.{kind}.{row['axis.reaction.mode']}"
                 f".frac{row['axis.faults[0].frac']:g}"
                 f".det{row['axis.reaction.detect_slots']}")
        emit(label, row["reaction_slots"] * slot_us[name],
             f"blackholed={row['blackholed_bytes']:.1f},"
             f"p50_completion={row['extra']['p50_completion']:g},"
             f"goodput={row['mean_goodput']:.4f}")


if __name__ == "__main__":
    run()
