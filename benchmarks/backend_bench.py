"""Backend dispatch benchmark: megabatch vs per-group vs NumPy pool.

Runs the paper-style acceptance grid — routing × NIC stack × fault
fraction × seed over one registry scenario — through the three dispatch
paths and reports wall-clock, dispatch/compile counts, warm slots/sec,
and peak RSS:

  * **numpy_pool** — the reference engine over a `ProcessPoolExecutor`
    (one process per point);
  * **per_group**  — the PR 3 JAX path: one compiled program and one
    launch per (scenario, routing, nic, fault) structure, seeds vmapped
    (`jx_dispatch="group"`);
  * **megabatch**  — the fused path: the whole grid stacks into ONE
    `jit(vmap)`/pmap launch that compiles once, with per-element traced
    routing/NIC branch selection (`jx_dispatch="megabatch"`).

Each JAX path is timed cold (first call pays XLA compilation) and warm
(executable cache hit — the steady state of any repeated sweep).  The
machine-readable summary is written to `BENCH_backend.json` so CI can
assert the single-launch property (`megabatch.dispatches == 1`,
`megabatch.compiles == 1`) and track the perf trajectory as an
artifact.

CLI (CI runs the smoke variant):

  PYTHONPATH=src python -m benchmarks.backend_bench
  PYTHONPATH=src python -m benchmarks.backend_bench --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import time
from typing import Optional, Tuple

# one XLA host device per core, so the jax backend's batches shard
# across cores like the NumPy pool's workers do; must be set before JAX
# initializes (the runner imports it lazily, on first use)
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count="
        f"{os.cpu_count() or 1}").strip()

from repro.experiments import (Axis, Experiment, execute_points,  # noqa: E402
                               product)
from repro.scenarios import list_scenarios  # noqa: E402

from .common import emit

DEFAULT_SCENARIO = "flap_during_incast"
# the giga-scale single point (4096 hosts / 102,400 flows): the shape
# that forces the engine's sparse segment-summed aggregation path
LARGE_SCENARIO = "giga_fabric_storage"
DEFAULT_JSON = "BENCH_backend.json"
# the committed perf trajectory: the last blessed run of this benchmark,
# checked in at the repo root and regenerated whenever perf moves on
# purpose (CI's megabatch-smoke gate fails a >20% warm-throughput drop)
BASELINE_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_backend.json")
SCHEMA = 1


def bench_grid(scenario: str, routings, nics, fracs, n_seeds: int,
               slots: Optional[int]) -> Experiment:
    """The acceptance grid: routing × nic × fault-frac × seed (the
    fault-frac axis rescales the scenario's first fault in place;
    fault-less scenarios drop that axis rather than crash)."""
    from repro.scenarios import get_scenario

    axes = [Axis("sim.routing", tuple(routings)),
            Axis("sim.nic", tuple(nics))]
    if fracs and get_scenario(scenario).faults:
        axes.append(Axis("faults[0].frac", tuple(fracs)))
    axes.append(Axis("seed", tuple(range(n_seeds))))
    if slots:
        axes.append(Axis("sim.slots", (slots,)))
    return Experiment(name="backend_bench.grid", base=scenario,
                      axes=product(*axes))


def compare_baseline(out: dict, base: Optional[dict]) -> dict:
    """Fresh run vs the committed snapshot.  Only megabatch warm
    throughput is gated — cold time is dominated by XLA compile noise.
    Runs are comparable only on the same grid and device count; a
    mismatch reports `comparable: false` so CI skips instead of failing
    on cross-machine variance."""
    if base is None:
        return {"comparable": False, "reason": "no committed baseline"}
    if base.get("schema") != out["schema"]:
        return {"comparable": False,
                "reason": f"schema {base.get('schema')} != {out['schema']}"}
    if base.get("grid") != out["grid"]:
        return {"comparable": False, "reason": "grid differs"}
    if base.get("devices") != out["devices"]:
        return {"comparable": False,
                "reason": (f"devices {base.get('devices')} != "
                           f"{out['devices']}")}
    ref = base.get("megabatch", {}).get("warm_slots_per_s")
    if not ref:
        return {"comparable": False,
                "reason": "baseline has no megabatch.warm_slots_per_s"}
    cur = out["megabatch"]["warm_slots_per_s"]
    cmp = {"comparable": True, "reason": "",
           "baseline_warm_slots_per_s": ref,
           "warm_slots_per_s": cur, "ratio": cur / ref}
    # the giga-scale point's trajectory, when both the run and the
    # snapshot carry one for the same scenario/shape: warm-throughput
    # ratio plus the wall-clock ratio CI gates (>= 0.8 — a fresh run
    # may be at most 25% slower than the committed snapshot)
    lb, lo = base.get("large_scale"), out.get("large_scale")
    if lb and lo and lb.get("warm_slots_per_s") and (
            {k: lo.get(k) for k in ("scenario", "hosts", "flows",
                                    "slots", "x64")}
            == {k: lb.get(k) for k in ("scenario", "hosts", "flows",
                                       "slots", "x64")}):
        cmp["large_ratio"] = (lo["warm_slots_per_s"]
                              / lb["warm_slots_per_s"])
        if lb.get("wall_s") and lo.get("wall_s"):
            cmp["large_wall_ratio"] = lb["wall_s"] / lo["wall_s"]
    return cmp


def run_large(scenario: str = LARGE_SCENARIO,
              slots: Optional[int] = None, warm_iters: int = 2) -> dict:
    """Time the giga-scale single point (O(4k) hosts / O(100k) flows)
    through the megabatch path — cold (XLA compile) and warm.  At this
    shape `agg_mode_default` selects the sparse segment-summed
    aggregation, so this is the perf point that guards the kernelized
    hot path at scale."""
    import jax

    from repro.netsim.jx import dispatch_stats, reset_dispatch_stats
    from repro.netsim.jx.engine import agg_mode_default
    from repro.scenarios import get_scenario
    from repro.scenarios.compile import compile_scenario

    spec = get_scenario(scenario)
    if slots:
        spec = spec.with_sim(slots=slots)
    compiled = compile_scenario(spec)
    n_flows = len(compiled.flows)
    topo = spec.topo
    reset_dispatch_stats()
    t_all = time.perf_counter()
    execute_points([spec], backend="jax", jx_dispatch="megabatch")
    cold = time.perf_counter() - t_all
    stats = dispatch_stats()
    warm = _time_best(
        lambda: execute_points([spec], backend="jax",
                               jx_dispatch="megabatch"), iters=warm_iters)
    row = {"scenario": scenario, "hosts": topo.n_hosts,
           "flows": n_flows, "planes": topo.n_planes,
           "slots": spec.sim.slots,
           "x64": bool(jax.config.jax_enable_x64),
           "agg_mode": agg_mode_default(topo.n_hosts, topo.n_leaves,
                                        topo.n_paths, topo.n_planes),
           "cold_s": cold, "warm_s": warm,
           "wall_s": time.perf_counter() - t_all,
           "peak_rss_mb": peak_rss_mb(),
           "dispatches": stats["dispatches"],
           "compiles": stats["compiles"],
           "warm_slots_per_s": spec.sim.slots / max(warm, 1e-9)}
    emit(f"backend_bench.large.{scenario}", warm * 1e6,
         f"hosts={topo.n_hosts},flows={n_flows},cold_s={cold:.2f},"
         f"warm_s={warm:.2f},agg={row['agg_mode']},"
         f"slots_per_s={row['warm_slots_per_s']:.1f},"
         f"rss_mb={row['peak_rss_mb']:.0f}")
    return row


def peak_rss_mb() -> float:
    """Peak resident set of this process in MiB (`ru_maxrss` is KiB on
    Linux but bytes on macOS)."""
    unit = 1 if sys.platform == "darwin" else 1024
    return (resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * unit
            / 2**20)


def _time_best(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(scenario: str = DEFAULT_SCENARIO,
        routings: Tuple[str, ...] = ("ar", "war", "ecmp"),
        nics: Tuple[str, ...] = ("spx", "dcqcn", "global", "esr", "swlb"),
        fracs: Tuple[float, ...] = (0.2, 0.4, 0.6, 0.8),
        n_seeds: int = 2, slots: Optional[int] = None,
        processes: Optional[int] = None, with_numpy: bool = True,
        json_out: Optional[str] = DEFAULT_JSON,
        baseline: Optional[str] = BASELINE_PATH,
        large: bool = False,
        large_slots: Optional[int] = None) -> dict:
    from repro.netsim.jx import dispatch_stats, reset_dispatch_stats

    # read the committed snapshot up front — json_out may legitimately
    # point at the same file (CI regenerates the baseline in place)
    base = None
    if baseline and os.path.exists(baseline):
        with open(baseline, encoding="utf-8") as f:
            base = json.load(f)

    exp = bench_grid(scenario, routings, nics, fracs, n_seeds, slots)
    points = [p.spec for p in exp.points()]
    n_points = len(points)
    spec_slots = points[0].sim.slots
    total_slots = n_points * spec_slots
    swept_fracs = "faults[0].frac" in exp.coord_names()
    grid_desc = {"scenario": scenario, "routings": list(routings),
                 "nics": list(nics),
                 "fault_fracs": list(fracs) if swept_fracs else [],
                 "seeds": n_seeds, "slots": spec_slots,
                 "points": n_points}

    out = {"schema": SCHEMA, "grid": grid_desc,
           "devices": int(os.cpu_count() or 1)}

    # numpy first: the process pool must fork before JAX spins up its
    # thread pools in this process
    rows = {}
    if with_numpy:
        t0 = time.perf_counter()
        rows["numpy"] = execute_points(points, processes=processes,
                                       backend="numpy")
        t_np = time.perf_counter() - t0
        out["numpy_pool"] = {"warm_s": t_np,
                             "slots_per_s": total_slots / max(t_np, 1e-9)}
        emit(f"backend_bench.{scenario}.numpy_pool", t_np * 1e6,
             f"wall_s={t_np:.3f},points={n_points}")

    for mode in ("group", "megabatch"):
        reset_dispatch_stats()
        t0 = time.perf_counter()
        rows[mode] = execute_points(points, backend="jax",
                                    jx_dispatch=mode)
        cold = time.perf_counter() - t0
        cold_stats = dispatch_stats()
        reset_dispatch_stats()
        warm = _time_best(
            lambda m=mode: execute_points(points, backend="jax",
                                          jx_dispatch=m), iters=3)
        warm_stats = dispatch_stats()
        key = "per_group" if mode == "group" else "megabatch"
        out[key] = {
            "cold_s": cold, "warm_s": warm,
            "compile_s": max(0.0, cold - warm),
            "dispatches": cold_stats["dispatches"],
            "compiles": cold_stats["compiles"],
            "warm_compiles": warm_stats["compiles"],
            "warm_slots_per_s": total_slots / max(warm, 1e-9),
        }
        emit(f"backend_bench.{scenario}.{key}", warm * 1e6,
             f"cold_s={cold:.3f},warm_s={warm:.3f},"
             f"dispatches={cold_stats['dispatches']},"
             f"compiles={cold_stats['compiles']},"
             f"slots_per_s={total_slots / max(warm, 1e-9):.0f}")

    # dispatch-path agreement (float32 jitter tolerated via the 4dp CSV
    # rounding; exact 1e-5 x64 row-identity is tests/test_megabatch.py's
    # job)
    mism = sum(a.to_row() != b.to_row()
               for a, b in zip(rows["group"], rows["megabatch"]))
    out["row_mismatches_group_vs_megabatch"] = int(mism)
    out["speedup_warm_vs_per_group"] = (
        out["per_group"]["warm_s"] / max(out["megabatch"]["warm_s"],
                                         1e-9))
    if with_numpy:
        out["speedup_warm_vs_numpy"] = (
            out["numpy_pool"]["warm_s"] / max(out["megabatch"]["warm_s"],
                                              1e-9))
    out["peak_rss_bytes"] = int(peak_rss_mb() * 2**20)
    emit(f"backend_bench.{scenario}.speedup", 0.0,
         f"megabatch_vs_per_group={out['speedup_warm_vs_per_group']:.2f}x"
         + (f",megabatch_vs_numpy={out['speedup_warm_vs_numpy']:.2f}x"
            if with_numpy else "")
         + f",row_mismatches={mism}")

    if large:
        out["large_scale"] = run_large(slots=large_slots)

    out["baseline"] = cmp = compare_baseline(out, base)
    if cmp["comparable"]:
        print(f"# bench baseline: ratio={cmp['ratio']:.3f} "
              f"(warm {cmp['warm_slots_per_s']:.0f} vs committed "
              f"{cmp['baseline_warm_slots_per_s']:.0f} slots/s)"
              + (f", large_ratio={cmp['large_ratio']:.3f}"
                 if "large_ratio" in cmp else ""),
              flush=True)
    else:
        print(f"# bench baseline: not comparable ({cmp['reason']})",
              flush=True)

    if json_out:
        with open(json_out, "w", encoding="utf-8") as f:
            json.dump(out, f, indent=2)
        print(f"# bench json: {json_out}", flush=True)
    return out


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scenario", default=DEFAULT_SCENARIO,
                   choices=list_scenarios())
    p.add_argument("--seeds", type=int, default=None,
                   help="seed-axis length (default 2)")
    p.add_argument("--routings", nargs="+", default=None,
                   help="default: ar war ecmp")
    p.add_argument("--nics", nargs="+", default=None,
                   help="default: all five stacks (smoke: spx dcqcn)")
    p.add_argument("--fracs", nargs="+", type=float, default=None,
                   help="fault-frac axis values (default .2 .4 .6 .8; "
                        "smoke: .3 .5 .8)")
    p.add_argument("--slots", type=int, default=None,
                   help="override spec slots (default: spec's own; "
                        "smoke: 120)")
    p.add_argument("--processes", type=int, default=None,
                   help="numpy pool size (default: min(points, cpus))")
    p.add_argument("--no-numpy", action="store_true",
                   help="skip the process-pool baseline")
    p.add_argument("--json-out", default=DEFAULT_JSON)
    p.add_argument("--baseline", default=BASELINE_PATH,
                   help="committed snapshot to compare against "
                        "(default: repo-root BENCH_backend.json; "
                        "'' disables)")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized defaults: 2 nics x 3 fracs x 2 "
                        "seeds, 120 slots (36 points); explicit flags "
                        "still win")
    p.add_argument("--large", action="store_true",
                   help="also time the giga-scale single point "
                        f"({LARGE_SCENARIO}: 4096 hosts, 102,400 "
                        "flows) through the sparse aggregation path")
    p.add_argument("--large-slots", type=int, default=None,
                   help="override the giga point's slot count")
    args = p.parse_args(argv)
    print("name,us_per_call,derived")
    # smoke only changes the *defaults* — explicit flags always win
    if args.smoke:
        nics, fracs, slots = ("spx", "dcqcn"), (0.3, 0.5, 0.8), 120
    else:
        nics = ("spx", "dcqcn", "global", "esr", "swlb")
        fracs, slots = (0.2, 0.4, 0.6, 0.8), None
    run(args.scenario,
        routings=tuple(args.routings or ("ar", "war", "ecmp")),
        nics=tuple(args.nics) if args.nics else nics,
        fracs=tuple(args.fracs) if args.fracs is not None else fracs,
        n_seeds=args.seeds if args.seeds is not None else 2,
        slots=args.slots if args.slots is not None else slots,
        processes=args.processes, with_numpy=not args.no_numpy,
        json_out=args.json_out, baseline=args.baseline or None,
        large=args.large, large_slots=args.large_slots)


if __name__ == "__main__":
    main(sys.argv[1:])
