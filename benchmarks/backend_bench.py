"""Backend benchmark: NumPy process-pool vs JAX batched scenario sweeps.

Runs the same (seeds x routings) grid of one registry scenario through
both backends and reports wall-clock, simulated slots/sec, and the
speedup.  The default grid is the paper's Fig 9 isolation scenario
(`fig9_victim_noise`, the registry port of `benchmarks/fig9_isolation`)
over 16 seeds x (ar, ecmp) — the acceptance workload for the JAX port.

The JAX backend is timed twice: cold (first call pays `jax.jit`
compilation, once per (scenario, routing, nic) structure) and warm
(compilation cache hit — the steady state for any sweep that reuses a
structure, i.e. every multi-seed study).

CLI (CI runs the smoke variant):

  PYTHONPATH=src python -m benchmarks.backend_bench
  PYTHONPATH=src python -m benchmarks.backend_bench --smoke
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Optional, Tuple

# one XLA host device per core, so the jax backend's (routing, nic)
# groups run concurrently like the NumPy pool's workers do; must be set
# before JAX initializes (the runner imports it lazily, on first use)
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count="
        f"{os.cpu_count() or 1}").strip()

from repro.scenarios import SweepGrid, list_scenarios, sweep  # noqa: E402

from .common import emit

DEFAULT_SCENARIO = "fig9_victim_noise"
DEFAULT_ROUTINGS = ("ar", "ecmp")
DEFAULT_SEEDS = 16


def run(scenario: str = DEFAULT_SCENARIO, n_seeds: int = DEFAULT_SEEDS,
        routings: Tuple[str, ...] = DEFAULT_ROUTINGS,
        slots: Optional[int] = None,
        processes: Optional[int] = None) -> dict:
    grid = SweepGrid(seeds=tuple(range(n_seeds)), routings=routings,
                     slots=slots)
    # numpy first: the process pool must fork before JAX spins up its
    # thread pools in this process
    t0 = time.perf_counter()
    rows_np = sweep(scenario, grid, processes=processes)
    t_np = time.perf_counter() - t0

    t0 = time.perf_counter()
    rows_jx = sweep(scenario, grid, backend="jax")
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep(scenario, grid, backend="jax")
    t_warm = time.perf_counter() - t0

    n_points = len(rows_np)
    total_slots = n_points * (slots or _spec_slots(scenario))
    for name, wall in (("numpy_pool", t_np), ("jax_cold", t_cold),
                       ("jax_warm", t_warm)):
        emit(f"backend_bench.{scenario}.{name}", wall * 1e6,
             f"wall_s={wall:.3f},points={n_points},"
             f"slots_per_s={total_slots / max(wall, 1e-9):.0f}")
    emit(f"backend_bench.{scenario}.speedup", 0.0,
         f"cold={t_np / max(t_cold, 1e-9):.2f}x,"
         f"warm={t_np / max(t_warm, 1e-9):.2f}x")
    # both backends must agree on what they simulated (goodput to 4 dp)
    mism = sum(a.to_row() != b.to_row()
               for a, b in zip(rows_np, rows_jx))
    emit(f"backend_bench.{scenario}.row_mismatches", float(mism),
         "numpy-vs-jax CSV rows (float32 jitter tolerated via "
         "4dp rounding; exact parity is the x64 test suite's job)")
    return {"numpy": t_np, "jax_cold": t_cold, "jax_warm": t_warm}


def _spec_slots(scenario: str) -> int:
    from repro.scenarios import get_scenario
    return get_scenario(scenario).sim.slots


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scenario", default=DEFAULT_SCENARIO,
                   choices=list_scenarios())
    p.add_argument("--seeds", type=int, default=DEFAULT_SEEDS)
    p.add_argument("--routings", nargs="+", default=list(DEFAULT_ROUTINGS))
    p.add_argument("--slots", type=int, default=None,
                   help="override spec slots (default: spec's own)")
    p.add_argument("--processes", type=int, default=None,
                   help="numpy pool size (default: min(points, cpus))")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized: 2 seeds, 100 slots")
    args = p.parse_args(argv)
    print("name,us_per_call,derived")
    if args.smoke:
        run(args.scenario, n_seeds=2, routings=tuple(args.routings),
            slots=100, processes=args.processes)
    else:
        run(args.scenario, n_seeds=args.seeds,
            routings=tuple(args.routings), slots=args.slots,
            processes=args.processes)


if __name__ == "__main__":
    main(sys.argv[1:])
