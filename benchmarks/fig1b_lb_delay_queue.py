"""Fig 1b — switch JSQ load-balancing decision delay vs queue depth
(slot-accurate 256-port microsimulation, 100 ns slots)."""
from __future__ import annotations

from repro.netsim.queuesim import jsq_delay_sim

from .common import emit


def run() -> None:
    base = None
    for delay_ns in (100, 500, 1000, 2500, 5000):
        r = jsq_delay_sim(n_ports=256, load=0.92,
                          decision_delay_ns=delay_ns, slots=40_000)
        if base is None:
            base = max(r.mean_queue, 1e-9)
        emit(f"fig1b.jsq.delay{delay_ns}ns", r.mean_delay_us,
             f"mean_queue={r.mean_queue:.2f}pkts,"
             f"growth_x={r.mean_queue / base:.1f}")


if __name__ == "__main__":
    run()
