"""Pallas kernel microbenchmarks vs their jnp oracles.

On this CPU container the kernels run in interpret mode, so the µs numbers
measure the oracle and the kernel-structure dispatch — the artifact that
matters for TPU is the BlockSpec tiling, benchmarked here for shape
coverage and numerics only.

`benchmarks/run.py` invokes this with a JSON artifact path, so every CI
bench run leaves a machine-readable `BENCH_kernels.json` next to the CSV
stream (schema: one `{name, us_per_call, derived}` row per kernel)."""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.kernels import link_load, ops, ref

from .common import emit, timeit

DEFAULT_JSON = "BENCH_kernels.json"
SCHEMA = 1


def run(json_out: Optional[str] = None) -> List[dict]:
    rows: List[dict] = []

    def bench(name: str, fn, iters: int, derived: str) -> None:
        us = timeit(lambda: jax.block_until_ready(fn()), iters=iters)
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": us, "derived": derived})

    key = jax.random.PRNGKey(0)
    B, H, S, D = 1, 4, 512, 128
    q = jax.random.normal(key, (B, H, S, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D))

    bench("kernels.flash_attention.ref_jnp",
          lambda: ref.flash_attention_ref(q, k, v), 3, f"B{B}H{H}S{S}D{D}")
    bench("kernels.flash_attention.pallas_interpret",
          lambda: ops.flash_attention(q, k, v, bq=128, bk=128), 1,
          "bq128_bk128")

    lengths = jnp.full((B,), S, jnp.int32)
    bench("kernels.decode_attention.pallas_interpret",
          lambda: ops.decode_attention(q[:, :, :1], k, v, lengths, bk=256),
          1, "bk256")

    queues = jax.random.uniform(key, (256,))
    up = jnp.ones(256)
    w = jnp.ones(256)
    h = jax.random.randint(key, (4096,), 0, 1 << 30).astype(jnp.uint32)
    bench("kernels.jsq_route.pallas_interpret",
          lambda: ops.jsq_route(queues, up, w, h), 2, "ports256_pkts4096")

    ra = jnp.ones(4) * 0.8
    el = jnp.ones(4)
    lq = jax.random.uniform(key, (4,))
    tx = jnp.full((4096,), 0.25)
    bench("kernels.plb_select.pallas_interpret",
          lambda: ops.plb_select(ra, el, lq, tx, h), 2, "planes4_pkts4096")

    x = jax.random.normal(key, (4096, 512))
    noise = jax.random.uniform(jax.random.fold_in(key, 3), x.shape,
                               minval=-0.5, maxval=0.5)
    bench("kernels.int8_encode.pallas_interpret",
          lambda: ops.int8_encode(x, noise), 2, "4096x512")

    # the simulator's sparse flow->link accumulation hot path: one
    # monolithic segment_sum over a giga-sized flow axis vs the same
    # population streamed through the chunked scatter-add
    F, P, n_links = 102_400, 2, 8192
    vals = jax.random.uniform(jax.random.fold_in(key, 4), (F, P))
    keys_fl = jax.random.randint(jax.random.fold_in(key, 5), (F, P), 0,
                                 n_links).astype(jnp.int32)
    seg = jax.jit(lambda a, b: link_load.segment_load(a, b, n_links))
    bench("kernels.segment_load.monolithic",
          lambda: seg(vals, keys_fl), 3, f"F{F}P{P}links{n_links}")
    ch = 4096
    vc = vals.reshape(F // ch, ch, P)
    kc = keys_fl.reshape(F // ch, ch, P)

    @jax.jit
    def chunked(vc, kc):
        acc = jnp.zeros((n_links,), vals.dtype)
        return jax.lax.scan(
            lambda a, xs: (link_load.segment_load_chunk(a, *xs), None),
            acc, (vc, kc))[0]

    bench("kernels.segment_load.chunked_scan",
          lambda: chunked(vc, kc), 3, f"chunk{ch}")

    cap = jnp.ones((P, n_links))
    load = jax.random.uniform(jax.random.fold_in(key, 6), (P, n_links))
    bot = jax.jit(lambda c, l: link_load.bottleneck(
        c, l, eps=1e-12, use_pallas=False))
    bench("kernels.bottleneck.ref_jnp", lambda: bot(cap, load), 3,
          f"P{P}links{n_links}")

    if json_out:
        with open(json_out, "w", encoding="utf-8") as f:
            json.dump({"schema": SCHEMA, "rows": rows}, f, indent=2)
        print(f"# bench json: {json_out}", flush=True)
    return rows


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--json-out", default=DEFAULT_JSON,
                   help="machine-readable artifact path ('' disables)")
    args = p.parse_args(argv)
    print("name,us_per_call,derived")
    run(json_out=args.json_out or None)


if __name__ == "__main__":
    main(sys.argv[1:])
