"""Pallas kernel microbenchmarks vs their jnp oracles.

On this CPU container the kernels run in interpret mode, so the µs numbers
measure the oracle and the kernel-structure dispatch — the artifact that
matters for TPU is the BlockSpec tiling, benchmarked here for shape
coverage and numerics only."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit, timeit


def run() -> None:
    key = jax.random.PRNGKey(0)
    B, H, S, D = 1, 4, 512, 128
    q = jax.random.normal(key, (B, H, S, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D))

    us = timeit(lambda: jax.block_until_ready(
        ref.flash_attention_ref(q, k, v)), iters=3)
    emit("kernels.flash_attention.ref_jnp", us, f"B{B}H{H}S{S}D{D}")
    us = timeit(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v, bq=128, bk=128)), iters=1)
    emit("kernels.flash_attention.pallas_interpret", us, "bq128_bk128")

    lengths = jnp.full((B,), S, jnp.int32)
    us = timeit(lambda: jax.block_until_ready(
        ops.decode_attention(q[:, :, :1], k, v, lengths, bk=256)), iters=1)
    emit("kernels.decode_attention.pallas_interpret", us, "bk256")

    queues = jax.random.uniform(key, (256,))
    up = jnp.ones(256)
    w = jnp.ones(256)
    h = jax.random.randint(key, (4096,), 0, 1 << 30).astype(jnp.uint32)
    us = timeit(lambda: jax.block_until_ready(
        ops.jsq_route(queues, up, w, h)), iters=2)
    emit("kernels.jsq_route.pallas_interpret", us, "ports256_pkts4096")

    ra = jnp.ones(4) * 0.8
    el = jnp.ones(4)
    lq = jax.random.uniform(key, (4,))
    tx = jnp.full((4096,), 0.25)
    us = timeit(lambda: jax.block_until_ready(
        ops.plb_select(ra, el, lq, tx, h)), iters=2)
    emit("kernels.plb_select.pallas_interpret", us, "planes4_pkts4096")

    x = jax.random.normal(key, (4096, 512))
    noise = jax.random.uniform(jax.random.fold_in(key, 3), x.shape,
                               minval=-0.5, maxval=0.5)
    us = timeit(lambda: jax.block_until_ready(
        ops.int8_encode(x, noise)), iters=2)
    emit("kernels.int8_encode.pallas_interpret", us, "4096x512")


if __name__ == "__main__":
    run()
