"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (fig1a_latency_all2all, fig1b_lb_delay_queue,
                   fig1c_maxflow_failures, fig8_bisection, fig9_isolation,
                   fig11_static_resiliency, fig12_flap_recovery,
                   fig14_large_scale, fig15_plane_lb, fig_reroute_reaction,
                   fig_train_comms, kernels_bench, roofline,
                   scenario_sweep)
    print("name,us_per_call,derived")
    # entries are callables so modules with artifacts can be passed
    # their output path (kernels_bench leaves BENCH_kernels.json)
    modules = [
        ("fig1a", fig1a_latency_all2all.run),
        ("fig1b", fig1b_lb_delay_queue.run),
        ("fig1c", fig1c_maxflow_failures.run),
        ("fig8", fig8_bisection.run),
        ("fig9/10", fig9_isolation.run),
        ("fig11", fig11_static_resiliency.run),
        ("fig12", fig12_flap_recovery.run),
        ("fig14", fig14_large_scale.run),
        ("fig15", fig15_plane_lb.run),
        ("train_comms", fig_train_comms.run),
        ("reroute", fig_reroute_reaction.run),
        ("kernels", lambda: kernels_bench.run(
            json_out=kernels_bench.DEFAULT_JSON)),
        ("roofline", roofline.run),
        ("scenarios", scenario_sweep.run),
    ]
    failed = []
    for name, fn in modules:
        try:
            fn()
        except Exception:                                  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED benchmarks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
