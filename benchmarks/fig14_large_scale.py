"""Fig 14 — large-scale resiliency (the paper's NSX compositional method).

(a) Fabric flaps, 64K single-plane 2-level FT: P99 CCT of 256-rank ring
collectives vs concurrent failed links k, expectation-weighted by the
Poisson pmf of concurrent failures (10 flaps/min fleet, 10 s duration).
The k sweep is the `fig14a_fabric_flaps` experiment — a `faults` axis of
exact-k random uplink kills, averaged over a seed axis.
(b) 256K multi-plane endpoint flaps: P99 CCT slowdown as a function of the
NIC's plane-failover convergence time (pristine/failed/degraded NIC-state
composition) — pure composition math, no fabric sim.

`--giga` adds (c): a *directly simulated* 4096-host / 102,400-flow
multiplane point (`giga_fabric_storage`) through the JAX engine's sparse
segment-summed aggregation path — the pristine fabric vs the same fabric
with 8 concurrent random link kills, fig14a's degradation question asked
of the full fluid simulation instead of the compositional proxy.

`--giga --full` widens (c) into the sweep the compositional method
approximates: k ∈ {0, 2, 4, 8} concurrent kills × a seed axis, all 12
giga-shape points fused by the streaming megabatch path into one
dispatch (and one compile) per shape bucket — the pristine timeline and
the faulted one — with host prep pipelined against device execution."""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.fault_tolerance import concurrent_failure_pmf
from repro.experiments import execute_points, get_experiment, run_experiment

from .common import emit


def run() -> None:
    # ---- (a) fabric flaps: expectation over the k-failure pmf ----
    pmf = concurrent_failure_pmf(flaps_per_minute=10, duration_s=10,
                                 max_k=10)
    rs = run_experiment(get_experiment("fig14a_fabric_flaps"))
    # p99 CCT per k, seed-averaged (slowest flow gates the collective)
    mean_cct = {key[0]: float(np.mean([r["extra"]["p99_cct"]
                                       for r in grp.rows()]))
                for key, grp in rs.group_by("axis.faults").items()}
    ks = sorted(mean_cct)
    cct_k = [mean_cct[k] for k in ks]
    cct0 = cct_k[0]
    expected = float(np.dot(pmf, cct_k))
    emit("fig14a.fabric_flaps.p99cct", 0.0,
         f"normalized={expected / cct0:.4f},worst_k10="
         f"{cct_k[-1] / cct0:.3f}")

    # ---- (b) endpoint flaps: paper's NIC-state composition ----
    # states: pristine (bw 1.0), failed (bw 0 until converged), degraded
    # (0.75 of line after convergence). One failure per 256-rank ring.
    flap_rate_per_s = 10.0 / 60.0
    duration_s = 10.0
    n_collectives, iters = 1024, 200
    cct_base = 1.0
    for conv_ms in (1, 10, 30, 100, 300):
        conv_s = conv_ms / 1000.0
        rng2 = np.random.default_rng(13)
        p99s = []
        for _ in range(iters):
            # fraction of collectives touched by >=1 flapped NIC this iter
            lam = flap_rate_per_s * (duration_s + conv_s)
            n_fail = rng2.poisson(lam * 16)       # fleet-scaled proxy
            ccts = np.full(n_collectives, cct_base)
            hit = rng2.choice(n_collectives, size=min(n_fail,
                                                      n_collectives),
                              replace=False)
            # during convergence the ring stalls; after, it runs at 0.75
            frac_stalled = conv_s / (conv_s + duration_s)
            cct_hit = frac_stalled * 60.0 + (1 - frac_stalled) / 0.75
            ccts[hit] = cct_hit
            p99s.append(np.quantile(ccts, 0.99))
        slow = float(np.mean(p99s))
        emit(f"fig14b.endpoint_flap.conv{conv_ms}ms", conv_ms * 1e3,
             f"p99cct_slowdown={slow:.2f}x")


def run_giga(slots: int = 0) -> None:
    """(c) the giga-scale point, simulated rather than composed: mean
    goodput of 102,400 storage flows over 4096 hosts with and without
    8 concurrent random fabric link kills, plus the wall clock the
    sparse aggregation path takes for each."""
    from dataclasses import replace

    from repro.scenarios import get_scenario

    spec = get_scenario("giga_fabric_storage")
    if slots:
        spec = spec.with_sim(slots=slots)
    pristine = replace(spec, faults=())
    t0 = time.perf_counter()
    out = execute_points([pristine, spec], backend="jax",
                         jx_dispatch="megabatch")
    wall = time.perf_counter() - t0
    g0, gk = out[0].mean_goodput, out[1].mean_goodput
    emit("fig14c.giga_sim.k8_random_kill", wall * 1e6,
         f"hosts=4096,flows=102400,goodput_pristine={g0:.4f},"
         f"goodput_k8={gk:.4f},degradation={gk / g0:.4f},"
         f"wall_s={wall:.1f}")


def run_giga_full(slots: int = 0, seeds=(0, 1, 2),
                  ks=(0, 2, 4, 8)) -> dict:
    """(c) at full sweep width: fig14a's k-concurrent-failure question
    asked of the directly simulated giga point.  k ∈ {0, 2, 4, 8}
    random fabric link kills × a fault/ECMP seed axis, every point at
    4096 hosts / 102,400 flows, fused by the megabatch path into one
    dispatch per shape bucket (the pristine timeline and the faulted
    one) with host prep pipelined against device execution.  Returns
    the summary dict it emits, so the CI smoke can assert on it."""
    from dataclasses import replace

    from repro.scenarios import get_scenario

    spec = get_scenario("giga_fabric_storage")
    if slots:
        spec = spec.with_sim(slots=slots)
    points = []
    for k in ks:
        for s in seeds:
            p = spec.with_sim(seed=s)
            # `random_fail` count=0 means "fail each link independently
            # with probability frac", not "zero concurrent failures" —
            # the pristine point drops the fault instead
            points.append(replace(
                p, faults=() if k == 0 else
                (replace(spec.faults[0], count=k),)))
    flight = {}
    t0 = time.perf_counter()
    out = execute_points(points, backend="jax", jx_dispatch="megabatch",
                         flight=flight)
    wall = time.perf_counter() - t0
    by_k = {}
    for p, m in zip(points, out):
        k = p.faults[0].count if p.faults else 0
        by_k.setdefault(k, []).append(m.mean_goodput)
    g0 = float(np.mean(by_k[ks[0]]))
    for k in ks:
        gk = float(np.mean(by_k[k]))
        emit(f"fig14c.giga_full.k{k}", wall * 1e6 / len(points),
             f"goodput={gk:.4f},degradation={gk / g0:.4f},"
             f"seeds={len(seeds)}")
    stats = flight.get("dispatch_stats", {})
    pipe = flight.get("pipeline", {})
    summary = {"points": len(points), "wall_s": wall,
               "dispatches": stats.get("dispatches"),
               "compiles": stats.get("compiles"),
               "launches": pipe.get("launches"),
               "pipelined": bool(pipe.get("pipelined")),
               "degradation": {k: float(np.mean(by_k[k]) / g0)
                               for k in ks}}
    emit("fig14c.giga_full.sweep", wall * 1e6,
         f"points={len(points)},wall_s={wall:.1f},"
         f"dispatches={summary['dispatches']},"
         f"compiles={summary['compiles']},"
         f"pipelined={summary['pipelined']}")
    return summary


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--giga", action="store_true",
                   help="also simulate the 4096-host / 102,400-flow "
                        "point directly (JAX sparse aggregation path)")
    p.add_argument("--giga-only", action="store_true",
                   help="skip (a)/(b); just the giga sim point")
    p.add_argument("--giga-slots", type=int, default=0,
                   help="override the giga point's slot count")
    p.add_argument("--full", action="store_true",
                   help="with --giga: the full k x seed sweep (k in "
                        "{0,2,4,8} x 3 seeds), one pipelined megabatch "
                        "dispatch per shape bucket")
    args = p.parse_args(argv)
    if not args.giga_only:
        run()
    if args.giga or args.giga_only:
        if args.full:
            run_giga_full(slots=args.giga_slots)
        else:
            run_giga(slots=args.giga_slots)


if __name__ == "__main__":
    main(sys.argv[1:])
