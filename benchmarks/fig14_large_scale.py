"""Fig 14 — large-scale resiliency (the paper's NSX compositional method).

(a) Fabric flaps, 64K single-plane 2-level FT: P99 CCT of 256-rank ring
collectives vs concurrent failed links k, expectation-weighted by the
Poisson pmf of concurrent failures (10 flaps/min fleet, 10 s duration).
(b) 256K multi-plane endpoint flaps: P99 CCT slowdown as a function of the
NIC's plane-failover convergence time (pristine/failed/degraded NIC-state
composition)."""
from __future__ import annotations

import numpy as np

from repro.core.fault_tolerance import concurrent_failure_pmf
from repro.netsim import LeafSpine, ring_neighbors
from repro.netsim.sim import SimConfig, run_sim

from .common import emit, pctl


def _ring_p99_cct(t: LeafSpine, k_failed: int, rng) -> float:
    """P99 per-flow completion proxy for ring traffic with k random fabric
    link failures, AR routing (scaled-down proxy of the 64K sim)."""
    topo = t.copy()
    for _ in range(k_failed):
        topo.fail_uplink(0, rng.integers(topo.n_leaves),
                         rng.integers(topo.n_spines))
    hosts = rng.permutation(topo.n_hosts)[:64]
    flows = ring_neighbors(hosts)
    r = run_sim(topo, flows, SimConfig(slots=300, nic="spx", routing="war",
                                       seed=int(rng.integers(1 << 30))))
    gp = np.maximum(r.mean_goodput, 1e-3)
    return float(1.0 / np.quantile(gp, 0.01))      # slowest flow gates CCT


def run() -> None:
    rng = np.random.default_rng(11)
    base = LeafSpine(n_leaves=16, n_spines=16, hosts_per_leaf=8,
                     n_planes=1)
    pmf = concurrent_failure_pmf(flaps_per_minute=10, duration_s=10,
                                 max_k=10)
    cct_k = [_ring_p99_cct(base, k, rng) for k in range(11)]
    cct0 = cct_k[0]
    expected = float(np.dot(pmf, cct_k))
    emit("fig14a.fabric_flaps.p99cct", 0.0,
         f"normalized={expected / cct0:.4f},worst_k10="
         f"{cct_k[10] / cct0:.3f}")

    # ---- (b) endpoint flaps: paper's NIC-state composition ----
    # states: pristine (bw 1.0), failed (bw 0 until converged), degraded
    # (0.75 of line after convergence). One failure per 256-rank ring.
    flap_rate_per_s = 10.0 / 60.0
    duration_s = 10.0
    n_collectives, iters = 1024, 200
    cct_base = 1.0
    for conv_ms in (1, 10, 30, 100, 300):
        conv_s = conv_ms / 1000.0
        rng2 = np.random.default_rng(13)
        p99s = []
        for _ in range(iters):
            # fraction of collectives touched by >=1 flapped NIC this iter
            lam = flap_rate_per_s * (duration_s + conv_s)
            n_fail = rng2.poisson(lam * 16)       # fleet-scaled proxy
            ccts = np.full(n_collectives, cct_base)
            hit = rng2.choice(n_collectives, size=min(n_fail,
                                                      n_collectives),
                              replace=False)
            # during convergence the ring stalls; after, it runs at 0.75
            frac_stalled = conv_s / (conv_s + duration_s)
            cct_hit = frac_stalled * 60.0 + (1 - frac_stalled) / 0.75
            ccts[hit] = cct_hit
            p99s.append(np.quantile(ccts, 0.99))
        slow = float(np.mean(p99s))
        emit(f"fig14b.endpoint_flap.conv{conv_ms}ms", conv_ms * 1e3,
             f"p99cct_slowdown={slow:.2f}x")


if __name__ == "__main__":
    run()
