"""Fig 14 — large-scale resiliency (the paper's NSX compositional method).

(a) Fabric flaps, 64K single-plane 2-level FT: P99 CCT of 256-rank ring
collectives vs concurrent failed links k, expectation-weighted by the
Poisson pmf of concurrent failures (10 flaps/min fleet, 10 s duration).
The k sweep is the `fig14a_fabric_flaps` experiment — a `faults` axis of
exact-k random uplink kills, averaged over a seed axis.
(b) 256K multi-plane endpoint flaps: P99 CCT slowdown as a function of the
NIC's plane-failover convergence time (pristine/failed/degraded NIC-state
composition) — pure composition math, no fabric sim."""
from __future__ import annotations

import numpy as np

from repro.core.fault_tolerance import concurrent_failure_pmf
from repro.experiments import get_experiment, run_experiment

from .common import emit


def run() -> None:
    # ---- (a) fabric flaps: expectation over the k-failure pmf ----
    pmf = concurrent_failure_pmf(flaps_per_minute=10, duration_s=10,
                                 max_k=10)
    rs = run_experiment(get_experiment("fig14a_fabric_flaps"))
    # p99 CCT per k, seed-averaged (slowest flow gates the collective)
    mean_cct = {key[0]: float(np.mean([r["extra"]["p99_cct"]
                                       for r in grp.rows()]))
                for key, grp in rs.group_by("axis.faults").items()}
    ks = sorted(mean_cct)
    cct_k = [mean_cct[k] for k in ks]
    cct0 = cct_k[0]
    expected = float(np.dot(pmf, cct_k))
    emit("fig14a.fabric_flaps.p99cct", 0.0,
         f"normalized={expected / cct0:.4f},worst_k10="
         f"{cct_k[-1] / cct0:.3f}")

    # ---- (b) endpoint flaps: paper's NIC-state composition ----
    # states: pristine (bw 1.0), failed (bw 0 until converged), degraded
    # (0.75 of line after convergence). One failure per 256-rank ring.
    flap_rate_per_s = 10.0 / 60.0
    duration_s = 10.0
    n_collectives, iters = 1024, 200
    cct_base = 1.0
    for conv_ms in (1, 10, 30, 100, 300):
        conv_s = conv_ms / 1000.0
        rng2 = np.random.default_rng(13)
        p99s = []
        for _ in range(iters):
            # fraction of collectives touched by >=1 flapped NIC this iter
            lam = flap_rate_per_s * (duration_s + conv_s)
            n_fail = rng2.poisson(lam * 16)       # fleet-scaled proxy
            ccts = np.full(n_collectives, cct_base)
            hit = rng2.choice(n_collectives, size=min(n_fail,
                                                      n_collectives),
                              replace=False)
            # during convergence the ring stalls; after, it runs at 0.75
            frac_stalled = conv_s / (conv_s + duration_s)
            cct_hit = frac_stalled * 60.0 + (1 - frac_stalled) / 0.75
            ccts[hit] = cct_hit
            p99s.append(np.quantile(ccts, 0.99))
        slow = float(np.mean(p99s))
        emit(f"fig14b.endpoint_flap.conv{conv_ms}ms", conv_ms * 1e3,
             f"p99cct_slowdown={slow:.2f}x")


if __name__ == "__main__":
    run()
