"""Fig 1a — impact of network latency on All2All collective bandwidth
(256-endpoint analytic model over the simulator's CCT law)."""
from __future__ import annotations

import numpy as np

from repro.netsim.workloads import all2all_cct_us, bus_bandwidth_gbps

from .common import LINE_RATE_GBPS, emit


def run() -> None:
    n = 256
    for lat_us in (4.0, 8.0, 16.0, 32.0, 64.0):
        for msg_mb in (1, 8, 64, 512):
            msg = msg_mb * (1 << 20)
            cct = all2all_cct_us(msg, n, LINE_RATE_GBPS, lat_us)
            bw = bus_bandwidth_gbps(msg, cct, n)
            emit(f"fig1a.all2all.lat{lat_us:g}us.msg{msg_mb}MB", cct,
                 f"busbw_gbps={bw:.1f}")


if __name__ == "__main__":
    run()
