"""Markdown report generator for EXPERIMENTS.md §Dry-run / §Roofline.

  PYTHONPATH=src python -m benchmarks.report > experiments/report.md
"""
from __future__ import annotations

import sys

import numpy as np

from .roofline import load_cells, roofline_row


def fmt_bytes(b) -> str:
    if not isinstance(b, (int, float)):
        return "-"
    return f"{b / (1 << 30):.2f}"


def main() -> None:
    cells = load_cells()
    print("## §Dry-run (per-device memory from the production compile)\n")
    print("| arch | shape | mesh | status | args GiB | temp GiB | "
          "compile s |")
    print("|---|---|---|---|---|---|---|")
    for c in sorted(cells, key=lambda x: (x["arch"], x["shape"],
                                          x["mesh"])):
        if c.get("skipped"):
            status = "SKIP (full-attn @500k)"
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {status} "
                  f"| - | - | - |")
            continue
        status = "OK" if c.get("ok") else "FAIL"
        mem = c.get("memory", {})
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {status} | "
              f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
              f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
              f"{c.get('compile_s', '-')} |")

    print("\n## §Roofline (single-pod 16x16; per-device terms, TPU v5e "
          "constants)\n")
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | MODEL/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    rows = [r for r in (roofline_row(c) for c in cells)
            if r and r["mesh"] == "single"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        print(f"| {r['arch']} | {r['shape']} | "
              f"{r['t_compute_s'] * 1e3:.2f} | "
              f"{r['t_memory_s'] * 1e3:.2f} | "
              f"{r['t_collective_s'] * 1e3:.2f} | {r['dominant']} | "
              f"{r['useful_ratio']:.2f} | "
              f"{r['roofline_fraction']:.3f} |")

    print("\n## Multi-pod pass/fail\n")
    multi = [c for c in cells if c["mesh"] == "multi"]
    ok = sum(1 for c in multi if c.get("ok") and not c.get("skipped"))
    skip = sum(1 for c in multi if c.get("skipped"))
    fail = [f"{c['arch']}/{c['shape']}" for c in multi
            if not c.get("ok") and not c.get("skipped")]
    print(f"- {ok} compiled, {skip} skipped (long_500k full-attention), "
          f"{len(fail)} failed {fail if fail else ''}")


if __name__ == "__main__":
    main()
