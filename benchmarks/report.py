"""Markdown report generator for EXPERIMENTS.md §Dry-run / §Roofline,
plus an observability section from the committed backend-bench snapshot
(`BENCH_backend.json`) and an optional ResultSet's flight-recorder
stats.

  PYTHONPATH=src python -m benchmarks.report > experiments/report.md
  PYTHONPATH=src python -m benchmarks.report --resultset sweep.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from .backend_bench import BASELINE_PATH
from .roofline import load_cells, roofline_row


def fmt_bytes(b) -> str:
    if not isinstance(b, (int, float)):
        return "-"
    return f"{b / (1 << 30):.2f}"


def print_observability(bench_path: str = BASELINE_PATH,
                        resultset_path: str | None = None) -> None:
    """The in-tree perf trajectory (committed bench snapshot) and, when
    a ResultSet JSON is given, its flight-recorder executor stats."""
    print("\n## Observability\n")
    if os.path.exists(bench_path):
        b = json.load(open(bench_path, encoding="utf-8"))
        g = b["grid"]
        print(f"Committed backend bench ({g['scenario']}, "
              f"{g['points']} points x {g['slots']} slots, "
              f"{b['devices']} device(s)):\n")
        print("| path | warm s | warm slots/s | dispatches | compiles |")
        print("|---|---|---|---|---|")
        np_row = b.get("numpy_pool")
        if np_row:
            print(f"| numpy_pool | {np_row['warm_s']:.3f} | "
                  f"{np_row['slots_per_s']:.0f} | - | - |")
        for key in ("per_group", "megabatch"):
            r = b.get(key)
            if r:
                print(f"| {key} | {r['warm_s']:.3f} | "
                      f"{r['warm_slots_per_s']:.0f} | "
                      f"{r['dispatches']} | {r['compiles']} |")
        print(f"\n- megabatch vs per-group warm: "
              f"{b['speedup_warm_vs_per_group']:.2f}x; peak RSS "
              f"{b['peak_rss_bytes'] / (1 << 20):.0f} MiB")
    else:
        print(f"- no committed bench snapshot at {bench_path}")
    if resultset_path:
        from repro.experiments import ResultSet

        rs = ResultSet.from_json(
            open(resultset_path, encoding="utf-8").read())
        fl = rs.flight
        if not fl:
            print(f"\n- {resultset_path}: no flight-recorder data")
            return
        print(f"\nFlight recorder ({resultset_path}, "
              f"experiment {fl.get('experiment')!r}): "
              f"{fl.get('cache_hits', 0)} cache hits, "
              f"{fl.get('cache_misses', 0)} misses\n")
        print("| backend | mode | points | wall s | dispatch stats |")
        print("|---|---|---|---|---|")
        for ex in fl.get("executions", ()):
            stats = ex.get("dispatch_stats")
            print(f"| {ex.get('backend')} | {ex.get('mode')} | "
                  f"{ex.get('n_points')} | {ex.get('wall_s', 0.0):.3f} | "
                  f"{stats if stats else '-'} |")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--resultset", default=None,
                   help="ResultSet JSON whose flight-recorder stats "
                        "join the observability section")
    args = p.parse_args()
    cells = load_cells()
    print("## §Dry-run (per-device memory from the production compile)\n")
    print("| arch | shape | mesh | status | args GiB | temp GiB | "
          "compile s |")
    print("|---|---|---|---|---|---|---|")
    for c in sorted(cells, key=lambda x: (x["arch"], x["shape"],
                                          x["mesh"])):
        if c.get("skipped"):
            status = "SKIP (full-attn @500k)"
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {status} "
                  f"| - | - | - |")
            continue
        status = "OK" if c.get("ok") else "FAIL"
        mem = c.get("memory", {})
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {status} | "
              f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
              f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
              f"{c.get('compile_s', '-')} |")

    print("\n## §Roofline (single-pod 16x16; per-device terms, TPU v5e "
          "constants)\n")
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | MODEL/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    rows = [r for r in (roofline_row(c) for c in cells)
            if r and r["mesh"] == "single"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        print(f"| {r['arch']} | {r['shape']} | "
              f"{r['t_compute_s'] * 1e3:.2f} | "
              f"{r['t_memory_s'] * 1e3:.2f} | "
              f"{r['t_collective_s'] * 1e3:.2f} | {r['dominant']} | "
              f"{r['useful_ratio']:.2f} | "
              f"{r['roofline_fraction']:.3f} |")

    print("\n## Multi-pod pass/fail\n")
    multi = [c for c in cells if c["mesh"] == "multi"]
    ok = sum(1 for c in multi if c.get("ok") and not c.get("skipped"))
    skip = sum(1 for c in multi if c.get("skipped"))
    fail = [f"{c['arch']}/{c['shape']}" for c in multi
            if not c.get("ok") and not c.get("skipped")]
    print(f"- {ok} compiled, {skip} skipped (long_500k full-attention), "
          f"{len(fail)} failed {fail if fail else ''}")

    print_observability(resultset_path=args.resultset)


if __name__ == "__main__":
    main()
