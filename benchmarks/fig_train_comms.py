"""Train-step co-simulation — compiled collective schedules (DP ring
sync, MoE all2all, PP edges, checkpoint writes) through the fabric.

The `train_comms_resiliency` experiment runs real-ModelConfig schedules
over a 4-plane leaf-spine: an access-plane flap landing in the DP sync
window inflates the derived step time, and the first post-heal step
recovers to near-baseline."""
from __future__ import annotations

from repro.experiments import get_experiment, run_experiment

from .common import emit


def run() -> None:
    rs = run_experiment(get_experiment("train_comms_resiliency"))
    for row in rs.rows():
        x = row["extra"]
        st = x["step_time_slots"]
        slot_us = 100.0                       # registry SimSpec slot_us
        emit(f"train_comms.{row['scenario']}", max(st) * slot_us,
             f"step_slots={[int(s) for s in st]},"
             f"inflation={x['step_inflation']:.3f},"
             f"last_ratio={x['last_step_ratio']:.3f},"
             f"period={x['step_period']}")


if __name__ == "__main__":
    run()
