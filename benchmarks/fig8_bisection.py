"""Fig 8 — RDMA bisection under maximum load: per-pair bandwidth
distribution and p99 latency, SPX (per-packet AR) vs ETH (ECMP+DCQCN).

Paper: SPX p01 = 98% of line rate, p99 latency 8-9 µs; ETH median 75% with
pairs collapsing to ~6%, p99 latency 13-22 µs.

Setup comes from the scenario registry ('fig8_bisection'); only the
NIC/routing stack varies per curve."""
from __future__ import annotations

import numpy as np

from repro.scenarios import get_scenario, run_scenario

from .common import emit, pctl, timeit


def run() -> None:
    base = get_scenario("fig8_bisection")
    for name, nic, routing in (("eth", "dcqcn", "ecmp"),
                               ("spx", "spx", "ar")):
        spec = base.with_sim(nic=nic, routing=routing)
        us = timeit(lambda: run_scenario(spec), iters=1, warmup=0)
        r = run_scenario(spec)
        gp = r.mean_goodput
        lat = r.rtt[r.rtt.shape[0] // 2:]
        emit(f"fig8.bisection.{name}", us,
             f"p01_bw={pctl(gp, 0.01):.3f},median_bw={np.median(gp):.3f},"
             f"p99_lat_us={pctl(lat, 0.99):.1f}")


if __name__ == "__main__":
    run()
