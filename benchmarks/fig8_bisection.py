"""Fig 8 — RDMA bisection under maximum load: per-pair bandwidth
distribution and p99 latency, SPX (per-packet AR) vs ETH (ECMP+DCQCN).

Paper: SPX p01 = 98% of line rate, p99 latency 8-9 µs; ETH median 75% with
pairs collapsing to ~6%, p99 latency 13-22 µs.

The sweep is the `fig8_bisection_stacks` experiment (registry scenario
'fig8_bisection' x the paired NIC/routing stacks)."""
from __future__ import annotations

import time

from repro.experiments import get_experiment, run_experiment
from repro.experiments.library import STACK_NAMES

from .common import emit


def run() -> None:
    t0 = time.perf_counter()
    rs = run_experiment(get_experiment("fig8_bisection_stacks"))
    us = (time.perf_counter() - t0) / max(len(rs), 1) * 1e6
    for row in rs.rows():
        x = row["extra"]
        emit(f"fig8.bisection.{STACK_NAMES[row['nic']]}", us,
             f"p01_bw={x['p01_bw']:.3f},median_bw={x['median_bw']:.3f},"
             f"p99_lat_us={x['p99_lat_us']:.1f}")


if __name__ == "__main__":
    run()
