"""Fig 8 — RDMA bisection under maximum load: per-pair bandwidth
distribution and p99 latency, SPX (per-packet AR) vs ETH (ECMP+DCQCN).

Paper: SPX p01 = 98% of line rate, p99 latency 8-9 µs; ETH median 75% with
pairs collapsing to ~6%, p99 latency 13-22 µs."""
from __future__ import annotations

import numpy as np

from repro.netsim import LeafSpine, bisection_pairs
from repro.netsim.sim import SimConfig, run_sim

from .common import emit, pctl, timeit


def run() -> None:
    rng = np.random.default_rng(0)
    t0 = LeafSpine(n_leaves=8, n_spines=8, hosts_per_leaf=8, n_planes=1)
    flows = bisection_pairs(t0, range(t0.n_hosts), rng)
    for name, nic, routing in (("eth", "dcqcn", "ecmp"),
                               ("spx", "spx", "ar")):
        us = timeit(lambda: run_sim(
            t0.copy(), flows,
            SimConfig(slots=600, nic=nic, routing=routing, seed=1)),
            iters=1, warmup=0)
        r = run_sim(t0.copy(), flows,
                    SimConfig(slots=600, nic=nic, routing=routing, seed=1))
        gp = r.mean_goodput
        lat = r.rtt[r.rtt.shape[0] // 2:]
        emit(f"fig8.bisection.{name}", us,
             f"p01_bw={pctl(gp, 0.01):.3f},median_bw={np.median(gp):.3f},"
             f"p99_lat_us={pctl(lat, 0.99):.1f}")


if __name__ == "__main__":
    run()
