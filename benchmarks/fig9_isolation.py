"""Fig 9 / Fig 10 — performance isolation.

(left) Single All2All: SPX reaches ~99.5% of theoretical capacity; ETH
peaks lower.  (right) Victim All2All (16 nodes) + noise All2All (48
nodes): ETH victim collapses ~80%; SPX is near-perfectly isolated.
(Fig 10) DeepSeek-V3-proxy training step time with and without RDMA
bisection noise: ETH degrades ~1.6x, SPX unchanged."""
from __future__ import annotations

import numpy as np

from repro.netsim import LeafSpine, all2all, bisection_pairs
from repro.netsim.sim import SimConfig, run_sim

from .common import emit


def _mean_gp(res, group):
    return res.group_mean(group)


def run() -> None:
    rng = np.random.default_rng(3)
    t0 = LeafSpine(n_leaves=8, n_spines=8, hosts_per_leaf=8, n_planes=1)

    # --- single All2All ---
    flows = all2all(t0, range(32), group="main")
    for name, nic, routing in (("eth", "dcqcn", "ecmp"),
                               ("spx", "spx", "ar")):
        r = run_sim(t0.copy(), flows,
                    SimConfig(slots=400, nic=nic, routing=routing, seed=2))
        # collective bw is gated by the slowest flow (stragglers, §2.1)
        gated = float(r.mean_goodput.min() * 31)
        per_rank = r.mean_goodput.reshape(32, 31).sum(1)
        emit(f"fig9.single_a2a.{name}", 0.0,
             f"rank_bw_frac={per_rank.mean():.3f},"
             f"cct_gated_bw={gated:.3f}")

    # --- victim + noise: ranks interleaved across leaves (the paper's
    # random-uniform placement), so they share uplinks ---
    victims = list(range(0, 64, 4))
    noise = [h for h in range(64) if h % 4 != 0]
    flows = (all2all(t0, victims, group="victim") +
             all2all(t0, noise, group="noise"))
    for name, nic, routing in (("eth", "dcqcn", "ecmp"),
                               ("spx", "spx", "ar")):
        r = run_sim(t0.copy(), flows,
                    SimConfig(slots=400, nic=nic, routing=routing, seed=2))
        vi = r.groups.index("victim")
        vflows = r.mean_goodput[r.group_of == vi]
        v = vflows.reshape(16, 15).sum(1)
        gated = float(vflows.min() * 15)
        emit(f"fig9.victim_a2a.{name}", 0.0,
             f"victim_bw_frac={v.mean():.3f},cct_gated_bw={gated:.3f}")

    # --- Fig 10: training step time under noise ---
    # step = compute + comm; comm bytes fixed, comm time = bytes / victim bw
    compute_ms, comm_ideal_ms = 400.0, 267.0   # 667 ms baseline split
    for name, nic, routing in (("eth", "dcqcn", "ecmp"),
                               ("spx", "spx", "ar")):
        for noisy in (False, True):
            fl = all2all(t0, victims, group="victim")
            if noisy:
                fl += bisection_pairs(t0, noise, rng, group="noise")
            r = run_sim(t0.copy(), fl,
                        SimConfig(slots=400, nic=nic, routing=routing,
                                  seed=4))
            vi = r.groups.index("victim")
            vflows = r.mean_goodput[r.group_of == vi]
            bw = max(float(vflows.min() * 15), 1e-3)   # straggler-gated
            step = compute_ms + comm_ideal_ms / bw
            tag = "noise" if noisy else "alone"
            emit(f"fig10.dsv3_step.{name}.{tag}", step * 1e3,
                 f"step_ms={step:.0f},victim_bw={bw:.3f}")


if __name__ == "__main__":
    run()
