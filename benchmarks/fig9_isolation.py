"""Fig 9 / Fig 10 — performance isolation.

(left) Single All2All: SPX reaches ~99.5% of theoretical capacity; ETH
peaks lower.  (right) Victim All2All (16 nodes) + noise All2All (48
nodes): ETH victim collapses ~80%; SPX is near-perfectly isolated.
(Fig 10) DeepSeek-V3-proxy training step time with and without RDMA
bisection noise: ETH degrades ~1.6x, SPX unchanged.

Setups come from the scenario registry ('fig9_single_all2all',
'fig9_victim_noise', 'fig10_victim_alone', 'fig10_victim_noise')."""
from __future__ import annotations

from repro.scenarios import get_scenario, run_scenario

from .common import emit

STACKS = (("eth", "dcqcn", "ecmp"), ("spx", "spx", "ar"))


def run() -> None:
    # --- single All2All ---
    base = get_scenario("fig9_single_all2all")
    for name, nic, routing in STACKS:
        r = run_scenario(base.with_sim(nic=nic, routing=routing))
        # collective bw is gated by the slowest flow (stragglers, §2.1)
        gated = float(r.mean_goodput.min() * 31)
        per_rank = r.mean_goodput.reshape(32, 31).sum(1)
        emit(f"fig9.single_a2a.{name}", 0.0,
             f"rank_bw_frac={per_rank.mean():.3f},"
             f"cct_gated_bw={gated:.3f}")

    # --- victim + noise: ranks interleaved across leaves (the paper's
    # random-uniform placement), so they share uplinks ---
    base = get_scenario("fig9_victim_noise")
    for name, nic, routing in STACKS:
        r = run_scenario(base.with_sim(nic=nic, routing=routing))
        vi = r.groups.index("victim")
        vflows = r.mean_goodput[r.group_of == vi]
        v = vflows.reshape(16, 15).sum(1)
        gated = float(vflows.min() * 15)
        emit(f"fig9.victim_a2a.{name}", 0.0,
             f"victim_bw_frac={v.mean():.3f},cct_gated_bw={gated:.3f}")

    # --- Fig 10: training step time under noise ---
    # step = compute + comm; comm bytes fixed, comm time = bytes / victim bw
    compute_ms, comm_ideal_ms = 400.0, 267.0   # 667 ms baseline split
    for name, nic, routing in STACKS:
        for noisy in (False, True):
            scen = ("fig10_victim_noise" if noisy
                    else "fig10_victim_alone")
            r = run_scenario(get_scenario(scen).with_sim(nic=nic,
                                                         routing=routing))
            vi = r.groups.index("victim")
            vflows = r.mean_goodput[r.group_of == vi]
            bw = max(float(vflows.min() * 15), 1e-3)   # straggler-gated
            step = compute_ms + comm_ideal_ms / bw
            tag = "noise" if noisy else "alone"
            emit(f"fig10.dsv3_step.{name}.{tag}", step * 1e3,
                 f"step_ms={step:.0f},victim_bw={bw:.3f}")


if __name__ == "__main__":
    run()
