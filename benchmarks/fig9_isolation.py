"""Fig 9 / Fig 10 — performance isolation.

(left) Single All2All: SPX reaches ~99.5% of theoretical capacity; ETH
peaks lower.  (right) Victim All2All (16 nodes) + noise All2All (48
nodes): ETH victim collapses ~80%; SPX is near-perfectly isolated.
(Fig 10) DeepSeek-V3-proxy training step time with and without RDMA
bisection noise: ETH degrades ~1.6x, SPX unchanged.

Sweeps are the `fig9_isolation` and `fig10_step_time` experiments
(scenario x stack grids over the registry entries)."""
from __future__ import annotations

from repro.experiments import get_experiment, run_experiment
from repro.experiments.library import STACK_NAMES

from .common import emit


def run() -> None:
    # --- Fig 9: single All2All ceiling + victim/noise isolation ---
    rs = run_experiment(get_experiment("fig9_isolation"))
    for row in rs.rows():
        name = STACK_NAMES[row["nic"]]
        x = row["extra"]
        if row["axis.scenario"] == "fig9_single_all2all":
            emit(f"fig9.single_a2a.{name}", 0.0,
                 f"rank_bw_frac={x['rank_bw_frac']:.3f},"
                 f"cct_gated_bw={x['cct_gated_bw']:.3f}")
        else:
            emit(f"fig9.victim_a2a.{name}", 0.0,
                 f"victim_bw_frac={x['victim_bw_frac']:.3f},"
                 f"cct_gated_bw={x['cct_gated_bw']:.3f}")

    # --- Fig 10: training step time under noise ---
    # step = compute + comm; comm bytes fixed, comm time = bytes / victim bw
    compute_ms, comm_ideal_ms = 400.0, 267.0   # 667 ms baseline split
    rs = run_experiment(get_experiment("fig10_step_time"))
    for row in rs.rows():
        name = STACK_NAMES[row["nic"]]
        bw = row["extra"]["victim_gated_bw"]   # straggler-gated
        step = compute_ms + comm_ideal_ms / bw
        tag = ("noise" if row["axis.scenario"] == "fig10_victim_noise"
               else "alone")
        emit(f"fig10.dsv3_step.{name}.{tag}", step * 1e3,
             f"step_ms={step:.0f},victim_bw={bw:.3f}")


if __name__ == "__main__":
    run()
