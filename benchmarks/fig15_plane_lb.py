"""Fig 15 — multiplane load balancing under noise-induced asymmetry
(the Fig 16 testbed: 4 planes, 3 leaves x 16 NICs; planes 2/3 degraded to
25% uplinks).

* per-plane CC (SPX PLB) vs a single Global CC context: Global CC
  collapses >40-50% under asymmetry; SPX stays near baseline.
* message-size convergence: short bursts end before the PLB accumulates
  per-plane congestion signals (fresh CC state per burst).
* ESR (entropy-based source routing): entangled CC+LB loops oscillate.
"""
from __future__ import annotations

import numpy as np

from repro.netsim import LeafSpine, all2all, one_to_many
from repro.netsim.fabric import Flow
from repro.netsim.sim import SimConfig, run_sim

from .common import emit


def _testbed(asym: bool) -> LeafSpine:
    # 16 NICs/leaf, 4 planes of 200G ports (access 0.25 x line), leaf
    # uplinks 16 x 200G per plane (2 spines x 8 parallel x 0.25)
    t = LeafSpine(n_leaves=3, n_spines=2, hosts_per_leaf=16, n_planes=4,
                  parallel_links=8, link_cap=0.25, access_cap=0.25)
    if asym:
        t.trim_leaf_uplinks(2, 1, 0.25)   # plane 2 / leaf 1 -> 4 links
        t.trim_leaf_uplinks(3, 2, 0.25)   # plane 3 / leaf 2 -> 4 links
    return t


def _main_noise_flows(t: LeafSpine, kind: str):
    mains, noises = [], []
    for leaf in range(3):
        base = leaf * 16
        mains += list(range(base, base + 8))
        noises += list(range(base + 8, base + 16))
    if kind == "one2many":
        fl = one_to_many(t, mains[:8], mains[8:], group="main")
    else:
        fl = all2all(t, mains, group="main")
    fl += all2all(t, noises, group="noise")
    return fl


def run() -> None:
    for kind in ("one2many", "all2all"):
        for name, nic in (("spx", "spx"), ("globalcc", "global")):
            for asym in (False, True):
                t = _testbed(asym)
                fl = _main_noise_flows(t, kind)
                r = run_sim(t, fl, SimConfig(slots=500, nic=nic,
                                             routing="ar", seed=8))
                mi = r.groups.index("main")
                flows_per_nic = 16 if kind == "one2many" else 23
                n_nics = 8 if kind == "one2many" else 24
                per_nic = r.mean_goodput[r.group_of == mi].reshape(
                    n_nics, -1).sum(1)
                tag = "asym" if asym else "base"
                emit(f"fig15.{kind}.{name}.{tag}", 0.0,
                     f"per_nic_bw={per_nic.mean():.3f}")

    # --- message-size convergence (fresh PLB state per burst) ---
    # ideal per-flow rate = NIC line / 16 destinations
    per_flow = 1.0 / 16
    for msg_slots in (5, 20, 80, 320):
        t = _testbed(True)
        fl = _main_noise_flows(t, "one2many")
        warm = 150          # noise saturates the degraded planes first
        for f in fl:
            if f.group == "main":
                f.bytes_total = msg_slots * per_flow
                f.start_slot = warm
        r = run_sim(t, fl, SimConfig(slots=8 * msg_slots + 2 * warm,
                                     nic="spx", routing="ar", seed=9,
                                     warmup_frac=0.0))
        mi = r.groups.index("main")
        comp = r.completion_slot[r.group_of == mi].astype(float)
        comp[comp < 0] = r.goodput.shape[0]
        comp -= warm
        ratio = msg_slots / max(float(np.mean(comp)), 1e-9)
        emit(f"fig15c.convergence.msg{msg_slots}slots", 0.0,
             f"normalized_bw={min(ratio, 1.0):.3f}")

    # --- ESR oscillation ---
    for name, nic in (("spx", "spx"), ("esr", "esr")):
        t = _testbed(True)
        fl = _main_noise_flows(t, "all2all")
        r = run_sim(t, fl, SimConfig(slots=600, nic=nic, routing="ar",
                                     seed=10))
        mi = r.groups.index("main")
        series = r.goodput[:, r.group_of == mi].sum(1)
        tail = series[len(series) // 2:]
        osc = float(tail.std() / max(tail.mean(), 1e-9))
        emit(f"fig15d.esr_oscillation.{name}", 0.0,
             f"bw_cv={osc:.3f},mean={tail.mean():.2f}")


if __name__ == "__main__":
    run()
